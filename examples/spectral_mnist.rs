//! The Fig. 3 workload as a runnable example: cluster the 10-class,
//! 10-dimensional spectral-embedding-like dataset (the MNIST-SC stand-in,
//! DESIGN.md §Substitutions) with k-means, CKM and QCKM, and print the
//! SSE/N + ARI comparison — one trial of the full `experiment fig3` grid.
//!
//! ```bash
//! cargo run --release --example spectral_mnist            # N = 70000
//! cargo run --release --example spectral_mnist -- --quick # N = 8000
//! ```

use qckm::experiments::{run_method_once, MethodRun};
use qckm::frequency::{FrequencyLaw, SigmaHeuristic};
use qckm::metrics::{adjusted_rand_index, assign_labels};
use qckm::prelude::*;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let n_samples = if quick { 8_000 } else { 70_000 };
    let (dim, k, m) = (10, 10, 1000);
    let mut rng = Rng::new(1);

    eprintln!("generating spectral-embedding-like data: N={n_samples}, n={dim}, K={k}");
    let data = qckm::data::spectral_embedding_like(n_samples, dim, k, &mut rng);
    let sigma = SigmaHeuristic::default().resolve(&data.points, &mut rng);

    // k-means (5 replicates, selected by SSE).
    let km = kmeans(
        &data.points,
        k,
        &KMeansParams {
            replicates: 5,
            ..Default::default()
        },
        &mut rng,
    );
    let km_ari = adjusted_rand_index(&km.labels, &data.labels);

    println!(
        "{:<10} {:>10} {:>8}   (m = {m} frequencies, sigma = {sigma:.3})",
        "method", "SSE/N", "ARI"
    );
    println!(
        "{:<10} {:>10.4} {:>8.3}",
        "k-means",
        km.sse / n_samples as f64,
        km_ari
    );

    for method in [MethodSpec::parse("ckm").unwrap(), MethodSpec::parse("qckm").unwrap()] {
        let run = MethodRun {
            method: method.clone(),
            m,
            replicates: if quick { 1 } else { 5 },
            sigma,
            law: FrequencyLaw::AdaptedRadius,
            params: Default::default(),
            decoder: Default::default(),
            streamed: false,
        };
        let out = run_method_once(&run, &data.points, Some(&data.labels), k, &mut rng);
        println!(
            "{:<10} {:>10.4} {:>8.3}",
            method.canonical(),
            out.sse / n_samples as f64,
            out.ari
        );
    }
    let _ = assign_labels(&data.points, &km.centroids); // doc: labels API
}
