//! The generalized-signature playground (Sec. 3 of the paper).
//!
//! Shows Prop. 1 in action: *any* admissible periodic signature — cosine,
//! 1-bit universal quantizer, triangle wave, multi-bit staircases — can
//! encode the sketch, as long as the argument is dithered and decoding uses
//! the first harmonic. For each signature we print its Fourier structure,
//! its Prop.-1 constants, and the centroid error decoding the *same*
//! 2-Dirac mixture from its sketch.
//!
//! ```bash
//! cargo run --release --example signature_zoo
//! ```

use qckm::frequency::{DrawnFrequencies, FrequencyLaw};
use qckm::prelude::*;
use qckm::signature::MultiBitQuantizer;
use std::sync::Arc;

fn main() {
    let signatures: Vec<Arc<dyn Signature>> = vec![
        Arc::new(Cosine),
        Arc::new(UniversalQuantizer),
        Arc::new(Triangle),
        Arc::new(MultiBitQuantizer::new(2)),
        Arc::new(MultiBitQuantizer::new(4)),
        // The odd one out: the self-reset ramp's first harmonic carries a
        // π/2 phase, which the decode atoms absorb (same row, same code).
        Arc::new(ModuloRamp),
    ];

    // A fixed 2-Dirac mixture to recover in 3-D.
    let truth = Mat::from_vec(2, 3, vec![1.0, -0.5, 0.8, -0.9, 0.7, -0.4]);
    let weights = [0.55, 0.45];

    println!(
        "{:<18} {:>8} {:>8} {:>10} {:>12}",
        "signature", "2|F1|", "C_f", "tail/c_P", "centroid err"
    );
    for sig in signatures {
        let mut rng = Rng::new(99);
        let freqs = DrawnFrequencies::draw(FrequencyLaw::AdaptedRadius, 3, 200, 1.0, &mut rng);
        let op = SketchOperator::new(freqs, sig.clone());

        // Encode P with the full signature (exact for a Dirac mixture)…
        let mut z = vec![0.0; op.sketch_len()];
        for (k, &a) in weights.iter().enumerate() {
            let e = op.encode_point(truth.row(k));
            qckm::linalg::axpy(a, &e, &mut z);
        }
        // …decode with first-harmonic atoms.
        let sol = ClOmpr::new(&op, 2)
            .with_bounds(vec![-2.0; 3], vec![2.0; 3])
            .run(&z, &mut rng);

        // Greedy match.
        let mut err: f64 = 0.0;
        let mut used = [false; 2];
        for t in 0..2 {
            let (mut best, mut bj) = (f64::INFINITY, 0);
            for j in 0..2 {
                if !used[j] {
                    let d = qckm::linalg::sq_dist(sol.centroids.row(j), truth.row(t));
                    if d < best {
                        best = d;
                        bj = j;
                    }
                }
            }
            used[bj] = true;
            err = err.max(best.sqrt());
        }

        println!(
            "{:<18} {:>8.4} {:>8.4} {:>10.4} {:>12.4}",
            sig.name(),
            sig.first_harmonic_amplitude(),
            sig.prop1_constant(),
            sig.tail_energy_ratio(),
            err
        );
    }
    println!("\n(the dithering + first-harmonic decode makes every row work — Prop. 1)");
}
