//! The paper's Fig. 1 deployment, end to end: a cloud of simulated 1-bit
//! sensors streams bit-packed sketch contributions through the Layer-3
//! coordinator; the leader pools them and decodes the cluster centroids —
//! the full dataset never exists in one place, and only `2M` bits per
//! example ever cross the wire.
//!
//! Also runs the same acquisition with the full-precision (CKM) wire format
//! to show the 64× acquisition-bandwidth gap.
//!
//! ```bash
//! cargo run --release --example streaming_sensor
//! ```

use qckm::coordinator::{run_pipeline, PipelineConfig, SampleSource, WireFormat};
use qckm::frequency::{DrawnFrequencies, FrequencyLaw, SigmaHeuristic};
use qckm::prelude::*;
use std::sync::Arc;

fn main() {
    let dim = 6;
    let k = 3;
    let total = 200_000;
    let m = 300;
    let mut rng = Rng::new(42);

    // The "physical field" each sensor observes: K Gaussian sources.
    let proto = qckm::data::gaussian_mixture_pm1(512, dim, k, &mut rng);
    let means = Arc::new(proto.means.clone());
    let std = (dim as f64 / 20.0).sqrt();
    let source = SampleSource::Synthetic {
        total,
        dim,
        make: Arc::new(move |r: &mut Rng, out: &mut [f64]| {
            let c = r.next_below(3) as usize;
            for (j, v) in out.iter_mut().enumerate() {
                *v = means.get(c, j) + std * r.gaussian();
            }
        }),
    };

    let sigma = SigmaHeuristic::default().resolve(&proto.points, &mut rng);
    let freqs = DrawnFrequencies::draw(FrequencyLaw::AdaptedRadius, dim, m, sigma, &mut rng);

    // ---- QCKM wire: 1 bit per measurement.
    let op_q = SketchOperator::quantized(freqs.clone());
    let cfg = PipelineConfig {
        workers: 8,
        batch_size: 128,
        queue_capacity: 16,
        wire: WireFormat::PackedBits,
    };
    let rep_q = run_pipeline(&op_q, &source, &cfg, 7);
    println!(
        "[bits ] {} samples via {} sensors in {:.2}s → {:.0} samples/s, {:.1} MB on the wire ({} stalls)",
        rep_q.samples,
        cfg.workers,
        rep_q.elapsed_secs,
        rep_q.throughput(),
        rep_q.payload_bytes as f64 / 1e6,
        rep_q.blocked_sends,
    );

    // ---- CKM wire: 64-bit floats per measurement (same frequencies).
    let op_c = SketchOperator::new(freqs, std::sync::Arc::new(Cosine));
    let rep_c = run_pipeline(
        &op_c,
        &source,
        &PipelineConfig {
            wire: WireFormat::DenseF64,
            ..cfg
        },
        7,
    );
    println!(
        "[dense] {} samples in {:.2}s → {:.0} samples/s, {:.1} MB on the wire",
        rep_c.samples,
        rep_c.elapsed_secs,
        rep_c.throughput(),
        rep_c.payload_bytes as f64 / 1e6,
    );
    println!(
        "acquisition bandwidth ratio (dense/bits): {:.0}×",
        rep_c.payload_bytes as f64 / rep_q.payload_bytes as f64
    );

    // ---- Decode from the 1-bit pooled sketch.
    let lo = vec![-3.0; dim];
    let hi = vec![3.0; dim];
    let sol = ClOmpr::new(&op_q, k)
        .with_bounds(lo, hi)
        .run(&rep_q.sketch, &mut rng);
    println!("decoded centroids from the 1-bit stream:");
    for i in 0..k {
        let c: Vec<String> = sol.centroids.row(i).iter().map(|v| format!("{v:+.2}")).collect();
        println!("  α={:.2} [{}]", sol.weights[i], c.join(", "));
    }
    assert_eq!(rep_q.samples, total as u64);
    // 64× up to the packed payload's word padding (2M bits round up to
    // whole u64 words: here 600 bits ship as 640).
    let ratio = rep_c.payload_bytes as f64 / rep_q.payload_bytes as f64;
    assert!(
        (55.0..=64.0).contains(&ratio),
        "dense/bits wire ratio {ratio} out of range"
    );
}
