//! Quickstart: the 60-second tour of the public API.
//!
//! Generates a small Gaussian mixture, acquires it as a 1-bit quantized
//! sketch (QCKM), decodes the centroids with CL-OMPR, and compares against
//! k-means — the whole paper in ~40 lines.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use qckm::prelude::*;

fn main() {
    let mut rng = Rng::new(0);

    // 1. A dataset: N = 10000 samples, K = 3 separated Gaussians in 2-D.
    let k = 3;
    let truth = Mat::from_vec(k, 2, vec![-2.0, 0.0, 2.0, 0.0, 0.0, 2.5]);
    let mut x = Mat::zeros(0, 2);
    for i in 0..10_000 {
        let c = i % k;
        x.push_row(&[
            truth.get(c, 0) + 0.35 * rng.gaussian(),
            truth.get(c, 1) + 0.35 * rng.gaussian(),
        ]);
    }

    // 2. Draw the sketch randomness: M frequencies + dither, bandwidth from
    //    the data heuristic.
    let m = 150;
    let sigma = SigmaHeuristic::default().resolve(&x, &mut rng);
    let freqs = DrawnFrequencies::draw(FrequencyLaw::AdaptedRadius, 2, m, sigma, &mut rng);

    // 3. QCKM acquisition: every example becomes 2M = 300 *bits*.
    let op = SketchOperator::quantized(freqs);
    let z = op.sketch_dataset(&x);
    println!(
        "sketched 10000 examples into {} real slots ({} bits/example on the wire)",
        z.len(),
        op.sketch_len()
    );

    // 4. Decode K centroids from the sketch alone (no data access).
    let (lo, hi) = qckm::linalg::bounding_box(&x);
    let sol = ClOmpr::new(&op, k).with_bounds(lo, hi).run(&z, &mut rng);
    println!("decoded centroids (weight):");
    for i in 0..k {
        println!(
            "  ({:+.2}, {:+.2})  ({:.2})",
            sol.centroids.get(i, 0),
            sol.centroids.get(i, 1),
            sol.weights[i]
        );
    }

    // 5. Compare with k-means on the full data.
    let km = kmeans(&x, k, &KMeansParams::default(), &mut rng);
    let qckm_sse = sse(&x, &sol.centroids);
    println!(
        "SSE: qckm = {:.1}, k-means = {:.1}  (success ≤ 1.2×: {})",
        qckm_sse,
        km.sse,
        qckm::metrics::is_success(qckm_sse, km.sse)
    );
    assert!(
        qckm::metrics::is_success(qckm_sse, km.sse),
        "quickstart should succeed on this easy mixture"
    );
}
