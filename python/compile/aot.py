"""AOT lowering: JAX/Pallas -> HLO text artifacts for the Rust runtime.

Run once per build (``make artifacts``); Python never executes on the Rust
request path. Emits, for the flagship shapes (B=256, n=10, M=1000 — the
Fig. 3 configuration):

  artifacts/sketch_qckm.hlo.txt   pooled 1-bit-quantized sketch (batch sum)
  artifacts/sketch_ckm.hlo.txt    pooled cosine sketch (batch sum)
  artifacts/decode_atoms.hlo.txt  decode-side cosine atoms (K=10)
  artifacts/manifest.txt          index consumed by qckm::runtime

HLO *text*, not serialized protos: jax >= 0.5 emits 64-bit instruction ids
that the image's xla_extension 0.5.1 rejects; the text parser reassigns ids
(see /opt/xla-example/README.md).
"""

import argparse
import os

import jax
import jax.numpy as jnp

from .model import lower_to_hlo_text, make_decode_atoms, make_sketch_sum

FLAGSHIP_BATCH = 256
FLAGSHIP_DIM = 10
FLAGSHIP_M = 1000
FLAGSHIP_K = 10


def build_artifacts(out_dir: str, batch: int, dim: int, m: int, k: int) -> list[str]:
    os.makedirs(out_dir, exist_ok=True)
    manifest_lines = ["# name kind batch dim m file"]

    x_spec = jax.ShapeDtypeStruct((batch, dim), jnp.float32)
    omega_spec = jax.ShapeDtypeStruct((dim, m), jnp.float32)
    xi_spec = jax.ShapeDtypeStruct((m,), jnp.float32)

    for signature in ("qckm", "ckm"):
        fn = make_sketch_sum(signature)
        text = lower_to_hlo_text(fn, (x_spec, omega_spec, xi_spec))
        fname = f"sketch_{signature}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        manifest_lines.append(f"sketch_{signature} sketch {batch} {dim} {m} {fname}")
        print(f"lowered sketch_{signature}: {len(text)} chars")

    c_spec = jax.ShapeDtypeStruct((k, dim), jnp.float32)
    atoms_text = lower_to_hlo_text(make_decode_atoms(), (c_spec, omega_spec, xi_spec))
    with open(os.path.join(out_dir, "decode_atoms.hlo.txt"), "w") as f:
        f.write(atoms_text)
    manifest_lines.append(f"decode_atoms atoms {k} {dim} {m} decode_atoms.hlo.txt")
    print(f"lowered decode_atoms: {len(atoms_text)} chars")

    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    return manifest_lines


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    parser.add_argument("--batch", type=int, default=FLAGSHIP_BATCH)
    parser.add_argument("--dim", type=int, default=FLAGSHIP_DIM)
    parser.add_argument("--m", type=int, default=FLAGSHIP_M)
    parser.add_argument("--k", type=int, default=FLAGSHIP_K)
    args = parser.parse_args()
    lines = build_artifacts(args.out_dir, args.batch, args.dim, args.m, args.k)
    print(f"wrote {len(lines) - 1} artifacts to {args.out_dir}")


if __name__ == "__main__":
    main()
