"""Layer-2 JAX model: the acquisition-side compute graph.

The paper's "model" is the sketch operator itself — the only dense compute
on the request path. This module assembles the jittable functions that
``aot.py`` lowers to HLO text for the Rust runtime:

* :func:`make_sketch_sum` — the pooled (summed) sketch of a fixed-shape
  batch, calling the Layer-1 Pallas kernel. This is the artifact the Rust
  ``PjrtEngine`` executes per batch.
* :func:`make_decode_atoms` — the decode-side cosine atom matrix
  ``a(c_k)`` for a batch of candidate centroids (first-harmonic operator of
  Prop. 1; the ``2|F_1|`` amplitude is applied on the Rust side). Lowered
  as a second artifact to document that the whole numeric stack can be
  served from PJRT; the shipped decoder evaluates atoms natively because
  its shapes vary per CL-OMPR iteration.

Python here is build-time only: these functions run under ``jax.jit``
lowering exactly once, in ``make artifacts``.
"""

import jax
import jax.numpy as jnp

from .kernels.usketch import sketch_sum


def make_sketch_sum(signature: str):
    """Return ``fn(x[B,n], omega[n,M], xi[M]) -> f32[2M]`` (batch sum)."""

    def fn(x, omega, xi):
        return sketch_sum(x, omega, xi, signature=signature)

    fn.__name__ = f"sketch_sum_{signature}"
    return fn


def make_decode_atoms():
    """Return ``fn(c[K,n], omega[n,M], xi[M]) -> f32[K, 2M]``: unit-amplitude
    cosine atoms ``cos(omega_j.c + xi_j + p*pi/2)`` in the paired-slot layout."""

    def fn(c, omega, xi):
        proj = c @ omega  # [K, M]
        arg = proj + xi[None, :]
        a0 = jnp.cos(arg)
        a1 = -jnp.sin(arg)  # cos(arg + pi/2)
        return jnp.stack([a0, a1], axis=-1).reshape(c.shape[0], -1)

    fn.__name__ = "decode_atoms"
    return fn


def lower_to_hlo_text(fn, example_args):
    """Lower a jittable function to HLO **text** (the interchange format the
    ``xla`` crate's XLA 0.5.1 accepts — serialized jax>=0.5 protos are not;
    see /opt/xla-example/README.md)."""
    from jax._src.lib import xla_client as xc

    lowered = jax.jit(fn).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()
