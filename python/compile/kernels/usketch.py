"""Layer-1 Pallas kernel: pooled periodic-signature sketch.

Computes the batch-summed sketch contributions

    out[2j + p] = sum_i f(omega_j . x_i + xi_j + p*pi/2),   p in {0, 1}

for a 2-pi-periodic signature ``f`` (QCKM's 1-bit universal quantizer
``q(t) = sign(cos t)``, CKM's cosine, or the triangle ablation), fused as a
single kernel: the ``X @ Omega`` projection feeds the MXU, the signature and
the batch reduction are VPU element-wise work on the same VMEM-resident tile,
and the output block is revisited across the batch grid dimension so the
pooled sum never round-trips to HBM.

TPU mapping (DESIGN.md section "Hardware adaptation"): the paper's "sensor"
is an analog front end, so the kernel models the *datacenter* encode path.
Block shauping targets VMEM: X tile ``(Bt, n)``, Omega tile ``(n, Mt)``,
accumulator ``(2*Mt,)``; with the flagship ``Bt=128, n<=64, Mt=256`` the
working set is ~420 KiB of f32, far under the ~16 MiB VMEM budget, and the
matmul tile keeps the MXU at its native 128x128 granularity.

``interpret=True`` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; real-TPU numbers are estimated in EXPERIMENTS.md section Perf.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: Signatures the kernel knows how to fuse.
SIGNATURES = ("qckm", "ckm", "triangle")

#: Default block sizes (see module docstring for the VMEM budget).
DEFAULT_BLOCK_B = 128
DEFAULT_BLOCK_M = 256


def _apply_signature(signature: str, arg):
    """Evaluate the signature f(arg) element-wise (f32-safe)."""
    if signature == "qckm":
        # q(t) = sign(cos t), with the measure-zero boundary sent to +1 to
        # match the Rust reference (`UniversalQuantizer::bit`).
        return jnp.where(jnp.cos(arg) >= 0.0, 1.0, -1.0).astype(arg.dtype)
    if signature == "ckm":
        return jnp.cos(arg)
    if signature == "triangle":
        # Even triangle wave: 1 - 2*d/pi with d = distance of (arg mod 2pi)
        # to the nearest multiple of 2pi.
        two_pi = 2.0 * jnp.pi
        r = jnp.mod(arg, two_pi)
        d = jnp.minimum(r, two_pi - r)
        return (1.0 - 2.0 * d / jnp.pi).astype(arg.dtype)
    raise ValueError(f"unknown signature '{signature}' (expected {SIGNATURES})")


def _sketch_kernel(x_ref, omega_ref, xi_ref, o_ref, *, signature: str, batch: int, block_b: int):
    """One (batch-tile, frequency-tile) grid step.

    Grid is (num_batch_tiles, num_freq_tiles); the output block depends only
    on the frequency tile, so it is revisited along the batch dimension and
    accumulates the per-tile partial sums.
    """
    i = pl.program_id(0)

    # MXU: (Bt, n) @ (n, Mt) projection.
    proj = jnp.dot(x_ref[...], omega_ref[...], preferred_element_type=jnp.float32)
    arg = proj + xi_ref[...][None, :]

    # VPU: signature at both dither offsets.
    v0 = _apply_signature(signature, arg)
    v1 = _apply_signature(signature, arg + 0.5 * jnp.pi)

    # Mask padded batch rows (X is zero-padded to a multiple of Bt, but
    # f(0 + xi) != 0, so padded rows must not contribute).
    row_ids = i * block_b + jax.lax.broadcasted_iota(jnp.int32, v0.shape, 0)
    valid = row_ids < batch
    v0 = jnp.where(valid, v0, 0.0)
    v1 = jnp.where(valid, v1, 0.0)

    # Batch reduction, then interleave (2j, 2j+1) slots.
    z0 = jnp.sum(v0, axis=0)
    z1 = jnp.sum(v1, axis=0)
    contrib = jnp.stack([z0, z1], axis=-1).reshape(-1)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = contrib

    @pl.when(i > 0)
    def _accum():
        o_ref[...] += contrib


def sketch_sum(x, omega, xi, *, signature: str = "qckm",
               block_b: int = DEFAULT_BLOCK_B, block_m: int = DEFAULT_BLOCK_M):
    """Pooled (summed) sketch of a batch: returns ``f32[2*M]``.

    Args:
      x: ``f32[B, n]`` batch of examples.
      omega: ``f32[n, M]`` frequency matrix (column j = omega_j).
      xi: ``f32[M]`` dither.
      signature: one of :data:`SIGNATURES`.
      block_b / block_m: Pallas tile sizes (clamped to the actual shapes).
    """
    if signature not in SIGNATURES:
        raise ValueError(f"unknown signature '{signature}'")
    b, n = x.shape
    n2, m = omega.shape
    if n2 != n:
        raise ValueError(f"omega rows {n2} != x cols {n}")
    if xi.shape != (m,):
        raise ValueError(f"xi shape {xi.shape} != ({m},)")

    bt = max(1, min(block_b, b))
    mt = max(1, min(block_m, m))
    # Zero-pad to tile multiples; padded rows are masked inside the kernel,
    # padded frequency columns are sliced off the output.
    b_pad = -(-b // bt) * bt
    m_pad = -(-m // mt) * mt
    if b_pad != b:
        x = jnp.pad(x, ((0, b_pad - b), (0, 0)))
    if m_pad != m:
        omega = jnp.pad(omega, ((0, 0), (0, m_pad - m)))
        xi = jnp.pad(xi, (0, m_pad - m))

    grid = (b_pad // bt, m_pad // mt)
    out = pl.pallas_call(
        partial(_sketch_kernel, signature=signature, batch=b, block_b=bt),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, n), lambda i, j: (i, 0)),
            pl.BlockSpec((n, mt), lambda i, j: (0, j)),
            pl.BlockSpec((mt,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((2 * mt,), lambda i, j: (j,)),
        out_shape=jax.ShapeDtypeStruct((2 * m_pad,), jnp.float32),
        interpret=True,  # CPU PJRT cannot execute Mosaic custom-calls
    )(x.astype(jnp.float32), omega.astype(jnp.float32), xi.astype(jnp.float32))
    return out[: 2 * m]
