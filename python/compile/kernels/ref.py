"""Pure-jnp oracle for the Pallas sketch kernel.

No tiling, no pallas: the straightforward expression of the same math, used
by pytest (and hypothesis sweeps) to validate ``usketch.sketch_sum``.
"""

import jax.numpy as jnp

from .usketch import SIGNATURES, _apply_signature


def sketch_sum_ref(x, omega, xi, *, signature: str = "qckm"):
    """Reference batch-summed sketch: ``f32[2*M]``.

    Identical contract to :func:`..usketch.sketch_sum`.
    """
    if signature not in SIGNATURES:
        raise ValueError(f"unknown signature '{signature}'")
    x = jnp.asarray(x, jnp.float32)
    omega = jnp.asarray(omega, jnp.float32)
    xi = jnp.asarray(xi, jnp.float32)
    proj = x @ omega  # [B, M]
    arg = proj + xi[None, :]
    v0 = _apply_signature(signature, arg)
    v1 = _apply_signature(signature, arg + 0.5 * jnp.pi)
    z0 = jnp.sum(v0, axis=0)
    z1 = jnp.sum(v1, axis=0)
    return jnp.stack([z0, z1], axis=-1).reshape(-1)


def sketch_mean_ref(x, omega, xi, *, signature: str = "qckm"):
    """Mean (rather than sum) pooled sketch — matches the Rust
    ``SketchOperator::sketch_dataset`` convention."""
    return sketch_sum_ref(x, omega, xi, signature=signature) / x.shape[0]
