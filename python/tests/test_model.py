"""L2 tests: model graph shapes, decode atoms vs closed form, AOT lowering."""

import os
import tempfile

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile.aot import build_artifacts
from compile.model import lower_to_hlo_text, make_decode_atoms, make_sketch_sum

jax.config.update("jax_platform_name", "cpu")


def test_decode_atoms_closed_form():
    rng = np.random.default_rng(0)
    k, n, m = 3, 4, 20
    c = rng.normal(size=(k, n)).astype(np.float32)
    omega = rng.normal(size=(n, m)).astype(np.float32)
    xi = rng.uniform(0, 2 * np.pi, size=(m,)).astype(np.float32)
    atoms = np.asarray(make_decode_atoms()(c, omega, xi))
    assert atoms.shape == (k, 2 * m)
    proj = c @ omega + xi[None, :]
    want = np.stack([np.cos(proj), -np.sin(proj)], axis=-1).reshape(k, -1)
    np.testing.assert_allclose(atoms, want, rtol=1e-5, atol=1e-5)
    # Constant atom norm: ||a(c)||^2 = M for unit amplitude.
    norms = np.sum(atoms**2, axis=1)
    np.testing.assert_allclose(norms, m, rtol=1e-4)


def test_sketch_fn_jits_and_pools():
    fn = jax.jit(make_sketch_sum("qckm"))
    rng = np.random.default_rng(1)
    x = rng.normal(size=(8, 3)).astype(np.float32)
    omega = rng.normal(size=(3, 10)).astype(np.float32)
    xi = rng.uniform(0, 2 * np.pi, size=(10,)).astype(np.float32)
    z = np.asarray(fn(x, omega, xi))
    assert z.shape == (20,)
    # Sum of 8 contributions, each +-1 -> even integer in [-8, 8].
    assert np.all(np.abs(z) <= 8.0)
    assert np.allclose(z % 2, 0.0)


def test_lower_to_hlo_text_produces_hlo():
    fn = make_sketch_sum("ckm")
    spec = lambda s: jax.ShapeDtypeStruct(s, jnp.float32)  # noqa: E731
    text = lower_to_hlo_text(fn, (spec((16, 4)), spec((4, 32)), spec((32,))))
    assert "HloModule" in text
    assert "f32[16,4]" in text  # input shape survived
    assert "f32[64]" in text or "f32[64]{0}" in text  # 2M output


def test_build_artifacts_writes_manifest_and_files():
    with tempfile.TemporaryDirectory() as d:
        lines = build_artifacts(d, batch=32, dim=4, m=50, k=3)
        assert any("sketch_qckm sketch 32 4 50" in l for l in lines)
        assert any("sketch_ckm sketch 32 4 50" in l for l in lines)
        assert any("decode_atoms atoms 3 4 50" in l for l in lines)
        for fname in (
            "sketch_qckm.hlo.txt",
            "sketch_ckm.hlo.txt",
            "decode_atoms.hlo.txt",
            "manifest.txt",
        ):
            path = os.path.join(d, fname)
            assert os.path.exists(path), fname
            assert os.path.getsize(path) > 0
        manifest = open(os.path.join(d, "manifest.txt")).read()
        assert manifest.startswith("# name kind batch dim m file")


@pytest.mark.parametrize("signature", ["qckm", "ckm"])
def test_lowered_stablehlo_reexecutes_correctly(signature):
    """Compile the lowered StableHLO back through XLA out-of-band (no jit
    cache) and compare numerics with direct jit execution. The HLO-*text*
    round trip through xla_extension 0.5.1 is exercised by the Rust
    integration test `rust/tests/pjrt_e2e.rs`."""
    from jax._src import xla_bridge
    from jax._src.lib import xla_client as xc

    fn = make_sketch_sum(signature)
    rng = np.random.default_rng(7)
    b, n, m = 16, 3, 24
    x = rng.normal(size=(b, n)).astype(np.float32)
    omega = rng.normal(size=(n, m)).astype(np.float32)
    xi = rng.uniform(0, 2 * np.pi, size=(m,)).astype(np.float32)

    lowered = jax.jit(fn).lower(
        *(jax.ShapeDtypeStruct(s, jnp.float32) for s in ((b, n), (n, m), (m,)))
    )
    mlir_text = str(lowered.compiler_ir("stablehlo"))

    backend = xla_bridge.get_backend("cpu")
    devs = xc.DeviceList(tuple(backend.local_devices()[:1]))
    exe = backend.compile_and_load(mlir_text, devs)
    outs = exe.execute([backend.buffer_from_pyval(v) for v in (x, omega, xi)])
    got = np.asarray(outs[0]).ravel()
    want = np.asarray(fn(x, omega, xi))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4 * b)
