"""L1 correctness: Pallas kernel vs the pure-jnp oracle.

Hypothesis sweeps shapes (including tile-boundary and padding cases) and
signatures; exact invariants (values in {-1,+1} for the quantizer, cos^2 +
sin^2 pairing for CKM) are asserted directly.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from compile.kernels.ref import sketch_mean_ref, sketch_sum_ref
from compile.kernels.usketch import SIGNATURES, sketch_sum

jax.config.update("jax_platform_name", "cpu")


def rand_problem(rng, b, n, m, scale=2.0):
    x = rng.normal(size=(b, n)).astype(np.float32) * scale
    omega = rng.normal(size=(n, m)).astype(np.float32)
    xi = rng.uniform(0.0, 2.0 * np.pi, size=(m,)).astype(np.float32)
    return x, omega, xi


@pytest.mark.parametrize("signature", SIGNATURES)
@pytest.mark.parametrize("shape", [(1, 1, 1), (4, 3, 8), (130, 5, 260), (256, 10, 100)])
def test_kernel_matches_ref(signature, shape):
    b, n, m = shape
    rng = np.random.default_rng(hash(shape) % 2**32)
    x, omega, xi = rand_problem(rng, b, n, m)
    got = np.asarray(sketch_sum(x, omega, xi, signature=signature))
    want = np.asarray(sketch_sum_ref(x, omega, xi, signature=signature))
    assert got.shape == (2 * m,)
    # The quantizer is discontinuous: a projection landing within float
    # round-off of a quantization boundary can legitimately flip sign
    # between the two evaluation orders. Tolerate <=0.1% flipped slots
    # (each flip shifts a slot sum by 2).
    if signature == "qckm":
        flips = np.sum(np.abs(got - want) > 1e-4) / got.size
        assert flips <= 1e-3, f"{flips:.2%} slots differ"
    else:
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4 * b)


@settings(max_examples=60, deadline=None)
@given(
    b=st.integers(1, 300),
    n=st.integers(1, 12),
    m=st.integers(1, 300),
    signature=st.sampled_from(SIGNATURES),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_matches_ref_hypothesis(b, n, m, signature, seed):
    rng = np.random.default_rng(seed)
    x, omega, xi = rand_problem(rng, b, n, m, scale=1.5)
    got = np.asarray(sketch_sum(x, omega, xi, signature=signature))
    want = np.asarray(sketch_sum_ref(x, omega, xi, signature=signature))
    if signature == "qckm":
        # Allow rare boundary flips (discontinuity + f32 reassociation).
        flips = np.sum(np.abs(got - want) > 1e-4)
        assert flips <= max(1, int(2e-3 * got.size)), f"{flips} flipped slots"
    else:
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-4 * b)


@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(2, 64),
    n=st.integers(1, 8),
    m=st.integers(1, 64),
    seed=st.integers(0, 2**31 - 1),
)
def test_sketch_linearity(b, n, m, seed):
    """The sketch sum is linear: halves add up to the whole (exact for the
    quantizer whose contributions are +-1)."""
    rng = np.random.default_rng(seed)
    x, omega, xi = rand_problem(rng, b, n, m)
    whole = np.asarray(sketch_sum(x, omega, xi, signature="qckm"))
    h1 = np.asarray(sketch_sum(x[: b // 2], omega, xi, signature="qckm"))
    h2 = np.asarray(sketch_sum(x[b // 2 :], omega, xi, signature="qckm"))
    np.testing.assert_allclose(whole, h1 + h2, atol=1e-4)


def test_quantizer_values_are_plus_minus_one():
    rng = np.random.default_rng(0)
    x, omega, xi = rand_problem(rng, 1, 4, 50)
    z = np.asarray(sketch_sum(x, omega, xi, signature="qckm"))
    assert np.all(np.isin(z, [-1.0, 1.0]))


def test_ckm_pair_identity():
    """cos^2 + sin^2 = 1: for a single example, slot pairs of the cosine
    sketch are (cos t, -sin t)."""
    rng = np.random.default_rng(1)
    x, omega, xi = rand_problem(rng, 1, 3, 40)
    z = np.asarray(sketch_sum(x, omega, xi, signature="ckm"))
    pairs = z.reshape(-1, 2)
    np.testing.assert_allclose(pairs[:, 0] ** 2 + pairs[:, 1] ** 2, 1.0, atol=1e-5)


def test_triangle_range_and_period():
    rng = np.random.default_rng(2)
    x, omega, xi = rand_problem(rng, 1, 3, 64)
    z = np.asarray(sketch_sum(x, omega, xi, signature="triangle"))
    assert np.all(z >= -1.0 - 1e-6) and np.all(z <= 1.0 + 1e-6)


def test_mean_ref_is_sum_over_n():
    rng = np.random.default_rng(3)
    x, omega, xi = rand_problem(rng, 10, 2, 7)
    s = np.asarray(sketch_sum_ref(x, omega, xi))
    m = np.asarray(sketch_mean_ref(x, omega, xi))
    np.testing.assert_allclose(m, s / 10.0, rtol=1e-6)


def test_rejects_bad_shapes_and_signature():
    x = np.zeros((2, 3), np.float32)
    omega = np.zeros((4, 5), np.float32)  # wrong rows
    xi = np.zeros((5,), np.float32)
    with pytest.raises(ValueError):
        sketch_sum(x, omega, xi)
    with pytest.raises(ValueError):
        sketch_sum(np.zeros((2, 4), np.float32), omega, np.zeros((6,), np.float32))
    with pytest.raises(ValueError):
        sketch_sum(np.zeros((2, 4), np.float32), omega, xi, signature="dct")
    with pytest.raises(ValueError):
        sketch_sum_ref(np.zeros((2, 4), np.float32), omega, xi, signature="dct")


def test_block_sizes_do_not_change_result():
    rng = np.random.default_rng(4)
    x, omega, xi = rand_problem(rng, 70, 6, 90)
    a = np.asarray(sketch_sum(x, omega, xi, signature="ckm", block_b=16, block_m=32))
    b = np.asarray(sketch_sum(x, omega, xi, signature="ckm", block_b=128, block_m=256))
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-3)
