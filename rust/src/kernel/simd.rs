//! x86-64 AVX2 specializations of the dense kernels.
//!
//! Bitwise-equivalence argument (I-22, locked by `tests/determinism.rs` and
//! the unit tests in [`super::tests`]):
//!
//! * [`dot_avx2`] holds the scalar code's four accumulators `s0..s3` as the
//!   four lanes of one `__m256d`. Each loop iteration performs exactly the
//!   scalar iteration's `sN += a[4i+N] * b[4i+N]` in lane `N`, using
//!   separate `mul`/`add` — **never FMA**, which fuses the rounding step and
//!   would change results. The horizontal reduction combines lanes in the
//!   scalar order `(s0+s1)+(s2+s3)` with SSE2 shuffles, and the remainder
//!   loop is the scalar code verbatim. Same multiplies, same adds, same
//!   order ⇒ same bits.
//! * [`axpy_avx2`] is element-wise: `y[j] += alpha * x[j]` has no reduction
//!   order to preserve, so the 4-lane version is trivially identical.
//!
//! These functions are `unsafe` only because of `#[target_feature]`: the
//! dispatcher in [`super`] guarantees they are reached exclusively after
//! `is_x86_feature_detected!("avx2")` succeeded.

#![cfg(target_arch = "x86_64")]

use std::arch::x86_64::{
    __m128d, _mm256_add_pd, _mm256_castpd256_pd128, _mm256_extractf128_pd, _mm256_loadu_pd,
    _mm256_mul_pd, _mm256_set1_pd, _mm256_setzero_pd, _mm256_storeu_pd, _mm_add_sd, _mm_cvtsd_f64,
    _mm_unpackhi_pd,
};

/// Lane 0 of `v` plus lane 1 of `v`, as a scalar in lane 0.
#[inline]
unsafe fn hsum2(v: __m128d) -> __m128d {
    _mm_add_sd(v, _mm_unpackhi_pd(v, v))
}

/// AVX2 dot product, bitwise identical to [`super::scalar::dot`].
///
/// # Safety
///
/// The CPU must support AVX2 (`is_x86_feature_detected!("avx2")`).
#[target_feature(enable = "avx2")]
pub unsafe fn dot_avx2(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 4;
    let pa = a.as_ptr();
    let pb = b.as_ptr();
    // acc lanes 0..3 are the scalar accumulators s0..s3.
    let mut acc = _mm256_setzero_pd();
    for i in 0..chunks {
        let j = i * 4;
        let av = _mm256_loadu_pd(pa.add(j));
        let bv = _mm256_loadu_pd(pb.add(j));
        // mul then add — not fmadd — to round exactly like the scalar code.
        acc = _mm256_add_pd(acc, _mm256_mul_pd(av, bv));
    }
    // (s0 + s1) + (s2 + s3), the scalar reduction order.
    let lo = _mm256_castpd256_pd128(acc); // [s0, s1]
    let hi = _mm256_extractf128_pd::<1>(acc); // [s2, s3]
    let mut s = _mm_cvtsd_f64(_mm_add_sd(hsum2(lo), hsum2(hi)));
    for j in chunks * 4..n {
        s += a[j] * b[j];
    }
    s
}

/// AVX2 `y += alpha * x`, bitwise identical to [`super::scalar::axpy`].
///
/// # Safety
///
/// The CPU must support AVX2 (`is_x86_feature_detected!("avx2")`).
#[target_feature(enable = "avx2")]
pub unsafe fn axpy_avx2(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    let n = x.len();
    let chunks = n / 4;
    let px = x.as_ptr();
    let py = y.as_mut_ptr();
    let av = _mm256_set1_pd(alpha);
    for i in 0..chunks {
        let j = i * 4;
        let xv = _mm256_loadu_pd(px.add(j));
        let yv = _mm256_loadu_pd(py.add(j));
        // mul then add — not fmadd — to round exactly like the scalar code.
        _mm256_storeu_pd(py.add(j), _mm256_add_pd(yv, _mm256_mul_pd(xv, av)));
    }
    for j in chunks * 4..n {
        y[j] += alpha * x[j];
    }
}
