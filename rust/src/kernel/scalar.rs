//! Portable scalar reference kernels.
//!
//! This is the exact code `linalg/ops.rs` shipped from the seed onward —
//! moved here unchanged so the SIMD specializations in [`super::simd`] have
//! a pinned reduction order to reproduce (I-22). `linalg::dot`/`axpy` now
//! delegate to the dispatcher in [`super`], which falls back here.

/// Dot product — 4-way unrolled accumulators combined as
/// `(s0+s1)+(s2+s3)`, then a scalar remainder loop.
///
/// The unroll lets the compiler vectorize without violating float
/// associativity semantics in a surprising way, and the fixed reduction
/// tree is what the AVX2 kernel reproduces lane-for-lane.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    for i in 0..chunks {
        let j = i * 4;
        s0 += a[j] * b[j];
        s1 += a[j + 1] * b[j + 1];
        s2 += a[j + 2] * b[j + 2];
        s3 += a[j + 3] * b[j + 3];
    }
    let mut s = (s0 + s1) + (s2 + s3);
    for j in chunks * 4..n {
        s += a[j] * b[j];
    }
    s
}

/// `y += alpha * x` — element-wise, so any vectorization of it is
/// automatically bitwise identical.
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}
