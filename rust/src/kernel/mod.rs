//! Runtime-dispatched compute kernels — the word-parallel / SIMD hot layer.
//!
//! Everything above this module (sketch encode, streaming, serving, fan-in)
//! funnels its inner loops through three primitives:
//!
//! * [`dot`] / [`axpy`] — the dense f64 vector kernels behind the Ω·x
//!   projection, the decode gemv, and the gemm in [`crate::linalg`];
//! * [`bitpanel`] — the transposed 64-row bit-panel encode for ±1-valued
//!   signatures: pack the signs of up to 64 examples × `2M` slots into
//!   `u64` lanes (one word = 64 rows' bits for one slot) and pool with a
//!   single `count_ones()` per slot instead of 64 f64 additions.
//!
//! Two implementations exist for the dense kernels: the portable scalar
//! code ([`scalar`], the exact code `linalg/ops.rs` always had) and wide
//! SIMD specializations ([`simd`], AVX2 on x86-64). Selection happens once
//! per process, at first use:
//!
//! 1. `QCKM_KERNEL=scalar|wide` forces a mode (anything else warns once and
//!    falls back to the default);
//! 2. otherwise the default is `wide`, which uses AVX2 when
//!    `is_x86_feature_detected!("avx2")` says the CPU has it and the
//!    portable code when it does not.
//!
//! The resolved selection is visible as the `qckm_kernel_info` gauge on the
//! `qckm ctl metrics` page and via [`describe`].
//!
//! ## The invariant that makes dispatch safe (I-22)
//!
//! Kernel dispatch **never changes any output bit**:
//!
//! * the AVX2 `dot` reproduces the scalar code's 4-accumulator reduction
//!   tree exactly — four independent lanes combined as `(s0+s1)+(s2+s3)`
//!   plus a scalar remainder — using separate multiply and add (never FMA,
//!   which would change rounding);
//! * `axpy` is element-wise, so vectorizing it cannot reorder anything;
//! * the bit-panel pool produces per-slot partial sums `2·ones − rows`
//!   that are small exact integers — the same integers the f64 fold
//!   accumulates (±1 terms round nowhere) — added to the pool in the same
//!   per-batch order.
//!
//! Locked by `rust/tests/determinism.rs` (`i22_*`), the bit-panel proptests,
//! and the unit tests in this module.

pub mod bitpanel;
pub mod scalar;
#[cfg(target_arch = "x86_64")]
pub mod simd;

use std::sync::atomic::{AtomicU8, Ordering};

/// Which kernel family is selected (see the module docs for how).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelMode {
    /// The portable scalar reference path everywhere: f64 signature fold,
    /// scalar `dot`/`axpy`. This is the exact legacy code path.
    Scalar,
    /// Word-parallel bit-panel pooling for ±1 signatures plus the widest
    /// SIMD `dot`/`axpy` the CPU supports (portable code when it supports
    /// none). Bit-for-bit identical to [`KernelMode::Scalar`] (I-22).
    Wide,
}

impl KernelMode {
    /// Stable lowercase name (`scalar` / `wide`) — the `QCKM_KERNEL` values
    /// and the `mode` label of the `qckm_kernel_info` gauge.
    pub fn name(self) -> &'static str {
        match self {
            KernelMode::Scalar => "scalar",
            KernelMode::Wide => "wide",
        }
    }
}

/// Resolved dispatch state, cached in [`DISPATCH`].
const UNRESOLVED: u8 = 0;
const SCALAR: u8 = 1;
const WIDE_PORTABLE: u8 = 2;
const WIDE_AVX2: u8 = 3;

/// One-time-resolved dispatch cache. `set_mode` may overwrite it (tests and
/// benches compare modes in-process); plain loads keep the hot-path cost to
/// one relaxed atomic read.
static DISPATCH: AtomicU8 = AtomicU8::new(UNRESOLVED);

#[inline]
fn dispatch() -> u8 {
    let d = DISPATCH.load(Ordering::Relaxed);
    if d != UNRESOLVED {
        d
    } else {
        resolve_from_env()
    }
}

#[cold]
fn resolve_from_env() -> u8 {
    set_mode(default_mode());
    DISPATCH.load(Ordering::Relaxed)
}

/// The mode the environment asks for: `QCKM_KERNEL=scalar|wide`, defaulting
/// to [`KernelMode::Wide`]. An unrecognized value warns once on stderr and
/// falls back to the default (never an error: kernel selection is a
/// performance knob, not a correctness one — see I-22).
pub fn default_mode() -> KernelMode {
    match std::env::var("QCKM_KERNEL") {
        Ok(v) if v.eq_ignore_ascii_case("scalar") => KernelMode::Scalar,
        Ok(v) if v.eq_ignore_ascii_case("wide") => KernelMode::Wide,
        Ok(v) => {
            eprintln!("qckm: ignoring unknown QCKM_KERNEL={v:?} (expected scalar|wide)");
            KernelMode::Wide
        }
        Err(_) => KernelMode::Wide,
    }
}

/// Force a kernel mode for the rest of the process (until the next call).
///
/// Exists so tests and benches can compare modes within one process — the
/// env var alone would pin the whole run. Safe to call at any time from any
/// thread *because of I-22*: both modes produce identical bits, so a flip
/// mid-computation cannot change any result.
pub fn set_mode(mode: KernelMode) {
    let d = match mode {
        KernelMode::Scalar => SCALAR,
        KernelMode::Wide => {
            if simd_available() {
                WIDE_AVX2
            } else {
                WIDE_PORTABLE
            }
        }
    };
    DISPATCH.store(d, Ordering::Relaxed);
}

/// Whether the wide SIMD specializations can run on this CPU.
#[inline]
fn simd_available() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        std::arch::is_x86_feature_detected!("avx2")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// The currently selected mode (resolving it on first call).
#[inline]
pub fn mode() -> KernelMode {
    if dispatch() == SCALAR {
        KernelMode::Scalar
    } else {
        KernelMode::Wide
    }
}

/// The instruction set the dispatched dense kernels execute with:
/// `"avx2"` when the wide AVX2 specializations are active, `"portable"`
/// otherwise (scalar mode, or a CPU without AVX2).
pub fn simd_level() -> &'static str {
    if dispatch() == WIDE_AVX2 {
        "avx2"
    } else {
        "portable"
    }
}

/// Human-readable summary of the resolved dispatch, e.g. `wide (avx2)` —
/// what `qckm serve` logs at startup and what the `qckm_kernel_info` gauge
/// labels carry.
pub fn describe() -> String {
    format!("{} ({})", mode().name(), simd_level())
}

/// Dot product, dispatched. Bitwise identical across modes (I-22).
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    #[cfg(target_arch = "x86_64")]
    if dispatch() == WIDE_AVX2 {
        // SAFETY: WIDE_AVX2 is only ever stored after
        // `is_x86_feature_detected!("avx2")` returned true.
        return unsafe { simd::dot_avx2(a, b) };
    }
    scalar::dot(a, b)
}

/// `y += alpha * x`, dispatched. Bitwise identical across modes (I-22).
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    #[cfg(target_arch = "x86_64")]
    if dispatch() == WIDE_AVX2 {
        // SAFETY: WIDE_AVX2 is only ever stored after
        // `is_x86_feature_detected!("avx2")` returned true.
        unsafe { simd::axpy_avx2(alpha, x, y) };
        return;
    }
    scalar::axpy(alpha, x, y)
}

/// Serializes crate tests that flip the dispatch mode via [`set_mode`], and
/// restores the environment-resolved default when dropped — so concurrent
/// tests always observe a settled mode outside these critical sections.
/// (Even a mid-test flip would be invisible in outputs — that is I-22 — but
/// serializing keeps each comparison honest about which mode it measured.)
#[cfg(test)]
pub(crate) struct ModeGuard(#[allow(dead_code)] std::sync::MutexGuard<'static, ()>);

#[cfg(test)]
pub(crate) fn lock_mode_for_test() -> ModeGuard {
    static LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());
    ModeGuard(LOCK.lock().unwrap_or_else(|p| p.into_inner()))
}

#[cfg(test)]
impl Drop for ModeGuard {
    fn drop(&mut self) {
        set_mode(default_mode());
    }
}

#[cfg(test)]
mod tests;
