//! Transposed bit-panel encode/pool for ±1-valued signatures.
//!
//! The QCKM signature is one bit per slot, but the original fold evaluated
//! that bit as an `f64` and pooled it with f64 additions — one add per row
//! per slot. This module keeps the projection (that part is genuinely
//! dense) and replaces everything after the sign with word-level
//! parallelism: the signs of up to 64 rows are packed *transposed* into one
//! `u64` lane per slot (bit `i` of the slot-`j` word is row `i`'s sign),
//! and pooling a whole 64-row panel into a slot costs a single
//! `count_ones()` instead of 64 additions.
//!
//! ## Exactness (I-22)
//!
//! A batch of `b ≤ 64` rows contributes `Σ_i ±1 = 2·ones − b` to each
//! slot — an integer of magnitude ≤ 64, exactly representable in `f64`.
//! The f64 fold computes the same value by accumulating the ±1 terms one
//! by one, and every partial sum along the way is also a small integer, so
//! no rounding occurs anywhere: the panel's `(2·ones − b) as f64` is
//! bit-for-bit the fold's accumulator. Both paths then add that one value
//! to the pool in the same per-batch order. Locked by
//! `prop_bit_panel_pooling_matches_scalar_fold_bitwise` and the `i22_*`
//! determinism tests.
//!
//! Two entry points mirror the two legacy encode conventions (their
//! projections round differently in the last ulp, so each panel fold
//! replicates its own legacy path exactly):
//!
//! * [`pool_dense_range`] mirrors `SketchOperator::sketch_range_into`
//!   (ξ-initialized batched projection, pooled into an f64 sum);
//! * [`pool_bits_range`] mirrors per-row `encode_point_bits` +
//!   `BitAggregator::add` (zero-initialized projection with ξ added after
//!   the fold, pooled into integer one-counts).

use crate::linalg::Mat;
use crate::signature::Signature;
use crate::sketch::BitAggregator;
use std::ops::Range;

/// Panel height: one `u64` lane holds one sign bit per row.
pub const PANEL_ROWS: usize = 64;

/// Pool rows `rows` of `x` into the running f64 slot sums `sum`
/// (length `2M`), bit-for-bit like the dense fold in
/// `SketchOperator::sketch_range_into` — see the module docs.
///
/// `om` is the `n × M` frequency matrix, `xi` the `M` dithers, and `sig`
/// must be ±1-valued (`Signature::is_binary`); the caller dispatches.
pub fn pool_dense_range(
    om: &Mat,
    xi: &[f64],
    sig: &dyn Signature,
    x: &Mat,
    rows: Range<usize>,
    sum: &mut [f64],
) {
    let m = om.cols();
    debug_assert_eq!(xi.len(), m);
    debug_assert_eq!(sum.len(), 2 * m);
    debug_assert_eq!(x.cols(), om.rows());
    let mut proj = vec![0.0; PANEL_ROWS * m];
    let mut s0 = vec![false; m];
    let mut s1 = vec![false; m];
    let mut w0 = vec![0u64; m];
    let mut w1 = vec![0u64; m];
    let mut row = rows.start;
    while row < rows.end {
        let b = PANEL_ROWS.min(rows.end - row);
        // Projection identical to the f64 fold: ξ-initialized rows, then one
        // (branchless) axpy per data coordinate.
        for i in 0..b {
            proj[i * m..(i + 1) * m].copy_from_slice(xi);
        }
        for i in 0..b {
            let xrow = x.row(row + i);
            let dst = &mut proj[i * m..(i + 1) * m];
            for (r, &xr) in xrow.iter().enumerate() {
                super::axpy(xr, om.row(r), dst);
            }
        }
        // Transpose the signs into slot-major lanes: bit i of w0[j] is row
        // i's sign for slot 2j (w1 for slot 2j+1).
        w0.fill(0);
        w1.fill(0);
        for i in 0..b {
            sig.eval_pair_sign_batch(&proj[i * m..(i + 1) * m], &mut s0, &mut s1);
            for j in 0..m {
                w0[j] |= (s0[j] as u64) << i;
                w1[j] |= (s1[j] as u64) << i;
            }
        }
        // Σ_i ±1 = 2·ones − b: the exact integer the f64 fold's batch
        // accumulator holds, added to the pool at the same point.
        let bi = b as i64;
        for j in 0..m {
            sum[2 * j] += (2 * w0[j].count_ones() as i64 - bi) as f64;
            sum[2 * j + 1] += (2 * w1[j].count_ones() as i64 - bi) as f64;
        }
        row += b;
    }
}

/// Pool rows `rows` of `x` into `agg`'s integer one-counts, bit-for-bit
/// like per-row `encode_point_bits` + `BitAggregator::add` — the sensor
/// acquisition path (see the module docs).
pub fn pool_bits_range(
    om: &Mat,
    xi: &[f64],
    sig: &dyn Signature,
    x: &Mat,
    rows: Range<usize>,
    agg: &mut BitAggregator,
) {
    let m = om.cols();
    debug_assert_eq!(xi.len(), m);
    debug_assert_eq!(agg.len(), 2 * m);
    debug_assert_eq!(x.cols(), om.rows());
    let mut proj = vec![0.0; m];
    let mut s0 = vec![false; m];
    let mut s1 = vec![false; m];
    let mut w0 = vec![0u64; m];
    let mut w1 = vec![0u64; m];
    let mut row = rows.start;
    while row < rows.end {
        let b = PANEL_ROWS.min(rows.end - row);
        w0.fill(0);
        w1.fill(0);
        for i in 0..b {
            // Projection identical to encode_point_bits: zero-initialized
            // fold, dither added after.
            proj.fill(0.0);
            let xrow = x.row(row + i);
            for (r, &xr) in xrow.iter().enumerate() {
                super::axpy(xr, om.row(r), &mut proj);
            }
            for (p, &d) in proj.iter_mut().zip(xi) {
                *p += d;
            }
            sig.eval_pair_sign_batch(&proj, &mut s0, &mut s1);
            for j in 0..m {
                w0[j] |= (s0[j] as u64) << i;
                w1[j] |= (s1[j] as u64) << i;
            }
        }
        agg.add_panel(&w0, &w1, b as u32);
        row += b;
    }
}
