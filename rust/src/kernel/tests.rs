//! Unit locks for the kernel layer — I-22 at the smallest scope: the
//! dispatched dense kernels and the bit-panel pooling must be bit-for-bit
//! the scalar reference on every input shape, including the awkward ones
//! (empty, sub-lane, non-multiple-of-4/64 lengths).

use super::{bitpanel, scalar, KernelMode};
use crate::frequency::{DrawnFrequencies, FrequencyLaw};
use crate::linalg::Mat;
use crate::rng::Rng;
use crate::signature::{MultiBitQuantizer, Signature, UniversalQuantizer};
use crate::sketch::{BitAggregator, SketchOperator};

#[test]
fn mode_names_and_describe_are_stable() {
    assert_eq!(KernelMode::Scalar.name(), "scalar");
    assert_eq!(KernelMode::Wide.name(), "wide");
    let _guard = super::lock_mode_for_test();
    super::set_mode(KernelMode::Scalar);
    assert_eq!(super::mode(), KernelMode::Scalar);
    assert!(super::describe().starts_with("scalar ("));
    super::set_mode(KernelMode::Wide);
    assert_eq!(super::mode(), KernelMode::Wide);
    assert!(super::describe().starts_with("wide ("));
    assert!(matches!(super::simd_level(), "avx2" | "portable"));
}

/// The dispatched `dot`/`axpy` equal the scalar reference bit-for-bit in
/// both modes, across lengths that cover the remainder-loop edge cases.
#[test]
fn dispatched_dense_kernels_match_scalar_bitwise() {
    let _guard = super::lock_mode_for_test();
    let mut rng = Rng::new(0x5EED);
    for n in [0usize, 1, 2, 3, 4, 5, 7, 8, 15, 16, 63, 64, 65, 257] {
        let a: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
        let b: Vec<f64> = (0..n).map(|_| 3.0 * rng.gaussian()).collect();
        let alpha = rng.gaussian();
        let want_dot = scalar::dot(&a, &b);
        let mut want_y = b.clone();
        scalar::axpy(alpha, &a, &mut want_y);
        for mode in [KernelMode::Scalar, KernelMode::Wide] {
            super::set_mode(mode);
            assert_eq!(
                super::dot(&a, &b).to_bits(),
                want_dot.to_bits(),
                "dot n={n} mode={}",
                mode.name()
            );
            let mut y = b.clone();
            super::axpy(alpha, &a, &mut y);
            let same = y
                .iter()
                .zip(&want_y)
                .all(|(u, v)| u.to_bits() == v.to_bits());
            assert!(same, "axpy n={n} mode={}", mode.name());
        }
    }
}

fn quantized_op(dim: usize, m: usize, seed: u64) -> SketchOperator {
    let freqs = DrawnFrequencies::draw(
        FrequencyLaw::AdaptedRadius,
        dim,
        m,
        0.8,
        &mut Rng::new(seed),
    );
    SketchOperator::quantized(freqs)
}

/// Reference fold for the panel paths: force scalar mode and run the
/// legacy per-row / f64 code, then compare the wide panel against it.
#[test]
fn bit_panel_pooling_matches_scalar_fold_bitwise() {
    let _guard = super::lock_mode_for_test();
    // Row counts around the 64-row panel boundary (trailing-lane masking).
    for rows in [1usize, 63, 64, 65, 130] {
        let op = quantized_op(5, 37, rows as u64);
        let mut rng = Rng::new(99 + rows as u64);
        let x = Mat::from_fn(rows, op.dim(), |_, _| {
            // Exact zeros mixed in: the branchless-axpy edge case.
            if rng.next_u64() % 4 == 0 {
                0.0
            } else {
                rng.gaussian()
            }
        });

        super::set_mode(KernelMode::Scalar);
        let mut want = crate::sketch::PooledSketch::new(op.sketch_len());
        op.sketch_into(&x, &mut want);
        let mut want_agg = BitAggregator::new(op.sketch_len());
        op.pool_bits_range(&x, 0..rows, &mut want_agg);

        super::set_mode(KernelMode::Wide);
        let mut got = crate::sketch::PooledSketch::new(op.sketch_len());
        op.sketch_into(&x, &mut got);
        assert_eq!(got.count(), want.count(), "rows={rows}");
        let sums_equal = got
            .sum()
            .iter()
            .zip(want.sum())
            .all(|(u, v)| u.to_bits() == v.to_bits());
        assert!(sums_equal, "dense panel fold rows={rows}");

        let mut got_agg = BitAggregator::new(op.sketch_len());
        op.pool_bits_range(&x, 0..rows, &mut got_agg);
        assert_eq!(got_agg.count(), want_agg.count(), "rows={rows}");
        assert_eq!(got_agg.to_sum(), want_agg.to_sum(), "bit panel rows={rows}");
    }
}

/// The `is_binary` sign contract: `eval_pair_sign_batch` equals
/// `eval_pair_batch(..) > 0.0` slot-for-slot and the values are exactly ±1
/// — for the hand-written [`UniversalQuantizer`] override (whose sign and
/// value formulas are written separately) and for the derived default
/// ([`MultiBitQuantizer`] at B = 1).
#[test]
fn panel_sign_bits_match_f64_signature_values() {
    let mut rng = Rng::new(7);
    let sig = UniversalQuantizer;
    let args: Vec<f64> = (0..257).map(|_| 7.0 * rng.gaussian()).collect();
    let mut v0 = vec![0.0; args.len()];
    let mut v1 = vec![0.0; args.len()];
    sig.eval_pair_batch(&args, &mut v0, &mut v1);
    let mut s0 = vec![false; args.len()];
    let mut s1 = vec![false; args.len()];
    sig.eval_pair_sign_batch(&args, &mut s0, &mut s1);
    for j in 0..args.len() {
        assert_eq!(s0[j], v0[j] > 0.0, "slot0 t={}", args[j]);
        assert_eq!(s1[j], v1[j] > 0.0, "slot1 t={}", args[j]);
        assert_eq!(v0[j].abs(), 1.0);
        assert_eq!(v1[j].abs(), 1.0);
    }
    // The derived default (MultiBitQuantizer B=1) honors the same contract.
    let mb = MultiBitQuantizer::new(1);
    assert!(mb.is_binary());
    assert!(!MultiBitQuantizer::new(2).is_binary());
    sig_contract_holds(&mb, &args);
    assert!(UniversalQuantizer.is_binary());
    assert!(!crate::signature::Cosine.is_binary());
}

fn sig_contract_holds(sig: &dyn Signature, args: &[f64]) {
    let mut v0 = vec![0.0; args.len()];
    let mut v1 = vec![0.0; args.len()];
    sig.eval_pair_batch(args, &mut v0, &mut v1);
    let mut s0 = vec![false; args.len()];
    let mut s1 = vec![false; args.len()];
    sig.eval_pair_sign_batch(args, &mut s0, &mut s1);
    for j in 0..args.len() {
        assert_eq!(v0[j].abs(), 1.0, "is_binary signature must be ±1");
        assert_eq!(v1[j].abs(), 1.0);
        assert_eq!(s0[j], v0[j] > 0.0);
        assert_eq!(s1[j], v1[j] > 0.0);
    }
}

/// `pool_bits_range` (the kernel entry, not the operator dispatch) equals
/// per-row encode + add for a partial trailing panel, and the counts add up.
#[test]
fn bitpanel_aggregator_entry_matches_per_row_adds() {
    let op = quantized_op(3, 21, 42);
    let rows = 70; // one full panel + a 6-row trailing panel
    let mut rng = Rng::new(4242);
    let x = Mat::from_fn(rows, op.dim(), |_, _| rng.gaussian());
    let mut want = BitAggregator::new(op.sketch_len());
    for r in 0..rows {
        want.add(&op.encode_point_bits(x.row(r)));
    }
    let mut got = BitAggregator::new(op.sketch_len());
    bitpanel::pool_bits_range(
        &op.frequencies().omega,
        &op.frequencies().xi,
        op.signature(),
        &x,
        0..rows,
        &mut got,
    );
    assert_eq!(got.count(), rows as u64);
    assert_eq!(got.to_sum(), want.to_sum());
    assert_eq!(got.mean(), want.mean());
}
