//! The wire protocol of the sketch service — length-prefixed binary frames
//! over TCP, std-only, little-endian throughout (matching `.qsk`).
//!
//! ```text
//! frame    := u32 len | payload          (len counts the payload bytes)
//! payload  := u8 proto_version | u8 tag | body
//! ```
//!
//! Requests and responses share the framing; a response's first body byte
//! is a status (`0` ok, `1` error + UTF-8 message). Every integer and
//! float field is fixed-width little-endian, strings are `u32 len + UTF-8`
//! — the same primitives as the `.qsk` container, so the snapshot response
//! body *is* a `.qsk` byte stream.
//!
//! Decoding is defensive: frame lengths, row/dimension counts, string
//! lengths and vector sizes are all bounds-checked before allocation, so a
//! corrupt or adversarial peer gets an error, never an OOM or a panic.

use crate::obs::trace::TraceContext;
use anyhow::{bail, Context, Result};
use std::io::{Read, Write};

/// Protocol version carried in every frame. Version 2 added the declared
/// method-spec string to push/query/snapshot requests (so every stage of a
/// distributed job agrees on the method, mismatches refused server-side)
/// and to the stats report. Version 3 added the decoder-spec string to
/// query frames (the centroid cache keys on it, so a query can never be
/// served centroids decoded under a different algorithm) and per-decoder
/// query counters to the stats report. Version 4 added the metrics verb
/// (a Prometheus text page response, `qckm ctl metrics`) and the
/// `max_shards` capacity field to the stats report. Version 5 added the
/// optional trace-context extension on push/query/snapshot (a trailing
/// presence byte plus 16-byte trace id and 8-byte parent span id) and
/// the trace verb (`qckm ctl trace`, a JSON response of recent
/// server-side span trees). Version 6 added the tenant scope block (a
/// tenant name + auth token addressing one of several named sketches
/// hosted by a multi-tenant server) on push/query/snapshot/roll/stats/
/// trace, the delta verb (an aggregator forwarding a merged `.qsk` pool
/// upstream with an idempotency key, see `crate::fanin`), the busy
/// status (a typed overload refusal carrying a retry-after hint the
/// retrying client sleeps on), and per-tenant occupancy in the stats
/// report.
///
/// Unlike pre-v5 bumps, v5/v6 keep v4 decodable: this build *accepts*
/// versions [`MIN_PROTO_VERSION`]..=[`PROTO_VERSION`] and replies to
/// each request at the version the request arrived in, so pre-v6
/// clients are served identically (INVARIANTS.md I-19). Requests that
/// *carry* v6 content (a non-empty scope, the delta verb) refuse to
/// encode at lower versions instead of silently dropping it; the v6
/// stats extension fields are informational and are omitted, not
/// refused, in replies to older clients.
pub const PROTO_VERSION: u8 = 6;
/// Oldest protocol version this build still decodes (see
/// [`PROTO_VERSION`]). Requests below it are refused with a version
/// error, exactly as before.
pub const MIN_PROTO_VERSION: u8 = 4;

/// Whether `version` is one this build speaks.
pub fn version_supported(version: u8) -> bool {
    (MIN_PROTO_VERSION..=PROTO_VERSION).contains(&version)
}
/// Hard ceiling on one frame's payload (256 MiB) — covers the largest
/// plausible push batch and snapshot while bounding allocations.
pub const MAX_FRAME_BYTES: usize = 1 << 28;
/// Ceiling on rows in one push batch. For wide data the frame cap binds
/// first: a batch must also fit `rows × dim × 8` bytes under
/// [`MAX_FRAME_BYTES`] (see [`max_batch_rows`]).
pub const MAX_PUSH_ROWS: usize = 1 << 22;

/// The largest push batch (in rows) that fits one frame at dimension
/// `dim`, with headroom for the message header.
pub fn max_batch_rows(dim: usize) -> usize {
    ((MAX_FRAME_BYTES / 2) / (8 * dim.max(1))).clamp(1, MAX_PUSH_ROWS)
}
/// Ceiling on the dimension field (matches the `.qsk` plausibility bound).
pub const MAX_DIM: usize = 1 << 24;
/// Ceiling on shard-label bytes (matches `.qsk` provenance labels).
pub const MAX_SHARD_BYTES: usize = 256;
/// Ceiling on method-spec bytes (matches the `.qsk` method field cap).
pub const MAX_METHOD_BYTES: usize = 64;
/// Ceiling on decoder-spec bytes carried in query frames.
pub const MAX_DECODER_BYTES: usize = 64;
/// Ceiling on an error message's bytes, enforced on *both* sides of the
/// wire: `encode_response` truncates (on a char boundary, with a marker)
/// and `decode_response` refuses anything longer. Without the encode-side
/// truncation a long server error would decode client-side as
/// "implausible string field" instead of the actual message.
pub const MAX_ERROR_BYTES: usize = 1 << 16;
/// Ceiling on a metrics page's bytes (4 MiB), enforced like
/// [`MAX_ERROR_BYTES`] on both sides: `encode_response` truncates on a
/// char boundary with a marker, `decode_response` refuses anything
/// longer. A real page is kilobytes; the cap only bounds a hostile peer.
pub const MAX_METRICS_BYTES: usize = 1 << 22;
/// Ceiling on a trace-JSON response's bytes (4 MiB), enforced like
/// [`MAX_METRICS_BYTES`] on both sides. A full ring of max-depth traces
/// is well under this; the cap only bounds a hostile peer.
pub const MAX_TRACE_BYTES: usize = 1 << 22;
/// Ceiling on the `limit` field of a trace request — far above any real
/// ring capacity, small enough to be an obvious plausibility bound.
pub const MAX_TRACE_LIMIT: u32 = 1 << 16;
/// Ceiling on a tenant name's bytes. Tenant names also double as the
/// bounded `tenant` metric label, so they are further validated (charset
/// and declaration-time registration) above the wire layer.
pub const MAX_TENANT_BYTES: usize = 64;
/// Ceiling on an auth token's bytes carried in the v6 scope block.
pub const MAX_TOKEN_BYTES: usize = 128;
/// Ceiling on the `.qsk` payload of one delta frame — a merged pool plus
/// provenance, same bound as a snapshot body.
pub const MAX_DELTA_BYTES: usize = MAX_FRAME_BYTES / 2;

pub(crate) const TAG_PUSH: u8 = 1;
pub(crate) const TAG_QUERY: u8 = 2;
pub(crate) const TAG_SNAPSHOT: u8 = 3;
pub(crate) const TAG_ROLL: u8 = 4;
pub(crate) const TAG_STATS: u8 = 5;
pub(crate) const TAG_SHUTDOWN: u8 = 6;
pub(crate) const TAG_METRICS: u8 = 7;
pub(crate) const TAG_TRACE: u8 = 8;
pub(crate) const TAG_DELTA: u8 = 9;

const STATUS_OK: u8 = 0;
const STATUS_ERR: u8 = 1;
const STATUS_BUSY: u8 = 2;

/// The v6 tenant scope: which named sketch a request addresses, and the
/// auth token presented for it. An all-empty scope is the wire form of
/// "the server's default tenant, no token" — exactly what pre-v6 frames
/// decode to, so a single-tenant server serves old and new clients
/// identically.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct Scope {
    /// Tenant name; empty = the server's default tenant.
    pub tenant: String,
    /// Auth token; empty = none presented.
    pub token: String,
}

impl Scope {
    /// A scope addressing `tenant` with `token` (either may be empty).
    pub fn new(tenant: impl Into<String>, token: impl Into<String>) -> Self {
        Self {
            tenant: tenant.into(),
            token: token.into(),
        }
    }

    /// Whether this scope carries nothing — encodable at any version.
    pub fn is_empty(&self) -> bool {
        self.tenant.is_empty() && self.token.is_empty()
    }
}

/// A decode query: how many centroids, over which window, with which
/// decoder configuration.
#[derive(Clone, Debug, PartialEq)]
pub struct QuerySpec {
    /// Number of centroids to decode.
    pub k: u32,
    /// `0` = all-time; `E ≥ 1` = the open epoch plus the `E − 1` most
    /// recently closed epochs.
    pub window: u32,
    /// Decoder replicates (best objective wins); clamped to ≥ 1.
    pub replicates: u32,
    /// Decoder RNG seed; `None` = the operator's frequency-draw seed,
    /// matching `qckm decode`'s default.
    pub seed: Option<u64>,
    /// Centroid search box lower bound (every coordinate).
    pub lo: f64,
    /// Centroid search box upper bound (every coordinate).
    pub hi: f64,
    /// Canonical decoder spec ([`crate::decoder::DecoderSpec`]); empty =
    /// the server's default (`clompr`). Part of the centroid-cache key, so
    /// two queries with different decoders never share cached centroids.
    pub decoder: String,
}

/// A decoded window: centroids plus the window's bookkeeping.
#[derive(Clone, Debug, PartialEq)]
pub struct CentroidReport {
    /// `k × dim`, row-major.
    pub centroids: Vec<f64>,
    pub k: u32,
    pub dim: u32,
    /// Mixture weights, length `k`.
    pub weights: Vec<f64>,
    /// Final sketch-matching objective.
    pub objective: f64,
    /// Rows pooled into the decoded window.
    pub rows: u64,
    /// Epochs merged into the window (1 = just the open epoch).
    pub epochs: u32,
    /// Whether the centroid cache answered (no decode ran).
    pub cached: bool,
}

/// Server counters returned by a stats request. The `tenant` and
/// `tenants` fields are v6 extensions: informational, omitted (not
/// refused) when the reply encodes at v4/v5.
#[derive(Clone, Debug, PartialEq)]
pub struct StatsReport {
    /// The server operator's canonical method spec.
    pub method: String,
    /// Index of the open epoch (0-based; incremented by each roll).
    pub epoch: u64,
    /// All-time pooled rows.
    pub rows_total: u64,
    /// Closed epochs currently held in the window ring.
    pub epochs_held: u32,
    /// The server's shard-label cap ([`crate::server::ServiceConfig::max_shards`]) —
    /// reported so operators can see headroom against the cap (refusals
    /// start when `shards.len()` reaches it).
    pub max_shards: u64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    /// All-time per-shard row counts, in stable shard-key order.
    pub shards: Vec<(String, u64)>,
    /// Queries answered per canonical decoder spec (hits and misses), in
    /// stable spec order — the "active decoder(s)" view, so centroid-cache
    /// effectiveness per algorithm is observable from `qckm ctl stats`.
    pub decoders: Vec<(String, u64)>,
    /// The tenant this report describes; empty on a single-tenant server
    /// and in every pre-v6 reply.
    pub tenant: String,
    /// Per-tenant occupancy across the whole server, in stable name
    /// order: `(tenant, all-time rows, shard slots used)`. Empty on a
    /// single-tenant server and in every pre-v6 reply.
    pub tenants: Vec<(String, u64, u64)>,
}

/// Client → server messages.
///
/// `method` on push/query/snapshot is the client's *declared* canonical
/// method spec ([`crate::method::MethodSpec`]); empty means "don't check".
/// The server refuses any request whose declared method does not resolve
/// to its operator's method, so mixed-method pipelines fail loudly at the
/// protocol boundary instead of pooling incompatible sketches.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    /// Ingest a row batch into `shard`'s accumulator (`rows × dim`,
    /// row-major).
    Push {
        scope: Scope,
        shard: String,
        method: String,
        dim: u32,
        data: Vec<f64>,
        /// Optional v5 trace context; `None` on the wire at v4.
        trace: Option<TraceContext>,
    },
    /// Decode centroids from a window.
    Query {
        scope: Scope,
        spec: QuerySpec,
        method: String,
        /// Optional v5 trace context; `None` on the wire at v4.
        trace: Option<TraceContext>,
    },
    /// Serialize a window as `.qsk` bytes.
    Snapshot {
        scope: Scope,
        window: u32,
        method: String,
        /// Optional v5 trace context; `None` on the wire at v4.
        trace: Option<TraceContext>,
    },
    /// Close the open epoch and start a new one.
    Roll { scope: Scope },
    /// Report counters.
    Stats { scope: Scope },
    /// Render the server's metrics registry as a Prometheus text page.
    Metrics,
    /// Fetch recent server-side traces as JSON: one by id, or the
    /// newest `limit` (0 = the server's default). v5 only.
    Trace {
        scope: Scope,
        id: Option<[u8; 16]>,
        limit: u32,
    },
    /// Merge an aggregator's pre-pooled `.qsk` delta (see `crate::fanin`).
    /// Idempotency key: `(agg_id, instance, seq)` — the parent admits a
    /// delta only when `seq` advances past the last admitted sequence for
    /// this `agg_id`'s current `instance`, so the retrying flush link may
    /// replay a delta without double-counting (INVARIANTS.md I-21).
    /// v6 only.
    Delta {
        scope: Scope,
        /// The aggregator's identity; doubles as the server-side shard
        /// label prefix for the merged rows.
        agg_id: String,
        /// Startup nonce — a restarted aggregator gets a fresh instance,
        /// which resets its sequence tracking upstream.
        instance: u64,
        /// Flush sequence number, strictly increasing per instance.
        seq: u64,
        /// A full `.qsk` byte stream (meta + pooled sums + provenance).
        sketch: Vec<u8>,
        /// Optional trace context.
        trace: Option<TraceContext>,
    },
    /// Stop the server (responds before exiting).
    Shutdown,
}

impl Request {
    /// The request's protocol verb name — the `verb` label on the
    /// server's request counters and latency histograms.
    pub fn verb(&self) -> &'static str {
        match self {
            Request::Push { .. } => "push",
            Request::Query { .. } => "query",
            Request::Snapshot { .. } => "snapshot",
            Request::Roll { .. } => "roll",
            Request::Stats { .. } => "stats",
            Request::Metrics => "metrics",
            Request::Trace { .. } => "trace",
            Request::Delta { .. } => "delta",
            Request::Shutdown => "shutdown",
        }
    }

    /// The trace context carried by this request, if any (only
    /// push/query/snapshot/delta can carry one).
    pub fn trace_context(&self) -> Option<TraceContext> {
        match self {
            Request::Push { trace, .. }
            | Request::Query { trace, .. }
            | Request::Snapshot { trace, .. }
            | Request::Delta { trace, .. } => *trace,
            _ => None,
        }
    }

    /// The tenant scope this request addresses, if the verb is scoped
    /// (metrics and shutdown are server-wide).
    pub fn scope(&self) -> Option<&Scope> {
        match self {
            Request::Push { scope, .. }
            | Request::Query { scope, .. }
            | Request::Snapshot { scope, .. }
            | Request::Roll { scope }
            | Request::Stats { scope }
            | Request::Trace { scope, .. }
            | Request::Delta { scope, .. } => Some(scope),
            Request::Metrics | Request::Shutdown => None,
        }
    }
}

/// Server → client messages.
#[derive(Clone, Debug, PartialEq)]
pub enum Response {
    /// The request failed; human-readable reason.
    Error(String),
    /// The server is shedding load (rate limit or ingest backpressure):
    /// retry the same request after the hinted delay. Encodes as its own
    /// status byte at v6; for pre-v6 clients it degrades to a plain
    /// error carrying the same hint in text.
    Busy {
        /// How long the client should wait before retrying.
        retry_after_ms: u64,
        /// Human-readable reason (which limiter fired).
        message: String,
    },
    /// Push accepted: the shard's all-time rows and the server's total.
    PushAck { shard_rows: u64, total_rows: u64 },
    /// Query result.
    Centroids(CentroidReport),
    /// A `.qsk` byte stream (exactly what `save_sketch` would write).
    Snapshot(Vec<u8>),
    /// Epoch rolled: the new open epoch's index and the closed epoch's rows.
    RollAck { epoch: u64, rows_closed: u64 },
    Stats(StatsReport),
    /// A Prometheus text-format exposition page.
    Metrics(String),
    /// A JSON document of recent traces (`{"traces":[…]}`). v5 only.
    Traces(String),
    /// A delta was processed: whether it was merged (`false` = recognized
    /// replay, dropped idempotently) and the tenant's all-time rows after
    /// the call. v6 only.
    DeltaAck { merged: bool, rows_total: u64 },
    ShutdownAck,
}

// ------------------------------------------------------------------ framing

/// Write one frame: `u32 len | payload`.
pub fn write_frame(w: &mut impl Write, payload: &[u8]) -> Result<()> {
    if payload.len() > MAX_FRAME_BYTES {
        bail!(
            "message of {} bytes exceeds the {MAX_FRAME_BYTES}-byte frame cap \
             (split the batch)",
            payload.len()
        );
    }
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(payload)?;
    w.flush()?;
    Ok(())
}

/// Read one frame's payload. `Ok(None)` means the peer closed the
/// connection cleanly (EOF before any length byte).
pub fn read_frame(r: &mut impl Read) -> Result<Option<Vec<u8>>> {
    let mut len_buf = [0u8; 4];
    let mut filled = 0;
    while filled < 4 {
        let n = r.read(&mut len_buf[filled..])?;
        if n == 0 {
            if filled == 0 {
                return Ok(None);
            }
            bail!("connection closed mid-frame");
        }
        filled += n;
    }
    let len = u32::from_le_bytes(len_buf) as usize;
    if len == 0 || len > MAX_FRAME_BYTES {
        bail!("implausible frame length {len}");
    }
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload).context("truncated frame")?;
    Ok(Some(payload))
}

/// Write a request as one frame.
pub fn write_request(w: &mut impl Write, req: &Request) -> Result<()> {
    write_frame(w, &encode_request(req))
}

/// Read a request frame; `Ok(None)` on clean EOF.
pub fn read_request(r: &mut impl Read) -> Result<Option<Request>> {
    match read_frame(r)? {
        None => Ok(None),
        Some(payload) => Ok(Some(decode_request(&payload)?)),
    }
}

/// Write a response as one frame.
pub fn write_response(w: &mut impl Write, resp: &Response) -> Result<()> {
    write_frame(w, &encode_response(resp))
}

/// Read a response frame (EOF is an error — a reply was expected).
pub fn read_response(r: &mut impl Read) -> Result<Response> {
    match read_frame(r)? {
        None => bail!("server closed the connection before replying"),
        Some(payload) => decode_response(&payload),
    }
}

// ----------------------------------------------------------------- encoding

/// Serialize a request payload at the current version (version byte
/// included, frame length not).
pub fn encode_request(req: &Request) -> Vec<u8> {
    encode_request_v(req, PROTO_VERSION).expect("the current version encodes every request")
}

/// Serialize a request payload at a specific protocol version. Fails
/// when the request needs a capability the version lacks: at v4 that is
/// a carried trace context or the trace verb; below v6 it is a
/// non-empty tenant scope or the delta verb — refusing beats silently
/// dropping the tenant address and pooling into the wrong sketch.
pub fn encode_request_v(req: &Request, version: u8) -> Result<Vec<u8>> {
    if !version_supported(version) {
        bail!("cannot encode protocol version {version} (this build speaks {MIN_PROTO_VERSION}..={PROTO_VERSION})");
    }
    if version < 5 && req.trace_context().is_some() {
        bail!("trace context needs proto v5 (asked to encode v{version})");
    }
    if version < 6 && req.scope().is_some_and(|s| !s.is_empty()) {
        bail!("tenant scope needs proto v6 (asked to encode v{version})");
    }
    let mut b = vec![version];
    match req {
        Request::Push {
            scope,
            shard,
            method,
            dim,
            data,
            trace,
        } => {
            b.push(TAG_PUSH);
            put_scope(&mut b, scope, version);
            put_str(&mut b, shard);
            put_str(&mut b, method);
            b.extend_from_slice(&dim.to_le_bytes());
            b.extend_from_slice(&(data.len() as u64).to_le_bytes());
            for &v in data {
                b.extend_from_slice(&v.to_le_bytes());
            }
            put_trace(&mut b, trace, version);
        }
        Request::Query {
            scope,
            spec: q,
            method,
            trace,
        } => {
            b.push(TAG_QUERY);
            put_scope(&mut b, scope, version);
            put_str(&mut b, method);
            b.extend_from_slice(&q.k.to_le_bytes());
            b.extend_from_slice(&q.window.to_le_bytes());
            b.extend_from_slice(&q.replicates.to_le_bytes());
            b.push(q.seed.is_some() as u8);
            b.extend_from_slice(&q.seed.unwrap_or(0).to_le_bytes());
            b.extend_from_slice(&q.lo.to_le_bytes());
            b.extend_from_slice(&q.hi.to_le_bytes());
            put_str(&mut b, &q.decoder);
            put_trace(&mut b, trace, version);
        }
        Request::Snapshot {
            scope,
            window,
            method,
            trace,
        } => {
            b.push(TAG_SNAPSHOT);
            put_scope(&mut b, scope, version);
            put_str(&mut b, method);
            b.extend_from_slice(&window.to_le_bytes());
            put_trace(&mut b, trace, version);
        }
        Request::Roll { scope } => {
            b.push(TAG_ROLL);
            put_scope(&mut b, scope, version);
        }
        Request::Stats { scope } => {
            b.push(TAG_STATS);
            put_scope(&mut b, scope, version);
        }
        Request::Metrics => b.push(TAG_METRICS),
        Request::Trace { scope, id, limit } => {
            if version < 5 {
                bail!("the trace verb needs proto v5 (asked to encode v{version})");
            }
            b.push(TAG_TRACE);
            put_scope(&mut b, scope, version);
            b.push(id.is_some() as u8);
            if let Some(id) = id {
                b.extend_from_slice(id);
            }
            b.extend_from_slice(&limit.to_le_bytes());
        }
        Request::Delta {
            scope,
            agg_id,
            instance,
            seq,
            sketch,
            trace,
        } => {
            if version < 6 {
                bail!("the delta verb needs proto v6 (asked to encode v{version})");
            }
            b.push(TAG_DELTA);
            put_scope(&mut b, scope, version);
            put_str(&mut b, agg_id);
            b.extend_from_slice(&instance.to_le_bytes());
            b.extend_from_slice(&seq.to_le_bytes());
            b.extend_from_slice(&(sketch.len() as u64).to_le_bytes());
            b.extend_from_slice(sketch);
            put_trace(&mut b, trace, version);
        }
        Request::Shutdown => b.push(TAG_SHUTDOWN),
    }
    Ok(b)
}

/// Parse a request payload (any supported version; the version is
/// discarded — use [`decode_request_v`] to echo it in the reply).
pub fn decode_request(payload: &[u8]) -> Result<Request> {
    Ok(decode_request_v(payload)?.1)
}

/// Parse a request payload, returning the version it arrived in so the
/// server can answer pre-v5 clients at their own version.
pub fn decode_request_v(payload: &[u8]) -> Result<(u8, Request)> {
    let mut r = ByteReader::new(payload);
    let version = r.u8()?;
    if !version_supported(version) {
        bail!("unsupported protocol version {version} (this build speaks {MIN_PROTO_VERSION}..={PROTO_VERSION})");
    }
    let req = match r.u8()? {
        TAG_PUSH => {
            let scope = take_scope(&mut r, version)?;
            let shard = r.str(MAX_SHARD_BYTES)?;
            if shard.is_empty() {
                bail!("push: empty shard label");
            }
            let method = r.str(MAX_METHOD_BYTES)?;
            let dim = r.u32()?;
            if dim == 0 || dim as usize > MAX_DIM {
                bail!("push: implausible dimension {dim}");
            }
            let len = r.u64()? as usize;
            if len == 0 {
                // A zero-row push would create an empty shard accumulator
                // and a zero-row provenance record for nothing — refuse it
                // at the protocol boundary (the client has no reason to
                // send one, and a retrying client must not retry it).
                bail!("push: empty batch (zero rows)");
            }
            if len % dim as usize != 0 {
                bail!("push: {len} values is not a whole number of {dim}-dim rows");
            }
            if len / dim as usize > MAX_PUSH_ROWS {
                bail!("push: batch exceeds {MAX_PUSH_ROWS} rows");
            }
            let data = r.f64_vec(len)?;
            let trace = take_trace(&mut r, version)?;
            Request::Push {
                scope,
                shard,
                method,
                dim,
                data,
                trace,
            }
        }
        TAG_QUERY => {
            let scope = take_scope(&mut r, version)?;
            let method = r.str(MAX_METHOD_BYTES)?;
            let k = r.u32()?;
            let window = r.u32()?;
            let replicates = r.u32()?;
            let has_seed = r.u8()? != 0;
            let seed_raw = r.u64()?;
            let lo = r.f64()?;
            let hi = r.f64()?;
            let decoder = r.str(MAX_DECODER_BYTES)?;
            let trace = take_trace(&mut r, version)?;
            Request::Query {
                scope,
                spec: QuerySpec {
                    k,
                    window,
                    replicates,
                    seed: has_seed.then_some(seed_raw),
                    lo,
                    hi,
                    decoder,
                },
                method,
                trace,
            }
        }
        TAG_SNAPSHOT => {
            let scope = take_scope(&mut r, version)?;
            let method = r.str(MAX_METHOD_BYTES)?;
            let window = r.u32()?;
            let trace = take_trace(&mut r, version)?;
            Request::Snapshot {
                scope,
                method,
                window,
                trace,
            }
        }
        TAG_ROLL => Request::Roll {
            scope: take_scope(&mut r, version)?,
        },
        TAG_STATS => Request::Stats {
            scope: take_scope(&mut r, version)?,
        },
        TAG_METRICS => Request::Metrics,
        TAG_TRACE => {
            if version < 5 {
                bail!("the trace verb needs proto v5 (frame declares v{version})");
            }
            let scope = take_scope(&mut r, version)?;
            let has_id = r.u8()? != 0;
            let id = if has_id {
                let mut id = [0u8; 16];
                id.copy_from_slice(r.take(16)?);
                Some(id)
            } else {
                None
            };
            let limit = r.u32()?;
            if limit > MAX_TRACE_LIMIT {
                bail!("implausible trace limit {limit}");
            }
            Request::Trace { scope, id, limit }
        }
        TAG_DELTA => {
            if version < 6 {
                bail!("the delta verb needs proto v6 (frame declares v{version})");
            }
            let scope = take_scope(&mut r, version)?;
            let agg_id = r.str(MAX_SHARD_BYTES)?;
            if agg_id.is_empty() {
                bail!("delta: empty aggregator id");
            }
            let instance = r.u64()?;
            let seq = r.u64()?;
            let len = r.u64()? as usize;
            if len == 0 {
                bail!("delta: empty sketch payload");
            }
            if len > MAX_DELTA_BYTES {
                bail!("delta: sketch payload of {len} bytes exceeds the {MAX_DELTA_BYTES}-byte cap");
            }
            let sketch = r.bytes(len)?;
            let trace = take_trace(&mut r, version)?;
            Request::Delta {
                scope,
                agg_id,
                instance,
                seq,
                sketch,
                trace,
            }
        }
        TAG_SHUTDOWN => Request::Shutdown,
        tag => bail!("unknown request tag {tag}"),
    };
    r.finish()?;
    Ok((version, req))
}

/// Append the v5 trace-context block: a presence byte, then (when
/// present) the 16-byte trace id and 8-byte parent span id. At v4
/// nothing is written — the caller already refused Some(trace) at v4.
fn put_trace(b: &mut Vec<u8>, trace: &Option<TraceContext>, version: u8) {
    if version < 5 {
        return;
    }
    b.push(trace.is_some() as u8);
    if let Some(t) = trace {
        b.extend_from_slice(&t.trace_id);
        b.extend_from_slice(&t.parent_span);
    }
}

/// The tag byte of a request payload (`payload[1]`), if present. Lets
/// the multi-tenant router classify a frame (ingest? metrics? stats?)
/// without decoding the body.
pub(crate) fn payload_tag(payload: &[u8]) -> Option<u8> {
    payload.get(1).copied()
}

/// Whether this payload is an ingest frame (push or delta) — the verbs
/// the per-connection token-bucket rate limit applies to. Cheap: reads
/// two bytes, never allocates, so an overloaded node can shed the frame
/// before paying for a decode.
pub(crate) fn payload_is_ingest(payload: &[u8]) -> bool {
    matches!(payload_tag(payload), Some(TAG_PUSH) | Some(TAG_DELTA))
}

/// Peek the tenant scope of a request payload without a full decode —
/// the multi-tenant router reads it to pick the target service, then the
/// chosen service decodes the frame once. Anything that prevents a clean
/// peek (pre-v6 frame, unscoped verb, malformed block) yields the empty
/// scope: the request then routes to the default tenant, whose full
/// decode reports the real error.
pub(crate) fn peek_scope(payload: &[u8]) -> Scope {
    let Some(&version) = payload.first() else {
        return Scope::default();
    };
    if version < 6 || !version_supported(version) {
        return Scope::default();
    }
    match payload_tag(payload) {
        Some(TAG_PUSH) | Some(TAG_QUERY) | Some(TAG_SNAPSHOT) | Some(TAG_ROLL)
        | Some(TAG_STATS) | Some(TAG_TRACE) | Some(TAG_DELTA) => {
            let mut r = ByteReader::new(&payload[2..]);
            match (r.str(MAX_TENANT_BYTES), r.str(MAX_TOKEN_BYTES)) {
                (Ok(tenant), Ok(token)) => Scope { tenant, token },
                _ => Scope::default(),
            }
        }
        _ => Scope::default(),
    }
}

/// Append the v6 tenant-scope block: two strings (tenant, token)
/// immediately after the tag of every scoped verb. Below v6 nothing is
/// written — the caller already refused a non-empty scope there.
fn put_scope(b: &mut Vec<u8>, scope: &Scope, version: u8) {
    if version < 6 {
        return;
    }
    put_str(b, &scope.tenant);
    put_str(b, &scope.token);
}

/// Read the v6 tenant-scope block (absent entirely below v6, which
/// decodes to the empty scope — the default tenant, no token).
fn take_scope(r: &mut ByteReader<'_>, version: u8) -> Result<Scope> {
    if version < 6 {
        return Ok(Scope::default());
    }
    let tenant = r.str(MAX_TENANT_BYTES)?;
    let token = r.str(MAX_TOKEN_BYTES)?;
    Ok(Scope { tenant, token })
}

/// Read the v5 trace-context block (absent entirely at v4).
fn take_trace(r: &mut ByteReader<'_>, version: u8) -> Result<Option<TraceContext>> {
    if version < 5 {
        return Ok(None);
    }
    if r.u8()? == 0 {
        return Ok(None);
    }
    let mut trace_id = [0u8; 16];
    trace_id.copy_from_slice(r.take(16)?);
    let mut parent_span = [0u8; 8];
    parent_span.copy_from_slice(r.take(8)?);
    Ok(Some(TraceContext { trace_id, parent_span }))
}

/// Serialize a response payload at the current version.
pub fn encode_response(resp: &Response) -> Vec<u8> {
    encode_response_v(resp, PROTO_VERSION).expect("the current version encodes every response")
}

/// Serialize a response payload at a specific protocol version — the
/// server answers every request at the version it arrived in. Fails for
/// v5-only content at v4 (a traces response) and v6-only content below
/// v6 (a delta ack), neither of which can arise from a well-formed
/// older request. A busy response *degrades* below v6 — pre-v6 clients
/// must still hear about overload, so they get a plain error carrying
/// the hint in text. The v6 stats extension fields are informational
/// and are simply omitted below v6.
pub fn encode_response_v(resp: &Response, version: u8) -> Result<Vec<u8>> {
    if !version_supported(version) {
        bail!("cannot encode protocol version {version} (this build speaks {MIN_PROTO_VERSION}..={PROTO_VERSION})");
    }
    if version < 5 {
        if let Response::Traces(_) = resp {
            bail!("a traces response needs proto v5 (asked to encode v{version})");
        }
    }
    if version < 6 {
        if let Response::DeltaAck { .. } = resp {
            bail!("a delta ack needs proto v6 (asked to encode v{version})");
        }
    }
    let mut b = vec![version];
    match resp {
        Response::Error(msg) => {
            b.push(STATUS_ERR);
            put_str(&mut b, &truncate_to(msg, MAX_ERROR_BYTES));
        }
        Response::Busy {
            retry_after_ms,
            message,
        } => {
            if version < 6 {
                // Degrade, don't refuse: an old client must still learn
                // it was shed. The hint survives in text only.
                b.push(STATUS_ERR);
                let msg = format!("server busy (retry after {retry_after_ms} ms): {message}");
                put_str(&mut b, &truncate_to(&msg, MAX_ERROR_BYTES));
            } else {
                b.push(STATUS_BUSY);
                b.extend_from_slice(&retry_after_ms.to_le_bytes());
                put_str(&mut b, &truncate_to(message, MAX_ERROR_BYTES));
            }
        }
        Response::PushAck {
            shard_rows,
            total_rows,
        } => {
            b.push(STATUS_OK);
            b.push(TAG_PUSH);
            b.extend_from_slice(&shard_rows.to_le_bytes());
            b.extend_from_slice(&total_rows.to_le_bytes());
        }
        Response::Centroids(c) => {
            b.push(STATUS_OK);
            b.push(TAG_QUERY);
            b.extend_from_slice(&c.k.to_le_bytes());
            b.extend_from_slice(&c.dim.to_le_bytes());
            b.extend_from_slice(&c.objective.to_le_bytes());
            b.extend_from_slice(&c.rows.to_le_bytes());
            b.extend_from_slice(&c.epochs.to_le_bytes());
            b.push(c.cached as u8);
            for &v in &c.centroids {
                b.extend_from_slice(&v.to_le_bytes());
            }
            for &v in &c.weights {
                b.extend_from_slice(&v.to_le_bytes());
            }
        }
        Response::Snapshot(bytes) => {
            b.push(STATUS_OK);
            b.push(TAG_SNAPSHOT);
            b.extend_from_slice(&(bytes.len() as u64).to_le_bytes());
            b.extend_from_slice(bytes);
        }
        Response::RollAck { epoch, rows_closed } => {
            b.push(STATUS_OK);
            b.push(TAG_ROLL);
            b.extend_from_slice(&epoch.to_le_bytes());
            b.extend_from_slice(&rows_closed.to_le_bytes());
        }
        Response::Stats(s) => {
            b.push(STATUS_OK);
            b.push(TAG_STATS);
            put_str(&mut b, &s.method);
            b.extend_from_slice(&s.epoch.to_le_bytes());
            b.extend_from_slice(&s.rows_total.to_le_bytes());
            b.extend_from_slice(&s.epochs_held.to_le_bytes());
            b.extend_from_slice(&s.max_shards.to_le_bytes());
            b.extend_from_slice(&s.cache_hits.to_le_bytes());
            b.extend_from_slice(&s.cache_misses.to_le_bytes());
            b.extend_from_slice(&(s.shards.len() as u32).to_le_bytes());
            for (label, rows) in &s.shards {
                put_str(&mut b, label);
                b.extend_from_slice(&rows.to_le_bytes());
            }
            b.extend_from_slice(&(s.decoders.len() as u32).to_le_bytes());
            for (spec, queries) in &s.decoders {
                put_str(&mut b, spec);
                b.extend_from_slice(&queries.to_le_bytes());
            }
            if version >= 6 {
                put_str(&mut b, &s.tenant);
                b.extend_from_slice(&(s.tenants.len() as u32).to_le_bytes());
                for (name, rows, shards) in &s.tenants {
                    put_str(&mut b, name);
                    b.extend_from_slice(&rows.to_le_bytes());
                    b.extend_from_slice(&shards.to_le_bytes());
                }
            }
        }
        Response::Metrics(page) => {
            b.push(STATUS_OK);
            b.push(TAG_METRICS);
            put_str(&mut b, &truncate_to(page, MAX_METRICS_BYTES));
        }
        Response::Traces(json) => {
            b.push(STATUS_OK);
            b.push(TAG_TRACE);
            put_str(&mut b, &truncate_to(json, MAX_TRACE_BYTES));
        }
        Response::DeltaAck { merged, rows_total } => {
            b.push(STATUS_OK);
            b.push(TAG_DELTA);
            b.push(*merged as u8);
            b.extend_from_slice(&rows_total.to_le_bytes());
        }
        Response::ShutdownAck => {
            b.push(STATUS_OK);
            b.push(TAG_SHUTDOWN);
        }
    }
    Ok(b)
}

/// Parse a response payload (any supported version).
pub fn decode_response(payload: &[u8]) -> Result<Response> {
    let mut r = ByteReader::new(payload);
    let version = r.u8()?;
    if !version_supported(version) {
        bail!("unsupported protocol version {version} (this build speaks {MIN_PROTO_VERSION}..={PROTO_VERSION})");
    }
    let status = r.u8()?;
    if status == STATUS_ERR {
        let msg = r.str(MAX_ERROR_BYTES)?;
        r.finish()?;
        return Ok(Response::Error(msg));
    }
    if status == STATUS_BUSY {
        if version < 6 {
            bail!("the busy status needs proto v6 (frame declares v{version})");
        }
        let retry_after_ms = r.u64()?;
        let message = r.str(MAX_ERROR_BYTES)?;
        r.finish()?;
        return Ok(Response::Busy {
            retry_after_ms,
            message,
        });
    }
    if status != STATUS_OK {
        bail!("unknown response status {status}");
    }
    let resp = match r.u8()? {
        TAG_PUSH => Response::PushAck {
            shard_rows: r.u64()?,
            total_rows: r.u64()?,
        },
        TAG_QUERY => {
            let k = r.u32()?;
            let dim = r.u32()?;
            if k as usize > 1 << 16 || dim as usize > MAX_DIM {
                bail!("implausible centroid report ({k} × {dim})");
            }
            let objective = r.f64()?;
            let rows = r.u64()?;
            let epochs = r.u32()?;
            let cached = r.u8()? != 0;
            let centroids = r.f64_vec(k as usize * dim as usize)?;
            let weights = r.f64_vec(k as usize)?;
            Response::Centroids(CentroidReport {
                centroids,
                k,
                dim,
                weights,
                objective,
                rows,
                epochs,
                cached,
            })
        }
        TAG_SNAPSHOT => {
            let len = r.u64()? as usize;
            Response::Snapshot(r.bytes(len)?)
        }
        TAG_ROLL => Response::RollAck {
            epoch: r.u64()?,
            rows_closed: r.u64()?,
        },
        TAG_STATS => {
            let method = r.str(MAX_METHOD_BYTES)?;
            let epoch = r.u64()?;
            let rows_total = r.u64()?;
            let epochs_held = r.u32()?;
            let max_shards = r.u64()?;
            let cache_hits = r.u64()?;
            let cache_misses = r.u64()?;
            let n = r.u32()? as usize;
            if n > 1 << 20 {
                bail!("implausible shard count {n}");
            }
            let mut shards = Vec::with_capacity(n);
            for _ in 0..n {
                let label = r.str(MAX_SHARD_BYTES)?;
                let rows = r.u64()?;
                shards.push((label, rows));
            }
            let nd = r.u32()? as usize;
            if nd > 1 << 16 {
                bail!("implausible decoder count {nd}");
            }
            let mut decoders = Vec::with_capacity(nd);
            for _ in 0..nd {
                let spec = r.str(MAX_DECODER_BYTES)?;
                let queries = r.u64()?;
                decoders.push((spec, queries));
            }
            let (tenant, tenants) = if version >= 6 {
                let tenant = r.str(MAX_TENANT_BYTES)?;
                let nt = r.u32()? as usize;
                if nt > 1 << 16 {
                    bail!("implausible tenant count {nt}");
                }
                let mut tenants = Vec::with_capacity(nt);
                for _ in 0..nt {
                    let name = r.str(MAX_TENANT_BYTES)?;
                    let rows = r.u64()?;
                    let shards = r.u64()?;
                    tenants.push((name, rows, shards));
                }
                (tenant, tenants)
            } else {
                (String::new(), Vec::new())
            };
            Response::Stats(StatsReport {
                method,
                epoch,
                rows_total,
                epochs_held,
                max_shards,
                cache_hits,
                cache_misses,
                shards,
                decoders,
                tenant,
                tenants,
            })
        }
        TAG_METRICS => Response::Metrics(r.str(MAX_METRICS_BYTES)?),
        TAG_TRACE => {
            if version < 5 {
                bail!("a traces response needs proto v5 (frame declares v{version})");
            }
            Response::Traces(r.str(MAX_TRACE_BYTES)?)
        }
        TAG_DELTA => {
            if version < 6 {
                bail!("a delta ack needs proto v6 (frame declares v{version})");
            }
            Response::DeltaAck {
                merged: r.u8()? != 0,
                rows_total: r.u64()?,
            }
        }
        TAG_SHUTDOWN => Response::ShutdownAck,
        tag => bail!("unknown response tag {tag}"),
    };
    r.finish()?;
    Ok(resp)
}

// --------------------------------------------------------------- primitives

/// Clamp a string field to its decode-side cap so the encode side never
/// emits a string the decode side refuses (error messages to
/// [`MAX_ERROR_BYTES`], metrics pages to [`MAX_METRICS_BYTES`]).
/// Truncation lands on a UTF-8 char boundary and appends a marker so the
/// client can tell the content was cut rather than malformed.
fn truncate_to(msg: &str, cap: usize) -> std::borrow::Cow<'_, str> {
    const MARKER: &str = "… [truncated]";
    if msg.len() <= cap {
        return std::borrow::Cow::Borrowed(msg);
    }
    let mut cut = cap - MARKER.len();
    while !msg.is_char_boundary(cut) {
        cut -= 1;
    }
    std::borrow::Cow::Owned(format!("{}{MARKER}", &msg[..cut]))
}

fn put_str(b: &mut Vec<u8>, s: &str) {
    b.extend_from_slice(&(s.len() as u32).to_le_bytes());
    b.extend_from_slice(s.as_bytes());
}

/// Bounds-checked sequential reader over a frame payload.
struct ByteReader<'a> {
    buf: &'a [u8],
    at: usize,
}

impl<'a> ByteReader<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Self { buf, at: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.buf.len() - self.at < n {
            bail!(
                "truncated message: wanted {n} bytes at offset {}, have {}",
                self.at,
                self.buf.len() - self.at
            );
        }
        let s = &self.buf[self.at..self.at + n];
        self.at += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn str(&mut self, cap: usize) -> Result<String> {
        let len = self.u32()? as usize;
        if len > cap {
            bail!("implausible string field ({len} bytes)");
        }
        String::from_utf8(self.take(len)?.to_vec()).context("non-UTF-8 string field")
    }

    fn bytes(&mut self, len: usize) -> Result<Vec<u8>> {
        if len > MAX_FRAME_BYTES {
            bail!("implausible byte field ({len} bytes)");
        }
        Ok(self.take(len)?.to_vec())
    }

    fn f64_vec(&mut self, len: usize) -> Result<Vec<f64>> {
        if len > MAX_FRAME_BYTES / 8 {
            bail!("implausible f64 vector ({len} values)");
        }
        let raw = self.take(len * 8)?;
        Ok(raw
            .chunks_exact(8)
            .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    /// Require the payload to be fully consumed (catches length confusion).
    fn finish(&self) -> Result<()> {
        if self.at != self.buf.len() {
            bail!(
                "{} trailing bytes after message body",
                self.buf.len() - self.at
            );
        }
        Ok(())
    }
}
