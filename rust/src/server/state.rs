//! Server state: shard accumulators, epoch windows, and the centroid cache.
//!
//! ## State model
//!
//! * **Shards.** Every push names a *shard* — the client's partition label
//!   (a sensor, a data file, a connection). Each shard owns one
//!   [`PooledSketch`] accumulator per epoch plus one all-time accumulator,
//!   so a query can be answered from any subset of shards and epochs
//!   without re-encoding anything.
//! * **Epochs.** [`SketchService::roll_epoch`] freezes the open epoch's
//!   per-shard accumulators into a ring of closed epochs (capacity
//!   [`ServiceConfig::epoch_capacity`], oldest evicted). A query window of
//!   `E` merges the open epoch plus the `E − 1` newest closed epochs;
//!   window `0` uses the all-time accumulators, which never evict.
//! * **Cache.** Decoding is the only expensive operation, and the sketch
//!   is a *sufficient statistic*: the decode is a pure function of (pooled
//!   bits, decoder configuration). The cache therefore keys on the FNV
//!   fingerprint of the merged window's exact (count, sum-bits) plus the
//!   [`QuerySpec`] fields *and the canonical decoder spec* — repeated
//!   queries against an unchanged window are answered without running the
//!   decoder, any push or roll that changes the pooled bits changes the
//!   key, and a query naming a different [`crate::decoder::DecoderSpec`]
//!   is always a miss, so stale or cross-algorithm hits are impossible by
//!   construction.
//!
//! ## Determinism
//!
//! Merges happen in a stable order — epochs chronologically, shards in
//! `BTreeMap` key order within each epoch — and each push batch is encoded
//! through the fixed-chunk [`sketch_into_par`] fold. Given the same rows
//! per shard, the merged sums are reproducible; for the ±1 quantized
//! method the sums are exact integers, so they are bit-for-bit identical
//! to the offline pipeline *regardless of how pushes are batched or
//! interleaved across connections* (float addition of small integers is
//! order-invariant). Dense methods additionally require the same per-shard
//! batch sequence for bitwise equality, like any floating-point fold.
//!
//! [`sketch_into_par`]: crate::sketch::SketchOperator::sketch_into_par

use crate::clompr::ClOmprParams;
use crate::decoder::DecoderSpec;
use crate::linalg::Mat;
use crate::obs::trace::{TraceRecord, TraceStore};
use crate::obs::{Clock, Counter, Gauge, Histogram, Registry, Span};
use crate::parallel::Parallelism;
use crate::rng::Rng;
use crate::sketch::{PooledSketch, SketchOperator};
use crate::stream::{pool_fingerprint, write_sketch_to, ShardRecord, SketchMeta};
use anyhow::{bail, Result};
use std::collections::{BTreeMap, VecDeque};
use std::sync::{Arc, Mutex, MutexGuard};

use super::proto::{CentroidReport, QuerySpec, StatsReport, MAX_SHARD_BYTES};

/// Tuning knobs for [`SketchService`].
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    /// Closed epochs retained for windowed queries (the ring size).
    pub epoch_capacity: usize,
    /// Cached decodes retained (insertion-order eviction).
    pub cache_capacity: usize,
    /// Threads for the per-push parallel encode (0 = all cores).
    pub threads: Parallelism,
    /// Distinct shard labels accepted before new ones are refused. Labels
    /// are client-chosen, so without a cap an unauthenticated pusher
    /// spamming fresh labels grows the accumulator maps without bound;
    /// with it every piece of server state is capacity-bounded. The
    /// refusal is an application error, which [`super::RetryClient`] does
    /// not retry.
    pub max_shards: usize,
    /// Base decoder tuning for query answering (including its thread
    /// knob). The algorithm itself comes from each query's declared
    /// [`crate::decoder::DecoderSpec`] (default `clompr`), whose explicit
    /// params override fields of this base.
    pub decode: ClOmprParams,
    /// Where the service registers its counters/histograms. The default
    /// is a fresh private registry (so in-process unit-test services
    /// never share counters); `qckm serve` passes
    /// [`crate::obs::global`] so one `ctl metrics` scrape covers the
    /// server alongside the stream/decoder/parallel library metrics.
    pub registry: Arc<Registry>,
    /// Finished request traces retained in the ring served by
    /// `ctl trace` (oldest evicted past this).
    pub trace_capacity: usize,
    /// This service's tenant name on a multi-tenant node (see
    /// [`super::tenants`]). Empty = the unnamed default tenant, which is
    /// also the legacy single-tenant mode: every instrument keeps its
    /// historical label set (no `tenant` label) and requests with an
    /// empty scope are served exactly as before proto v6. Non-empty
    /// names add a bounded `tenant` label to every instrument.
    pub tenant: String,
    /// Auth token required on every scoped request addressed to this
    /// tenant. `None` = open (the legacy behavior). Compared in constant
    /// time ([`super::tenants::constant_time_eq`]); failures count under
    /// `qckm_auth_failures_total{tenant}`.
    pub token: Option<String>,
    /// Canonical decoder spec used when a query declares none (empty =
    /// the registry default, `clompr`). Per-tenant, so two tenants on one
    /// node can default to different decode algorithms.
    pub default_decoder: String,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self {
            epoch_capacity: 16,
            cache_capacity: 32,
            threads: Parallelism::serial(),
            max_shards: 1024,
            decode: ClOmprParams::default(),
            registry: Arc::new(Registry::new(Arc::new(crate::obs::MonotonicClock::new()))),
            trace_capacity: 128,
            tenant: String::new(),
            token: None,
            default_decoder: String::new(),
        }
    }
}

/// The protocol verbs — the label set of the per-verb request counters
/// and latency histograms.
const VERBS: [&str; 9] =
    ["push", "query", "snapshot", "roll", "stats", "metrics", "trace", "delta", "shutdown"];

/// `ctl trace` with no explicit limit returns this many newest traces.
pub(crate) const DEFAULT_TRACE_LIMIT: usize = 16;

/// The service's registered instruments, resolved once at construction so
/// the request path never does a name lookup.
struct ServerMetrics {
    /// `qckm_requests_total{verb}` / `qckm_request_seconds{verb}`,
    /// indexed like [`VERBS`].
    verbs: Vec<(&'static str, Arc<Counter>, Arc<Histogram>)>,
    /// `qckm_push_rows_total` — rows accepted into shard accumulators.
    push_rows: Arc<Counter>,
    /// `qckm_push_bytes_total` — accepted row payload bytes (rows × dim × 8).
    push_bytes: Arc<Counter>,
    /// `qckm_ingest_encode_seconds` — per-batch parallel sketch encode.
    encode_seconds: Arc<Histogram>,
    /// `qckm_window_merge_seconds` — merging a query/snapshot window.
    window_merge_seconds: Arc<Histogram>,
    /// `qckm_cache_hits_total` / `qckm_cache_misses_total` — the centroid
    /// cache (these back [`StatsReport`]'s fields; there is no separate
    /// hand-rolled counter anymore).
    cache_hits: Arc<Counter>,
    cache_misses: Arc<Counter>,
    /// `qckm_uptime_seconds` — seconds since service construction, on the
    /// registry's clock (so the FakeClock golden stays exact). Refreshed
    /// at scrape time by [`SketchService::render_metrics`].
    uptime_seconds: Arc<Gauge>,
    /// `qckm_shards` / `qckm_epoch_ring_epochs` — occupancy mirrors of
    /// what `ctl stats` reports, refreshed at scrape time.
    shards_gauge: Arc<Gauge>,
    epoch_ring_gauge: Arc<Gauge>,
    /// `qckm_query_residual_norm` — final sketch-matching residual
    /// `‖z − A(P)‖` of each decode that ran (cache hits excluded: no
    /// decode, no residual).
    residual_norm: Arc<Histogram>,
    /// `qckm_query_outer_iters_total` / `qckm_query_atoms_replaced_total`
    /// — CL-OMPR effort and churn of the winning replicate per decode.
    outer_iters: Arc<Counter>,
    atoms_replaced: Arc<Counter>,
    /// `qckm_deltas_total{outcome}` — aggregator deltas merged vs.
    /// recognized replays dropped by the idempotency gate (I-21).
    delta_merged: Arc<Counter>,
    delta_replayed: Arc<Counter>,
}

impl ServerMetrics {
    /// Register this service's instruments. A non-empty `tenant` adds a
    /// `tenant` label to every series, so several tenants can share one
    /// registry (the `qckm serve` global) without colliding; the empty
    /// name keeps the exact historical label sets, preserving every
    /// pinned single-tenant exposition page.
    fn new(reg: &Registry, tenant: &str) -> Self {
        let lat = crate::obs::latency_buckets();
        // Extend a label set with the tenant label when the tenant is
        // named; registration copies the slices, so borrowing from a
        // temporary Vec here is fine.
        let with_tenant = |labels: &[(&str, &str)]| -> Vec<(String, String)> {
            let mut v: Vec<(String, String)> = labels
                .iter()
                .map(|&(k, val)| (k.to_string(), val.to_string()))
                .collect();
            if !tenant.is_empty() {
                v.push(("tenant".to_string(), tenant.to_string()));
            }
            v
        };
        let refs = |owned: &[(String, String)]| -> Vec<(&str, &str)> {
            owned.iter().map(|(k, v)| (k.as_str(), v.as_str())).collect()
        };
        let counter = |name: &str, help: &str, labels: &[(&str, &str)]| {
            let owned = with_tenant(labels);
            reg.counter(name, help, &refs(&owned))
        };
        let gauge = |name: &str, help: &str| {
            let owned = with_tenant(&[]);
            reg.gauge(name, help, &refs(&owned))
        };
        let histogram = |name: &str, help: &str, buckets: &[f64]| {
            let owned = with_tenant(&[]);
            reg.histogram(name, help, &refs(&owned), buckets)
        };
        let verbs = VERBS
            .iter()
            .map(|&verb| {
                let owned = with_tenant(&[("verb", verb)]);
                (
                    verb,
                    reg.counter(
                        "qckm_requests_total",
                        "Requests handled, by protocol verb.",
                        &refs(&owned),
                    ),
                    reg.histogram(
                        "qckm_request_seconds",
                        "Request handling latency, by protocol verb.",
                        &refs(&owned),
                        &lat,
                    ),
                )
            })
            .collect();
        Self {
            verbs,
            push_rows: counter(
                "qckm_push_rows_total",
                "Rows accepted into shard accumulators.",
                &[],
            ),
            push_bytes: counter(
                "qckm_push_bytes_total",
                "Accepted push payload bytes (rows x dim x 8).",
                &[],
            ),
            encode_seconds: histogram(
                "qckm_ingest_encode_seconds",
                "Parallel sketch encode of one push batch.",
                &lat,
            ),
            window_merge_seconds: histogram(
                "qckm_window_merge_seconds",
                "Merging one query/snapshot window from shard accumulators.",
                &lat,
            ),
            cache_hits: counter(
                "qckm_cache_hits_total",
                "Centroid-cache hits (query answered without decoding).",
                &[],
            ),
            cache_misses: counter(
                "qckm_cache_misses_total",
                "Centroid-cache misses (a decode ran).",
                &[],
            ),
            uptime_seconds: gauge(
                "qckm_uptime_seconds",
                "Seconds since service construction, on the registry clock.",
            ),
            shards_gauge: gauge(
                "qckm_shards",
                "Distinct shard labels tracked (all-time accumulators).",
            ),
            epoch_ring_gauge: gauge(
                "qckm_epoch_ring_epochs",
                "Closed epochs currently held in the window ring.",
            ),
            residual_norm: histogram(
                "qckm_query_residual_norm",
                "Final sketch-matching residual of each decode that ran.",
                &Histogram::log_boundaries(1e-4, 4.0, 12),
            ),
            outer_iters: counter(
                "qckm_query_outer_iters_total",
                "Decoder outer iterations across all decodes that ran.",
                &[],
            ),
            atoms_replaced: counter(
                "qckm_query_atoms_replaced_total",
                "CL-OMPR hard-threshold atom replacements across all decodes.",
                &[],
            ),
            delta_merged: counter(
                "qckm_deltas_total",
                "Aggregator deltas, by outcome (merged vs replayed-and-dropped).",
                &[("outcome", "merged")],
            ),
            delta_replayed: counter(
                "qckm_deltas_total",
                "Aggregator deltas, by outcome (merged vs replayed-and-dropped).",
                &[("outcome", "replayed")],
            ),
        }
    }
}

/// A merged query/snapshot window: the pooled sketch, how many epochs went
/// into it, and per-shard provenance.
pub struct WindowPool {
    pub pool: PooledSketch,
    /// Epochs merged (1 = just the open epoch; for window 0 this counts
    /// every epoch seen so far).
    pub epochs: u32,
    /// Per-shard row counts, in merge order.
    pub provenance: Vec<ShardRecord>,
}

/// One closed epoch's per-shard accumulators.
struct ClosedEpoch {
    index: u64,
    shards: BTreeMap<String, PooledSketch>,
}

/// Everything behind the state lock.
struct Inner {
    /// Index of the open epoch (0-based, incremented by each roll).
    epoch_index: u64,
    /// Open epoch: one accumulator per shard.
    current: BTreeMap<String, PooledSketch>,
    /// Closed epochs, oldest at the front, capped at `epoch_capacity`.
    closed: VecDeque<ClosedEpoch>,
    /// All-time accumulators — never evicted, the window-0 source.
    alltime: BTreeMap<String, PooledSketch>,
    /// Centroid cache: (key, report) in insertion order. Hit/miss
    /// counters live in [`ServerMetrics`], not here.
    cache: VecDeque<(u64, CentroidReport)>,
    /// Queries answered per canonical decoder spec (hits and misses) —
    /// the stats view of which decode algorithms this server is running.
    /// Bounded at [`MAX_DECODER_STATS`] distinct specs (clients choose the
    /// strings, and every other piece of server state is capacity-bounded:
    /// shards by [`ServiceConfig::max_shards`], epochs by the ring, the
    /// cache by its capacity); overflow tallies under
    /// [`DECODER_STATS_OVERFLOW`].
    decoder_uses: BTreeMap<String, u64>,
    /// Delta idempotency gate: per aggregator id, the `(instance,
    /// last admitted seq)` pair. A delta with the same instance and
    /// `seq <= last` is a recognized replay and is dropped without
    /// merging; a new instance (aggregator restart) replaces the record
    /// and restarts the sequence. Bounded alongside the shard maps —
    /// an aggregator id is only admitted here after it passed the
    /// shard-label cap check (I-13, I-21).
    deltas: BTreeMap<String, (u64, u64)>,
}

/// Distinct decoder specs tracked in stats before new ones collapse into
/// the overflow bucket — plenty for real deployments (the registry has a
/// handful of algorithms), tiny enough that an unauthenticated client
/// spamming distinct-but-valid specs cannot grow server memory.
const MAX_DECODER_STATS: usize = 32;
/// The catch-all stats bucket once [`MAX_DECODER_STATS`] is reached.
const DECODER_STATS_OVERFLOW: &str = "(other)";

/// The shared, thread-safe server state. Cheap operations (merging a
/// pre-encoded batch, cache lookups, stats) run under one mutex; the
/// expensive ones (encoding a push batch, running CL-OMPR) run outside it,
/// so concurrent connections only serialize on vector adds.
pub struct SketchService {
    op: SketchOperator,
    meta: SketchMeta,
    cfg: ServiceConfig,
    metrics: ServerMetrics,
    inner: Mutex<Inner>,
    /// Finished request traces, ring-bounded at
    /// [`ServiceConfig::trace_capacity`].
    traces: TraceStore,
    /// Registry-clock reading at construction — the uptime anchor.
    start_ns: u64,
    /// `qckm_auth_failures_total{tenant}` — registered only when this
    /// tenant requires a token, so open servers keep their historical
    /// exposition pages byte-identical.
    auth_failures: Option<Arc<Counter>>,
}

impl SketchService {
    /// `meta` must describe `op` (same fingerprint) — build both via
    /// [`crate::stream::draw_operator`] + [`SketchMeta::for_operator`], or
    /// from a `.qsk` header via [`SketchMeta::rebuild_operator`].
    pub fn new(op: SketchOperator, meta: SketchMeta, cfg: ServiceConfig) -> Self {
        assert_eq!(
            meta.config_hash,
            crate::stream::operator_fingerprint(&op),
            "meta does not describe the operator"
        );
        let metrics = ServerMetrics::new(&cfg.registry, &cfg.tenant);
        let auth_failures = cfg.token.as_ref().map(|_| {
            cfg.registry.counter(
                "qckm_auth_failures_total",
                "Scoped requests refused for a bad or missing token, by tenant.",
                &[("tenant", &cfg.tenant)],
            )
        });
        // `qckm_build_info`: the constant-1 series whose label carries the
        // build's version — the standard Prometheus idiom for joining any
        // other series to a version.
        cfg.registry
            .gauge(
                "qckm_build_info",
                "Constant 1; the version label identifies this build.",
                &[("version", env!("CARGO_PKG_VERSION"))],
            )
            .set(1.0);
        let traces = TraceStore::new(cfg.trace_capacity);
        let start_ns = cfg.registry.now_ns();
        Self {
            op,
            meta,
            cfg,
            metrics,
            inner: Mutex::new(Inner {
                epoch_index: 0,
                current: BTreeMap::new(),
                closed: VecDeque::new(),
                alltime: BTreeMap::new(),
                cache: VecDeque::new(),
                decoder_uses: BTreeMap::new(),
                deltas: BTreeMap::new(),
            }),
            traces,
            start_ns,
            auth_failures,
        }
    }

    /// This service's tenant name (empty = the unnamed default tenant).
    pub fn tenant(&self) -> &str {
        &self.cfg.tenant
    }

    /// Authorize one scoped request against this tenant: the scope's
    /// tenant name must be this tenant (or empty — routing already
    /// happened), and when a token is configured the presented one must
    /// match in constant time (no early-exit byte compare, so response
    /// timing leaks nothing about how much of a guess was right).
    /// Failures count under `qckm_auth_failures_total{tenant}`.
    pub fn authorize(&self, scope: &super::proto::Scope) -> Result<()> {
        if !scope.tenant.is_empty() && scope.tenant != self.cfg.tenant {
            bail!("unknown tenant '{}'", scope.tenant);
        }
        if let Some(expected) = &self.cfg.token {
            if !super::tenants::constant_time_eq(expected.as_bytes(), scope.token.as_bytes()) {
                if let Some(c) = &self.auth_failures {
                    c.inc();
                }
                let shown = if self.cfg.tenant.is_empty() { "(default)" } else { &self.cfg.tenant };
                bail!("auth failed for tenant '{shown}' (bad or missing token)");
            }
        }
        Ok(())
    }

    /// Count one request of `verb` and start its latency span (drop the
    /// span when the response is ready). Used by the connection handler.
    pub(crate) fn request_span(&self, verb: &'static str) -> Span {
        let (_, count, seconds) = self
            .metrics
            .verbs
            .iter()
            .find(|(v, _, _)| *v == verb)
            .expect("unknown protocol verb");
        count.inc();
        self.cfg.registry.span(verb, seconds)
    }

    /// Render this service's metrics registry as a Prometheus text page —
    /// the body of the `ctl metrics` response. Scrape-time gauges
    /// (uptime, occupancy) are refreshed first, so the page always
    /// reflects the state at the moment of the scrape. The state lock is
    /// released before rendering (which takes the registry lock), keeping
    /// the lock order state → registry everywhere.
    pub fn render_metrics(&self) -> String {
        self.refresh_gauges();
        self.cfg.registry.render()
    }

    /// Refresh this service's scrape-time gauges (uptime, occupancy)
    /// without rendering. A multi-tenant node calls this on every tenant
    /// before rendering their shared registry once.
    pub fn refresh_gauges(&self) {
        let (shards, epochs_held) = {
            let inner = self.locked();
            (inner.alltime.len(), inner.closed.len())
        };
        self.metrics.shards_gauge.set(shards as f64);
        self.metrics.epoch_ring_gauge.set(epochs_held as f64);
        let now = self.cfg.registry.now_ns();
        self.metrics
            .uptime_seconds
            .set(now.saturating_sub(self.start_ns) as f64 * 1e-9);
    }

    /// This tenant's occupancy snapshot: (all-time rows, shard slots
    /// used) — the per-tenant row of the v6 stats report.
    pub fn occupancy(&self) -> (u64, u64) {
        let inner = self.locked();
        (
            inner.alltime.values().map(|p| p.count()).sum(),
            inner.alltime.len() as u64,
        )
    }

    /// The registry's clock — the time source for request trace trees,
    /// shared with every histogram span so the two never disagree.
    pub(crate) fn registry_clock(&self) -> Arc<dyn Clock> {
        self.cfg.registry.clock()
    }

    /// The metrics registry this service registers into. A multi-tenant
    /// node shares one registry across every tenant (label sets differ by
    /// `tenant`), renders it once per scrape, and drives its rate-limit
    /// bucket off the same clock.
    pub(crate) fn registry(&self) -> &Arc<Registry> {
        &self.cfg.registry
    }

    /// Store one finished request trace in the ring.
    pub(crate) fn record_trace(&self, rec: TraceRecord) {
        self.traces.push(rec);
    }

    /// Answer the trace verb: `{"traces":[…]}`, newest first — either
    /// the one trace with `id`, or the newest `limit` (0 = default).
    pub fn traces_json(&self, id: Option<[u8; 16]>, limit: u32) -> Result<String> {
        let records = match id {
            Some(id) => match self.traces.find(&id) {
                Some(rec) => vec![rec],
                None => bail!(
                    "trace {} not found (the ring keeps the newest {}; was the request sent with --trace?)",
                    crate::obs::trace::hex(&id),
                    self.traces.capacity()
                ),
            },
            None => {
                let limit = if limit == 0 { DEFAULT_TRACE_LIMIT } else { limit as usize };
                self.traces.recent(limit)
            }
        };
        Ok(crate::obs::trace::traces_to_json(&records))
    }

    /// Acquire the state lock, recovering from poisoning. A panic while
    /// the lock is held poisons the mutex, and propagating that poison
    /// would turn one bad request into a permanent denial of service:
    /// every later connection thread's `.unwrap()` panics too. Recovery is
    /// sound here because every lock-held mutation is merge-atomic — the
    /// only compound write is [`PooledSketch::merge`], which validates
    /// slot lengths *before* touching the accumulator, so a panic under
    /// the lock leaves `Inner` in the last consistent state rather than
    /// half-written.
    fn locked(&self) -> MutexGuard<'_, Inner> {
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Poison the state mutex by panicking while holding it — simulates a
    /// request thread dying mid-critical-section so tests can prove the
    /// service keeps answering afterwards.
    #[cfg(test)]
    pub(crate) fn poison_for_test(&self) {
        let _ = std::thread::scope(|s| {
            s.spawn(|| {
                let _guard = self.inner.lock().unwrap();
                panic!("injected panic while holding the state lock");
            })
            .join()
        });
        assert!(self.inner.is_poisoned(), "test hook failed to poison the lock");
    }

    /// The operator this service sketches with.
    pub fn operator(&self) -> &SketchOperator {
        &self.op
    }

    /// The operator's `.qsk` header description.
    pub fn meta(&self) -> &SketchMeta {
        &self.meta
    }

    /// Refresh shard `label`'s health gauges from its all-time
    /// accumulator: `qckm_shard_rows{shard}` and
    /// `qckm_shard_bit_balance{shard}` — the mean pooled slot value. For
    /// the ±1 quantized signature this is the paper's checkable
    /// fingerprint (PAPER.md §II): under proper dithering the pooled
    /// sums stay balanced near 0, so a drifting or mis-dithered pusher
    /// shows up as a walking balance long before clustering degrades.
    /// Label cardinality is bounded by [`ServiceConfig::max_shards`],
    /// the same cap that bounds the accumulator maps. Values are
    /// computed under the state lock by the caller; the gauge writes
    /// (which take the registry lock) happen after it is released.
    fn set_shard_health(&self, label: &str, rows: u64, balance: f64) {
        let reg = &self.cfg.registry;
        let mut labels = vec![("shard", label)];
        if !self.cfg.tenant.is_empty() {
            labels.push(("tenant", &self.cfg.tenant));
        }
        reg.gauge(
            "qckm_shard_rows",
            "All-time rows pooled per shard.",
            &labels,
        )
        .set(rows as f64);
        reg.gauge(
            "qckm_shard_bit_balance",
            "Mean pooled slot value per shard (near 0 under proper dithering for quantized methods).",
            &labels,
        )
        .set(balance);
    }

    /// Verify a client-declared method spec against this server's
    /// operator. Empty means the client declared nothing (legacy behavior:
    /// no check); anything else must parse and canonicalize to the
    /// server's method, so push/query/snapshot can never silently mix
    /// methods across a distributed job.
    pub fn check_method(&self, declared: &str) -> Result<()> {
        if declared.is_empty() {
            return Ok(());
        }
        let spec = crate::method::MethodSpec::parse(declared)?;
        if spec.canonical() != self.meta.method {
            bail!(
                "method mismatch: request declares '{}' but this server sketches with '{}'",
                spec.canonical(),
                self.meta.method
            );
        }
        Ok(())
    }

    /// Install a pre-existing pooled sketch (e.g. a snapshot from a
    /// previous run) as shard `label`'s *all-time* history. Seed data
    /// predates every epoch, so it participates in window-0 queries and
    /// snapshots but not in windowed ones.
    pub fn seed_with(&self, label: &str, pool: PooledSketch) -> Result<()> {
        if pool.len() != self.op.sketch_len() {
            bail!(
                "seed sketch has {} slots, operator needs {}",
                pool.len(),
                self.op.sketch_len()
            );
        }
        let mut inner = self.locked();
        if !inner.alltime.contains_key(label) && inner.alltime.len() >= self.cfg.max_shards {
            bail!(
                "shard cap reached: {} labels already tracked (max_shards {})",
                inner.alltime.len(),
                self.cfg.max_shards
            );
        }
        let seeded = inner
            .alltime
            .entry(label.to_string())
            .or_insert_with(|| PooledSketch::new(pool.len()));
        seeded.merge(&pool);
        let (rows, balance) = (seeded.count(), pool_balance(seeded));
        drop(inner);
        self.set_shard_health(label, rows, balance);
        Ok(())
    }

    /// Ingest one row batch into `shard`. The encode runs on the calling
    /// (connection) thread *outside* the state lock via the fixed-chunk
    /// parallel fold; only the two accumulator merges hold the lock.
    /// Returns the shard's all-time row count and the server's total.
    pub fn ingest(&self, shard: &str, batch: &Mat) -> Result<(u64, u64)> {
        if shard.is_empty() || shard.len() > MAX_SHARD_BYTES {
            bail!("invalid shard label ({} bytes)", shard.len());
        }
        if batch.cols() != self.op.dim() {
            bail!(
                "push batch dimension {} does not match the operator dimension {}",
                batch.cols(),
                self.op.dim()
            );
        }
        let mut partial = PooledSketch::new(self.op.sketch_len());
        if batch.rows() > 0 {
            let _span = self
                .cfg
                .registry
                .span("ingest_encode", &self.metrics.encode_seconds);
            self.op.sketch_into_par(batch, &mut partial, &self.cfg.threads);
        }
        let mut inner = self.locked();
        if !inner.alltime.contains_key(shard) && inner.alltime.len() >= self.cfg.max_shards {
            // `alltime` holds every label ever accepted (it never evicts),
            // so it is the superset to cap on. Known labels always pass —
            // only *new* ones are refused, and the refusal travels as an
            // application error the retrying client fails fast on.
            bail!(
                "shard cap reached: {} labels already tracked (max_shards {}); \
                 push to an existing shard or raise --max-shards",
                inner.alltime.len(),
                self.cfg.max_shards
            );
        }
        let len = self.op.sketch_len();
        inner
            .current
            .entry(shard.to_string())
            .or_insert_with(|| PooledSketch::new(len))
            .merge(&partial);
        let shard_pool = inner
            .alltime
            .entry(shard.to_string())
            .or_insert_with(|| PooledSketch::new(len));
        shard_pool.merge(&partial);
        let shard_rows = shard_pool.count();
        let balance = pool_balance(shard_pool);
        let total_rows = inner.alltime.values().map(|p| p.count()).sum();
        drop(inner);
        // Counted after the cap check: these are *accepted* rows/bytes.
        self.metrics.push_rows.add(batch.rows() as u64);
        self.metrics
            .push_bytes
            .add((batch.rows() * batch.cols() * 8) as u64);
        self.set_shard_health(shard, shard_rows, balance);
        Ok((shard_rows, total_rows))
    }

    /// Merge an aggregator's pre-pooled `.qsk` delta under the shard
    /// label `agg_id`, guarded by the idempotency gate: within one
    /// aggregator `instance`, only a `seq` strictly greater than the
    /// last admitted one merges — an at-least-once flush link may replay
    /// a delta (ack lost, connection resent) without double-counting
    /// (INVARIANTS.md I-21). A new instance (aggregator restart) resets
    /// the sequence; a restarted aggregator starts from empty local
    /// accumulators, so its fresh stream is genuinely new data.
    ///
    /// Returns `(merged, total_rows)`: `merged == false` means the delta
    /// was a recognized replay and was dropped, which the aggregator
    /// treats as success.
    pub fn ingest_delta(
        &self,
        agg_id: &str,
        instance: u64,
        seq: u64,
        sketch: &[u8],
    ) -> Result<(bool, u64)> {
        if agg_id.is_empty() || agg_id.len() > MAX_SHARD_BYTES {
            bail!("invalid aggregator id ({} bytes)", agg_id.len());
        }
        // Parse and verify outside the lock: the payload is a complete
        // `.qsk` stream (checksummed, fingerprinted), so a corrupt or
        // cross-operator delta is refused before any state is touched.
        let (meta, partial, _prov) =
            crate::stream::read_sketch_from(&mut &sketch[..], "delta")?;
        self.meta.ensure_mergeable(&meta)?;
        let rows = partial.count();
        let mut inner = self.locked();
        if let Some(&(inst, last)) = inner.deltas.get(agg_id) {
            if inst == instance && seq <= last {
                let total_rows = inner.alltime.values().map(|p| p.count()).sum();
                drop(inner);
                self.metrics.delta_replayed.inc();
                return Ok((false, total_rows));
            }
        }
        if !inner.alltime.contains_key(agg_id) && inner.alltime.len() >= self.cfg.max_shards {
            bail!(
                "shard cap reached: {} labels already tracked (max_shards {}); \
                 cannot admit aggregator '{agg_id}'",
                inner.alltime.len(),
                self.cfg.max_shards
            );
        }
        let len = self.op.sketch_len();
        inner
            .current
            .entry(agg_id.to_string())
            .or_insert_with(|| PooledSketch::new(len))
            .merge(&partial);
        let shard_pool = inner
            .alltime
            .entry(agg_id.to_string())
            .or_insert_with(|| PooledSketch::new(len));
        shard_pool.merge(&partial);
        let shard_rows = shard_pool.count();
        let balance = pool_balance(shard_pool);
        inner.deltas.insert(agg_id.to_string(), (instance, seq));
        let total_rows = inner.alltime.values().map(|p| p.count()).sum();
        drop(inner);
        self.metrics.delta_merged.inc();
        self.metrics.push_rows.add(rows);
        self.set_shard_health(agg_id, shard_rows, balance);
        Ok((true, total_rows))
    }

    /// Close the open epoch into the ring (evicting the oldest beyond
    /// capacity) and open the next. Returns the new open epoch's index and
    /// the rows that were in the closed one.
    pub fn roll_epoch(&self) -> (u64, u64) {
        let mut inner = self.locked();
        let shards = std::mem::take(&mut inner.current);
        let rows_closed = shards.values().map(|p| p.count()).sum();
        let index = inner.epoch_index;
        inner.closed.push_back(ClosedEpoch { index, shards });
        while inner.closed.len() > self.cfg.epoch_capacity {
            inner.closed.pop_front();
        }
        inner.epoch_index += 1;
        (inner.epoch_index, rows_closed)
    }

    /// Merge a window into one pool, in the stable order: epochs
    /// chronologically, shards in key order within each epoch (window 0:
    /// the all-time shard accumulators in key order).
    pub fn merge_window(&self, window: u32) -> WindowPool {
        let _span = self
            .cfg
            .registry
            .span("window_merge", &self.metrics.window_merge_seconds);
        let inner = self.locked();
        let mut pool = PooledSketch::new(self.op.sketch_len());
        let mut provenance = Vec::new();
        if window == 0 {
            for (label, shard) in &inner.alltime {
                pool.merge(shard);
                provenance.push(ShardRecord {
                    label: label.clone(),
                    rows: shard.count(),
                });
            }
            let epochs = inner.epoch_index + 1;
            return WindowPool {
                pool,
                epochs: epochs.min(u32::MAX as u64) as u32,
                provenance,
            };
        }
        let closed_take = (window as usize - 1).min(inner.closed.len());
        let skip = inner.closed.len() - closed_take;
        for epoch in inner.closed.iter().skip(skip) {
            for (label, shard) in &epoch.shards {
                pool.merge(shard);
                provenance.push(ShardRecord {
                    label: format!("e{}/{label}", epoch.index),
                    rows: shard.count(),
                });
            }
        }
        for (label, shard) in &inner.current {
            pool.merge(shard);
            provenance.push(ShardRecord {
                label: format!("e{}/{label}", inner.epoch_index),
                rows: shard.count(),
            });
        }
        WindowPool {
            pool,
            epochs: closed_take as u32 + 1,
            provenance,
        }
    }

    /// Answer a decode query, consulting the centroid cache first. The
    /// decode itself runs outside the state lock.
    pub fn query(&self, spec: &QuerySpec) -> Result<CentroidReport> {
        if spec.k == 0 {
            bail!("query: need k >= 1");
        }
        if spec.k as usize > 4096 {
            bail!("query: implausible k {}", spec.k);
        }
        if !(spec.lo <= spec.hi) {
            bail!("query: lo {} must not exceed hi {}", spec.lo, spec.hi);
        }
        // Resolve the declared decoder through the registry (empty = the
        // tenant's configured default, falling back to the registry
        // default `clompr`); junk specs error here with the valid-decoder
        // list. The *canonical* spec goes into the cache key, so aliases
        // share entries and different algorithms never do.
        let declared = if spec.decoder.is_empty() {
            self.cfg.default_decoder.as_str()
        } else {
            spec.decoder.as_str()
        };
        let decoder = if declared.is_empty() {
            DecoderSpec::default()
        } else {
            DecoderSpec::parse(declared)?
        };
        let window = self.merge_window(spec.window);
        if window.pool.count() == 0 {
            bail!(
                "query: window {} pools zero rows (nothing pushed yet?)",
                spec.window
            );
        }
        let replicates = spec.replicates.max(1);
        let seed = spec.seed.unwrap_or(self.meta.seed);
        let key = cache_key(&window.pool, spec, replicates, seed, decoder.canonical());

        {
            let mut inner = self.locked();
            let stats_key = if inner.decoder_uses.contains_key(decoder.canonical())
                || inner.decoder_uses.len() < MAX_DECODER_STATS
            {
                decoder.canonical()
            } else {
                DECODER_STATS_OVERFLOW
            };
            *inner.decoder_uses.entry(stats_key.to_string()).or_insert(0) += 1;
            if let Some((_, report)) = inner.cache.iter().find(|(k, _)| *k == key) {
                let mut hit = report.clone();
                hit.cached = true;
                // The key covers the pooled bits, not the window spec: two
                // windows with bit-identical pools share an entry, so the
                // epoch bookkeeping must come from THIS merge, not the
                // cached one.
                hit.epochs = window.epochs;
                self.metrics.cache_hits.inc();
                return Ok(hit);
            }
            self.metrics.cache_misses.inc();
        }

        let dim = self.op.dim();
        let z = window.pool.mean();
        let sol = decoder.decode_best_of(
            &self.op,
            spec.k as usize,
            &z,
            vec![spec.lo; dim],
            vec![spec.hi; dim],
            &self.cfg.decode,
            replicates as usize,
            &mut Rng::new(seed),
        );
        // Decode-quality instruments (I-18: reads of the finished
        // solution, nothing fed back): the final residual `‖z − A(P)‖`
        // is the objective itself, effort/churn come from the winning
        // replicate's iteration counters.
        self.metrics.residual_norm.observe(sol.objective);
        self.metrics.outer_iters.add(sol.outer_iters as u64);
        self.metrics.atoms_replaced.add(sol.atoms_replaced as u64);
        let report = CentroidReport {
            centroids: sol.centroids.as_slice().to_vec(),
            k: spec.k,
            dim: dim as u32,
            weights: sol.weights,
            objective: sol.objective,
            rows: window.pool.count(),
            epochs: window.epochs,
            cached: false,
        };
        let mut inner = self.locked();
        if !inner.cache.iter().any(|(k, _)| *k == key) {
            inner.cache.push_back((key, report.clone()));
            while inner.cache.len() > self.cfg.cache_capacity {
                inner.cache.pop_front();
            }
        }
        Ok(report)
    }

    /// Serialize a window as `.qsk` bytes — the file `save_sketch` would
    /// write, with per-shard provenance records, loadable by the offline
    /// `qckm merge` / `qckm decode` stages.
    pub fn snapshot(&self, window: u32) -> Result<Vec<u8>> {
        let win = self.merge_window(window);
        if win.pool.count() == 0 {
            // An empty pool has no mean sketch; a count=0 `.qsk` file is
            // undecodable and `write_sketch_to` refuses to produce one.
            // Surface the real condition instead.
            bail!(
                "snapshot: window {window} pools zero rows (nothing pushed yet?)"
            );
        }
        let mut bytes = Vec::new();
        write_sketch_to(&mut bytes, &self.meta, &win.pool, &win.provenance)?;
        Ok(bytes)
    }

    /// Current counters.
    pub fn stats(&self) -> StatsReport {
        let inner = self.locked();
        StatsReport {
            method: self.meta.method.clone(),
            epoch: inner.epoch_index,
            rows_total: inner.alltime.values().map(|p| p.count()).sum(),
            epochs_held: inner.closed.len() as u32,
            max_shards: self.cfg.max_shards as u64,
            cache_hits: self.metrics.cache_hits.get(),
            cache_misses: self.metrics.cache_misses.get(),
            shards: inner
                .alltime
                .iter()
                .map(|(label, p)| (label.clone(), p.count()))
                .collect(),
            decoders: inner
                .decoder_uses
                .iter()
                .map(|(spec, n)| (spec.clone(), *n))
                .collect(),
            tenant: self.cfg.tenant.clone(),
            // A single service only knows itself; the multi-tenant node
            // fills this with every tenant's occupancy.
            tenants: Vec::new(),
        }
    }
}

/// Mean pooled slot value — the bit-balance health signal (0 when the
/// pool is empty). See [`SketchService::set_shard_health`].
fn pool_balance(pool: &PooledSketch) -> f64 {
    let rows = pool.count();
    if rows == 0 || pool.len() == 0 {
        return 0.0;
    }
    pool.sum().iter().sum::<f64>() / (pool.len() as f64 * rows as f64)
}

/// Cache key: FNV over the merged window's exact pooled bits, every
/// decode-relevant query field, and the canonical decoder spec. Equal keys
/// ⇒ identical mean sketch and decoder configuration *and algorithm* ⇒
/// bit-identical decode, so hits are always sound — in particular a query
/// with a different `--decoder` on an unchanged window is a miss.
fn cache_key(
    pool: &PooledSketch,
    spec: &QuerySpec,
    replicates: u32,
    seed: u64,
    decoder: &str,
) -> u64 {
    let mut h = crate::stream::Fnv1a::new();
    h.write_u64(pool_fingerprint(pool));
    h.write_u64(spec.k as u64);
    h.write_u64(replicates as u64);
    h.write_u64(seed);
    h.write_u64(spec.lo.to_bits());
    h.write_u64(spec.hi.to_bits());
    h.write_bytes(decoder.as_bytes());
    h.finish()
}
