//! The TCP accept loop and per-connection handlers.
//!
//! One OS thread per connection (connections are few and long-lived: shard
//! pushers and query clients), with every blocking read bounded by a short
//! timeout so handlers poll the shutdown flag instead of parking forever —
//! a CI smoke run can always terminate the server, and a wedged client
//! cannot pin a handler past shutdown.
//!
//! Shutdown is cooperative: the handler that receives a shutdown request
//! acks it, raises the flag, and dials the listener once to wake the
//! accept loop; the loop then stops accepting and joins every handler.

use super::proto::{self, Request, Response};
use super::state::SketchService;
use crate::linalg::Mat;
use crate::obs::log::{self, Level, Value};
use crate::obs::trace::{self, TraceContext, TraceRecorder};
use anyhow::{bail, Context, Result};
use std::io::Read;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// How often a blocked handler read re-checks the shutdown flag.
const POLL_INTERVAL: Duration = Duration::from_millis(200);

/// Per-connection state a [`FrameHandler`] threads through a connection's
/// lifetime. Today that is the ingest rate bucket: rate limits are per
/// connection (reconnecting resets the bucket), so the bucket lives here
/// rather than on the handler.
pub(crate) struct ConnCtx {
    pub(crate) bucket: Option<super::tenants::TokenBucket>,
}

/// What the accept loop serves: anything that can turn one request
/// payload into a reply frame. The single- and multi-tenant servers
/// ([`super::tenants::Node`]) and the fan-in aggregator
/// (`crate::fanin::AggregatorNode`) all plug in here, sharing the
/// accept/read/shutdown machinery.
pub(crate) trait FrameHandler: Send + Sync {
    /// Called once per accepted connection.
    fn new_conn(&self) -> ConnCtx;
    /// Handle one length-prefixed payload.
    fn handle(&self, conn: &mut ConnCtx, payload: &[u8]) -> Handled;
    /// Called after the accept loop has stopped and every connection
    /// handler has been joined — the drain hook (aggregators flush
    /// pending deltas upstream here).
    fn drained(&self) {}
}

/// Run the service on an already-bound listener until a shutdown request
/// arrives. Returns the number of connections served.
pub fn serve(listener: TcpListener, service: Arc<SketchService>) -> Result<u64> {
    serve_handler(listener, Arc::new(super::tenants::Node::single(service)))
}

/// Run a multi-tenant node on an already-bound listener until a shutdown
/// request arrives. Returns the number of connections served.
pub fn serve_node(listener: TcpListener, node: Arc<super::tenants::Node>) -> Result<u64> {
    serve_handler(listener, node)
}

/// The generalized accept loop: one handler thread per connection, each
/// frame answered by `handler`, cooperative shutdown, drain hook after
/// the last connection is joined.
pub(crate) fn serve_handler<H: FrameHandler + 'static>(
    listener: TcpListener,
    handler: Arc<H>,
) -> Result<u64> {
    let addr = listener.local_addr().context("listener address")?;
    let stop = Arc::new(AtomicBool::new(false));
    let mut handlers = Vec::new();
    let mut served = 0u64;
    for conn in listener.incoming() {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        let stream = match conn {
            Ok(s) => s,
            Err(e) => {
                eprintln!("accept error: {e}");
                continue;
            }
        };
        served += 1;
        // Reap finished handlers so a long-lived server taking many
        // short-lived connections does not grow this Vec without bound.
        handlers.retain(|h| !h.is_finished());
        let handler = Arc::clone(&handler);
        let stop = Arc::clone(&stop);
        handlers.push(std::thread::spawn(move || {
            let peer = stream
                .peer_addr()
                .map(|a| a.to_string())
                .unwrap_or_else(|_| "?".into());
            if let Err(e) = handle_connection(stream, &*handler, &stop, addr) {
                eprintln!("connection {peer}: {e:#}");
            }
        }));
    }
    for h in handlers {
        let _ = h.join();
    }
    handler.drained();
    Ok(served)
}

/// Serve one connection until the peer hangs up or shutdown is flagged.
fn handle_connection<H: FrameHandler>(
    mut stream: TcpStream,
    handler: &H,
    stop: &AtomicBool,
    listen_addr: SocketAddr,
) -> Result<()> {
    stream
        .set_read_timeout(Some(POLL_INTERVAL))
        .context("set read timeout")?;
    // Bounded writes too: a peer that sends a query but never reads the
    // reply must error this handler out, not pin it past shutdown.
    stream
        .set_write_timeout(Some(Duration::from_secs(30)))
        .context("set write timeout")?;
    stream.set_nodelay(true).ok();
    let mut conn = handler.new_conn();
    loop {
        let payload = match read_frame_interruptible(&mut stream, stop)? {
            Some(p) => p,
            None => return Ok(()), // clean EOF or shutdown while idle
        };
        match handler.handle(&mut conn, &payload) {
            Handled::Reply(frame) => proto::write_frame(&mut stream, &frame)?,
            Handled::Shutdown(frame) => {
                proto::write_frame(&mut stream, &frame)?;
                stop.store(true, Ordering::SeqCst);
                // Wake the accept loop so it observes the flag. An
                // unspecified bind address (0.0.0.0) is not connectable on
                // every platform — dial loopback on the same port instead.
                let mut wake = listen_addr;
                if wake.ip().is_unspecified() {
                    wake.set_ip(std::net::IpAddr::V4(std::net::Ipv4Addr::LOCALHOST));
                }
                let _ = TcpStream::connect(wake);
                return Ok(());
            }
        }
    }
}

/// The outcome of one request payload: a reply frame to write, plus
/// whether the connection loop must raise the shutdown flag afterwards.
pub(crate) enum Handled {
    Reply(Vec<u8>),
    /// The encoded shutdown ack — write it, then stop the server.
    Shutdown(Vec<u8>),
}

/// Process one request payload end to end — decode (timed, so a traced
/// request's tree includes `frame_decode`), install the trace recorder
/// when the request carries a context, dispatch, store the finished
/// trace, and encode the reply *at the version the request arrived in*
/// so pre-v5 clients are served identically (I-19). Socket-free, so
/// tests drive the full path in-process.
pub(crate) fn handle_payload(service: &SketchService, payload: &[u8]) -> Handled {
    let clock = service.registry_clock();
    let t0 = clock.now_ns();
    let decoded = proto::decode_request_v(payload);
    let t1 = clock.now_ns();
    // Reply version: echo the request's. For an undecodable frame, trust
    // the leading version byte if it is one we speak (the error must be
    // readable by the sender), else answer at the current version.
    let version = match &decoded {
        Ok((v, _)) => *v,
        Err(_) => reply_version(payload),
    };
    let encode = |resp: &Response| -> Vec<u8> { encode_reply(resp, version) };
    match decoded {
        // Decode errors are protocol-level: report and keep the
        // connection (framing is intact — the bad frame was consumed).
        Err(e) => Handled::Reply(encode(&Response::Error(format!("{e:#}")))),
        Ok((_, Request::Shutdown)) => {
            let _span = service.request_span("shutdown");
            if log::enabled(Level::Info) {
                log::event(
                    Level::Info,
                    "request",
                    &[("verb", Value::Str("shutdown")), ("ok", Value::Bool(true))],
                );
            }
            Handled::Shutdown(encode(&Response::ShutdownAck))
        }
        Ok((_, req)) => {
            let result = match req.trace_context() {
                None => handle_request(service, req, None),
                Some(ctx) => {
                    let verb = req.verb();
                    let recorder = TraceRecorder::new(clock, ctx);
                    let result = {
                        let _active = trace::install(&recorder);
                        // Frame decode happened before the context it
                        // carries could be installed — backfill it as a
                        // root-level node from the measured interval.
                        recorder.record_closed("frame_decode", t0, t1);
                        handle_request(service, req, Some(&ctx))
                    };
                    service.record_trace(recorder.snapshot(verb, result.is_ok()));
                    result
                }
            };
            let resp = match result {
                Ok(resp) => resp,
                Err(e) => Response::Error(format!("{e:#}")),
            };
            Handled::Reply(encode(&resp))
        }
    }
}

/// Dispatch one request against the shared state, counting it and timing
/// it under its verb's metrics; with JSON logging on, one info-level
/// `request` event records the verb, outcome, and (when traced) the
/// trace id — the log ↔ trace join key.
fn handle_request(
    service: &SketchService,
    req: Request,
    ctx: Option<&TraceContext>,
) -> Result<Response> {
    let verb = req.verb();
    let _span = service.request_span(verb);
    let result = dispatch(service, req);
    if log::enabled(Level::Info) {
        let trace_hex = ctx.map(|c| c.trace_id_hex());
        let mut fields = vec![("verb", Value::Str(verb)), ("ok", Value::Bool(result.is_ok()))];
        if let Some(hex) = &trace_hex {
            fields.push(("trace", Value::Str(hex)));
        }
        log::event(Level::Info, "request", &fields);
    }
    result
}

fn dispatch(service: &SketchService, req: Request) -> Result<Response> {
    Ok(match req {
        Request::Push {
            scope,
            shard,
            method,
            dim,
            data,
            trace: _,
        } => {
            {
                let _t = trace::scoped("cap_check");
                service.authorize(&scope)?;
                service.check_method(&method)?;
            }
            let rows = data.len() / dim as usize;
            let batch = Mat::from_vec(rows, dim as usize, data);
            let (shard_rows, total_rows) = service.ingest(&shard, &batch)?;
            Response::PushAck {
                shard_rows,
                total_rows,
            }
        }
        Request::Query { scope, spec, method, trace: _ } => {
            {
                let _t = trace::scoped("cap_check");
                service.authorize(&scope)?;
                service.check_method(&method)?;
            }
            Response::Centroids(service.query(&spec)?)
        }
        Request::Snapshot { scope, window, method, trace: _ } => {
            {
                let _t = trace::scoped("cap_check");
                service.authorize(&scope)?;
                service.check_method(&method)?;
            }
            Response::Snapshot(service.snapshot(window)?)
        }
        Request::Delta {
            scope,
            agg_id,
            instance,
            seq,
            sketch,
            trace: _,
        } => {
            {
                let _t = trace::scoped("cap_check");
                service.authorize(&scope)?;
            }
            let (merged, rows_total) = service.ingest_delta(&agg_id, instance, seq, &sketch)?;
            Response::DeltaAck { merged, rows_total }
        }
        Request::Roll { scope } => {
            service.authorize(&scope)?;
            let (epoch, rows_closed) = service.roll_epoch();
            Response::RollAck { epoch, rows_closed }
        }
        Request::Stats { scope } => {
            service.authorize(&scope)?;
            Response::Stats(service.stats())
        }
        Request::Metrics => Response::Metrics(service.render_metrics()),
        Request::Trace { scope, id, limit } => {
            service.authorize(&scope)?;
            Response::Traces(service.traces_json(id, limit)?)
        }
        Request::Shutdown => unreachable!("handled by the connection loop"),
    })
}

/// The version an error or node-level reply to `payload` should be
/// encoded at: the frame's leading version byte when it is one we speak,
/// else the current version.
pub(crate) fn reply_version(payload: &[u8]) -> u8 {
    payload
        .first()
        .copied()
        .filter(|&v| proto::version_supported(v))
        .unwrap_or(proto::PROTO_VERSION)
}

/// Encode `resp` at `version`, degrading to a current-version error frame
/// when the content is unrepresentable at the peer's version (cannot
/// arise from a well-formed request of that version — send the reason).
pub(crate) fn encode_reply(resp: &Response, version: u8) -> Vec<u8> {
    proto::encode_response_v(resp, version)
        .unwrap_or_else(|e| proto::encode_response(&Response::Error(format!("{e:#}"))))
}

/// Read one frame, tolerating read timeouts between bytes so the shutdown
/// flag is observed. `Ok(None)` on clean EOF, or on shutdown while no
/// frame is in flight (a shutdown mid-frame abandons the connection —
/// it is ending anyway).
fn read_frame_interruptible(
    stream: &mut TcpStream,
    stop: &AtomicBool,
) -> Result<Option<Vec<u8>>> {
    let mut len_buf = [0u8; 4];
    if !read_full(stream, &mut len_buf, stop, true)? {
        return Ok(None);
    }
    let len = u32::from_le_bytes(len_buf) as usize;
    if len == 0 || len > proto::MAX_FRAME_BYTES {
        bail!("implausible frame length {len}");
    }
    let mut payload = vec![0u8; len];
    if !read_full(stream, &mut payload, stop, false)? {
        return Ok(None);
    }
    Ok(Some(payload))
}

/// Fill `buf`, polling `stop` on every timeout. Returns `false` on clean
/// EOF before the first byte (only if `eof_ok`) or on shutdown; errors on
/// EOF mid-buffer.
fn read_full(
    stream: &mut TcpStream,
    buf: &mut [u8],
    stop: &AtomicBool,
    eof_ok: bool,
) -> Result<bool> {
    let mut filled = 0;
    while filled < buf.len() {
        match stream.read(&mut buf[filled..]) {
            Ok(0) => {
                if filled == 0 && eof_ok {
                    return Ok(false);
                }
                bail!("connection closed mid-frame ({filled} of {} bytes)", buf.len());
            }
            Ok(n) => filled += n,
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                if stop.load(Ordering::SeqCst) {
                    return Ok(false);
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e).context("read frame"),
        }
    }
    Ok(true)
}
