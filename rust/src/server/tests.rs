//! Unit tests for the sketch service: protocol round-trips and defensive
//! decoding, shard/epoch/window state semantics, the centroid cache, the
//! snapshot ⇄ `.qsk` bridge, concurrent-ingest determinism, and one
//! in-process socket smoke (real `TcpListener`, no child processes —
//! `rust/tests/server_e2e.rs` drives the actual binary).

use super::proto::{self, CentroidReport, QuerySpec, Request, Response, StatsReport};
use super::state::{ServiceConfig, SketchService};
use crate::frequency::FrequencyLaw;
use crate::linalg::Mat;
use crate::method::MethodSpec;
use crate::rng::Rng;
use crate::sketch::PooledSketch;
use crate::stream::{draw_operator, read_sketch_from, SketchMeta};
use std::sync::Arc;

const DIM: usize = 4;
const M: usize = 24;
const SIGMA: f64 = 1.1;
const SEED: u64 = 5;

fn service(cfg: ServiceConfig) -> SketchService {
    let qckm = MethodSpec::parse("qckm").unwrap();
    let op = draw_operator(&qckm, FrequencyLaw::AdaptedRadius, M, DIM, SIGMA, SEED);
    let meta = SketchMeta::for_operator(&op, &qckm, SEED);
    SketchService::new(op, meta, cfg)
}

fn random_mat(rows: usize, cols: usize, seed: u64) -> Mat {
    let mut rng = Rng::new(seed);
    Mat::from_fn(rows, cols, |_, _| rng.gaussian())
}

fn spec(k: u32, window: u32) -> QuerySpec {
    QuerySpec {
        k,
        window,
        replicates: 1,
        seed: None,
        lo: -2.0,
        hi: 2.0,
        decoder: String::new(),
    }
}

// ------------------------------------------------------------------- proto

#[test]
fn proto_round_trips_every_request_variant() {
    let requests = [
        Request::Push {
            shard: "sensor-7".into(),
            method: "qckm:bits=2".into(),
            dim: 3,
            data: vec![1.5, -2.25, 0.0, 4.0, 5.0, -6.0],
        },
        Request::Push {
            shard: "sensor-8".into(),
            method: String::new(),
            dim: 2,
            data: vec![1.0, 2.0],
        },
        Request::Query {
            spec: QuerySpec {
                k: 4,
                window: 2,
                replicates: 3,
                seed: Some(99),
                lo: -1.5,
                hi: 1.5,
                decoder: "clompr:restarts=5".into(),
            },
            method: "modulo".into(),
        },
        Request::Query {
            spec: spec(1, 0),
            method: String::new(),
        },
        Request::Snapshot {
            window: 7,
            method: "qckm".into(),
        },
        Request::Roll,
        Request::Stats,
        Request::Metrics,
        Request::Shutdown,
    ];
    for req in &requests {
        let bytes = proto::encode_request(req);
        assert_eq!(&proto::decode_request(&bytes).unwrap(), req, "{req:?}");
    }
}

#[test]
fn proto_round_trips_every_response_variant() {
    let responses = [
        Response::Error("bad things".into()),
        Response::PushAck {
            shard_rows: 10,
            total_rows: 30,
        },
        Response::Centroids(CentroidReport {
            centroids: vec![0.5, -0.5, 1.0, -1.0],
            k: 2,
            dim: 2,
            weights: vec![0.25, 0.75],
            objective: 0.125,
            rows: 1000,
            epochs: 3,
            cached: true,
        }),
        Response::Snapshot(vec![1, 2, 3, 4, 5]),
        Response::RollAck {
            epoch: 4,
            rows_closed: 512,
        },
        Response::Stats(StatsReport {
            method: "qckm:bits=3".into(),
            epoch: 2,
            rows_total: 77,
            epochs_held: 2,
            max_shards: 1024,
            cache_hits: 5,
            cache_misses: 6,
            shards: vec![("a".into(), 40), ("b".into(), 37)],
            decoders: vec![("clompr".into(), 9), ("hier".into(), 2)],
        }),
        Response::Metrics("# HELP qckm_requests_total req\n".into()),
        Response::ShutdownAck,
    ];
    for resp in &responses {
        let bytes = proto::encode_response(resp);
        assert_eq!(&proto::decode_response(&bytes).unwrap(), resp, "{resp:?}");
    }
}

#[test]
fn proto_rejects_malformed_payloads() {
    // Wrong protocol version.
    let mut bytes = proto::encode_request(&Request::Roll);
    bytes[0] = 99;
    assert!(proto::decode_request(&bytes).is_err());

    // Unknown tag.
    let mut bytes = proto::encode_request(&Request::Roll);
    bytes[1] = 200;
    assert!(proto::decode_request(&bytes).is_err());

    // Truncated body.
    let bytes = proto::encode_request(&Request::Query {
        spec: spec(2, 0),
        method: String::new(),
    });
    assert!(proto::decode_request(&bytes[..bytes.len() - 1]).is_err());

    // Trailing garbage.
    let mut bytes = proto::encode_request(&Request::Stats);
    bytes.push(0);
    assert!(proto::decode_request(&bytes).is_err());

    // Push payload not a whole number of rows.
    let mut ok = proto::encode_request(&Request::Push {
        shard: "s".into(),
        method: String::new(),
        dim: 3,
        data: vec![0.0; 6],
    });
    // dim lives after the 1-byte version, 1-byte tag, 4+1 byte shard
    // label, and 4+0 byte method spec.
    ok[11] = 4; // now 6 values over dim 4
    assert!(proto::decode_request(&ok).is_err());

    // Oversized frame length on the wire.
    let mut wire = Vec::new();
    wire.extend_from_slice(&(u32::MAX).to_le_bytes());
    wire.extend_from_slice(&[0u8; 16]);
    assert!(proto::read_frame(&mut &wire[..]).is_err());

    // Clean EOF is None, mid-length EOF is an error.
    assert!(proto::read_frame(&mut &[][..]).unwrap().is_none());
    assert!(proto::read_frame(&mut &[1u8, 0][..]).is_err());
}

/// Regression: `decode_request` used to accept `len == 0` pushes, which
/// created empty shard accumulators and zero-row provenance records for
/// nothing. Empty batches are refused at the protocol boundary.
#[test]
fn proto_rejects_zero_row_pushes() {
    let bytes = proto::encode_request(&Request::Push {
        shard: "s".into(),
        method: String::new(),
        dim: 3,
        data: vec![],
    });
    let err = format!("{:#}", proto::decode_request(&bytes).unwrap_err());
    assert!(err.contains("empty batch"), "{err}");
}

/// Regression: `encode_response` used to write error strings unbounded
/// while `decode_response` caps them at [`proto::MAX_ERROR_BYTES`], so a
/// long server error surfaced client-side as "implausible string field"
/// instead of the message. The encoder now truncates on a char boundary
/// with a marker.
#[test]
fn error_responses_truncate_to_the_decode_cap() {
    // Way past the cap, with multi-byte chars ('é' is 2 bytes in UTF-8) so
    // a byte-offset cut would land mid-char and panic the slicer.
    let long = "é".repeat(proto::MAX_ERROR_BYTES);
    let bytes = proto::encode_response(&Response::Error(long));
    let Response::Error(msg) = proto::decode_response(&bytes).unwrap() else {
        panic!("expected an error response");
    };
    assert!(msg.len() <= proto::MAX_ERROR_BYTES);
    assert!(msg.ends_with("[truncated]"), "missing truncation marker");
    assert!(msg.starts_with("éé"), "prefix must survive");

    // At or under the cap nothing changes.
    let short = "x".repeat(proto::MAX_ERROR_BYTES);
    let bytes = proto::encode_response(&Response::Error(short.clone()));
    assert_eq!(proto::decode_response(&bytes).unwrap(), Response::Error(short));
}

/// Metrics pages get the same both-side truncation treatment as error
/// strings: the encoder cuts to [`proto::MAX_METRICS_BYTES`] on a char
/// boundary with a marker, so any decoded page re-encodes identically
/// (the canonicalization fixed-point the fuzz suite relies on).
#[test]
fn metrics_responses_truncate_to_the_decode_cap() {
    let long = "x".repeat(proto::MAX_METRICS_BYTES + 100);
    let bytes = proto::encode_response(&Response::Metrics(long));
    let Response::Metrics(page) = proto::decode_response(&bytes).unwrap() else {
        panic!("expected a metrics response");
    };
    assert!(page.len() <= proto::MAX_METRICS_BYTES);
    assert!(page.ends_with("[truncated]"), "missing truncation marker");

    let short = "# HELP a b\n".to_string();
    let bytes = proto::encode_response(&Response::Metrics(short.clone()));
    assert_eq!(proto::decode_response(&bytes).unwrap(), Response::Metrics(short));
}

/// The service's exposition page is valid Prometheus text and covers the
/// server families even before their stages have run (registration is
/// eager, so a scrape lists the whole catalog at zero).
#[test]
fn metrics_page_covers_server_families_and_validates() {
    let svc = service(ServiceConfig::default());
    let mut rng = Rng::new(21);
    let data = crate::data::gaussian_mixture_pm1(400, DIM, 2, &mut rng);
    svc.ingest("s", &data.points).unwrap();
    let _ = svc.query(&spec(2, 0)).unwrap(); // miss → decode
    let _ = svc.query(&spec(2, 0)).unwrap(); // hit
    let page = svc.render_metrics();
    crate::obs::prom::validate(&page).unwrap_or_else(|e| panic!("{e:#}\n{page}"));
    for needle in [
        "qckm_requests_total{verb=\"push\"} 0", // direct state calls skip request spans
        "qckm_requests_total{verb=\"metrics\"} 0",
        "qckm_push_rows_total 400",
        "qckm_ingest_encode_seconds_count 1",
        "qckm_window_merge_seconds_count",
        "qckm_cache_hits_total 1",
        "qckm_cache_misses_total 1",
    ] {
        assert!(page.contains(needle), "missing `{needle}` in page:\n{page}");
    }
}

// ------------------------------------------------------------------- state

#[test]
fn ingest_pools_exactly_like_the_offline_sketch() {
    let svc = service(ServiceConfig::default());
    let x = random_mat(500, DIM, 1);
    let a = x.select_rows(&(0..213).collect::<Vec<_>>());
    let b = x.select_rows(&(213..500).collect::<Vec<_>>());
    svc.ingest("a", &a).unwrap();
    svc.ingest("b", &b).unwrap();

    let win = svc.merge_window(0);
    assert_eq!(win.pool.count(), 500);
    let mut want = PooledSketch::new(svc.operator().sketch_len());
    svc.operator().sketch_into(&x, &mut want);
    // ±1 contributions sum to exact integers: shard order cannot matter.
    assert_eq!(win.pool.sum(), want.sum());
    let labels: Vec<&str> = win.provenance.iter().map(|r| r.label.as_str()).collect();
    assert_eq!(labels, ["a", "b"], "stable shard-key merge order");
}

#[test]
fn ingest_rejects_wrong_dimension_and_bad_labels() {
    let svc = service(ServiceConfig::default());
    assert!(svc.ingest("s", &random_mat(5, DIM + 1, 2)).is_err());
    assert!(svc.ingest("", &random_mat(5, DIM, 2)).is_err());
    assert!(svc.ingest(&"x".repeat(300), &random_mat(5, DIM, 2)).is_err());
}

#[test]
fn declared_methods_are_checked_against_the_operator() {
    let svc = service(ServiceConfig::default()); // operator method: qckm
    svc.check_method("").unwrap(); // nothing declared → no check
    svc.check_method("qckm").unwrap();
    svc.check_method("QCKM").unwrap(); // canonicalized before comparing
    svc.check_method("qckm:bits=1").unwrap(); // canonicalizes to qckm
    let err = format!("{:#}", svc.check_method("qckm:bits=2").unwrap_err());
    assert!(err.contains("method mismatch"), "{err}");
    let err = format!("{:#}", svc.check_method("ckm").unwrap_err());
    assert!(err.contains("method mismatch"), "{err}");
    // Junk specs surface the registry's parse error.
    let err = format!("{:#}", svc.check_method("nope").unwrap_err());
    assert!(err.contains("valid families"), "{err}");
    assert_eq!(svc.stats().method, "qckm");
}

#[test]
fn windows_partition_epochs_and_ring_evicts_oldest() {
    let svc = service(ServiceConfig {
        epoch_capacity: 2,
        ..ServiceConfig::default()
    });
    let xs: Vec<Mat> = (0..3).map(|i| random_mat(100 + i, DIM, 10 + i as u64)).collect();

    svc.ingest("s", &xs[0]).unwrap();
    let (epoch, closed) = svc.roll_epoch();
    assert_eq!((epoch, closed), (1, 100));
    svc.ingest("s", &xs[1]).unwrap();
    svc.roll_epoch();
    svc.ingest("s", &xs[2]).unwrap();

    // window 1 = open epoch only; window 2 = + newest closed; 0 = all-time.
    assert_eq!(svc.merge_window(1).pool.count(), 102);
    assert_eq!(svc.merge_window(2).pool.count(), 102 + 101);
    assert_eq!(svc.merge_window(3).pool.count(), 102 + 101 + 100);
    assert_eq!(svc.merge_window(0).pool.count(), 303);
    // Asking past the ring clamps to what is held.
    assert_eq!(svc.merge_window(99).pool.count(), 303);

    // A third roll evicts epoch 0 from the ring; all-time keeps it.
    svc.roll_epoch();
    assert_eq!(svc.merge_window(99).pool.count(), 102 + 101);
    assert_eq!(svc.merge_window(0).pool.count(), 303);
    assert_eq!(svc.stats().epochs_held, 2);

    // Windowed provenance is epoch-labelled, chronological.
    svc.ingest("s", &random_mat(7, DIM, 20)).unwrap();
    let win = svc.merge_window(3);
    let labels: Vec<&str> = win.provenance.iter().map(|r| r.label.as_str()).collect();
    assert_eq!(labels, ["e1/s", "e2/s", "e3/s"]);
    assert_eq!(win.epochs, 3);
}

#[test]
fn query_decodes_and_caches_until_the_pool_changes() {
    let svc = service(ServiceConfig::default());
    let mut rng = Rng::new(3);
    let data = crate::data::gaussian_mixture_pm1(600, DIM, 2, &mut rng);
    svc.ingest("s", &data.points).unwrap();

    let first = svc.query(&spec(2, 0)).unwrap();
    assert!(!first.cached);
    assert_eq!(first.rows, 600);
    assert_eq!(first.dim as usize, DIM);
    assert_eq!(first.centroids.len(), 2 * DIM);

    let second = svc.query(&spec(2, 0)).unwrap();
    assert!(second.cached, "unchanged window must be served from cache");
    assert_eq!(second.centroids, first.centroids);
    assert_eq!(second.objective.to_bits(), first.objective.to_bits());

    // A different decode configuration is a different cache entry.
    let other = svc.query(&spec(1, 0)).unwrap();
    assert!(!other.cached);

    // New rows change the pooled bits — the stale entry can never hit.
    svc.ingest("s", &random_mat(50, DIM, 4)).unwrap();
    let third = svc.query(&spec(2, 0)).unwrap();
    assert!(!third.cached);
    assert_eq!(third.rows, 650);

    let stats = svc.stats();
    assert_eq!(stats.cache_hits, 1);
    assert_eq!(stats.cache_misses, 3);
}

/// The centroid cache keys on the canonical decoder spec: a different
/// `--decoder` on an unchanged window is a miss, an alias of the same
/// decoder is a hit, and stats reports per-decoder query counts.
#[test]
fn cache_keys_on_the_decoder_spec() {
    let svc = service(ServiceConfig::default());
    let mut rng = Rng::new(13);
    let data = crate::data::gaussian_mixture_pm1(600, DIM, 2, &mut rng);
    svc.ingest("s", &data.points).unwrap();

    let with_decoder = |decoder: &str| QuerySpec {
        decoder: decoder.into(),
        ..spec(2, 0)
    };
    // Empty (server default) and the explicit default share an entry.
    let first = svc.query(&with_decoder("")).unwrap();
    assert!(!first.cached);
    let second = svc.query(&with_decoder("clompr")).unwrap();
    assert!(second.cached, "'' and 'clompr' resolve to the same decoder");
    assert_eq!(second.centroids, first.centroids);

    // A different algorithm — or differently parameterized one — on the
    // unchanged window must miss and may decode differently.
    let hier = svc.query(&with_decoder("hier")).unwrap();
    assert!(!hier.cached, "hier must not be served clompr centroids");
    let pinned = svc.query(&with_decoder("clompr:restarts=3")).unwrap();
    assert!(!pinned.cached, "explicit params are a distinct cache key");
    // Aliases canonicalize before keying: a repeat through `bisect` hits.
    let hier_again = svc.query(&with_decoder("bisect")).unwrap();
    assert!(hier_again.cached);
    assert_eq!(hier_again.centroids, hier.centroids);

    // Junk decoder specs error with the registry list.
    let err = format!("{:#}", svc.query(&with_decoder("nope")).unwrap_err());
    assert!(err.contains("valid decoders"), "{err}");

    let stats = svc.stats();
    assert_eq!(stats.cache_hits, 2);
    assert_eq!(stats.cache_misses, 3);
    assert_eq!(
        stats.decoders,
        vec![
            ("clompr".to_string(), 2),
            ("clompr:restarts=3".to_string(), 1),
            ("hier".to_string(), 2),
        ]
    );

    // The per-decoder stats map is bounded: distinct-but-valid specs past
    // the cap tally under the overflow bucket instead of growing state.
    for r in 1..=40u32 {
        let _ = svc.query(&with_decoder(&format!("clompr:restarts={r}")));
    }
    let stats = svc.stats();
    assert!(
        stats.decoders.len() <= 33,
        "decoder stats must stay bounded, got {}",
        stats.decoders.len()
    );
    assert!(
        stats.decoders.iter().any(|(s, _)| s == "(other)"),
        "overflow bucket missing: {:?}",
        stats.decoders
    );
}

#[test]
fn query_validates_inputs_and_empty_windows() {
    let svc = service(ServiceConfig::default());
    assert!(svc.query(&spec(0, 0)).is_err(), "k = 0");
    assert!(svc
        .query(&QuerySpec {
            lo: 1.0,
            hi: -1.0,
            ..spec(2, 0)
        })
        .is_err());
    assert!(svc.query(&spec(2, 0)).is_err(), "nothing pushed yet");
    svc.ingest("s", &random_mat(10, DIM, 5)).unwrap();
    svc.roll_epoch();
    assert!(svc.query(&spec(2, 1)).is_err(), "open epoch is empty");
    assert!(svc.query(&spec(2, 0)).is_ok());
}

/// Regression: the shard accumulator maps used to grow without bound under
/// client-chosen labels — an unauthenticated pusher spamming fresh labels
/// could OOM the server. New labels past `max_shards` are refused;
/// existing shards keep accepting pushes.
#[test]
fn shard_cap_refuses_new_labels_but_keeps_serving() {
    let svc = service(ServiceConfig {
        max_shards: 2,
        ..ServiceConfig::default()
    });
    svc.ingest("a", &random_mat(5, DIM, 1)).unwrap();
    svc.ingest("b", &random_mat(5, DIM, 2)).unwrap();
    let err = format!("{:#}", svc.ingest("c", &random_mat(5, DIM, 3)).unwrap_err());
    assert!(err.contains("shard cap"), "{err}");
    // Known labels are unaffected, and the refusal left no trace of "c".
    svc.ingest("a", &random_mat(5, DIM, 4)).unwrap();
    assert_eq!(svc.stats().shards.len(), 2);
    assert_eq!(svc.merge_window(0).pool.count(), 15);
    // Seeding is the other label-creating path; it honors the same cap.
    let err = format!(
        "{:#}",
        svc.seed_with("d", PooledSketch::new(svc.operator().sketch_len())).unwrap_err()
    );
    assert!(err.contains("shard cap"), "{err}");
    svc.seed_with("b", PooledSketch::new(svc.operator().sketch_len())).unwrap();
}

/// The cap refusal is an application error ([`super::ServerError`]), so
/// the reconnecting push client fails fast instead of uselessly retrying a
/// request the server has already processed and rejected.
#[test]
fn shard_cap_refusal_is_not_retried() {
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let svc = Arc::new(service(ServiceConfig {
        max_shards: 1,
        ..ServiceConfig::default()
    }));
    let server = {
        let svc = Arc::clone(&svc);
        std::thread::spawn(move || super::serve(listener, svc).unwrap())
    };

    let policy = super::RetryPolicy {
        attempts: 3,
        base: std::time::Duration::from_millis(1),
        cap: std::time::Duration::from_millis(2),
    };
    let mut rc = super::RetryClient::connect(&addr, "", policy).unwrap();
    rc.push("only", &random_mat(4, DIM, 1)).unwrap();
    let err = format!("{:#}", rc.push("extra", &random_mat(4, DIM, 2)).unwrap_err());
    assert!(err.contains("shard cap"), "{err}");
    // "after 1 attempt(s)" is the fail-fast proof: a transport error under
    // this policy would have burned all 4 attempts.
    assert!(err.contains("after 1 attempt"), "{err}");
    // The server is still up and still accepts the known shard.
    rc.push("only", &random_mat(4, DIM, 3)).unwrap();

    super::Client::connect(&addr).unwrap().shutdown().unwrap();
    server.join().unwrap();
}

/// Regression: every state method used `self.inner.lock().unwrap()`, so
/// one panic under the lock (a thread dying mid-request) poisoned the
/// mutex and permanently panicked every later connection — a one-shot
/// denial of service. The service now recovers the guard (sound because
/// lock-held mutations are merge-atomic: `PooledSketch::merge` validates
/// before it writes).
#[test]
fn poisoned_lock_recovers_and_the_service_keeps_answering() {
    let svc = service(ServiceConfig::default());
    svc.ingest("s", &random_mat(50, DIM, 1)).unwrap();
    let before = svc.merge_window(0).pool.sum().to_vec();

    svc.poison_for_test();

    // Reads, writes, and decodes all still work, on intact state.
    assert_eq!(svc.merge_window(0).pool.sum(), &before[..]);
    assert_eq!(svc.stats().rows_total, 50);
    svc.ingest("s", &random_mat(10, DIM, 2)).unwrap();
    svc.roll_epoch();
    assert!(svc.query(&spec(2, 0)).is_ok());
    assert_eq!(svc.stats().rows_total, 60);
}

/// Regression: `snapshot` of an empty window used to serialize a count=0
/// `.qsk`, which decoded downstream into NaN centroids. It now refuses,
/// like `query` always has.
#[test]
fn snapshot_refuses_empty_windows() {
    let svc = service(ServiceConfig::default());
    let err = format!("{:#}", svc.snapshot(0).unwrap_err());
    assert!(err.contains("zero rows"), "{err}");
    svc.ingest("s", &random_mat(20, DIM, 1)).unwrap();
    svc.roll_epoch();
    // The open epoch is empty again; window 1 covers only it.
    assert!(svc.snapshot(1).is_err());
    assert!(svc.snapshot(0).is_ok());
}

#[test]
fn snapshot_bytes_are_a_loadable_qsk_with_provenance() {
    let svc = service(ServiceConfig::default());
    let x = random_mat(300, DIM, 6);
    svc.ingest("shard-a", &x).unwrap();

    let bytes = svc.snapshot(0).unwrap();
    let mut cursor = &bytes[..];
    let (meta, pool, prov) = read_sketch_from(&mut cursor, "snapshot").unwrap();
    assert!(cursor.is_empty());
    assert_eq!(&meta, svc.meta());
    assert_eq!(pool.count(), 300);
    let mut want = PooledSketch::new(svc.operator().sketch_len());
    svc.operator().sketch_into(&x, &mut want);
    assert_eq!(pool.sum(), want.sum());
    assert_eq!(prov.len(), 1);
    assert_eq!(prov[0].label, "shard-a");
    assert_eq!(prov[0].rows, 300);

    // The rebuilt operator matches — a snapshot decodes offline.
    assert!(meta.rebuild_operator().is_ok());
}

#[test]
fn seeding_restores_a_snapshot_into_alltime_only() {
    let svc = service(ServiceConfig::default());
    let x = random_mat(200, DIM, 7);
    svc.ingest("s", &x).unwrap();
    let bytes = svc.snapshot(0).unwrap();
    let (_, pool, _) = read_sketch_from(&mut &bytes[..], "snap").unwrap();

    let restored = service(ServiceConfig::default());
    restored.seed_with("seed", pool).unwrap();
    assert_eq!(restored.merge_window(0).pool.sum(), svc.merge_window(0).pool.sum());
    // Seed history predates every epoch: windowed queries exclude it.
    assert_eq!(restored.merge_window(1).pool.count(), 0);

    // Wrong-length seeds are refused.
    assert!(restored.seed_with("bad", PooledSketch::new(4)).is_err());
}

// ----------------------------------------------------------- concurrency

/// N client threads pushing disjoint shards in randomized batch sizes and
/// interleavings must produce the merged sketch — and decoded centroids —
/// of the single-threaded reference, bit for bit (±1 contributions pool
/// as exact integers).
#[test]
fn concurrent_ingest_is_bitwise_deterministic() {
    let mut rng = Rng::new(8);
    let data = crate::data::gaussian_mixture_pm1(1200, DIM, 2, &mut rng);
    let shards: Vec<(String, Mat)> = (0..4)
        .map(|s| {
            let rows: Vec<usize> = (s * 300..(s + 1) * 300).collect();
            (format!("shard-{s}"), data.points.select_rows(&rows))
        })
        .collect();

    // Single-threaded reference: one push per shard, in order.
    let reference = service(ServiceConfig::default());
    for (label, x) in &shards {
        reference.ingest(label, x).unwrap();
    }
    let ref_win = reference.merge_window(0);
    let ref_decode = reference.query(&spec(2, 0)).unwrap();

    for trial in 0..3u64 {
        let svc = Arc::new(service(ServiceConfig::default()));
        std::thread::scope(|scope| {
            for (t, (label, x)) in shards.iter().enumerate() {
                let svc = Arc::clone(&svc);
                scope.spawn(move || {
                    // Randomized batch splits per trial/thread: pushes from
                    // different shards interleave arbitrarily at the lock.
                    let mut rng = Rng::new(trial * 31 + t as u64);
                    let mut at = 0;
                    while at < x.rows() {
                        let take = (1 + rng.next_below(96) as usize).min(x.rows() - at);
                        let rows: Vec<usize> = (at..at + take).collect();
                        svc.ingest(label, &x.select_rows(&rows)).unwrap();
                        at += take;
                    }
                });
            }
        });
        let win = svc.merge_window(0);
        assert_eq!(win.pool.count(), 1200, "trial {trial}");
        assert_eq!(win.pool.sum(), ref_win.pool.sum(), "trial {trial} sums deviated");
        let decode = svc.query(&spec(2, 0)).unwrap();
        assert_eq!(
            decode.centroids, ref_decode.centroids,
            "trial {trial} centroids deviated"
        );
        assert_eq!(decode.objective.to_bits(), ref_decode.objective.to_bits());
    }
}

// ------------------------------------------------------------ socket smoke

/// Full loop over a real socket: serve on an ephemeral port, push from two
/// concurrent client connections, query, snapshot, stats, shutdown — all
/// in-process.
#[test]
fn socket_smoke_push_query_snapshot_shutdown() {
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let svc = Arc::new(service(ServiceConfig::default()));
    let server = {
        let svc = Arc::clone(&svc);
        std::thread::spawn(move || super::serve(listener, svc).unwrap())
    };

    let mut rng = Rng::new(9);
    let data = crate::data::gaussian_mixture_pm1(800, DIM, 2, &mut rng);
    let a = data.points.select_rows(&(0..400).collect::<Vec<_>>());
    let b = data.points.select_rows(&(400..800).collect::<Vec<_>>());

    // Two concurrent pushing connections, declaring the method (the server
    // verifies it against its operator on every push).
    std::thread::scope(|scope| {
        for (label, x) in [("a", &a), ("b", &b)] {
            let addr = addr.clone();
            scope.spawn(move || {
                let mut client = super::Client::connect(&addr).unwrap().declare_method("qckm");
                let (shard_rows, _) = client.push(label, x).unwrap();
                assert_eq!(shard_rows, 400);
            });
        }
    });

    // A client declaring the wrong method is refused at the protocol
    // boundary (the connection survives; only the request errors).
    let mut wrong = super::Client::connect(&addr).unwrap().declare_method("ckm");
    let err = format!("{:#}", wrong.query(&spec(2, 0)).unwrap_err());
    assert!(err.contains("method mismatch"), "{err}");

    let mut client = super::Client::connect(&addr).unwrap().declare_method("qckm:bits=1");
    let report = client.query(&spec(2, 0)).unwrap();
    assert_eq!(report.rows, 800);
    assert_eq!(report.centroids, svc.query(&spec(2, 0)).unwrap().centroids);

    let bytes = client.snapshot(0).unwrap();
    let (meta, pool, _) = read_sketch_from(&mut &bytes[..], "snap").unwrap();
    assert_eq!(&meta, svc.meta());
    assert_eq!(pool.count(), 800);

    let stats = client.stats().unwrap();
    assert_eq!(stats.rows_total, 800);
    assert_eq!(stats.shards.len(), 2);
    assert_eq!(stats.max_shards, 1024);
    assert_eq!(stats.method, "qckm");

    // A metrics scrape over the same socket: valid exposition text whose
    // request counters reflect the traffic this test just generated.
    let page = client.metrics().unwrap();
    crate::obs::prom::validate(&page).unwrap_or_else(|e| panic!("{e:#}\n{page}"));
    assert!(page.contains("qckm_requests_total{verb=\"push\"} 2"), "{page}");
    assert!(page.contains("qckm_push_rows_total 800"), "{page}");

    client.shutdown().unwrap();
    let served = server.join().unwrap();
    assert!(served >= 3, "served {served} connections");
}
