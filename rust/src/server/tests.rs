//! Unit tests for the sketch service: protocol round-trips and defensive
//! decoding (v4 through v6), shard/epoch/window state semantics, the
//! centroid cache, the snapshot ⇄ `.qsk` bridge, request tracing (the
//! golden span tree, the bounded ring, v4 compatibility), tenant scoping
//! (auth, routing, delta idempotency, rate limiting), concurrent-ingest
//! determinism, and in-process socket smokes (real `TcpListener`, no
//! child processes — `rust/tests/server_e2e.rs` drives the actual binary).

use super::proto::{self, CentroidReport, QuerySpec, Request, Response, Scope, StatsReport};
use super::service::{handle_payload, Handled};
use super::state::{ServiceConfig, SketchService};
use crate::frequency::FrequencyLaw;
use crate::linalg::Mat;
use crate::method::MethodSpec;
use crate::obs::trace::{IdGen, SeqIdGen, TraceContext};
use crate::obs::{FakeClock, Registry};
use crate::rng::Rng;
use crate::sketch::PooledSketch;
use crate::stream::{draw_operator, read_sketch_from, write_sketch_to, ShardRecord, SketchMeta};
use std::sync::Arc;

const DIM: usize = 4;
const M: usize = 24;
const SIGMA: f64 = 1.1;
const SEED: u64 = 5;

fn service(cfg: ServiceConfig) -> SketchService {
    let qckm = MethodSpec::parse("qckm").unwrap();
    let op = draw_operator(&qckm, FrequencyLaw::AdaptedRadius, M, DIM, SIGMA, SEED);
    let meta = SketchMeta::for_operator(&op, &qckm, SEED);
    SketchService::new(op, meta, cfg)
}

fn random_mat(rows: usize, cols: usize, seed: u64) -> Mat {
    let mut rng = Rng::new(seed);
    Mat::from_fn(rows, cols, |_, _| rng.gaussian())
}

fn spec(k: u32, window: u32) -> QuerySpec {
    QuerySpec {
        k,
        window,
        replicates: 1,
        seed: None,
        lo: -2.0,
        hi: 2.0,
        decoder: String::new(),
    }
}

// ------------------------------------------------------------------- proto

/// A fixed, nontrivial trace context for round-trip literals.
fn test_ctx() -> TraceContext {
    TraceContext {
        trace_id: *b"0123456789abcdef",
        parent_span: *b"fedcba98",
    }
}

#[test]
fn proto_round_trips_every_request_variant() {
    let requests = [
        Request::Push {
            scope: Scope::default(),
            shard: "sensor-7".into(),
            method: "qckm:bits=2".into(),
            dim: 3,
            data: vec![1.5, -2.25, 0.0, 4.0, 5.0, -6.0],
            trace: None,
        },
        Request::Push {
            scope: Scope::new("acme", "s3cret-token"),
            shard: "sensor-8".into(),
            method: String::new(),
            dim: 2,
            data: vec![1.0, 2.0],
            trace: Some(test_ctx()),
        },
        Request::Query {
            scope: Scope::new("acme", ""),
            spec: QuerySpec {
                k: 4,
                window: 2,
                replicates: 3,
                seed: Some(99),
                lo: -1.5,
                hi: 1.5,
                decoder: "clompr:restarts=5".into(),
            },
            method: "modulo".into(),
            trace: None,
        },
        Request::Query {
            scope: Scope::default(),
            spec: spec(1, 0),
            method: String::new(),
            trace: Some(test_ctx()),
        },
        Request::Snapshot {
            scope: Scope::new("", "token-without-tenant"),
            window: 7,
            method: "qckm".into(),
            trace: None,
        },
        Request::Snapshot {
            scope: Scope::default(),
            window: 0,
            method: String::new(),
            trace: Some(test_ctx()),
        },
        Request::Roll {
            scope: Scope::new("beta", "t"),
        },
        Request::Stats {
            scope: Scope::default(),
        },
        Request::Metrics,
        Request::Trace {
            scope: Scope::default(),
            id: None,
            limit: 0,
        },
        Request::Trace {
            scope: Scope::new("acme", "tok"),
            id: Some(test_ctx().trace_id),
            limit: 25,
        },
        Request::Delta {
            scope: Scope::new("acme", "tok"),
            agg_id: "edge-1".into(),
            instance: 0xDEAD_BEEF,
            seq: 42,
            sketch: vec![9, 8, 7, 6, 5],
            trace: Some(test_ctx()),
        },
        Request::Delta {
            scope: Scope::default(),
            agg_id: "edge-2".into(),
            instance: 1,
            seq: 1,
            sketch: vec![0],
            trace: None,
        },
        Request::Shutdown,
    ];
    for req in &requests {
        let bytes = proto::encode_request(req);
        assert_eq!(&proto::decode_request(&bytes).unwrap(), req, "{req:?}");
    }
}

/// The v4 wire format is still spoken on both sides: every trace-free
/// request round-trips at version 4 (and reports that version to the
/// server), while v5-only content refuses to encode at v4 instead of
/// silently dropping fields.
#[test]
fn proto_v4_round_trips_and_refuses_v5_content() {
    let v4_requests = [
        Request::Push {
            scope: Scope::default(),
            shard: "sensor-7".into(),
            method: "qckm".into(),
            dim: 2,
            data: vec![1.0, 2.0],
            trace: None,
        },
        Request::Query {
            scope: Scope::default(),
            spec: spec(3, 1),
            method: String::new(),
            trace: None,
        },
        Request::Snapshot {
            scope: Scope::default(),
            window: 2,
            method: String::new(),
            trace: None,
        },
        Request::Roll {
            scope: Scope::default(),
        },
        Request::Stats {
            scope: Scope::default(),
        },
        Request::Metrics,
        Request::Shutdown,
    ];
    for req in &v4_requests {
        let bytes = proto::encode_request_v(req, 4).unwrap();
        assert_eq!(bytes[0], 4, "{req:?}");
        let (version, decoded) = proto::decode_request_v(&bytes).unwrap();
        assert_eq!(version, 4, "{req:?}");
        assert_eq!(&decoded, req, "{req:?}");
    }

    // A carried trace context and the trace verb are v5 capabilities: the
    // encoder refuses rather than producing a frame v4 peers misread.
    let traced = Request::Query {
        scope: Scope::default(),
        spec: spec(1, 0),
        method: String::new(),
        trace: Some(test_ctx()),
    };
    let err = format!("{:#}", proto::encode_request_v(&traced, 4).unwrap_err());
    assert!(err.contains("needs proto v5"), "{err}");
    let err = format!(
        "{:#}",
        proto::encode_request_v(
            &Request::Trace {
                scope: Scope::default(),
                id: None,
                limit: 1,
            },
            4,
        )
        .unwrap_err()
    );
    assert!(err.contains("needs proto v5"), "{err}");

    // Responses: everything the v4 protocol had encodes at v4 and decodes
    // back; a traces response is v5-only in both directions.
    let ack = Response::PushAck {
        shard_rows: 3,
        total_rows: 9,
    };
    let bytes = proto::encode_response_v(&ack, 4).unwrap();
    assert_eq!(bytes[0], 4);
    assert_eq!(proto::decode_response(&bytes).unwrap(), ack);
    let err = format!(
        "{:#}",
        proto::encode_response_v(&Response::Traces("{}".into()), 4).unwrap_err()
    );
    assert!(err.contains("needs proto v5"), "{err}");
    // A hand-crafted v4 frame claiming the traces tag is refused too:
    // version byte 4, STATUS_OK, tag 8 (trace), empty string.
    let forged = [4u8, 0, 8, 0, 0, 0, 0];
    let err = format!("{:#}", proto::decode_response(&forged).unwrap_err());
    assert!(err.contains("needs proto v5"), "{err}");
    // Same for a request frame: version 4, tag 8 (trace), no id, limit 0.
    let forged = [4u8, 8, 0, 0, 0, 0, 0];
    let err = format!("{:#}", proto::decode_request(&forged).unwrap_err());
    assert!(err.contains("needs proto v5"), "{err}");
}

#[test]
fn proto_round_trips_every_response_variant() {
    let responses = [
        Response::Error("bad things".into()),
        Response::Busy {
            retry_after_ms: 250,
            message: "per-connection ingest rate limit".into(),
        },
        Response::PushAck {
            shard_rows: 10,
            total_rows: 30,
        },
        Response::Centroids(CentroidReport {
            centroids: vec![0.5, -0.5, 1.0, -1.0],
            k: 2,
            dim: 2,
            weights: vec![0.25, 0.75],
            objective: 0.125,
            rows: 1000,
            epochs: 3,
            cached: true,
        }),
        Response::Snapshot(vec![1, 2, 3, 4, 5]),
        Response::RollAck {
            epoch: 4,
            rows_closed: 512,
        },
        Response::Stats(StatsReport {
            method: "qckm:bits=3".into(),
            epoch: 2,
            rows_total: 77,
            epochs_held: 2,
            max_shards: 1024,
            cache_hits: 5,
            cache_misses: 6,
            shards: vec![("a".into(), 40), ("b".into(), 37)],
            decoders: vec![("clompr".into(), 9), ("hier".into(), 2)],
            tenant: "acme".into(),
            tenants: vec![("acme".into(), 77, 2), ("beta".into(), 0, 0)],
        }),
        Response::Metrics("# HELP qckm_requests_total req\n".into()),
        Response::Traces("{\n  \"traces\": []\n}".into()),
        Response::DeltaAck {
            merged: true,
            rows_total: 4096,
        },
        Response::DeltaAck {
            merged: false,
            rows_total: 0,
        },
        Response::ShutdownAck,
    ];
    for resp in &responses {
        let bytes = proto::encode_response(resp);
        assert_eq!(&proto::decode_response(&bytes).unwrap(), resp, "{resp:?}");
    }
}

#[test]
fn proto_rejects_malformed_payloads() {
    // Wrong protocol version.
    let mut bytes = proto::encode_request(&Request::Roll {
        scope: Scope::default(),
    });
    bytes[0] = 99;
    assert!(proto::decode_request(&bytes).is_err());

    // Unknown tag.
    let mut bytes = proto::encode_request(&Request::Roll {
        scope: Scope::default(),
    });
    bytes[1] = 200;
    assert!(proto::decode_request(&bytes).is_err());

    // Truncated body.
    let bytes = proto::encode_request(&Request::Query {
        scope: Scope::default(),
        spec: spec(2, 0),
        method: String::new(),
        trace: None,
    });
    assert!(proto::decode_request(&bytes[..bytes.len() - 1]).is_err());

    // Truncated trace block: presence byte says a context follows, but
    // the id bytes are missing.
    let bytes = proto::encode_request(&Request::Query {
        scope: Scope::default(),
        spec: spec(2, 0),
        method: String::new(),
        trace: Some(test_ctx()),
    });
    assert!(proto::decode_request(&bytes[..bytes.len() - 8]).is_err());

    // Implausible trace limit.
    let mut bytes = proto::encode_request(&Request::Trace {
        scope: Scope::default(),
        id: None,
        limit: 1,
    });
    let at = bytes.len() - 4;
    bytes[at..].copy_from_slice(&(proto::MAX_TRACE_LIMIT + 1).to_le_bytes());
    let err = format!("{:#}", proto::decode_request(&bytes).unwrap_err());
    assert!(err.contains("implausible trace limit"), "{err}");

    // Trailing garbage.
    let mut bytes = proto::encode_request(&Request::Stats {
        scope: Scope::default(),
    });
    bytes.push(0);
    assert!(proto::decode_request(&bytes).is_err());

    // Push payload not a whole number of rows.
    let mut ok = proto::encode_request(&Request::Push {
        scope: Scope::default(),
        shard: "s".into(),
        method: String::new(),
        dim: 3,
        data: vec![0.0; 6],
        trace: None,
    });
    // dim lives after the 1-byte version, 1-byte tag, 4+0 byte tenant
    // name, 4+0 byte token, 4+1 byte shard label, and 4+0 byte method
    // spec.
    ok[19] = 4; // now 6 values over dim 4
    assert!(proto::decode_request(&ok).is_err());

    // Oversized scope strings: a tenant name or token past the caps is
    // refused before any allocation tracks the declared length.
    let long = proto::encode_request(&Request::Roll {
        scope: Scope::new("x".repeat(proto::MAX_TENANT_BYTES + 1), ""),
    });
    assert!(proto::decode_request(&long).is_err());
    let long = proto::encode_request(&Request::Roll {
        scope: Scope::new("t", "x".repeat(proto::MAX_TOKEN_BYTES + 1)),
    });
    assert!(proto::decode_request(&long).is_err());

    // Oversized frame length on the wire.
    let mut wire = Vec::new();
    wire.extend_from_slice(&(u32::MAX).to_le_bytes());
    wire.extend_from_slice(&[0u8; 16]);
    assert!(proto::read_frame(&mut &wire[..]).is_err());

    // Clean EOF is None, mid-length EOF is an error.
    assert!(proto::read_frame(&mut &[][..]).unwrap().is_none());
    assert!(proto::read_frame(&mut &[1u8, 0][..]).is_err());
}

/// Regression: `decode_request` used to accept `len == 0` pushes, which
/// created empty shard accumulators and zero-row provenance records for
/// nothing. Empty batches are refused at the protocol boundary.
#[test]
fn proto_rejects_zero_row_pushes() {
    let bytes = proto::encode_request(&Request::Push {
        scope: Scope::default(),
        shard: "s".into(),
        method: String::new(),
        dim: 3,
        data: vec![],
        trace: None,
    });
    let err = format!("{:#}", proto::decode_request(&bytes).unwrap_err());
    assert!(err.contains("empty batch"), "{err}");
}

/// Regression: `encode_response` used to write error strings unbounded
/// while `decode_response` caps them at [`proto::MAX_ERROR_BYTES`], so a
/// long server error surfaced client-side as "implausible string field"
/// instead of the message. The encoder now truncates on a char boundary
/// with a marker.
#[test]
fn error_responses_truncate_to_the_decode_cap() {
    // Way past the cap, with multi-byte chars ('é' is 2 bytes in UTF-8) so
    // a byte-offset cut would land mid-char and panic the slicer.
    let long = "é".repeat(proto::MAX_ERROR_BYTES);
    let bytes = proto::encode_response(&Response::Error(long));
    let Response::Error(msg) = proto::decode_response(&bytes).unwrap() else {
        panic!("expected an error response");
    };
    assert!(msg.len() <= proto::MAX_ERROR_BYTES);
    assert!(msg.ends_with("[truncated]"), "missing truncation marker");
    assert!(msg.starts_with("éé"), "prefix must survive");

    // At or under the cap nothing changes.
    let short = "x".repeat(proto::MAX_ERROR_BYTES);
    let bytes = proto::encode_response(&Response::Error(short.clone()));
    assert_eq!(proto::decode_response(&bytes).unwrap(), Response::Error(short));
}

/// Metrics pages get the same both-side truncation treatment as error
/// strings: the encoder cuts to [`proto::MAX_METRICS_BYTES`] on a char
/// boundary with a marker, so any decoded page re-encodes identically
/// (the canonicalization fixed-point the fuzz suite relies on).
#[test]
fn metrics_responses_truncate_to_the_decode_cap() {
    let long = "x".repeat(proto::MAX_METRICS_BYTES + 100);
    let bytes = proto::encode_response(&Response::Metrics(long));
    let Response::Metrics(page) = proto::decode_response(&bytes).unwrap() else {
        panic!("expected a metrics response");
    };
    assert!(page.len() <= proto::MAX_METRICS_BYTES);
    assert!(page.ends_with("[truncated]"), "missing truncation marker");

    let short = "# HELP a b\n".to_string();
    let bytes = proto::encode_response(&Response::Metrics(short.clone()));
    assert_eq!(proto::decode_response(&bytes).unwrap(), Response::Metrics(short));
}

/// The service's exposition page is valid Prometheus text and covers the
/// server families even before their stages have run (registration is
/// eager, so a scrape lists the whole catalog at zero).
#[test]
fn metrics_page_covers_server_families_and_validates() {
    let svc = service(ServiceConfig::default());
    let mut rng = Rng::new(21);
    let data = crate::data::gaussian_mixture_pm1(400, DIM, 2, &mut rng);
    svc.ingest("s", &data.points).unwrap();
    let _ = svc.query(&spec(2, 0)).unwrap(); // miss → decode
    let _ = svc.query(&spec(2, 0)).unwrap(); // hit
    let page = svc.render_metrics();
    crate::obs::prom::validate(&page).unwrap_or_else(|e| panic!("{e:#}\n{page}"));
    for needle in [
        "qckm_requests_total{verb=\"push\"} 0", // direct state calls skip request spans
        "qckm_requests_total{verb=\"metrics\"} 0",
        "qckm_requests_total{verb=\"trace\"} 0",
        "qckm_push_rows_total 400",
        "qckm_ingest_encode_seconds_count 1",
        "qckm_window_merge_seconds_count",
        "qckm_cache_hits_total 1",
        "qckm_cache_misses_total 1",
        // Build identity and scrape-time occupancy mirrors.
        concat!("qckm_build_info{version=\"", env!("CARGO_PKG_VERSION"), "\"} 1"),
        "qckm_uptime_seconds",
        "qckm_shards 1",
        "qckm_epoch_ring_epochs 0",
        // Sketch-health gauges, refreshed by the push above.
        "qckm_shard_rows{shard=\"s\"} 400",
        "qckm_shard_bit_balance{shard=\"s\"}",
        // Decode-quality instruments: exactly one decode ran (the second
        // query hit the cache), a k=2 CL-OMPR decode runs 2k = 4 outer
        // iterations.
        "qckm_query_residual_norm_count 1",
        "qckm_query_outer_iters_total 4",
        "qckm_query_atoms_replaced_total",
    ] {
        assert!(page.contains(needle), "missing `{needle}` in page:\n{page}");
    }
}

/// The uptime gauge runs on the registry's clock, so under a fake clock
/// the scraped value is an exact constant — and build info is pinned to
/// the crate version with a constant sample value of 1.
#[test]
fn uptime_and_build_info_track_the_registry_clock() {
    let clock = Arc::new(FakeClock::new());
    let svc = service(ServiceConfig {
        registry: Arc::new(Registry::new(clock.clone())),
        ..ServiceConfig::default()
    });
    clock.advance_ns(1_500_000_000); // exactly 1.5 s after construction
    let page = svc.render_metrics();
    assert!(page.contains("qckm_uptime_seconds 1.5"), "{page}");
    let build = concat!("qckm_build_info{version=\"", env!("CARGO_PKG_VERSION"), "\"} 1");
    assert!(page.contains(build), "{page}");
}

// ----------------------------------------------------------------- tracing

/// Drive one encoded request through the full socket-free payload path
/// (frame decode → trace install → dispatch → version-echoed encode).
fn roundtrip(svc: &SketchService, req: &Request) -> Response {
    let frame = match handle_payload(svc, &proto::encode_request(req)) {
        Handled::Reply(frame) | Handled::Shutdown(frame) => frame,
    };
    proto::decode_response(&frame).unwrap()
}

/// The tentpole acceptance: a traced query's server-side span tree,
/// fetched back through the trace verb, is an exact constant under the
/// fake clock — both the structure (frame decode, then the query span
/// with cap check / window merge / decode under it, the decode running
/// `2k = 2` CL-OMPR outer iterations of step 1 + step 5) and the
/// timings (all zero: a plain fake clock never moves).
#[test]
fn traced_query_span_tree_is_golden() {
    let svc = service(ServiceConfig {
        registry: Arc::new(Registry::new(Arc::new(FakeClock::new()))),
        ..ServiceConfig::default()
    });
    let mut rng = Rng::new(17);
    let data = crate::data::gaussian_mixture_pm1(300, DIM, 1, &mut rng);
    svc.ingest("s", &data.points).unwrap();

    let ctx = SeqIdGen::new(0xABCD).next_context();
    let resp = roundtrip(
        &svc,
        &Request::Query {
            scope: Scope::default(),
            spec: spec(1, 0),
            method: String::new(),
            trace: Some(ctx),
        },
    );
    assert!(matches!(resp, Response::Centroids(_)), "{resp:?}");

    let fetched = roundtrip(
        &svc,
        &Request::Trace {
            scope: Scope::default(),
            id: Some(ctx.trace_id),
            limit: 0,
        },
    );
    let Response::Traces(json) = fetched else {
        panic!("expected a traces response, got {fetched:?}");
    };
    let expected = r#"{
  "traces": [
    {
      "trace_id": "000000000000abcd0000000000000001",
      "parent_span": "0000000000000001",
      "verb": "query",
      "ok": true,
      "dropped_spans": 0,
      "spans": [
        {
          "stage": "frame_decode",
          "start_ns": 0,
          "elapsed_ns": 0,
          "children": []
        },
        {
          "stage": "query",
          "start_ns": 0,
          "elapsed_ns": 0,
          "children": [
            {
              "stage": "cap_check",
              "start_ns": 0,
              "elapsed_ns": 0,
              "children": []
            },
            {
              "stage": "window_merge",
              "start_ns": 0,
              "elapsed_ns": 0,
              "children": []
            },
            {
              "stage": "decode",
              "start_ns": 0,
              "elapsed_ns": 0,
              "children": [
                {
                  "stage": "clompr_step1",
                  "start_ns": 0,
                  "elapsed_ns": 0,
                  "children": []
                },
                {
                  "stage": "clompr_step5",
                  "start_ns": 0,
                  "elapsed_ns": 0,
                  "children": []
                },
                {
                  "stage": "clompr_step1",
                  "start_ns": 0,
                  "elapsed_ns": 0,
                  "children": []
                },
                {
                  "stage": "clompr_step5",
                  "start_ns": 0,
                  "elapsed_ns": 0,
                  "children": []
                }
              ]
            }
          ]
        }
      ]
    }
  ]
}"#;
    assert_eq!(json, expected);
}

/// The trace ring is bounded at `trace_capacity` (oldest evicted), id
/// lookups search newest-first, a missing id errors helpfully, and an
/// explicit limit caps the batch.
#[test]
fn trace_ring_bounds_evicts_and_finds_by_id() {
    let svc = service(ServiceConfig {
        trace_capacity: 2,
        ..ServiceConfig::default()
    });
    let mut gen = SeqIdGen::new(7);
    let mut ids = Vec::new();
    for i in 0..3u64 {
        let ctx = gen.next_context();
        ids.push(ctx.trace_id);
        let resp = roundtrip(
            &svc,
            &Request::Push {
                scope: Scope::default(),
                shard: "s".into(),
                method: String::new(),
                dim: DIM as u32,
                data: vec![0.25; DIM],
                trace: Some(ctx),
            },
        );
        assert!(matches!(resp, Response::PushAck { .. }), "push {i}: {resp:?}");
    }

    // The oldest of the three was evicted; the newest two are held.
    let err = format!("{:#}", svc.traces_json(Some(ids[0]), 0).unwrap_err());
    assert!(err.contains("not found"), "{err}");
    for id in &ids[1..] {
        let json = svc.traces_json(Some(*id), 0).unwrap();
        assert!(json.contains(&crate::obs::trace::hex(id)), "{json}");
        // A traced push times the encode under its push span.
        assert!(json.contains("\"verb\": \"push\""), "{json}");
        assert!(json.contains("\"stage\": \"ingest_encode\""), "{json}");
    }

    // Batch fetches: newest first, limited, defaulting when limit = 0.
    let batch = svc.traces_json(None, 1).unwrap();
    assert_eq!(batch.matches("\"trace_id\"").count(), 1);
    assert!(batch.contains(&crate::obs::trace::hex(&ids[2])), "{batch}");
    let both = svc.traces_json(None, 0).unwrap();
    assert_eq!(both.matches("\"trace_id\"").count(), 2);
    let first = both.find(&crate::obs::trace::hex(&ids[2])).unwrap();
    let second = both.find(&crate::obs::trace::hex(&ids[1])).unwrap();
    assert!(first < second, "newest must come first:\n{both}");

    // Untraced requests leave nothing behind (I-19: tracing is opt-in).
    let before = both.matches("\"trace_id\"").count();
    let resp = roundtrip(
        &svc,
        &Request::Push {
            scope: Scope::default(),
            shard: "s".into(),
            method: String::new(),
            dim: DIM as u32,
            data: vec![0.5; DIM],
            trace: None,
        },
    );
    assert!(matches!(resp, Response::PushAck { .. }));
    let after = svc.traces_json(None, 0).unwrap();
    assert_eq!(after.matches("\"trace_id\"").count(), before);
}

/// I-19 end to end: a v4 client (no trace fields anywhere) is served
/// byte-identically by the v5 server — every reply frame carries version
/// 4 — and a forged v4 trace-verb frame is refused without killing the
/// connection.
#[test]
fn v4_clients_are_served_at_their_own_version() {
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let svc = Arc::new(service(ServiceConfig::default()));
    let server = {
        let svc = Arc::clone(&svc);
        std::thread::spawn(move || super::serve(listener, svc).unwrap())
    };

    fn call_v4(stream: &mut std::net::TcpStream, req: &Request) -> (u8, Response) {
        let frame = proto::encode_request_v(req, 4).unwrap();
        proto::write_frame(stream, &frame).unwrap();
        let payload = proto::read_frame(stream).unwrap().unwrap();
        (payload[0], proto::decode_response(&payload).unwrap())
    }
    let mut stream = std::net::TcpStream::connect(&addr).unwrap();

    let x = random_mat(40, DIM, 11);
    let (version, resp) = call_v4(&mut stream, &Request::Push {
        scope: Scope::default(),
        shard: "old-client".into(),
        method: "qckm".into(),
        dim: DIM as u32,
        data: x.as_slice().to_vec(),
        trace: None,
    });
    assert_eq!(version, 4, "reply must echo the request's version");
    assert!(matches!(resp, Response::PushAck { .. }), "{resp:?}");

    let (version, resp) = call_v4(&mut stream, &Request::Query {
        scope: Scope::default(),
        spec: spec(1, 0),
        method: String::new(),
        trace: None,
    });
    assert_eq!(version, 4);
    let Response::Centroids(report) = resp else {
        panic!("expected centroids");
    };
    assert_eq!(report.rows, 40);
    // The v4 answer is the same decode a v5 client gets, bit for bit.
    assert_eq!(report.centroids, svc.query(&spec(1, 0)).unwrap().centroids);

    let (version, resp) = call_v4(&mut stream, &Request::Stats {
        scope: Scope::default(),
    });
    assert_eq!(version, 4);
    assert!(matches!(resp, Response::Stats(_)));

    // A forged v4 frame with the trace tag (8): refused, at v4, and the
    // connection keeps serving.
    proto::write_frame(&mut stream, &[4u8, 8, 0, 0, 0, 0, 0]).unwrap();
    let payload = proto::read_frame(&mut stream).unwrap().unwrap();
    assert_eq!(payload[0], 4);
    let Response::Error(msg) = proto::decode_response(&payload).unwrap() else {
        panic!("expected an error");
    };
    assert!(msg.contains("needs proto v5"), "{msg}");
    let (version, resp) = call_v4(&mut stream, &Request::Stats {
        scope: Scope::default(),
    });
    assert_eq!(version, 4);
    assert!(matches!(resp, Response::Stats(_)));
    drop(stream);

    super::Client::connect(&addr).unwrap().shutdown().unwrap();
    server.join().unwrap();
}

// ------------------------------------- tenants, deltas & rate limiting

/// The v5 wire format keeps working, and every v6-only construct (tenant
/// scopes, the delta verb, busy status) refuses to encode below v6 —
/// degrading where an old peer must still learn something (busy → error
/// text) and failing loudly everywhere silence would corrupt.
#[test]
fn proto_v5_round_trips_and_refuses_v6_content() {
    // An empty scope encodes at v5 (the scope block simply isn't written)
    // and round-trips, echoing the old version.
    let unscoped = Request::Push {
        scope: Scope::default(),
        shard: "s".into(),
        method: String::new(),
        dim: 2,
        data: vec![1.0, 2.0],
        trace: Some(test_ctx()),
    };
    let bytes = proto::encode_request_v(&unscoped, 5).unwrap();
    assert_eq!(bytes[0], 5);
    let (version, decoded) = proto::decode_request_v(&bytes).unwrap();
    assert_eq!(version, 5);
    assert_eq!(decoded, unscoped);

    // A non-empty scope is v6-only: refused at v5, round-tripped at v6.
    let scoped = Request::Push {
        scope: Scope::new("acme", "s3cret"),
        shard: "s".into(),
        method: String::new(),
        dim: 2,
        data: vec![1.0, 2.0],
        trace: None,
    };
    let err = format!("{:#}", proto::encode_request_v(&scoped, 5).unwrap_err());
    assert!(err.contains("needs proto v6"), "{err}");
    let bytes = proto::encode_request_v(&scoped, 6).unwrap();
    assert_eq!(proto::decode_request(&bytes).unwrap(), scoped);

    // The delta verb is v6-only in both directions.
    let delta = Request::Delta {
        scope: Scope::default(),
        agg_id: "edge-1".into(),
        instance: 3,
        seq: 1,
        sketch: vec![1, 2, 3],
        trace: None,
    };
    let err = format!("{:#}", proto::encode_request_v(&delta, 5).unwrap_err());
    assert!(err.contains("needs proto v6"), "{err}");
    let bytes = proto::encode_request_v(&delta, 6).unwrap();
    assert_eq!(proto::decode_request(&bytes).unwrap(), delta);
    // Forged v5 frame claiming the delta tag (9): refused at decode.
    let err = format!("{:#}", proto::decode_request(&[5u8, 9]).unwrap_err());
    assert!(err.contains("needs proto v6"), "{err}");

    // A delta ack is v6-only in both directions too.
    let ack = Response::DeltaAck {
        merged: true,
        rows_total: 7,
    };
    let err = format!("{:#}", proto::encode_response_v(&ack, 5).unwrap_err());
    assert!(err.contains("needs proto v6"), "{err}");
    // Forged v5 response: STATUS_OK then the delta tag (9).
    let err = format!("{:#}", proto::decode_response(&[5u8, 0, 9]).unwrap_err());
    assert!(err.contains("needs proto v6"), "{err}");

    // Busy *degrades* below v6 instead of refusing: an old client must
    // still learn it was shed, so the hint survives in the error text.
    let busy = Response::Busy {
        retry_after_ms: 120,
        message: "per-connection ingest rate limit".into(),
    };
    let bytes = proto::encode_response_v(&busy, 5).unwrap();
    assert_eq!(bytes[0], 5);
    let Response::Error(msg) = proto::decode_response(&bytes).unwrap() else {
        panic!("a v5 busy must decode as an error");
    };
    assert!(msg.contains("retry after 120 ms"), "{msg}");
    assert_eq!(proto::decode_response(&proto::encode_response(&busy)).unwrap(), busy);
    // A forged v5 frame claiming the busy status byte is refused.
    let err = format!("{:#}", proto::decode_response(&[5u8, 2]).unwrap_err());
    assert!(err.contains("needs proto v6"), "{err}");
}

/// Tenant auth: the configured token is required (compared in constant
/// time — see `tenants::constant_time_eq_matches_slice_equality` for the
/// primitive), a scope naming the wrong tenant is refused, and failures
/// count under `qckm_auth_failures_total{tenant}`.
#[test]
fn scoped_requests_authorize_and_count_failures() {
    let registry = Arc::new(Registry::new(Arc::new(FakeClock::new())));
    let svc = service(ServiceConfig {
        tenant: "acme".into(),
        token: Some("s3cret".into()),
        registry,
        ..ServiceConfig::default()
    });

    svc.authorize(&Scope::new("acme", "s3cret")).unwrap();
    // Routing already matched the tenant: an empty name means "whoever
    // you are" and only the token is checked.
    svc.authorize(&Scope::new("", "s3cret")).unwrap();

    let err = format!("{:#}", svc.authorize(&Scope::new("acme", "wrong")).unwrap_err());
    assert!(err.contains("auth failed"), "{err}");
    let err = format!("{:#}", svc.authorize(&Scope::new("acme", "")).unwrap_err());
    assert!(err.contains("auth failed"), "{err}");
    // Wrong tenant name is a routing error, not an auth failure.
    let err = format!("{:#}", svc.authorize(&Scope::new("beta", "s3cret")).unwrap_err());
    assert!(err.contains("unknown tenant"), "{err}");

    let page = svc.render_metrics();
    crate::obs::prom::validate(&page).unwrap_or_else(|e| panic!("{e:#}\n{page}"));
    assert!(
        page.contains("qckm_auth_failures_total{tenant=\"acme\"} 2"),
        "{page}"
    );
    // A named tenant labels every request-side series; the single-tenant
    // default keeps the historical unlabeled names (checked by
    // `metrics_page_covers_server_families_and_validates`).
    assert!(page.contains("tenant=\"acme\""), "{page}");
}

/// Build one pre-pooled `.qsk` delta payload the way an aggregator does:
/// sketch `rows` rows offline, serialize under the `edge-1` provenance.
fn delta_bytes(svc: &SketchService, rows: usize, seed: u64) -> Vec<u8> {
    let x = random_mat(rows, DIM, seed);
    let mut pool = PooledSketch::new(svc.operator().sketch_len());
    svc.operator().sketch_into(&x, &mut pool);
    let prov = [ShardRecord {
        label: "edge-1".into(),
        rows: rows as u64,
    }];
    let mut bytes = Vec::new();
    write_sketch_to(&mut bytes, svc.meta(), &pool, &prov).unwrap();
    bytes
}

/// I-21: within one aggregator instance, only strictly increasing
/// sequence numbers merge — replays and reordered stale deltas drop as
/// recognized duplicates; a new instance (restart) resets the gate. Each
/// aggregator id has an independent gate, and outcomes are counted under
/// `qckm_deltas_total{outcome}`.
#[test]
fn delta_ingest_is_idempotent_per_instance() {
    let svc = service(ServiceConfig::default());

    let d1 = delta_bytes(&svc, 5, 1);
    assert_eq!(svc.ingest_delta("edge-1", 7, 1, &d1).unwrap(), (true, 5));
    // Exact replay (ack lost, flush re-sent): dropped, totals unchanged.
    assert_eq!(svc.ingest_delta("edge-1", 7, 1, &d1).unwrap(), (false, 5));
    // A stale reordered sequence is a replay too.
    let d0 = delta_bytes(&svc, 9, 2);
    assert_eq!(svc.ingest_delta("edge-1", 7, 0, &d0).unwrap(), (false, 5));
    // The next sequence merges.
    let d2 = delta_bytes(&svc, 3, 3);
    assert_eq!(svc.ingest_delta("edge-1", 7, 2, &d2).unwrap(), (true, 8));
    // Restart: new instance, sequence starts over — genuinely new data
    // (a restarted aggregator begins from empty accumulators).
    let d3 = delta_bytes(&svc, 2, 4);
    assert_eq!(svc.ingest_delta("edge-1", 8, 1, &d3).unwrap(), (true, 10));
    // A different aggregator has its own gate.
    let d4 = delta_bytes(&svc, 4, 5);
    assert_eq!(svc.ingest_delta("edge-2", 7, 1, &d4).unwrap(), (true, 14));

    // All merged rows pool under the aggregator-id shard labels, exactly
    // once each: the merged window equals offline pooling of the four
    // admitted batches (replays contributed nothing).
    let mut want = PooledSketch::new(svc.operator().sketch_len());
    for (rows, seed) in [(5, 1u64), (3, 3), (2, 4), (4, 5)] {
        svc.operator().sketch_into(&random_mat(rows, DIM, seed), &mut want);
    }
    assert_eq!(svc.merge_window(0).pool.sum(), want.sum());
    let stats = svc.stats();
    assert_eq!(
        stats.shards,
        vec![("edge-1".to_string(), 10), ("edge-2".to_string(), 4)]
    );

    // Corrupt payloads are refused before any state changes.
    assert!(svc.ingest_delta("edge-1", 8, 2, b"not a qsk").is_err());
    // A delta sketched under a different operator draw (same shape,
    // different seed → different fingerprint) cannot merge.
    let foreign = {
        let qckm = MethodSpec::parse("qckm").unwrap();
        let op = draw_operator(&qckm, FrequencyLaw::AdaptedRadius, M, DIM, SIGMA, SEED + 1);
        let meta = SketchMeta::for_operator(&op, &qckm, SEED + 1);
        let mut pool = PooledSketch::new(op.sketch_len());
        op.sketch_into(&random_mat(2, DIM, 6), &mut pool);
        let mut bytes = Vec::new();
        write_sketch_to(
            &mut bytes,
            &meta,
            &pool,
            &[ShardRecord {
                label: "edge-1".into(),
                rows: 2,
            }],
        )
        .unwrap();
        bytes
    };
    assert!(svc.ingest_delta("edge-1", 8, 2, &foreign).is_err());

    let page = svc.render_metrics();
    assert!(page.contains("qckm_deltas_total{outcome=\"merged\"} 4"), "{page}");
    assert!(page.contains("qckm_deltas_total{outcome=\"replayed\"} 2"), "{page}");
}

/// The multi-tenant node routes scoped frames to the addressed tenant's
/// isolated state, refuses unknown and unscoped requests helpfully,
/// answers stats with the node-wide occupancy block, renders one shared
/// metrics page covering every tenant, and shuts down without needing an
/// unnamed default tenant.
#[test]
fn node_routes_scoped_requests_across_tenants() {
    use super::FrameHandler;

    let registry = Arc::new(Registry::new(Arc::new(FakeClock::new())));
    let tenant_svc = |name: &str, token: Option<&str>| {
        Arc::new(service(ServiceConfig {
            tenant: name.into(),
            token: token.map(str::to_string),
            registry: registry.clone(),
            ..ServiceConfig::default()
        }))
    };
    let mut tenants = std::collections::BTreeMap::new();
    tenants.insert("acme".to_string(), tenant_svc("acme", Some("ta")));
    tenants.insert("beta".to_string(), tenant_svc("beta", None));
    let node = super::Node::new(tenants, None, registry).unwrap();
    let mut conn = node.new_conn();
    let mut call = |req: &Request| -> Response {
        let frame = match node.handle(&mut conn, &proto::encode_request(req)) {
            Handled::Reply(f) | Handled::Shutdown(f) => f,
        };
        proto::decode_response(&frame).unwrap()
    };
    let push = |scope: Scope, rows: usize, seed: u64| Request::Push {
        scope,
        shard: "s".into(),
        method: String::new(),
        dim: DIM as u32,
        data: random_mat(rows, DIM, seed).as_slice().to_vec(),
        trace: None,
    };

    // Scoped pushes land in their tenant's isolated accumulators.
    let resp = call(&push(Scope::new("acme", "ta"), 3, 1));
    assert!(matches!(resp, Response::PushAck { total_rows: 3, .. }), "{resp:?}");
    let resp = call(&push(Scope::new("beta", ""), 2, 2));
    assert!(matches!(resp, Response::PushAck { total_rows: 2, .. }), "{resp:?}");

    // Bad scopes: wrong token, unknown tenant, and no tenant at all on a
    // node hosting only named ones.
    let Response::Error(msg) = call(&push(Scope::new("acme", "wrong"), 1, 3)) else {
        panic!("expected an auth error");
    };
    assert!(msg.contains("auth failed"), "{msg}");
    let Response::Error(msg) = call(&push(Scope::new("nope", ""), 1, 3)) else {
        panic!("expected a routing error");
    };
    assert!(msg.contains("unknown tenant"), "{msg}");
    let Response::Error(msg) = call(&push(Scope::default(), 1, 3)) else {
        panic!("expected a routing error");
    };
    assert!(msg.contains("named tenants"), "{msg}");

    // Stats answers from the addressed tenant and attaches every
    // tenant's occupancy, in stable name order.
    let Response::Stats(report) = call(&Request::Stats {
        scope: Scope::new("beta", ""),
    }) else {
        panic!("expected stats");
    };
    assert_eq!(report.tenant, "beta");
    assert_eq!(report.rows_total, 2);
    assert_eq!(
        report.tenants,
        vec![("acme".to_string(), 3, 1), ("beta".to_string(), 2, 1)]
    );

    // One shared page covers both tenants, label-separated.
    let Response::Metrics(page) = call(&Request::Metrics) else {
        panic!("expected metrics");
    };
    crate::obs::prom::validate(&page).unwrap_or_else(|e| panic!("{e:#}\n{page}"));
    assert!(page.contains("tenant=\"acme\""), "{page}");
    assert!(page.contains("tenant=\"beta\""), "{page}");

    // Shutdown is node-wide: no default tenant needed.
    let handled = node.handle(&mut conn, &proto::encode_request(&Request::Shutdown));
    assert!(matches!(handled, Handled::Shutdown(_)));
}

/// Satellite regression: a rate-limited push comes back as a typed busy
/// refusal carrying a retry-after hint, and the retrying client sleeps
/// the hint *on the same connection* (reconnecting would reset the
/// per-connection bucket) until the push succeeds.
#[test]
fn rate_limited_pushes_back_off_and_eventually_succeed() {
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let svc = Arc::new(service(ServiceConfig::default()));
    let mut tenants = std::collections::BTreeMap::new();
    tenants.insert(String::new(), Arc::clone(&svc));
    let node = super::Node::new(
        tenants,
        // One-frame burst, 50 tokens/s: the second immediate push is shed
        // with a ~20 ms hint.
        Some(super::RateLimit {
            rate: 50.0,
            burst: 1.0,
        }),
        Arc::clone(svc.registry()),
    )
    .unwrap();
    let server = std::thread::spawn(move || super::serve_node(listener, Arc::new(node)).unwrap());

    let policy = super::RetryPolicy {
        attempts: 5,
        base: std::time::Duration::from_millis(1),
        cap: std::time::Duration::from_millis(2),
    };
    let mut rc = super::RetryClient::connect(&addr, "qckm", policy).unwrap();
    rc.push("s", &random_mat(4, DIM, 1)).unwrap();
    // The burst token is spent: this push is shed at least once, then
    // succeeds after the client honors the server's hint.
    rc.push("s", &random_mat(4, DIM, 2)).unwrap();
    assert_eq!(svc.stats().rows_total, 8, "both pushes must land exactly once");

    // The shed frames were counted.
    let page = svc.render_metrics();
    assert!(page.contains("qckm_rate_limited_total"), "{page}");
    assert!(!page.contains("qckm_rate_limited_total 0\n"), "{page}");

    super::Client::connect(&addr).unwrap().shutdown().unwrap();
    server.join().unwrap();
}

// ------------------------------------------------------------------- state

#[test]
fn ingest_pools_exactly_like_the_offline_sketch() {
    let svc = service(ServiceConfig::default());
    let x = random_mat(500, DIM, 1);
    let a = x.select_rows(&(0..213).collect::<Vec<_>>());
    let b = x.select_rows(&(213..500).collect::<Vec<_>>());
    svc.ingest("a", &a).unwrap();
    svc.ingest("b", &b).unwrap();

    let win = svc.merge_window(0);
    assert_eq!(win.pool.count(), 500);
    let mut want = PooledSketch::new(svc.operator().sketch_len());
    svc.operator().sketch_into(&x, &mut want);
    // ±1 contributions sum to exact integers: shard order cannot matter.
    assert_eq!(win.pool.sum(), want.sum());
    let labels: Vec<&str> = win.provenance.iter().map(|r| r.label.as_str()).collect();
    assert_eq!(labels, ["a", "b"], "stable shard-key merge order");
}

#[test]
fn ingest_rejects_wrong_dimension_and_bad_labels() {
    let svc = service(ServiceConfig::default());
    assert!(svc.ingest("s", &random_mat(5, DIM + 1, 2)).is_err());
    assert!(svc.ingest("", &random_mat(5, DIM, 2)).is_err());
    assert!(svc.ingest(&"x".repeat(300), &random_mat(5, DIM, 2)).is_err());
}

#[test]
fn declared_methods_are_checked_against_the_operator() {
    let svc = service(ServiceConfig::default()); // operator method: qckm
    svc.check_method("").unwrap(); // nothing declared → no check
    svc.check_method("qckm").unwrap();
    svc.check_method("QCKM").unwrap(); // canonicalized before comparing
    svc.check_method("qckm:bits=1").unwrap(); // canonicalizes to qckm
    let err = format!("{:#}", svc.check_method("qckm:bits=2").unwrap_err());
    assert!(err.contains("method mismatch"), "{err}");
    let err = format!("{:#}", svc.check_method("ckm").unwrap_err());
    assert!(err.contains("method mismatch"), "{err}");
    // Junk specs surface the registry's parse error.
    let err = format!("{:#}", svc.check_method("nope").unwrap_err());
    assert!(err.contains("valid families"), "{err}");
    assert_eq!(svc.stats().method, "qckm");
}

#[test]
fn windows_partition_epochs_and_ring_evicts_oldest() {
    let svc = service(ServiceConfig {
        epoch_capacity: 2,
        ..ServiceConfig::default()
    });
    let xs: Vec<Mat> = (0..3).map(|i| random_mat(100 + i, DIM, 10 + i as u64)).collect();

    svc.ingest("s", &xs[0]).unwrap();
    let (epoch, closed) = svc.roll_epoch();
    assert_eq!((epoch, closed), (1, 100));
    svc.ingest("s", &xs[1]).unwrap();
    svc.roll_epoch();
    svc.ingest("s", &xs[2]).unwrap();

    // window 1 = open epoch only; window 2 = + newest closed; 0 = all-time.
    assert_eq!(svc.merge_window(1).pool.count(), 102);
    assert_eq!(svc.merge_window(2).pool.count(), 102 + 101);
    assert_eq!(svc.merge_window(3).pool.count(), 102 + 101 + 100);
    assert_eq!(svc.merge_window(0).pool.count(), 303);
    // Asking past the ring clamps to what is held.
    assert_eq!(svc.merge_window(99).pool.count(), 303);

    // A third roll evicts epoch 0 from the ring; all-time keeps it.
    svc.roll_epoch();
    assert_eq!(svc.merge_window(99).pool.count(), 102 + 101);
    assert_eq!(svc.merge_window(0).pool.count(), 303);
    assert_eq!(svc.stats().epochs_held, 2);

    // Windowed provenance is epoch-labelled, chronological.
    svc.ingest("s", &random_mat(7, DIM, 20)).unwrap();
    let win = svc.merge_window(3);
    let labels: Vec<&str> = win.provenance.iter().map(|r| r.label.as_str()).collect();
    assert_eq!(labels, ["e1/s", "e2/s", "e3/s"]);
    assert_eq!(win.epochs, 3);
}

#[test]
fn query_decodes_and_caches_until_the_pool_changes() {
    let svc = service(ServiceConfig::default());
    let mut rng = Rng::new(3);
    let data = crate::data::gaussian_mixture_pm1(600, DIM, 2, &mut rng);
    svc.ingest("s", &data.points).unwrap();

    let first = svc.query(&spec(2, 0)).unwrap();
    assert!(!first.cached);
    assert_eq!(first.rows, 600);
    assert_eq!(first.dim as usize, DIM);
    assert_eq!(first.centroids.len(), 2 * DIM);

    let second = svc.query(&spec(2, 0)).unwrap();
    assert!(second.cached, "unchanged window must be served from cache");
    assert_eq!(second.centroids, first.centroids);
    assert_eq!(second.objective.to_bits(), first.objective.to_bits());

    // A different decode configuration is a different cache entry.
    let other = svc.query(&spec(1, 0)).unwrap();
    assert!(!other.cached);

    // New rows change the pooled bits — the stale entry can never hit.
    svc.ingest("s", &random_mat(50, DIM, 4)).unwrap();
    let third = svc.query(&spec(2, 0)).unwrap();
    assert!(!third.cached);
    assert_eq!(third.rows, 650);

    let stats = svc.stats();
    assert_eq!(stats.cache_hits, 1);
    assert_eq!(stats.cache_misses, 3);
}

/// The centroid cache keys on the canonical decoder spec: a different
/// `--decoder` on an unchanged window is a miss, an alias of the same
/// decoder is a hit, and stats reports per-decoder query counts.
#[test]
fn cache_keys_on_the_decoder_spec() {
    let svc = service(ServiceConfig::default());
    let mut rng = Rng::new(13);
    let data = crate::data::gaussian_mixture_pm1(600, DIM, 2, &mut rng);
    svc.ingest("s", &data.points).unwrap();

    let with_decoder = |decoder: &str| QuerySpec {
        decoder: decoder.into(),
        ..spec(2, 0)
    };
    // Empty (server default) and the explicit default share an entry.
    let first = svc.query(&with_decoder("")).unwrap();
    assert!(!first.cached);
    let second = svc.query(&with_decoder("clompr")).unwrap();
    assert!(second.cached, "'' and 'clompr' resolve to the same decoder");
    assert_eq!(second.centroids, first.centroids);

    // A different algorithm — or differently parameterized one — on the
    // unchanged window must miss and may decode differently.
    let hier = svc.query(&with_decoder("hier")).unwrap();
    assert!(!hier.cached, "hier must not be served clompr centroids");
    let pinned = svc.query(&with_decoder("clompr:restarts=3")).unwrap();
    assert!(!pinned.cached, "explicit params are a distinct cache key");
    // Aliases canonicalize before keying: a repeat through `bisect` hits.
    let hier_again = svc.query(&with_decoder("bisect")).unwrap();
    assert!(hier_again.cached);
    assert_eq!(hier_again.centroids, hier.centroids);

    // Junk decoder specs error with the registry list.
    let err = format!("{:#}", svc.query(&with_decoder("nope")).unwrap_err());
    assert!(err.contains("valid decoders"), "{err}");

    let stats = svc.stats();
    assert_eq!(stats.cache_hits, 2);
    assert_eq!(stats.cache_misses, 3);
    assert_eq!(
        stats.decoders,
        vec![
            ("clompr".to_string(), 2),
            ("clompr:restarts=3".to_string(), 1),
            ("hier".to_string(), 2),
        ]
    );

    // The per-decoder stats map is bounded: distinct-but-valid specs past
    // the cap tally under the overflow bucket instead of growing state.
    for r in 1..=40u32 {
        let _ = svc.query(&with_decoder(&format!("clompr:restarts={r}")));
    }
    let stats = svc.stats();
    assert!(
        stats.decoders.len() <= 33,
        "decoder stats must stay bounded, got {}",
        stats.decoders.len()
    );
    assert!(
        stats.decoders.iter().any(|(s, _)| s == "(other)"),
        "overflow bucket missing: {:?}",
        stats.decoders
    );
}

#[test]
fn query_validates_inputs_and_empty_windows() {
    let svc = service(ServiceConfig::default());
    assert!(svc.query(&spec(0, 0)).is_err(), "k = 0");
    assert!(svc
        .query(&QuerySpec {
            lo: 1.0,
            hi: -1.0,
            ..spec(2, 0)
        })
        .is_err());
    assert!(svc.query(&spec(2, 0)).is_err(), "nothing pushed yet");
    svc.ingest("s", &random_mat(10, DIM, 5)).unwrap();
    svc.roll_epoch();
    assert!(svc.query(&spec(2, 1)).is_err(), "open epoch is empty");
    assert!(svc.query(&spec(2, 0)).is_ok());
}

/// Regression: the shard accumulator maps used to grow without bound under
/// client-chosen labels — an unauthenticated pusher spamming fresh labels
/// could OOM the server. New labels past `max_shards` are refused;
/// existing shards keep accepting pushes.
#[test]
fn shard_cap_refuses_new_labels_but_keeps_serving() {
    let svc = service(ServiceConfig {
        max_shards: 2,
        ..ServiceConfig::default()
    });
    svc.ingest("a", &random_mat(5, DIM, 1)).unwrap();
    svc.ingest("b", &random_mat(5, DIM, 2)).unwrap();
    let err = format!("{:#}", svc.ingest("c", &random_mat(5, DIM, 3)).unwrap_err());
    assert!(err.contains("shard cap"), "{err}");
    // Known labels are unaffected, and the refusal left no trace of "c".
    svc.ingest("a", &random_mat(5, DIM, 4)).unwrap();
    assert_eq!(svc.stats().shards.len(), 2);
    assert_eq!(svc.merge_window(0).pool.count(), 15);
    // Seeding is the other label-creating path; it honors the same cap.
    let err = format!(
        "{:#}",
        svc.seed_with("d", PooledSketch::new(svc.operator().sketch_len())).unwrap_err()
    );
    assert!(err.contains("shard cap"), "{err}");
    svc.seed_with("b", PooledSketch::new(svc.operator().sketch_len())).unwrap();
}

/// The cap refusal is an application error ([`super::ServerError`]), so
/// the reconnecting push client fails fast instead of uselessly retrying a
/// request the server has already processed and rejected.
#[test]
fn shard_cap_refusal_is_not_retried() {
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let svc = Arc::new(service(ServiceConfig {
        max_shards: 1,
        ..ServiceConfig::default()
    }));
    let server = {
        let svc = Arc::clone(&svc);
        std::thread::spawn(move || super::serve(listener, svc).unwrap())
    };

    let policy = super::RetryPolicy {
        attempts: 3,
        base: std::time::Duration::from_millis(1),
        cap: std::time::Duration::from_millis(2),
    };
    let mut rc = super::RetryClient::connect(&addr, "", policy).unwrap();
    rc.push("only", &random_mat(4, DIM, 1)).unwrap();
    let err = format!("{:#}", rc.push("extra", &random_mat(4, DIM, 2)).unwrap_err());
    assert!(err.contains("shard cap"), "{err}");
    // "after 1 attempt(s)" is the fail-fast proof: a transport error under
    // this policy would have burned all 4 attempts.
    assert!(err.contains("after 1 attempt"), "{err}");
    // The server is still up and still accepts the known shard.
    rc.push("only", &random_mat(4, DIM, 3)).unwrap();

    super::Client::connect(&addr).unwrap().shutdown().unwrap();
    server.join().unwrap();
}

/// Regression: every state method used `self.inner.lock().unwrap()`, so
/// one panic under the lock (a thread dying mid-request) poisoned the
/// mutex and permanently panicked every later connection — a one-shot
/// denial of service. The service now recovers the guard (sound because
/// lock-held mutations are merge-atomic: `PooledSketch::merge` validates
/// before it writes).
#[test]
fn poisoned_lock_recovers_and_the_service_keeps_answering() {
    let svc = service(ServiceConfig::default());
    svc.ingest("s", &random_mat(50, DIM, 1)).unwrap();
    let before = svc.merge_window(0).pool.sum().to_vec();

    svc.poison_for_test();

    // Reads, writes, and decodes all still work, on intact state.
    assert_eq!(svc.merge_window(0).pool.sum(), &before[..]);
    assert_eq!(svc.stats().rows_total, 50);
    svc.ingest("s", &random_mat(10, DIM, 2)).unwrap();
    svc.roll_epoch();
    assert!(svc.query(&spec(2, 0)).is_ok());
    assert_eq!(svc.stats().rows_total, 60);
}

/// Regression: `snapshot` of an empty window used to serialize a count=0
/// `.qsk`, which decoded downstream into NaN centroids. It now refuses,
/// like `query` always has.
#[test]
fn snapshot_refuses_empty_windows() {
    let svc = service(ServiceConfig::default());
    let err = format!("{:#}", svc.snapshot(0).unwrap_err());
    assert!(err.contains("zero rows"), "{err}");
    svc.ingest("s", &random_mat(20, DIM, 1)).unwrap();
    svc.roll_epoch();
    // The open epoch is empty again; window 1 covers only it.
    assert!(svc.snapshot(1).is_err());
    assert!(svc.snapshot(0).is_ok());
}

#[test]
fn snapshot_bytes_are_a_loadable_qsk_with_provenance() {
    let svc = service(ServiceConfig::default());
    let x = random_mat(300, DIM, 6);
    svc.ingest("shard-a", &x).unwrap();

    let bytes = svc.snapshot(0).unwrap();
    let mut cursor = &bytes[..];
    let (meta, pool, prov) = read_sketch_from(&mut cursor, "snapshot").unwrap();
    assert!(cursor.is_empty());
    assert_eq!(&meta, svc.meta());
    assert_eq!(pool.count(), 300);
    let mut want = PooledSketch::new(svc.operator().sketch_len());
    svc.operator().sketch_into(&x, &mut want);
    assert_eq!(pool.sum(), want.sum());
    assert_eq!(prov.len(), 1);
    assert_eq!(prov[0].label, "shard-a");
    assert_eq!(prov[0].rows, 300);

    // The rebuilt operator matches — a snapshot decodes offline.
    assert!(meta.rebuild_operator().is_ok());
}

#[test]
fn seeding_restores_a_snapshot_into_alltime_only() {
    let svc = service(ServiceConfig::default());
    let x = random_mat(200, DIM, 7);
    svc.ingest("s", &x).unwrap();
    let bytes = svc.snapshot(0).unwrap();
    let (_, pool, _) = read_sketch_from(&mut &bytes[..], "snap").unwrap();

    let restored = service(ServiceConfig::default());
    restored.seed_with("seed", pool).unwrap();
    assert_eq!(restored.merge_window(0).pool.sum(), svc.merge_window(0).pool.sum());
    // Seed history predates every epoch: windowed queries exclude it.
    assert_eq!(restored.merge_window(1).pool.count(), 0);

    // Wrong-length seeds are refused.
    assert!(restored.seed_with("bad", PooledSketch::new(4)).is_err());
}

// ----------------------------------------------------------- concurrency

/// N client threads pushing disjoint shards in randomized batch sizes and
/// interleavings must produce the merged sketch — and decoded centroids —
/// of the single-threaded reference, bit for bit (±1 contributions pool
/// as exact integers).
#[test]
fn concurrent_ingest_is_bitwise_deterministic() {
    let mut rng = Rng::new(8);
    let data = crate::data::gaussian_mixture_pm1(1200, DIM, 2, &mut rng);
    let shards: Vec<(String, Mat)> = (0..4)
        .map(|s| {
            let rows: Vec<usize> = (s * 300..(s + 1) * 300).collect();
            (format!("shard-{s}"), data.points.select_rows(&rows))
        })
        .collect();

    // Single-threaded reference: one push per shard, in order.
    let reference = service(ServiceConfig::default());
    for (label, x) in &shards {
        reference.ingest(label, x).unwrap();
    }
    let ref_win = reference.merge_window(0);
    let ref_decode = reference.query(&spec(2, 0)).unwrap();

    for trial in 0..3u64 {
        let svc = Arc::new(service(ServiceConfig::default()));
        std::thread::scope(|scope| {
            for (t, (label, x)) in shards.iter().enumerate() {
                let svc = Arc::clone(&svc);
                scope.spawn(move || {
                    // Randomized batch splits per trial/thread: pushes from
                    // different shards interleave arbitrarily at the lock.
                    let mut rng = Rng::new(trial * 31 + t as u64);
                    let mut at = 0;
                    while at < x.rows() {
                        let take = (1 + rng.next_below(96) as usize).min(x.rows() - at);
                        let rows: Vec<usize> = (at..at + take).collect();
                        svc.ingest(label, &x.select_rows(&rows)).unwrap();
                        at += take;
                    }
                });
            }
        });
        let win = svc.merge_window(0);
        assert_eq!(win.pool.count(), 1200, "trial {trial}");
        assert_eq!(win.pool.sum(), ref_win.pool.sum(), "trial {trial} sums deviated");
        let decode = svc.query(&spec(2, 0)).unwrap();
        assert_eq!(
            decode.centroids, ref_decode.centroids,
            "trial {trial} centroids deviated"
        );
        assert_eq!(decode.objective.to_bits(), ref_decode.objective.to_bits());
    }
}

// ------------------------------------------------------------ socket smoke

/// Full loop over a real socket: serve on an ephemeral port, push from two
/// concurrent client connections, query, snapshot, stats, shutdown — all
/// in-process.
#[test]
fn socket_smoke_push_query_snapshot_shutdown() {
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let svc = Arc::new(service(ServiceConfig::default()));
    let server = {
        let svc = Arc::clone(&svc);
        std::thread::spawn(move || super::serve(listener, svc).unwrap())
    };

    let mut rng = Rng::new(9);
    let data = crate::data::gaussian_mixture_pm1(800, DIM, 2, &mut rng);
    let a = data.points.select_rows(&(0..400).collect::<Vec<_>>());
    let b = data.points.select_rows(&(400..800).collect::<Vec<_>>());

    // Two concurrent pushing connections, declaring the method (the server
    // verifies it against its operator on every push).
    std::thread::scope(|scope| {
        for (label, x) in [("a", &a), ("b", &b)] {
            let addr = addr.clone();
            scope.spawn(move || {
                let mut client = super::Client::connect(&addr).unwrap().declare_method("qckm");
                let (shard_rows, _) = client.push(label, x).unwrap();
                assert_eq!(shard_rows, 400);
            });
        }
    });

    // A client declaring the wrong method is refused at the protocol
    // boundary (the connection survives; only the request errors).
    let mut wrong = super::Client::connect(&addr).unwrap().declare_method("ckm");
    let err = format!("{:#}", wrong.query(&spec(2, 0)).unwrap_err());
    assert!(err.contains("method mismatch"), "{err}");

    let mut client = super::Client::connect(&addr).unwrap().declare_method("qckm:bits=1");
    let report = client.query(&spec(2, 0)).unwrap();
    assert_eq!(report.rows, 800);
    assert_eq!(report.centroids, svc.query(&spec(2, 0)).unwrap().centroids);

    let bytes = client.snapshot(0).unwrap();
    let (meta, pool, _) = read_sketch_from(&mut &bytes[..], "snap").unwrap();
    assert_eq!(&meta, svc.meta());
    assert_eq!(pool.count(), 800);

    let stats = client.stats().unwrap();
    assert_eq!(stats.rows_total, 800);
    assert_eq!(stats.shards.len(), 2);
    assert_eq!(stats.max_shards, 1024);
    assert_eq!(stats.method, "qckm");

    // A metrics scrape over the same socket: valid exposition text whose
    // request counters reflect the traffic this test just generated.
    let page = client.metrics().unwrap();
    crate::obs::prom::validate(&page).unwrap_or_else(|e| panic!("{e:#}\n{page}"));
    assert!(page.contains("qckm_requests_total{verb=\"push\"} 2"), "{page}");
    assert!(page.contains("qckm_push_rows_total 800"), "{page}");

    // A traced query over a fresh socket, then the trace verb on the
    // same connection: the server hands back the span tree for exactly
    // the id the client generated.
    let mut traced = super::Client::connect(&addr)
        .unwrap()
        .declare_method("qckm")
        .with_tracing(Box::new(SeqIdGen::new(1)));
    traced.query(&spec(2, 0)).unwrap();
    let id = traced.last_trace_id().expect("a traced query records its id");
    let json = traced.trace(Some(id), 1).unwrap();
    assert!(json.contains(&crate::obs::trace::hex(&id)), "{json}");
    assert!(json.contains("\"verb\": \"query\""), "{json}");

    client.shutdown().unwrap();
    let served = server.join().unwrap();
    assert!(served >= 3, "served {served} connections");
}
