//! Blocking client for the sketch service — the library behind
//! `qckm push` / `qckm query` / `qckm snapshot` / `qckm ctl`.

use super::proto::{
    self, CentroidReport, QuerySpec, Request, Response, StatsReport,
};
use crate::linalg::Mat;
use anyhow::{bail, Context, Result};
use std::net::TcpStream;
use std::time::Duration;

/// One connection to a serving node. Requests are strictly sequential
/// (send, then wait for the reply); open several clients for concurrency —
/// the server runs one handler thread per connection.
pub struct Client {
    stream: TcpStream,
    /// Declared canonical method spec carried on push/query/snapshot
    /// (empty = declare nothing; the server then skips the check).
    method: String,
}

impl Client {
    /// Connect to `addr` (`host:port`). Reads time out after five minutes
    /// so a dead server fails the client instead of hanging it (decode of
    /// a realistic sketch is seconds, not minutes).
    pub fn connect(addr: &str) -> Result<Self> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
        stream
            .set_read_timeout(Some(Duration::from_secs(300)))
            .context("set read timeout")?;
        stream.set_nodelay(true).ok();
        Ok(Self {
            stream,
            method: String::new(),
        })
    }

    /// Declare the method this client expects the server to sketch with.
    /// Every subsequent push/query/snapshot carries the spec, and the
    /// server refuses the request if its operator's method differs.
    pub fn declare_method(mut self, spec: &str) -> Self {
        self.method = spec.to_string();
        self
    }

    fn call(&mut self, req: &Request) -> Result<Response> {
        proto::write_request(&mut self.stream, req)?;
        match proto::read_response(&mut self.stream)? {
            Response::Error(msg) => bail!("server: {msg}"),
            resp => Ok(resp),
        }
    }

    /// Push a row batch into `shard`. Returns (shard rows, total rows)
    /// accumulated all-time on the server.
    pub fn push(&mut self, shard: &str, batch: &Mat) -> Result<(u64, u64)> {
        let req = Request::Push {
            shard: shard.to_string(),
            method: self.method.clone(),
            dim: batch.cols() as u32,
            data: batch.as_slice().to_vec(),
        };
        match self.call(&req)? {
            Response::PushAck {
                shard_rows,
                total_rows,
            } => Ok((shard_rows, total_rows)),
            other => bail!("unexpected reply to push: {other:?}"),
        }
    }

    /// Decode centroids from a window.
    pub fn query(&mut self, spec: &QuerySpec) -> Result<CentroidReport> {
        let req = Request::Query {
            spec: spec.clone(),
            method: self.method.clone(),
        };
        match self.call(&req)? {
            Response::Centroids(report) => Ok(report),
            other => bail!("unexpected reply to query: {other:?}"),
        }
    }

    /// Fetch a window as `.qsk` bytes (write them to a file and they are a
    /// regular sketch file for `qckm merge` / `qckm decode`).
    pub fn snapshot(&mut self, window: u32) -> Result<Vec<u8>> {
        let req = Request::Snapshot {
            window,
            method: self.method.clone(),
        };
        match self.call(&req)? {
            Response::Snapshot(bytes) => Ok(bytes),
            other => bail!("unexpected reply to snapshot: {other:?}"),
        }
    }

    /// Close the open epoch. Returns (new epoch index, rows closed).
    pub fn roll(&mut self) -> Result<(u64, u64)> {
        match self.call(&Request::Roll)? {
            Response::RollAck { epoch, rows_closed } => Ok((epoch, rows_closed)),
            other => bail!("unexpected reply to roll: {other:?}"),
        }
    }

    /// Fetch server counters.
    pub fn stats(&mut self) -> Result<StatsReport> {
        match self.call(&Request::Stats)? {
            Response::Stats(report) => Ok(report),
            other => bail!("unexpected reply to stats: {other:?}"),
        }
    }

    /// Ask the server to stop (acked before it exits).
    pub fn shutdown(&mut self) -> Result<()> {
        match self.call(&Request::Shutdown)? {
            Response::ShutdownAck => Ok(()),
            other => bail!("unexpected reply to shutdown: {other:?}"),
        }
    }
}
