//! Blocking client for the sketch service — the library behind
//! `qckm push` / `qckm query` / `qckm snapshot` / `qckm ctl` — plus the
//! reconnecting, bounded-exponential-backoff wrapper `qckm push` uses to
//! survive server restarts.

use super::proto::{
    self, CentroidReport, QuerySpec, Request, Response, Scope, StatsReport,
};
use crate::linalg::Mat;
use crate::obs::log::{self, Level, Value};
use crate::obs::trace::{IdGen, ProcessIdGen, TraceContext};
use anyhow::{bail, Context, Result};
use std::fmt;
use std::net::TcpStream;
use std::time::Duration;

/// An error the *server* reported after processing a request (method
/// mismatch, bad query, …). The request reached the service and was
/// refused — retrying it cannot succeed, so [`RetryClient`] fails fast on
/// these and only retries transport-level errors (refused connections,
/// resets, timeouts).
#[derive(Debug)]
pub struct ServerError(pub String);

impl fmt::Display for ServerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "server: {}", self.0)
    }
}

impl std::error::Error for ServerError {}

/// The server shed this request under load (per-connection ingest rate
/// limit) and told us when to come back. Unlike [`ServerError`] this is
/// retryable *on the same connection* — the rate bucket is per
/// connection, so reconnecting would reset it and defeat the limit.
/// [`RetryClient`] sleeps the hint and re-sends without reconnecting.
#[derive(Debug)]
pub struct ServerBusy {
    /// The server's hint: how long until a token has refilled.
    pub retry_after: Duration,
    pub message: String,
}

impl fmt::Display for ServerBusy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "server busy (retry after {} ms): {}",
            self.retry_after.as_millis(),
            self.message
        )
    }
}

impl std::error::Error for ServerBusy {}

/// One connection to a serving node. Requests are strictly sequential
/// (send, then wait for the reply); open several clients for concurrency —
/// the server runs one handler thread per connection.
pub struct Client {
    stream: TcpStream,
    /// Declared canonical method spec carried on push/query/snapshot
    /// (empty = declare nothing; the server then skips the check).
    method: String,
    /// Tenant scope (name + token) carried on every scoped request.
    /// Empty = the server's unnamed default tenant, encoded identically
    /// to a pre-v6 client's frames.
    scope: Scope,
    /// When set, every push/query/snapshot carries a fresh trace context
    /// from this generator (`--trace`); the server then records a span
    /// tree retrievable via [`Client::trace`].
    tracer: Option<Box<dyn IdGen>>,
    /// The context the most recent traced request carried.
    last_trace: Option<TraceContext>,
}

impl Client {
    /// Connect to `addr` (`host:port`). Reads time out after five minutes
    /// so a dead server fails the client instead of hanging it (decode of
    /// a realistic sketch is seconds, not minutes).
    pub fn connect(addr: &str) -> Result<Self> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
        stream
            .set_read_timeout(Some(Duration::from_secs(300)))
            .context("set read timeout")?;
        stream.set_nodelay(true).ok();
        Ok(Self {
            stream,
            method: String::new(),
            scope: Scope::default(),
            tracer: None,
            last_trace: None,
        })
    }

    /// Declare the method this client expects the server to sketch with.
    /// Every subsequent push/query/snapshot carries the spec, and the
    /// server refuses the request if its operator's method differs.
    pub fn declare_method(mut self, spec: &str) -> Self {
        self.method = spec.to_string();
        self
    }

    /// Address a named tenant (with its auth token) on a multi-tenant
    /// node. Empty tenant + empty token is the default scope — the
    /// unnamed tenant, no auth.
    pub fn with_scope(mut self, tenant: &str, token: &str) -> Self {
        self.scope = Scope::new(tenant, token);
        self
    }

    /// Trace every subsequent push/query/snapshot: each request carries a
    /// fresh context from `gen` (inject [`crate::obs::SeqIdGen`] in tests
    /// for deterministic ids, [`ProcessIdGen`] in production).
    pub fn with_tracing(mut self, gen: Box<dyn IdGen>) -> Self {
        self.tracer = Some(gen);
        self
    }

    /// The trace id of the most recent traced request — the handle to
    /// fetch its server-side span tree via [`Client::trace`].
    pub fn last_trace_id(&self) -> Option<[u8; 16]> {
        self.last_trace.map(|c| c.trace_id)
    }

    fn next_trace(&mut self) -> Option<TraceContext> {
        let ctx = self.tracer.as_mut().map(|g| g.next_context());
        if ctx.is_some() {
            self.last_trace = ctx;
        }
        ctx
    }

    fn call(&mut self, req: &Request) -> Result<Response> {
        proto::write_request(&mut self.stream, req)?;
        match proto::read_response(&mut self.stream)? {
            Response::Error(msg) => Err(anyhow::Error::new(ServerError(msg))),
            Response::Busy {
                retry_after_ms,
                message,
            } => Err(anyhow::Error::new(ServerBusy {
                retry_after: Duration::from_millis(retry_after_ms),
                message,
            })),
            resp => Ok(resp),
        }
    }

    /// Push a row batch into `shard`. Returns (shard rows, total rows)
    /// accumulated all-time on the server.
    pub fn push(&mut self, shard: &str, batch: &Mat) -> Result<(u64, u64)> {
        let req = Request::Push {
            scope: self.scope.clone(),
            shard: shard.to_string(),
            method: self.method.clone(),
            dim: batch.cols() as u32,
            data: batch.as_slice().to_vec(),
            trace: self.next_trace(),
        };
        match self.call(&req)? {
            Response::PushAck {
                shard_rows,
                total_rows,
            } => Ok((shard_rows, total_rows)),
            other => bail!("unexpected reply to push: {other:?}"),
        }
    }

    /// Forward a pre-pooled `.qsk` delta under the (aggregator id,
    /// instance, sequence) idempotency key. Returns (merged, total rows):
    /// `merged = false` means the server recognized a replay and dropped
    /// it — success, not an error.
    pub fn delta(
        &mut self,
        agg_id: &str,
        instance: u64,
        seq: u64,
        sketch: Vec<u8>,
    ) -> Result<(bool, u64)> {
        let req = Request::Delta {
            scope: self.scope.clone(),
            agg_id: agg_id.to_string(),
            instance,
            seq,
            sketch,
            trace: self.next_trace(),
        };
        match self.call(&req)? {
            Response::DeltaAck { merged, rows_total } => Ok((merged, rows_total)),
            other => bail!("unexpected reply to delta: {other:?}"),
        }
    }

    /// Decode centroids from a window.
    pub fn query(&mut self, spec: &QuerySpec) -> Result<CentroidReport> {
        let req = Request::Query {
            scope: self.scope.clone(),
            spec: spec.clone(),
            method: self.method.clone(),
            trace: self.next_trace(),
        };
        match self.call(&req)? {
            Response::Centroids(report) => Ok(report),
            other => bail!("unexpected reply to query: {other:?}"),
        }
    }

    /// Fetch a window as `.qsk` bytes (write them to a file and they are a
    /// regular sketch file for `qckm merge` / `qckm decode`).
    pub fn snapshot(&mut self, window: u32) -> Result<Vec<u8>> {
        let req = Request::Snapshot {
            scope: self.scope.clone(),
            window,
            method: self.method.clone(),
            trace: self.next_trace(),
        };
        match self.call(&req)? {
            Response::Snapshot(bytes) => Ok(bytes),
            other => bail!("unexpected reply to snapshot: {other:?}"),
        }
    }

    /// Close the open epoch. Returns (new epoch index, rows closed).
    pub fn roll(&mut self) -> Result<(u64, u64)> {
        let req = Request::Roll {
            scope: self.scope.clone(),
        };
        match self.call(&req)? {
            Response::RollAck { epoch, rows_closed } => Ok((epoch, rows_closed)),
            other => bail!("unexpected reply to roll: {other:?}"),
        }
    }

    /// Fetch server counters.
    pub fn stats(&mut self) -> Result<StatsReport> {
        let req = Request::Stats {
            scope: self.scope.clone(),
        };
        match self.call(&req)? {
            Response::Stats(report) => Ok(report),
            other => bail!("unexpected reply to stats: {other:?}"),
        }
    }

    /// Fetch the server's metrics registry as a Prometheus text page.
    pub fn metrics(&mut self) -> Result<String> {
        match self.call(&Request::Metrics)? {
            Response::Metrics(page) => Ok(page),
            other => bail!("unexpected reply to metrics: {other:?}"),
        }
    }

    /// Fetch recent server-side traces as a JSON document — one trace by
    /// id, or the newest `limit` (0 = the server's default).
    pub fn trace(&mut self, id: Option<[u8; 16]>, limit: u32) -> Result<String> {
        let req = Request::Trace {
            scope: self.scope.clone(),
            id,
            limit,
        };
        match self.call(&req)? {
            Response::Traces(json) => Ok(json),
            other => bail!("unexpected reply to trace: {other:?}"),
        }
    }

    /// Ask the server to stop (acked before it exits).
    pub fn shutdown(&mut self) -> Result<()> {
        match self.call(&Request::Shutdown)? {
            Response::ShutdownAck => Ok(()),
            other => bail!("unexpected reply to shutdown: {other:?}"),
        }
    }
}

// ------------------------------------------------------------------- retry

/// Bounded exponential backoff for [`RetryClient`]: delay
/// `min(base · 2^attempt, cap)` between attempts, at most `attempts`
/// retries after the first failure. No jitter — reconnect timing stays
/// deterministic like everything else in this crate.
#[derive(Clone, Debug)]
pub struct RetryPolicy {
    /// Retries after the first failure (0 = fail fast, the legacy
    /// behavior).
    pub attempts: u32,
    /// First backoff delay.
    pub base: Duration,
    /// Delay ceiling.
    pub cap: Duration,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        Self {
            attempts: 0,
            base: Duration::from_millis(100),
            cap: Duration::from_secs(2),
        }
    }
}

impl RetryPolicy {
    /// The delay before retry number `attempt` (0-based).
    pub fn delay(&self, attempt: u32) -> Duration {
        let mult = 1u32.checked_shl(attempt.min(31)).unwrap_or(u32::MAX);
        self.base.saturating_mul(mult).min(self.cap)
    }
}

/// A reconnecting wrapper over [`Client`] for the ingest path: on a
/// transport error (connection refused, reset, timeout) it drops the
/// connection, sleeps per the [`RetryPolicy`]'s bounded exponential
/// backoff, reconnects, and re-sends the failed request — so `qckm push`
/// survives a server kill-and-restart mid-stream.
///
/// Semantics are **at-least-once**: if the failure hit after the server
/// merged a batch but before the ack arrived, the re-send double-counts
/// that batch. Application-level refusals ([`ServerError`], e.g. a method
/// mismatch) fail immediately — the server processed and rejected the
/// request, so retrying is useless. [`ServerBusy`] (rate-limited) is the
/// third case: retried after sleeping the server's hint, *keeping* the
/// connection — the rate bucket is per connection and a reconnect would
/// reset it.
pub struct RetryClient {
    addr: String,
    method: String,
    scope: Scope,
    policy: RetryPolicy,
    /// When true, every (re)connected inner client traces its requests
    /// through a fresh [`ProcessIdGen`] (each retry attempt is a
    /// distinct trace — causality stays per-wire-request).
    tracing: bool,
    inner: Option<Client>,
    /// Reconnect attempts made over this client's lifetime (also counted
    /// in the global registry as `qckm_retry_attempts_total`).
    attempts_total: u64,
    /// Total backoff slept (also `qckm_retry_backoff_ms_total`).
    backoff_total: Duration,
}

impl RetryClient {
    /// Connect to `addr`, retrying the initial connect under the same
    /// policy — a pusher may come up before its server does. `method` is
    /// the declared method spec (empty = declare nothing).
    pub fn connect(addr: &str, method: &str, policy: RetryPolicy) -> Result<RetryClient> {
        let mut rc = RetryClient {
            addr: addr.to_string(),
            method: method.to_string(),
            scope: Scope::default(),
            policy,
            tracing: false,
            inner: None,
            attempts_total: 0,
            backoff_total: Duration::ZERO,
        };
        rc.with_retry(|_| Ok(()))?;
        Ok(rc)
    }

    /// Address a named tenant (see [`Client::with_scope`]). Applies to
    /// the current connection and every reconnect.
    pub fn set_scope(&mut self, tenant: &str, token: &str) {
        self.scope = Scope::new(tenant, token);
        if let Some(c) = self.inner.take() {
            self.inner = Some(c.with_scope(tenant, token));
        }
    }

    /// Trace every subsequent push (`qckm push --trace`). Applies to the
    /// current connection and every reconnect.
    pub fn enable_tracing(&mut self) {
        self.tracing = true;
        if let Some(c) = self.inner.take() {
            self.inner = Some(c.with_tracing(Box::new(ProcessIdGen::new())));
        }
    }

    /// The trace id of the most recent traced request, if any.
    pub fn last_trace_id(&self) -> Option<[u8; 16]> {
        self.inner.as_ref().and_then(|c| c.last_trace_id())
    }

    /// Fetch one server-side trace by id (see [`Client::trace`]),
    /// retrying transport errors like any other request.
    pub fn trace(&mut self, id: Option<[u8; 16]>, limit: u32) -> Result<String> {
        self.with_retry(|c| c.trace(id, limit))
    }

    /// Retry counters for this client: (reconnect attempts, total backoff
    /// slept). Zero attempts means no transport failure ever occurred —
    /// the summary `qckm push` prints on exit.
    pub fn retry_stats(&self) -> (u64, Duration) {
        (self.attempts_total, self.backoff_total)
    }

    fn client(&mut self) -> Result<&mut Client> {
        if self.inner.is_none() {
            let mut c = Client::connect(&self.addr)?;
            if !self.method.is_empty() {
                c = c.declare_method(&self.method);
            }
            if !self.scope.is_empty() {
                c = c.with_scope(&self.scope.tenant, &self.scope.token);
            }
            if self.tracing {
                c = c.with_tracing(Box::new(ProcessIdGen::new()));
            }
            self.inner = Some(c);
        }
        Ok(self.inner.as_mut().unwrap())
    }

    /// Run `op` against a (re)connected client, retrying transport errors
    /// (reconnecting) and busy refusals (sleeping the server's hint on
    /// the same connection) per the policy.
    fn with_retry<T>(&mut self, op: impl Fn(&mut Client) -> Result<T>) -> Result<T> {
        let mut attempt = 0u32;
        loop {
            match self.client().and_then(&op) {
                Ok(v) => return Ok(v),
                Err(e) => {
                    let busy_hint = e.downcast_ref::<ServerBusy>().map(|b| b.retry_after);
                    if busy_hint.is_none() {
                        // The connection may be mid-frame or half-dead:
                        // never reuse it after a non-busy failure. (A busy
                        // refusal left the connection healthy — the frame
                        // was consumed, the reply read — and the rate
                        // bucket it drew from is per connection.)
                        self.inner = None;
                    }
                    let fatal = busy_hint.is_none() && e.downcast_ref::<ServerError>().is_some();
                    if fatal || attempt >= self.policy.attempts {
                        return Err(e).with_context(|| {
                            format!("giving up on {} after {} attempt(s)", self.addr, attempt + 1)
                        });
                    }
                    let delay = match busy_hint {
                        // Honor the server's hint (one token's refill
                        // time), bounded by the policy's ceiling.
                        Some(hint) => hint.min(self.policy.cap).max(Duration::from_millis(1)),
                        None => self.policy.delay(attempt),
                    };
                    attempt += 1;
                    self.attempts_total += 1;
                    self.backoff_total += delay;
                    let m = crate::obs::lib_metrics();
                    m.retry_attempts.inc();
                    m.retry_backoff_ms.add(delay.as_millis().min(u64::MAX as u128) as u64);
                    if log::enabled(Level::Warn) {
                        log::event(
                            Level::Warn,
                            "retry",
                            &[
                                ("addr", Value::Str(&self.addr)),
                                ("attempt", Value::U64(attempt as u64)),
                                ("backoff_ms", Value::U64(delay.as_millis() as u64)),
                                ("error", Value::Str(&format!("{e:#}"))),
                            ],
                        );
                    }
                    eprintln!(
                        "push: {e:#}; retrying in {delay:?} (attempt {attempt}/{})",
                        self.policy.attempts
                    );
                    std::thread::sleep(delay);
                }
            }
        }
    }

    /// [`Client::push`] with reconnect-and-resend on transport errors.
    pub fn push(&mut self, shard: &str, batch: &Mat) -> Result<(u64, u64)> {
        self.with_retry(|c| c.push(shard, batch))
    }

    /// [`Client::delta`] with reconnect-and-resend. Safe to re-send
    /// blind: the (agg_id, instance, seq) key makes the merge idempotent
    /// — a replay of an already-merged delta acks `merged = false`.
    pub fn delta(
        &mut self,
        agg_id: &str,
        instance: u64,
        seq: u64,
        sketch: &[u8],
    ) -> Result<(bool, u64)> {
        self.with_retry(|c| c.delta(agg_id, instance, seq, sketch.to_vec()))
    }
}
