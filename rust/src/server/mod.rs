//! The online sketch service — `qckm serve`.
//!
//! The pooled sketch is a tiny, linear, mergeable sufficient statistic, so
//! the natural server-side state for a *live* clustering service is the
//! sketch itself: ingest point batches forever, keep (sum, count) pairs,
//! and decode centroids on demand. This module turns the batch pipeline
//! (`qckm sketch` → `merge` → `decode`) into an always-on TCP service:
//!
//! * [`proto`] — a dependency-free length-prefixed binary protocol
//!   (push / query / snapshot / roll / stats / metrics / trace /
//!   shutdown) over TCP; `metrics` returns the node's Prometheus
//!   exposition page (see [`crate::obs`]), `trace` returns recent
//!   per-request span trees as JSON (see [`crate::obs::trace`]). Since
//!   v5, push/query/snapshot can carry an optional client-generated
//!   trace context; v4 clients are still decoded and answered at v4.
//!   Since v6, frames can carry a *scope* (tenant name + auth token), a
//!   `delta` verb merges pre-pooled `.qsk` payloads idempotently, and a
//!   `busy` status carries a retry-after hint.
//! * [`tenants`] — the multi-tenant [`Node`]: several named
//!   [`SketchService`]s behind one listener, each its own operator draw
//!   and state, with constant-time token auth and per-connection
//!   token-bucket ingest rate limits. `crate::fanin` builds the fan-in
//!   aggregator tier on the same frame-handler machinery.
//! * [`SketchService`] — the shared server state: one accumulator per
//!   *shard* (the client-chosen partition label), a ring of per-epoch
//!   windows so queries can ask for "the last E epochs" as well as
//!   all-time, and a centroid cache keyed by the exact pooled bits so
//!   repeated queries against an unchanged sketch never re-decode.
//! * [`serve`] — the accept loop: one handler thread per connection,
//!   encode via [`SketchOperator::sketch_into_par`] outside the state
//!   lock, cooperative shutdown with bounded timeouts (CI can never hang).
//! * [`Client`] — the blocking client used by `qckm push` / `qckm query` /
//!   `qckm snapshot` / `qckm ctl`; [`RetryClient`] wraps it with
//!   reconnect-and-resend under bounded exponential backoff so
//!   `qckm push --retry N` survives a server kill-and-restart.
//!
//! ## Determinism
//!
//! The serving node preserves the repo-wide reproducibility contract the
//! same way the offline stages do: shard accumulators are merged in stable
//! shard-key order (and epochs in chronological order) at query/snapshot
//! time, each push batch is encoded through the fixed-chunk parallel
//! encode, and the decoder is seeded from the operator seed by default.
//! For the 1-bit quantized method every contribution is an exact small
//! integer, so the pooled sums — and therefore the decoded centroids —
//! are bit-for-bit identical to the offline `sketch → merge → decode`
//! pipeline on the same rows, no matter how pushes interleave across
//! connections (`rust/tests/server_e2e.rs` locks this in).
//!
//! ## Snapshots
//!
//! [`SketchService::snapshot`] serializes the merged window in the exact
//! `.qsk` format (fingerprint-checked, checksummed, with per-shard
//! provenance records), so a serving node can be seeded from — and drained
//! back into — the offline pipeline: `qckm snapshot` then `qckm decode`,
//! or `qckm serve --seed-sketch old.qsk` to resume.
//!
//! [`SketchOperator::sketch_into_par`]: crate::sketch::SketchOperator::sketch_into_par

pub mod client;
pub mod proto;
mod service;
mod state;
pub mod tenants;

pub(crate) use service::{encode_reply, reply_version, serve_handler, ConnCtx, FrameHandler, Handled};

pub use client::{Client, RetryClient, RetryPolicy, ServerBusy, ServerError};
pub use proto::{CentroidReport, QuerySpec, Request, Response, Scope, StatsReport};
pub use service::{serve, serve_node};
pub use state::{ServiceConfig, SketchService, WindowPool};
pub use tenants::{Node, RateLimit};

#[cfg(test)]
mod tests;
