//! Multi-tenant registry: one serving node hosting several named
//! sketches, plus the ingest guards in front of them.
//!
//! A *tenant* is a named [`SketchService`] — its own operator draw
//! (method, m, d, sigma, seed), its own default decoder, its own
//! shard/epoch state, centroid cache, and trace ring. Proto-v6 frames
//! address a tenant through the scope block ([`Scope`]); pre-v6 frames
//! carry no scope and route to the unnamed default tenant, so a
//! single-tenant node serves old clients byte-identically.
//!
//! The [`Node`] is the router the accept loop hands every frame to:
//!
//! 1. **Rate limit** — ingest frames (push/delta) draw from a
//!    per-connection [`TokenBucket`]; an empty bucket answers
//!    [`Response::Busy`] with a retry-after hint *before* the frame is
//!    decoded, so shedding load costs two byte reads, not a parse.
//! 2. **Route** — the scope's tenant name picks the service
//!    ([`proto::peek_scope`] reads just the scope block; the chosen
//!    service then decodes the frame exactly once).
//! 3. **Authorize** — the routed service compares the presented token in
//!    constant time ([`constant_time_eq`]) and counts failures under
//!    `qckm_auth_failures_total{tenant}`.
//!
//! Tenant names are validated at declaration time ([`validate_tenant_name`]):
//! short, `[A-Za-z0-9_.-]`, so the `tenant` metric label stays bounded
//! and clean. Unknown names requested over the wire are *not* echoed
//! into labels — they count under a single `(unknown)` bucket.

use super::proto::{self, Response, Scope};
use super::service::{handle_payload, ConnCtx, FrameHandler, Handled};
use super::state::SketchService;
use crate::obs::{Clock, Counter, Registry};
use anyhow::{bail, Result};
use std::collections::BTreeMap;
use std::sync::Arc;

/// Compare two byte strings in time independent of where they differ.
/// Early-exit comparison (`==` on byte slices) returns as soon as a byte
/// mismatches, so response timing reveals how long a correct prefix an
/// attacker has guessed; folding every byte through XOR-OR reveals only
/// the lengths, which are already public (the wire carries them).
pub fn constant_time_eq(a: &[u8], b: &[u8]) -> bool {
    if a.len() != b.len() {
        return false;
    }
    let mut acc = 0u8;
    for (&x, &y) in a.iter().zip(b.iter()) {
        acc |= x ^ y;
    }
    acc == 0
}

/// A tenant name fit for wire routing and the bounded `tenant` metric
/// label: 1..=64 bytes of `[A-Za-z0-9_.-]`.
pub fn validate_tenant_name(name: &str) -> Result<()> {
    if name.is_empty() || name.len() > proto::MAX_TENANT_BYTES {
        bail!("tenant name must be 1..={} bytes", proto::MAX_TENANT_BYTES);
    }
    if !name
        .bytes()
        .all(|b| b.is_ascii_alphanumeric() || b == b'_' || b == b'.' || b == b'-')
    {
        bail!("tenant name '{name}' may only contain [A-Za-z0-9_.-]");
    }
    Ok(())
}

// -------------------------------------------------------------- rate limit

/// Per-connection ingest rate limit: a classic token bucket holding up
/// to `burst` tokens, refilled at `rate` tokens/second. Each push/delta
/// frame costs one token.
#[derive(Clone, Copy, Debug)]
pub struct RateLimit {
    /// Sustained ingest frames per second per connection.
    pub rate: f64,
    /// Burst capacity (frames admitted back-to-back from a full bucket).
    pub burst: f64,
}

impl RateLimit {
    /// Parse `RATE` or `RATE:BURST` (e.g. `100` or `100:25`).
    pub fn parse(s: &str) -> Result<Self> {
        let (rate_s, burst_s) = match s.split_once(':') {
            Some((r, b)) => (r, Some(b)),
            None => (s, None),
        };
        let rate: f64 = rate_s
            .parse()
            .map_err(|_| anyhow::anyhow!("rate limit: cannot parse rate '{rate_s}'"))?;
        if !(rate > 0.0) || !rate.is_finite() {
            bail!("rate limit: rate must be a positive number (got '{rate_s}')");
        }
        let burst: f64 = match burst_s {
            Some(b) => b
                .parse()
                .map_err(|_| anyhow::anyhow!("rate limit: cannot parse burst '{b}'"))?,
            None => rate.max(1.0),
        };
        if !(burst >= 1.0) || !burst.is_finite() {
            bail!("rate limit: burst must be >= 1");
        }
        Ok(Self { rate, burst })
    }
}

/// The refillable bucket itself. Time comes from the registry clock, so
/// tests drive it deterministically with a `FakeClock`.
#[derive(Debug)]
pub struct TokenBucket {
    capacity: f64,
    tokens: f64,
    rate: f64,
    last_ns: u64,
}

impl TokenBucket {
    /// A full bucket as of `now_ns`.
    pub fn new(limit: RateLimit, now_ns: u64) -> Self {
        Self {
            capacity: limit.burst,
            tokens: limit.burst,
            rate: limit.rate,
            last_ns: now_ns,
        }
    }

    /// Take one token at `now_ns`. On refusal returns the retry-after
    /// hint in milliseconds — the time until the bucket has refilled a
    /// whole token, which is exactly what [`Response::Busy`] carries and
    /// the retrying client sleeps on.
    pub fn try_take(&mut self, now_ns: u64) -> std::result::Result<(), u64> {
        let elapsed = now_ns.saturating_sub(self.last_ns) as f64 * 1e-9;
        self.last_ns = now_ns;
        self.tokens = (self.tokens + elapsed * self.rate).min(self.capacity);
        if self.tokens >= 1.0 {
            self.tokens -= 1.0;
            return Ok(());
        }
        let deficit = 1.0 - self.tokens;
        let ms = (deficit / self.rate * 1000.0).ceil().max(1.0);
        Err(ms as u64)
    }
}

// -------------------------------------------------------------------- node

/// The multi-tenant router the accept loop serves. Also the
/// single-tenant path: [`Node::single`] wraps one service under the
/// empty (default) name with no rate limit, reproducing the pre-v6
/// server exactly.
pub struct Node {
    /// Tenants by name. The empty key is the unnamed default tenant —
    /// where pre-v6 frames and empty scopes route.
    tenants: BTreeMap<String, Arc<SketchService>>,
    rate: Option<RateLimit>,
    registry: Arc<Registry>,
    clock: Arc<dyn Clock>,
    /// `qckm_rate_limited_total` — registered only when a rate limit is
    /// configured, so unlimited nodes keep their exposition pages.
    rate_limited: Option<Arc<Counter>>,
}

impl Node {
    /// A node hosting `tenants` (keys already validated; the empty key,
    /// when present, is the default tenant) with an optional ingest rate
    /// limit. All tenants must share `registry` — the node refreshes
    /// every tenant's gauges and renders the registry once per scrape.
    pub fn new(
        tenants: BTreeMap<String, Arc<SketchService>>,
        rate: Option<RateLimit>,
        registry: Arc<Registry>,
    ) -> Result<Self> {
        if tenants.is_empty() {
            bail!("a node needs at least one tenant");
        }
        for name in tenants.keys() {
            if !name.is_empty() {
                validate_tenant_name(name)?;
            }
        }
        let rate_limited = rate.map(|_| {
            registry.counter(
                "qckm_rate_limited_total",
                "Ingest frames shed by the per-connection token bucket.",
                &[],
            )
        });
        let clock = registry.clock();
        Ok(Self {
            tenants,
            rate,
            registry,
            clock,
            rate_limited,
        })
    }

    /// The legacy single-tenant node: one unnamed service, no rate limit.
    pub fn single(service: Arc<SketchService>) -> Self {
        let registry = service.registry().clone();
        let clock = registry.clock();
        let mut tenants = BTreeMap::new();
        tenants.insert(String::new(), service);
        Self {
            tenants,
            rate: None,
            registry,
            clock,
            rate_limited: None,
        }
    }

    /// The tenant a scope addresses: its name, or the default tenant for
    /// an empty name.
    pub fn resolve(&self, tenant: &str) -> Result<&Arc<SketchService>> {
        match self.tenants.get(tenant) {
            Some(svc) => Ok(svc),
            None if tenant.is_empty() => bail!(
                "this server hosts only named tenants ({}); address one with --tenant",
                self.tenant_names()
            ),
            None => {
                // Count under a single bucket — echoing attacker-chosen
                // names into metric labels would unbound the cardinality.
                self.registry
                    .counter(
                        "qckm_auth_failures_total",
                        "Scoped requests refused for a bad or missing token, by tenant.",
                        &[("tenant", "(unknown)")],
                    )
                    .inc();
                bail!(
                    "unknown tenant '{tenant}' (this server hosts: {})",
                    self.tenant_names()
                )
            }
        }
    }

    fn tenant_names(&self) -> String {
        self.tenants
            .keys()
            .map(|n| if n.is_empty() { "(default)" } else { n.as_str() })
            .collect::<Vec<_>>()
            .join(", ")
    }

    /// Every tenant's occupancy, in stable name order — the `tenants`
    /// block of a v6 stats report.
    pub fn occupancy(&self) -> Vec<(String, u64, u64)> {
        self.tenants
            .iter()
            .map(|(name, svc)| {
                let (rows, shards) = svc.occupancy();
                (name.clone(), rows, shards)
            })
            .collect()
    }

    fn multi(&self) -> bool {
        self.tenants.len() > 1
    }

    /// The service that handles unscoped, server-wide verbs (metrics):
    /// the default tenant if present, else the first by name.
    fn any_service(&self) -> &Arc<SketchService> {
        self.tenants
            .get("")
            .unwrap_or_else(|| self.tenants.values().next().expect("node has tenants"))
    }

    /// Server-wide metrics page: refresh every tenant's scrape-time
    /// gauges, then render the shared registry once.
    fn render_metrics_all(&self) -> String {
        for svc in self.tenants.values() {
            svc.refresh_gauges();
        }
        self.registry.render()
    }

    /// A multi-tenant stats request: answer from the addressed tenant,
    /// then attach the per-tenant occupancy block covering the node.
    fn stats_all(&self, payload: &[u8]) -> Handled {
        let version = super::service::reply_version(payload);
        let resp = (|| -> Result<Response> {
            let (_, req) = proto::decode_request_v(payload)?;
            let scope = req.scope().cloned().unwrap_or_default();
            let svc = self.resolve(&scope.tenant)?;
            let _span = svc.request_span("stats");
            svc.authorize(&scope)?;
            let mut report = svc.stats();
            report.tenants = self.occupancy();
            Ok(Response::Stats(report))
        })()
        .unwrap_or_else(|e| Response::Error(format!("{e:#}")));
        Handled::Reply(super::service::encode_reply(&resp, version))
    }
}

impl FrameHandler for Node {
    fn new_conn(&self) -> ConnCtx {
        ConnCtx {
            bucket: self
                .rate
                .map(|limit| TokenBucket::new(limit, self.clock.now_ns())),
        }
    }

    fn handle(&self, conn: &mut ConnCtx, payload: &[u8]) -> Handled {
        // 1. Rate limit ingest frames before decoding anything.
        if proto::payload_is_ingest(payload) {
            if let Some(bucket) = conn.bucket.as_mut() {
                if let Err(retry_after_ms) = bucket.try_take(self.clock.now_ns()) {
                    if let Some(c) = &self.rate_limited {
                        c.inc();
                    }
                    let resp = Response::Busy {
                        retry_after_ms,
                        message: "per-connection ingest rate limit".to_string(),
                    };
                    return Handled::Reply(super::service::encode_reply(
                        &resp,
                        super::service::reply_version(payload),
                    ));
                }
            }
        }
        // 2. Server-wide verbs a multi-tenant node must answer itself.
        if self.multi() {
            match proto::payload_tag(payload) {
                Some(proto::TAG_METRICS) => {
                    let svc = self.any_service();
                    let _span = svc.request_span("metrics");
                    let resp = Response::Metrics(self.render_metrics_all());
                    return Handled::Reply(super::service::encode_reply(
                        &resp,
                        super::service::reply_version(payload),
                    ));
                }
                Some(proto::TAG_STATS) => return self.stats_all(payload),
                _ => {}
            }
        }
        // 3. Route on the peeked scope; the routed service decodes once.
        // Unscoped verbs (metrics, shutdown) and frames with no readable
        // tag are node-wide: any service answers them — shutdown must
        // work even when no unnamed default tenant exists, and a garbage
        // frame should earn the decoder's error message, not a routing
        // complaint.
        let routed = match proto::payload_tag(payload) {
            Some(
                proto::TAG_PUSH
                | proto::TAG_QUERY
                | proto::TAG_SNAPSHOT
                | proto::TAG_ROLL
                | proto::TAG_STATS
                | proto::TAG_TRACE
                | proto::TAG_DELTA,
            ) => self.resolve(&proto::peek_scope(payload).tenant),
            _ => Ok(self.any_service()),
        };
        match routed {
            Ok(svc) => handle_payload(svc, payload),
            Err(e) => Handled::Reply(super::service::encode_reply(
                &Response::Error(format!("{e:#}")),
                super::service::reply_version(payload),
            )),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_time_eq_matches_slice_equality() {
        let cases: [(&[u8], &[u8]); 7] = [
            (b"", b""),
            (b"a", b"a"),
            (b"a", b"b"),
            (b"secret-token", b"secret-token"),
            (b"secret-token", b"secret-tokeN"),
            (b"secret-token", b"Xecret-token"),
            (b"short", b"longer-than-short"),
        ];
        for (a, b) in cases {
            assert_eq!(constant_time_eq(a, b), a == b, "{a:?} vs {b:?}");
        }
    }

    #[test]
    fn constant_time_eq_has_no_early_exit() {
        // Structural check: equal-length inputs always fold every byte.
        // (A timing assertion would be flaky in CI; instead this pins the
        // XOR-OR fold by checking mismatches at every position are all
        // detected — an early-exit bug cannot pass position-sensitivity
        // plus the all-positions sweep.)
        let a = b"0123456789abcdef";
        for i in 0..a.len() {
            let mut b = *a;
            b[i] ^= 0x20;
            assert!(!constant_time_eq(a, &b), "flip at {i} must be detected");
        }
        assert!(constant_time_eq(a, a));
    }

    #[test]
    fn token_bucket_refills_at_rate_and_hints_retry() {
        let limit = RateLimit { rate: 10.0, burst: 2.0 };
        let mut b = TokenBucket::new(limit, 0);
        // Burst of 2 admits two back-to-back frames.
        assert!(b.try_take(0).is_ok());
        assert!(b.try_take(0).is_ok());
        // Empty: the hint is one token's refill time (100ms at 10/s).
        let ms = b.try_take(0).unwrap_err();
        assert_eq!(ms, 100);
        // 50ms later: still short, hint shrinks accordingly.
        let ms = b.try_take(50_000_000).unwrap_err();
        assert!(ms <= 51, "hint was {ms}ms");
        // After a full refill interval the take succeeds again.
        assert!(b.try_take(200_000_000).is_ok());
    }

    #[test]
    fn rate_limit_parses_rate_and_burst() {
        let r = RateLimit::parse("100").unwrap();
        assert_eq!(r.rate, 100.0);
        assert_eq!(r.burst, 100.0);
        let r = RateLimit::parse("50:5").unwrap();
        assert_eq!(r.rate, 50.0);
        assert_eq!(r.burst, 5.0);
        assert!(RateLimit::parse("0").is_err());
        assert!(RateLimit::parse("-1").is_err());
        assert!(RateLimit::parse("10:0.5").is_err());
        assert!(RateLimit::parse("junk").is_err());
    }

    #[test]
    fn tenant_names_validate() {
        assert!(validate_tenant_name("sensors-eu.prod_1").is_ok());
        assert!(validate_tenant_name("").is_err());
        assert!(validate_tenant_name("has space").is_err());
        assert!(validate_tenant_name("bad/slash").is_err());
        assert!(validate_tenant_name(&"x".repeat(65)).is_err());
    }
}
