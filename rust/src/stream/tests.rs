//! Unit tests for the streaming sketch subsystem: reader parity with the
//! eager loaders, bit-for-bit streamed == in-memory sketches across thread
//! counts and encodings, `.qsk` round-trips, and corruption/mismatch
//! rejection.

use super::*;
use crate::coordinator::WireFormat;
use crate::method::MethodSpec;
use crate::data::{save_csv, save_f64_bin};
use crate::frequency::FrequencyLaw;
use crate::linalg::Mat;
use crate::parallel::Parallelism;
use crate::rng::Rng;
use crate::sketch::{PooledSketch, PAR_CHUNK_ROWS};
use std::path::PathBuf;

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("qckm_stream_{tag}"));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn random_mat(rows: usize, cols: usize, seed: u64) -> Mat {
    let mut rng = Rng::new(seed);
    Mat::from_fn(rows, cols, |_, _| rng.gaussian())
}

// ------------------------------------------------------------------ readers

#[test]
fn csv_reader_streams_same_values_as_eager_loader() {
    let dir = temp_dir("csv_parity");
    let path = dir.join("data.csv");
    let x = random_mat(257, 3, 1);
    save_csv(&path, &x).unwrap();

    let mut reader = CsvChunkedReader::open(&path).unwrap();
    assert_eq!(reader.dim(), 3);
    // Odd block size so block boundaries never align with row batches.
    let mut streamed = Vec::new();
    loop {
        if reader.next_block(13, &mut streamed).unwrap() == 0 {
            break;
        }
    }
    let eager = crate::data::load_csv(&path).unwrap();
    assert_eq!(streamed, eager.as_slice());
    assert_eq!(eager.as_slice(), x.as_slice(), "CSV round-trip is exact");
}

#[test]
fn csv_reader_skips_comments_and_rejects_ragged_rows() {
    let dir = temp_dir("csv_errors");
    let ok = dir.join("commented.csv");
    std::fs::write(&ok, "# header\n1,2\n\n3,4\n").unwrap();
    let mut reader = CsvChunkedReader::open(&ok).unwrap();
    let mut out = Vec::new();
    assert_eq!(reader.next_block(100, &mut out).unwrap(), 2);
    assert_eq!(out, vec![1.0, 2.0, 3.0, 4.0]);

    let ragged = dir.join("ragged.csv");
    std::fs::write(&ragged, "1,2\n3\n").unwrap();
    let mut reader = CsvChunkedReader::open(&ragged).unwrap();
    let mut out = Vec::new();
    assert!(reader.next_block(100, &mut out).is_err());

    let empty = dir.join("empty.csv");
    std::fs::write(&empty, "# nothing\n\n").unwrap();
    assert!(CsvChunkedReader::open(&empty).is_err());
}

#[test]
fn raw_reader_streams_same_values_and_rejects_truncation() {
    let dir = temp_dir("raw_parity");
    let path = dir.join("data.bin");
    let x = random_mat(101, 4, 2);
    save_f64_bin(&path, &x).unwrap();

    let mut reader = RawF64ChunkedReader::open(&path).unwrap();
    assert_eq!(reader.dim(), 4);
    assert_eq!(reader.rows_total(), 101);
    let mut streamed = Vec::new();
    loop {
        if reader.next_block(7, &mut streamed).unwrap() == 0 {
            break;
        }
    }
    assert_eq!(streamed, x.as_slice());

    // Truncate mid-payload: reading must fail with an error, not garbage.
    let bytes = std::fs::read(&path).unwrap();
    let trunc = dir.join("trunc.bin");
    std::fs::write(&trunc, &bytes[..bytes.len() - 5]).unwrap();
    let mut reader = RawF64ChunkedReader::open(&trunc).unwrap();
    let mut out = Vec::new();
    assert!(reader.next_block(usize::MAX, &mut out).is_err());
}

/// The windowed positional reader is interchangeable with the buffered one:
/// same dim/rows_total, same values at every (odd) block size, even when the
/// two readers are driven with different block schedules.
#[test]
fn mapped_reader_streams_same_values_as_buffered_reader() {
    let dir = temp_dir("mmap_parity");
    let path = dir.join("data.bin");
    let x = random_mat(101, 4, 20);
    save_f64_bin(&path, &x).unwrap();

    let mut mapped = MappedF64ChunkedReader::open(&path).unwrap();
    let mut buffered = RawF64ChunkedReader::open(&path).unwrap();
    assert_eq!(mapped.dim(), buffered.dim());
    assert_eq!(mapped.rows_total(), buffered.rows_total());

    let (mut from_mapped, mut from_buffered) = (Vec::new(), Vec::new());
    loop {
        // Coprime block sizes: block boundaries never coincide.
        let a = mapped.next_block(7, &mut from_mapped).unwrap();
        while from_buffered.len() < from_mapped.len() {
            assert_ne!(buffered.next_block(13, &mut from_buffered).unwrap(), 0);
        }
        if a == 0 {
            break;
        }
    }
    assert_eq!(from_mapped, x.as_slice());
    assert_eq!(from_buffered, x.as_slice());
}

/// Failure parity: both raw-f64 readers report the identical error for a
/// truncated header, an implausible column count, and a payload truncated
/// mid-row — the readers must be interchangeable in failure too.
#[test]
fn mapped_reader_fails_exactly_like_buffered_reader() {
    let dir = temp_dir("mmap_errors");
    let x = random_mat(31, 3, 21);
    let good = dir.join("good.bin");
    save_f64_bin(&good, &x).unwrap();
    let bytes = std::fs::read(&good).unwrap();

    // Truncated header (10 of 16 bytes).
    let p = dir.join("short_header.bin");
    std::fs::write(&p, &bytes[..10]).unwrap();
    let a = format!("{:#}", MappedF64ChunkedReader::open(&p).unwrap_err());
    let b = format!("{:#}", RawF64ChunkedReader::open(&p).unwrap_err());
    assert_eq!(a, b, "header-truncation errors must match");
    assert!(a.contains("truncated header"), "{a}");

    // Implausible column count (cols = 0).
    let mut bad = bytes.clone();
    bad[8..16].copy_from_slice(&0u64.to_le_bytes());
    let p = dir.join("zero_cols.bin");
    std::fs::write(&p, &bad).unwrap();
    let a = format!("{:#}", MappedF64ChunkedReader::open(&p).unwrap_err());
    let b = format!("{:#}", RawF64ChunkedReader::open(&p).unwrap_err());
    assert_eq!(a, b, "implausible-cols errors must match");
    assert!(a.contains("implausible column count 0"), "{a}");

    // Payload truncated mid-row: same row-range context from both.
    let p = dir.join("trunc.bin");
    std::fs::write(&p, &bytes[..bytes.len() - 5]).unwrap();
    let mut mapped = MappedF64ChunkedReader::open(&p).unwrap();
    let mut buffered = RawF64ChunkedReader::open(&p).unwrap();
    let mut out = Vec::new();
    let a = format!("{:#}", mapped.next_block(usize::MAX, &mut out).unwrap_err());
    out.clear();
    let b = format!("{:#}", buffered.next_block(usize::MAX, &mut out).unwrap_err());
    assert_eq!(a, b, "truncation errors must match");
    assert!(a.contains("truncated in rows"), "{a}");
}

/// `open_dataset_with(.., mmap = true)` routes raw files to the windowed
/// reader and refuses CSV; the streamed sketch through it is bit-for-bit
/// the in-memory sketch (so `qckm sketch --mmap` changes nothing but I/O).
#[test]
fn open_dataset_with_mmap_dispatch_and_sketch_parity() {
    let dir = temp_dir("mmap_dispatch");
    let x = random_mat(777, 5, 22);
    let bin = dir.join("data.bin");
    save_f64_bin(&bin, &x).unwrap();
    let csv = dir.join("data.csv");
    save_csv(&csv, &x).unwrap();

    let err = format!("{:#}", open_dataset_with(&csv, true).unwrap_err());
    assert!(err.contains("--mmap requires the raw f64 dataset format"), "{err}");

    let op = quantized_op(5, 24, 23);
    let par = Parallelism::fixed(2);
    let want = op.sketch_dataset_par(&x, &par);
    for mmap in [false, true] {
        let mut reader = open_dataset_with(&bin, mmap).unwrap();
        let mut pool = PooledSketch::new(op.sketch_len());
        let rows =
            sketch_reader(&op, reader.as_mut(), WireFormat::DenseF64, &mut pool, &par).unwrap();
        assert_eq!(rows, 777);
        assert_eq!(pool.mean(), want, "mmap = {mmap}");
    }
}

#[test]
fn mat_reader_and_read_all_round_trip() {
    let x = random_mat(97, 5, 3);
    let mut reader = MatChunkedReader::new(&x);
    let back = read_all(&mut reader).unwrap();
    assert_eq!(back.shape(), x.shape());
    assert_eq!(back.as_slice(), x.as_slice());
}

// ---------------------------------------------------- streamed == in-memory

fn spec(s: &str) -> MethodSpec {
    MethodSpec::parse(s).unwrap()
}

fn quantized_op(n: usize, m: usize, seed: u64) -> crate::sketch::SketchOperator {
    draw_operator(&spec("qckm"), FrequencyLaw::AdaptedRadius, m, n, 1.0, seed)
}

fn cosine_op(n: usize, m: usize, seed: u64) -> crate::sketch::SketchOperator {
    draw_operator(&spec("ckm"), FrequencyLaw::AdaptedRadius, m, n, 1.0, seed)
}

/// The acceptance bar: streamed sketching of a multi-chunk dataset is
/// bit-for-bit `sketch_dataset_par` on the in-memory copy, across thread
/// counts {1, 2, 7}, for both the dense-f64 and packed-bit encodings.
#[test]
fn streamed_sketch_is_bitwise_equal_to_in_memory() {
    let n = 5;
    let rows = 2 * PAR_CHUNK_ROWS + 333; // several chunks + a ragged tail
    let x = random_mat(rows, n, 4);
    let cases: [(crate::sketch::SketchOperator, WireFormat); 3] = [
        (quantized_op(n, 33, 5), WireFormat::DenseF64),
        (quantized_op(n, 33, 5), WireFormat::PackedBits),
        (cosine_op(n, 33, 5), WireFormat::DenseF64),
    ];
    for (op, wire) in &cases {
        for threads in [1usize, 2, 7] {
            let par = Parallelism::fixed(threads);
            let want = op.sketch_dataset_par(&x, &par);
            let mut pool = PooledSketch::new(op.sketch_len());
            let pooled =
                sketch_reader(op, &mut MatChunkedReader::new(&x), *wire, &mut pool, &par).unwrap();
            assert_eq!(pooled, rows as u64);
            assert_eq!(pool.count(), rows as u64);
            assert_eq!(
                pool.mean(),
                want,
                "streamed ({wire:?}, {threads} threads) deviated from in-memory"
            );
        }
    }
}

#[test]
fn streamed_sketch_from_csv_file_matches_in_memory() {
    let dir = temp_dir("file_sketch");
    let path = dir.join("data.csv");
    let x = random_mat(700, 4, 6);
    save_csv(&path, &x).unwrap();
    let op = quantized_op(4, 24, 7);
    let pool = sketch_file(&op, &path, WireFormat::DenseF64, &Parallelism::serial()).unwrap();
    assert_eq!(pool.mean(), op.sketch_dataset_par(&x, &Parallelism::serial()));
}

#[test]
fn packed_bit_streaming_rejects_non_binary_signatures() {
    let op = cosine_op(3, 8, 8);
    let x = random_mat(10, 3, 9);
    let mut pool = PooledSketch::new(op.sketch_len());
    let err = sketch_reader(
        &op,
        &mut MatChunkedReader::new(&x),
        WireFormat::PackedBits,
        &mut pool,
        &Parallelism::serial(),
    );
    assert!(err.is_err());
}

#[test]
fn sketch_reader_rejects_dimension_mismatch() {
    let op = quantized_op(4, 8, 10);
    let x = random_mat(10, 3, 11);
    let mut pool = PooledSketch::new(op.sketch_len());
    assert!(sketch_reader(
        &op,
        &mut MatChunkedReader::new(&x),
        WireFormat::DenseF64,
        &mut pool,
        &Parallelism::serial(),
    )
    .is_err());
}

// --------------------------------------------------------------------- qsk

fn sample_sketch(seed: u64) -> (SketchMeta, PooledSketch, crate::sketch::SketchOperator) {
    let op = quantized_op(4, 16, seed);
    let x = random_mat(500, 4, seed ^ 0xABCD);
    let mut pool = PooledSketch::new(op.sketch_len());
    op.sketch_into(&x, &mut pool);
    let meta = SketchMeta::for_operator(&op, &spec("qckm"), seed);
    (meta, pool, op)
}

#[test]
fn qsk_round_trip_preserves_meta_and_pool_exactly() {
    let dir = temp_dir("qsk_roundtrip");
    let path = dir.join("sketch.qsk");
    let (meta, pool, _op) = sample_sketch(12);
    save_sketch(&path, &meta, &pool).unwrap();
    let (meta2, pool2) = load_sketch(&path).unwrap();
    assert_eq!(meta2, meta);
    assert_eq!(pool2.count(), pool.count());
    assert_eq!(pool2.sum(), pool.sum());
}

#[test]
fn qsk_rebuild_operator_reproduces_the_draw() {
    let (meta, _pool, op) = sample_sketch(13);
    let rebuilt = meta.rebuild_operator().unwrap();
    assert_eq!(rebuilt.frequencies().omega.as_slice(), op.frequencies().omega.as_slice());
    assert_eq!(rebuilt.frequencies().xi, op.frequencies().xi);
    assert_eq!(operator_fingerprint(&rebuilt), meta.config_hash);
}

#[test]
fn qsk_load_rejects_bad_magic_version_and_truncation() {
    let dir = temp_dir("qsk_corrupt");
    let path = dir.join("sketch.qsk");
    let (meta, pool, _op) = sample_sketch(14);
    save_sketch(&path, &meta, &pool).unwrap();
    let good = std::fs::read(&path).unwrap();

    // Bad magic.
    let mut bad = good.clone();
    bad[0] ^= 0xFF;
    let p = dir.join("bad_magic.qsk");
    std::fs::write(&p, &bad).unwrap();
    let err = format!("{:#}", load_sketch(&p).unwrap_err());
    assert!(err.contains("bad magic"), "{err}");

    // Unsupported version.
    let mut bad = good.clone();
    bad[4] = 99;
    let p = dir.join("bad_version.qsk");
    std::fs::write(&p, &bad).unwrap();
    let err = format!("{:#}", load_sketch(&p).unwrap_err());
    assert!(err.contains("version"), "{err}");

    // Truncated payload.
    let p = dir.join("truncated.qsk");
    std::fs::write(&p, &good[..good.len() - 3]).unwrap();
    assert!(load_sketch(&p).is_err());

    // Trailing garbage.
    let mut bad = good.clone();
    bad.push(0);
    let p = dir.join("trailing.qsk");
    std::fs::write(&p, &bad).unwrap();
    let err = format!("{:#}", load_sketch(&p).unwrap_err());
    assert!(err.contains("trailing"), "{err}");
}

/// Regression: `read_sketch_from` used to accept `count = 0` files, whose
/// undefined mean sketch decoded to NaN centroids downstream. Empty
/// sketches are now refused at both the read and the write boundary.
#[test]
fn qsk_refuses_empty_sketches() {
    let dir = temp_dir("qsk_empty");
    let (meta, _pool, op) = sample_sketch(45);

    // The writer refuses to produce a count=0 file…
    let empty = PooledSketch::new(op.sketch_len());
    let err = format!(
        "{:#}",
        save_sketch(&dir.join("empty.qsk"), &meta, &empty).unwrap_err()
    );
    assert!(err.contains("empty sketch"), "{err}");

    // …and the reader refuses one from another producer (craft a v1 file
    // by hand — v1 has no checksum, so only the count guard can catch it).
    let path = dir.join("crafted_empty.qsk");
    std::fs::write(&path, craft_v1_bytes(&meta, &empty)).unwrap();
    let err = format!("{:#}", load_sketch(&path).unwrap_err());
    assert!(err.contains("count=0"), "{err}");
}

#[test]
fn qsk_refuses_merging_mismatched_operators() {
    let (meta_a, _pool_a, _) = sample_sketch(15);
    // Same shape, different seed → different Ω bits → different hash.
    let (meta_b, _pool_b, _) = sample_sketch(16);
    assert!(meta_a.ensure_mergeable(&meta_a).is_ok());
    assert!(meta_a.ensure_mergeable(&meta_b).is_err());

    // A tampered hash alone must also refuse.
    let mut tampered = meta_a.clone();
    tampered.config_hash ^= 1;
    assert!(meta_a.ensure_mergeable(&tampered).is_err());
}

#[test]
fn qsk_rebuild_rejects_tampered_hash() {
    let (mut meta, _pool, _) = sample_sketch(17);
    meta.config_hash ^= 0xDEAD_BEEF;
    let err = format!("{:#}", meta.rebuild_operator().unwrap_err());
    assert!(err.contains("fingerprint"), "{err}");
}

// ------------------------------------------------------------------ qsk v2

#[test]
fn qsk_v2_round_trips_provenance_records() {
    let dir = temp_dir("qsk_prov");
    let path = dir.join("sketch.qsk");
    let (meta, pool, _op) = sample_sketch(40);
    let prov = vec![
        ShardRecord {
            label: "shard_a".into(),
            rows: 300,
        },
        ShardRecord {
            label: "e7/sensor-12".into(),
            rows: 200,
        },
    ];
    save_sketch_with(&path, &meta, &pool, &prov).unwrap();
    let (meta2, pool2, prov2) = load_sketch_full(&path).unwrap();
    assert_eq!(meta2, meta);
    assert_eq!(pool2.sum(), pool.sum());
    assert_eq!(prov2, prov);
    // The plain loader ignores provenance but reads the same sketch.
    let (meta3, pool3) = load_sketch(&path).unwrap();
    assert_eq!(meta3, meta);
    assert_eq!(pool3.sum(), pool.sum());
}

#[test]
fn qsk_v2_rejects_flipped_payload_byte_via_checksum() {
    let dir = temp_dir("qsk_checksum");
    let path = dir.join("sketch.qsk");
    let (meta, pool, _op) = sample_sketch(41);
    save_sketch(&path, &meta, &pool).unwrap();
    let mut bytes = std::fs::read(&path).unwrap();
    // Flip a byte in the middle of the f64 payload (well past the header,
    // well before the trailing checksum word).
    let at = bytes.len() - 8 - pool.len() * 4;
    bytes[at] ^= 0x01;
    let p = dir.join("flipped.qsk");
    std::fs::write(&p, &bytes).unwrap();
    let err = format!("{:#}", load_sketch(&p).unwrap_err());
    assert!(err.contains("checksum"), "{err}");
}

/// A hand-written version-1 file (no provenance, no checksum) must still
/// load to the identical meta and pool — the compatibility promise.
#[test]
fn qsk_v1_files_still_load() {
    let dir = temp_dir("qsk_v1");
    let path = dir.join("old.qsk");
    let (meta, pool, _op) = sample_sketch(42);
    std::fs::write(&path, craft_v1_bytes(&meta, &pool)).unwrap();
    let (meta2, pool2, prov) = load_sketch_full(&path).unwrap();
    assert_eq!(meta2, meta);
    assert_eq!(pool2.count(), pool.count());
    assert_eq!(pool2.sum(), pool.sum());
    assert!(prov.is_empty());
}

/// Write a version-1 `.qsk` byte stream by hand (no provenance, no
/// checksum) for compatibility tests.
fn craft_v1_bytes(meta: &SketchMeta, pool: &PooledSketch) -> Vec<u8> {
    let mut bytes = Vec::new();
    bytes.extend_from_slice(&QSK_MAGIC);
    bytes.extend_from_slice(&QSK_VERSION_V1.to_le_bytes());
    for s in [&meta.method, &meta.law] {
        bytes.extend_from_slice(&(s.len() as u32).to_le_bytes());
        bytes.extend_from_slice(s.as_bytes());
    }
    bytes.extend_from_slice(&meta.sigma.to_le_bytes());
    bytes.extend_from_slice(&meta.seed.to_le_bytes());
    bytes.extend_from_slice(&meta.m.to_le_bytes());
    bytes.extend_from_slice(&meta.d.to_le_bytes());
    bytes.extend_from_slice(&pool.count().to_le_bytes());
    bytes.extend_from_slice(&meta.config_hash.to_le_bytes());
    for &v in pool.sum() {
        bytes.extend_from_slice(&v.to_le_bytes());
    }
    bytes
}

// ------------------------------------------------------------------ qsk v3

/// Legacy method names keep writing version-2 headers, so every file a
/// pre-registry build could produce stays byte-identical.
#[test]
fn qsk_legacy_methods_keep_v2_header_bytes() {
    let dir = temp_dir("qsk_legacy_version");
    let path = dir.join("legacy.qsk");
    let (meta, pool, _op) = sample_sketch(50); // method "qckm"
    save_sketch(&path, &meta, &pool).unwrap();
    let bytes = std::fs::read(&path).unwrap();
    assert_eq!(
        u32::from_le_bytes(bytes[4..8].try_into().unwrap()),
        QSK_VERSION_V2,
        "legacy methods must stay on the v2 header"
    );
    let (meta2, _pool2) = load_sketch(&path).unwrap();
    assert_eq!(meta2, meta);
}

/// Parameterized / new-family methods round-trip through a v3 header and
/// rebuild their exact operator from it.
#[test]
fn qsk_v3_round_trips_parameterized_methods() {
    let dir = temp_dir("qsk_v3");
    for spec_str in ["qckm:bits=3", "modulo"] {
        let m = MethodSpec::parse(spec_str).unwrap();
        let op = draw_operator(&m, FrequencyLaw::AdaptedRadius, 16, 4, 1.0, 51);
        let x = random_mat(300, 4, 52);
        let mut pool = PooledSketch::new(op.sketch_len());
        op.sketch_into(&x, &mut pool);
        let meta = SketchMeta::for_operator(&op, &m, 51);
        assert_eq!(meta.method, spec_str, "meta stores the canonical spec");

        let path = dir.join(format!("{}.qsk", spec_str.replace([':', '='], "_")));
        save_sketch(&path, &meta, &pool).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        assert_eq!(
            u32::from_le_bytes(bytes[4..8].try_into().unwrap()),
            QSK_VERSION,
            "non-legacy methods need the v3 header"
        );

        let (meta2, pool2) = load_sketch(&path).unwrap();
        assert_eq!(meta2, meta);
        assert_eq!(pool2.sum(), pool.sum());
        let rebuilt = meta2.rebuild_operator().unwrap();
        assert_eq!(rebuilt.signature().name(), op.signature().name());
        assert_eq!(operator_fingerprint(&rebuilt), meta.config_hash);
    }
}

/// v1, v2 and v3 files of the *same* operator inter-load and merge: the
/// version is a container detail, not an operator property.
#[test]
fn qsk_v1_v2_headers_still_merge_with_current_files() {
    let dir = temp_dir("qsk_crossver");
    let (meta, pool, op) = sample_sketch(53);

    // A v1 file of shard A…
    let v1_path = dir.join("old.qsk");
    std::fs::write(&v1_path, craft_v1_bytes(&meta, &pool)).unwrap();
    // …and a current-writer (v2, legacy method) file of shard B.
    let x = random_mat(200, 4, 54);
    let mut pool_b = PooledSketch::new(op.sketch_len());
    op.sketch_into(&x, &mut pool_b);
    let v2_path = dir.join("new.qsk");
    save_sketch(&v2_path, &meta, &pool_b).unwrap();

    let (meta_a, mut pool_a, _) = load_sketch_full(&v1_path).unwrap();
    let (meta_b, pool_b2, _) = load_sketch_full(&v2_path).unwrap();
    meta_a.ensure_mergeable(&meta_b).unwrap();
    let want_count = pool_a.count() + pool_b2.count();
    pool_a.merge(&pool_b2);
    assert_eq!(pool_a.count(), want_count);
}

/// The wire form (`write_sketch_to` / `read_sketch_from`) is byte-identical
/// to the file form — the server snapshot path reuses the exact format.
#[test]
fn qsk_wire_round_trip_matches_file_bytes() {
    let dir = temp_dir("qsk_wire");
    let path = dir.join("sketch.qsk");
    let (meta, pool, _op) = sample_sketch(43);
    let prov = vec![ShardRecord {
        label: "live".into(),
        rows: pool.count(),
    }];
    save_sketch_with(&path, &meta, &pool, &prov).unwrap();
    let file_bytes = std::fs::read(&path).unwrap();
    let mut wire_bytes = Vec::new();
    write_sketch_to(&mut wire_bytes, &meta, &pool, &prov).unwrap();
    assert_eq!(wire_bytes, file_bytes);

    let mut cursor = &wire_bytes[..];
    let (meta2, pool2, prov2) = read_sketch_from(&mut cursor, "wire").unwrap();
    assert!(cursor.is_empty(), "read_sketch_from must consume exactly the sketch");
    assert_eq!(meta2, meta);
    assert_eq!(pool2.sum(), pool.sum());
    assert_eq!(prov2, prov);
}

#[test]
fn qsk_save_rejects_oversized_provenance_label() {
    let dir = temp_dir("qsk_label");
    let (meta, pool, _op) = sample_sketch(44);
    let prov = vec![ShardRecord {
        label: "x".repeat(MAX_LABEL_BYTES + 1),
        rows: 1,
    }];
    assert!(save_sketch_with(&dir.join("bad.qsk"), &meta, &pool, &prov).is_err());
}

/// Shard → merge equals whole-dataset sketching for the 1-bit quantizer
/// (±1 contributions sum to exact integers, so float addition commutes),
/// and merging is associative in any grouping.
#[test]
fn sharded_qsk_merge_is_exact_and_associative_for_quantizer() {
    let op = quantized_op(4, 16, 18);
    let x = random_mat(1000, 4, 19);
    let splits = [0usize, 311, 700, 1000];
    let mut shard_pools: Vec<PooledSketch> = Vec::new();
    for w in splits.windows(2) {
        let rows: Vec<usize> = (w[0]..w[1]).collect();
        let shard = x.select_rows(&rows);
        let mut pool = PooledSketch::new(op.sketch_len());
        op.sketch_into(&shard, &mut pool);
        shard_pools.push(pool);
    }
    let mut whole = PooledSketch::new(op.sketch_len());
    op.sketch_into(&x, &mut whole);

    // Left-fold merge.
    let mut left = PooledSketch::new(op.sketch_len());
    for p in &shard_pools {
        left.merge(p);
    }
    // Right-fold merge (different grouping).
    let mut right = PooledSketch::new(op.sketch_len());
    for p in shard_pools.iter().rev() {
        right.merge(p);
    }
    assert_eq!(left.sum(), whole.sum());
    assert_eq!(left.count(), whole.count());
    assert_eq!(right.sum(), whole.sum());
}
