//! `.qsk` — the persistent pooled-sketch format.
//!
//! The sketch, not the dataset, is this system's unit of storage and
//! transport: it is linear, mergeable in any order, and tiny (`2M` f64
//! plus a header) regardless of `N`. A `.qsk` file captures one pooled
//! *(sum, count)* pair together with everything needed to (a) refuse
//! merging with a sketch of a different operator and (b) rebuild the exact
//! operator for decoding — so acquisition, merging and decoding can run as
//! separate processes on separate machines (`qckm sketch` / `qckm merge` /
//! `qckm decode`), and a live `qckm serve` node can be seeded from, and
//! drained back into, the same offline pipeline.
//!
//! ## Layout (all little-endian)
//!
//! ```text
//! magic       4  b"QSKF"
//! version     u32   (2 or 3 — see the version policy below)
//! method      u32 length + UTF-8   (canonical method spec string, e.g.
//!                    "qckm" or "qckm:bits=3" — see crate::method)
//! law         u32 length + UTF-8   (frequency law name)
//! sigma       f64   (kernel bandwidth the frequencies were scaled with)
//! seed        u64   (frequency-draw seed)
//! m           u64   (number of frequencies; the sketch has 2M slots)
//! d           u64   (data dimension)
//! count       u64   (examples pooled into the sum)
//! config_hash u64   (fingerprint of the drawn Ω/ξ + signature, see
//!                    [`operator_fingerprint`])
//! prov_count  u32   (v2: number of provenance records, may be 0)
//! prov[i]     u32 length + UTF-8 label, u64 rows   (v2: where the pooled
//!                    rows came from — shard files, server shard labels)
//! payload     2M × f64   (the *sum* of contributions — not the mean, so
//!                         merges stay exact)
//! checksum    u64   (v2: FNV-1a over count + the exact payload bits, so a
//!                    flipped payload byte fails loudly instead of decoding
//!                    garbage centroids)
//! ```
//!
//! ## Version policy
//!
//! * **v1** (no provenance, no checksum) still loads.
//! * **v2** and **v3** share the exact layout above; the difference is the
//!   *method field's vocabulary*. v2 carries only the legacy bare names
//!   (`ckm`, `qckm`, `triangle`); v3 may carry any canonical
//!   [`crate::method::MethodSpec`] string (`qckm:bits=3`, `modulo`, …).
//! * The writer emits v2 whenever the method is a legacy name — so every
//!   sketch a legacy pipeline could have produced stays **byte-for-byte**
//!   what the previous build wrote — and v3 otherwise, so pre-registry
//!   builds reject new-family sketches up front with a clear
//!   "unsupported version" instead of failing mid-decode on an unknown
//!   method name.
//!
//! The `config_hash` covers the actual frequency matrix bits and the
//! signature name, so two sketches merge only if they were drawn from the
//! *same* randomness — matching `(seed, m, d, sigma, law, method)` alone
//! would miss a changed RNG or draw algorithm between builds.

use crate::method::MethodSpec;
use crate::frequency::{DrawnFrequencies, FrequencyLaw};
use crate::rng::Rng;
use crate::sketch::{PooledSketch, SketchOperator};
use anyhow::{bail, Context, Result};
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

/// File magic: "QSK file".
pub const QSK_MAGIC: [u8; 4] = *b"QSKF";
/// Newest format version (parameterized method-spec vocabulary; layout
/// identical to v2 — see the version policy in the module docs).
pub const QSK_VERSION: u32 = 3;
/// The checksummed/provenance version, still written for legacy method
/// names so their files stay byte-identical across builds.
pub const QSK_VERSION_V2: u32 = 2;
/// The original format version (still readable).
pub const QSK_VERSION_V1: u32 = 1;
/// Legacy (v2-era) method vocabulary: sketches of these methods keep the
/// v2 header version.
const LEGACY_V2_METHODS: [&str; 3] = ["ckm", "qckm", "triangle"];
/// Longest accepted provenance label, in bytes.
pub const MAX_LABEL_BYTES: usize = 256;
/// Longest accepted method/law header string, in bytes. Enforced on write
/// as well as read: a registry family whose canonical spec exceeded this
/// would otherwise save files that no build can load back.
pub const MAX_HEADER_STR_BYTES: usize = 64;

/// Everything a `.qsk` header records about how its sketch was produced.
#[derive(Clone, Debug, PartialEq)]
pub struct SketchMeta {
    /// Canonical method spec string ([`MethodSpec::canonical`]).
    pub method: String,
    /// Frequency-law name ([`FrequencyLaw::name`]).
    pub law: String,
    /// Kernel bandwidth the frequencies were scaled with.
    pub sigma: f64,
    /// Seed of the frequency/dither draw.
    pub seed: u64,
    /// Number of frequencies `M`.
    pub m: u64,
    /// Data dimension `n`.
    pub d: u64,
    /// Fingerprint of the drawn operator (see [`operator_fingerprint`]).
    pub config_hash: u64,
}

/// One provenance record: a labelled row count that went into the pool
/// (a shard file, a server shard, a seeded snapshot…). Purely descriptive —
/// merges concatenate records and never interpret them.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardRecord {
    /// Human-readable origin (shard label, file stem, `e{epoch}/{shard}`).
    pub label: String,
    /// Rows this origin contributed.
    pub rows: u64,
}

impl SketchMeta {
    /// Describe an operator produced by [`draw_operator`].
    pub fn for_operator(op: &SketchOperator, method: &MethodSpec, seed: u64) -> Self {
        let freqs = op.frequencies();
        Self {
            method: method.canonical().to_string(),
            law: freqs.law.name().to_string(),
            sigma: freqs.sigma,
            seed,
            m: op.num_frequencies() as u64,
            d: op.dim() as u64,
            config_hash: operator_fingerprint(op),
        }
    }

    /// Check that a sketch described by `other` pools the same quantity as
    /// one described by `self` (merging them is meaningful).
    pub fn ensure_mergeable(&self, other: &SketchMeta) -> Result<()> {
        if self.config_hash != other.config_hash
            || self.method != other.method
            || self.law != other.law
            || self.sigma.to_bits() != other.sigma.to_bits()
            || self.seed != other.seed
            || self.m != other.m
            || self.d != other.d
        {
            bail!(
                "sketch operators differ: ({}) vs ({}) — refusing to merge sketches \
                 taken with mismatched frequency draws",
                self.describe(),
                other.describe()
            );
        }
        Ok(())
    }

    /// One-line human description (for logs and error messages).
    pub fn describe(&self) -> String {
        format!(
            "method={} law={} m={} d={} sigma={:.6} seed={} hash={:016x}",
            self.method, self.law, self.m, self.d, self.sigma, self.seed, self.config_hash
        )
    }

    /// Re-draw the exact operator this sketch was taken with, verifying the
    /// fingerprint so a changed RNG/draw implementation fails loudly
    /// instead of decoding garbage.
    pub fn rebuild_operator(&self) -> Result<SketchOperator> {
        let method = MethodSpec::parse(&self.method)?;
        let law = FrequencyLaw::parse(&self.law)?;
        if self.m == 0 || self.d == 0 {
            bail!("corrupt sketch meta: m={} d={}", self.m, self.d);
        }
        let op = draw_operator(
            &method,
            law,
            self.m as usize,
            self.d as usize,
            self.sigma,
            self.seed,
        );
        let fp = operator_fingerprint(&op);
        if fp != self.config_hash {
            bail!(
                "operator fingerprint mismatch (file {:016x}, redrawn {:016x}): the sketch \
                 was taken with an incompatible frequency draw",
                self.config_hash,
                fp
            );
        }
        Ok(op)
    }
}

/// Draw the sketch operator as a pure function of
/// `(method, law, m, d, sigma, seed)` — the `.qsk` reproducibility
/// contract. Every stage (shard sketchers, the decoder, the live server)
/// calls this with the same arguments and gets the bit-identical Ω and ξ.
pub fn draw_operator(
    method: &MethodSpec,
    law: FrequencyLaw,
    m: usize,
    d: usize,
    sigma: f64,
    seed: u64,
) -> SketchOperator {
    let mut rng = Rng::new(seed);
    let freqs = if method.dithered() {
        DrawnFrequencies::draw(law, d, m, sigma, &mut rng)
    } else {
        DrawnFrequencies::draw_undithered(law, d, m, sigma, &mut rng)
    };
    SketchOperator::new(freqs, method.signature())
}

/// FNV-1a fingerprint of a drawn operator: dimensions, signature name, and
/// the exact f64 bits of Ω and ξ. Two operators fingerprint equal iff they
/// sketch every dataset identically.
pub fn operator_fingerprint(op: &SketchOperator) -> u64 {
    let mut h = Fnv1a::new();
    h.write_u64(op.dim() as u64);
    h.write_u64(op.num_frequencies() as u64);
    h.write_bytes(op.signature().name().as_bytes());
    let freqs = op.frequencies();
    for &v in freqs.omega.as_slice() {
        h.write_u64(v.to_bits());
    }
    for &v in &freqs.xi {
        h.write_u64(v.to_bits());
    }
    h.finish()
}

/// FNV-1a fingerprint of a pool's exact contents (count + sum bits). This
/// is what the v2 payload checksum stores, and what the server's centroid
/// cache keys on: equal fingerprints ⇒ bit-identical mean sketch ⇒
/// bit-identical decode.
pub fn pool_fingerprint(pool: &PooledSketch) -> u64 {
    let mut h = Fnv1a::new();
    h.write_u64(pool.count());
    for &v in pool.sum() {
        h.write_u64(v.to_bits());
    }
    h.finish()
}

/// Minimal FNV-1a (64-bit) — stable, dependency-free, endian-independent.
pub(crate) struct Fnv1a(u64);

impl Fnv1a {
    pub(crate) fn new() -> Self {
        Self(0xcbf2_9ce4_8422_2325)
    }

    pub(crate) fn write_bytes(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }

    pub(crate) fn write_u64(&mut self, v: u64) {
        self.write_bytes(&v.to_le_bytes());
    }

    pub(crate) fn finish(&self) -> u64 {
        self.0
    }
}

// ------------------------------------------------------------------ save

/// Write a pooled sketch (its *sum*, not its mean) plus metadata to `path`.
pub fn save_sketch(path: &Path, meta: &SketchMeta, pool: &PooledSketch) -> Result<()> {
    save_sketch_with(path, meta, pool, &[])
}

/// Like [`save_sketch`], with provenance records describing where the
/// pooled rows came from.
///
/// Writes to a sibling `.tmp` file and renames into place, so a failed
/// write (oversized label, disk full) can never destroy an existing
/// sketch — `qckm sketch --append` rewrites its input in place and relies
/// on this.
pub fn save_sketch_with(
    path: &Path,
    meta: &SketchMeta,
    pool: &PooledSketch,
    provenance: &[ShardRecord],
) -> Result<()> {
    let mut tmp_name = path
        .file_name()
        .map(|n| n.to_os_string())
        .unwrap_or_else(|| "sketch.qsk".into());
    tmp_name.push(".tmp");
    let tmp = path.with_file_name(tmp_name);
    let file =
        std::fs::File::create(&tmp).with_context(|| format!("create {}", tmp.display()))?;
    let mut w = BufWriter::new(file);
    let wrote = write_sketch_to(&mut w, meta, pool, provenance)
        .and_then(|()| w.flush().map_err(anyhow::Error::from));
    drop(w);
    if let Err(e) = wrote {
        let _ = std::fs::remove_file(&tmp);
        return Err(e);
    }
    std::fs::rename(&tmp, path)
        .with_context(|| format!("rename {} -> {}", tmp.display(), path.display()))?;
    Ok(())
}

/// The header version a sketch of `method` is written with: legacy bare
/// names keep v2 (byte-identical files to pre-registry builds), every
/// parameterized or newer family needs v3 (see the module docs).
fn wire_version(method: &str) -> u32 {
    if LEGACY_V2_METHODS.iter().any(|m| *m == method) {
        QSK_VERSION_V2
    } else {
        QSK_VERSION
    }
}

/// Serialize a `.qsk` (version 2 or 3, by method vocabulary) into any
/// writer — the file format and the server's snapshot wire format are the
/// same bytes.
pub fn write_sketch_to(
    w: &mut impl Write,
    meta: &SketchMeta,
    pool: &PooledSketch,
    provenance: &[ShardRecord],
) -> Result<()> {
    assert_eq!(
        pool.len() as u64,
        2 * meta.m,
        "pool length {} does not match meta m={}",
        pool.len(),
        meta.m
    );
    for (field, value) in [("method", &meta.method), ("law", &meta.law)] {
        if value.len() > MAX_HEADER_STR_BYTES {
            bail!(
                "{field} string '{value}' exceeds {MAX_HEADER_STR_BYTES} bytes — the file \
                 would be unreadable"
            );
        }
    }
    if pool.count() == 0 {
        // A count=0 sketch has no mean and therefore cannot be decoded;
        // refusing to write one here keeps every `.qsk` on disk (and every
        // server snapshot frame) decodable by construction. The reader
        // enforces the same bound for files from other producers.
        bail!("refusing to write an empty sketch (zero pooled rows)");
    }
    w.write_all(&QSK_MAGIC)?;
    w.write_all(&wire_version(&meta.method).to_le_bytes())?;
    write_str(w, &meta.method)?;
    write_str(w, &meta.law)?;
    w.write_all(&meta.sigma.to_le_bytes())?;
    w.write_all(&meta.seed.to_le_bytes())?;
    w.write_all(&meta.m.to_le_bytes())?;
    w.write_all(&meta.d.to_le_bytes())?;
    w.write_all(&pool.count().to_le_bytes())?;
    w.write_all(&meta.config_hash.to_le_bytes())?;
    w.write_all(&(provenance.len() as u32).to_le_bytes())?;
    for rec in provenance {
        if rec.label.len() > MAX_LABEL_BYTES {
            bail!(
                "provenance label '{}…' exceeds {MAX_LABEL_BYTES} bytes",
                rec.label.chars().take(32).collect::<String>()
            );
        }
        write_str(w, &rec.label)?;
        w.write_all(&rec.rows.to_le_bytes())?;
    }
    for &v in pool.sum() {
        w.write_all(&v.to_le_bytes())?;
    }
    w.write_all(&pool_fingerprint(pool).to_le_bytes())?;
    Ok(())
}

// ------------------------------------------------------------------ load

/// Load a `.qsk` file, validating magic, version, checksum (v2), and
/// internal consistency.
pub fn load_sketch(path: &Path) -> Result<(SketchMeta, PooledSketch)> {
    let (meta, pool, _prov) = load_sketch_full(path)?;
    Ok((meta, pool))
}

/// Load a `.qsk` file including its provenance records (empty for v1
/// files and for sketches saved without provenance).
pub fn load_sketch_full(path: &Path) -> Result<(SketchMeta, PooledSketch, Vec<ShardRecord>)> {
    let file = std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?;
    let mut r = BufReader::new(file);
    let src = path.display().to_string();
    let loaded = read_sketch_from(&mut r, &src)?;
    let mut trailing = [0u8; 1];
    if r.read(&mut trailing)? != 0 {
        bail!("{src}: trailing bytes after sketch payload");
    }
    Ok(loaded)
}

/// Deserialize a `.qsk` from any reader (file or wire), consuming exactly
/// the sketch's bytes. `src` labels error messages. Callers that require
/// end-of-input (files, single-sketch frames) check for trailing bytes
/// themselves.
pub fn read_sketch_from(
    r: &mut impl Read,
    src: &str,
) -> Result<(SketchMeta, PooledSketch, Vec<ShardRecord>)> {
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)
        .with_context(|| format!("{src}: truncated header"))?;
    if magic != QSK_MAGIC {
        bail!("{src}: not a .qsk sketch file (bad magic)");
    }
    let version = read_u32(r, src)?;
    if !(QSK_VERSION_V1..=QSK_VERSION).contains(&version) {
        bail!(
            "{src}: unsupported .qsk format version {version} \
             (this build reads {QSK_VERSION_V1} through {QSK_VERSION})"
        );
    }
    let method = read_str(r, src, MAX_HEADER_STR_BYTES)?;
    let law = read_str(r, src, MAX_HEADER_STR_BYTES)?;
    let sigma = f64::from_le_bytes(read_8(r, src)?);
    let seed = u64::from_le_bytes(read_8(r, src)?);
    let m = u64::from_le_bytes(read_8(r, src)?);
    let d = u64::from_le_bytes(read_8(r, src)?);
    let count = u64::from_le_bytes(read_8(r, src)?);
    let config_hash = u64::from_le_bytes(read_8(r, src)?);
    // Plausibility bounds before the payload allocation: a corrupt header
    // must fail cleanly, not OOM. 2^24 frequencies = a 256 MiB payload,
    // far beyond any real sketch (M ≲ 10⁴ in the paper's regime).
    if m == 0 || m > (1 << 24) {
        bail!("{src}: implausible frequency count m={m}");
    }
    if d == 0 || d > (1 << 24) {
        bail!("{src}: implausible data dimension d={d}");
    }
    if count == 0 {
        // The mean sketch z = sum/count is undefined at count=0 — such a
        // file would decode to NaN centroids (or panic) downstream, so
        // refuse it at the same boundary that checks m and d.
        bail!("{src}: empty sketch (count=0) — nothing to decode");
    }
    let mut provenance = Vec::new();
    if version >= QSK_VERSION_V2 {
        let prov_count = read_u32(r, src)?;
        if prov_count > (1 << 20) {
            bail!("{src}: implausible provenance record count {prov_count}");
        }
        for _ in 0..prov_count {
            let label = read_str(r, src, MAX_LABEL_BYTES)?;
            let rows = u64::from_le_bytes(read_8(r, src)?);
            provenance.push(ShardRecord { label, rows });
        }
    }
    let mut sum = vec![0.0f64; 2 * m as usize];
    for v in sum.iter_mut() {
        *v = f64::from_le_bytes(read_8(r, src)?);
    }
    let pool = PooledSketch::from_raw(sum, count);
    if version >= QSK_VERSION_V2 {
        let stored = u64::from_le_bytes(read_8(r, src)?);
        let actual = pool_fingerprint(&pool);
        if stored != actual {
            bail!(
                "{src}: payload checksum mismatch (stored {stored:016x}, computed \
                 {actual:016x}) — the sketch payload is corrupt"
            );
        }
    }
    let meta = SketchMeta {
        method,
        law,
        sigma,
        seed,
        m,
        d,
        config_hash,
    };
    Ok((meta, pool, provenance))
}

fn write_str(w: &mut impl Write, s: &str) -> Result<()> {
    w.write_all(&(s.len() as u32).to_le_bytes())?;
    w.write_all(s.as_bytes())?;
    Ok(())
}

fn read_8(r: &mut impl Read, src: &str) -> Result<[u8; 8]> {
    let mut buf = [0u8; 8];
    r.read_exact(&mut buf)
        .with_context(|| format!("{src}: truncated sketch file"))?;
    Ok(buf)
}

fn read_u32(r: &mut impl Read, src: &str) -> Result<u32> {
    let mut buf = [0u8; 4];
    r.read_exact(&mut buf)
        .with_context(|| format!("{src}: truncated sketch file"))?;
    Ok(u32::from_le_bytes(buf))
}

fn read_str(r: &mut impl Read, src: &str, cap: usize) -> Result<String> {
    let len = read_u32(r, src)? as usize;
    if len > cap {
        bail!("{src}: implausible string field ({len} bytes)");
    }
    let mut buf = vec![0u8; len];
    r.read_exact(&mut buf)
        .with_context(|| format!("{src}: truncated sketch file"))?;
    String::from_utf8(buf).with_context(|| format!("{src}: non-UTF-8 string field"))
}
