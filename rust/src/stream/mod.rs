//! Out-of-core streaming sketch subsystem.
//!
//! The paper's premise is that the pooled sketch — not the dataset — is the
//! unit of storage, transport, and learning: it is linear, mergeable in any
//! order, and updatable online. This module makes the repo live up to that:
//!
//! * **Bounded-memory ingestion** ([`ChunkedReader`]): datasets stream in
//!   fixed row blocks from CSV ([`CsvChunkedReader`]), the raw-f64 format
//!   ([`RawF64ChunkedReader`], or its windowed positional variant
//!   [`MappedF64ChunkedReader`] behind `qckm sketch --mmap`) or memory
//!   ([`MatChunkedReader`]) — the full `N × n` matrix is never
//!   materialized.
//! * **Streaming encode** ([`sketch_reader`], [`sketch_file`]): feeds those
//!   blocks through the existing parallel encode in
//!   [`PAR_CHUNK_ROWS`]-row chunks, *bit-for-bit identical* to
//!   [`SketchOperator::sketch_dataset_par`] on the in-memory copy at every
//!   thread count (see the determinism argument below).
//! * **Sketch persistence** ([`save_sketch`], [`load_sketch`]): the
//!   versioned `.qsk` format with a config fingerprint, so shard sketches
//!   from different machines merge only when their operators match, and the
//!   decoder can rebuild the exact operator from the header alone.
//!
//! Together with the `qckm sketch` / `qckm merge` / `qckm decode`
//! subcommands this turns the binary into the distributed acquisition
//! pipeline of the paper's Fig. 1: sketch each shard where the data lives,
//! ship the (tiny) `.qsk` files, merge associatively, decode once.
//!
//! ## Determinism of the streamed fold
//!
//! [`sketch_reader`] reads a *window* of `threads × PAR_CHUNK_ROWS` rows,
//! fans the window out in [`PAR_CHUNK_ROWS`]-row chunks through
//! [`crate::parallel::run_chunked`], merges the per-chunk partial pools in
//! chunk order, and repeats. Because every window except the last is an
//! exact multiple of [`PAR_CHUNK_ROWS`], the global chunk boundaries are
//! the same fixed multiples of `PAR_CHUNK_ROWS` that
//! [`SketchOperator::sketch_into_par`] uses, each chunk's fold is the
//! identical serial code, and the merge order is the global chunk order —
//! so the streamed pool is bit-for-bit the in-memory pool, at every thread
//! count and whatever the window size. (The window does scale with the
//! thread budget, but per the contract in [`crate::parallel`] only chunk
//! *boundaries* may influence results, and those stay fixed.)

mod qsk;
mod reader;

pub use qsk::{
    draw_operator, load_sketch, load_sketch_full, operator_fingerprint, pool_fingerprint,
    read_sketch_from, save_sketch, save_sketch_with, write_sketch_to, ShardRecord, SketchMeta,
    MAX_HEADER_STR_BYTES, MAX_LABEL_BYTES, QSK_MAGIC, QSK_VERSION, QSK_VERSION_V1, QSK_VERSION_V2,
};
pub(crate) use qsk::Fnv1a;
pub use reader::{
    open_dataset, open_dataset_with, read_all, ChunkedReader, CsvChunkedReader,
    MappedF64ChunkedReader, MatChunkedReader, RawF64ChunkedReader,
};

use crate::coordinator::WireFormat;
use crate::linalg::Mat;
use crate::parallel::{self, Parallelism};
use crate::sketch::{BitAggregator, PooledSketch, SketchOperator, PAR_CHUNK_ROWS};
use anyhow::{bail, Result};
use std::path::Path;

/// Accumulate the pooled (sum, count) of every row a reader yields into
/// `pool`, using up to `par` threads and O(`threads × PAR_CHUNK_ROWS × n`)
/// memory. Returns the number of rows pooled.
///
/// With `WireFormat::DenseF64` the per-chunk fold is exactly
/// [`SketchOperator::sketch_range_into`], so the result is bit-for-bit
/// [`SketchOperator::sketch_into_par`] on the in-memory dataset. With
/// `WireFormat::PackedBits` (±1 signatures only) each chunk pools through a
/// [`BitAggregator`] — integer one-counts, the sensor acquisition path —
/// whose (sum, count) is exactly the dense fold's because ±1 sums are
/// integers, so the two encodings agree to the last bit too.
pub fn sketch_reader(
    op: &SketchOperator,
    reader: &mut dyn ChunkedReader,
    wire: WireFormat,
    pool: &mut PooledSketch,
    par: &Parallelism,
) -> Result<u64> {
    if reader.dim() != op.dim() {
        bail!(
            "dataset dimension {} does not match operator dimension {}",
            reader.dim(),
            op.dim()
        );
    }
    assert_eq!(pool.len(), op.sketch_len());
    if wire == WireFormat::PackedBits && op.signature().name() != "universal-1bit" {
        bail!(
            "packed-bit streaming requires the ±1 universal quantizer signature, got '{}'",
            op.signature().name()
        );
    }

    let dim = op.dim();
    let window_rows = PAR_CHUNK_ROWS * par.resolved_threads().max(1);
    let mut buf: Vec<f64> = Vec::new();
    let mut total = 0u64;
    loop {
        // Fill a whole window (streams deliver short blocks only at EOF, so
        // every window but the last is a multiple of PAR_CHUNK_ROWS — the
        // global chunk grid stays aligned).
        buf.clear();
        let mut rows = 0usize;
        while rows < window_rows {
            let got = reader.next_block(window_rows - rows, &mut buf)?;
            if got == 0 {
                break;
            }
            rows += got;
        }
        if rows == 0 {
            break;
        }
        // Observational only (I-18): a rows counter plus one span per
        // window into `qckm_stream_window_seconds`.
        let m = crate::obs::lib_metrics();
        m.stream_rows.add(rows as u64);
        let _span = crate::obs::global().span("stream_window", &m.stream_window_seconds);
        let window = Mat::from_vec(rows, dim, buf);
        let partials = parallel::run_chunked(rows, PAR_CHUNK_ROWS, par, |_, range| match wire {
            WireFormat::DenseF64 => {
                let mut partial = PooledSketch::new(op.sketch_len());
                op.sketch_range_into(&window, range, &mut partial);
                partial
            }
            WireFormat::PackedBits => {
                let mut agg = BitAggregator::new(op.sketch_len());
                op.pool_bits_range(&window, range, &mut agg);
                let (sum, count) = agg.to_sum();
                PooledSketch::from_raw(sum, count)
            }
        });
        // Ordered merge — the global fixed reduction order.
        for partial in &partials {
            pool.merge(partial);
        }
        total += rows as u64;
        buf = window.into_vec();
        if rows < window_rows {
            break; // EOF
        }
    }
    Ok(total)
}

/// Stream-sketch a dataset file (CSV or raw f64, dispatched by extension)
/// into a fresh pool. Errors on an empty dataset.
pub fn sketch_file(
    op: &SketchOperator,
    path: &Path,
    wire: WireFormat,
    par: &Parallelism,
) -> Result<PooledSketch> {
    let mut reader = open_dataset(path)?;
    let mut pool = PooledSketch::new(op.sketch_len());
    let rows = sketch_reader(op, reader.as_mut(), wire, &mut pool, par)?;
    if rows == 0 {
        bail!("{}: empty dataset", path.display());
    }
    Ok(pool)
}

#[cfg(test)]
mod tests;
