//! Bounded-memory dataset readers — the ingestion side of the streaming
//! sketch subsystem.
//!
//! A [`ChunkedReader`] yields a dataset as fixed-size row blocks instead of
//! one materialized `Mat`, so the sketch of an out-of-core dataset can be
//! pooled with memory proportional to the block window, never to `N`. The
//! parsing/validation semantics of each implementation are *identical* to
//! the corresponding eager loader in [`crate::data`] (same skipped lines,
//! same error messages modulo buffering, same `f64` values), which is what
//! makes the streamed sketch bit-for-bit equal to the in-memory one (see
//! [`super::sketch_reader`]).

use crate::linalg::Mat;
use anyhow::{bail, Context, Result};
use std::io::{BufRead, BufReader, Read};
use std::path::Path;

/// A row source that yields a dataset in bounded-size blocks.
///
/// The row stream is *positional*: every call appends the next rows in
/// dataset order, so concatenating all blocks reproduces the dataset
/// exactly. Implementations validate as they go and fail fast with the
/// offending location, like the eager loaders in [`crate::data`].
pub trait ChunkedReader {
    /// Number of columns (the sample dimension `n`), known up front.
    fn dim(&self) -> usize;

    /// Append up to `max_rows` further rows (row-major, `rows * dim`
    /// values) to `out` and return how many rows were appended. `Ok(0)`
    /// means end of stream; callers may keep calling and will keep
    /// getting `Ok(0)`.
    fn next_block(&mut self, max_rows: usize, out: &mut Vec<f64>) -> Result<usize>;
}

/// Drain a reader into an in-memory `Mat` (the eager fallback, e.g. when a
/// data-dependent bandwidth heuristic genuinely needs the whole dataset).
pub fn read_all(reader: &mut dyn ChunkedReader) -> Result<Mat> {
    let dim = reader.dim();
    let mut data = Vec::new();
    loop {
        let got = reader.next_block(usize::MAX, &mut data)?;
        if got == 0 {
            break;
        }
    }
    if data.is_empty() {
        bail!("empty dataset");
    }
    Ok(Mat::from_vec(data.len() / dim, dim, data))
}

/// Open `path` as a chunked reader, dispatching on the extension:
/// `.csv` → [`CsvChunkedReader`], anything else → [`RawF64ChunkedReader`]
/// (the `u64 rows, u64 cols, f64…` format of [`crate::data::save_f64_bin`]).
pub fn open_dataset(path: &Path) -> Result<Box<dyn ChunkedReader>> {
    open_dataset_with(path, false)
}

/// [`open_dataset`] with the reader strategy made explicit: `mmap = true`
/// selects the windowed positional reader ([`MappedF64ChunkedReader`]) for
/// raw-f64 datasets — the `qckm sketch --mmap` path. CSV has no positional
/// fixed-stride layout to window over, so `mmap` + `.csv` is an error.
pub fn open_dataset_with(path: &Path, mmap: bool) -> Result<Box<dyn ChunkedReader>> {
    let is_csv = path
        .extension()
        .and_then(|e| e.to_str())
        .is_some_and(|e| e.eq_ignore_ascii_case("csv"));
    match (is_csv, mmap) {
        (true, false) => Ok(Box::new(CsvChunkedReader::open(path)?)),
        (true, true) => bail!(
            "{}: --mmap requires the raw f64 dataset format, not CSV",
            path.display()
        ),
        (false, false) => Ok(Box::new(RawF64ChunkedReader::open(path)?)),
        (false, true) => Ok(Box::new(MappedF64ChunkedReader::open(path)?)),
    }
}

// ------------------------------------------------------------------- CSV

/// Streaming headerless-CSV reader with [`crate::data::load_csv`] semantics:
/// blank lines and `#` comments are skipped, every row must have the same
/// column count as the first, and bad numbers fail with file:line context.
pub struct CsvChunkedReader {
    path: String,
    reader: BufReader<std::fs::File>,
    cols: usize,
    /// First data row, parsed during `open` to learn `cols`; emitted by the
    /// first `next_block` call.
    pending: Option<Vec<f64>>,
    /// 1-based line number of the last line read.
    lineno: usize,
    line: String,
}

impl CsvChunkedReader {
    pub fn open(path: &Path) -> Result<Self> {
        let file =
            std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?;
        let mut new = Self {
            path: path.display().to_string(),
            reader: BufReader::new(file),
            cols: 0,
            pending: None,
            lineno: 0,
            line: String::new(),
        };
        // Scan to the first data row to learn the column count.
        match new.read_row()? {
            Some(row) => {
                new.cols = row.len();
                new.pending = Some(row);
            }
            None => bail!("{}: empty dataset", new.path),
        }
        Ok(new)
    }

    /// Parse the next data row, or `None` at end of file. Row semantics
    /// come from the shared [`crate::data`] line parser, so the streamed
    /// and eager loaders cannot diverge.
    fn read_row(&mut self) -> Result<Option<Vec<f64>>> {
        loop {
            self.line.clear();
            let n = self
                .reader
                .read_line(&mut self.line)
                .with_context(|| format!("read {}", self.path))?;
            if n == 0 {
                return Ok(None);
            }
            self.lineno += 1;
            match crate::data::parse_csv_line(&self.line, self.cols, &self.path, self.lineno)? {
                Some(vals) => return Ok(Some(vals)),
                None => continue,
            }
        }
    }
}

impl ChunkedReader for CsvChunkedReader {
    fn dim(&self) -> usize {
        self.cols
    }

    fn next_block(&mut self, max_rows: usize, out: &mut Vec<f64>) -> Result<usize> {
        if max_rows == 0 {
            return Ok(0);
        }
        let mut rows = 0;
        if let Some(row) = self.pending.take() {
            out.extend_from_slice(&row);
            rows += 1;
        }
        while rows < max_rows {
            match self.read_row()? {
                Some(row) => {
                    out.extend_from_slice(&row);
                    rows += 1;
                }
                None => break,
            }
        }
        Ok(rows)
    }
}

// --------------------------------------------------------------- raw f64

/// Streaming reader for the raw little-endian format of
/// [`crate::data::save_f64_bin`] (`u64 rows, u64 cols, rows*cols f64`).
/// Unlike the eager loader there is no total-size ceiling — streaming
/// datasets larger than memory is the point — but a truncated payload
/// still fails with the row position.
pub struct RawF64ChunkedReader {
    path: String,
    reader: BufReader<std::fs::File>,
    cols: usize,
    rows_total: u64,
    rows_read: u64,
}

impl RawF64ChunkedReader {
    pub fn open(path: &Path) -> Result<Self> {
        let file =
            std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?;
        let mut reader = BufReader::new(file);
        let mut u64buf = [0u8; 8];
        reader
            .read_exact(&mut u64buf)
            .with_context(|| format!("{}: truncated header", path.display()))?;
        let rows_total = u64::from_le_bytes(u64buf);
        reader
            .read_exact(&mut u64buf)
            .with_context(|| format!("{}: truncated header", path.display()))?;
        let cols = u64::from_le_bytes(u64buf);
        // Same plausibility ceiling as the .qsk loader's `d`: a corrupt
        // header must fail cleanly before any column-sized allocation.
        if cols == 0 || cols > (1 << 24) {
            bail!("{}: implausible column count {cols}", path.display());
        }
        Ok(Self {
            path: path.display().to_string(),
            reader,
            cols: cols as usize,
            rows_total,
            rows_read: 0,
        })
    }

    /// Total rows the header promises (a streaming-only convenience).
    pub fn rows_total(&self) -> u64 {
        self.rows_total
    }
}

impl ChunkedReader for RawF64ChunkedReader {
    fn dim(&self) -> usize {
        self.cols
    }

    fn next_block(&mut self, max_rows: usize, out: &mut Vec<f64>) -> Result<usize> {
        // Cap one bulk read at ~8 MiB so a corrupt header promising 2^60
        // rows cannot trigger a giant allocation; callers loop, and a short
        // (non-zero) return just means "call again".
        let cap = ((8 << 20) / (self.cols * 8)).max(1);
        let left = (self.rows_total - self.rows_read)
            .min(max_rows as u64)
            .min(cap as u64) as usize;
        if left == 0 {
            return Ok(0);
        }
        // One bulk read per block (this is the out-of-core hot path), then
        // decode in place.
        let mut bytes = vec![0u8; left * self.cols * 8];
        self.reader.read_exact(&mut bytes).with_context(|| {
            format!(
                "{}: truncated in rows {}..{} of {}",
                self.path,
                self.rows_read,
                self.rows_read + left as u64,
                self.rows_total
            )
        })?;
        out.extend(
            bytes
                .chunks_exact(8)
                .map(|c| f64::from_le_bytes(c.try_into().unwrap())),
        );
        self.rows_read += left as u64;
        Ok(left)
    }
}

// ------------------------------------------------------- raw f64, windowed

/// Positional window size of [`MappedF64ChunkedReader`]: large enough to
/// amortize the syscall per window, small enough that the resident window
/// stays cache-friendly and a corrupt header cannot trigger a giant
/// allocation (same ceiling the buffered reader uses per read).
const MAPPED_WINDOW_BYTES: usize = 8 << 20;

/// Memory-mapped-style reader for the raw little-endian format of
/// [`crate::data::save_f64_bin`] — the out-of-core fast path behind
/// `qckm sketch --mmap`.
///
/// Std-only (no `libc`, no `mmap(2)` bindings): the file is accessed
/// through positional reads ([`File::read_at`] on Unix — no seek syscalls,
/// no reader-side offset state, safe to extend to concurrent readers) into
/// one reusable row-aligned window buffer. Each `next_block` call
/// pre-faults its whole window with a single bulk positional read, exactly
/// the page-in pattern a real `mmap` + sequential scan produces, and then
/// decodes in place. Compared to [`RawF64ChunkedReader`] this removes the
/// `BufReader` double-copy and the per-block `Vec` allocation — the window
/// is allocated once and reused for the life of the reader.
///
/// Header validation and error messages are *identical* to
/// [`RawF64ChunkedReader`] (parity-locked by the stream tests), so the two
/// readers are interchangeable: same rows, same values, same failures.
///
/// [`File::read_at`]: std::os::unix::fs::FileExt::read_at
pub struct MappedF64ChunkedReader {
    path: String,
    file: std::fs::File,
    cols: usize,
    rows_total: u64,
    rows_read: u64,
    /// Reusable window buffer (rows-aligned, ≤ [`MAPPED_WINDOW_BYTES`]),
    /// allocated lazily on the first block.
    window: Vec<u8>,
    /// Rows per full window.
    window_rows: usize,
}

/// `read_exact` at an absolute file offset, without touching any shared
/// seek cursor. Unix uses `pread(2)`; the portable fallback seeks —
/// correctness is identical, only the syscall shape differs.
fn read_exact_at(file: &std::fs::File, buf: &mut [u8], offset: u64) -> std::io::Result<()> {
    #[cfg(unix)]
    {
        use std::os::unix::fs::FileExt;
        file.read_exact_at(buf, offset)
    }
    #[cfg(not(unix))]
    {
        use std::io::{Seek, SeekFrom};
        let mut f = file;
        f.seek(SeekFrom::Start(offset))?;
        f.read_exact(buf)
    }
}

impl MappedF64ChunkedReader {
    pub fn open(path: &Path) -> Result<Self> {
        let file =
            std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?;
        let mut header = [0u8; 16];
        read_exact_at(&file, &mut header, 0)
            .with_context(|| format!("{}: truncated header", path.display()))?;
        let rows_total = u64::from_le_bytes(header[..8].try_into().unwrap());
        let cols = u64::from_le_bytes(header[8..].try_into().unwrap());
        // Same plausibility ceiling as the buffered reader (and the .qsk
        // loader's `d`): a corrupt header must fail cleanly before any
        // column-sized allocation.
        if cols == 0 || cols > (1 << 24) {
            bail!("{}: implausible column count {cols}", path.display());
        }
        let cols = cols as usize;
        Ok(Self {
            path: path.display().to_string(),
            file,
            cols,
            rows_total,
            rows_read: 0,
            window: Vec::new(),
            window_rows: (MAPPED_WINDOW_BYTES / (cols * 8)).max(1),
        })
    }

    /// Total rows the header promises (a streaming-only convenience).
    pub fn rows_total(&self) -> u64 {
        self.rows_total
    }
}

impl ChunkedReader for MappedF64ChunkedReader {
    fn dim(&self) -> usize {
        self.cols
    }

    fn next_block(&mut self, max_rows: usize, out: &mut Vec<f64>) -> Result<usize> {
        let left = (self.rows_total - self.rows_read)
            .min(max_rows as u64)
            .min(self.window_rows as u64) as usize;
        if left == 0 {
            return Ok(0);
        }
        // Pre-fault the window with one positional bulk read into the
        // reusable buffer (first call allocates it; `resize` after that is
        // a length adjustment, the capacity is retained).
        let bytes = left * self.cols * 8;
        self.window.resize(bytes, 0);
        let offset = 16 + self.rows_read * self.cols as u64 * 8;
        read_exact_at(&self.file, &mut self.window[..bytes], offset).with_context(|| {
            format!(
                "{}: truncated in rows {}..{} of {}",
                self.path,
                self.rows_read,
                self.rows_read + left as u64,
                self.rows_total
            )
        })?;
        out.extend(
            self.window[..bytes]
                .chunks_exact(8)
                .map(|c| f64::from_le_bytes(c.try_into().unwrap())),
        );
        self.rows_read += left as u64;
        Ok(left)
    }
}

// -------------------------------------------------------------- in-memory

/// A `ChunkedReader` over an in-memory matrix — the test/bench adapter that
/// lets the streamed path be compared against its in-memory baseline, and
/// the experiment harnesses exercise the streaming fold without touching
/// disk.
pub struct MatChunkedReader<'a> {
    x: &'a Mat,
    next_row: usize,
}

impl<'a> MatChunkedReader<'a> {
    pub fn new(x: &'a Mat) -> Self {
        Self { x, next_row: 0 }
    }
}

impl ChunkedReader for MatChunkedReader<'_> {
    fn dim(&self) -> usize {
        self.x.cols()
    }

    fn next_block(&mut self, max_rows: usize, out: &mut Vec<f64>) -> Result<usize> {
        let rows = max_rows.min(self.x.rows() - self.next_row);
        let cols = self.x.cols();
        let start = self.next_row * cols;
        out.extend_from_slice(&self.x.as_slice()[start..start + rows * cols]);
        self.next_row += rows;
        Ok(rows)
    }
}
