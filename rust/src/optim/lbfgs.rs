//! Projected L-BFGS for box-constrained smooth minimization.
//!
//! This is the "gradient-projection + limited-memory BFGS direction"
//! variant: iterates are kept feasible by clipping to the box, the search
//! direction comes from the standard two-loop recursion on the *projected*
//! gradient history, and an Armijo backtracking line search (on the
//! projected path) guarantees monotone descent. For the smooth, moderately
//! conditioned objectives of CL-OMPR (sums of sinusoids) it reaches the
//! same optima as a textbook L-BFGS-B at a fraction of the complexity, and
//! the decoder only needs local optima anyway (it restarts globally).

use crate::linalg::{dot, norm2};

/// Box constraints `lo ≤ x ≤ hi`, per coordinate. `None` = unbounded side.
#[derive(Clone, Debug)]
pub struct Bounds {
    pub lo: Vec<Option<f64>>,
    pub hi: Vec<Option<f64>>,
}

impl Bounds {
    /// Fully unbounded in `n` dimensions.
    pub fn unbounded(n: usize) -> Self {
        Self {
            lo: vec![None; n],
            hi: vec![None; n],
        }
    }

    /// A closed box `[lo_i, hi_i]` in every coordinate.
    pub fn boxed(lo: &[f64], hi: &[f64]) -> Self {
        assert_eq!(lo.len(), hi.len());
        assert!(
            lo.iter().zip(hi).all(|(a, b)| a <= b),
            "box bounds must satisfy lo <= hi"
        );
        Self {
            lo: lo.iter().map(|&v| Some(v)).collect(),
            hi: hi.iter().map(|&v| Some(v)).collect(),
        }
    }

    /// Concatenate (for joint (C, α) variables).
    pub fn concat(mut self, other: Bounds) -> Bounds {
        self.lo.extend(other.lo);
        self.hi.extend(other.hi);
        self
    }

    /// Only a lower bound (e.g. `α ≥ 0`).
    pub fn lower(lo: &[f64]) -> Self {
        Self {
            lo: lo.iter().map(|&v| Some(v)).collect(),
            hi: vec![None; lo.len()],
        }
    }

    pub fn len(&self) -> usize {
        self.lo.len()
    }

    pub fn is_empty(&self) -> bool {
        self.lo.is_empty()
    }

    /// Project `x` onto the box in place.
    pub fn project(&self, x: &mut [f64]) {
        assert_eq!(x.len(), self.lo.len(), "bounds dimension mismatch");
        for i in 0..x.len() {
            if let Some(l) = self.lo[i] {
                if x[i] < l {
                    x[i] = l;
                }
            }
            if let Some(h) = self.hi[i] {
                if x[i] > h {
                    x[i] = h;
                }
            }
        }
    }

    /// The projected-gradient stationarity measure
    /// `‖P(x − g) − x‖∞` (zero at a KKT point).
    pub fn stationarity(&self, x: &[f64], g: &[f64]) -> f64 {
        let mut y: Vec<f64> = x.iter().zip(g).map(|(xi, gi)| xi - gi).collect();
        self.project(&mut y);
        y.iter()
            .zip(x)
            .map(|(yi, xi)| (yi - xi).abs())
            .fold(0.0, f64::max)
    }
}

/// Tuning knobs for [`lbfgsb`].
#[derive(Clone, Debug)]
pub struct LbfgsParams {
    /// History size (pairs kept by the two-loop recursion).
    pub memory: usize,
    /// Maximum iterations.
    pub max_iters: usize,
    /// Stop when the projected-gradient sup-norm falls below this.
    pub pg_tol: f64,
    /// Stop when the relative objective decrease falls below this.
    pub f_tol: f64,
    /// Armijo sufficient-decrease constant.
    pub armijo_c: f64,
    /// Line-search shrink factor.
    pub backtrack: f64,
    /// Max line-search trials per iteration.
    pub max_ls: usize,
}

impl Default for LbfgsParams {
    fn default() -> Self {
        Self {
            memory: 8,
            max_iters: 200,
            pg_tol: 1e-7,
            f_tol: 1e-12,
            armijo_c: 1e-4,
            backtrack: 0.5,
            max_ls: 30,
        }
    }
}

/// Outcome of an [`lbfgsb`] run.
#[derive(Clone, Debug)]
pub struct LbfgsResult {
    pub x: Vec<f64>,
    pub f: f64,
    pub iters: usize,
    /// Final projected-gradient sup-norm.
    pub pg_norm: f64,
    /// True if the tolerance (not the iteration cap) stopped the run.
    pub converged: bool,
    /// Total objective/gradient evaluations.
    pub evals: usize,
}

/// Minimize `f` over the box, starting at `x0`.
///
/// `func` evaluates the objective and writes the gradient into its second
/// argument, returning the objective value.
pub fn lbfgsb(
    mut func: impl FnMut(&[f64], &mut [f64]) -> f64,
    x0: &[f64],
    bounds: &Bounds,
    params: &LbfgsParams,
) -> LbfgsResult {
    let n = x0.len();
    assert_eq!(bounds.len(), n, "bounds/variable dimension mismatch");
    let mut x = x0.to_vec();
    bounds.project(&mut x);
    let mut g = vec![0.0; n];
    let mut f = func(&x, &mut g);
    let mut evals = 1usize;

    // L-BFGS history.
    let m = params.memory.max(1);
    let mut s_hist: Vec<Vec<f64>> = Vec::with_capacity(m);
    let mut y_hist: Vec<Vec<f64>> = Vec::with_capacity(m);
    let mut rho: Vec<f64> = Vec::with_capacity(m);

    let mut pg = bounds.stationarity(&x, &g);
    let mut iters = 0;
    let mut converged = pg <= params.pg_tol;

    while iters < params.max_iters && !converged {
        iters += 1;

        // Two-loop recursion for d = −H·g.
        let mut d: Vec<f64> = g.iter().map(|v| -v).collect();
        let k = s_hist.len();
        let mut alpha = vec![0.0; k];
        for i in (0..k).rev() {
            alpha[i] = rho[i] * dot(&s_hist[i], &d);
            crate::linalg::axpy(-alpha[i], &y_hist[i], &mut d);
        }
        if k > 0 {
            let last = k - 1;
            let gamma = dot(&s_hist[last], &y_hist[last]) / dot(&y_hist[last], &y_hist[last]);
            if gamma.is_finite() && gamma > 0.0 {
                crate::linalg::scale(gamma, &mut d);
            }
        }
        for i in 0..k {
            let beta = rho[i] * dot(&y_hist[i], &d);
            crate::linalg::axpy(alpha[i] - beta, &s_hist[i], &mut d);
        }

        // Ensure descent; fall back to steepest descent if curvature info
        // produced an ascent direction (can happen right after projection).
        if dot(&d, &g) >= 0.0 {
            for (di, gi) in d.iter_mut().zip(&g) {
                *di = -gi;
            }
            s_hist.clear();
            y_hist.clear();
            rho.clear();
        }

        // Backtracking Armijo search along the projected path
        // x(t) = P(x + t d).
        let f0 = f;
        let g0_dot_d = dot(&g, &d);
        let mut t = 1.0;
        let mut x_new = vec![0.0; n];
        let mut g_new = vec![0.0; n];
        let mut f_new;
        let mut ls_ok = false;
        for _ in 0..params.max_ls {
            for i in 0..n {
                x_new[i] = x[i] + t * d[i];
            }
            bounds.project(&mut x_new);
            f_new = func(&x_new, &mut g_new);
            evals += 1;
            // Armijo on the projected step: use the actual displacement.
            let disp: Vec<f64> = x_new.iter().zip(&x).map(|(a, b)| a - b).collect();
            let pred = dot(&g, &disp).min(t * g0_dot_d);
            if f_new <= f0 + params.armijo_c * pred || norm2(&disp) == 0.0 {
                // Accept (or the step collapsed to zero — handled below).
                if norm2(&disp) == 0.0 {
                    break;
                }
                // Curvature pair from the accepted step.
                let s: Vec<f64> = disp;
                let yv: Vec<f64> = g_new.iter().zip(&g).map(|(a, b)| a - b).collect();
                let sy = dot(&s, &yv);
                if sy > 1e-12 * norm2(&s) * norm2(&yv) {
                    if s_hist.len() == m {
                        s_hist.remove(0);
                        y_hist.remove(0);
                        rho.remove(0);
                    }
                    rho.push(1.0 / sy);
                    s_hist.push(s);
                    y_hist.push(yv);
                }
                x.copy_from_slice(&x_new);
                g.copy_from_slice(&g_new);
                f = f_new;
                ls_ok = true;
                break;
            }
            t *= params.backtrack;
        }

        pg = bounds.stationarity(&x, &g);
        let f_rel = (f0 - f).abs() / f0.abs().max(1.0);
        if pg <= params.pg_tol || (ls_ok && f_rel <= params.f_tol) || !ls_ok {
            converged = pg <= params.pg_tol || f_rel <= params.f_tol;
            break;
        }
    }

    LbfgsResult {
        x,
        f,
        iters,
        pg_norm: pg,
        converged,
        evals,
    }
}
