//! Lawson–Hanson non-negative least squares.
//!
//! Solves `min_x ‖A x − b‖₂  s.t.  x ≥ 0` by active-set iteration: grow a
//! passive set P greedily by the most positive gradient coordinate, solve
//! the unconstrained LS on P (Householder QR from `crate::linalg`), and
//! back-track along the segment to feasibility whenever the LS solution
//! leaves the positive orthant. Finite termination is guaranteed; sizes in
//! this crate are tiny (columns = |C| ≤ 2K), so no fancy updating is needed.

use crate::linalg::{lstsq, matvec, matvec_t, sub, Mat};

/// Solve `min ‖A x − b‖, x ≥ 0`. Returns the solution (length `A.cols()`).
pub fn nnls(a: &Mat, b: &[f64]) -> Vec<f64> {
    let (m, n) = a.shape();
    assert_eq!(b.len(), m, "nnls: rhs length mismatch");
    let mut x = vec![0.0; n];
    let mut passive = vec![false; n];

    // w = Aᵀ(b − A x): the negative gradient.
    let mut w = matvec_t(a, b);
    let tol = 1e-10 * a.max_abs().max(1.0) * b.iter().fold(0.0f64, |acc, v| acc.max(v.abs())).max(1.0);

    for _outer in 0..(3 * n.max(10)) {
        // Pick the most promising zero coordinate.
        let mut best = None;
        let mut best_w = tol;
        for j in 0..n {
            if !passive[j] && w[j] > best_w {
                best_w = w[j];
                best = Some(j);
            }
        }
        let Some(j_star) = best else { break };
        passive[j_star] = true;

        // Inner loop: LS on the passive set, clip to feasibility.
        loop {
            let p_idx: Vec<usize> = (0..n).filter(|&j| passive[j]).collect();
            if p_idx.is_empty() {
                break;
            }
            // Sub-matrix with passive columns.
            let ap = Mat::from_fn(m, p_idx.len(), |r, c| a.get(r, p_idx[c]));
            let z = match lstsq(&ap, b) {
                Some(z) => z,
                None => {
                    // Rank-deficient passive set: drop the newest column.
                    passive[*p_idx.last().unwrap()] = false;
                    break;
                }
            };
            if z.iter().all(|&v| v > tol) {
                // Fully feasible LS solution on P.
                x.fill(0.0);
                for (c, &j) in p_idx.iter().enumerate() {
                    x[j] = z[c];
                }
                break;
            }
            // Back-track: find the largest step keeping x ≥ 0, zero the
            // blocking coordinates, and retry.
            let mut alpha = 1.0f64;
            for (c, &j) in p_idx.iter().enumerate() {
                if z[c] <= tol {
                    let xj = x[j];
                    let denom = xj - z[c];
                    if denom > 0.0 {
                        alpha = alpha.min(xj / denom);
                    }
                }
            }
            for (c, &j) in p_idx.iter().enumerate() {
                x[j] += alpha * (z[c] - x[j]);
                if x[j] <= tol {
                    x[j] = 0.0;
                    passive[j] = false;
                }
            }
        }

        // Refresh the gradient.
        let r = sub(b, &matvec(a, &x));
        w = matvec_t(a, &r);
        if (0..n).all(|j| passive[j] || w[j] <= tol) {
            break;
        }
    }
    x
}
