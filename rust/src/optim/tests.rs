//! Optimizer tests: quadratics with known solutions, Rosenbrock, boxes,
//! NNLS against KKT conditions.

use super::*;
use crate::linalg::{matvec, matvec_t, sub, Mat};
use crate::rng::Rng;

fn quadratic<'a>(a: &'a Mat, b: &'a [f64]) -> impl FnMut(&[f64], &mut [f64]) -> f64 + 'a {
    // f(x) = ½ xᵀAx − bᵀx, ∇f = Ax − b.
    move |x, g| {
        let ax = matvec(a, x);
        for i in 0..x.len() {
            g[i] = ax[i] - b[i];
        }
        0.5 * crate::linalg::dot(x, &ax) - crate::linalg::dot(b, x)
    }
}

#[test]
fn lbfgs_solves_unconstrained_quadratic() {
    let a = Mat::from_vec(3, 3, vec![4., 1., 0., 1., 3., 0.5, 0., 0.5, 2.]);
    let b = vec![1.0, -2.0, 0.5];
    let res = lbfgsb(
        quadratic(&a, &b),
        &[0.0; 3],
        &Bounds::unbounded(3),
        &LbfgsParams::default(),
    );
    assert!(res.converged, "did not converge: {res:?}");
    // Solution solves A x = b.
    let ax = matvec(&a, &res.x);
    for (l, r) in ax.iter().zip(&b) {
        assert!((l - r).abs() < 1e-5, "Ax−b residual");
    }
}

#[test]
fn lbfgs_respects_box_constraints() {
    // min (x−3)² + (y+2)² on [0,1]×[0,1] → (1, 0).
    let f = |x: &[f64], g: &mut [f64]| {
        g[0] = 2.0 * (x[0] - 3.0);
        g[1] = 2.0 * (x[1] + 2.0);
        (x[0] - 3.0).powi(2) + (x[1] + 2.0).powi(2)
    };
    let bounds = Bounds::boxed(&[0.0, 0.0], &[1.0, 1.0]);
    let res = lbfgsb(f, &[0.5, 0.5], &bounds, &LbfgsParams::default());
    assert!((res.x[0] - 1.0).abs() < 1e-7, "x = {:?}", res.x);
    assert!(res.x[1].abs() < 1e-7, "x = {:?}", res.x);
    assert!(res.converged);
}

#[test]
fn lbfgs_rosenbrock() {
    let f = |x: &[f64], g: &mut [f64]| {
        let (a, b) = (x[0], x[1]);
        g[0] = -2.0 * (1.0 - a) - 400.0 * a * (b - a * a);
        g[1] = 200.0 * (b - a * a);
        (1.0 - a).powi(2) + 100.0 * (b - a * a).powi(2)
    };
    let p = LbfgsParams {
        max_iters: 2000,
        ..LbfgsParams::default()
    };
    let res = lbfgsb(f, &[-1.2, 1.0], &Bounds::unbounded(2), &p);
    assert!(
        (res.x[0] - 1.0).abs() < 1e-4 && (res.x[1] - 1.0).abs() < 1e-4,
        "rosenbrock solution {:?} after {} iters",
        res.x,
        res.iters
    );
}

#[test]
fn lbfgs_sinusoidal_objective_finds_local_min() {
    // The decoder's objective class: sum of cosines. From a start near a
    // basin, it must find that basin's minimum.
    let f = |x: &[f64], g: &mut [f64]| {
        g[0] = -3.0 * (3.0 * x[0]).sin(); // d/dx cos(3x) = −3 sin(3x)
        (3.0 * x[0]).cos()
    };
    let res = lbfgsb(
        f,
        &[0.9],
        &Bounds::boxed(&[0.0], &[2.0]),
        &LbfgsParams::default(),
    );
    // Nearest minimum of cos(3x): 3x = π → x = π/3 ≈ 1.0472.
    assert!(
        (res.x[0] - std::f64::consts::PI / 3.0).abs() < 1e-6,
        "x = {:?}",
        res.x
    );
}

#[test]
fn lbfgs_starts_projected_if_infeasible() {
    let f = |x: &[f64], g: &mut [f64]| {
        g[0] = 2.0 * x[0];
        x[0] * x[0]
    };
    let res = lbfgsb(
        f,
        &[10.0],
        &Bounds::boxed(&[1.0], &[5.0]),
        &LbfgsParams::default(),
    );
    assert!((res.x[0] - 1.0).abs() < 1e-9);
}

#[test]
fn bounds_helpers() {
    let b = Bounds::boxed(&[0.0], &[1.0]).concat(Bounds::lower(&[0.0, 0.0]));
    assert_eq!(b.len(), 3);
    assert!(!b.is_empty());
    let mut x = vec![2.0, -1.0, 5.0];
    b.project(&mut x);
    assert_eq!(x, vec![1.0, 0.0, 5.0]);
    // Stationarity: zero gradient → zero measure.
    assert_eq!(b.stationarity(&x, &[0.0, 0.0, 0.0]), 0.0);
    // Gradient pushing out of the box → measure 0 at the boundary.
    assert_eq!(b.stationarity(&[1.0, 0.0, 1.0], &[-1.0, 1.0, 0.0]), 0.0);
}

#[test]
#[should_panic]
fn bounds_reject_inverted_box() {
    let _ = Bounds::boxed(&[1.0], &[0.0]);
}

#[test]
fn nnls_matches_unconstrained_when_interior() {
    // If the LS solution is positive, NNLS must return it.
    let a = Mat::from_vec(4, 2, vec![1., 0., 0., 1., 1., 1., 1., -1.]);
    let x_true = [2.0, 1.0];
    let b = matvec(&a, &x_true);
    let x = nnls(&a, &b);
    for (xi, ti) in x.iter().zip(&x_true) {
        assert!((xi - ti).abs() < 1e-8, "nnls {x:?}");
    }
}

#[test]
fn nnls_clamps_negative_coordinates() {
    // LS solution has a negative coordinate → NNLS must zero it.
    let a = Mat::from_vec(3, 2, vec![1., 1., 1., 1.000001, 1., 1.]);
    let b = [1.0, -0.5, 0.7];
    let x = nnls(&a, &b);
    assert!(x.iter().all(|&v| v >= 0.0), "negative output {x:?}");
    // KKT: for active coordinates (x_j = 0), gradient w_j = (Aᵀr)_j ≤ tol.
    let r = sub(&b, &matvec(&a, &x));
    let w = matvec_t(&a, &r);
    for (j, (&xj, &wj)) in x.iter().zip(&w).enumerate() {
        if xj == 0.0 {
            assert!(wj < 1e-6, "KKT violated at {j}: w = {wj}");
        } else {
            assert!(wj.abs() < 1e-6, "stationarity violated at {j}: w = {wj}");
        }
    }
}

#[test]
fn nnls_random_problems_satisfy_kkt() {
    let mut rng = Rng::new(123);
    for trial in 0..25 {
        let m = 20 + (trial % 5) * 7;
        let n = 2 + trial % 6;
        let a = Mat::from_fn(m, n, |_, _| rng.gaussian());
        let b: Vec<f64> = (0..m).map(|_| rng.gaussian()).collect();
        let x = nnls(&a, &b);
        assert!(x.iter().all(|&v| v >= 0.0));
        let r = sub(&b, &matvec(&a, &x));
        let w = matvec_t(&a, &r);
        for j in 0..n {
            if x[j] > 1e-9 {
                assert!(w[j].abs() < 1e-6, "trial {trial}: w[{j}] = {}", w[j]);
            } else {
                assert!(w[j] < 1e-6, "trial {trial}: w[{j}] = {}", w[j]);
            }
        }
    }
}

#[test]
fn nnls_zero_rhs_gives_zero() {
    let a = Mat::from_vec(3, 2, vec![1., 2., 3., 4., 5., 6.]);
    let x = nnls(&a, &[0.0, 0.0, 0.0]);
    assert!(x.iter().all(|&v| v == 0.0));
}

#[test]
fn nnls_handles_duplicate_columns() {
    // Rank-deficient A: two identical columns. Any split is optimal; the
    // solver must terminate and satisfy x ≥ 0 with small residual gradient.
    let a = Mat::from_vec(3, 2, vec![1., 1., 2., 2., 3., 3.]);
    let b = [2.0, 4.0, 6.0];
    let x = nnls(&a, &b);
    let fitted = matvec(&a, &x);
    for (f, t) in fitted.iter().zip(&b) {
        assert!((f - t).abs() < 1e-6, "fit {fitted:?}");
    }
}
