//! Numerical optimizers backing the CL-OMPR decoder.
//!
//! CL-OMPR needs two solvers, both implemented here from scratch:
//!
//! * [`lbfgsb`] — box-constrained limited-memory quasi-Newton minimization
//!   (projected L-BFGS with Armijo backtracking). Used for Step 1 (find a
//!   centroid correlated with the residual, `l ≤ c ≤ u`) and Step 5 (joint
//!   refinement of all centroids and weights, with `α ≥ 0`).
//! * [`nnls`] — non-negative least squares `min ‖A x − b‖, x ≥ 0` via
//!   Lawson–Hanson active sets. Used for Steps 3 and 4 (support reduction
//!   and weight projection).

mod lbfgs;
mod nnls;

pub use lbfgs::{lbfgsb, Bounds, LbfgsParams, LbfgsResult};
pub use nnls::nnls;

#[cfg(test)]
mod tests;
