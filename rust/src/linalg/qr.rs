//! Householder QR and dense least squares.
//!
//! Backs the passive-set solves inside Lawson–Hanson NNLS
//! ([`crate::optim::nnls`]). Sizes there are tiny (2m × |C| with |C| ≤ 2K),
//! so a straightforward column-by-column Householder factorization is both
//! robust and fast enough.

use super::Mat;

/// A thin Householder QR factorization of an `m × n` matrix with `m ≥ n`.
pub struct QrFactorization {
    /// Packed factors: R in the upper triangle, Householder vectors below.
    qr: Mat,
    /// Householder scalar coefficients (tau).
    tau: Vec<f64>,
}

impl QrFactorization {
    /// Factor `a` (consumed by copy). Requires `rows ≥ cols`.
    pub fn new(a: &Mat) -> Self {
        let (m, n) = a.shape();
        assert!(m >= n, "QR requires rows >= cols, got {m}x{n}");
        let mut qr = a.clone();
        let mut tau = vec![0.0; n];
        for k in 0..n {
            // Build the Householder reflector for column k below the diagonal.
            let mut norm = 0.0;
            for i in k..m {
                let v = qr.get(i, k);
                norm += v * v;
            }
            let norm = norm.sqrt();
            if norm == 0.0 {
                tau[k] = 0.0;
                continue;
            }
            let akk = qr.get(k, k);
            let alpha = if akk >= 0.0 { -norm } else { norm };
            // v = x - alpha e1, normalized so v[k] = 1.
            let vkk = akk - alpha;
            for i in (k + 1)..m {
                let v = qr.get(i, k) / vkk;
                qr.set(i, k, v);
            }
            tau[k] = -vkk / alpha;
            qr.set(k, k, alpha);
            // Apply the reflector to the remaining columns.
            for j in (k + 1)..n {
                let mut s = qr.get(k, j);
                for i in (k + 1)..m {
                    s += qr.get(i, k) * qr.get(i, j);
                }
                s *= tau[k];
                let cur = qr.get(k, j);
                qr.set(k, j, cur - s);
                for i in (k + 1)..m {
                    let cur = qr.get(i, j);
                    let vik = qr.get(i, k);
                    qr.set(i, j, cur - s * vik);
                }
            }
        }
        Self { qr, tau }
    }

    /// Apply `Qᵀ` to a vector in place.
    fn apply_qt(&self, b: &mut [f64]) {
        let (m, n) = self.qr.shape();
        assert_eq!(b.len(), m);
        for k in 0..n {
            if self.tau[k] == 0.0 {
                continue;
            }
            let mut s = b[k];
            for i in (k + 1)..m {
                s += self.qr.get(i, k) * b[i];
            }
            s *= self.tau[k];
            b[k] -= s;
            for i in (k + 1)..m {
                b[i] -= s * self.qr.get(i, k);
            }
        }
    }

    /// Solve the least-squares problem `min ‖A x − b‖₂`.
    ///
    /// Returns `None` if R is numerically singular (rank-deficient A).
    pub fn solve(&self, b: &[f64]) -> Option<Vec<f64>> {
        let (m, n) = self.qr.shape();
        assert_eq!(b.len(), m);
        let mut y = b.to_vec();
        self.apply_qt(&mut y);
        // Back-substitute R x = y[..n].
        let mut x = vec![0.0; n];
        for k in (0..n).rev() {
            let rkk = self.qr.get(k, k);
            if rkk.abs() < 1e-12 * self.qr.max_abs().max(1.0) {
                return None;
            }
            let mut s = y[k];
            for j in (k + 1)..n {
                s -= self.qr.get(k, j) * x[j];
            }
            x[k] = s / rkk;
        }
        Some(x)
    }
}

/// One-shot dense least squares `argmin_x ‖A x − b‖₂` (A must be tall).
pub fn lstsq(a: &Mat, b: &[f64]) -> Option<Vec<f64>> {
    QrFactorization::new(a).solve(b)
}
