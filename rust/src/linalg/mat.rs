//! Row-major dense matrix.

use std::fmt;

/// A dense row-major `rows × cols` matrix of `f64`.
///
/// Rows are the natural unit in this crate (a dataset is `N × n` with one
/// example per row; a frequency matrix is `n × M`).
#[derive(Clone, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Mat {
    /// All-zeros matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    /// Build from a flat row-major buffer (length must be `rows*cols`).
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(
            data.len(),
            rows * cols,
            "Mat::from_vec: buffer length {} != {rows}x{cols}",
            data.len()
        );
        Self { rows, cols, data }
    }

    /// Build from a closure `f(row, col)`.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                data.push(f(r, c));
            }
        }
        Self { rows, cols, data }
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Self {
        Self::from_fn(n, n, |r, c| if r == c { 1.0 } else { 0.0 })
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> f64 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f64) {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c] = v;
    }

    /// Borrow row `r` as a slice.
    #[inline]
    pub fn row(&self, r: usize) -> &[f64] {
        debug_assert!(r < self.rows);
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Mutably borrow row `r`.
    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f64] {
        debug_assert!(r < self.rows);
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Copy column `c` out into a vector.
    pub fn col(&self, c: usize) -> Vec<f64> {
        debug_assert!(c < self.cols);
        (0..self.rows).map(|r| self.get(r, c)).collect()
    }

    /// Flat row-major view of the whole buffer.
    #[inline]
    pub fn as_slice(&self) -> &[f64] {
        &self.data
    }

    /// Flat mutable row-major view.
    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Consume into the flat buffer.
    pub fn into_vec(self) -> Vec<f64> {
        self.data
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        // Blocked transpose for cache friendliness on big matrices.
        const B: usize = 32;
        for rb in (0..self.rows).step_by(B) {
            for cb in (0..self.cols).step_by(B) {
                for r in rb..(rb + B).min(self.rows) {
                    for c in cb..(cb + B).min(self.cols) {
                        out.data[c * self.rows + r] = self.data[r * self.cols + c];
                    }
                }
            }
        }
        out
    }

    /// A new matrix keeping only the listed rows (in the given order).
    pub fn select_rows(&self, idx: &[usize]) -> Mat {
        let mut out = Mat::zeros(idx.len(), self.cols);
        for (i, &r) in idx.iter().enumerate() {
            out.row_mut(i).copy_from_slice(self.row(r));
        }
        out
    }

    /// Append a row (must match `cols`).
    pub fn push_row(&mut self, row: &[f64]) {
        assert_eq!(row.len(), self.cols, "push_row dimension mismatch");
        self.data.extend_from_slice(row);
        self.rows += 1;
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    /// Max absolute entry.
    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0, |a, &x| a.max(x.abs()))
    }
}

impl fmt::Debug for Mat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Mat {}x{} [", self.rows, self.cols)?;
        let show_rows = self.rows.min(6);
        for r in 0..show_rows {
            let row = self.row(r);
            let shown: Vec<String> = row.iter().take(8).map(|v| format!("{v:9.4}")).collect();
            writeln!(
                f,
                "  [{}{}]",
                shown.join(", "),
                if self.cols > 8 { ", …" } else { "" }
            )?;
        }
        if self.rows > show_rows {
            writeln!(f, "  …")?;
        }
        write!(f, "]")
    }
}
