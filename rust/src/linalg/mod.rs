//! Minimal dense linear algebra, built from scratch (no external crates).
//!
//! The compressive-clustering pipeline needs: a row-major matrix type,
//! matrix-matrix and matrix-vector products (the sketch encode is one big
//! `X · Ω`), vector kernels (dot/axpy/norms) for the optimizers, and a
//! Householder-QR least-squares solver that backs the Lawson–Hanson NNLS in
//! [`crate::optim::nnls`].
//!
//! Everything is `f64`: the decoder's line searches are sensitive to
//! round-off and the sketch sizes involved (m ≲ 10⁴) make memory a non-issue.

mod mat;
mod ops;
mod qr;

pub use mat::Mat;
pub use ops::*;
pub use qr::{lstsq, QrFactorization};

#[cfg(test)]
mod tests;
