//! Vector kernels and matrix products.
//!
//! The `dot`/`axpy` primitives dispatch through [`crate::kernel`] (portable
//! scalar reference vs runtime-selected SIMD — bitwise identical either
//! way, I-22); the gemm here is a simple register-blocked ikj loop built on
//! them — enough to keep the sketch encode memory-bound rather than
//! instruction-bound (see EXPERIMENTS.md §Perf for measurements against
//! the roofline).

use super::Mat;

/// Dot product — dispatched through [`crate::kernel`] (scalar reference or
/// runtime-selected SIMD; bitwise identical either way, I-22).
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    crate::kernel::dot(a, b)
}

/// `y += alpha * x` — dispatched through [`crate::kernel`] (scalar
/// reference or runtime-selected SIMD; bitwise identical either way, I-22).
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    crate::kernel::axpy(alpha, x, y)
}

/// `x *= alpha`.
#[inline]
pub fn scale(alpha: f64, x: &mut [f64]) {
    for xi in x.iter_mut() {
        *xi *= alpha;
    }
}

/// Euclidean norm.
#[inline]
pub fn norm2(x: &[f64]) -> f64 {
    dot(x, x).sqrt()
}

/// Squared Euclidean distance between two vectors.
#[inline]
pub fn sq_dist(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut s = 0.0;
    for (x, y) in a.iter().zip(b) {
        let d = x - y;
        s += d * d;
    }
    s
}

/// `out = a - b` (allocating).
pub fn sub(a: &[f64], b: &[f64]) -> Vec<f64> {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x - y).collect()
}

/// Dense matrix-vector product `A·x`.
pub fn matvec(a: &Mat, x: &[f64]) -> Vec<f64> {
    assert_eq!(a.cols(), x.len(), "matvec: {:?} · {}", a.shape(), x.len());
    (0..a.rows()).map(|r| dot(a.row(r), x)).collect()
}

/// Dense transposed matrix-vector product `Aᵀ·x`.
pub fn matvec_t(a: &Mat, x: &[f64]) -> Vec<f64> {
    assert_eq!(a.rows(), x.len(), "matvec_t: {:?}ᵀ · {}", a.shape(), x.len());
    let mut out = vec![0.0; a.cols()];
    for (r, &xr) in x.iter().enumerate() {
        axpy(xr, a.row(r), &mut out);
    }
    out
}

/// Dense matrix product `A·B`, cache-blocked ikj ordering.
pub fn matmul(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(
        a.cols(),
        b.rows(),
        "matmul: {:?} · {:?}",
        a.shape(),
        b.shape()
    );
    let mut c = Mat::zeros(a.rows(), b.cols());
    matmul_into(a, b, &mut c);
    c
}

/// `C = A·B` into a preallocated output (C is overwritten).
///
/// ikj loop order: the inner loop streams a row of B and a row of C with unit
/// stride, so the compiler autovectorizes it; blocking over k keeps the B
/// panel in L1/L2.
pub fn matmul_into(a: &Mat, b: &Mat, c: &mut Mat) {
    assert_eq!(a.cols(), b.rows());
    assert_eq!(c.shape(), (a.rows(), b.cols()));
    let (m, kk) = (a.rows(), a.cols());
    c.as_mut_slice().fill(0.0);
    const KB: usize = 256; // k-panel
    for k0 in (0..kk).step_by(KB) {
        let k1 = (k0 + KB).min(kk);
        for i in 0..m {
            let arow = a.row(i);
            let crow = c.row_mut(i);
            for k in k0..k1 {
                let aik = arow[k];
                if aik == 0.0 {
                    continue;
                }
                let brow = b.row(k);
                axpy(aik, brow, crow);
            }
        }
    }
}

/// `C = Aᵀ·B` without materializing the transpose.
pub fn matmul_tn(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.rows(), b.rows(), "matmul_tn shape mismatch");
    let (k, m, n) = (a.rows(), a.cols(), b.cols());
    let mut c = Mat::zeros(m, n);
    for p in 0..k {
        let arow = a.row(p);
        let brow = b.row(p);
        for i in 0..m {
            let aip = arow[i];
            if aip == 0.0 {
                continue;
            }
            axpy(aip, brow, c.row_mut(i));
        }
    }
    c
}

/// Mean of the rows of `a`.
pub fn row_mean(a: &Mat) -> Vec<f64> {
    let mut mean = vec![0.0; a.cols()];
    for r in 0..a.rows() {
        axpy(1.0, a.row(r), &mut mean);
    }
    scale(1.0 / a.rows().max(1) as f64, &mut mean);
    mean
}

/// Per-coordinate min and max over the rows of `a` — the data bounding box
/// `l ≤ x ≤ u` the CL-OMPR centroid searches are constrained to.
pub fn bounding_box(a: &Mat) -> (Vec<f64>, Vec<f64>) {
    assert!(a.rows() > 0, "bounding box of empty matrix");
    let mut lo = a.row(0).to_vec();
    let mut hi = a.row(0).to_vec();
    for r in 1..a.rows() {
        for (c, &v) in a.row(r).iter().enumerate() {
            if v < lo[c] {
                lo[c] = v;
            }
            if v > hi[c] {
                hi[c] = v;
            }
        }
    }
    (lo, hi)
}
