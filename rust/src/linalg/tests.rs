//! Unit tests for the linalg substrate.

use super::*;
use crate::rng::Rng;

fn random_mat(rng: &mut Rng, r: usize, c: usize) -> Mat {
    Mat::from_fn(r, c, |_, _| rng.gaussian())
}

#[test]
fn mat_basics() {
    let mut m = Mat::zeros(2, 3);
    assert_eq!(m.shape(), (2, 3));
    m.set(1, 2, 5.0);
    assert_eq!(m.get(1, 2), 5.0);
    assert_eq!(m.row(1), &[0.0, 0.0, 5.0]);
    assert_eq!(m.col(2), vec![0.0, 5.0]);
    m.row_mut(0)[0] = -1.0;
    assert_eq!(m.as_slice(), &[-1.0, 0.0, 0.0, 0.0, 0.0, 5.0]);
}

#[test]
#[should_panic]
fn from_vec_rejects_bad_len() {
    let _ = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0]);
}

#[test]
fn eye_and_matmul_identity() {
    let mut rng = Rng::new(1);
    let a = random_mat(&mut rng, 7, 7);
    let i = Mat::eye(7);
    let ai = matmul(&a, &i);
    let ia = matmul(&i, &a);
    assert!((0..49).all(|k| (ai.as_slice()[k] - a.as_slice()[k]).abs() < 1e-12));
    assert!((0..49).all(|k| (ia.as_slice()[k] - a.as_slice()[k]).abs() < 1e-12));
}

#[test]
fn transpose_round_trip_and_blocked_path() {
    let mut rng = Rng::new(2);
    // > 32 in both dims to exercise the blocking.
    let a = random_mat(&mut rng, 45, 70);
    let att = a.transpose().transpose();
    assert_eq!(a, att);
    assert_eq!(a.transpose().shape(), (70, 45));
    assert_eq!(a.get(3, 60), a.transpose().get(60, 3));
}

#[test]
fn matmul_against_naive() {
    let mut rng = Rng::new(3);
    let a = random_mat(&mut rng, 13, 300); // k > KB exercises panel loop
    let b = random_mat(&mut rng, 300, 9);
    let c = matmul(&a, &b);
    for i in 0..13 {
        for j in 0..9 {
            let want: f64 = (0..300).map(|k| a.get(i, k) * b.get(k, j)).sum();
            assert!(
                (c.get(i, j) - want).abs() < 1e-9 * want.abs().max(1.0),
                "c[{i},{j}]"
            );
        }
    }
}

#[test]
fn matmul_tn_matches_explicit_transpose() {
    let mut rng = Rng::new(4);
    let a = random_mat(&mut rng, 20, 6);
    let b = random_mat(&mut rng, 20, 5);
    let c1 = matmul_tn(&a, &b);
    let c2 = matmul(&a.transpose(), &b);
    assert_eq!(c1.shape(), (6, 5));
    for k in 0..30 {
        assert!((c1.as_slice()[k] - c2.as_slice()[k]).abs() < 1e-10);
    }
}

#[test]
fn matvec_and_transposed() {
    let a = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
    assert_eq!(matvec(&a, &[1., 0., -1.]), vec![-2., -2.]);
    assert_eq!(matvec_t(&a, &[1., 1.]), vec![5., 7., 9.]);
}

#[test]
fn vector_kernels() {
    let a = [1.0, 2.0, 3.0, 4.0, 5.0]; // odd len exercises remainder loop
    let b = [5.0, 4.0, 3.0, 2.0, 1.0];
    assert_eq!(dot(&a, &b), 35.0);
    assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-15);
    assert_eq!(sq_dist(&a, &b), 16.0 + 4.0 + 0.0 + 4.0 + 16.0);
    let mut y = vec![1.0, 1.0];
    axpy(2.0, &[3.0, -1.0], &mut y);
    assert_eq!(y, vec![7.0, -1.0]);
    scale(0.5, &mut y);
    assert_eq!(y, vec![3.5, -0.5]);
    assert_eq!(sub(&[3.0, 1.0], &[1.0, 1.0]), vec![2.0, 0.0]);
}

#[test]
fn row_mean_and_bounding_box() {
    let a = Mat::from_vec(3, 2, vec![0., 10., 2., 20., 4., 60.]);
    assert_eq!(row_mean(&a), vec![2.0, 30.0]);
    let (lo, hi) = bounding_box(&a);
    assert_eq!(lo, vec![0.0, 10.0]);
    assert_eq!(hi, vec![4.0, 60.0]);
}

#[test]
fn select_rows_and_push_row() {
    let a = Mat::from_vec(3, 2, vec![1., 2., 3., 4., 5., 6.]);
    let s = a.select_rows(&[2, 0]);
    assert_eq!(s.as_slice(), &[5., 6., 1., 2.]);
    let mut b = Mat::zeros(0, 2);
    b.push_row(&[7.0, 8.0]);
    assert_eq!(b.shape(), (1, 2));
    assert_eq!(b.row(0), &[7.0, 8.0]);
}

#[test]
fn qr_solves_exact_square_system() {
    let a = Mat::from_vec(3, 3, vec![4., 1., 0., 1., 3., 1., 0., 1., 2.]);
    let x_true = [1.0, -2.0, 0.5];
    let b = matvec(&a, &x_true);
    let x = lstsq(&a, &b).expect("solvable");
    for (xi, ti) in x.iter().zip(&x_true) {
        assert!((xi - ti).abs() < 1e-10);
    }
}

#[test]
fn qr_least_squares_matches_normal_equations() {
    let mut rng = Rng::new(5);
    let a = random_mat(&mut rng, 40, 4);
    let b: Vec<f64> = (0..40).map(|_| rng.gaussian()).collect();
    let x = lstsq(&a, &b).expect("full rank w.p. 1");
    // Residual must be orthogonal to the column space: Aᵀ(Ax − b) = 0.
    let ax = matvec(&a, &x);
    let r = sub(&ax, &b);
    let g = matvec_t(&a, &r);
    assert!(norm2(&g) < 1e-9, "normal-equation residual {}", norm2(&g));
}

#[test]
fn qr_detects_rank_deficiency() {
    // Two identical columns.
    let a = Mat::from_vec(4, 2, vec![1., 1., 2., 2., 3., 3., 4., 4.]);
    assert!(lstsq(&a, &[1., 2., 3., 4.]).is_none());
}

#[test]
fn fro_norm_and_max_abs() {
    let a = Mat::from_vec(2, 2, vec![3., 0., 0., -4.]);
    assert!((a.fro_norm() - 5.0).abs() < 1e-15);
    assert_eq!(a.max_abs(), 4.0);
}
