//! Dataset generation and I/O.
//!
//! Provides the synthetic workloads of the paper's evaluation:
//!
//! * [`gaussian_mixture_pm1`] — the Fig. 2a setup: K isotropic Gaussians
//!   with means `±(1,…,1)` (K = 2) or random in `{±1}^n` (general K) and
//!   covariance `(n/20)·Id`, N samples drawn with uniform cluster weights.
//! * [`spectral_embedding_like`] — the Fig. 3 substitute for the private
//!   MNIST spectral-clustering features: K = 10 non-Gaussian, anisotropic,
//!   partially overlapping clusters in ℝ¹⁰ (see DESIGN.md §Substitutions).
//! * CSV/binary dataset I/O so the CLI can cluster user data.

mod io;
mod synth;

pub use io::{load_csv, load_f64_bin, save_csv, save_f64_bin};
pub(crate) use io::parse_csv_line;
pub use synth::{gaussian_mixture_pm1, spectral_embedding_like, LabeledData};

#[cfg(test)]
mod tests;
