//! Synthetic dataset generators matching the paper's experimental setups.

use crate::linalg::Mat;
use crate::rng::Rng;

/// A dataset with ground-truth cluster labels and the true means.
#[derive(Clone, Debug)]
pub struct LabeledData {
    /// `N × n` sample matrix.
    pub points: Mat,
    /// Ground-truth cluster index per row.
    pub labels: Vec<usize>,
    /// `K × n` true cluster means.
    pub means: Mat,
}

/// The paper's Fig. 2 generator.
///
/// Draws `N` samples from `K` isotropic Gaussians with covariance
/// `(n/20)·Id` and uniform weights. Means: for `K = 2`, `±(1,…,1)` exactly
/// as Fig. 2a; for general `K`, drawn uniformly in `{±1}^n` (Fig. 2b),
/// rejecting duplicate corners so the K components are distinct (requires
/// `K ≤ 2^n`).
pub fn gaussian_mixture_pm1(n_samples: usize, dim: usize, k: usize, rng: &mut Rng) -> LabeledData {
    assert!(dim >= 1 && k >= 1 && n_samples >= k);
    let mut means = Mat::zeros(0, dim);
    if k == 2 {
        means.push_row(&vec![1.0; dim]);
        means.push_row(&vec![-1.0; dim]);
    } else {
        assert!(
            (k as f64) <= 2f64.powi(dim.min(60) as i32),
            "cannot place {k} distinct means in {{±1}}^{dim}"
        );
        let mut seen = std::collections::HashSet::new();
        while means.rows() < k {
            let corner: Vec<f64> = (0..dim)
                .map(|_| if rng.next_f64() < 0.5 { -1.0 } else { 1.0 })
                .collect();
            let key: Vec<i8> = corner.iter().map(|&v| v as i8).collect();
            if seen.insert(key) {
                means.push_row(&corner);
            }
        }
    }
    let std = (dim as f64 / 20.0).sqrt();
    let mut points = Mat::zeros(0, dim);
    let mut labels = Vec::with_capacity(n_samples);
    let mut row = vec![0.0; dim];
    for _ in 0..n_samples {
        let c = rng.next_below(k as u64) as usize;
        for (j, v) in row.iter_mut().enumerate() {
            *v = means.get(c, j) + std * rng.gaussian();
        }
        points.push_row(&row);
        labels.push(c);
    }
    LabeledData {
        points,
        labels,
        means,
    }
}

/// Fig. 3 substitute: a spectral-embedding-like 10-class dataset in ℝ¹⁰
/// (see DESIGN.md §Substitutions for the rationale).
///
/// Each cluster k is built to be *non-Gaussian and anisotropic*, mimicking
/// the banana/filament shapes of spectral-clustering feature spaces:
/// a Gaussian with per-axis scales drawn in `[0.02, 0.14]` is curved by a
/// quadratic warp along a random pair of axes, heavy-tailed by scaling with
/// `1/sqrt(u)` on 10% of samples, and placed at a mean on the unit sphere
/// (spectral embeddings are near-normalized). Cluster weights are unequal
/// (Zipf-ish), like real digit frequencies.
pub fn spectral_embedding_like(n_samples: usize, dim: usize, k: usize, rng: &mut Rng) -> LabeledData {
    assert!(dim >= 2 && k >= 1 && n_samples >= k);
    // Cluster means: random directions on the sphere, mildly repelled so
    // clusters overlap partially but not totally.
    let mut means = Mat::zeros(0, dim);
    while means.rows() < k {
        let cand = rng.sphere_direction(dim);
        let ok = (0..means.rows()).all(|j| crate::linalg::sq_dist(&cand, means.row(j)) > 0.35);
        if ok {
            means.push_row(&cand);
        }
    }
    // Per-cluster anisotropic scales, warp axes and strengths.
    let mut scales = Mat::zeros(k, dim);
    let mut warp_from = vec![0usize; k];
    let mut warp_to = vec![0usize; k];
    let mut warp_strength = vec![0.0f64; k];
    for c in 0..k {
        for j in 0..dim {
            scales.set(c, j, rng.uniform(0.02, 0.14));
        }
        warp_from[c] = rng.next_below(dim as u64) as usize;
        warp_to[c] = {
            let mut t = rng.next_below(dim as u64) as usize;
            while t == warp_from[c] {
                t = rng.next_below(dim as u64) as usize;
            }
            t
        };
        warp_strength[c] = rng.uniform(1.0, 3.0);
    }
    // Unequal cluster weights ∝ 1/(1+c/2) (normalized by sampling).
    let weights: Vec<f64> = (0..k).map(|c| 1.0 / (1.0 + c as f64 / 2.0)).collect();

    let mut points = Mat::zeros(0, dim);
    let mut labels = Vec::with_capacity(n_samples);
    let mut row = vec![0.0; dim];
    for _ in 0..n_samples {
        let c = rng.weighted_index(&weights).unwrap();
        // Base anisotropic Gaussian.
        for (j, v) in row.iter_mut().enumerate() {
            *v = scales.get(c, j) * rng.gaussian();
        }
        // Quadratic warp: bend axis `to` by the square of axis `from`
        // (relative to its scale) — produces curved, non-Gaussian clusters.
        let t = row[warp_from[c]] / scales.get(c, warp_from[c]).max(1e-9);
        row[warp_to[c]] += warp_strength[c] * scales.get(c, warp_to[c]) * (t * t - 1.0);
        // Heavy tail on 10% of draws.
        if rng.next_f64() < 0.1 {
            let boost = 1.0 / rng.uniform(0.25, 1.0);
            for v in row.iter_mut() {
                *v *= boost;
            }
        }
        // Translate to the cluster mean.
        for (j, v) in row.iter_mut().enumerate() {
            *v += means.get(c, j);
        }
        points.push_row(&row);
        labels.push(c);
    }
    LabeledData {
        points,
        labels,
        means,
    }
}
