//! Tests for synthetic generators and dataset I/O.

use super::*;
use crate::linalg::{row_mean, sq_dist, Mat};
use crate::rng::Rng;

#[test]
fn fig2a_generator_statistics() {
    let mut rng = Rng::new(1);
    let n = 20;
    let d = gaussian_mixture_pm1(20_000, n, 2, &mut rng);
    assert_eq!(d.points.shape(), (20_000, n));
    assert_eq!(d.means.shape(), (2, n));
    // Means exactly ±1⃗.
    assert!(d.means.row(0).iter().all(|&v| v == 1.0));
    assert!(d.means.row(1).iter().all(|&v| v == -1.0));
    // Empirical per-cluster variance ≈ n/20 = 1.0.
    let cluster0: Vec<usize> = (0..d.labels.len()).filter(|&i| d.labels[i] == 0).collect();
    let x0 = d.points.select_rows(&cluster0);
    let mu = row_mean(&x0);
    assert!(mu.iter().all(|&m| (m - 1.0).abs() < 0.05), "cluster-0 mean {mu:?}");
    let mut var = 0.0;
    for i in 0..x0.rows() {
        var += sq_dist(x0.row(i), &mu);
    }
    var /= (x0.rows() * n) as f64;
    assert!((var - 1.0).abs() < 0.05, "per-dim variance {var}");
    // Roughly balanced clusters.
    let frac = cluster0.len() as f64 / 20_000.0;
    assert!((frac - 0.5).abs() < 0.02, "cluster balance {frac}");
}

#[test]
fn fig2b_means_are_distinct_corners() {
    let mut rng = Rng::new(2);
    let d = gaussian_mixture_pm1(1000, 5, 6, &mut rng);
    assert_eq!(d.means.shape(), (6, 5));
    for k in 0..6 {
        assert!(d.means.row(k).iter().all(|&v| v == 1.0 || v == -1.0));
        for j in 0..k {
            assert!(
                sq_dist(d.means.row(k), d.means.row(j)) > 0.0,
                "duplicate corners {k}/{j}"
            );
        }
    }
}

#[test]
#[should_panic]
fn fig2b_rejects_impossible_corner_count() {
    let mut rng = Rng::new(3);
    let _ = gaussian_mixture_pm1(100, 2, 5, &mut rng); // 2^2 = 4 < 5
}

#[test]
fn spectral_like_generator_shape_and_nongaussianity() {
    let mut rng = Rng::new(4);
    let d = spectral_embedding_like(30_000, 10, 10, &mut rng);
    assert_eq!(d.points.shape(), (30_000, 10));
    assert_eq!(d.means.shape(), (10, 10));
    // Unequal weights: largest cluster clearly bigger than smallest.
    let mut counts = vec![0usize; 10];
    for &l in &d.labels {
        counts[l] += 1;
    }
    let (mn, mx) = (counts.iter().min().unwrap(), counts.iter().max().unwrap());
    assert!(*mx as f64 > 1.5 * *mn as f64, "weights too equal: {counts:?}");
    // Non-Gaussianity: excess kurtosis of some coordinate within a cluster
    // should be clearly nonzero (heavy tails + warp).
    let c0: Vec<usize> = (0..d.labels.len()).filter(|&i| d.labels[i] == 0).collect();
    let x0 = d.points.select_rows(&c0);
    let mu = row_mean(&x0);
    let mut worst_kurt: f64 = 0.0;
    for j in 0..10 {
        let (mut m2, mut m4) = (0.0, 0.0);
        for i in 0..x0.rows() {
            let v = x0.get(i, j) - mu[j];
            m2 += v * v;
            m4 += v * v * v * v;
        }
        m2 /= x0.rows() as f64;
        m4 /= x0.rows() as f64;
        let kurt = m4 / (m2 * m2) - 3.0;
        worst_kurt = worst_kurt.max(kurt.abs());
    }
    assert!(worst_kurt > 1.0, "clusters look Gaussian (kurtosis {worst_kurt})");
}

#[test]
fn csv_round_trip() {
    let dir = std::env::temp_dir().join("qckm_test_csv");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("data.csv");
    let m = Mat::from_vec(3, 2, vec![1.5, -2.0, 0.0, 3.25, 1e-7, 42.0]);
    save_csv(&path, &m).unwrap();
    let back = load_csv(&path).unwrap();
    assert_eq!(back.shape(), (3, 2));
    for (a, b) in back.as_slice().iter().zip(m.as_slice()) {
        assert!((a - b).abs() < 1e-12);
    }
}

#[test]
fn csv_rejects_ragged_rows_and_junk() {
    let dir = std::env::temp_dir().join("qckm_test_csv2");
    std::fs::create_dir_all(&dir).unwrap();
    let ragged = dir.join("ragged.csv");
    std::fs::write(&ragged, "1,2\n3\n").unwrap();
    assert!(load_csv(&ragged).is_err());
    let junk = dir.join("junk.csv");
    std::fs::write(&junk, "1,abc\n").unwrap();
    assert!(load_csv(&junk).is_err());
    let empty = dir.join("empty.csv");
    std::fs::write(&empty, "# only a comment\n\n").unwrap();
    assert!(load_csv(&empty).is_err());
}

#[test]
fn csv_skips_comments_and_blanks() {
    let dir = std::env::temp_dir().join("qckm_test_csv3");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("commented.csv");
    std::fs::write(&path, "# header\n1,2\n\n3,4\n").unwrap();
    let m = load_csv(&path).unwrap();
    assert_eq!(m.shape(), (2, 2));
    assert_eq!(m.as_slice(), &[1.0, 2.0, 3.0, 4.0]);
}

#[test]
fn bin_round_trip() {
    let dir = std::env::temp_dir().join("qckm_test_bin");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("data.bin");
    let mut rng = Rng::new(5);
    let m = Mat::from_fn(17, 5, |_, _| rng.gaussian());
    save_f64_bin(&path, &m).unwrap();
    let back = load_f64_bin(&path).unwrap();
    assert_eq!(back.shape(), m.shape());
    assert_eq!(back.as_slice(), m.as_slice());
}

#[test]
fn csv_round_trip_is_bit_exact() {
    // `save_csv` prints f64 with Rust's shortest round-trip formatting, so
    // load(save(x)) must reproduce every value to the last bit — the
    // property the stage-split CLI tests lean on when comparing centroid
    // files.
    let dir = std::env::temp_dir().join("qckm_test_csv4");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("exact.csv");
    let mut rng = Rng::new(7);
    let mut m = Mat::from_fn(11, 3, |_, _| rng.gaussian() * 1e-7);
    // Throw in awkward values: subnormal-ish, huge, negative zero, integers.
    m.set(0, 0, 1.0e-300);
    m.set(0, 1, -9.87654321e18);
    m.set(0, 2, -0.0);
    m.set(1, 0, 42.0);
    save_csv(&path, &m).unwrap();
    let back = load_csv(&path).unwrap();
    assert_eq!(back.shape(), m.shape());
    for (a, b) in back.as_slice().iter().zip(m.as_slice()) {
        assert_eq!(a.to_bits(), b.to_bits(), "{a} != {b} bitwise");
    }
}

#[test]
fn bin_rejects_empty_and_bare_header_files() {
    let dir = std::env::temp_dir().join("qckm_test_bin3");
    std::fs::create_dir_all(&dir).unwrap();
    // Zero-byte file: no header at all.
    let empty = dir.join("empty.bin");
    std::fs::write(&empty, b"").unwrap();
    assert!(load_f64_bin(&empty).is_err());
    // Header promising data that never comes.
    let bare = dir.join("bare.bin");
    let mut bytes = Vec::new();
    bytes.extend_from_slice(&2u64.to_le_bytes());
    bytes.extend_from_slice(&3u64.to_le_bytes());
    std::fs::write(&bare, &bytes).unwrap();
    assert!(load_f64_bin(&bare).is_err());
}

#[test]
fn bin_load_rejects_truncated() {
    let dir = std::env::temp_dir().join("qckm_test_bin2");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("trunc.bin");
    std::fs::write(&path, 100u64.to_le_bytes()).unwrap();
    assert!(load_f64_bin(&path).is_err());
}
