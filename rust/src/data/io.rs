//! Dataset file I/O: headerless CSV and a raw little-endian f64 format.

use crate::linalg::Mat;
use anyhow::{bail, Context, Result};
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

/// Parse one CSV line into values, or `None` for skipped (blank/`#`)
/// lines. `expect_cols == 0` accepts any width; otherwise the width must
/// match. Shared by the eager loader below and the streaming
/// [`crate::stream::CsvChunkedReader`] so their parsing semantics — and
/// therefore the in-memory and streamed sketches — cannot diverge.
pub(crate) fn parse_csv_line(
    line: &str,
    expect_cols: usize,
    path: &str,
    lineno: usize,
) -> Result<Option<Vec<f64>>> {
    let trimmed = line.trim();
    if trimmed.is_empty() || trimmed.starts_with('#') {
        return Ok(None);
    }
    let vals: Vec<f64> = trimmed
        .split(',')
        .map(|tok| {
            tok.trim()
                .parse::<f64>()
                .with_context(|| format!("{path}:{lineno}: bad number '{tok}'"))
        })
        .collect::<Result<_>>()?;
    if expect_cols != 0 && vals.len() != expect_cols {
        bail!(
            "{path}:{lineno}: expected {expect_cols} columns, got {}",
            vals.len()
        );
    }
    Ok(Some(vals))
}

/// Load a headerless numeric CSV (one sample per row).
pub fn load_csv(path: &Path) -> Result<Mat> {
    let file = std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?;
    let reader = BufReader::new(file);
    let pathstr = path.display().to_string();
    let mut data: Vec<f64> = Vec::new();
    let mut cols = 0usize;
    let mut rows = 0usize;
    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        let Some(vals) = parse_csv_line(&line, cols, &pathstr, lineno + 1)? else {
            continue;
        };
        cols = vals.len();
        data.extend(vals);
        rows += 1;
    }
    if rows == 0 {
        bail!("{}: empty dataset", path.display());
    }
    Ok(Mat::from_vec(rows, cols, data))
}

/// Save as headerless CSV.
pub fn save_csv(path: &Path, m: &Mat) -> Result<()> {
    let file = std::fs::File::create(path).with_context(|| format!("create {}", path.display()))?;
    let mut w = BufWriter::new(file);
    for r in 0..m.rows() {
        let row: Vec<String> = m.row(r).iter().map(|v| format!("{v}")).collect();
        writeln!(w, "{}", row.join(","))?;
    }
    Ok(())
}

/// Raw binary format: `u64 rows, u64 cols, rows*cols f64` all little-endian.
pub fn save_f64_bin(path: &Path, m: &Mat) -> Result<()> {
    let file = std::fs::File::create(path).with_context(|| format!("create {}", path.display()))?;
    let mut w = BufWriter::new(file);
    w.write_all(&(m.rows() as u64).to_le_bytes())?;
    w.write_all(&(m.cols() as u64).to_le_bytes())?;
    for &v in m.as_slice() {
        w.write_all(&v.to_le_bytes())?;
    }
    Ok(())
}

/// Load the raw binary format written by [`save_f64_bin`].
pub fn load_f64_bin(path: &Path) -> Result<Mat> {
    let file = std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?;
    let mut r = BufReader::new(file);
    let mut u64buf = [0u8; 8];
    r.read_exact(&mut u64buf)?;
    let rows = u64::from_le_bytes(u64buf) as usize;
    r.read_exact(&mut u64buf)?;
    let cols = u64::from_le_bytes(u64buf) as usize;
    let count = rows
        .checked_mul(cols)
        .context("dataset dimensions overflow")?;
    if count > (1 << 31) {
        bail!("dataset too large: {rows}x{cols}");
    }
    let mut data = vec![0.0f64; count];
    for v in data.iter_mut() {
        r.read_exact(&mut u64buf)?;
        *v = f64::from_le_bytes(u64buf);
    }
    Ok(Mat::from_vec(rows, cols, data))
}
