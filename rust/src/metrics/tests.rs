//! Metric tests: SSE by hand, ARI reference values and invariances.

use super::*;
use crate::rng::Rng;

#[test]
fn sse_and_labels_by_hand() {
    let x = Mat::from_vec(4, 1, vec![0.0, 1.0, 10.0, 11.0]);
    let c = Mat::from_vec(2, 1, vec![0.5, 10.5]);
    assert_eq!(assign_labels(&x, &c), vec![0, 0, 1, 1]);
    assert!((sse(&x, &c) - 4.0 * 0.25).abs() < 1e-12);
}

#[test]
fn sse_zero_when_centroids_cover_points() {
    let x = Mat::from_vec(3, 2, vec![1., 2., 3., 4., 5., 6.]);
    assert_eq!(sse(&x, &x), 0.0);
}

#[test]
fn success_criterion_threshold() {
    assert!(is_success(1.0, 1.0));
    assert!(is_success(1.19, 1.0));
    assert!(!is_success(1.21, 1.0));
}

#[test]
fn ari_identical_partitions_is_one() {
    let a = vec![0, 0, 1, 1, 2, 2];
    assert!((adjusted_rand_index(&a, &a) - 1.0).abs() < 1e-12);
    // Invariance to label permutation.
    let b = vec![2, 2, 0, 0, 1, 1];
    assert!((adjusted_rand_index(&a, &b) - 1.0).abs() < 1e-12);
}

#[test]
fn ari_known_value() {
    // Classic example: a = [0,0,1,1], b = [0,1,1,1].
    // Contingency: [[1,1],[0,2]]; Σcomb(n_ij)=1, Σcomb(a)=2, Σcomb(b)=3+0=3,
    // total comb = 6; expected = 1; max = 2.5 → ARI = 0/1.5 = 0.
    let a = vec![0, 0, 1, 1];
    let b = vec![0, 1, 1, 1];
    let got = adjusted_rand_index(&a, &b);
    assert!(got.abs() < 1e-12, "ARI = {got}");
}

#[test]
fn ari_random_labels_near_zero() {
    let mut rng = Rng::new(4);
    let n = 20_000;
    let a: Vec<usize> = (0..n).map(|_| rng.next_below(5) as usize).collect();
    let b: Vec<usize> = (0..n).map(|_| rng.next_below(5) as usize).collect();
    let ari = adjusted_rand_index(&a, &b);
    assert!(ari.abs() < 0.01, "random ARI = {ari}");
}

#[test]
fn ari_degenerate_all_singletons_vs_all_same() {
    let a: Vec<usize> = (0..6).collect(); // singletons
    let b = vec![0; 6]; // one block
    // max_index == expected → defined as 0 here (not identical partitions).
    assert_eq!(adjusted_rand_index(&a, &b), 0.0);
    // Tiny inputs.
    assert_eq!(adjusted_rand_index(&[0], &[0]), 1.0);
}

#[test]
fn running_stats_mean_std() {
    let mut s = RunningStats::default();
    for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
        s.push(x);
    }
    assert_eq!(s.count(), 8);
    assert!((s.mean() - 5.0).abs() < 1e-12);
    // Unbiased std of that classic dataset = sqrt(32/7).
    assert!((s.std() - (32.0f64 / 7.0).sqrt()).abs() < 1e-12);
    let single = {
        let mut t = RunningStats::default();
        t.push(3.0);
        t
    };
    assert_eq!(single.std(), 0.0);
}
