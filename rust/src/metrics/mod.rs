//! Clustering quality metrics: SSE, cluster assignment, Adjusted Rand Index.

use crate::linalg::{sq_dist, Mat};

/// Assign each row of `x` to its nearest centroid; returns labels.
pub fn assign_labels(x: &Mat, centroids: &Mat) -> Vec<usize> {
    assert_eq!(x.cols(), centroids.cols(), "dimension mismatch");
    assert!(centroids.rows() > 0, "no centroids");
    (0..x.rows())
        .map(|i| {
            let xi = x.row(i);
            let mut best = 0;
            let mut best_d = f64::INFINITY;
            for k in 0..centroids.rows() {
                let d = sq_dist(xi, centroids.row(k));
                if d < best_d {
                    best_d = d;
                    best = k;
                }
            }
            best
        })
        .collect()
}

/// Sum of Squared Errors (Eq. 1): `Σ_i min_k ‖x_i − c_k‖²`.
pub fn sse(x: &Mat, centroids: &Mat) -> f64 {
    assert_eq!(x.cols(), centroids.cols(), "dimension mismatch");
    assert!(centroids.rows() > 0, "no centroids");
    let mut total = 0.0;
    for i in 0..x.rows() {
        let xi = x.row(i);
        let mut best = f64::INFINITY;
        for k in 0..centroids.rows() {
            let d = sq_dist(xi, centroids.row(k));
            if d < best {
                best = d;
            }
        }
        total += best;
    }
    total
}

/// The paper's success criterion for the phase-transition diagrams:
/// `SSE_method ≤ 1.2 · SSE_kmeans`.
pub fn is_success(sse_method: f64, sse_kmeans: f64) -> bool {
    sse_method <= 1.2 * sse_kmeans
}

/// Adjusted Rand Index between two labelings (Hubert & Arabie / Vinh et al.).
///
/// 1 for identical partitions, 0 in expectation for random ones; may be
/// negative for adversarial partitions.
pub fn adjusted_rand_index(a: &[usize], b: &[usize]) -> f64 {
    assert_eq!(a.len(), b.len(), "labelings must have equal length");
    let n = a.len();
    if n < 2 {
        return 1.0;
    }
    let ka = a.iter().max().map_or(0, |&m| m + 1);
    let kb = b.iter().max().map_or(0, |&m| m + 1);
    // Contingency table.
    let mut table = vec![0u64; ka * kb];
    let mut row = vec![0u64; ka];
    let mut col = vec![0u64; kb];
    for (&ai, &bi) in a.iter().zip(b) {
        table[ai * kb + bi] += 1;
        row[ai] += 1;
        col[bi] += 1;
    }
    let comb2 = |x: u64| -> f64 { (x as f64) * (x as f64 - 1.0) / 2.0 };
    let sum_table: f64 = table.iter().map(|&v| comb2(v)).sum();
    let sum_row: f64 = row.iter().map(|&v| comb2(v)).sum();
    let sum_col: f64 = col.iter().map(|&v| comb2(v)).sum();
    let total = comb2(n as u64);
    let expected = sum_row * sum_col / total;
    let max_index = 0.5 * (sum_row + sum_col);
    if (max_index - expected).abs() < 1e-12 {
        return if (sum_table - expected).abs() < 1e-12 {
            1.0
        } else {
            0.0
        };
    }
    (sum_table - expected) / (max_index - expected)
}

/// Running mean / (unbiased) standard deviation accumulator, used by the
/// experiment harnesses to report `mean ± std` like the paper's Fig. 3.
#[derive(Clone, Debug, Default)]
pub struct RunningStats {
    n: u64,
    mean: f64,
    m2: f64,
}

impl RunningStats {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn std(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            (self.m2 / (self.n - 1) as f64).sqrt()
        }
    }
}

#[cfg(test)]
mod tests;
