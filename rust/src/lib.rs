//! # qckm — Quantized Compressive K-Means
//!
//! A production-grade reproduction of
//! *"Quantized Compressive K-Means"* (V. Schellekens & L. Jacques, IEEE
//! Signal Processing Letters 2018): compressive clustering where the whole
//! dataset is acquired as pooled, dithered, **1-bit universally quantized**
//! random signatures, and the K cluster centroids are decoded from that
//! single `2M`-dimensional sketch by a CL-OMPR greedy matching pursuit.
//!
//! The crate is the Layer-3 (coordination + decoding) half of a three-layer
//! Rust + JAX + Pallas stack; see `DESIGN.md` at the repository root for the
//! architecture and `EXPERIMENTS.md` for the paper-vs-measured record.
//!
//! ## Quick tour
//!
//! ```no_run
//! use qckm::prelude::*;
//!
//! // Synthetic 2-cluster data (Fig. 2a setup).
//! let mut rng = Rng::new(0);
//! let data = qckm::data::gaussian_mixture_pm1(10_000, 8, 2, &mut rng);
//!
//! // Draw frequencies + dither, build the 1-bit (QCKM) operator.
//! let sigma = SigmaHeuristic::default().resolve(&data.points, &mut rng);
//! let freqs = DrawnFrequencies::draw(FrequencyLaw::AdaptedRadius, 8, 400, sigma, &mut rng);
//! let op = SketchOperator::quantized(freqs);
//!
//! // Acquire (1 bit per measurement per example) and pool — here across
//! // all cores. The parallel encode is bit-for-bit identical at every
//! // thread count (see `qckm::parallel` for the contract).
//! let z = op.sketch_dataset_par(&data.points, &Parallelism::auto());
//!
//! // Decode K = 2 centroids from the sketch alone.
//! let sol = ClOmpr::new(&op, 2).run(&z, &mut rng);
//! println!("centroids: {:?}", sol.centroids);
//! ```
//!
//! ## Parallelism
//!
//! The hot paths — [`sketch::SketchOperator::sketch_dataset_par`], CL-OMPR's
//! Step 1 ([`clompr::ClOmprParams::threads`]), the streaming coordinator's
//! sensor workers, and the experiment grids — all fan out through the
//! deterministic chunked runner in [`parallel`]. Thread counts come from the
//! `--threads` CLI knob / `threads` config key ([`parallel::Parallelism`],
//! 0 = all cores) and change wall-clock time only: fixed chunk boundaries
//! plus ordered merges make every result bit-for-bit independent of the
//! thread count.
//!
//! ## Kernels
//!
//! The innermost loops — the Ω·x projection, the dense `dot`/`axpy`, and
//! the 1-bit sign pooling — dispatch through [`kernel`]: a word-parallel
//! bit-panel encode for ±1 signatures plus runtime-selected SIMD wide
//! kernels, forceable via `QCKM_KERNEL=scalar|wide` and guaranteed to
//! never change any output bit (invariant I-22).

pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod decoder;
pub mod experiments;
pub mod fanin;
pub mod frequency;
pub mod kernel;
pub mod kmeans;
pub mod linalg;
pub mod method;
pub mod metrics;
pub mod obs;
pub mod optim;
pub mod parallel;
pub mod rng;
pub mod runtime;
pub mod server;
pub mod signature;
pub mod sketch;
mod spec;
pub mod stream;
pub mod testkit;

/// CL-OMPR now lives in the decoder registry ([`decoder`]); this re-export
/// keeps the original `qckm::clompr` path working unchanged.
pub use decoder::clompr;

/// The most common imports in one place.
pub mod prelude {
    pub use crate::clompr::{ClOmpr, ClOmprParams, Solution};
    pub use crate::decoder::{DecoderSpec, SketchDecoder};
    pub use crate::frequency::{DrawnFrequencies, FrequencyLaw, SigmaHeuristic};
    pub use crate::kmeans::{kmeans, KMeansParams};
    pub use crate::linalg::Mat;
    pub use crate::method::MethodSpec;
    pub use crate::metrics::{adjusted_rand_index, sse};
    pub use crate::parallel::Parallelism;
    pub use crate::rng::Rng;
    pub use crate::signature::{Cosine, ModuloRamp, Signature, Triangle, UniversalQuantizer};
    pub use crate::sketch::{BitAggregator, BitSketch, PooledSketch, SketchOperator};
}
