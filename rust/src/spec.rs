//! Shared `key=value` parameter machinery for the open spec-string
//! registries.
//!
//! Both registries — compressive methods ([`crate::method`]) and sketch
//! decoders ([`crate::decoder`]) — speak the same grammar:
//!
//! ```text
//! spec   := name [":" param ("," param)*]
//! param  := key "=" value
//! ```
//!
//! This module owns the param-list half of that grammar: splitting,
//! duplicate detection, typed takes with taken-tracking, and the
//! "unknown parameter" rejection that names what a family accepts. The
//! `kind` string ("method" or "decoder") only flavors the error messages,
//! so both registries fail with the same actionable shape.

use anyhow::{bail, Result};

/// Parsed `key=value` params with taken-tracking, so a family builder only
/// names the keys it accepts and everything else is an actionable error.
pub(crate) struct Params {
    /// "method" or "decoder" — the registry kind, for error messages.
    kind: &'static str,
    /// The family name the params belong to, for error messages.
    owner: String,
    pairs: Vec<(String, String, bool)>,
}

impl Params {
    /// Parse the part after the family name's `:` (or `None` when the spec
    /// was just a bare family name).
    pub(crate) fn parse(kind: &'static str, owner: &str, rest: Option<&str>) -> Result<Params> {
        let mut pairs: Vec<(String, String, bool)> = Vec::new();
        if let Some(rest) = rest {
            if rest.is_empty() {
                bail!("{kind} '{owner}': empty parameter list after ':'");
            }
            for item in rest.split(',') {
                let Some((key, value)) = item.split_once('=') else {
                    bail!(
                        "{kind} '{owner}': malformed parameter '{item}' (expected key=value)"
                    );
                };
                let (key, value) = (key.trim(), value.trim());
                if key.is_empty() || value.is_empty() {
                    bail!(
                        "{kind} '{owner}': malformed parameter '{item}' (expected key=value)"
                    );
                }
                if pairs.iter().any(|(k, _, _)| k == key) {
                    bail!("{kind} '{owner}': duplicate parameter '{key}'");
                }
                pairs.push((key.to_string(), value.to_string(), false));
            }
        }
        Ok(Params {
            kind,
            owner: owner.to_string(),
            pairs,
        })
    }

    pub(crate) fn take_u32(&mut self, key: &str) -> Result<Option<u32>> {
        for (k, v, taken) in self.pairs.iter_mut() {
            if k == key {
                *taken = true;
                return match v.parse::<u32>() {
                    Ok(n) => Ok(Some(n)),
                    Err(_) => bail!("parameter '{key}': cannot parse '{v}' as an integer"),
                };
            }
        }
        Ok(None)
    }

    /// Reject leftover params, naming what the family accepts.
    pub(crate) fn finish(&self, params_help: &str) -> Result<()> {
        if let Some((k, _, _)) = self.pairs.iter().find(|(_, _, taken)| !taken) {
            bail!(
                "{} '{}' does not accept parameter '{k}' (accepted: {params_help})",
                self.kind,
                self.owner
            );
        }
        Ok(())
    }
}
