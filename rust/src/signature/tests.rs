//! Tests for signature functions: analytic vs numeric Fourier coefficients,
//! periodicity, parity, amplitude conventions.

use super::*;
use std::f64::consts::PI;

fn check_periodic_even_bounded(f: &dyn Signature) {
    for i in 0..200 {
        let t = -10.0 + i as f64 * 0.1;
        let v = f.eval(t);
        assert!((-1.0..=1.0).contains(&v), "{} out of range at {t}", f.name());
        assert!(
            (f.eval(t + 2.0 * PI) - v).abs() < 1e-12,
            "{} not 2π-periodic at {t}",
            f.name()
        );
        // Even symmetry (skip exact discontinuity points of the quantizer).
        let r = wrap_2pi(t);
        let near_disc = (r - 0.5 * PI).abs() < 1e-6 || (r - 1.5 * PI).abs() < 1e-6;
        if !near_disc {
            assert!(
                (f.eval(-t) - v).abs() < 1e-9,
                "{} not even at {t}",
                f.name()
            );
        }
    }
}

fn check_analytic_matches_numeric(f: &dyn Signature, tol: f64) {
    for k in 0..=7 {
        let analytic = f.fourier_coeff(k);
        let numeric = numeric_fourier_coeff(&|t| f.eval(t), k);
        assert!(
            (analytic - numeric).abs() < tol,
            "{}: F_{k} analytic {analytic} vs numeric {numeric}",
            f.name()
        );
    }
}

#[test]
fn cosine_shape_and_coeffs() {
    let f = Cosine;
    check_periodic_even_bounded(&f);
    check_analytic_matches_numeric(&f, 1e-9);
    assert!((f.first_harmonic_amplitude() - 1.0).abs() < 1e-12);
    assert_eq!(f.fourier_coeff(0), 0.0);
    assert_eq!(f.fourier_coeff(-1), 0.5);
}

#[test]
fn universal_quantizer_is_sign_of_cos() {
    let q = UniversalQuantizer;
    check_periodic_even_bounded(&q);
    for i in 0..1000 {
        let t = -15.0 + i as f64 * 0.03;
        if t.cos().abs() > 1e-9 {
            assert_eq!(q.eval(t), t.cos().signum(), "q({t})");
        }
        assert_eq!(q.bit(t), q.eval(t) > 0.0);
    }
}

#[test]
fn universal_quantizer_fourier_series() {
    let q = UniversalQuantizer;
    check_analytic_matches_numeric(&q, 1e-4);
    // F_1 = 2/π, first harmonic amplitude 4/π.
    assert!((q.fourier_coeff(1) - 2.0 / PI).abs() < 1e-12);
    assert!((q.first_harmonic_amplitude() - 4.0 / PI).abs() < 1e-12);
    // Square wave: F_3 = -2/(3π), F_5 = +2/(5π), even harmonics vanish.
    assert!((q.fourier_coeff(3) + 2.0 / (3.0 * PI)).abs() < 1e-12);
    assert!((q.fourier_coeff(5) - 2.0 / (5.0 * PI)).abs() < 1e-12);
    assert_eq!(q.fourier_coeff(2), 0.0);
}

#[test]
fn universal_quantizer_lsb_identity() {
    // q is the LSB of a stepsize-π uniform quantizer: q(t) = +1 iff
    // floor((t + π/2)/π) is even.
    let q = UniversalQuantizer;
    for i in 0..2000 {
        let t = -20.0 + i as f64 * 0.02;
        let lsb_even = ((t + 0.5 * PI).div_euclid(PI)) as i64 % 2 == 0;
        if (t.cos()).abs() > 1e-9 {
            assert_eq!(q.bit(t), lsb_even, "LSB identity fails at t={t}");
        }
    }
}

#[test]
fn triangle_shape_and_coeffs() {
    let f = Triangle;
    check_periodic_even_bounded(&f);
    check_analytic_matches_numeric(&f, 1e-6);
    assert!((f.eval(0.0) - 1.0).abs() < 1e-12);
    assert!((f.eval(PI) + 1.0).abs() < 1e-12);
    assert!(f.eval(0.5 * PI).abs() < 1e-12);
    assert!((f.first_harmonic_amplitude() - 8.0 / (PI * PI)).abs() < 1e-12);
}

#[test]
fn multibit_quantizer_interpolates_cosine() {
    // B=8: the staircase is within one step of the cosine.
    let f = MultiBitQuantizer::new(8);
    check_periodic_even_bounded(&f);
    for i in 0..100 {
        let t = i as f64 * 0.07;
        assert!((f.eval(t) - t.cos()).abs() < 0.02, "8-bit staircase at {t}");
    }
    // F1 approaches cosine's 0.5 as B grows.
    let f1_2 = MultiBitQuantizer::new(2).fourier_coeff(1);
    let f1_8 = MultiBitQuantizer::new(8).fourier_coeff(1);
    assert!((f1_8 - 0.5).abs() < 0.01, "F1(8 bits) = {f1_8}");
    assert!((f1_2 - 0.5).abs() > (f1_8 - 0.5).abs());
    assert_eq!(f.bits(), 8);
}

#[test]
#[should_panic]
fn multibit_rejects_zero_bits() {
    let _ = MultiBitQuantizer::new(0);
}

#[test]
fn modulo_ramp_shape_and_sine_series() {
    let f = ModuloRamp;
    // Periodic, bounded, centered — but *odd*, not even.
    for i in 0..200 {
        let t = -10.0 + i as f64 * 0.1;
        let v = f.eval(t);
        assert!((-1.0..=1.0).contains(&v), "out of range at {t}");
        assert!((f.eval(t + 2.0 * PI) - v).abs() < 1e-12, "not 2π-periodic at {t}");
        // Odd symmetry f(−t) = −f(t), away from the wrap discontinuity.
        let r = wrap_2pi(t);
        if r > 1e-6 && (2.0 * PI - r) > 1e-6 {
            assert!((f.eval(-t) + v).abs() < 1e-9, "not odd at {t}");
        }
    }
    // The ramp itself: f(0⁺) = −1 rising linearly to f(2π⁻) = 1.
    assert!((f.eval(0.0) + 1.0).abs() < 1e-12);
    assert!((f.eval(PI) - 0.0).abs() < 1e-12);
    assert!((f.eval(1.5 * PI) - 0.5).abs() < 1e-12);
    // Mean zero (F_0 = 0) numerically.
    assert!(numeric_fourier_coeff(&|t| f.eval(t), 0).abs() < 1e-9);

    // fourier_coeff reports magnitudes |F_k| = 1/(πk): cross-check against
    // the numeric cosine AND sine projections, c_k and s_k, via
    // |F_k| = hypot(c_k, s_k) (for the pure sawtooth c_k ≈ 0).
    for k in 1..=7i32 {
        let c_k = numeric_fourier_coeff(&|t| f.eval(t), k);
        let s_k = {
            // (1/2π) ∫ f(t) sin(kt) dt on the same Simpson grid.
            let n = 1 << 16;
            let h = 2.0 * PI / n as f64;
            let g = |t: f64| f.eval(t) * (k as f64 * t).sin();
            let mut s = g(0.0) + g(2.0 * PI);
            for i in 1..n {
                let t = i as f64 * h;
                s += if i % 2 == 1 { 4.0 } else { 2.0 } * g(t);
            }
            (s * h / 3.0) / (2.0 * PI)
        };
        assert!(c_k.abs() < 1e-6, "sawtooth has no cosine part: c_{k} = {c_k}");
        let numeric_mag = (c_k * c_k + s_k * s_k).sqrt();
        assert!(
            (f.fourier_coeff(k) - numeric_mag).abs() < 1e-6,
            "|F_{k}|: analytic {} vs numeric {numeric_mag}",
            f.fourier_coeff(k)
        );
        // First harmonic phase: f1 = 2|F1| cos(t + φ) ⇒ c_1 = |F1| cos φ,
        // s_1 = −|F1| sin φ ⇒ φ = atan2(−s_1, c_1).
        if k == 1 {
            let phi = (-s_k).atan2(c_k);
            assert!(
                (phi - f.first_harmonic_phase()).abs() < 1e-6,
                "phase: numeric {phi} vs declared {}",
                f.first_harmonic_phase()
            );
        }
    }
    assert!((f.first_harmonic_amplitude() - 2.0 / PI).abs() < 1e-12);
    // Tail energy Σ_{k≥2} 1/k² = π²/6 − 1 (truncation at 1025 ≈ 1/1025).
    assert!(
        (f.tail_energy_ratio() - (PI * PI / 6.0 - 1.0)).abs() < 2e-3,
        "ramp tail {}",
        f.tail_energy_ratio()
    );
}

#[test]
fn even_signatures_declare_zero_phase() {
    assert_eq!(Cosine.first_harmonic_phase(), 0.0);
    assert_eq!(UniversalQuantizer.first_harmonic_phase(), 0.0);
    assert_eq!(Triangle.first_harmonic_phase(), 0.0);
    assert_eq!(MultiBitQuantizer::new(3).first_harmonic_phase(), 0.0);
}

#[test]
fn multibit_names_distinguish_bit_depths() {
    // The name feeds the .qsk operator fingerprint — depths must differ.
    let names: Vec<&str> = (1..=16).map(|b| MultiBitQuantizer::new(b).name()).collect();
    for (i, n) in names.iter().enumerate() {
        assert_eq!(*n, format!("multibit-{}", i + 1));
    }
}

#[test]
fn prop1_constants() {
    // C_f = 8 F1⁴/(1+2F1)⁴. For cosine F1 = 1/2 → 8·(1/16)/16 = 1/32.
    assert!((Cosine.prop1_constant() - 1.0 / 32.0).abs() < 1e-12);
    let q = UniversalQuantizer;
    let f1: f64 = 2.0 / PI;
    let want = 8.0 * f1.powi(4) / (1.0 + 2.0 * f1).powi(4);
    assert!((q.prop1_constant() - want).abs() < 1e-12);
}

#[test]
fn tail_energy_ratios_ordering() {
    // Cosine has no tail; quantizer has the largest tail; triangle in between.
    let c = Cosine.tail_energy_ratio();
    let t = Triangle.tail_energy_ratio();
    let q = UniversalQuantizer.tail_energy_ratio();
    assert!(c < 1e-12);
    assert!(t > 0.0 && q > t, "tails: cos={c}, tri={t}, quant={q}");
    // Square wave tail: Σ_{odd k≥3} (2/πk)² / (2/π)² · ... = Σ 1/k² over odd k ≥ 3
    // = π²/8 − 1 ≈ 0.2337.
    // Truncated at k ≤ 1025: remainder Σ_{odd k>1025} 1/k² ≈ 1/2050.
    assert!((q - (PI * PI / 8.0 - 1.0)).abs() < 2e-3, "quantizer tail {q}");
}

#[test]
fn wrap_2pi_range() {
    for &t in &[-100.0, -1.0, 0.0, 1.0, 6.28, 100.0] {
        let r = wrap_2pi(t);
        assert!((0.0..2.0 * PI).contains(&r), "wrap({t}) = {r}");
        let q = (t - r) / (2.0 * PI);
        assert!((q - q.round()).abs() < 1e-9, "wrap({t}) not a 2π shift");
    }
}
