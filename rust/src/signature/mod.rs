//! Periodic signature functions — Sec. 3 of the paper.
//!
//! The generalized sketch operator is `A_f(P) = E_{x~P} f(Ω^T x + ξ)` where
//! `f` is any 2π-periodic function, centered (`F_0 = 0`), taking values in
//! `[-1, 1]`, with a non-vanishing first Fourier harmonic `F_1 ≠ 0`.
//! Prop. 1 shows that after uniform dithering the sketch distance
//! `‖A_f(P) − A_{f1}(Q)‖²` concentrates around the MMD `γ²_Λ(P,Q)` plus a
//! Q-independent constant, where `f1(t) = 2|F_1| cos(t + φ₁)` is `f`'s first
//! harmonic. Decoding therefore always uses *cosine* atoms with amplitude
//! `2|F_1|`, regardless of which `f` encoded the data.
//!
//! This module provides the [`Signature`] trait plus the instances used in
//! the paper and the experiments:
//!
//! * [`Cosine`] — classical CKM (real/imaginary parts of `exp(-i·)` are the
//!   cosine at two dither offsets, see `crate::sketch`),
//! * [`UniversalQuantizer`] — the paper's headline 1-bit signature
//!   `q(t) = sign(cos t)`, the least-significant bit of a uniform quantizer
//!   with stepsize π,
//! * [`Triangle`] — a piecewise-linear periodic signature (an ADC ramp
//!   model), exercised in the ablation experiments,
//! * [`MultiBitQuantizer`] — a B-bit staircase approximation of the cosine,
//!   interpolating between `UniversalQuantizer` (B=1, after re-scaling) and
//!   `Cosine` (B→∞); used by the bit-depth ablation,
//! * [`ModuloRamp`] — the self-reset ADC sawtooth `(t mod 2π)/π − 1`, the
//!   one *odd* signature in the zoo: its first harmonic carries a π/2
//!   phase, reported via [`Signature::first_harmonic_phase`] and absorbed
//!   into the decode atoms by [`crate::sketch::SketchOperator`].
//!
//! Most of these are *even* functions (real Fourier coefficients, phase
//! zero), which is the default the sketch layout in `crate::sketch`
//! assumes; an odd signature like the ramp declares its first-harmonic
//! phase and decoding evaluates `cos(· + φ₁)` instead — the dithering
//! supplies all other phase diversity.

mod quantizers;

pub use quantizers::{ModuloRamp, MultiBitQuantizer, Triangle, UniversalQuantizer};

use std::f64::consts::PI;

/// A 2π-periodic, centered, even signature function `f: ℝ → [-1, 1]`.
pub trait Signature: Send + Sync {
    /// Evaluate `f(t)` (t need not be reduced mod 2π).
    fn eval(&self, t: f64) -> f64;

    /// The Fourier coefficient `F_k` of `e^{ikt}` in
    /// `f(t) = Σ_k F_k e^{ikt}`. Even `f` ⇒ `F_k = F_{-k} ∈ ℝ` and this is
    /// the signed real coefficient; a non-even signature (e.g.
    /// [`ModuloRamp`]) returns the *magnitude* `|F_k|` here and reports the
    /// first harmonic's phase via
    /// [`first_harmonic_phase`](Self::first_harmonic_phase). Every consumer
    /// in this crate uses `|F_k|` or `F_k²` only, so both conventions feed
    /// the same formulas.
    ///
    /// The default implementation integrates numerically (even signatures
    /// only); concrete signatures override with their analytic series
    /// (tests cross-check the two).
    fn fourier_coeff(&self, k: i32) -> f64 {
        numeric_fourier_coeff(&|t| self.eval(t), k)
    }

    /// Amplitude of the first harmonic `f1(t) = 2|F_1| cos(t + φ₁)`.
    /// Must be > 0.
    fn first_harmonic_amplitude(&self) -> f64 {
        2.0 * self.fourier_coeff(1).abs()
    }

    /// Phase `φ₁` of the first harmonic `f1(t) = 2|F_1| cos(t + φ₁)`.
    ///
    /// Even signatures have `φ₁ = 0` (the default). An odd signature like
    /// the self-reset ramp declares its phase here;
    /// [`crate::sketch::SketchOperator`] adds it to every decode-atom
    /// argument so sketch matching stays phase-aligned (Prop. 1 holds for
    /// any `φ₁` — the dither expectation cancels the phase).
    fn first_harmonic_phase(&self) -> f64 {
        0.0
    }

    /// Short identifier used in configs / logs.
    fn name(&self) -> &'static str;

    /// Batched evaluation of the paired slots `f(t)` and `f(t + π/2)` for
    /// every `t` in `args` — the encode hot loop.
    ///
    /// The default delegates to [`Signature::eval`]; concrete signatures
    /// override it to amortize the dynamic dispatch to one call per tile
    /// and to share work between the pair (e.g. one `sin_cos` for the
    /// cosine). Measured impact in EXPERIMENTS.md §Perf.
    fn eval_pair_batch(&self, args: &[f64], out0: &mut [f64], out1: &mut [f64]) {
        debug_assert_eq!(args.len(), out0.len());
        debug_assert_eq!(args.len(), out1.len());
        for ((t, o0), o1) in args.iter().zip(out0.iter_mut()).zip(out1.iter_mut()) {
            *o0 = self.eval(*t);
            *o1 = self.eval(*t + std::f64::consts::FRAC_PI_2);
        }
    }

    /// Whether this signature takes values in `{-1, +1}` only (a 1-bit
    /// signature in the paper's sense).
    ///
    /// `true` is a contract with the bit-parallel encode kernels
    /// ([`crate::kernel::bitpanel`]): [`eval_pair_batch`] must produce
    /// exactly `±1.0` and [`eval_pair_sign_batch`] must equal
    /// `eval_pair_batch(..) > 0.0` slot for slot, so pooling signs with
    /// popcounts reproduces the f64 fold bit-for-bit (I-22).
    ///
    /// [`eval_pair_batch`]: Self::eval_pair_batch
    /// [`eval_pair_sign_batch`]: Self::eval_pair_sign_batch
    fn is_binary(&self) -> bool {
        false
    }

    /// Batched *sign* evaluation of the paired slots: `out0[j] = f(t_j) > 0`
    /// and `out1[j] = f(t_j + π/2) > 0` — the 1-bit acquisition hot loop.
    ///
    /// Only meaningful for ±1 signatures ([`is_binary`](Self::is_binary)),
    /// where the sign *is* the value; the bit-panel kernels call this so no
    /// f64 signature values are ever materialized. The default derives the
    /// signs from [`eval_pair_batch`](Self::eval_pair_batch), which keeps
    /// the contract true by construction; concrete ±1 signatures override
    /// with the direct bit computation.
    fn eval_pair_sign_batch(&self, args: &[f64], out0: &mut [bool], out1: &mut [bool]) {
        debug_assert_eq!(args.len(), out0.len());
        debug_assert_eq!(args.len(), out1.len());
        let mut v0 = vec![0.0; args.len()];
        let mut v1 = vec![0.0; args.len()];
        self.eval_pair_batch(args, &mut v0, &mut v1);
        for j in 0..args.len() {
            out0[j] = v0[j] > 0.0;
            out1[j] = v1[j] > 0.0;
        }
    }

    /// The concentration constant `C_f = 8|F_1|⁴ (1 + 2|F_1|)⁻⁴` of Prop. 1:
    /// the failure probability is `≤ 2 exp(−C_f m ε²)`.
    fn prop1_constant(&self) -> f64 {
        let f1 = self.fourier_coeff(1).abs();
        8.0 * f1.powi(4) / (1.0 + 2.0 * f1).powi(4)
    }

    /// Energy in harmonics |k| ≥ 2, relative to the first harmonic:
    /// `Σ_{|k|≥2} |F_k|² / (2|F_1|²)`. This bounds the Prop.-1 offset
    /// `c_P` (it equals `c_P` when `P` is a Dirac, since then |φ_P| = 1).
    fn tail_energy_ratio(&self) -> f64 {
        let f1sq = self.fourier_coeff(1).powi(2);
        let mut tail = 0.0;
        for k in 2..=1025 {
            tail += 2.0 * self.fourier_coeff(k).powi(2); // ±k
        }
        tail / (2.0 * f1sq)
    }
}

/// Reduce `t` to the canonical period `[0, 2π)`.
#[inline]
pub fn wrap_2pi(t: f64) -> f64 {
    let two_pi = 2.0 * PI;
    let r = t % two_pi;
    if r < 0.0 {
        r + two_pi
    } else {
        r
    }
}

/// Numeric Fourier cosine coefficient `(1/2π)∫ f(t) cos(kt) dt` (even f).
pub fn numeric_fourier_coeff(f: &dyn Fn(f64) -> f64, k: i32) -> f64 {
    // Composite Simpson on a fine grid; the discontinuous signatures are
    // bounded so this converges fast enough for the ~1e-6 accuracy we need.
    let n = 1 << 16; // even
    let h = 2.0 * PI / n as f64;
    let g = |t: f64| f(t) * (k as f64 * t).cos();
    let mut s = g(0.0) + g(2.0 * PI);
    for i in 1..n {
        let t = i as f64 * h;
        s += if i % 2 == 1 { 4.0 } else { 2.0 } * g(t);
    }
    (s * h / 3.0) / (2.0 * PI)
}

/// The classical CKM signature: `f(t) = cos t`.
///
/// The complex-exponential sketch of CKM is recovered by evaluating the
/// cosine at dither offsets `ξ` and `ξ + π/2` per frequency (real and
/// negated-imaginary parts of `e^{-i(ω^T x + ξ)}`).
#[derive(Clone, Copy, Debug, Default)]
pub struct Cosine;

impl Signature for Cosine {
    #[inline]
    fn eval(&self, t: f64) -> f64 {
        t.cos()
    }

    fn eval_pair_batch(&self, args: &[f64], out0: &mut [f64], out1: &mut [f64]) {
        // cos(t + π/2) = −sin t: one sin_cos serves both slots.
        for ((t, o0), o1) in args.iter().zip(out0.iter_mut()).zip(out1.iter_mut()) {
            let (s, c) = t.sin_cos();
            *o0 = c;
            *o1 = -s;
        }
    }

    fn fourier_coeff(&self, k: i32) -> f64 {
        if k.abs() == 1 {
            0.5
        } else {
            0.0
        }
    }

    fn name(&self) -> &'static str {
        "cosine"
    }
}

#[cfg(test)]
mod tests;
