//! Quantizing / hardware-model signatures.

use super::{wrap_2pi, Signature};
use std::f64::consts::PI;

/// 1-bit universal quantization `q(t) = sign(cos t) ∈ {-1, +1}` — the
/// paper's headline signature (Sec. 4).
///
/// `q` is the least-significant bit of a uniform quantizer with stepsize π
/// (+1 on `[-π/2, π/2)` mod 2π, -1 elsewhere; the measure-zero boundary is
/// assigned +1). Each example's sketch contribution is exactly one bit per
/// measurement — see [`crate::sketch::BitSketch`] for the packed encoding
/// where -1 is stored as 0.
///
/// Fourier series: `q(t) = (4/π) Σ_{j≥0} (-1)^j cos((2j+1) t) / (2j+1)`,
/// so `F_1 = 2/π` and the first harmonic is `q₁(t) = (4/π) cos t`.
#[derive(Clone, Copy, Debug, Default)]
pub struct UniversalQuantizer;

impl UniversalQuantizer {
    /// The raw acquired bit: `true` ⇔ `q(t) = +1`.
    ///
    /// `cos t ≥ 0 ⇔ (t + π/2)/π ∈ [2k, 2k+1) ⇔ ⌊(t + π/2)/π⌋ even` — the
    /// LSB view, branch-free (the encode hot loop relies on this).
    #[inline]
    pub fn bit(&self, t: f64) -> bool {
        ((t + 0.5 * PI).div_euclid(PI) as i64) & 1 == 0
    }
}

impl Signature for UniversalQuantizer {
    #[inline]
    fn eval(&self, t: f64) -> f64 {
        if self.bit(t) {
            1.0
        } else {
            -1.0
        }
    }

    fn eval_pair_batch(&self, args: &[f64], out0: &mut [f64], out1: &mut [f64]) {
        // Branch-free and division-free: multiply by 1/π, floor, take the
        // LSB (no trig at all — this is what makes the 1-bit encode ~4×
        // cheaper than the cosine's sin_cos, see EXPERIMENTS.md §Perf).
        const INV_PI: f64 = 1.0 / PI;
        for ((t, o0), o1) in args.iter().zip(out0.iter_mut()).zip(out1.iter_mut()) {
            let u = t * INV_PI; // cells of the stepsize-π quantizer
            let cell0 = (u + 0.5).floor() as i64;
            let cell1 = (u + 1.0).floor() as i64;
            *o0 = 1.0 - 2.0 * ((cell0 & 1) as f64);
            *o1 = 1.0 - 2.0 * ((cell1 & 1) as f64);
        }
    }

    fn is_binary(&self) -> bool {
        true
    }

    fn eval_pair_sign_batch(&self, args: &[f64], out0: &mut [bool], out1: &mut [bool]) {
        // The cell formula of `eval_pair_batch`, keeping only the LSB: the
        // sign is "cell index even". (The `div_euclid` view in `bit()` can
        // disagree with this in the last ulp; the batch formula is what the
        // encode paths evaluate, so it is what the bit path must replicate
        // — I-22.)
        const INV_PI: f64 = 1.0 / PI;
        for ((t, o0), o1) in args.iter().zip(out0.iter_mut()).zip(out1.iter_mut()) {
            let u = t * INV_PI; // cells of the stepsize-π quantizer
            *o0 = ((u + 0.5).floor() as i64) & 1 == 0;
            *o1 = ((u + 1.0).floor() as i64) & 1 == 0;
        }
    }

    fn fourier_coeff(&self, k: i32) -> f64 {
        let k = k.abs();
        if k % 2 == 0 {
            0.0
        } else {
            // (2/π) (-1)^((k-1)/2) / k  for odd k.
            let j = (k - 1) / 2;
            let sign = if j % 2 == 0 { 1.0 } else { -1.0 };
            sign * 2.0 / (PI * k as f64)
        }
    }

    fn name(&self) -> &'static str {
        "universal-1bit"
    }
}

/// Even triangle wave: `tri(0) = 1`, `tri(±π) = -1`, linear in between.
///
/// Models a ramp-compare ADC front end; used in the signature ablation to
/// show Prop. 1 holds beyond the quantizer (its harmonics decay like 1/k²,
/// so its Prop.-1 offset `c_P` is much smaller than the quantizer's).
#[derive(Clone, Copy, Debug, Default)]
pub struct Triangle;

impl Signature for Triangle {
    #[inline]
    fn eval(&self, t: f64) -> f64 {
        let r = wrap_2pi(t); // [0, 2π)
        let d = if r <= PI { r } else { 2.0 * PI - r }; // distance to 0 mod 2π
        1.0 - 2.0 * d / PI
    }

    fn fourier_coeff(&self, k: i32) -> f64 {
        let k = k.abs();
        if k % 2 == 0 {
            0.0
        } else {
            // tri(t) = (8/π²) Σ_{odd k} cos(kt)/k²  ⇒ F_k = 4/(π² k²).
            4.0 / (PI * PI * (k * k) as f64)
        }
    }

    fn name(&self) -> &'static str {
        "triangle"
    }
}

/// A `2^B`-level midrise staircase quantization of the cosine:
/// `f(t) = Q_B(cos t)` with `Q_B` the uniform midrise quantizer on `[-1,1]`.
///
/// `B = 1` gives `sign(cos t)/...` scaled to half amplitude (levels ±1/2,
/// rescaled below to fill `[-1,1]`), and `B → ∞` converges to [`Cosine`].
/// Used by the bit-depth ablation bench (how many bits per measurement do
/// you need before you match CKM's constant?).
#[derive(Clone, Copy, Debug)]
pub struct MultiBitQuantizer {
    bits: u32,
}

impl MultiBitQuantizer {
    pub fn new(bits: u32) -> Self {
        assert!((1..=16).contains(&bits), "bits must be in 1..=16");
        Self { bits }
    }

    pub fn bits(&self) -> u32 {
        self.bits
    }

    /// Midrise-quantize `v ∈ [-1, 1]` to `2^bits` levels, rescaled so the
    /// outermost levels sit at ±1 (keeps the signature onto `[-1,1]`).
    #[inline]
    fn quantize(&self, v: f64) -> f64 {
        let levels = 1u64 << self.bits; // even
        let half = (levels / 2) as f64;
        // cell index in 0..levels
        let cell = ((v + 1.0) * half).floor().clamp(0.0, (levels - 1) as f64);
        let mid = (cell - half + 0.5) / half; // midrise reconstruction in (-1,1)
        // Rescale so max |value| = 1 (keeps F1 comparisons fair).
        mid / ((half - 0.5) / half)
    }
}

impl Signature for MultiBitQuantizer {
    #[inline]
    fn eval(&self, t: f64) -> f64 {
        self.quantize(t.cos())
    }

    /// `B = 1` is a ±1 staircase (levels ±1 after the rescale), so it
    /// qualifies for the bit-parallel encode; the default derived
    /// [`Signature::eval_pair_sign_batch`] keeps the sign/value contract
    /// true by construction. (Note the canonical 1-bit spec `qckm:bits=1`
    /// builds a [`UniversalQuantizer`] instead — see
    /// `crate::method::MethodSpec` — so this mostly guards direct users.)
    fn is_binary(&self) -> bool {
        self.bits == 1
    }

    fn name(&self) -> &'static str {
        // Per-bit-depth names: the name feeds the `.qsk` operator
        // fingerprint, and a 2-bit and a 3-bit staircase must never
        // fingerprint equal (their sketches are incompatible).
        const NAMES: [&str; 16] = [
            "multibit-1",
            "multibit-2",
            "multibit-3",
            "multibit-4",
            "multibit-5",
            "multibit-6",
            "multibit-7",
            "multibit-8",
            "multibit-9",
            "multibit-10",
            "multibit-11",
            "multibit-12",
            "multibit-13",
            "multibit-14",
            "multibit-15",
            "multibit-16",
        ];
        NAMES[(self.bits - 1) as usize]
    }
}

/// Self-reset ADC ramp ("modulo" sampling): `f(t) = (t mod 2π)/π − 1`, the
/// sawtooth a self-reset ADC front end produces when its integrator wraps
/// instead of saturating.
///
/// The one *odd* signature in the zoo — its Fourier series is pure sine,
/// `f(t) = −(2/π) Σ_{k≥1} sin(kt)/k`, so the first harmonic is
/// `(2/π)·cos(t + π/2)`: amplitude `2|F_1| = 2/π` with a `π/2` phase that
/// [`Signature::first_harmonic_phase`] reports and the decode atoms absorb.
/// Exists to prove the open method registry handles signatures beyond the
/// even family the seed shipped with.
#[derive(Clone, Copy, Debug, Default)]
pub struct ModuloRamp;

impl Signature for ModuloRamp {
    #[inline]
    fn eval(&self, t: f64) -> f64 {
        wrap_2pi(t) / PI - 1.0
    }

    /// Magnitudes `|F_k| = 1/(πk)` (odd signature — see the trait docs;
    /// the phase lives in [`Signature::first_harmonic_phase`]).
    fn fourier_coeff(&self, k: i32) -> f64 {
        let k = k.abs();
        if k == 0 {
            0.0
        } else {
            1.0 / (PI * k as f64)
        }
    }

    fn first_harmonic_phase(&self) -> f64 {
        std::f64::consts::FRAC_PI_2
    }

    fn name(&self) -> &'static str {
        "modulo-ramp"
    }
}
