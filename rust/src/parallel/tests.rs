//! Unit tests for the deterministic chunked runner.

use super::*;

#[test]
fn fixed_chunks_partition_exactly() {
    assert_eq!(fixed_chunks(0, 4), Vec::<Range<usize>>::new());
    assert_eq!(fixed_chunks(1, 4), vec![0..1]);
    assert_eq!(fixed_chunks(8, 4), vec![0..4, 4..8]);
    assert_eq!(fixed_chunks(9, 4), vec![0..4, 4..8, 8..9]);
    // Boundaries cover 0..total exactly once, in order.
    let chunks = fixed_chunks(1000, 64);
    let mut expect = 0;
    for c in &chunks {
        assert_eq!(c.start, expect);
        assert!(c.len() <= 64 && !c.is_empty());
        expect = c.end;
    }
    assert_eq!(expect, 1000);
}

#[test]
#[should_panic]
fn fixed_chunks_reject_zero_chunk() {
    let _ = fixed_chunks(10, 0);
}

#[test]
fn run_chunked_results_in_chunk_order_at_any_thread_count() {
    // Work returns (index, range) so any mis-ordering is visible.
    let reference: Vec<(usize, Range<usize>)> =
        run_chunked(103, 10, &Parallelism::serial(), |i, r| (i, r));
    for threads in [2, 3, 7, 16] {
        let got = run_chunked(103, 10, &Parallelism::fixed(threads), |i, r| (i, r));
        assert_eq!(got, reference, "threads = {threads}");
    }
    assert_eq!(reference.len(), 11);
    assert_eq!(reference[10], (10, 100..103));
}

#[test]
fn run_chunked_float_reduction_is_thread_invariant() {
    // An order-sensitive floating-point reduction: per-chunk partial sums
    // merged in chunk order must be bit-identical at every thread count.
    let xs: Vec<f64> = (0..10_000).map(|i| ((i * 2654435761_usize) as f64).sqrt()).collect();
    let reduce = |par: &Parallelism| -> f64 {
        run_chunked(xs.len(), 128, par, |_, r| xs[r].iter().sum::<f64>())
            .into_iter()
            .fold(0.0, |acc, s| acc + s)
    };
    let serial = reduce(&Parallelism::serial());
    for threads in [2, 5, 7] {
        let par = reduce(&Parallelism::fixed(threads));
        assert_eq!(par.to_bits(), serial.to_bits(), "threads = {threads}");
    }
}

#[test]
fn par_map_matches_serial_map() {
    let want: Vec<usize> = (0..57).map(|i| i * i).collect();
    for threads in [1, 2, 7] {
        let got = par_map(57, &Parallelism::fixed(threads), |i| i * i);
        assert_eq!(got, want, "threads = {threads}");
    }
}

#[test]
fn skewed_work_still_merges_in_order() {
    // Make early chunks much slower than late ones so stealing reorders
    // completion; the output order must not care.
    let got = run_chunked(16, 1, &Parallelism::fixed(4), |i, _| {
        if i < 4 {
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        i
    });
    assert_eq!(got, (0..16).collect::<Vec<_>>());
}

#[test]
fn worker_panic_propagates() {
    let result = std::panic::catch_unwind(|| {
        run_chunked(8, 1, &Parallelism::fixed(4), |i, _| {
            if i == 5 {
                panic!("chunk 5 exploded");
            }
            i
        })
    });
    let payload = result.unwrap_err();
    let msg = payload
        .downcast_ref::<&str>()
        .copied()
        .unwrap_or("<non-str>");
    assert!(msg.contains("exploded"), "payload: {msg}");
}

#[test]
fn parallelism_resolution() {
    assert_eq!(Parallelism::serial().resolved_threads(), 1);
    assert_eq!(Parallelism::fixed(3).resolved_threads(), 3);
    assert!(Parallelism::auto().resolved_threads() >= 1);
    assert_eq!(Parallelism::default(), Parallelism::auto());
    assert_eq!(Parallelism::fixed(0), Parallelism::auto());
}

#[test]
fn empty_input_yields_empty_output() {
    let got: Vec<u8> = run_chunked(0, 8, &Parallelism::auto(), |_, _| unreachable!());
    assert!(got.is_empty());
}
