//! Deterministic scoped-thread execution layer for the crate's hot paths.
//!
//! Everything CPU-bound in this system — the pooled-sketch encode, CL-OMPR's
//! Step-1 candidate screening / L-BFGS restarts, and the experiment
//! replicate grids — fans out through this module. It is a small chunked
//! runner over `std::thread::scope` (the environment vendors no `rayon`):
//! work is split into **fixed-size chunks**, worker threads *steal* chunk
//! indices from a shared atomic counter, and the per-chunk results are
//! handed back **merged in chunk order**.
//!
//! ## Determinism contract
//!
//! Results are a function of the input and the chunk size alone — **never**
//! of the thread count or the OS schedule. Concretely:
//!
//! 1. **Fixed chunk boundaries.** [`fixed_chunks`] partitions `0..total`
//!    into `⌈total/chunk⌉` contiguous ranges whose boundaries depend only on
//!    `total` and `chunk`. Callers must not derive chunk sizes from the
//!    thread count.
//! 2. **Pure chunk work.** The work closure sees `(chunk_index, range)` and
//!    must not communicate between chunks; every chunk is computed by
//!    identical code on identical inputs, whichever thread runs it.
//! 3. **Ordered merge.** [`run_chunked`] returns results indexed by chunk,
//!    and callers reduce them in that order. Floating-point reduction order
//!    is therefore fixed, so parallel output is *bit-for-bit identical* to
//!    the 1-thread run at any thread count.
//!
//! The coordinator's sensor sharding ([`crate::coordinator`]) reuses
//! [`fixed_chunks`] as its sharding rule (blocks of samples assigned
//! round-robin by block index), and the sketch encode uses it with
//! [`crate::sketch::PAR_CHUNK_ROWS`]-row chunks; the determinism test suite
//! (`rust/tests/determinism.rs`) locks the contract in for thread counts
//! {1, 2, 7}.

use std::ops::Range;
use std::sync::atomic::{AtomicUsize, Ordering};

/// How many threads a parallel region may use.
///
/// `threads == 0` means "auto": one thread per available core. The knob is
/// plumbed from `--threads` on the CLI and the `threads` config key; thanks
/// to the determinism contract it changes wall-clock time only, never
/// results.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Parallelism {
    /// Thread budget; 0 = all available cores.
    pub threads: usize,
}

impl Parallelism {
    /// Exactly one thread (runs inline, no spawning).
    pub const fn serial() -> Self {
        Self { threads: 1 }
    }

    /// One thread per available core.
    pub const fn auto() -> Self {
        Self { threads: 0 }
    }

    /// Exactly `threads` threads (0 = auto).
    pub const fn fixed(threads: usize) -> Self {
        Self { threads }
    }

    /// The concrete thread count this knob resolves to.
    pub fn resolved_threads(&self) -> usize {
        if self.threads == 0 {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        } else {
            self.threads
        }
    }
}

impl Default for Parallelism {
    fn default() -> Self {
        Self::auto()
    }
}

/// Partition `0..total` into contiguous chunks of `chunk` items (the last
/// chunk may be short). The boundaries depend only on `total` and `chunk` —
/// this is the fixed sharding rule of the determinism contract, shared with
/// the coordinator's sensor sharding.
pub fn fixed_chunks(total: usize, chunk: usize) -> Vec<Range<usize>> {
    assert!(chunk >= 1, "chunk size must be >= 1");
    let mut out = Vec::with_capacity(total.div_ceil(chunk));
    let mut start = 0;
    while start < total {
        let end = (start + chunk).min(total);
        out.push(start..end);
        start = end;
    }
    out
}

/// Run `work(chunk_index, range)` over every fixed chunk of `0..total`,
/// using up to `par` threads, and return the results **in chunk order**.
///
/// Scheduling is dynamic (threads pull the next chunk index from an atomic
/// counter — cheap work stealing), but per the determinism contract the
/// output is independent of both the schedule and the thread count. A panic
/// in any chunk propagates to the caller with its original payload.
pub fn run_chunked<R, F>(total: usize, chunk: usize, par: &Parallelism, work: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize, Range<usize>) -> R + Sync,
{
    let chunks = fixed_chunks(total, chunk);
    let n_chunks = chunks.len();
    let threads = par.resolved_threads().clamp(1, n_chunks.max(1));
    // Per-run and per-chunk accounting (observational only, I-18): the
    // chunk histogram is how utilization shows up — if per-chunk times
    // vary wildly, dynamic stealing is doing real balancing work. Costs a
    // few relaxed atomics per chunk, negligible against the chunk itself.
    let m = crate::obs::lib_metrics();
    m.parallel_runs.inc();
    m.parallel_chunks.add(n_chunks as u64);
    let timed_work = |i: usize, range: Range<usize>| {
        let _span = crate::obs::global().span("parallel_chunk", &m.parallel_chunk_seconds);
        work(i, range)
    };
    if threads <= 1 {
        return chunks
            .into_iter()
            .enumerate()
            .map(|(i, range)| timed_work(i, range))
            .collect();
    }

    let next = AtomicUsize::new(0);
    let next_ref = &next;
    let chunks_ref = &chunks;
    let work_ref = &timed_work;
    let per_thread: Vec<Vec<(usize, R)>> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..threads)
            .map(|_| {
                scope.spawn(move || {
                    let mut local = Vec::new();
                    loop {
                        let i = next_ref.fetch_add(1, Ordering::Relaxed);
                        if i >= n_chunks {
                            break;
                        }
                        local.push((i, work_ref(i, chunks_ref[i].clone())));
                    }
                    local
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().unwrap_or_else(|p| std::panic::resume_unwind(p)))
            .collect()
    });

    let mut indexed: Vec<(usize, R)> = per_thread.into_iter().flatten().collect();
    indexed.sort_by_key(|&(i, _)| i);
    debug_assert_eq!(indexed.len(), n_chunks);
    indexed.into_iter().map(|(_, r)| r).collect()
}

/// Map `f` over `0..total` with up to `par` threads; results in index
/// order. Sugar for [`run_chunked`] with single-item chunks — use it for
/// coarse tasks (experiment trials, L-BFGS restarts), not tight loops.
pub fn par_map<R, F>(total: usize, par: &Parallelism, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize) -> R + Sync,
{
    run_chunked(total, 1, par, |i, _range| f(i))
}

#[cfg(test)]
mod tests;
