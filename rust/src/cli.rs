//! A tiny declarative CLI argument parser (no `clap` in this environment).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`s, positional
//! arguments, and generates usage text. Each binary declares its options
//! up front; unknown options are hard errors (typos should not silently
//! change an experiment).

use anyhow::{bail, Result};
use std::collections::BTreeMap;

/// Declaration of one option.
#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    /// `true` for boolean flags (no value token).
    pub is_flag: bool,
    /// `true` for repeatable value options: every occurrence is kept, in
    /// order, retrievable via [`ParsedArgs::get_all`].
    pub is_multi: bool,
    /// Shown in usage for value options.
    pub value_hint: &'static str,
    pub default: Option<&'static str>,
}

/// A declared CLI: options + positional description.
#[derive(Clone, Debug, Default)]
pub struct CliSpec {
    pub name: &'static str,
    pub about: &'static str,
    pub positionals: &'static str,
    pub opts: Vec<OptSpec>,
}

impl CliSpec {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Self {
            name,
            about,
            positionals: "",
            opts: Vec::new(),
        }
    }

    pub fn positionals(mut self, desc: &'static str) -> Self {
        self.positionals = desc;
        self
    }

    /// Declare a value option.
    pub fn opt(
        mut self,
        name: &'static str,
        value_hint: &'static str,
        default: Option<&'static str>,
        help: &'static str,
    ) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            is_flag: false,
            is_multi: false,
            value_hint,
            default,
        });
        self
    }

    /// Declare a repeatable value option: `--name a --name b` keeps both,
    /// in order (a plain [`CliSpec::opt`] would keep only the last).
    pub fn multi(
        mut self,
        name: &'static str,
        value_hint: &'static str,
        help: &'static str,
    ) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            is_flag: false,
            is_multi: true,
            value_hint,
            default: None,
        });
        self
    }

    /// Declare a boolean flag.
    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            is_flag: true,
            is_multi: false,
            value_hint: "",
            default: None,
        });
        self
    }

    /// Render usage text.
    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nUSAGE:\n  {} ", self.name, self.about, self.name);
        if !self.positionals.is_empty() {
            s.push_str(self.positionals);
            s.push(' ');
        }
        s.push_str("[OPTIONS]\n\nOPTIONS:\n");
        for o in &self.opts {
            let left = if o.is_flag {
                format!("  --{}", o.name)
            } else if o.is_multi {
                format!("  --{} <{}>...", o.name, o.value_hint)
            } else {
                format!("  --{} <{}>", o.name, o.value_hint)
            };
            let default = o
                .default
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            s.push_str(&format!("{left:<34}{}{default}\n", o.help));
        }
        s.push_str("  --help                          print this message\n");
        s
    }

    /// Parse a token stream (not including argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(&self, args: I) -> Result<ParsedArgs> {
        let mut values: BTreeMap<String, String> = BTreeMap::new();
        let mut multi: BTreeMap<String, Vec<String>> = BTreeMap::new();
        let mut flags: Vec<String> = Vec::new();
        let mut positionals: Vec<String> = Vec::new();
        let mut it = args.into_iter().peekable();
        while let Some(tok) = it.next() {
            if tok == "--help" || tok == "-h" {
                bail!("{}", self.usage());
            }
            if let Some(stripped) = tok.strip_prefix("--") {
                let (name, inline_value) = match stripped.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let Some(spec) = self.opts.iter().find(|o| o.name == name) else {
                    bail!("unknown option '--{name}'\n\n{}", self.usage());
                };
                if spec.is_flag {
                    if inline_value.is_some() {
                        bail!("flag '--{name}' takes no value");
                    }
                    flags.push(name);
                } else {
                    let value = match inline_value {
                        Some(v) => v,
                        None => match it.next() {
                            Some(v) => v,
                            None => bail!("option '--{name}' requires a value"),
                        },
                    };
                    if spec.is_multi {
                        multi.entry(name).or_default().push(value);
                    } else {
                        values.insert(name, value);
                    }
                }
            } else {
                positionals.push(tok);
            }
        }
        // Fill declared defaults.
        for o in &self.opts {
            if let Some(d) = o.default {
                values.entry(o.name.to_string()).or_insert_with(|| d.to_string());
            }
        }
        Ok(ParsedArgs {
            values,
            multi,
            flags,
            positionals,
        })
    }
}

/// Parse outcome with typed getters.
#[derive(Clone, Debug, Default)]
pub struct ParsedArgs {
    values: BTreeMap<String, String>,
    multi: BTreeMap<String, Vec<String>>,
    flags: Vec<String>,
    positionals: Vec<String>,
}

impl ParsedArgs {
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    /// Every occurrence of a repeatable option, in argv order (empty if
    /// absent).
    pub fn get_all(&self, name: &str) -> &[String] {
        self.multi.get(name).map(|v| v.as_slice()).unwrap_or(&[])
    }

    pub fn get_usize(&self, name: &str) -> Result<Option<usize>> {
        self.parse_as(name)
    }

    pub fn get_u64(&self, name: &str) -> Result<Option<u64>> {
        self.parse_as(name)
    }

    pub fn get_f64(&self, name: &str) -> Result<Option<f64>> {
        self.parse_as(name)
    }

    fn parse_as<T: std::str::FromStr>(&self, name: &str) -> Result<Option<T>> {
        match self.values.get(name) {
            None => Ok(None),
            Some(raw) => match raw.parse::<T>() {
                Ok(v) => Ok(Some(v)),
                Err(_) => bail!("option '--{name}': cannot parse '{raw}'"),
            },
        }
    }

    pub fn positional(&self, index: usize) -> Option<&str> {
        self.positionals.get(index).map(|s| s.as_str())
    }

    pub fn positionals(&self) -> &[String] {
        &self.positionals
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> CliSpec {
        CliSpec::new("qckm", "test")
            .positionals("<cmd>")
            .opt("m", "NUM", Some("1000"), "frequencies")
            .opt("sigma", "FLOAT", None, "bandwidth")
            .multi("tenant", "NAME=SPEC", "declare a tenant (repeatable)")
            .flag("full", "run the full grid")
    }

    #[test]
    fn parses_values_flags_positionals() {
        let args = spec()
            .parse(["fig2a", "--m", "500", "--full", "--sigma=2.5"].map(String::from))
            .unwrap();
        assert_eq!(args.positional(0), Some("fig2a"));
        assert_eq!(args.get_usize("m").unwrap(), Some(500));
        assert_eq!(args.get_f64("sigma").unwrap(), Some(2.5));
        assert!(args.flag("full"));
        assert!(!args.flag("other"));
        assert_eq!(args.positionals().len(), 1);
    }

    #[test]
    fn multi_options_keep_every_occurrence_in_order() {
        let args = spec()
            .parse(
                ["--tenant", "a=a.toml", "--m", "5", "--tenant=b=b.toml"].map(String::from),
            )
            .unwrap();
        assert_eq!(args.get_all("tenant"), ["a=a.toml", "b=b.toml"]);
        assert_eq!(args.get_all("absent"), Vec::<String>::new().as_slice());
        // A plain value option still keeps only the last occurrence.
        let args = spec()
            .parse(["--m", "5", "--m", "7"].map(String::from))
            .unwrap();
        assert_eq!(args.get_usize("m").unwrap(), Some(7));
    }

    #[test]
    fn defaults_apply_when_missing() {
        let args = spec().parse(Vec::<String>::new()).unwrap();
        assert_eq!(args.get_usize("m").unwrap(), Some(1000));
        assert_eq!(args.get("sigma"), None);
    }

    #[test]
    fn rejects_unknown_and_malformed() {
        assert!(spec().parse(["--nope".to_string()]).is_err());
        assert!(spec().parse(["--m".to_string()]).is_err()); // missing value
        assert!(spec().parse(["--full=yes".to_string()]).is_err()); // flag with value
        let e = spec()
            .parse(["--m".to_string(), "abc".to_string()])
            .unwrap()
            .get_usize("m");
        assert!(e.is_err());
    }

    #[test]
    fn help_bails_with_usage() {
        let err = spec().parse(["--help".to_string()]).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("USAGE"));
        assert!(msg.contains("--m <NUM>"));
        assert!(msg.contains("[default: 1000]"));
    }
}
