//! `qckm` — the command-line launcher.
//!
//! ```text
//! qckm cluster     --data x.csv --k 10 [--method qckm:bits=3] [--config job.toml]
//! qckm sketch      --data shard.csv --sigma 1.2 --seed 7 --out shard.qsk
//! qckm sketch      --data more.csv --append shard.qsk  (online update)
//! qckm merge       --out merged.qsk shard0.qsk shard1.qsk …
//! qckm decode      --sketch merged.qsk --k 10 [--lo -2 --hi 2] --out c.csv
//! qckm serve       --dim 5 --m 1000 --sigma 1.2 --seed 7 [--port 0]
//! qckm push        --addr host:port --data shard.csv [--shard name]
//! qckm query       --addr host:port --k 10 [--window E] [--out c.csv]
//! qckm snapshot    --addr host:port --out live.qsk [--window E]
//! qckm ctl         --addr host:port stats|roll|shutdown
//! qckm experiment  fig2a|fig2b|fig3|prop1|ablation [--full]
//! qckm pipeline    [--workers 8] [--samples 100000] … (streaming demo)
//! ```
//!
//! `sketch` → `merge` → `decode` is the paper's distributed acquisition
//! pipeline split into stages: each shard is stream-sketched (bounded
//! memory, bit-for-bit the in-memory sketch) where its data lives, the
//! tiny `.qsk` files are merged associatively, and centroids are decoded
//! once from the pooled sketch — no stage ever needs the whole dataset.
//! `serve` keeps the same pooled state live behind a TCP protocol:
//! `push` streams batches in, `query` decodes centroids on demand (with a
//! centroid cache), `snapshot` drains the live pool back into a `.qsk`
//! the offline stages understand.
//!
//! Every `--method` takes an open-registry spec string (`ckm`, `qckm`,
//! `qckm:bits=B`, `triangle`, `modulo` — see `qckm::method`); on the
//! service verbs it is a *declaration* the server verifies, so a
//! distributed job can never silently mix methods.
//!
//! Every run prints its seed and full parameterization so results are
//! reproducible; experiment outputs are the rows/series recorded in
//! EXPERIMENTS.md.

use anyhow::{bail, Context, Result};
use qckm::cli::CliSpec;
use qckm::clompr::{decode_best_of, ClOmprParams};
use qckm::config::JobConfig;
use qckm::coordinator::{run_pipeline, PipelineConfig, SampleSource, WireFormat};
use qckm::method::MethodSpec;
use qckm::data::{load_csv, save_csv};
use qckm::experiments as exp;
use qckm::frequency::{DrawnFrequencies, SigmaHeuristic};
use qckm::linalg::{bounding_box, Mat};
use qckm::parallel::Parallelism;
use qckm::rng::Rng;
use qckm::server::{self, QuerySpec, ServiceConfig, SketchService};
use qckm::sketch::{PooledSketch, SketchOperator};
use qckm::stream;
use std::path::Path;
use std::sync::Arc;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(args) {
        eprintln!("{e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: Vec<String>) -> Result<()> {
    let Some(cmd) = args.first().cloned() else {
        bail!(
            "usage: qckm <cluster|sketch|merge|decode|serve|push|query|snapshot|ctl|\
             experiment|pipeline> …  (use --help per command)\n\
             see README.md for a tour"
        );
    };
    let rest = args[1..].to_vec();
    match cmd.as_str() {
        "cluster" => cmd_cluster(rest),
        "sketch" => cmd_sketch(rest),
        "merge" => cmd_merge(rest),
        "decode" => cmd_decode(rest),
        "serve" => cmd_serve(rest),
        "push" => cmd_push(rest),
        "query" => cmd_query(rest),
        "snapshot" => cmd_snapshot(rest),
        "ctl" => cmd_ctl(rest),
        "experiment" => cmd_experiment(rest),
        "pipeline" => cmd_pipeline(rest),
        other => {
            bail!(
                "unknown command '{other}' (cluster|sketch|merge|decode|serve|push|query|\
                 snapshot|ctl|experiment|pipeline)"
            )
        }
    }
}

/// Load the job config (file + CLI overrides).
fn job_from(args: &qckm::cli::ParsedArgs) -> Result<JobConfig> {
    let mut cfg = match args.get("config") {
        Some(path) => {
            let text = std::fs::read_to_string(path).with_context(|| format!("read {path}"))?;
            JobConfig::from_toml_str(&text)?
        }
        None => JobConfig::default(),
    };
    if let Some(m) = args.get_usize("m")? {
        cfg.sketch.num_frequencies = m;
    }
    if let Some(k) = args.get_usize("k")? {
        cfg.decode.k = k;
    }
    if let Some(method) = args.get("method") {
        cfg.sketch.method = MethodSpec::parse(method)?;
    }
    if let Some(s) = args.get_f64("sigma")? {
        cfg.sketch.sigma = SigmaHeuristic::Fixed(s);
    }
    if let Some(seed) = args.get_u64("seed")? {
        cfg.seed = seed;
    }
    if let Some(r) = args.get_usize("replicates")? {
        cfg.decode.replicates = r;
    }
    if let Some(t) = args.get_usize("threads")? {
        cfg.threads = t;
        cfg.decode.params.threads = t;
    }
    Ok(cfg)
}

fn build_operator(cfg: &JobConfig, x: &Mat, rng: &mut Rng) -> SketchOperator {
    let sigma = cfg.sketch.sigma.resolve(x, rng);
    let freqs = if cfg.sketch.method.dithered() {
        DrawnFrequencies::draw(cfg.sketch.law, x.cols(), cfg.sketch.num_frequencies, sigma, rng)
    } else {
        DrawnFrequencies::draw_undithered(
            cfg.sketch.law,
            x.cols(),
            cfg.sketch.num_frequencies,
            sigma,
            rng,
        )
    };
    eprintln!(
        "operator: method={} law={} M={} sigma={sigma:.4}",
        cfg.sketch.method.canonical(),
        cfg.sketch.law.name(),
        cfg.sketch.num_frequencies
    );
    SketchOperator::new(freqs, cfg.sketch.method.signature())
}

/// Shared `--method` help text. The CLI layer needs a `'static` string, so
/// this is a hint only; a bad spec gets the registry's authoritative
/// valid-family list at parse time.
const METHOD_HELP: &str = "method spec: ckm | qckm[:bits=B] | triangle | modulo";

/// Verify an optional `--method` declaration against the method a `.qsk`
/// header recorded (canonicalized through the registry first, so aliases
/// and case agree). `what` names the conflicting source in the error.
fn check_declared_method(
    parsed: &qckm::cli::ParsedArgs,
    meta_method: &str,
    what: &str,
) -> Result<()> {
    if let Some(m) = parsed.get("method") {
        if MethodSpec::parse(m)?.canonical() != meta_method {
            bail!("--method {m} conflicts with {what} (method={meta_method})");
        }
    }
    Ok(())
}

fn cmd_cluster(args: Vec<String>) -> Result<()> {
    let spec = CliSpec::new("qckm cluster", "compressively cluster a CSV dataset")
        .opt("data", "FILE", None, "input CSV (one sample per row)")
        .opt("k", "NUM", None, "number of clusters")
        .opt("m", "NUM", None, "number of frequencies")
        .opt("method", "SPEC", None, METHOD_HELP)
        .opt("sigma", "FLOAT", None, "kernel bandwidth (default: heuristic)")
        .opt("seed", "NUM", None, "RNG seed")
        .opt("replicates", "NUM", None, "decoder replicates")
        .opt(
            "threads",
            "NUM",
            None,
            "decoder threads, 0 = all cores (acquisition uses [pipeline] workers)",
        )
        .opt("config", "FILE", None, "TOML job config")
        .opt("out", "FILE", None, "write centroids CSV here");
    let parsed = spec.parse(args)?;
    let cfg = job_from(&parsed)?;
    let data_path = parsed.get("data").context("--data is required")?;
    let x = load_csv(Path::new(data_path))?;
    eprintln!("loaded {} x {} from {data_path}", x.rows(), x.cols());

    let mut rng = Rng::new(cfg.seed);
    let op = build_operator(&cfg, &x, &mut rng);

    // Acquire through the streaming coordinator (the Fig. 1 dataflow),
    // with the method's preferred pooling encoding on the wire.
    let wire = cfg.sketch.method.preferred_wire_format();
    let report = run_pipeline(
        &op,
        &SampleSource::Shared(Arc::new(x.clone())),
        &PipelineConfig {
            wire,
            ..cfg.pipeline.clone()
        },
        cfg.seed,
    );
    eprintln!(
        "acquired {} samples in {:.3}s ({:.0}/s), {} wire bytes, {} backpressure stalls",
        report.samples,
        report.elapsed_secs,
        report.throughput(),
        report.payload_bytes,
        report.blocked_sends
    );

    let (lo, hi) = bounding_box(&x);
    let sol = decode_best_of(
        &op,
        cfg.decode.k,
        &report.sketch,
        lo,
        hi,
        &cfg.decode.params,
        cfg.decode.replicates,
        &mut rng,
    );
    let s = qckm::metrics::sse(&x, &sol.centroids);
    println!("objective = {:.6}, SSE/N = {:.6}", sol.objective, s / x.rows() as f64);
    for k in 0..sol.centroids.rows() {
        let row: Vec<String> = sol.centroids.row(k).iter().map(|v| format!("{v:.5}")).collect();
        println!("c[{k}] (alpha={:.3}): {}", sol.weights[k], row.join(", "));
    }
    if let Some(out) = parsed.get("out") {
        save_csv(Path::new(out), &sol.centroids)?;
        eprintln!("centroids written to {out}");
    }
    Ok(())
}

/// Per-chunk pooling encoding for the streamed sketch — `auto` defers to
/// the method's preferred wire format (the one source of the method→wire
/// mapping, see [`MethodSpec::preferred_wire_format`]).
fn wire_from(parsed: &qckm::cli::ParsedArgs, method: &MethodSpec) -> Result<WireFormat> {
    Ok(match parsed.get("encoding").unwrap_or("auto") {
        "auto" => method.preferred_wire_format(),
        // The streaming fold re-checks this against the signature, but
        // failing at the flag gives the actionable error.
        "bits" if method.preferred_wire_format() != WireFormat::PackedBits => bail!(
            "--encoding bits needs a ±1-valued method (e.g. qckm); '{}' pools dense",
            method.canonical()
        ),
        "bits" => WireFormat::PackedBits,
        "dense" => WireFormat::DenseF64,
        other => bail!("unknown encoding '{other}' (auto|bits|dense)"),
    })
}

fn cmd_sketch(args: Vec<String>) -> Result<()> {
    let spec = CliSpec::new(
        "qckm sketch",
        "stream the pooled sketch of a dataset shard into a .qsk file",
    )
    .opt("data", "FILE", None, "input dataset (.csv, else raw f64 bin)")
    .opt("m", "NUM", None, "number of frequencies")
    .opt("method", "SPEC", None, METHOD_HELP)
    .opt(
        "sigma",
        "FLOAT",
        None,
        "kernel bandwidth; required for out-of-core streaming and for shards to merge",
    )
    .opt("seed", "NUM", None, "frequency-draw seed (must match across shards)")
    .opt("threads", "NUM", None, "compute threads (0 = all cores)")
    .opt("encoding", "FMT", Some("auto"), "per-chunk pooling: auto|bits|dense")
    .opt(
        "append",
        "FILE",
        None,
        "online update: stream --data into this existing .qsk (operator comes \
         from its header, fingerprint-verified) and rewrite it",
    )
    .opt("shard", "NAME", None, "provenance label (default: the data file stem)")
    .opt("config", "FILE", None, "TOML job config")
    .opt("out", "FILE", None, "write the pooled sketch (.qsk) here")
    .opt("out-csv", "FILE", None, "also write the mean sketch as one CSV row");
    let parsed = spec.parse(args)?;
    let cfg = job_from(&parsed)?;
    let data_path = parsed.get("data").context("--data is required")?;
    let par = Parallelism::fixed(cfg.threads);
    let shard_label = match parsed.get("shard") {
        Some(s) => s.to_string(),
        None => Path::new(data_path)
            .file_stem()
            .map(|s| s.to_string_lossy().into_owned())
            .unwrap_or_else(|| data_path.to_string()),
    };

    if let Some(append_path) = parsed.get("append") {
        return sketch_append(&parsed, append_path, data_path, &shard_label, &par);
    }
    let method = cfg.sketch.method.clone();
    let wire = wire_from(&parsed, &method)?;

    // The frequency draw is a pure function of (method, law, m, d, sigma,
    // seed) — the `.qsk` contract that lets every shard and the decoder
    // reproduce the same operator. A fixed sigma streams out-of-core; the
    // data-dependent heuristic needs the dataset once, in memory.
    let (op, pool) = match cfg.sketch.sigma {
        SigmaHeuristic::Fixed(sigma) => {
            let mut reader = stream::open_dataset(Path::new(data_path))?;
            let op = stream::draw_operator(
                &method,
                cfg.sketch.law,
                cfg.sketch.num_frequencies,
                reader.dim(),
                sigma,
                cfg.seed,
            );
            let mut pool = PooledSketch::new(op.sketch_len());
            let rows = stream::sketch_reader(&op, reader.as_mut(), wire, &mut pool, &par)?;
            if rows == 0 {
                bail!("{data_path}: empty dataset");
            }
            eprintln!("streamed {rows} rows from {data_path} ({wire:?} pooling)");
            (op, pool)
        }
        heuristic => {
            let mut reader = stream::open_dataset(Path::new(data_path))?;
            let x = stream::read_all(reader.as_mut())?;
            let sigma = heuristic.resolve(&x, &mut Rng::new(cfg.seed).substream(1));
            eprintln!(
                "note: sigma {sigma:.4} was estimated from the data in memory; pass --sigma \
                 to stream out-of-core and to keep independent shards mergeable"
            );
            let op = stream::draw_operator(
                &method,
                cfg.sketch.law,
                cfg.sketch.num_frequencies,
                x.cols(),
                sigma,
                cfg.seed,
            );
            // Same chunked fold as the streamed path (bitwise identical to
            // `sketch_into_par`), so --encoding is honored here too.
            let mut pool = PooledSketch::new(op.sketch_len());
            stream::sketch_reader(
                &op,
                &mut stream::MatChunkedReader::new(&x),
                wire,
                &mut pool,
                &par,
            )?;
            (op, pool)
        }
    };
    eprintln!(
        "operator: method={} law={} M={} sigma={:.4}",
        method.canonical(),
        cfg.sketch.law.name(),
        op.num_frequencies(),
        op.frequencies().sigma
    );

    let meta = stream::SketchMeta::for_operator(&op, &method, cfg.seed);
    if let Some(out) = parsed.get("out") {
        let prov = [stream::ShardRecord {
            label: shard_label.clone(),
            rows: pool.count(),
        }];
        stream::save_sketch_with(Path::new(out), &meta, &pool, &prov)?;
        eprintln!("sketch written to {out} [{}]", meta.describe());
    }
    let z = pool.mean();
    println!(
        "sketch: {} slots over {} samples, first 8: {:?}",
        z.len(),
        pool.count(),
        &z[..z.len().min(8)]
    );
    if let Some(out) = parsed.get("out-csv") {
        save_csv(Path::new(out), &Mat::from_vec(1, z.len(), z))?;
        eprintln!("mean sketch written to {out}");
    }
    Ok(())
}

/// `qckm sketch --append`: the online-update mode. The operator is NOT
/// re-drawn from CLI flags — it is rebuilt from the existing `.qsk` header
/// (fingerprint-verified), the new rows are streamed into the loaded pool
/// through the same bounded-memory fold, and the file is rewritten with an
/// extra provenance record. Any operator flag that contradicts the header
/// is an error (silently sketching new rows with a different operator
/// would corrupt the pool).
fn sketch_append(
    parsed: &qckm::cli::ParsedArgs,
    append_path: &str,
    data_path: &str,
    shard_label: &str,
    par: &Parallelism,
) -> Result<()> {
    let (meta, mut pool, mut prov) = stream::load_sketch_full(Path::new(append_path))?;
    if let Some(m) = parsed.get_usize("m")? {
        if m as u64 != meta.m {
            bail!("--m {m} conflicts with {append_path} (m={})", meta.m);
        }
    }
    check_declared_method(parsed, &meta.method, append_path)?;
    if let Some(sigma) = parsed.get_f64("sigma")? {
        if sigma.to_bits() != meta.sigma.to_bits() {
            bail!("--sigma {sigma} conflicts with {append_path} (sigma={})", meta.sigma);
        }
    }
    if let Some(seed) = parsed.get_u64("seed")? {
        if seed != meta.seed {
            bail!("--seed {seed} conflicts with {append_path} (seed={})", meta.seed);
        }
    }
    let op = meta.rebuild_operator()?;
    let method = MethodSpec::parse(&meta.method)?;
    let wire = wire_from(parsed, &method)?;
    let before = pool.count();
    let mut reader = stream::open_dataset(Path::new(data_path))?;
    let rows = stream::sketch_reader(&op, reader.as_mut(), wire, &mut pool, par)?;
    if rows == 0 {
        bail!("{data_path}: empty dataset");
    }
    prov.push(stream::ShardRecord {
        label: shard_label.to_string(),
        rows,
    });
    let out = parsed.get("out").unwrap_or(append_path);
    stream::save_sketch_with(Path::new(out), &meta, &pool, &prov)?;
    println!(
        "appended {rows} rows from {data_path} to {append_path} ({before} -> {} samples) -> {out}",
        pool.count()
    );
    Ok(())
}

fn cmd_merge(args: Vec<String>) -> Result<()> {
    let spec = CliSpec::new(
        "qckm merge",
        "pool shard sketches (.qsk) into one — associative, any order",
    )
    .positionals("<shard.qsk>…")
    .opt(
        "method",
        "SPEC",
        None,
        "declare the expected method; refused if the shards differ",
    )
    .opt("out", "FILE", None, "write the merged .qsk here");
    let parsed = spec.parse(args)?;
    let inputs = parsed.positionals();
    if inputs.is_empty() {
        bail!("need at least one input .qsk (see --help)");
    }
    let out = parsed.get("out").context("--out is required")?;

    let (meta, mut pool, mut prov) = stream::load_sketch_full(Path::new(&inputs[0]))?;
    check_declared_method(&parsed, &meta.method, &inputs[0])?;
    eprintln!("{}: {} samples [{}]", inputs[0], pool.count(), meta.describe());
    for input in &inputs[1..] {
        let (shard_meta, shard_pool, shard_prov) = stream::load_sketch_full(Path::new(input))?;
        meta.ensure_mergeable(&shard_meta)
            .with_context(|| format!("merging {input}"))?;
        eprintln!("{}: {} samples", input, shard_pool.count());
        pool.merge(&shard_pool);
        prov.extend(shard_prov);
    }
    stream::save_sketch_with(Path::new(out), &meta, &pool, &prov)?;
    println!(
        "merged {} shard(s), {} samples -> {out}",
        inputs.len(),
        pool.count()
    );
    Ok(())
}

fn cmd_decode(args: Vec<String>) -> Result<()> {
    let spec = CliSpec::new(
        "qckm decode",
        "decode K centroids from a pooled sketch (.qsk) — no dataset needed",
    )
    .opt("sketch", "FILE", None, "input .qsk sketch")
    .opt("k", "NUM", None, "number of clusters")
    .opt(
        "method",
        "SPEC",
        None,
        "declare the expected method; refused if the sketch differs",
    )
    .opt("replicates", "NUM", Some("1"), "decoder replicates (best objective wins)")
    .opt("threads", "NUM", Some("1"), "decoder threads (0 = all cores)")
    .opt("seed", "NUM", None, "decoder RNG seed (default: the sketch's seed)")
    .opt("lo", "FLOAT", Some("-1"), "centroid search box lower bound (every coordinate)")
    .opt("hi", "FLOAT", Some("1"), "centroid search box upper bound (every coordinate)")
    .opt("data", "FILE", None, "optional dataset: use its bounding box and report SSE")
    .opt("out", "FILE", None, "write centroids CSV here");
    let parsed = spec.parse(args)?;
    let sketch_path = parsed.get("sketch").context("--sketch is required")?;
    let k = parsed.get_usize("k")?.context("--k is required")?;

    let (meta, pool) = stream::load_sketch(Path::new(sketch_path))?;
    check_declared_method(&parsed, &meta.method, sketch_path)?;
    if pool.count() == 0 {
        bail!("{sketch_path}: sketch pools zero samples");
    }
    let op = meta.rebuild_operator()?;
    eprintln!(
        "sketch: {} samples, {} slots [{}]",
        pool.count(),
        pool.len(),
        meta.describe()
    );

    let x = match parsed.get("data") {
        Some(p) => {
            let mut reader = stream::open_dataset(Path::new(p))?;
            let x = stream::read_all(reader.as_mut())?;
            if x.cols() != op.dim() {
                bail!(
                    "{p}: dataset dimension {} does not match the sketch's dimension {}",
                    x.cols(),
                    op.dim()
                );
            }
            Some(x)
        }
        None => None,
    };
    let (lo, hi) = match &x {
        Some(x) => bounding_box(x),
        None => {
            let lo = parsed.get_f64("lo")?.unwrap();
            let hi = parsed.get_f64("hi")?.unwrap();
            if lo > hi {
                bail!("--lo {lo} must not exceed --hi {hi}");
            }
            (vec![lo; op.dim()], vec![hi; op.dim()])
        }
    };

    let params = ClOmprParams {
        threads: parsed.get_usize("threads")?.unwrap(),
        ..ClOmprParams::default()
    };
    let replicates = parsed.get_usize("replicates")?.unwrap().max(1);
    let seed = parsed.get_u64("seed")?.unwrap_or(meta.seed);
    let z = pool.mean();
    let mut rng = Rng::new(seed);
    let sol = decode_best_of(&op, k, &z, lo, hi, &params, replicates, &mut rng);

    println!("objective = {:.6}", sol.objective);
    if let Some(x) = &x {
        let s = qckm::metrics::sse(x, &sol.centroids);
        println!("SSE/N = {:.6}", s / x.rows() as f64);
    }
    for c in 0..sol.centroids.rows() {
        let row: Vec<String> = sol.centroids.row(c).iter().map(|v| format!("{v:.5}")).collect();
        println!("c[{c}] (alpha={:.3}): {}", sol.weights[c], row.join(", "));
    }
    if let Some(out) = parsed.get("out") {
        save_csv(Path::new(out), &sol.centroids)?;
        eprintln!("centroids written to {out}");
    }
    Ok(())
}

/// `qckm serve` — the online sketch service (see `qckm::server`).
fn cmd_serve(args: Vec<String>) -> Result<()> {
    let spec = CliSpec::new(
        "qckm serve",
        "run the online sketch service: concurrent ingest, windowed pooling, live decode",
    )
    .opt("host", "ADDR", Some("127.0.0.1"), "bind address")
    .opt("port", "NUM", Some("0"), "bind port (0 = ephemeral; the bound port is printed)")
    .opt("dim", "NUM", None, "data dimension (required unless --seed-sketch)")
    .opt("m", "NUM", None, "number of frequencies")
    .opt("method", "SPEC", None, METHOD_HELP)
    .opt("sigma", "FLOAT", None, "kernel bandwidth (required unless --seed-sketch)")
    .opt("seed", "NUM", None, "frequency-draw seed")
    .opt("threads", "NUM", None, "encode/decode threads (0 = all cores)")
    .opt("epochs", "NUM", Some("16"), "closed epochs retained for windowed queries")
    .opt("cache", "NUM", Some("32"), "cached decodes retained")
    .opt(
        "seed-sketch",
        "FILE",
        None,
        "seed the server from this .qsk (operator comes from its header)",
    )
    .opt("seed-shard", "NAME", Some("__seed__"), "shard label for the seeded history")
    .opt("config", "FILE", None, "TOML job config");
    let parsed = spec.parse(args)?;
    let cfg = job_from(&parsed)?;

    // The operator is fixed for the server's lifetime: either rebuilt from
    // a snapshot header (fingerprint-verified) or drawn fresh from the
    // CLI parameters — the same pure-function draw the offline stages use.
    let (meta, op, seed_pool) = match parsed.get("seed-sketch") {
        Some(path) => {
            let (meta, pool, prov) = stream::load_sketch_full(Path::new(path))?;
            // The operator comes entirely from the snapshot header; refuse
            // operator flags that contradict it (same convention as
            // `qckm sketch --append`) instead of silently ignoring them.
            if let Some(m) = parsed.get_usize("m")? {
                if m as u64 != meta.m {
                    bail!("--m {m} conflicts with {path} (m={})", meta.m);
                }
            }
            check_declared_method(&parsed, &meta.method, path)?;
            if let SigmaHeuristic::Fixed(sigma) = cfg.sketch.sigma {
                if sigma.to_bits() != meta.sigma.to_bits() {
                    bail!("--sigma {sigma} conflicts with {path} (sigma={})", meta.sigma);
                }
            }
            if let Some(seed) = parsed.get_u64("seed")? {
                if seed != meta.seed {
                    bail!("--seed {seed} conflicts with {path} (seed={})", meta.seed);
                }
            }
            let op = meta.rebuild_operator()?;
            eprintln!(
                "seeded from {path}: {} samples across {} provenance record(s)",
                pool.count(),
                prov.len()
            );
            (meta, op, Some(pool))
        }
        None => {
            let dim = parsed
                .get_usize("dim")?
                .context("--dim is required without --seed-sketch")?;
            let SigmaHeuristic::Fixed(sigma) = cfg.sketch.sigma else {
                bail!("--sigma is required without --seed-sketch (shards must agree on it)");
            };
            let op = stream::draw_operator(
                &cfg.sketch.method,
                cfg.sketch.law,
                cfg.sketch.num_frequencies,
                dim,
                sigma,
                cfg.seed,
            );
            let meta = stream::SketchMeta::for_operator(&op, &cfg.sketch.method, cfg.seed);
            (meta, op, None)
        }
    };
    eprintln!("operator: {}", meta.describe());

    let service_cfg = ServiceConfig {
        epoch_capacity: parsed.get_usize("epochs")?.unwrap().max(1),
        cache_capacity: parsed.get_usize("cache")?.unwrap().max(1),
        threads: Parallelism::fixed(cfg.threads),
        decode: ClOmprParams {
            threads: cfg.threads,
            ..ClOmprParams::default()
        },
    };
    let service = SketchService::new(op, meta, service_cfg);
    if let Some(pool) = seed_pool {
        service.seed_with(parsed.get("seed-shard").unwrap(), pool)?;
    }

    let host = parsed.get("host").unwrap();
    let port = parsed.get_usize("port")?.unwrap();
    if port > u16::MAX as usize {
        bail!("--port {port} out of range");
    }
    let listener = std::net::TcpListener::bind((host, port as u16))
        .with_context(|| format!("bind {host}:{port}"))?;
    // Machine-parseable: tests and scripts read the ephemeral port here.
    println!("LISTENING {}", listener.local_addr()?);
    std::io::Write::flush(&mut std::io::stdout())?;

    let served = server::serve(listener, Arc::new(service))?;
    eprintln!("server stopped after {served} connection(s)");
    Ok(())
}

/// Connect a service client, declaring `--method` (canonicalized through
/// the registry, so typos and junk fail locally with the valid-family
/// list) if the flag was given.
fn connect_with_method(
    addr: &str,
    parsed: &qckm::cli::ParsedArgs,
) -> Result<qckm::server::Client> {
    let client = qckm::server::Client::connect(addr)?;
    Ok(match parsed.get("method") {
        Some(m) => client.declare_method(MethodSpec::parse(m)?.canonical()),
        None => client,
    })
}

fn cmd_push(args: Vec<String>) -> Result<()> {
    let spec = CliSpec::new("qckm push", "stream a dataset into a serving node's shard")
        .opt("addr", "HOST:PORT", None, "server address")
        .opt("data", "FILE", None, "input dataset (.csv, else raw f64 bin)")
        .opt("shard", "NAME", None, "shard label (default: the data file stem)")
        .opt(
            "method",
            "SPEC",
            None,
            "declare the expected method; the server refuses a mismatch",
        )
        .opt("batch", "NUM", Some("4096"), "rows per push message");
    let parsed = spec.parse(args)?;
    let addr = parsed.get("addr").context("--addr is required")?;
    let data_path = parsed.get("data").context("--data is required")?;
    let batch = parsed.get_usize("batch")?.unwrap().max(1);
    let shard = match parsed.get("shard") {
        Some(s) => s.to_string(),
        None => Path::new(data_path)
            .file_stem()
            .map(|s| s.to_string_lossy().into_owned())
            .unwrap_or_else(|| data_path.to_string()),
    };

    let mut reader = stream::open_dataset(Path::new(data_path))?;
    let dim = reader.dim();
    // Clamp the batch so every push message fits one protocol frame.
    let cap = qckm::server::proto::max_batch_rows(dim);
    let batch = if batch > cap {
        eprintln!("note: --batch {batch} clamped to {cap} rows (frame size cap at dim {dim})");
        cap
    } else {
        batch
    };
    let mut client = connect_with_method(addr, &parsed)?;
    let mut pushed = 0u64;
    let mut buf: Vec<f64> = Vec::new();
    let (mut shard_rows, mut total_rows) = (0, 0);
    loop {
        buf.clear();
        let mut rows = 0usize;
        while rows < batch {
            let got = reader.next_block(batch - rows, &mut buf)?;
            if got == 0 {
                break;
            }
            rows += got;
        }
        if rows == 0 {
            break;
        }
        let block = Mat::from_vec(rows, dim, std::mem::take(&mut buf));
        (shard_rows, total_rows) = client.push(&shard, &block)?;
        buf = block.into_vec();
        pushed += rows as u64;
    }
    if pushed == 0 {
        bail!("{data_path}: empty dataset");
    }
    println!(
        "pushed {pushed} rows from {data_path} to shard '{shard}' \
         (shard total {shard_rows}, server total {total_rows})"
    );
    Ok(())
}

fn cmd_query(args: Vec<String>) -> Result<()> {
    let spec = CliSpec::new("qckm query", "decode centroids live from a serving node")
        .opt("addr", "HOST:PORT", None, "server address")
        .opt("k", "NUM", None, "number of clusters")
        .opt(
            "method",
            "SPEC",
            None,
            "declare the expected method; the server refuses a mismatch",
        )
        .opt(
            "window",
            "NUM",
            Some("0"),
            "epochs to pool: 0 = all-time, E = open epoch + E-1 newest closed",
        )
        .opt("replicates", "NUM", Some("1"), "decoder replicates (best objective wins)")
        .opt("seed", "NUM", None, "decoder RNG seed (default: the operator's seed)")
        .opt("lo", "FLOAT", Some("-1"), "centroid search box lower bound (every coordinate)")
        .opt("hi", "FLOAT", Some("1"), "centroid search box upper bound (every coordinate)")
        .opt("out", "FILE", None, "write centroids CSV here");
    let parsed = spec.parse(args)?;
    let addr = parsed.get("addr").context("--addr is required")?;
    let k = parsed.get_usize("k")?.context("--k is required")?;

    let mut client = connect_with_method(addr, &parsed)?;
    let report = client.query(&QuerySpec {
        k: k as u32,
        window: parsed.get_usize("window")?.unwrap() as u32,
        replicates: parsed.get_usize("replicates")?.unwrap().max(1) as u32,
        seed: parsed.get_u64("seed")?,
        lo: parsed.get_f64("lo")?.unwrap(),
        hi: parsed.get_f64("hi")?.unwrap(),
    })?;
    eprintln!(
        "window: {} rows over {} epoch(s){}",
        report.rows,
        report.epochs,
        if report.cached { " [cached]" } else { "" }
    );
    println!("objective = {:.6}", report.objective);
    let centroids = Mat::from_vec(report.k as usize, report.dim as usize, report.centroids);
    for c in 0..centroids.rows() {
        let row: Vec<String> = centroids.row(c).iter().map(|v| format!("{v:.5}")).collect();
        println!("c[{c}] (alpha={:.3}): {}", report.weights[c], row.join(", "));
    }
    if let Some(out) = parsed.get("out") {
        save_csv(Path::new(out), &centroids)?;
        eprintln!("centroids written to {out}");
    }
    Ok(())
}

fn cmd_snapshot(args: Vec<String>) -> Result<()> {
    let spec = CliSpec::new(
        "qckm snapshot",
        "drain a serving node's window into a .qsk file (offline-decodable)",
    )
    .opt("addr", "HOST:PORT", None, "server address")
    .opt("window", "NUM", Some("0"), "epochs to pool (0 = all-time)")
    .opt(
        "method",
        "SPEC",
        None,
        "declare the expected method; the server refuses a mismatch",
    )
    .opt("out", "FILE", None, "write the .qsk here");
    let parsed = spec.parse(args)?;
    let addr = parsed.get("addr").context("--addr is required")?;
    let out = parsed.get("out").context("--out is required")?;

    let mut client = connect_with_method(addr, &parsed)?;
    let bytes = client.snapshot(parsed.get_usize("window")?.unwrap() as u32)?;
    std::fs::write(out, &bytes).with_context(|| format!("write {out}"))?;
    // Re-load what we wrote: validates the checksum end-to-end and tells
    // the operator what they got.
    let (meta, pool, prov) = stream::load_sketch_full(Path::new(out))?;
    println!(
        "snapshot: {} samples across {} shard record(s) -> {out} [{}]",
        pool.count(),
        prov.len(),
        meta.describe()
    );
    Ok(())
}

fn cmd_ctl(args: Vec<String>) -> Result<()> {
    let spec = CliSpec::new("qckm ctl", "administer a serving node")
        .positionals("<stats|roll|shutdown>")
        .opt("addr", "HOST:PORT", None, "server address");
    let parsed = spec.parse(args)?;
    let addr = parsed.get("addr").context("--addr is required")?;
    let verb = parsed.positional(0).context("which action? (stats|roll|shutdown)")?;
    let mut client = qckm::server::Client::connect(addr)?;
    match verb {
        "stats" => {
            let s = client.stats()?;
            println!(
                "method {} | epoch {} | {} rows all-time | {} closed epoch(s) held | \
                 cache {} hit / {} miss",
                s.method, s.epoch, s.rows_total, s.epochs_held, s.cache_hits, s.cache_misses
            );
            for (label, rows) in &s.shards {
                println!("  shard '{label}': {rows} rows");
            }
        }
        "roll" => {
            let (epoch, rows_closed) = client.roll()?;
            println!("rolled: epoch {epoch} open, {rows_closed} rows closed");
        }
        "shutdown" => {
            client.shutdown()?;
            println!("server acknowledged shutdown");
        }
        other => bail!("unknown ctl action '{other}' (stats|roll|shutdown)"),
    }
    Ok(())
}

fn cmd_experiment(args: Vec<String>) -> Result<()> {
    let spec = CliSpec::new("qckm experiment", "regenerate a paper figure")
        .positionals("<fig2a|fig2b|fig3|prop1|ablation>")
        .flag("full", "paper-scale grid (slow) instead of the quick grid")
        .flag("streamed", "fig2 only: sketch trials through the streaming fold")
        .opt("trials", "NUM", None, "override trials per cell")
        .opt("samples", "NUM", None, "override dataset size")
        .opt("seed", "NUM", None, "override seed")
        .opt("threads", "NUM", None, "trial fan-out threads (0 = all cores)");
    let parsed = spec.parse(args)?;
    let which = parsed
        .positional(0)
        .context("which experiment? (fig2a|fig2b|fig3|prop1|ablation)")?;
    let full = parsed.flag("full");

    match which {
        "fig2a" | "fig2b" => {
            let variant = if which == "fig2a" {
                exp::Fig2Variant::VaryDimension
            } else {
                exp::Fig2Variant::VaryClusters
            };
            let mut cfg = if full {
                exp::Fig2Config::full(variant)
            } else {
                exp::Fig2Config::quick(variant)
            };
            if let Some(t) = parsed.get_usize("trials")? {
                cfg.trials = t;
            }
            if let Some(s) = parsed.get_usize("samples")? {
                cfg.n_samples = s;
            }
            if let Some(seed) = parsed.get_u64("seed")? {
                cfg.seed = seed;
            }
            if let Some(t) = parsed.get_usize("threads")? {
                cfg.threads = t;
            }
            cfg.streamed = parsed.flag("streamed");
            let res = exp::run_fig2(&cfg);
            println!("{}", res.render());
        }
        "fig3" => {
            let mut cfg = if full {
                exp::Fig3Config::full()
            } else {
                exp::Fig3Config::quick()
            };
            if let Some(t) = parsed.get_usize("trials")? {
                cfg.trials = t;
            }
            if let Some(s) = parsed.get_usize("samples")? {
                cfg.n_samples = s;
            }
            if let Some(seed) = parsed.get_u64("seed")? {
                cfg.seed = seed;
            }
            if let Some(t) = parsed.get_usize("threads")? {
                cfg.threads = t;
            }
            let res = exp::run_fig3(&cfg);
            println!("{}", res.render());
        }
        "prop1" => {
            let mut cfg = exp::Prop1Config::default();
            if let Some(t) = parsed.get_usize("trials")? {
                cfg.repeats = t;
            }
            if let Some(seed) = parsed.get_u64("seed")? {
                cfg.seed = seed;
            }
            let sigs: [Arc<dyn qckm::signature::Signature>; 3] = [
                Arc::new(qckm::signature::UniversalQuantizer),
                Arc::new(qckm::signature::Triangle),
                Arc::new(qckm::signature::ModuloRamp),
            ];
            for sig in sigs {
                let res = exp::run_prop1(sig, &cfg);
                println!("{}", res.render());
            }
        }
        "ablation" => {
            let mut cfg = exp::AblationConfig::default();
            if let Some(t) = parsed.get_usize("trials")? {
                cfg.trials = t;
            }
            if let Some(t) = parsed.get_usize("threads")? {
                cfg.threads = t;
            }
            if full {
                cfg.trials = 30;
                cfg.ratios = vec![0.5, 1.0, 2.0, 4.0, 8.0];
            }
            let res = exp::run_ablation(&cfg);
            println!("{}", res.render());
        }
        other => bail!("unknown experiment '{other}'"),
    }
    Ok(())
}

fn cmd_pipeline(args: Vec<String>) -> Result<()> {
    let spec = CliSpec::new("qckm pipeline", "streaming 1-bit sensor-cloud demo")
        .opt("workers", "NUM", Some("4"), "sensor workers")
        .opt("samples", "NUM", Some("100000"), "total samples to acquire")
        .opt("dim", "NUM", Some("10"), "sample dimension")
        .opt("k", "NUM", Some("4"), "clusters to synthesize + decode")
        .opt("m", "NUM", Some("400"), "frequencies")
        .opt("batch", "NUM", Some("64"), "examples per wire message")
        .opt("queue", "NUM", Some("16"), "channel capacity")
        .opt("wire", "FMT", Some("bits"), "bits|dense")
        .opt(
            "method",
            "SPEC",
            None,
            "encode method (default: the wire's preferred method — \
             qckm for bits, ckm for dense)",
        )
        .opt("seed", "NUM", Some("0"), "seed");
    let parsed = spec.parse(args)?;
    let workers = parsed.get_usize("workers")?.unwrap();
    let samples = parsed.get_usize("samples")?.unwrap();
    let dim = parsed.get_usize("dim")?.unwrap();
    let k = parsed.get_usize("k")?.unwrap();
    let m = parsed.get_usize("m")?.unwrap();
    let seed = parsed.get_u64("seed")?.unwrap();
    let wire = match parsed.get("wire").unwrap() {
        "bits" => WireFormat::PackedBits,
        "dense" => WireFormat::DenseF64,
        other => bail!("unknown wire '{other}'"),
    };

    // Synthetic sensor field: K Gaussians at random ±1 corners.
    let mut rng = Rng::new(seed);
    let proto = qckm::data::gaussian_mixture_pm1(k.max(2) * 64, dim, k, &mut rng);
    let means = Arc::new(proto.means.clone());
    let std = (dim as f64 / 20.0).sqrt();
    let source = SampleSource::Synthetic {
        total: samples,
        dim,
        make: Arc::new(move |r: &mut Rng, out: &mut [f64]| {
            let c = r.next_below(means.rows() as u64) as usize;
            for (j, v) in out.iter_mut().enumerate() {
                *v = means.get(c, j) + std * r.gaussian();
            }
        }),
    };

    let sigma = SigmaHeuristic::default().resolve(&proto.points, &mut rng);
    let freqs = DrawnFrequencies::draw(
        qckm::frequency::FrequencyLaw::AdaptedRadius,
        dim,
        m,
        sigma,
        &mut rng,
    );
    // The signature comes from the method spec, not from an assumption
    // about the wire: dense no longer hardcodes the cosine, and any
    // registry family can drive the demo. (The frequency draw above stays
    // dithered for every method, as this demo always did.)
    let method = match parsed.get("method") {
        Some(s) => MethodSpec::parse(s)?,
        None => MethodSpec::parse(match wire {
            WireFormat::PackedBits => "qckm",
            WireFormat::DenseF64 => "ckm",
        })?,
    };
    if wire == WireFormat::PackedBits
        && method.preferred_wire_format() != WireFormat::PackedBits
    {
        bail!(
            "--wire bits needs a ±1-valued method (e.g. qckm); '{}' requires --wire dense",
            method.canonical()
        );
    }
    eprintln!("pipeline method: {}", method.canonical());
    let op = SketchOperator::new(freqs, method.signature());

    let report = run_pipeline(
        &op,
        &source,
        &PipelineConfig {
            workers,
            batch_size: parsed.get_usize("batch")?.unwrap(),
            queue_capacity: parsed.get_usize("queue")?.unwrap(),
            wire,
        },
        seed,
    );
    println!(
        "pipeline: {} samples in {:.3}s → {:.0} samples/s",
        report.samples,
        report.elapsed_secs,
        report.throughput()
    );
    println!(
        "wire: {} bytes total ({:.2} bytes/sample), queue high-water {}, {} stalls",
        report.payload_bytes,
        report.payload_bytes as f64 / report.samples as f64,
        report.queue_high_water,
        report.blocked_sends
    );

    let lo = vec![-2.0; dim];
    let hi = vec![2.0; dim];
    let sol = qckm::clompr::ClOmpr::new(&op, k)
        .with_bounds(lo, hi)
        .run(&report.sketch, &mut rng);
    println!(
        "decoded {} centroids, objective {:.4}",
        sol.centroids.rows(),
        sol.objective
    );
    for i in 0..sol.centroids.rows() {
        let c: Vec<String> = sol
            .centroids
            .row(i)
            .iter()
            .take(6)
            .map(|v| format!("{v:+.2}"))
            .collect();
        println!("  c[{i}] alpha={:.3} [{} …]", sol.weights[i], c.join(", "));
    }
    Ok(())
}
