//! `qckm` — the command-line launcher.
//!
//! ```text
//! qckm cluster     --data x.csv --k 10 [--method qckm] [--config job.toml]
//! qckm sketch      --data x.csv [--method qckm] --out sketch.csv
//! qckm experiment  fig2a|fig2b|fig3|prop1|ablation [--full]
//! qckm pipeline    [--workers 8] [--samples 100000] … (streaming demo)
//! ```
//!
//! Every run prints its seed and full parameterization so results are
//! reproducible; experiment outputs are the rows/series recorded in
//! EXPERIMENTS.md.

use anyhow::{bail, Context, Result};
use qckm::cli::CliSpec;
use qckm::clompr::decode_best_of;
use qckm::config::{JobConfig, Method};
use qckm::coordinator::{run_pipeline, PipelineConfig, SampleSource, WireFormat};
use qckm::data::{load_csv, save_csv};
use qckm::experiments as exp;
use qckm::frequency::{DrawnFrequencies, SigmaHeuristic};
use qckm::linalg::{bounding_box, Mat};
use qckm::rng::Rng;
use qckm::sketch::SketchOperator;
use std::path::Path;
use std::sync::Arc;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(args) {
        eprintln!("{e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: Vec<String>) -> Result<()> {
    let Some(cmd) = args.first().cloned() else {
        bail!(
            "usage: qckm <cluster|sketch|experiment|pipeline> …  (use --help per command)\n\
             see README.md for a tour"
        );
    };
    let rest = args[1..].to_vec();
    match cmd.as_str() {
        "cluster" => cmd_cluster(rest),
        "sketch" => cmd_sketch(rest),
        "experiment" => cmd_experiment(rest),
        "pipeline" => cmd_pipeline(rest),
        other => bail!("unknown command '{other}' (cluster|sketch|experiment|pipeline)"),
    }
}

/// Load the job config (file + CLI overrides).
fn job_from(args: &qckm::cli::ParsedArgs) -> Result<JobConfig> {
    let mut cfg = match args.get("config") {
        Some(path) => {
            let text = std::fs::read_to_string(path).with_context(|| format!("read {path}"))?;
            JobConfig::from_toml_str(&text)?
        }
        None => JobConfig::default(),
    };
    if let Some(m) = args.get_usize("m")? {
        cfg.sketch.num_frequencies = m;
    }
    if let Some(k) = args.get_usize("k")? {
        cfg.decode.k = k;
    }
    if let Some(method) = args.get("method") {
        cfg.sketch.method = Method::parse(method)?;
    }
    if let Some(s) = args.get_f64("sigma")? {
        cfg.sketch.sigma = SigmaHeuristic::Fixed(s);
    }
    if let Some(seed) = args.get_u64("seed")? {
        cfg.seed = seed;
    }
    if let Some(r) = args.get_usize("replicates")? {
        cfg.decode.replicates = r;
    }
    if let Some(t) = args.get_usize("threads")? {
        cfg.threads = t;
        cfg.decode.params.threads = t;
    }
    Ok(cfg)
}

fn build_operator(cfg: &JobConfig, x: &Mat, rng: &mut Rng) -> SketchOperator {
    let sigma = cfg.sketch.sigma.resolve(x, rng);
    let freqs = if cfg.sketch.method.dithered() {
        DrawnFrequencies::draw(cfg.sketch.law, x.cols(), cfg.sketch.num_frequencies, sigma, rng)
    } else {
        DrawnFrequencies::draw_undithered(
            cfg.sketch.law,
            x.cols(),
            cfg.sketch.num_frequencies,
            sigma,
            rng,
        )
    };
    eprintln!(
        "operator: method={} law={} M={} sigma={sigma:.4}",
        cfg.sketch.method.name(),
        cfg.sketch.law.name(),
        cfg.sketch.num_frequencies
    );
    SketchOperator::new(freqs, cfg.sketch.method.signature())
}

fn cmd_cluster(args: Vec<String>) -> Result<()> {
    let spec = CliSpec::new("qckm cluster", "compressively cluster a CSV dataset")
        .opt("data", "FILE", None, "input CSV (one sample per row)")
        .opt("k", "NUM", None, "number of clusters")
        .opt("m", "NUM", None, "number of frequencies")
        .opt("method", "NAME", None, "ckm|qckm|triangle")
        .opt("sigma", "FLOAT", None, "kernel bandwidth (default: heuristic)")
        .opt("seed", "NUM", None, "RNG seed")
        .opt("replicates", "NUM", None, "decoder replicates")
        .opt(
            "threads",
            "NUM",
            None,
            "decoder threads, 0 = all cores (acquisition uses [pipeline] workers)",
        )
        .opt("config", "FILE", None, "TOML job config")
        .opt("out", "FILE", None, "write centroids CSV here");
    let parsed = spec.parse(args)?;
    let cfg = job_from(&parsed)?;
    let data_path = parsed.get("data").context("--data is required")?;
    let x = load_csv(Path::new(data_path))?;
    eprintln!("loaded {} x {} from {data_path}", x.rows(), x.cols());

    let mut rng = Rng::new(cfg.seed);
    let op = build_operator(&cfg, &x, &mut rng);

    // Acquire through the streaming coordinator (the Fig. 1 dataflow).
    let wire = match cfg.sketch.method {
        Method::Qckm => WireFormat::PackedBits,
        _ => WireFormat::DenseF64,
    };
    let report = run_pipeline(
        &op,
        &SampleSource::Shared(Arc::new(x.clone())),
        &PipelineConfig {
            wire,
            ..cfg.pipeline.clone()
        },
        cfg.seed,
    );
    eprintln!(
        "acquired {} samples in {:.3}s ({:.0}/s), {} wire bytes, {} backpressure stalls",
        report.samples,
        report.elapsed_secs,
        report.throughput(),
        report.payload_bytes,
        report.blocked_sends
    );

    let (lo, hi) = bounding_box(&x);
    let sol = decode_best_of(
        &op,
        cfg.decode.k,
        &report.sketch,
        lo,
        hi,
        &cfg.decode.params,
        cfg.decode.replicates,
        &mut rng,
    );
    let s = qckm::metrics::sse(&x, &sol.centroids);
    println!("objective = {:.6}, SSE/N = {:.6}", sol.objective, s / x.rows() as f64);
    for k in 0..sol.centroids.rows() {
        let row: Vec<String> = sol.centroids.row(k).iter().map(|v| format!("{v:.5}")).collect();
        println!("c[{k}] (alpha={:.3}): {}", sol.weights[k], row.join(", "));
    }
    if let Some(out) = parsed.get("out") {
        save_csv(Path::new(out), &sol.centroids)?;
        eprintln!("centroids written to {out}");
    }
    Ok(())
}

fn cmd_sketch(args: Vec<String>) -> Result<()> {
    let spec = CliSpec::new("qckm sketch", "compute the pooled sketch of a CSV dataset")
        .opt("data", "FILE", None, "input CSV")
        .opt("m", "NUM", None, "number of frequencies")
        .opt("method", "NAME", None, "ckm|qckm|triangle")
        .opt("sigma", "FLOAT", None, "kernel bandwidth")
        .opt("seed", "NUM", None, "RNG seed")
        .opt("threads", "NUM", None, "compute threads (0 = all cores)")
        .opt("config", "FILE", None, "TOML job config")
        .opt("out", "FILE", None, "write the sketch as one CSV row");
    let parsed = spec.parse(args)?;
    let cfg = job_from(&parsed)?;
    let data_path = parsed.get("data").context("--data is required")?;
    let x = load_csv(Path::new(data_path))?;
    let mut rng = Rng::new(cfg.seed);
    let op = build_operator(&cfg, &x, &mut rng);
    let z = op.sketch_dataset_par(&x, &qckm::parallel::Parallelism::fixed(cfg.threads));
    println!(
        "sketch: {} slots, first 8: {:?}",
        z.len(),
        &z[..z.len().min(8)]
    );
    if let Some(out) = parsed.get("out") {
        save_csv(Path::new(out), &Mat::from_vec(1, z.len(), z))?;
        eprintln!("sketch written to {out}");
    }
    Ok(())
}

fn cmd_experiment(args: Vec<String>) -> Result<()> {
    let spec = CliSpec::new("qckm experiment", "regenerate a paper figure")
        .positionals("<fig2a|fig2b|fig3|prop1|ablation>")
        .flag("full", "paper-scale grid (slow) instead of the quick grid")
        .opt("trials", "NUM", None, "override trials per cell")
        .opt("samples", "NUM", None, "override dataset size")
        .opt("seed", "NUM", None, "override seed")
        .opt("threads", "NUM", None, "trial fan-out threads (0 = all cores)");
    let parsed = spec.parse(args)?;
    let which = parsed
        .positional(0)
        .context("which experiment? (fig2a|fig2b|fig3|prop1|ablation)")?;
    let full = parsed.flag("full");

    match which {
        "fig2a" | "fig2b" => {
            let variant = if which == "fig2a" {
                exp::Fig2Variant::VaryDimension
            } else {
                exp::Fig2Variant::VaryClusters
            };
            let mut cfg = if full {
                exp::Fig2Config::full(variant)
            } else {
                exp::Fig2Config::quick(variant)
            };
            if let Some(t) = parsed.get_usize("trials")? {
                cfg.trials = t;
            }
            if let Some(s) = parsed.get_usize("samples")? {
                cfg.n_samples = s;
            }
            if let Some(seed) = parsed.get_u64("seed")? {
                cfg.seed = seed;
            }
            if let Some(t) = parsed.get_usize("threads")? {
                cfg.threads = t;
            }
            let res = exp::run_fig2(&cfg);
            println!("{}", res.render());
        }
        "fig3" => {
            let mut cfg = if full {
                exp::Fig3Config::full()
            } else {
                exp::Fig3Config::quick()
            };
            if let Some(t) = parsed.get_usize("trials")? {
                cfg.trials = t;
            }
            if let Some(s) = parsed.get_usize("samples")? {
                cfg.n_samples = s;
            }
            if let Some(seed) = parsed.get_u64("seed")? {
                cfg.seed = seed;
            }
            if let Some(t) = parsed.get_usize("threads")? {
                cfg.threads = t;
            }
            let res = exp::run_fig3(&cfg);
            println!("{}", res.render());
        }
        "prop1" => {
            let mut cfg = exp::Prop1Config::default();
            if let Some(t) = parsed.get_usize("trials")? {
                cfg.repeats = t;
            }
            if let Some(seed) = parsed.get_u64("seed")? {
                cfg.seed = seed;
            }
            let sigs: [Arc<dyn qckm::signature::Signature>; 2] = [
                Arc::new(qckm::signature::UniversalQuantizer),
                Arc::new(qckm::signature::Triangle),
            ];
            for sig in sigs {
                let res = exp::run_prop1(sig, &cfg);
                println!("{}", res.render());
            }
        }
        "ablation" => {
            let mut cfg = exp::AblationConfig::default();
            if let Some(t) = parsed.get_usize("trials")? {
                cfg.trials = t;
            }
            if let Some(t) = parsed.get_usize("threads")? {
                cfg.threads = t;
            }
            if full {
                cfg.trials = 30;
                cfg.ratios = vec![0.5, 1.0, 2.0, 4.0, 8.0];
            }
            let res = exp::run_ablation(&cfg);
            println!("{}", res.render());
        }
        other => bail!("unknown experiment '{other}'"),
    }
    Ok(())
}

fn cmd_pipeline(args: Vec<String>) -> Result<()> {
    let spec = CliSpec::new("qckm pipeline", "streaming 1-bit sensor-cloud demo")
        .opt("workers", "NUM", Some("4"), "sensor workers")
        .opt("samples", "NUM", Some("100000"), "total samples to acquire")
        .opt("dim", "NUM", Some("10"), "sample dimension")
        .opt("k", "NUM", Some("4"), "clusters to synthesize + decode")
        .opt("m", "NUM", Some("400"), "frequencies")
        .opt("batch", "NUM", Some("64"), "examples per wire message")
        .opt("queue", "NUM", Some("16"), "channel capacity")
        .opt("wire", "FMT", Some("bits"), "bits|dense")
        .opt("seed", "NUM", Some("0"), "seed");
    let parsed = spec.parse(args)?;
    let workers = parsed.get_usize("workers")?.unwrap();
    let samples = parsed.get_usize("samples")?.unwrap();
    let dim = parsed.get_usize("dim")?.unwrap();
    let k = parsed.get_usize("k")?.unwrap();
    let m = parsed.get_usize("m")?.unwrap();
    let seed = parsed.get_u64("seed")?.unwrap();
    let wire = match parsed.get("wire").unwrap() {
        "bits" => WireFormat::PackedBits,
        "dense" => WireFormat::DenseF64,
        other => bail!("unknown wire '{other}'"),
    };

    // Synthetic sensor field: K Gaussians at random ±1 corners.
    let mut rng = Rng::new(seed);
    let proto = qckm::data::gaussian_mixture_pm1(k.max(2) * 64, dim, k, &mut rng);
    let means = Arc::new(proto.means.clone());
    let std = (dim as f64 / 20.0).sqrt();
    let source = SampleSource::Synthetic {
        total: samples,
        dim,
        make: Arc::new(move |r: &mut Rng, out: &mut [f64]| {
            let c = r.next_below(means.rows() as u64) as usize;
            for (j, v) in out.iter_mut().enumerate() {
                *v = means.get(c, j) + std * r.gaussian();
            }
        }),
    };

    let sigma = SigmaHeuristic::default().resolve(&proto.points, &mut rng);
    let freqs = DrawnFrequencies::draw(
        qckm::frequency::FrequencyLaw::AdaptedRadius,
        dim,
        m,
        sigma,
        &mut rng,
    );
    let op = match wire {
        WireFormat::PackedBits => SketchOperator::quantized(freqs),
        WireFormat::DenseF64 => SketchOperator::new(freqs, Method::Ckm.signature()),
    };

    let report = run_pipeline(
        &op,
        &source,
        &PipelineConfig {
            workers,
            batch_size: parsed.get_usize("batch")?.unwrap(),
            queue_capacity: parsed.get_usize("queue")?.unwrap(),
            wire,
        },
        seed,
    );
    println!(
        "pipeline: {} samples in {:.3}s → {:.0} samples/s",
        report.samples,
        report.elapsed_secs,
        report.throughput()
    );
    println!(
        "wire: {} bytes total ({:.2} bytes/sample), queue high-water {}, {} stalls",
        report.payload_bytes,
        report.payload_bytes as f64 / report.samples as f64,
        report.queue_high_water,
        report.blocked_sends
    );

    let lo = vec![-2.0; dim];
    let hi = vec![2.0; dim];
    let sol = qckm::clompr::ClOmpr::new(&op, k)
        .with_bounds(lo, hi)
        .run(&report.sketch, &mut rng);
    println!(
        "decoded {} centroids, objective {:.4}",
        sol.centroids.rows(),
        sol.objective
    );
    for i in 0..sol.centroids.rows() {
        let c: Vec<String> = sol
            .centroids
            .row(i)
            .iter()
            .take(6)
            .map(|v| format!("{v:+.2}"))
            .collect();
        println!("  c[{i}] alpha={:.3} [{} …]", sol.weights[i], c.join(", "));
    }
    Ok(())
}
