//! `qckm` — the command-line launcher.
//!
//! ```text
//! qckm cluster     --data x.csv --k 10 [--method qckm:bits=3] [--decoder hier]
//! qckm sketch      --data shard.csv --sigma 1.2 --seed 7 --out shard.qsk
//! qckm sketch      --data more.csv --append shard.qsk  (online update)
//! qckm merge       --out merged.qsk shard0.qsk shard1.qsk …
//! qckm decode      --sketch merged.qsk --k 10 [--decoder clompr:restarts=5]
//! qckm serve       --dim 5 --m 1000 --sigma 1.2 --seed 7 [--port 0]
//! qckm serve       --tenant acme=acme.toml --tenant beta=beta.toml [--rate-limit 100]
//! qckm aggregate   --upstream host:port --agg-id edge-1 [--tenant name=spec …]
//! qckm push        --addr host:port --data shard.csv [--shard name] [--retry 8]
//! qckm query       --addr host:port --k 10 [--window E] [--decoder hier]
//! qckm snapshot    --addr host:port --out live.qsk [--window E]
//! qckm ctl         --addr host:port stats|roll|shutdown
//! qckm experiment  fig2a|fig2b|fig3|prop1|ablation [--full] [--decoder SPEC]
//! qckm pipeline    [--workers 8] [--samples 100000] … (streaming demo)
//! ```
//!
//! `sketch` → `merge` → `decode` is the paper's distributed acquisition
//! pipeline split into stages; `serve` keeps the same pooled state live
//! behind a TCP protocol (see the README for the tour). Every `--method`
//! takes an open-registry spec string (`ckm`, `qckm`, `qckm:bits=B`,
//! `triangle`, `modulo` — see `qckm::method`), and every decode-side verb
//! takes a `--decoder` spec resolved by the mirror-image decoder registry
//! (`clompr`, `clompr:restarts=R,replacements=P`, `hier` — see
//! `qckm::decoder`); on the service verbs both are *declarations* the
//! server verifies, so a distributed job can never silently mix methods
//! and a cached answer can never come from a different decode algorithm.
//!
//! Every run prints its seed and full parameterization so results are
//! reproducible; experiment outputs are the rows/series recorded in
//! EXPERIMENTS.md. All verb logic lives in `cmds/` — this file is only
//! the dispatch table.

use anyhow::{bail, Result};

mod cmds;

fn main() {
    // QCKM_LOG=json[:level] turns on structured logging for any verb;
    // `qckm serve --log-json` is the flag-shaped equivalent.
    qckm::obs::init_from_env();
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(args) {
        eprintln!("{e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: Vec<String>) -> Result<()> {
    let Some(cmd) = args.first().cloned() else {
        bail!(
            "usage: qckm <cluster|sketch|merge|decode|serve|aggregate|push|query|snapshot|\
             ctl|experiment|pipeline> …  (use --help per command)\n\
             see README.md for a tour"
        );
    };
    let rest = args[1..].to_vec();
    match cmd.as_str() {
        "cluster" => cmds::cluster::run(rest),
        "sketch" => cmds::sketch::run(rest),
        "merge" => cmds::merge::run(rest),
        "decode" => cmds::decode::run(rest),
        "serve" => cmds::serve::run(rest),
        "aggregate" => cmds::aggregate::run(rest),
        "push" => cmds::push::run(rest),
        "query" => cmds::query::run(rest),
        "snapshot" => cmds::snapshot::run(rest),
        "ctl" => cmds::ctl::run(rest),
        "experiment" => cmds::experiment::run(rest),
        "pipeline" => cmds::pipeline::run(rest),
        other => {
            bail!(
                "unknown command '{other}' (cluster|sketch|merge|decode|serve|aggregate|\
                 push|query|snapshot|ctl|experiment|pipeline)"
            )
        }
    }
}
