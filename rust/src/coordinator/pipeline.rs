//! The sensor → aggregator streaming pipeline.

use super::channel::{bounded, Sender};
use crate::linalg::Mat;
use crate::rng::Rng;
use crate::sketch::{BitAggregator, BitSketch, PooledSketch, SketchOperator};
use std::sync::Arc;
use std::time::Instant;

/// What each sensor puts on the wire for a batch of examples.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WireFormat {
    /// QCKM acquisition: `2M` *bits* per example, packed (Fig. 1d).
    PackedBits,
    /// CKM acquisition: `2M` f64 per example (full-precision signatures).
    DenseF64,
}

/// Where sensor workers get their samples.
#[derive(Clone)]
pub enum SampleSource {
    /// A shared in-memory dataset, sharded row-wise across workers.
    Shared(Arc<Mat>),
    /// Pure sensor simulation: each worker synthesizes its own stream with
    /// a deterministic per-worker RNG substream. `make` fills one sample.
    Synthetic {
        total: usize,
        dim: usize,
        make: Arc<dyn Fn(&mut Rng, &mut [f64]) + Send + Sync>,
    },
}

/// Pipeline knobs.
#[derive(Clone, Debug)]
pub struct PipelineConfig {
    /// Number of sensor worker threads.
    pub workers: usize,
    /// Examples per wire message.
    pub batch_size: usize,
    /// Bounded-queue capacity (messages) between sensors and aggregator.
    pub queue_capacity: usize,
    /// Wire format (1-bit QCKM vs full-precision CKM).
    pub wire: WireFormat,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        Self {
            workers: 4,
            batch_size: 64,
            queue_capacity: 16,
            wire: WireFormat::PackedBits,
        }
    }
}

/// What the pipeline produced, plus its runtime behaviour.
#[derive(Clone, Debug)]
pub struct PipelineReport {
    /// The pooled dataset sketch `z_X` (length `2M`).
    pub sketch: Vec<f64>,
    /// Examples acquired.
    pub samples: u64,
    /// Bytes that crossed the sensor→aggregator boundary (payload only).
    pub payload_bytes: u64,
    /// Wall-clock duration of the acquisition.
    pub elapsed_secs: f64,
    /// Number of sends that hit a full queue (backpressure events).
    pub blocked_sends: u64,
    /// Deepest queue occupancy observed.
    pub queue_high_water: u64,
    /// Samples produced by each worker.
    pub per_worker: Vec<u64>,
}

impl PipelineReport {
    pub fn throughput(&self) -> f64 {
        self.samples as f64 / self.elapsed_secs.max(1e-12)
    }
}

enum Payload {
    Bits(Vec<BitSketch>),
    /// Flattened `count × 2M` full-precision contributions.
    Dense { data: Vec<f64>, count: u64 },
}

impl Payload {
    fn wire_bytes(&self) -> u64 {
        match self {
            Payload::Bits(v) => v.iter().map(|b| b.payload_bytes() as u64).sum(),
            Payload::Dense { data, .. } => (data.len() * 8) as u64,
        }
    }
}

/// Run the full acquisition pipeline and return the pooled sketch + stats.
///
/// Deterministic given `seed` (worker substreams are derived from it), up to
/// the order-insensitivity of pooling (sums commute).
pub fn run_pipeline(
    op: &SketchOperator,
    source: &SampleSource,
    config: &PipelineConfig,
    seed: u64,
) -> PipelineReport {
    assert!(config.workers >= 1 && config.batch_size >= 1);
    let sketch_len = op.sketch_len();
    let start = Instant::now();
    let (tx, rx) = bounded::<Payload>(config.queue_capacity);

    let mut per_worker = vec![0u64; config.workers];
    let mut payload_bytes = 0u64;
    let mut bits_agg = BitAggregator::new(sketch_len);
    let mut dense_pool = PooledSketch::new(sketch_len);

    std::thread::scope(|scope| {
        // ---- Sensor workers.
        for w in 0..config.workers {
            let tx = tx.clone();
            let op = op.clone();
            let source = source.clone();
            let wire = config.wire;
            let batch = config.batch_size;
            scope.spawn(move || {
                sensor_worker(&op, &source, wire, batch, w, config.workers, seed, tx);
            });
        }
        drop(tx); // aggregator sees close once all workers finish

        // ---- Aggregator (this thread).
        while let Some(msg) = rx.recv() {
            payload_bytes += msg.wire_bytes();
            match msg {
                Payload::Bits(contribs) => {
                    for b in &contribs {
                        bits_agg.add(b);
                    }
                }
                Payload::Dense { data, count } => {
                    for i in 0..count as usize {
                        dense_pool.add(&data[i * sketch_len..(i + 1) * sketch_len]);
                    }
                }
            }
        }
    });

    // Merge whichever aggregators got data.
    let mut total = PooledSketch::new(sketch_len);
    if !bits_agg.is_empty() {
        let (sum, count) = bits_agg.to_sum();
        total.add_sum(&sum, count);
    }
    if !dense_pool.is_empty() {
        total.merge(&dense_pool);
    }
    let samples = total.count();
    // Per-worker sample counts are deterministic from the sharding rule.
    for (w, c) in per_worker.iter_mut().enumerate() {
        *c = planned_samples(source, w, config.workers) as u64;
    }

    PipelineReport {
        sketch: total.mean(),
        samples,
        payload_bytes,
        elapsed_secs: start.elapsed().as_secs_f64(),
        blocked_sends: rx.blocked_sends(),
        queue_high_water: rx.high_water(),
        per_worker,
    }
}

/// How many samples worker `w` of `workers` is responsible for.
fn planned_samples(source: &SampleSource, w: usize, workers: usize) -> usize {
    let total = match source {
        SampleSource::Shared(m) => m.rows(),
        SampleSource::Synthetic { total, .. } => *total,
    };
    let base = total / workers;
    let extra = usize::from(w < total % workers);
    base + extra
}

fn sensor_worker(
    op: &SketchOperator,
    source: &SampleSource,
    wire: WireFormat,
    batch: usize,
    w: usize,
    workers: usize,
    seed: u64,
    tx: Sender<Payload>,
) {
    let quota = planned_samples(source, w, workers);
    if quota == 0 {
        return;
    }
    let dim = op.dim();
    let sketch_len = op.sketch_len();
    // Worker-local RNG substream (only used by synthetic sources).
    let mut rng = Rng::new(seed).substream(w as u64 + 1);

    // Row-range shard for shared sources: contiguous blocks.
    let (shard_start, shared): (usize, Option<&Arc<Mat>>) = match source {
        SampleSource::Shared(m) => {
            let total = m.rows();
            let base = total / workers;
            let extra = total % workers;
            // Workers 0..extra get (base+1) rows.
            let start = w * base + w.min(extra);
            (start, Some(m))
        }
        SampleSource::Synthetic { .. } => (0, None),
    };

    let mut produced = 0usize;
    let mut sample = vec![0.0; dim];
    while produced < quota {
        let b = batch.min(quota - produced);
        let payload = match wire {
            WireFormat::PackedBits => {
                let mut contribs = Vec::with_capacity(b);
                for i in 0..b {
                    let x: &[f64] = match (&shared, source) {
                        (Some(m), _) => m.row(shard_start + produced + i),
                        (None, SampleSource::Synthetic { make, .. }) => {
                            make(&mut rng, &mut sample);
                            &sample
                        }
                        _ => unreachable!(),
                    };
                    contribs.push(op.encode_point_bits(x));
                }
                Payload::Bits(contribs)
            }
            WireFormat::DenseF64 => {
                let mut data = Vec::with_capacity(b * sketch_len);
                for i in 0..b {
                    let x: &[f64] = match (&shared, source) {
                        (Some(m), _) => m.row(shard_start + produced + i),
                        (None, SampleSource::Synthetic { make, .. }) => {
                            make(&mut rng, &mut sample);
                            &sample
                        }
                        _ => unreachable!(),
                    };
                    data.extend_from_slice(&op.encode_point(x));
                }
                Payload::Dense {
                    data,
                    count: b as u64,
                }
            }
        };
        if tx.send(payload).is_err() {
            return; // aggregator shut down
        }
        produced += b;
    }
}
