//! The sensor → aggregator streaming pipeline.
//!
//! ## Determinism contract
//!
//! The pooled sketch produced by [`run_pipeline`] depends only on the
//! operator, the sample source and the seed — **never** on `workers`,
//! `batch_size` or `queue_capacity`. Three mechanisms guarantee it (locked
//! in by `rust/tests/determinism.rs`):
//!
//! * **Fixed sharding.** Samples are cut into fixed [`SHARD_BLOCK`]-sized
//!   blocks by [`crate::parallel::fixed_chunks`] (the shared sharding rule)
//!   and blocks are assigned round-robin by block index, so the partition
//!   never depends on scheduling.
//! * **Per-block RNG substreams.** Synthetic sources derive their stream
//!   from the block id, not the worker id, so the synthesized samples are a
//!   pure function of (seed, sample index).
//! * **Ordered reduction.** 1-bit payloads pool into exact integer counts
//!   (addition commutes exactly, arrival order is irrelevant); dense f64
//!   payloads carry their global start row, fold on arrival into their
//!   block's partial pool (in row order — one producer per block), and the
//!   completed block partials merge in block order, fixing the
//!   floating-point reduction order with O(blocks in flight) memory.

use super::channel::{bounded, Sender};
use crate::linalg::Mat;
use crate::rng::Rng;
use crate::sketch::{BitAggregator, BitSketch, PooledSketch, SketchOperator};
use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

/// Fixed sensor-sharding block size (samples per work unit). Part of the
/// determinism contract above; independent of the worker count by design.
pub const SHARD_BLOCK: usize = 1024;

/// What each sensor puts on the wire for a batch of examples.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WireFormat {
    /// QCKM acquisition: `2M` *bits* per example, packed (Fig. 1d).
    PackedBits,
    /// CKM acquisition: `2M` f64 per example (full-precision signatures).
    DenseF64,
}

/// Where sensor workers get their samples.
#[derive(Clone)]
pub enum SampleSource {
    /// A shared in-memory dataset, sharded row-wise across workers.
    Shared(Arc<Mat>),
    /// Pure sensor simulation: samples are synthesized in fixed
    /// [`SHARD_BLOCK`]-sized blocks, each from a deterministic per-block
    /// RNG substream (worker-count invariant). `make` fills one sample.
    Synthetic {
        total: usize,
        dim: usize,
        make: Arc<dyn Fn(&mut Rng, &mut [f64]) + Send + Sync>,
    },
}

/// Pipeline knobs.
#[derive(Clone, Debug)]
pub struct PipelineConfig {
    /// Number of sensor worker threads.
    pub workers: usize,
    /// Examples per wire message.
    pub batch_size: usize,
    /// Bounded-queue capacity (messages) between sensors and aggregator.
    pub queue_capacity: usize,
    /// Wire format (1-bit QCKM vs full-precision CKM).
    pub wire: WireFormat,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        Self {
            workers: 4,
            batch_size: 64,
            queue_capacity: 16,
            wire: WireFormat::PackedBits,
        }
    }
}

/// What the pipeline produced, plus its runtime behaviour.
#[derive(Clone, Debug)]
pub struct PipelineReport {
    /// The pooled dataset sketch `z_X` (length `2M`).
    pub sketch: Vec<f64>,
    /// Examples acquired.
    pub samples: u64,
    /// Bytes that crossed the sensor→aggregator boundary (payload only).
    pub payload_bytes: u64,
    /// Wall-clock duration of the acquisition.
    pub elapsed_secs: f64,
    /// Number of sends that hit a full queue (backpressure events).
    pub blocked_sends: u64,
    /// Deepest queue occupancy observed.
    pub queue_high_water: u64,
    /// Samples produced by each worker.
    pub per_worker: Vec<u64>,
}

impl PipelineReport {
    pub fn throughput(&self) -> f64 {
        self.samples as f64 / self.elapsed_secs.max(1e-12)
    }
}

enum Payload {
    /// Packed 1-bit contributions; pooling is exact integer counting, so no
    /// ordering information is needed.
    Bits(Vec<BitSketch>),
    /// Flattened `count × 2M` full-precision contributions of the samples
    /// `start..start + count` (global indices, for the ordered reduction).
    Dense {
        start: usize,
        data: Vec<f64>,
        count: usize,
    },
}

impl Payload {
    fn wire_bytes(&self) -> u64 {
        match self {
            Payload::Bits(v) => v.iter().map(|b| b.payload_bytes() as u64).sum(),
            Payload::Dense { data, .. } => (data.len() * 8) as u64,
        }
    }
}

/// Run the full acquisition pipeline and return the pooled sketch + stats.
///
/// Deterministic given `seed`: the pooled sketch is bit-for-bit identical
/// across any `workers` / `batch_size` / `queue_capacity` (see the module
/// docs for the contract).
pub fn run_pipeline(
    op: &SketchOperator,
    source: &SampleSource,
    config: &PipelineConfig,
    seed: u64,
) -> PipelineReport {
    assert!(config.workers >= 1 && config.batch_size >= 1);
    let sketch_len = op.sketch_len();
    let start = Instant::now();
    let (tx, rx) = bounded::<Payload>(config.queue_capacity);

    let mut per_worker = vec![0u64; config.workers];
    let mut payload_bytes = 0u64;
    let mut bits_agg = BitAggregator::new(sketch_len);
    let mut dense_pool = PooledSketch::new(sketch_len);
    // Dense ordered reduction: each payload folds on arrival into its
    // block's partial pool (a block has a single producer and the channel
    // is FIFO per sender, so within-block payloads arrive in row order),
    // and completed blocks are folded into `dense_pool` in block order.
    // Aggregator memory is O(in-flight blocks × 2M), never O(N × 2M).
    let total_samples = source_total(source);
    let block_len = |b: usize| SHARD_BLOCK.min(total_samples.saturating_sub(b * SHARD_BLOCK));
    let mut dense_blocks: BTreeMap<usize, PooledSketch> = BTreeMap::new();
    let mut next_block = 0usize;

    std::thread::scope(|scope| {
        // ---- Sensor workers.
        for w in 0..config.workers {
            let tx = tx.clone();
            let op = op.clone();
            let source = source.clone();
            let wire = config.wire;
            let batch = config.batch_size;
            scope.spawn(move || {
                sensor_worker(&op, &source, wire, batch, w, config.workers, seed, tx);
            });
        }
        drop(tx); // aggregator sees close once all workers finish

        // ---- Aggregator (this thread).
        while let Some(msg) = rx.recv() {
            payload_bytes += msg.wire_bytes();
            match msg {
                Payload::Bits(contribs) => {
                    for b in &contribs {
                        bits_agg.add(b);
                    }
                }
                Payload::Dense { start, data, count } => {
                    let block = start / SHARD_BLOCK;
                    let partial = dense_blocks
                        .entry(block)
                        .or_insert_with(|| PooledSketch::new(sketch_len));
                    for i in 0..count {
                        partial.add(&data[i * sketch_len..(i + 1) * sketch_len]);
                    }
                    // Evict the contiguous prefix of completed blocks, in
                    // block order (the fixed reduction order).
                    while dense_blocks
                        .get(&next_block)
                        .is_some_and(|p| p.count() as usize >= block_len(next_block))
                    {
                        let done = dense_blocks.remove(&next_block).unwrap();
                        dense_pool.merge(&done);
                        next_block += 1;
                    }
                }
            }
        }
    });
    // Any remaining (necessarily trailing) block partials, in block order.
    for partial in dense_blocks.values() {
        dense_pool.merge(partial);
    }

    // Merge whichever aggregators got data.
    let mut total = PooledSketch::new(sketch_len);
    if !bits_agg.is_empty() {
        let (sum, count) = bits_agg.to_sum();
        total.add_sum(&sum, count);
    }
    if !dense_pool.is_empty() {
        total.merge(&dense_pool);
    }
    let samples = total.count();
    // Per-worker sample counts are deterministic from the sharding rule.
    for (w, c) in per_worker.iter_mut().enumerate() {
        *c = planned_samples(source, w, config.workers) as u64;
    }

    PipelineReport {
        sketch: total.mean(),
        samples,
        payload_bytes,
        elapsed_secs: start.elapsed().as_secs_f64(),
        blocked_sends: rx.blocked_sends(),
        queue_high_water: rx.high_water(),
        per_worker,
    }
}

/// Total samples a source yields.
fn source_total(source: &SampleSource) -> usize {
    match source {
        SampleSource::Shared(m) => m.rows(),
        SampleSource::Synthetic { total, .. } => *total,
    }
}

/// How many samples worker `w` of `workers` is responsible for: the sum of
/// the fixed [`SHARD_BLOCK`]-sized blocks assigned round-robin to `w`.
fn planned_samples(source: &SampleSource, w: usize, workers: usize) -> usize {
    crate::parallel::fixed_chunks(source_total(source), SHARD_BLOCK)
        .iter()
        .enumerate()
        .filter(|(b, _)| b % workers == w)
        .map(|(_, block)| block.len())
        .sum()
}

/// Fetch sample `row` — a borrowed dataset row, or one synthesized into
/// `scratch` from the caller's per-block RNG substream. Shared by both wire
/// formats so the sharding/RNG rule cannot diverge between them.
fn fetch_sample<'a>(
    shared: Option<&'a Arc<Mat>>,
    source: &SampleSource,
    row: usize,
    rng: &mut Rng,
    scratch: &'a mut [f64],
) -> &'a [f64] {
    match (shared, source) {
        (Some(m), _) => m.row(row),
        (None, SampleSource::Synthetic { make, .. }) => {
            make(rng, scratch);
            scratch
        }
        _ => unreachable!(),
    }
}

#[allow(clippy::too_many_arguments)]
fn sensor_worker(
    op: &SketchOperator,
    source: &SampleSource,
    wire: WireFormat,
    batch: usize,
    w: usize,
    workers: usize,
    seed: u64,
    tx: Sender<Payload>,
) {
    let dim = op.dim();
    let sketch_len = op.sketch_len();
    let blocks = crate::parallel::fixed_chunks(source_total(source), SHARD_BLOCK);
    let shared: Option<&Arc<Mat>> = match source {
        SampleSource::Shared(m) => Some(m),
        SampleSource::Synthetic { .. } => None,
    };

    let mut sample = vec![0.0; dim];
    for (b, block) in blocks.iter().enumerate() {
        if b % workers != w {
            continue;
        }
        // Per-block RNG substream (synthetic sources): the sample stream is
        // a function of (seed, block id), never of the worker count.
        let mut rng = Rng::new(seed).substream(b as u64 + 1);
        let mut row = block.start;
        while row < block.end {
            let bsz = batch.min(block.end - row);
            let payload = match wire {
                WireFormat::PackedBits => {
                    let mut contribs = Vec::with_capacity(bsz);
                    for i in 0..bsz {
                        let x = fetch_sample(shared, source, row + i, &mut rng, &mut sample);
                        contribs.push(op.encode_point_bits(x));
                    }
                    Payload::Bits(contribs)
                }
                WireFormat::DenseF64 => {
                    let mut data = Vec::with_capacity(bsz * sketch_len);
                    for i in 0..bsz {
                        let x = fetch_sample(shared, source, row + i, &mut rng, &mut sample);
                        data.extend_from_slice(&op.encode_point(x));
                    }
                    Payload::Dense {
                        start: row,
                        data,
                        count: bsz,
                    }
                }
            };
            if tx.send(payload).is_err() {
                return; // aggregator shut down
            }
            row += bsz;
        }
    }
}
