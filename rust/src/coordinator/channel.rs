//! A bounded MPMC channel with blocking backpressure, built on
//! `Mutex` + `Condvar` (the environment has no tokio/crossbeam).
//!
//! Semantics match what the streaming pipeline needs:
//! * `send` blocks while the queue is full — natural backpressure from the
//!   aggregator to the sensor workers;
//! * `recv` blocks while empty, and returns `None` once every sender is
//!   dropped *and* the queue is drained;
//! * instrumented: high-water mark and blocked-send count feed the
//!   pipeline's backpressure report.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

struct Inner<T> {
    queue: Mutex<State<T>>,
    not_full: Condvar,
    not_empty: Condvar,
    capacity: usize,
    senders: AtomicU64,
    blocked_sends: AtomicU64,
    high_water: AtomicU64,
}

struct State<T> {
    items: VecDeque<T>,
    closed: bool,
}

/// Sending half. Cloneable; the channel closes when all senders drop.
pub struct Sender<T> {
    inner: Arc<Inner<T>>,
}

/// Receiving half. Cloneable (MPMC).
pub struct Receiver<T> {
    inner: Arc<Inner<T>>,
}

/// Create a bounded channel of the given capacity (≥ 1).
pub fn bounded<T>(capacity: usize) -> (Sender<T>, Receiver<T>) {
    assert!(capacity >= 1, "channel capacity must be >= 1");
    let inner = Arc::new(Inner {
        queue: Mutex::new(State {
            items: VecDeque::with_capacity(capacity),
            closed: false,
        }),
        not_full: Condvar::new(),
        not_empty: Condvar::new(),
        capacity,
        senders: AtomicU64::new(1),
        blocked_sends: AtomicU64::new(0),
        high_water: AtomicU64::new(0),
    });
    (
        Sender {
            inner: inner.clone(),
        },
        Receiver { inner },
    )
}

impl<T> Clone for Sender<T> {
    fn clone(&self) -> Self {
        self.inner.senders.fetch_add(1, Ordering::SeqCst);
        Sender {
            inner: self.inner.clone(),
        }
    }
}

impl<T> Drop for Sender<T> {
    fn drop(&mut self) {
        if self.inner.senders.fetch_sub(1, Ordering::SeqCst) == 1 {
            // Last sender: close and wake all receivers.
            let mut st = self.inner.queue.lock().unwrap();
            st.closed = true;
            drop(st);
            self.inner.not_empty.notify_all();
        }
    }
}

impl<T> Clone for Receiver<T> {
    fn clone(&self) -> Self {
        Receiver {
            inner: self.inner.clone(),
        }
    }
}

/// Error returned when sending on a channel whose receivers are gone.
#[derive(Debug, PartialEq, Eq)]
pub struct SendError;

impl<T> Sender<T> {
    /// Blocking send with backpressure. Returns `Err` if the channel was
    /// explicitly closed (receiver side shut down).
    pub fn send(&self, item: T) -> Result<(), SendError> {
        let mut st = self.inner.queue.lock().unwrap();
        if st.items.len() >= self.inner.capacity {
            self.inner.blocked_sends.fetch_add(1, Ordering::Relaxed);
        }
        while st.items.len() >= self.inner.capacity {
            if st.closed {
                return Err(SendError);
            }
            st = self.inner.not_full.wait(st).unwrap();
        }
        if st.closed {
            return Err(SendError);
        }
        st.items.push_back(item);
        let depth = st.items.len() as u64;
        self.inner.high_water.fetch_max(depth, Ordering::Relaxed);
        drop(st);
        self.inner.not_empty.notify_one();
        Ok(())
    }

    /// Times a sender blocked on a full queue (backpressure events).
    pub fn blocked_sends(&self) -> u64 {
        self.inner.blocked_sends.load(Ordering::Relaxed)
    }

    /// Deepest the queue ever got.
    pub fn high_water(&self) -> u64 {
        self.inner.high_water.load(Ordering::Relaxed)
    }
}

impl<T> Receiver<T> {
    /// Times any sender blocked on a full queue (backpressure events).
    pub fn blocked_sends(&self) -> u64 {
        self.inner.blocked_sends.load(Ordering::Relaxed)
    }

    /// Deepest the queue ever got.
    pub fn high_water(&self) -> u64 {
        self.inner.high_water.load(Ordering::Relaxed)
    }

    /// Blocking receive; `None` when the channel is closed and drained.
    pub fn recv(&self) -> Option<T> {
        let mut st = self.inner.queue.lock().unwrap();
        loop {
            if let Some(item) = st.items.pop_front() {
                drop(st);
                self.inner.not_full.notify_one();
                return Some(item);
            }
            if st.closed {
                return None;
            }
            st = self.inner.not_empty.wait(st).unwrap();
        }
    }

    /// Close from the receiving side: subsequent/blocked sends fail fast.
    pub fn close(&self) {
        let mut st = self.inner.queue.lock().unwrap();
        st.closed = true;
        drop(st);
        self.inner.not_full.notify_all();
        self.inner.not_empty.notify_all();
    }
}
