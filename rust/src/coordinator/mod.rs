//! Layer-3 streaming acquisition coordinator.
//!
//! Simulates the paper's Fig. 1 deployment: a *cloud of low-power 1-bit
//! sensors* acquires the dataset — each example leaves its sensor only as an
//! `m`-bit universal-quantized sketch contribution — and a leader pools the
//! contributions into the linear dataset sketch, then decodes centroids with
//! CL-OMPR. Nothing but sketch bits crosses the sensor→leader link.
//!
//! Topology (threads + bounded channels, backpressure by blocking):
//!
//! ```text
//!  sensor worker 0 ─┐ BitBatch
//!  sensor worker 1 ─┼──▶ bounded channel ──▶ aggregator ──▶ z_X ─▶ CL-OMPR
//!       …           │     (capacity Q,         (BitAggregator
//!  sensor worker W ─┘      blocking send)        or PooledSketch)
//! ```
//!
//! Two wire formats are supported per [`WireFormat`]: the QCKM 1-bit packed
//! payload (`2M` bits/example) and the full-precision CKM payload
//! (`2M` f64/example) — the bench `pipeline_bench` measures the 64×
//! acquisition-bandwidth gap between them.

mod channel;
mod pipeline;

pub use channel::{bounded, Receiver, SendError, Sender};
pub use pipeline::{
    run_pipeline, PipelineConfig, PipelineReport, SampleSource, WireFormat, SHARD_BLOCK,
};

#[cfg(test)]
mod tests;
