//! Coordinator tests: channel semantics, pipeline correctness vs the
//! single-threaded sketch, wire accounting, failure injection.

use super::*;
use crate::frequency::{DrawnFrequencies, FrequencyLaw};
use crate::linalg::Mat;
use crate::rng::Rng;
use crate::signature::{Cosine, UniversalQuantizer};
use crate::sketch::SketchOperator;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

fn test_op(n: usize, m: usize, seed: u64) -> SketchOperator {
    let mut rng = Rng::new(seed);
    let freqs = DrawnFrequencies::draw(FrequencyLaw::Gaussian, n, m, 1.0, &mut rng);
    SketchOperator::quantized(freqs)
}

// ---------------------------------------------------------------- channel

#[test]
fn channel_fifo_single_thread() {
    let (tx, rx) = bounded::<u32>(4);
    tx.send(1).unwrap();
    tx.send(2).unwrap();
    drop(tx);
    assert_eq!(rx.recv(), Some(1));
    assert_eq!(rx.recv(), Some(2));
    assert_eq!(rx.recv(), None);
}

#[test]
fn channel_backpressure_blocks_then_drains() {
    let (tx, rx) = bounded::<u64>(2);
    let produced = Arc::new(AtomicU64::new(0));
    let p = produced.clone();
    let handle = std::thread::spawn(move || {
        for i in 0..100 {
            tx.send(i).unwrap();
            p.fetch_add(1, Ordering::SeqCst);
        }
        tx.blocked_sends()
    });
    // Give the producer a chance to fill the queue and block.
    std::thread::sleep(Duration::from_millis(50));
    let before = produced.load(Ordering::SeqCst);
    assert!(before <= 3, "producer ran ahead of a capacity-2 queue: {before}");
    let mut got = Vec::new();
    while let Some(v) = rx.recv() {
        got.push(v);
    }
    let blocked = handle.join().unwrap();
    assert_eq!(got, (0..100).collect::<Vec<_>>());
    assert!(blocked > 0, "no backpressure events recorded");
}

#[test]
fn channel_mpmc_totals() {
    let (tx, rx) = bounded::<u64>(8);
    let sum = Arc::new(AtomicU64::new(0));
    std::thread::scope(|s| {
        for t in 0..4 {
            let tx = tx.clone();
            s.spawn(move || {
                for i in 0..250 {
                    tx.send(t * 1000 + i).unwrap();
                }
            });
        }
        drop(tx);
        for _ in 0..3 {
            let rx = rx.clone();
            let sum = sum.clone();
            s.spawn(move || {
                while let Some(v) = rx.recv() {
                    sum.fetch_add(v, Ordering::SeqCst);
                }
            });
        }
        while let Some(v) = rx.recv() {
            sum.fetch_add(v, Ordering::SeqCst);
        }
    });
    let want: u64 = (0..4u64).map(|t| (0..250u64).map(|i| t * 1000 + i).sum::<u64>()).sum();
    assert_eq!(sum.load(Ordering::SeqCst), want);
}

#[test]
fn channel_close_unblocks_senders() {
    let (tx, rx) = bounded::<u32>(1);
    tx.send(0).unwrap();
    let handle = std::thread::spawn(move || tx.send(1));
    std::thread::sleep(Duration::from_millis(20));
    rx.close(); // receiver shuts down while sender is blocked
    assert_eq!(handle.join().unwrap(), Err(SendError));
}

// --------------------------------------------------------------- pipeline

#[test]
fn pipeline_bits_matches_single_threaded_sketch() {
    let op = test_op(4, 30, 1);
    let mut rng = Rng::new(2);
    let x = Arc::new(Mat::from_fn(503, 4, |_, _| rng.gaussian()));
    let want = op.sketch_dataset(&x);
    for workers in [1, 3, 8] {
        let report = run_pipeline(
            &op,
            &SampleSource::Shared(x.clone()),
            &PipelineConfig {
                workers,
                batch_size: 32,
                queue_capacity: 4,
                wire: WireFormat::PackedBits,
            },
            7,
        );
        assert_eq!(report.samples, 503);
        for (a, b) in report.sketch.iter().zip(&want) {
            assert!((a - b).abs() < 1e-12, "pipeline ({workers} workers) deviates");
        }
        assert_eq!(report.per_worker.iter().sum::<u64>(), 503);
    }
}

#[test]
fn pipeline_dense_matches_and_costs_64x_more_wire() {
    let op = test_op(3, 32, 3); // 64 slots → 8 bytes packed vs 512 dense
    let mut rng = Rng::new(4);
    let x = Arc::new(Mat::from_fn(256, 3, |_, _| rng.gaussian()));
    let want = op.sketch_dataset(&x);

    let mk = |wire| {
        run_pipeline(
            &op,
            &SampleSource::Shared(x.clone()),
            &PipelineConfig {
                workers: 2,
                batch_size: 16,
                queue_capacity: 4,
                wire,
            },
            5,
        )
    };
    let bits = mk(WireFormat::PackedBits);
    let dense = mk(WireFormat::DenseF64);
    for (a, b) in bits.sketch.iter().zip(&want) {
        assert!((a - b).abs() < 1e-12);
    }
    // Dense pipeline uses the full signature, which for the quantizer is
    // ±1-valued too — identical pooled sketch.
    for (a, b) in dense.sketch.iter().zip(&want) {
        assert!((a - b).abs() < 1e-12);
    }
    // Wire accounting: 2M bits = 2M/8 bytes vs 2M × 8 bytes → 64×.
    assert_eq!(bits.payload_bytes, 256 * 8); // 64 bits = 8 bytes each
    assert_eq!(dense.payload_bytes, 256 * 64 * 8);
    assert_eq!(dense.payload_bytes / bits.payload_bytes, 64);
}

#[test]
fn pipeline_synthetic_source_is_deterministic_per_seed() {
    let op = test_op(2, 20, 6);
    let source = SampleSource::Synthetic {
        total: 300,
        dim: 2,
        make: Arc::new(|rng: &mut Rng, out: &mut [f64]| {
            out[0] = rng.gaussian();
            out[1] = rng.gaussian() + 2.0;
        }),
    };
    let config = PipelineConfig::default();
    let r1 = run_pipeline(&op, &source, &config, 42);
    let r2 = run_pipeline(&op, &source, &config, 42);
    assert_eq!(r1.samples, 300);
    assert_eq!(r1.sketch, r2.sketch, "same seed must give identical sketch");
    let r3 = run_pipeline(&op, &source, &config, 43);
    assert_ne!(r1.sketch, r3.sketch, "different seed should differ");
}

#[test]
fn pipeline_worker_sharding_covers_all_rows_exactly_once() {
    // A dataset where each row is identifiable: row i = (i, i).
    // The pooled *mean* over any worker split must equal the global mean of
    // contributions — checked with the cosine signature (dense path), which
    // is injective enough to catch double-processing.
    let mut rng = Rng::new(8);
    let freqs = DrawnFrequencies::draw(FrequencyLaw::Gaussian, 2, 16, 5.0, &mut rng);
    let op = SketchOperator::new(freqs, Arc::new(Cosine));
    let x = Arc::new(Mat::from_fn(101, 2, |r, _| r as f64 / 101.0));
    let want = op.sketch_dataset(&x);
    let report = run_pipeline(
        &op,
        &SampleSource::Shared(x.clone()),
        &PipelineConfig {
            workers: 7,
            batch_size: 5,
            queue_capacity: 2,
            wire: WireFormat::DenseF64,
        },
        0,
    );
    assert_eq!(report.samples, 101);
    for (a, b) in report.sketch.iter().zip(&want) {
        assert!((a - b).abs() < 1e-12);
    }
}

#[test]
fn pipeline_more_workers_than_samples() {
    let op = test_op(2, 8, 9);
    let mut rng = Rng::new(10);
    let x = Arc::new(Mat::from_fn(3, 2, |_, _| rng.gaussian()));
    let report = run_pipeline(
        &op,
        &SampleSource::Shared(x.clone()),
        &PipelineConfig {
            workers: 8,
            ..Default::default()
        },
        0,
    );
    assert_eq!(report.samples, 3);
    assert_eq!(report.per_worker.iter().sum::<u64>(), 3);
    assert_eq!(report.sketch, op.sketch_dataset(&x));
}

#[test]
fn pipeline_reports_throughput_and_stats() {
    let op = test_op(2, 8, 11);
    let source = SampleSource::Synthetic {
        total: 1000,
        dim: 2,
        make: Arc::new(|rng: &mut Rng, out: &mut [f64]| {
            out.fill(rng.gaussian());
        }),
    };
    let report = run_pipeline(&op, &source, &PipelineConfig::default(), 1);
    assert!(report.elapsed_secs > 0.0);
    assert!(report.throughput() > 0.0);
    assert!(report.queue_high_water >= 1);
    assert!(report.payload_bytes > 0);
    let _ = UniversalQuantizer; // silence unused import in some cfgs
}
