//! Opt-in structured event log: one JSON object per line on stderr.
//!
//! Off by default (zero output, one relaxed atomic load per guard).
//! Enabled by `QCKM_LOG=json` (or `json:debug` / `json:info` / `json:warn`
//! / `json:error` to set the minimum level) via [`init_from_env`], or
//! programmatically by `qckm serve --log-json` via [`set_json`].
//!
//! Schema (see README §Observability): every line is one object with
//! `ts_ms` (Unix epoch milliseconds), `level`, `event`, then the event's
//! own fields. Lines go to stderr so they never interleave with protocol
//! or CSV output on stdout.

use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};
use std::time::{SystemTime, UNIX_EPOCH};

/// Event severity, ordered `Debug < Info < Warn < Error`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Debug = 0,
    Info = 1,
    Warn = 2,
    Error = 3,
}

impl Level {
    fn name(self) -> &'static str {
        match self {
            Level::Debug => "debug",
            Level::Info => "info",
            Level::Warn => "warn",
            Level::Error => "error",
        }
    }

    fn parse(s: &str) -> Option<Level> {
        match s.trim().to_ascii_lowercase().as_str() {
            "debug" => Some(Level::Debug),
            "info" => Some(Level::Info),
            "warn" | "warning" => Some(Level::Warn),
            "error" => Some(Level::Error),
            _ => None,
        }
    }
}

/// 0 = off; otherwise `min_level as u8 + 1`.
static MODE: AtomicU8 = AtomicU8::new(0);

/// Turn JSON logging on (at `min_level` and above) or off.
pub fn set_json(enabled: bool, min_level: Level) {
    MODE.store(if enabled { min_level as u8 + 1 } else { 0 }, Ordering::Relaxed);
}

/// Configure from `QCKM_LOG` (`json` or `json:<level>`; default level
/// info). Unknown values are ignored — observability must never turn an
/// env typo into a startup failure.
pub fn init_from_env() {
    let Ok(raw) = std::env::var("QCKM_LOG") else { return };
    let (mode, level) = match raw.split_once(':') {
        Some((m, l)) => (m, Level::parse(l).unwrap_or(Level::Info)),
        None => (raw.as_str(), Level::Info),
    };
    if mode.trim().eq_ignore_ascii_case("json") {
        set_json(true, level);
    }
}

/// Would an event at `level` be written? Use to skip building fields.
pub fn enabled(level: Level) -> bool {
    let mode = MODE.load(Ordering::Relaxed);
    mode != 0 && (level as u8) + 1 >= mode
}

/// A typed JSON field value.
pub enum Value<'a> {
    Str(&'a str),
    U64(u64),
    I64(i64),
    F64(f64),
    Bool(bool),
}

/// Emit one event line (a no-op unless [`enabled`]). The line is built in
/// full then written under the stderr lock, so concurrent events never
/// interleave mid-line.
pub fn event(level: Level, event: &str, fields: &[(&str, Value<'_>)]) {
    if !enabled(level) {
        return;
    }
    let ts_ms = SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_millis().min(u64::MAX as u128) as u64)
        .unwrap_or(0);
    let mut line = String::with_capacity(96);
    line.push_str("{\"ts_ms\":");
    line.push_str(&ts_ms.to_string());
    line.push_str(",\"level\":\"");
    line.push_str(level.name());
    line.push_str("\",\"event\":\"");
    escape_into(&mut line, event);
    line.push('"');
    for (key, value) in fields {
        line.push_str(",\"");
        escape_into(&mut line, key);
        line.push_str("\":");
        match value {
            Value::Str(s) => {
                line.push('"');
                escape_into(&mut line, s);
                line.push('"');
            }
            Value::U64(n) => line.push_str(&n.to_string()),
            Value::I64(n) => line.push_str(&n.to_string()),
            // JSON has no Inf/NaN literal; null is the conventional stand-in.
            Value::F64(x) if x.is_finite() => line.push_str(&format!("{x}")),
            Value::F64(_) => line.push_str("null"),
            Value::Bool(b) => line.push_str(if *b { "true" } else { "false" }),
        }
    }
    line.push_str("}\n");
    let stderr = std::io::stderr();
    let mut out = stderr.lock();
    let _ = out.write_all(line.as_bytes());
}

fn escape_into(out: &mut String, s: &str) {
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
}
