//! Scoped span timers: construct at the top of a stage, drop at the end.
//!
//! On drop the span observes its elapsed time (in seconds) into its
//! histogram, and — only when JSON logging is enabled at debug level —
//! emits one `{"event":"span",…}` line. Cost when logging is off: two
//! clock reads and a histogram observe (a few relaxed atomics).

use super::clock::Clock;
use super::log::{self, Level, Value};
use super::registry::Histogram;
use super::trace;
use std::sync::Arc;

/// A running stage timer; created via [`super::Registry::span`].
///
/// When a request trace is active on this thread (see [`trace`]), the
/// span doubles as a node in that trace's tree — timed on the *trace's*
/// clock, nested by RAII order. Holding the trace handle makes `Span`
/// `!Send`, which is fine: spans are always scoped guards on the thread
/// that opened them.
pub struct Span {
    clock: Arc<dyn Clock>,
    hist: Arc<Histogram>,
    stage: &'static str,
    start_ns: u64,
    trace: Option<trace::SpanHandle>,
}

impl Span {
    pub(crate) fn new(clock: Arc<dyn Clock>, hist: Arc<Histogram>, stage: &'static str) -> Self {
        let start_ns = clock.now_ns();
        let trace = trace::on_span_start(stage);
        Self { clock, hist, stage, start_ns, trace }
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        if let Some(handle) = self.trace.take() {
            handle.finish();
        }
        let elapsed_ns = self.clock.now_ns().saturating_sub(self.start_ns);
        self.hist.observe(elapsed_ns as f64 * 1e-9);
        if log::enabled(Level::Debug) {
            log::event(
                Level::Debug,
                "span",
                &[("stage", Value::Str(self.stage)), ("elapsed_ns", Value::U64(elapsed_ns))],
            );
        }
    }
}
