//! Std-only observability: a metrics registry, scoped span timers,
//! Prometheus text exposition, and an opt-in structured JSON event log.
//!
//! The crate vendors no telemetry dependency, so this module is the whole
//! stack: [`registry::Registry`] holds atomic counters, gauges, and
//! fixed-boundary histograms; [`span::Span`] times a scope against a
//! [`clock::Clock`] (monotonic in production, a settable
//! [`clock::FakeClock`] in tests so exposition pages are deterministically
//! golden-testable); [`prom::render`] encodes the registry as a Prometheus
//! text page (served by the `qckm ctl metrics` protocol verb); and
//! [`log`] emits one JSON line per event/span to stderr when enabled via
//! `QCKM_LOG=json[:level]` or `qckm serve --log-json`; and [`trace`]
//! threads the same `Span` guards into per-request hierarchical span
//! trees for the proto-v5 tracing extension (`query --trace`,
//! `ctl trace`).
//!
//! ## The observational-only contract (INVARIANTS.md I-18)
//!
//! Instrumentation never touches the data path: handles are atomics, spans
//! read the clock and write atomics, and the logger writes stderr. No RNG
//! is consumed, no float in a result is produced or reordered, so every
//! sketch/decode/serve output is bit-for-bit identical with telemetry on,
//! off, or logging enabled (locked by
//! `telemetry_never_perturbs_outputs`).
//!
//! ## Instrument naming
//!
//! All metric families are prefixed `qckm_`, durations are histograms in
//! seconds (`*_seconds`), monotone totals end in `_total`. The full name
//! table lives in README §Observability; the library-wide (label-free)
//! handles are centralized in [`LibMetrics`] so names can never drift
//! between call sites.

pub mod clock;
pub mod log;
pub mod prom;
pub mod registry;
pub mod span;
pub mod trace;

#[cfg(test)]
mod tests;

pub use clock::{Clock, FakeClock, MonotonicClock};
pub use log::{init_from_env, set_json, Level};
pub use registry::{Counter, Gauge, Histogram, Registry};
pub use span::Span;
pub use trace::{IdGen, ProcessIdGen, SeqIdGen, TraceContext, TraceRecord, TraceStore};

use std::sync::{Arc, OnceLock};

/// The process-wide registry, on a monotonic clock. Library-layer
/// instrumentation (stream, decoder, parallel, retry) always records
/// here; the server wires the same registry into its [`ServiceConfig`] so
/// one `ctl metrics` scrape covers every layer.
///
/// [`ServiceConfig`]: crate::server::ServiceConfig
pub fn global() -> &'static Arc<Registry> {
    static GLOBAL: OnceLock<Arc<Registry>> = OnceLock::new();
    GLOBAL.get_or_init(|| Arc::new(Registry::new(Arc::new(MonotonicClock::new()))))
}

/// The standard log-scale latency boundaries, in seconds: 1 µs · 4^i for
/// i in 0..16, topping out near 18 minutes — wide enough for a chunk
/// kernel and a worst-case decode on one fixed grid, so every duration
/// histogram is cross-comparable.
pub fn latency_buckets() -> Vec<f64> {
    Histogram::log_boundaries(1e-6, 4.0, 16)
}

/// Per-replicate decode latency, labeled by decoder *family* (`clompr`,
/// `hier`, …) rather than the full canonical spec: clients choose spec
/// parameters freely, and label cardinality must stay bounded like every
/// other piece of client-influenced state (cf. the shard and
/// decoder-stats caps).
pub fn decode_seconds(family: &str) -> Arc<Histogram> {
    global().histogram(
        "qckm_decode_seconds",
        "Wall time of one decoder replicate, by decoder family.",
        &[("decoder", family)],
        &latency_buckets(),
    )
}

/// Label-free handles for the library's hot layers, registered in the
/// [`global`] registry on first touch. One struct so the name table has a
/// single source of truth, and so `qckm serve` can pre-register every
/// family at startup (a scrape then shows the full schema even before the
/// first push).
pub struct LibMetrics {
    /// `qckm_stream_rows_total` — rows consumed by the streaming sketcher.
    pub stream_rows: Arc<Counter>,
    /// `qckm_stream_window_seconds` — sketch+merge time per streaming window.
    pub stream_window_seconds: Arc<Histogram>,
    /// `qckm_clompr_step1_seconds` — CL-OMPR Step 1 (atom pick) per outer iteration.
    pub clompr_step1_seconds: Arc<Histogram>,
    /// `qckm_clompr_step5_seconds` — CL-OMPR Step 5 (joint refinement) per outer iteration.
    pub clompr_step5_seconds: Arc<Histogram>,
    /// `qckm_hier_split_seconds` — one hierarchical-bisection k=2 split solve.
    pub hier_split_seconds: Arc<Histogram>,
    /// `qckm_parallel_runs_total` — `run_chunked` invocations.
    pub parallel_runs: Arc<Counter>,
    /// `qckm_parallel_chunks_total` — chunks executed across all runs.
    pub parallel_chunks: Arc<Counter>,
    /// `qckm_parallel_chunk_seconds` — per-chunk wall time in the runner.
    pub parallel_chunk_seconds: Arc<Histogram>,
    /// `qckm_retry_attempts_total` — RetryClient reconnect attempts.
    pub retry_attempts: Arc<Counter>,
    /// `qckm_retry_backoff_ms_total` — total backoff milliseconds slept.
    pub retry_backoff_ms: Arc<Counter>,
    /// `qckm_kernel_info{mode,simd}` — constant `1` gauge carrying the
    /// resolved compute-kernel dispatch (see [`crate::kernel`]): `mode` is
    /// `scalar`/`wide` and `simd` the instruction set the dense kernels run
    /// with. Labels reflect the dispatch at first registry touch; flipping
    /// modes later (tests/benches) is invisible here, which is fine — the
    /// gauge is informational and I-22 makes the modes indistinguishable by
    /// output.
    pub kernel_info: Arc<Gauge>,
}

/// The library-layer instruments (see [`LibMetrics`]).
pub fn lib_metrics() -> &'static LibMetrics {
    static LIB: OnceLock<LibMetrics> = OnceLock::new();
    LIB.get_or_init(|| {
        let r = global();
        let lat = latency_buckets();
        LibMetrics {
            stream_rows: r.counter(
                "qckm_stream_rows_total",
                "Rows consumed by the streaming sketcher.",
                &[],
            ),
            stream_window_seconds: r.histogram(
                "qckm_stream_window_seconds",
                "Wall time to sketch and merge one streaming window.",
                &[],
                &lat,
            ),
            clompr_step1_seconds: r.histogram(
                "qckm_clompr_step1_seconds",
                "CL-OMPR Step 1 (screen + L-BFGS atom pick) wall time per outer iteration.",
                &[],
                &lat,
            ),
            clompr_step5_seconds: r.histogram(
                "qckm_clompr_step5_seconds",
                "CL-OMPR Step 5 (joint refinement) wall time per outer iteration.",
                &[],
                &lat,
            ),
            hier_split_seconds: r.histogram(
                "qckm_hier_split_seconds",
                "Hierarchical-bisection k=2 split solve wall time.",
                &[],
                &lat,
            ),
            parallel_runs: r.counter("qckm_parallel_runs_total", "run_chunked invocations.", &[]),
            parallel_chunks: r.counter(
                "qckm_parallel_chunks_total",
                "Chunks executed across all run_chunked invocations.",
                &[],
            ),
            parallel_chunk_seconds: r.histogram(
                "qckm_parallel_chunk_seconds",
                "Per-chunk wall time inside the deterministic chunked runner.",
                &[],
                &lat,
            ),
            retry_attempts: r.counter(
                "qckm_retry_attempts_total",
                "Transport-level reconnect attempts by RetryClient.",
                &[],
            ),
            retry_backoff_ms: r.counter(
                "qckm_retry_backoff_ms_total",
                "Total backoff milliseconds slept by RetryClient.",
                &[],
            ),
            kernel_info: {
                let g = r.gauge(
                    "qckm_kernel_info",
                    "Resolved compute-kernel dispatch (constant 1; see labels).",
                    &[
                        ("mode", crate::kernel::mode().name()),
                        ("simd", crate::kernel::simd_level()),
                    ],
                );
                g.set(1.0);
                g
            },
        }
    })
}
