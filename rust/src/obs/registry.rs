//! The metric registry: named families of counters, gauges, and
//! histograms, each family fanning out into label-keyed series.
//!
//! Registration (rare) takes a mutex; every increment/observe on a
//! returned handle is lock-free atomics, so instrumenting a hot loop
//! costs a few relaxed atomic ops. Handles are `Arc`s — callers cache
//! them (in a struct or a `OnceLock`) instead of re-looking-up by name on
//! the hot path.
//!
//! Metric and label names are validated against the Prometheus grammar at
//! registration; violations panic, because a bad name is a programming
//! error in this crate, never runtime input.

use super::clock::Clock;
use super::span::Span;
use std::collections::BTreeMap;
use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Monotone event count (`*_total`).
pub struct Counter {
    v: AtomicU64,
}

impl Counter {
    fn new() -> Self {
        Self { v: AtomicU64::new(0) }
    }

    pub fn inc(&self) {
        self.v.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.v.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// A value that can go up and down, stored as f64 bits in one atomic.
pub struct Gauge {
    bits: AtomicU64,
}

impl Gauge {
    fn new() -> Self {
        Self { bits: AtomicU64::new(0f64.to_bits()) }
    }

    pub fn set(&self, v: f64) {
        self.bits.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.bits.load(Ordering::Relaxed))
    }
}

/// Fixed-boundary histogram. `bounds` are the finite bucket upper limits
/// (strictly increasing); an implicit `+Inf` bucket catches the rest —
/// exactly the Prometheus model, where bucket `le=B` counts observations
/// `≤ B` cumulatively.
pub struct Histogram {
    bounds: Vec<f64>,
    /// Per-bucket (non-cumulative) counts; `bounds.len() + 1` entries,
    /// the last being the `+Inf` overflow bucket.
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_bits: AtomicU64,
}

impl Histogram {
    fn new(bounds: &[f64]) -> Self {
        assert!(!bounds.is_empty(), "histogram needs at least one bucket bound");
        assert!(
            bounds.iter().all(|b| b.is_finite()),
            "histogram bounds must be finite (the +Inf bucket is implicit)"
        );
        assert!(
            bounds.windows(2).all(|w| w[0] < w[1]),
            "histogram bounds must be strictly increasing"
        );
        Self {
            bounds: bounds.to_vec(),
            buckets: (0..=bounds.len()).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_bits: AtomicU64::new(0f64.to_bits()),
        }
    }

    /// Log-scale boundaries `start · factor^i` for `i in 0..n`.
    pub fn log_boundaries(start: f64, factor: f64, n: usize) -> Vec<f64> {
        assert!(start > 0.0 && factor > 1.0 && n >= 1);
        (0..n).map(|i| start * factor.powi(i as i32)).collect()
    }

    /// Record one observation (for latency histograms: seconds).
    pub fn observe(&self, v: f64) {
        // First bound ≥ v, i.e. the smallest bucket with v ≤ le; NaN falls
        // through every comparison into +Inf.
        let idx = self.bounds.partition_point(|b| *b < v);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        // f64 add via CAS on the bit pattern — lock-free like the rest.
        let mut cur = self.sum_bits.load(Ordering::Relaxed);
        loop {
            let next = (f64::from_bits(cur) + v).to_bits();
            match self
                .sum_bits
                .compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed)
            {
                Ok(_) => break,
                Err(seen) => cur = seen,
            }
        }
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> f64 {
        f64::from_bits(self.sum_bits.load(Ordering::Relaxed))
    }

    /// The finite bucket bounds.
    pub fn bounds(&self) -> &[f64] {
        &self.bounds
    }

    /// Per-bucket (non-cumulative) counts, `+Inf` last, plus count and sum.
    pub(crate) fn snapshot(&self) -> (Vec<u64>, u64, f64) {
        let buckets = self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect();
        (buckets, self.count(), self.sum())
    }
}

/// One registered series handle.
pub(crate) enum Instrument {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Histogram(Arc<Histogram>),
}

/// One metric family: a name's help text, type, and label-keyed series.
pub(crate) struct Family {
    pub(crate) help: String,
    pub(crate) kind: &'static str,
    /// Keyed by the rendered label block (`{k="v",…}`, empty for none) —
    /// already exposition-ready and totally ordered for stable output.
    pub(crate) series: BTreeMap<String, Instrument>,
}

/// A set of metric families sharing one [`Clock`]. See the module docs;
/// most code uses the process-global instance ([`super::global`]) — tests
/// build private registries around a [`super::FakeClock`].
pub struct Registry {
    clock: Arc<dyn Clock>,
    families: Mutex<BTreeMap<String, Family>>,
}

impl Registry {
    pub fn new(clock: Arc<dyn Clock>) -> Self {
        Self { clock, families: Mutex::new(BTreeMap::new()) }
    }

    /// Register (or re-fetch) a counter series. Idempotent: the same
    /// (name, labels) always returns the same underlying counter.
    pub fn counter(&self, name: &str, help: &str, labels: &[(&str, &str)]) -> Arc<Counter> {
        match self.series(name, help, "counter", labels, || {
            Instrument::Counter(Arc::new(Counter::new()))
        }) {
            Instrument::Counter(c) => c,
            _ => unreachable!("kind checked in series()"),
        }
    }

    /// Register (or re-fetch) a gauge series.
    pub fn gauge(&self, name: &str, help: &str, labels: &[(&str, &str)]) -> Arc<Gauge> {
        match self.series(name, help, "gauge", labels, || {
            Instrument::Gauge(Arc::new(Gauge::new()))
        }) {
            Instrument::Gauge(g) => g,
            _ => unreachable!("kind checked in series()"),
        }
    }

    /// Register (or re-fetch) a histogram series with the given finite
    /// bucket bounds. Re-registration must use identical bounds.
    pub fn histogram(
        &self,
        name: &str,
        help: &str,
        labels: &[(&str, &str)],
        bounds: &[f64],
    ) -> Arc<Histogram> {
        match self.series(name, help, "histogram", labels, || {
            Instrument::Histogram(Arc::new(Histogram::new(bounds)))
        }) {
            Instrument::Histogram(h) => {
                assert!(
                    h.bounds() == bounds,
                    "histogram {name} re-registered with different bounds"
                );
                h
            }
            _ => unreachable!("kind checked in series()"),
        }
    }

    fn series(
        &self,
        name: &str,
        help: &str,
        kind: &'static str,
        labels: &[(&str, &str)],
        make: impl FnOnce() -> Instrument,
    ) -> Instrument {
        assert!(valid_metric_name(name), "invalid metric name {name:?}");
        let key = label_key(labels);
        // Lock recovery mirrors the server state lock: registration never
        // leaves a family half-written (BTreeMap insert is the only
        // mutation), so a poisoned guard is safe to take over.
        let mut fams = self.families.lock().unwrap_or_else(|e| e.into_inner());
        let fam = fams.entry(name.to_string()).or_insert_with(|| Family {
            help: help.to_string(),
            kind,
            series: BTreeMap::new(),
        });
        assert!(
            fam.kind == kind,
            "metric {name} already registered as a {} (asked for a {kind})",
            fam.kind
        );
        let inst = fam.series.entry(key).or_insert_with(make);
        match inst {
            Instrument::Counter(c) => Instrument::Counter(Arc::clone(c)),
            Instrument::Gauge(g) => Instrument::Gauge(Arc::clone(g)),
            Instrument::Histogram(h) => Instrument::Histogram(Arc::clone(h)),
        }
    }

    /// Start a scoped timer: on drop it observes the elapsed seconds into
    /// `hist` and, when JSON logging is on at debug level, emits one span
    /// line.
    pub fn span(&self, stage: &'static str, hist: &Arc<Histogram>) -> Span {
        Span::new(Arc::clone(&self.clock), Arc::clone(hist), stage)
    }

    /// The registry's clock reading (the span primitive, exposed for
    /// callers that need raw timestamps).
    pub fn now_ns(&self) -> u64 {
        self.clock.now_ns()
    }

    /// The clock itself — shared with trace recorders so span-tree
    /// timings and histogram timings come from one time source.
    pub fn clock(&self) -> Arc<dyn Clock> {
        Arc::clone(&self.clock)
    }

    /// Render every family as a Prometheus text-format page.
    pub fn render(&self) -> String {
        let fams = self.families.lock().unwrap_or_else(|e| e.into_inner());
        super::prom::render(&fams)
    }
}

impl fmt::Debug for Registry {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let n = self.families.lock().map(|g| g.len()).unwrap_or(0);
        f.debug_struct("Registry").field("families", &n).finish_non_exhaustive()
    }
}

/// `[a-zA-Z_:][a-zA-Z0-9_:]*` — the Prometheus metric-name grammar.
pub(crate) fn valid_metric_name(name: &str) -> bool {
    let mut chars = name.chars();
    matches!(chars.next(), Some(c) if c.is_ascii_alphabetic() || c == '_' || c == ':')
        && chars.all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
}

/// `[a-zA-Z_][a-zA-Z0-9_]*` — the label-name grammar (no colon).
pub(crate) fn valid_label_name(name: &str) -> bool {
    let mut chars = name.chars();
    matches!(chars.next(), Some(c) if c.is_ascii_alphabetic() || c == '_')
        && chars.all(|c| c.is_ascii_alphanumeric() || c == '_')
}

/// Render a label set as its exposition block (`{k="v",…}`), keys sorted
/// so the same set always produces the same series key whatever order the
/// call site lists them in.
fn label_key(labels: &[(&str, &str)]) -> String {
    if labels.is_empty() {
        return String::new();
    }
    let mut sorted: Vec<&(&str, &str)> = labels.iter().collect();
    sorted.sort_by_key(|(k, _)| *k);
    let mut s = String::from("{");
    for (i, (k, v)) in sorted.iter().enumerate() {
        assert!(valid_label_name(k), "invalid label name {k:?}");
        if i > 0 {
            s.push(',');
        }
        s.push_str(k);
        s.push_str("=\"");
        for c in v.chars() {
            match c {
                '\\' => s.push_str("\\\\"),
                '"' => s.push_str("\\\""),
                '\n' => s.push_str("\\n"),
                c => s.push(c),
            }
        }
        s.push('"');
    }
    s.push('}');
    s
}
