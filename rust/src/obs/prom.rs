//! Prometheus text-format exposition (version 0.0.4): rendering from the
//! registry, plus a grammar validator the test suite (and `INVARIANTS.md`
//! I-17's fuzz coverage of the metrics frame) checks pages against.
//!
//! Families render in name order and series in label order (both
//! `BTreeMap`s), so two scrapes of identical counter states are
//! byte-identical — that is what makes the fake-clock golden test
//! possible.

use super::registry::{valid_label_name, valid_metric_name, Family, Instrument};
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::fmt::Write;

/// Render families as a text page: `# HELP` / `# TYPE` then one sample
/// line per series (histograms expand to cumulative `_bucket` lines plus
/// `_sum` and `_count`).
pub(crate) fn render(families: &BTreeMap<String, Family>) -> String {
    let mut out = String::new();
    for (name, fam) in families {
        let _ = writeln!(out, "# HELP {name} {}", escape_help(&fam.help));
        let _ = writeln!(out, "# TYPE {name} {}", fam.kind);
        for (labels, inst) in &fam.series {
            match inst {
                Instrument::Counter(c) => {
                    let _ = writeln!(out, "{name}{labels} {}", c.get());
                }
                Instrument::Gauge(g) => {
                    let _ = writeln!(out, "{name}{labels} {}", fmt_f64(g.get()));
                }
                Instrument::Histogram(h) => {
                    let (buckets, count, sum) = h.snapshot();
                    let mut cumulative = 0u64;
                    for (i, bound) in h.bounds().iter().enumerate() {
                        cumulative += buckets[i];
                        let le = with_le(labels, &fmt_f64(*bound));
                        let _ = writeln!(out, "{name}_bucket{le} {cumulative}");
                    }
                    cumulative += buckets[h.bounds().len()];
                    let le = with_le(labels, "+Inf");
                    let _ = writeln!(out, "{name}_bucket{le} {cumulative}");
                    let _ = writeln!(out, "{name}_sum{labels} {}", fmt_f64(sum));
                    let _ = writeln!(out, "{name}_count{labels} {count}");
                }
            }
        }
    }
    out
}

/// Append `le="<bound>"` to a rendered label block (or create one).
fn with_le(labels: &str, le: &str) -> String {
    if labels.is_empty() {
        format!("{{le=\"{le}\"}}")
    } else {
        // labels is `{…}` — splice before the closing brace.
        format!("{},le=\"{le}\"}}", &labels[..labels.len() - 1])
    }
}

/// Exposition float formatting. Rust's `{}` never uses scientific
/// notation and round-trips shortest, which Prometheus accepts; the
/// non-finite spellings are the format's own.
pub(crate) fn fmt_f64(x: f64) -> String {
    if x.is_nan() {
        "NaN".to_string()
    } else if x == f64::INFINITY {
        "+Inf".to_string()
    } else if x == f64::NEG_INFINITY {
        "-Inf".to_string()
    } else {
        format!("{x}")
    }
}

fn escape_help(help: &str) -> String {
    help.replace('\\', "\\\\").replace('\n', "\\n")
}

/// Check that `page` is well-formed exposition text: every non-empty line
/// is `# HELP <name> <text>`, `# TYPE <name> <type>`, or a sample
/// `name[{labels}] value`. This is the checker behind the golden test and
/// the e2e scrape assertion — kept in the library so every consumer
/// validates against one grammar.
pub fn validate(page: &str) -> Result<()> {
    for (i, line) in page.lines().enumerate() {
        validate_line(line).with_context(|| format!("exposition line {}: {line:?}", i + 1))?;
    }
    Ok(())
}

fn validate_line(line: &str) -> Result<()> {
    if line.is_empty() {
        return Ok(());
    }
    if let Some(rest) = line.strip_prefix("# ") {
        let (keyword, rest) = rest.split_once(' ').context("bare comment keyword")?;
        if keyword != "HELP" && keyword != "TYPE" {
            bail!("unknown comment keyword {keyword:?}");
        }
        let name = rest.split(' ').next().unwrap_or("");
        if !valid_metric_name(name) {
            bail!("invalid metric name {name:?}");
        }
        if keyword == "TYPE" {
            let kind = rest[name.len()..].trim();
            if !matches!(kind, "counter" | "gauge" | "histogram" | "summary" | "untyped") {
                bail!("invalid metric type {kind:?}");
            }
        }
        return Ok(());
    }
    // Sample line: name[{labels}] value
    let (name_part, value_part) = match line.find('{') {
        Some(brace) => {
            let name = &line[..brace];
            let close = find_label_block_end(&line[brace..])
                .context("unterminated label block")?;
            let labels = &line[brace..brace + close + 1];
            validate_labels(labels)?;
            (name, line[brace + close + 1..].trim_start())
        }
        None => {
            let (name, value) = line.split_once(' ').context("sample line without value")?;
            (name, value)
        }
    };
    if !valid_metric_name(name_part) {
        bail!("invalid sample metric name {name_part:?}");
    }
    let value = value_part.trim();
    // f64 parsing accepts the exposition spellings ("+Inf", "NaN") too.
    if value.is_empty() || value.parse::<f64>().is_err() {
        bail!("unparseable sample value {value:?}");
    }
    Ok(())
}

/// Index of the `}` closing the label block that starts at byte 0 of `s`,
/// honoring `\"` escapes inside label values.
fn find_label_block_end(s: &str) -> Option<usize> {
    let mut in_string = false;
    let mut escaped = false;
    for (i, c) in s.char_indices() {
        if escaped {
            escaped = false;
            continue;
        }
        match c {
            '\\' if in_string => escaped = true,
            '"' => in_string = !in_string,
            '}' if !in_string => return Some(i),
            _ => {}
        }
    }
    None
}

fn validate_labels(block: &str) -> Result<()> {
    let inner = block
        .strip_prefix('{')
        .and_then(|s| s.strip_suffix('}'))
        .context("label block must be brace-delimited")?;
    if inner.is_empty() {
        return Ok(()); // `{}` is legal, if pointless.
    }
    let mut rest = inner;
    loop {
        let eq = rest.find('=').context("label without '='")?;
        let name = &rest[..eq];
        if !valid_label_name(name) {
            bail!("invalid label name {name:?}");
        }
        rest = rest[eq + 1..]
            .strip_prefix('"')
            .context("label value must be quoted")?;
        // Scan to the closing quote, honoring backslash escapes.
        let mut end = None;
        let mut escaped = false;
        for (i, c) in rest.char_indices() {
            if escaped {
                escaped = false;
            } else if c == '\\' {
                escaped = true;
            } else if c == '"' {
                end = Some(i);
                break;
            }
        }
        let end = end.context("unterminated label value")?;
        rest = &rest[end + 1..];
        if rest.is_empty() {
            return Ok(());
        }
        rest = rest.strip_prefix(',').context("expected ',' between labels")?;
    }
}
