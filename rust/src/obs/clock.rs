//! The time source behind span timers and histogram observations.
//!
//! Production uses [`MonotonicClock`] (an `Instant` anchor, immune to
//! wall-clock steps). Tests inject [`FakeClock`] and advance it by hand,
//! so a span's measured duration — and therefore the whole Prometheus
//! exposition page — is an exact, assertable constant.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// Nanosecond time source. `now_ns` must be monotone non-decreasing; the
/// epoch is arbitrary (spans only ever subtract two readings).
pub trait Clock: Send + Sync {
    fn now_ns(&self) -> u64;
}

/// Monotonic production clock: nanoseconds since the clock was created.
pub struct MonotonicClock {
    base: Instant,
}

impl MonotonicClock {
    pub fn new() -> Self {
        Self { base: Instant::now() }
    }
}

impl Default for MonotonicClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for MonotonicClock {
    fn now_ns(&self) -> u64 {
        // A u64 of nanoseconds lasts ~584 years from the anchor.
        self.base.elapsed().as_nanos().min(u64::MAX as u128) as u64
    }
}

/// Manually-advanced test clock. Starts at 0; time moves only through
/// [`FakeClock::advance_ns`], so timings recorded against it are exact.
pub struct FakeClock {
    now_ns: AtomicU64,
}

impl FakeClock {
    pub fn new() -> Self {
        Self { now_ns: AtomicU64::new(0) }
    }

    /// Move time forward by `ns` nanoseconds.
    pub fn advance_ns(&self, ns: u64) {
        self.now_ns.fetch_add(ns, Ordering::SeqCst);
    }
}

impl Default for FakeClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for FakeClock {
    fn now_ns(&self) -> u64 {
        self.now_ns.load(Ordering::SeqCst)
    }
}
