//! The time source behind span timers and histogram observations.
//!
//! Production uses [`MonotonicClock`] (an `Instant` anchor, immune to
//! wall-clock steps). Tests inject [`FakeClock`] and advance it by hand,
//! so a span's measured duration — and therefore the whole Prometheus
//! exposition page — is an exact, assertable constant.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// Nanosecond time source. `now_ns` must be monotone non-decreasing; the
/// epoch is arbitrary (spans only ever subtract two readings).
pub trait Clock: Send + Sync {
    fn now_ns(&self) -> u64;
}

/// Monotonic production clock: nanoseconds since the clock was created.
pub struct MonotonicClock {
    base: Instant,
}

impl MonotonicClock {
    pub fn new() -> Self {
        Self { base: Instant::now() }
    }
}

impl Default for MonotonicClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for MonotonicClock {
    fn now_ns(&self) -> u64 {
        // A u64 of nanoseconds lasts ~584 years from the anchor.
        self.base.elapsed().as_nanos().min(u64::MAX as u128) as u64
    }
}

/// Manually-advanced test clock. Starts at 0; time moves only through
/// [`FakeClock::advance_ns`] — or, in stepping mode
/// ([`FakeClock::stepping`]), by a fixed increment after every read — so
/// timings recorded against it are exact.
pub struct FakeClock {
    now_ns: AtomicU64,
    /// Auto-advance per `now_ns` read; 0 in the plain (settable) mode.
    step_ns: u64,
}

impl FakeClock {
    pub fn new() -> Self {
        Self { now_ns: AtomicU64::new(0), step_ns: 0 }
    }

    /// A clock whose every read returns the previous reading plus
    /// `step_ns`, starting from 0. Trace timings under it are exact
    /// functions of the clock-read count — nonzero and assertable.
    pub fn stepping(step_ns: u64) -> Self {
        Self { now_ns: AtomicU64::new(0), step_ns }
    }

    /// Move time forward by `ns` nanoseconds.
    pub fn advance_ns(&self, ns: u64) {
        self.now_ns.fetch_add(ns, Ordering::SeqCst);
    }
}

impl Default for FakeClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for FakeClock {
    fn now_ns(&self) -> u64 {
        // fetch_add returns the pre-increment value: the first read is 0
        // in both modes, and a step of 0 is a plain load.
        self.now_ns.fetch_add(self.step_ns, Ordering::SeqCst)
    }
}
