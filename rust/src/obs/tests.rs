//! Unit tests for the observability layer: instrument semantics, the
//! fake-clock golden exposition page, the exposition grammar validator,
//! JSON log filtering/escaping, and the I-18 lock — telemetry never
//! perturbs deterministic outputs.

use super::clock::FakeClock;
use super::log::{self, Level};
use super::prom;
use super::registry::{Histogram, Registry};
use super::latency_buckets;
use std::sync::{Arc, Mutex, MutexGuard};

/// The JSON log mode is process-global state; tests that flip it hold
/// this lock so they cannot race each other under the parallel test
/// runner.
fn log_mode_lock() -> MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn fake_registry() -> (Arc<FakeClock>, Registry) {
    let clock = Arc::new(FakeClock::new());
    let reg = Registry::new(clock.clone());
    (clock, reg)
}

// -------------------------------------------------------------- instruments

#[test]
fn counters_and_gauges_do_arithmetic() {
    let (_, reg) = fake_registry();
    let c = reg.counter("qckm_test_total", "t", &[]);
    c.inc();
    c.add(41);
    assert_eq!(c.get(), 42);
    let g = reg.gauge("qckm_test_gauge", "t", &[]);
    g.set(-2.5);
    assert_eq!(g.get(), -2.5);
}

#[test]
fn registration_is_idempotent_and_labels_are_order_invariant() {
    let (_, reg) = fake_registry();
    let a = reg.counter("qckm_test_total", "t", &[("x", "1"), ("y", "2")]);
    let b = reg.counter("qckm_test_total", "t", &[("y", "2"), ("x", "1")]);
    a.inc();
    assert_eq!(b.get(), 1, "same (name, labels) must share one counter");
    let other = reg.counter("qckm_test_total", "t", &[("x", "other")]);
    assert_eq!(other.get(), 0, "different labels are a different series");
}

#[test]
#[should_panic(expected = "already registered")]
fn kind_conflict_panics() {
    let (_, reg) = fake_registry();
    let _ = reg.counter("qckm_test_total", "t", &[]);
    let _ = reg.gauge("qckm_test_total", "t", &[]);
}

#[test]
fn histogram_buckets_are_le_inclusive() {
    let (_, reg) = fake_registry();
    let h = reg.histogram("qckm_test_seconds", "t", &[], &[1.0, 10.0]);
    h.observe(1.0); // exactly a bound: belongs to that bucket (v <= le)
    h.observe(0.5);
    h.observe(10.5); // overflows into +Inf
    h.observe(f64::NAN); // NaN compares false everywhere -> +Inf
    let (buckets, count, _) = h.snapshot();
    assert_eq!(buckets, vec![2, 0, 2]);
    assert_eq!(count, 4);
    assert!(h.sum().is_nan());
}

#[test]
fn log_boundaries_are_geometric() {
    let b = Histogram::log_boundaries(1e-6, 4.0, 3);
    assert_eq!(b, vec![1e-6, 4e-6, 1.6e-5]);
    let lat = latency_buckets();
    assert_eq!(lat.len(), 16);
    assert!(lat.windows(2).all(|w| w[0] < w[1]));
}

// ------------------------------------------------------- golden exposition

/// The fake-clock golden test the ISSUE names: spans timed on a settable
/// clock make the whole page an exact constant.
#[test]
fn fake_clock_exposition_page_is_golden() {
    let (clock, reg) = fake_registry();
    let c = reg.counter("qckm_requests_total", "Requests handled.", &[("verb", "push")]);
    c.add(3);
    let h = reg.histogram("qckm_request_seconds", "Latency.", &[], &[0.001, 0.01, 0.1]);
    {
        let _span = reg.span("request", &h);
        clock.advance_ns(2_000_000); // exactly 2 ms
    }
    let page = reg.render();
    let expected = "\
# HELP qckm_request_seconds Latency.
# TYPE qckm_request_seconds histogram
qckm_request_seconds_bucket{le=\"0.001\"} 0
qckm_request_seconds_bucket{le=\"0.01\"} 1
qckm_request_seconds_bucket{le=\"0.1\"} 1
qckm_request_seconds_bucket{le=\"+Inf\"} 1
qckm_request_seconds_sum 0.002
qckm_request_seconds_count 1
# HELP qckm_requests_total Requests handled.
# TYPE qckm_requests_total counter
qckm_requests_total{verb=\"push\"} 3
";
    assert_eq!(page, expected);
    prom::validate(&page).unwrap();
}

#[test]
fn exposition_validator_accepts_the_global_page_and_rejects_junk() {
    // Touch the library families so the global page is non-trivial.
    let _ = super::lib_metrics();
    let _ = super::decode_seconds("clompr");
    let page = super::global().render();
    assert!(page.contains("qckm_stream_rows_total"));
    // Display formatting never goes scientific: the first latency bound
    // (1 µs) renders as a plain decimal.
    assert!(page.contains("qckm_decode_seconds_bucket{decoder=\"clompr\",le=\"0.000001\"}"));
    prom::validate(&page).unwrap();

    for bad in [
        "no_value_here",
        "1leading_digit 3",
        "name{unclosed=\"x\" 3",
        "name{le=0.1} 3",
        "name{} not_a_number",
        "# WAT name counter",
        "# TYPE name flavor",
    ] {
        assert!(prom::validate(bad).is_err(), "accepted {bad:?}");
    }
    for good in [
        "name 3",
        "name{a=\"b\",c=\"d e,f\"} 0.25",
        "name{a=\"quote \\\" and brace } inside\"} +Inf",
        "# HELP name some help",
        "# TYPE name histogram",
        "",
    ] {
        assert!(prom::validate(good).is_ok(), "rejected {good:?}");
    }
}

#[test]
fn label_values_are_escaped_in_exposition() {
    let (_, reg) = fake_registry();
    let c = reg.counter("qckm_test_total", "t", &[("path", "a\"b\\c\nd")]);
    c.inc();
    let page = reg.render();
    assert!(page.contains("qckm_test_total{path=\"a\\\"b\\\\c\\nd\"} 1"));
    prom::validate(&page).unwrap();
}

// ------------------------------------------------------------ structured log

#[test]
fn json_log_mode_filters_by_level() {
    let _guard = log_mode_lock();
    log::set_json(false, Level::Debug);
    assert!(!log::enabled(Level::Error), "off means nothing is enabled");
    log::set_json(true, Level::Warn);
    assert!(log::enabled(Level::Error));
    assert!(log::enabled(Level::Warn));
    assert!(!log::enabled(Level::Info));
    assert!(!log::enabled(Level::Debug));
    log::set_json(true, Level::Debug);
    assert!(log::enabled(Level::Debug));
    // Emit one of each shape — exercises the writer path end to end.
    log::event(
        Level::Info,
        "test \"quoted\"",
        &[
            ("s", log::Value::Str("line\nbreak")),
            ("u", log::Value::U64(7)),
            ("i", log::Value::I64(-7)),
            ("f", log::Value::F64(0.5)),
            ("nan", log::Value::F64(f64::NAN)),
            ("b", log::Value::Bool(true)),
        ],
    );
    log::set_json(false, Level::Info);
}

// ------------------------------------------------------------------- I-18

/// INVARIANTS.md I-18: telemetry is observational only. The same decode —
/// through the instrumented parallel runner, CL-OMPR step spans, and
/// per-family decode histograms — must be bit-for-bit identical with JSON
/// span logging at debug level versus logging off.
#[test]
fn telemetry_never_perturbs_outputs() {
    use crate::clompr::ClOmprParams;
    use crate::decoder::DecoderSpec;
    use crate::frequency::{DrawnFrequencies, FrequencyLaw};
    use crate::parallel::Parallelism;
    use crate::rng::Rng;
    use crate::sketch::SketchOperator;

    let run = || {
        let mut rng = Rng::new(9);
        let data = crate::data::gaussian_mixture_pm1(300, 3, 2, &mut rng);
        let freqs =
            DrawnFrequencies::draw(FrequencyLaw::AdaptedRadius, 3, 48, 1.0, &mut Rng::new(5));
        let op = SketchOperator::quantized(freqs);
        let z = op.sketch_dataset_par(&data.points, &Parallelism::fixed(2));
        let spec = DecoderSpec::parse("clompr").unwrap();
        let sol = spec.decode_best_of(
            &op,
            2,
            &z,
            vec![-1.0; 3],
            vec![1.0; 3],
            &ClOmprParams::default(),
            1,
            &mut Rng::new(1),
        );
        let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<u64>>();
        (bits(sol.centroids.as_slice()), bits(&sol.weights), sol.objective.to_bits())
    };

    let _guard = log_mode_lock();
    log::set_json(false, Level::Info);
    let quiet = run();
    log::set_json(true, Level::Debug); // every span now also emits a line
    let loud = run();
    log::set_json(false, Level::Info);
    assert_eq!(quiet, loud, "telemetry must never perturb decode outputs");
}
