//! Unit tests for the observability layer: instrument semantics, the
//! fake-clock golden exposition page, the exposition grammar validator,
//! JSON log filtering/escaping, and the I-18 lock — telemetry never
//! perturbs deterministic outputs.

use super::clock::FakeClock;
use super::log::{self, Level};
use super::prom;
use super::registry::{Histogram, Registry};
use super::latency_buckets;
use std::sync::{Arc, Mutex, MutexGuard};

/// The JSON log mode is process-global state; tests that flip it hold
/// this lock so they cannot race each other under the parallel test
/// runner.
fn log_mode_lock() -> MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn fake_registry() -> (Arc<FakeClock>, Registry) {
    let clock = Arc::new(FakeClock::new());
    let reg = Registry::new(clock.clone());
    (clock, reg)
}

// -------------------------------------------------------------- instruments

#[test]
fn counters_and_gauges_do_arithmetic() {
    let (_, reg) = fake_registry();
    let c = reg.counter("qckm_test_total", "t", &[]);
    c.inc();
    c.add(41);
    assert_eq!(c.get(), 42);
    let g = reg.gauge("qckm_test_gauge", "t", &[]);
    g.set(-2.5);
    assert_eq!(g.get(), -2.5);
}

#[test]
fn registration_is_idempotent_and_labels_are_order_invariant() {
    let (_, reg) = fake_registry();
    let a = reg.counter("qckm_test_total", "t", &[("x", "1"), ("y", "2")]);
    let b = reg.counter("qckm_test_total", "t", &[("y", "2"), ("x", "1")]);
    a.inc();
    assert_eq!(b.get(), 1, "same (name, labels) must share one counter");
    let other = reg.counter("qckm_test_total", "t", &[("x", "other")]);
    assert_eq!(other.get(), 0, "different labels are a different series");
}

#[test]
#[should_panic(expected = "already registered")]
fn kind_conflict_panics() {
    let (_, reg) = fake_registry();
    let _ = reg.counter("qckm_test_total", "t", &[]);
    let _ = reg.gauge("qckm_test_total", "t", &[]);
}

#[test]
fn histogram_buckets_are_le_inclusive() {
    let (_, reg) = fake_registry();
    let h = reg.histogram("qckm_test_seconds", "t", &[], &[1.0, 10.0]);
    h.observe(1.0); // exactly a bound: belongs to that bucket (v <= le)
    h.observe(0.5);
    h.observe(10.5); // overflows into +Inf
    h.observe(f64::NAN); // NaN compares false everywhere -> +Inf
    let (buckets, count, _) = h.snapshot();
    assert_eq!(buckets, vec![2, 0, 2]);
    assert_eq!(count, 4);
    assert!(h.sum().is_nan());
}

#[test]
fn log_boundaries_are_geometric() {
    let b = Histogram::log_boundaries(1e-6, 4.0, 3);
    assert_eq!(b, vec![1e-6, 4e-6, 1.6e-5]);
    let lat = latency_buckets();
    assert_eq!(lat.len(), 16);
    assert!(lat.windows(2).all(|w| w[0] < w[1]));
}

// ------------------------------------------------------- golden exposition

/// The fake-clock golden test the ISSUE names: spans timed on a settable
/// clock make the whole page an exact constant.
#[test]
fn fake_clock_exposition_page_is_golden() {
    let (clock, reg) = fake_registry();
    let c = reg.counter("qckm_requests_total", "Requests handled.", &[("verb", "push")]);
    c.add(3);
    let h = reg.histogram("qckm_request_seconds", "Latency.", &[], &[0.001, 0.01, 0.1]);
    {
        let _span = reg.span("request", &h);
        clock.advance_ns(2_000_000); // exactly 2 ms
    }
    let page = reg.render();
    let expected = "\
# HELP qckm_request_seconds Latency.
# TYPE qckm_request_seconds histogram
qckm_request_seconds_bucket{le=\"0.001\"} 0
qckm_request_seconds_bucket{le=\"0.01\"} 1
qckm_request_seconds_bucket{le=\"0.1\"} 1
qckm_request_seconds_bucket{le=\"+Inf\"} 1
qckm_request_seconds_sum 0.002
qckm_request_seconds_count 1
# HELP qckm_requests_total Requests handled.
# TYPE qckm_requests_total counter
qckm_requests_total{verb=\"push\"} 3
";
    assert_eq!(page, expected);
    prom::validate(&page).unwrap();
}

#[test]
fn exposition_validator_accepts_the_global_page_and_rejects_junk() {
    // Touch the library families so the global page is non-trivial.
    let _ = super::lib_metrics();
    let _ = super::decode_seconds("clompr");
    let page = super::global().render();
    assert!(page.contains("qckm_stream_rows_total"));
    // Display formatting never goes scientific: the first latency bound
    // (1 µs) renders as a plain decimal.
    assert!(page.contains("qckm_decode_seconds_bucket{decoder=\"clompr\",le=\"0.000001\"}"));
    prom::validate(&page).unwrap();

    for bad in [
        "no_value_here",
        "1leading_digit 3",
        "name{unclosed=\"x\" 3",
        "name{le=0.1} 3",
        "name{} not_a_number",
        "# WAT name counter",
        "# TYPE name flavor",
    ] {
        assert!(prom::validate(bad).is_err(), "accepted {bad:?}");
    }
    for good in [
        "name 3",
        "name{a=\"b\",c=\"d e,f\"} 0.25",
        "name{a=\"quote \\\" and brace } inside\"} +Inf",
        "# HELP name some help",
        "# TYPE name histogram",
        "",
    ] {
        assert!(prom::validate(good).is_ok(), "rejected {good:?}");
    }
}

#[test]
fn label_values_are_escaped_in_exposition() {
    let (_, reg) = fake_registry();
    let c = reg.counter("qckm_test_total", "t", &[("path", "a\"b\\c\nd")]);
    c.inc();
    let page = reg.render();
    assert!(page.contains("qckm_test_total{path=\"a\\\"b\\\\c\\nd\"} 1"));
    prom::validate(&page).unwrap();
}

// ----------------------------------------------------------------- tracing

#[test]
fn trace_ids_render_and_parse_round_trip() {
    use super::trace::{hex, parse_trace_id};
    let id: [u8; 16] = *b"\x00\x01\x02\x03\x04\x05\x06\x07\x08\x09\x0a\x0b\x0c\x0d\x0e\xff";
    let s = hex(&id);
    assert_eq!(s, "000102030405060708090a0b0c0d0eff");
    assert_eq!(parse_trace_id(&s).unwrap(), id);
    assert_eq!(parse_trace_id(" 000102030405060708090a0b0c0d0eff\n").unwrap(), id);
    let (short, nonhex, long) = ("0".repeat(31), "g".repeat(32), "0".repeat(33));
    for bad in ["", "abc", short.as_str(), nonhex.as_str(), long.as_str()] {
        assert!(parse_trace_id(bad).is_err(), "accepted {bad:?}");
    }
}

/// The recorder's JSON under a stepping clock is an exact constant:
/// every clock read advances time by a fixed step, so each span's start
/// and elapsed time is a pure function of the read count — outer opens
/// at read 0 and closes at read 3 (30 ns), inner occupies reads 1–2.
#[test]
fn stepping_clock_trace_json_is_golden() {
    use super::trace::{self, IdGen, SeqIdGen, TraceRecorder};
    let rec = TraceRecorder::new(
        Arc::new(FakeClock::stepping(10)),
        SeqIdGen::new(0xF00D).next_context(),
    );
    rec.record_closed("frame_decode", 0, 5);
    {
        let _g = trace::install(&rec);
        let _outer = trace::scoped("outer");
        let _inner = trace::scoped("inner");
    }
    let expected = r#"{
  "trace_id": "000000000000f00d0000000000000001",
  "parent_span": "0000000000000001",
  "verb": "demo",
  "ok": true,
  "dropped_spans": 0,
  "spans": [
    {
      "stage": "frame_decode",
      "start_ns": 0,
      "elapsed_ns": 5,
      "children": []
    },
    {
      "stage": "outer",
      "start_ns": 0,
      "elapsed_ns": 30,
      "children": [
        {
          "stage": "inner",
          "start_ns": 10,
          "elapsed_ns": 10,
          "children": []
        }
      ]
    }
  ]
}"#;
    assert_eq!(rec.snapshot("demo", true).to_json(), expected);
}

/// The per-trace span cap bounds memory and is accounted for: spans past
/// [`MAX_TRACE_SPANS`] vanish but bump the record's `dropped_spans`.
#[test]
fn span_cap_bounds_the_tree_and_counts_drops() {
    use super::trace::{self, IdGen, SeqIdGen, TraceRecorder, MAX_TRACE_SPANS};
    let rec = TraceRecorder::new(Arc::new(FakeClock::new()), SeqIdGen::new(1).next_context());
    let _g = trace::install(&rec);
    for _ in 0..MAX_TRACE_SPANS + 3 {
        let _s = trace::scoped("leaf");
    }
    let record = rec.snapshot("push", true);
    assert_eq!(record.spans.len(), MAX_TRACE_SPANS);
    assert_eq!(record.dropped, 3);
    assert!(record.to_json().contains("\"dropped_spans\": 3"));
}

// ------------------------------------------------------------ structured log

#[test]
fn json_log_mode_filters_by_level() {
    let _guard = log_mode_lock();
    log::set_json(false, Level::Debug);
    assert!(!log::enabled(Level::Error), "off means nothing is enabled");
    log::set_json(true, Level::Warn);
    assert!(log::enabled(Level::Error));
    assert!(log::enabled(Level::Warn));
    assert!(!log::enabled(Level::Info));
    assert!(!log::enabled(Level::Debug));
    log::set_json(true, Level::Debug);
    assert!(log::enabled(Level::Debug));
    // Emit one of each shape — exercises the writer path end to end.
    log::event(
        Level::Info,
        "test \"quoted\"",
        &[
            ("s", log::Value::Str("line\nbreak")),
            ("u", log::Value::U64(7)),
            ("i", log::Value::I64(-7)),
            ("f", log::Value::F64(0.5)),
            ("nan", log::Value::F64(f64::NAN)),
            ("b", log::Value::Bool(true)),
        ],
    );
    log::set_json(false, Level::Info);
}

// ------------------------------------------------------------------- I-18

/// INVARIANTS.md I-18: telemetry is observational only. The same decode —
/// through the instrumented parallel runner, CL-OMPR step spans, and
/// per-family decode histograms — must be bit-for-bit identical with JSON
/// span logging at debug level versus logging off.
#[test]
fn telemetry_never_perturbs_outputs() {
    use crate::clompr::ClOmprParams;
    use crate::decoder::DecoderSpec;
    use crate::frequency::{DrawnFrequencies, FrequencyLaw};
    use crate::parallel::Parallelism;
    use crate::rng::Rng;
    use crate::sketch::SketchOperator;

    let run = || {
        let mut rng = Rng::new(9);
        let data = crate::data::gaussian_mixture_pm1(300, 3, 2, &mut rng);
        let freqs =
            DrawnFrequencies::draw(FrequencyLaw::AdaptedRadius, 3, 48, 1.0, &mut Rng::new(5));
        let op = SketchOperator::quantized(freqs);
        let z = op.sketch_dataset_par(&data.points, &Parallelism::fixed(2));
        let spec = DecoderSpec::parse("clompr").unwrap();
        let sol = spec.decode_best_of(
            &op,
            2,
            &z,
            vec![-1.0; 3],
            vec![1.0; 3],
            &ClOmprParams::default(),
            1,
            &mut Rng::new(1),
        );
        let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<u64>>();
        (bits(sol.centroids.as_slice()), bits(&sol.weights), sol.objective.to_bits())
    };

    let _guard = log_mode_lock();
    log::set_json(false, Level::Info);
    let quiet = run();
    log::set_json(true, Level::Debug); // every span now also emits a line
    let loud = run();
    log::set_json(false, Level::Info);
    assert_eq!(quiet, loud, "telemetry must never perturb decode outputs");

    // I-19 extends the lock to tracing: the same run under an installed
    // trace recorder is also bit-for-bit identical, and the recorder saw
    // the request-thread stages only — parallel worker spans stay out by
    // construction (workers never inherit the thread-local recorder, and
    // their stage is excluded even on the calling thread).
    use super::trace::{self, IdGen, SeqIdGen, TraceRecorder};
    let rec = TraceRecorder::new(Arc::new(FakeClock::new()), SeqIdGen::new(7).next_context());
    let traced = {
        let _g = trace::install(&rec);
        run()
    };
    assert_eq!(quiet, traced, "tracing must never perturb decode outputs");
    let record = rec.snapshot("query", true);
    let stages: Vec<&str> = record.spans.iter().map(|s| s.stage.as_str()).collect();
    assert!(stages.contains(&"decode"), "{stages:?}");
    assert!(stages.contains(&"clompr_step1"), "{stages:?}");
    assert!(stages.contains(&"clompr_step5"), "{stages:?}");
    assert!(!stages.contains(&"parallel_chunk"), "{stages:?}");
}
