//! End-to-end request tracing: binary trace context, a per-request span
//! tree recorder, and a bounded server-side trace ring.
//!
//! A client that opts in (`push --trace` / `query --trace`) generates a
//! 16-byte trace id plus an 8-byte parent span id through an injectable
//! [`IdGen`] (deterministic in tests) and sends them in a proto-v5 frame
//! extension. On the server, the connection thread installs a
//! [`TraceRecorder`] for the duration of that one request; every
//! [`super::Span`] the request passes through — frame decode, cap check,
//! `ingest_encode`, `window_merge`, per-iteration `clompr_step1`/`step5`,
//! `hier_split` — attaches itself as a node in the recorder's tree. The
//! finished tree lands in a bounded [`TraceStore`] ring, served back as
//! JSON by the `ctl trace` protocol verb.
//!
//! ## The observational-only contract (INVARIANTS.md I-19)
//!
//! Recording is clock reads and `Vec` pushes on the connection thread;
//! no RNG is consumed and no data-path float is touched, so outputs are
//! bit-for-bit identical with tracing on or off. Worker threads spawned
//! by the parallel runner never see the thread-local recorder (it is
//! deliberately thread-local, not global), and the unbounded-cardinality
//! `parallel_chunk` stage is excluded outright.

use super::clock::Clock;
use anyhow::{bail, Result};
use std::cell::{Cell, RefCell};
use std::collections::VecDeque;
use std::rc::Rc;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Span-tree nodes recorded per trace before further spans are counted
/// only in `dropped_spans`. Bounds server memory against a pathological
/// decode (the deepest honest tree is `O(outer_iters)` ≈ tens of nodes).
pub const MAX_TRACE_SPANS: usize = 512;

// ------------------------------------------------------------- trace context

/// The client-generated identity of one traced request: a 16-byte trace
/// id (globally unique per request) and an 8-byte parent span id (the
/// client-side span the server tree hangs under; opaque to the server).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TraceContext {
    pub trace_id: [u8; 16],
    pub parent_span: [u8; 8],
}

impl TraceContext {
    pub fn trace_id_hex(&self) -> String {
        hex(&self.trace_id)
    }

    pub fn parent_span_hex(&self) -> String {
        hex(&self.parent_span)
    }
}

/// Lowercase hex of a byte string (trace ids in logs, JSON, and `--id`).
pub fn hex(bytes: &[u8]) -> String {
    let mut s = String::with_capacity(bytes.len() * 2);
    for b in bytes {
        s.push(char::from_digit((b >> 4) as u32, 16).unwrap());
        s.push(char::from_digit((b & 0xf) as u32, 16).unwrap());
    }
    s
}

/// Parse the 32-hex-char form produced by [`TraceContext::trace_id_hex`]
/// (the `ctl trace --id` argument).
pub fn parse_trace_id(s: &str) -> Result<[u8; 16]> {
    let s = s.trim();
    if s.len() != 32 || !s.is_ascii() {
        bail!("trace id must be exactly 32 hex characters, got {:?}", s);
    }
    let mut id = [0u8; 16];
    for (i, chunk) in s.as_bytes().chunks_exact(2).enumerate() {
        let hi = (chunk[0] as char).to_digit(16);
        let lo = (chunk[1] as char).to_digit(16);
        match (hi, lo) {
            (Some(h), Some(l)) => id[i] = ((h << 4) | l) as u8,
            _ => bail!("trace id contains a non-hex character: {:?}", s),
        }
    }
    Ok(id)
}

// ------------------------------------------------------------------- id gen

/// Source of trace contexts on the client side. Injectable so tests pin
/// ids exactly; production uses [`ProcessIdGen`].
pub trait IdGen: Send {
    fn next_context(&mut self) -> TraceContext;
}

/// Deterministic generator for tests: trace id = `base` ++ a counter
/// (both big-endian u64s), parent span = the counter.
pub struct SeqIdGen {
    base: u64,
    counter: u64,
}

impl SeqIdGen {
    pub fn new(base: u64) -> Self {
        Self { base, counter: 0 }
    }
}

impl IdGen for SeqIdGen {
    fn next_context(&mut self) -> TraceContext {
        self.counter += 1;
        let mut trace_id = [0u8; 16];
        trace_id[..8].copy_from_slice(&self.base.to_be_bytes());
        trace_id[8..].copy_from_slice(&self.counter.to_be_bytes());
        TraceContext { trace_id, parent_span: self.counter.to_be_bytes() }
    }
}

/// Std-only production generator: a splitmix64 stream seeded from wall
/// time, the process id, and a process-global counter. Not
/// cryptographic — trace ids only need to be distinct, not secret.
pub struct ProcessIdGen {
    state: u64,
}

impl ProcessIdGen {
    pub fn new() -> Self {
        static NONCE: AtomicU64 = AtomicU64::new(0);
        let t = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_nanos() as u64)
            .unwrap_or(0);
        let n = NONCE.fetch_add(1, Ordering::Relaxed);
        Self { state: t ^ (std::process::id() as u64).rotate_left(32) ^ n.rotate_left(17) }
    }

    fn next_u64(&mut self) -> u64 {
        // splitmix64: full-period, passes the mixers-we-need bar.
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

impl Default for ProcessIdGen {
    fn default() -> Self {
        Self::new()
    }
}

impl IdGen for ProcessIdGen {
    fn next_context(&mut self) -> TraceContext {
        let mut trace_id = [0u8; 16];
        trace_id[..8].copy_from_slice(&self.next_u64().to_be_bytes());
        trace_id[8..].copy_from_slice(&self.next_u64().to_be_bytes());
        TraceContext { trace_id, parent_span: self.next_u64().to_be_bytes() }
    }
}

// ----------------------------------------------------------------- recorder

struct Node {
    stage: &'static str,
    parent: Option<u32>,
    start_ns: u64,
    end_ns: u64,
}

/// Per-request span-tree recorder, installed in a thread-local for the
/// duration of one request on the connection thread. Spans nest by RAII
/// order: an open span is the parent of any span opened before it
/// closes, which matches the call tree exactly because `Span` guards are
/// scoped.
pub struct TraceRecorder {
    clock: Arc<dyn Clock>,
    ctx: TraceContext,
    nodes: RefCell<Vec<Node>>,
    stack: RefCell<Vec<u32>>,
    dropped: Cell<u32>,
}

thread_local! {
    static ACTIVE: RefCell<Option<Rc<TraceRecorder>>> = const { RefCell::new(None) };
}

impl TraceRecorder {
    pub fn new(clock: Arc<dyn Clock>, ctx: TraceContext) -> Rc<Self> {
        Rc::new(Self {
            clock,
            ctx,
            nodes: RefCell::new(Vec::new()),
            stack: RefCell::new(Vec::new()),
            dropped: Cell::new(0),
        })
    }

    fn enter(&self, stage: &'static str) -> Option<u32> {
        let mut nodes = self.nodes.borrow_mut();
        if nodes.len() >= MAX_TRACE_SPANS {
            self.dropped.set(self.dropped.get().saturating_add(1));
            return None;
        }
        let parent = self.stack.borrow().last().copied();
        let now = self.clock.now_ns();
        nodes.push(Node { stage, parent, start_ns: now, end_ns: now });
        let idx = (nodes.len() - 1) as u32;
        self.stack.borrow_mut().push(idx);
        Some(idx)
    }

    fn exit(&self, idx: u32) {
        let now = self.clock.now_ns();
        self.nodes.borrow_mut()[idx as usize].end_ns = now;
        let mut stack = self.stack.borrow_mut();
        // LIFO in the common case; tolerate out-of-order guard drops
        // rather than corrupting later parentage.
        if stack.last() == Some(&idx) {
            stack.pop();
        } else {
            stack.retain(|&i| i != idx);
        }
    }

    /// Record an already-measured interval as a node (no stack entry).
    /// Used for frame decode, which finishes before the trace context it
    /// carries can be installed.
    pub fn record_closed(&self, stage: &'static str, start_ns: u64, end_ns: u64) {
        let mut nodes = self.nodes.borrow_mut();
        if nodes.len() >= MAX_TRACE_SPANS {
            self.dropped.set(self.dropped.get().saturating_add(1));
            return;
        }
        let parent = self.stack.borrow().last().copied();
        nodes.push(Node { stage, parent, start_ns, end_ns });
    }

    /// Freeze the tree into an owned record (the recorder stays usable,
    /// but in practice this is the last touch before the store).
    pub fn snapshot(&self, verb: &str, ok: bool) -> TraceRecord {
        let spans = self
            .nodes
            .borrow()
            .iter()
            .map(|n| SpanRecord {
                stage: n.stage.to_string(),
                parent: n.parent,
                start_ns: n.start_ns,
                end_ns: n.end_ns.max(n.start_ns),
            })
            .collect();
        TraceRecord {
            trace_id: self.ctx.trace_id,
            parent_span: self.ctx.parent_span,
            verb: verb.to_string(),
            ok,
            dropped: self.dropped.get(),
            spans,
        }
    }
}

/// Install `rec` as this thread's active recorder until the guard drops.
pub fn install(rec: &Rc<TraceRecorder>) -> InstallGuard {
    let prev = ACTIVE.with(|a| a.borrow_mut().replace(Rc::clone(rec)));
    InstallGuard { prev }
}

/// Restores the previously-active recorder (usually `None`) on drop.
pub struct InstallGuard {
    prev: Option<Rc<TraceRecorder>>,
}

impl Drop for InstallGuard {
    fn drop(&mut self) {
        let prev = self.prev.take();
        ACTIVE.with(|a| *a.borrow_mut() = prev);
    }
}

/// `parallel_chunk` is the one span stage excluded from traces: its node
/// count is workload-proportional (one per chunk) and under a threaded
/// runner most chunks execute off the connection thread anyway, so
/// including it would record a thread-schedule-dependent subset.
fn stage_is_traced(stage: &str) -> bool {
    stage != "parallel_chunk"
}

/// Hook for [`super::Span::new`]: attach a node to the active recorder,
/// if any. Returns `None` (free) when no trace is active on this thread.
pub(crate) fn on_span_start(stage: &'static str) -> Option<SpanHandle> {
    if !stage_is_traced(stage) {
        return None;
    }
    let rec = ACTIVE.with(|a| a.borrow().as_ref().map(Rc::clone))?;
    let idx = rec.enter(stage)?;
    Some(SpanHandle { rec, idx })
}

/// An open node in the active trace; closed by [`SpanHandle::finish`]
/// from the owning `Span`'s drop.
pub(crate) struct SpanHandle {
    rec: Rc<TraceRecorder>,
    idx: u32,
}

impl SpanHandle {
    pub(crate) fn finish(self) {
        self.rec.exit(self.idx);
    }
}

/// A trace-only scoped node for stages that have no metrics histogram
/// (e.g. the server's cap/method check). Free when no trace is active.
pub fn scoped(stage: &'static str) -> Option<ScopedTraceSpan> {
    on_span_start(stage).map(|handle| ScopedTraceSpan { handle: Some(handle) })
}

pub struct ScopedTraceSpan {
    handle: Option<SpanHandle>,
}

impl Drop for ScopedTraceSpan {
    fn drop(&mut self) {
        if let Some(h) = self.handle.take() {
            h.finish();
        }
    }
}

// ------------------------------------------------------------------ records

/// One closed span: `parent` indexes into the owning record's `spans`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SpanRecord {
    pub stage: String,
    pub parent: Option<u32>,
    pub start_ns: u64,
    pub end_ns: u64,
}

/// One finished request trace, as stored in the ring and rendered by
/// `ctl trace`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TraceRecord {
    pub trace_id: [u8; 16],
    pub parent_span: [u8; 8],
    pub verb: String,
    pub ok: bool,
    /// Spans not recorded because the tree hit [`MAX_TRACE_SPANS`].
    pub dropped: u32,
    pub spans: Vec<SpanRecord>,
}

impl TraceRecord {
    /// Deterministic pretty JSON (2-space indent, keys in fixed order,
    /// spans as a forest in recording order). The CLI prints this string
    /// verbatim — no client-side JSON machinery needed — and the golden
    /// test pins it exactly.
    pub fn to_json(&self) -> String {
        let mut out = String::new();
        write_record(&mut out, self, 0);
        out
    }
}

/// Render a batch of records as `{"traces":[…]}`, newest first (the
/// `ctl trace` response body).
pub fn traces_to_json(records: &[TraceRecord]) -> String {
    let mut out = String::new();
    if records.is_empty() {
        out.push_str("{\n  \"traces\": []\n}");
        return out;
    }
    out.push_str("{\n  \"traces\": [\n");
    for (i, rec) in records.iter().enumerate() {
        push_indent(&mut out, 2);
        write_record(&mut out, rec, 2);
        out.push_str(if i + 1 < records.len() { ",\n" } else { "\n" });
    }
    out.push_str("  ]\n}");
    out
}

fn push_indent(out: &mut String, level: usize) {
    for _ in 0..level {
        out.push_str("  ");
    }
}

fn push_json_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// `level` is the indent depth (in 2-space units) of the record's own
/// opening brace; continuation lines indent one deeper.
fn write_record(out: &mut String, rec: &TraceRecord, level: usize) {
    // Children lists from the flat parent-indexed representation.
    let n = rec.spans.len();
    let mut children: Vec<Vec<usize>> = vec![Vec::new(); n];
    let mut roots: Vec<usize> = Vec::new();
    for (i, s) in rec.spans.iter().enumerate() {
        match s.parent {
            // Defend against a corrupt parent index (forward or
            // self-reference cannot come from the recorder, but records
            // are stored data): treat it as a root.
            Some(p) if (p as usize) < i => children[p as usize].push(i),
            Some(_) => roots.push(i),
            None => roots.push(i),
        }
    }

    out.push_str("{\n");
    push_indent(out, level + 1);
    out.push_str("\"trace_id\": ");
    push_json_str(out, &hex(&rec.trace_id));
    out.push_str(",\n");
    push_indent(out, level + 1);
    out.push_str("\"parent_span\": ");
    push_json_str(out, &hex(&rec.parent_span));
    out.push_str(",\n");
    push_indent(out, level + 1);
    out.push_str("\"verb\": ");
    push_json_str(out, &rec.verb);
    out.push_str(",\n");
    push_indent(out, level + 1);
    out.push_str(&format!("\"ok\": {},\n", rec.ok));
    push_indent(out, level + 1);
    out.push_str(&format!("\"dropped_spans\": {},\n", rec.dropped));
    push_indent(out, level + 1);
    if roots.is_empty() {
        out.push_str("\"spans\": []\n");
    } else {
        out.push_str("\"spans\": [\n");
        for (i, &r) in roots.iter().enumerate() {
            write_span(out, rec, &children, r, level + 2);
            out.push_str(if i + 1 < roots.len() { ",\n" } else { "\n" });
        }
        push_indent(out, level + 1);
        out.push_str("]\n");
    }
    push_indent(out, level);
    out.push('}');
}

fn write_span(out: &mut String, rec: &TraceRecord, children: &[Vec<usize>], idx: usize, level: usize) {
    let s = &rec.spans[idx];
    push_indent(out, level);
    out.push_str("{\n");
    push_indent(out, level + 1);
    out.push_str("\"stage\": ");
    push_json_str(out, &s.stage);
    out.push_str(",\n");
    push_indent(out, level + 1);
    out.push_str(&format!("\"start_ns\": {},\n", s.start_ns));
    push_indent(out, level + 1);
    out.push_str(&format!("\"elapsed_ns\": {},\n", s.end_ns.saturating_sub(s.start_ns)));
    push_indent(out, level + 1);
    let kids = &children[idx];
    if kids.is_empty() {
        out.push_str("\"children\": []\n");
    } else {
        out.push_str("\"children\": [\n");
        for (i, &k) in kids.iter().enumerate() {
            write_span(out, rec, children, k, level + 2);
            out.push_str(if i + 1 < kids.len() { ",\n" } else { "\n" });
        }
        push_indent(out, level + 1);
        out.push_str("]\n");
    }
    push_indent(out, level);
    out.push('}');
}

// -------------------------------------------------------------------- store

/// Bounded ring of finished traces: pushing past capacity evicts the
/// oldest. Shared across connection threads behind one mutex — traces
/// finish at request granularity, so contention is negligible next to
/// the request itself.
pub struct TraceStore {
    cap: usize,
    inner: Mutex<VecDeque<TraceRecord>>,
}

impl TraceStore {
    pub fn new(cap: usize) -> Self {
        Self { cap: cap.max(1), inner: Mutex::new(VecDeque::new()) }
    }

    fn locked(&self) -> std::sync::MutexGuard<'_, VecDeque<TraceRecord>> {
        // Same poison-recovery stance as the server state lock: every
        // mutation leaves the deque structurally whole.
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn len(&self) -> usize {
        self.locked().len()
    }

    pub fn is_empty(&self) -> bool {
        self.locked().is_empty()
    }

    pub fn push(&self, rec: TraceRecord) {
        let mut q = self.locked();
        if q.len() == self.cap {
            q.pop_front();
        }
        q.push_back(rec);
    }

    /// Newest-first, at most `limit` records.
    pub fn recent(&self, limit: usize) -> Vec<TraceRecord> {
        self.locked().iter().rev().take(limit).cloned().collect()
    }

    pub fn find(&self, trace_id: &[u8; 16]) -> Option<TraceRecord> {
        self.locked().iter().rev().find(|r| &r.trace_id == trace_id).cloned()
    }
}
