//! The fan-in aggregation tier — `qckm aggregate`.
//!
//! One serving node cannot terminate millions of pusher connections, but
//! the pooled sketch is an associative (sum, count) statistic: pooling a
//! million pushes at the edge and forwarding one merged delta upstream
//! yields *bit-for-bit* the state the root would have reached ingesting
//! every push directly (for ±1 quantized methods the sums are exact small
//! integers, so float addition is order- and grouping-invariant — the
//! same argument as I-2/I-3, now across processes). An aggregator tree of
//! any depth is therefore exact, and `rust/tests/proptests.rs` locks the
//! tree == flat invariant (I-20) over random topologies.
//!
//! [`AggregatorNode`] speaks the same wire protocol as the server:
//!
//! * **push** — authorized, method-checked, encoded through the same
//!   fixed-chunk parallel fold, then merged into the tenant's local
//!   *pending* accumulator. The pusher gets a normal ack; nothing goes
//!   upstream yet.
//! * **delta** — a child aggregator's flush: dedup-gated by the child's
//!   (aggregator id, instance, seq) key exactly like the root (trees
//!   compose), then merged into pending.
//! * **query / snapshot / roll / stats / trace** — refused with a
//!   pointer at the root: the edge holds only an unflushed remainder,
//!   so answering locally would silently serve a sliver of the data.
//!
//! A flusher thread drains pending upstream over [`RetryClient`] when a
//! row threshold or timer fires. Flushes are **at-least-once with an
//! idempotency key** (I-21): each rotation assigns the next `seq` and the
//! frozen `(seq, bytes)` stays *in flight* until the parent acks it —
//! a retried or replayed send re-transmits the same delta, never a
//! re-pooled one under a fresh seq, so the parent either merges it once
//! or recognizes the key and drops it. Shutdown drains synchronously:
//! the ack is written, connections are joined, then every tenant's
//! pending + in-flight delta is pushed upstream before the process exits.

use crate::linalg::Mat;
use crate::obs::{Counter, Registry};
use crate::parallel::Parallelism;
use crate::server::proto::{self, Response, Scope};
use crate::server::tenants::{constant_time_eq, RateLimit, TokenBucket};
use crate::server::{encode_reply, reply_version, ConnCtx, FrameHandler, Handled};
use crate::server::{RetryClient, RetryPolicy};
use crate::sketch::{PooledSketch, SketchOperator};
use crate::stream::{read_sketch_from, write_sketch_to, ShardRecord, SketchMeta};
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

/// Tuning for one aggregator process.
pub struct AggregatorConfig {
    /// This aggregator's identity upstream — the idempotency-key prefix
    /// and the provenance label its deltas carry. Must be unique among
    /// the parent's children (two nodes sharing an id would dedupe each
    /// other's deltas away).
    pub agg_id: String,
    /// The parent to flush into: a serving node or another aggregator.
    pub upstream: String,
    /// Flush when a tenant's pending pool reaches this many rows.
    pub flush_rows: u64,
    /// Flush every tenant at least this often regardless of rows.
    pub flush_interval: Duration,
    /// Retry policy for the upstream links.
    pub retry: RetryPolicy,
    /// Fault injection: send every delta twice. The duplicate must be
    /// recognized upstream and dropped (`merged = false`) — the CI e2e
    /// runs one edge in this mode to prove the dedup gate end to end.
    pub replay: bool,
    /// Optional per-connection ingest rate limit (same bucket as serve).
    pub rate: Option<RateLimit>,
    pub registry: Arc<Registry>,
    /// Threads for the per-push parallel encode.
    pub threads: Parallelism,
    /// Distinct shard labels accepted per tenant before new ones are
    /// refused (the same I-13 bound the root enforces).
    pub max_shards: usize,
}

/// One tenant hosted at the edge: the operator it encodes pushes with
/// (drawn from the same spec as the root's, so the pools are mergeable)
/// plus its local accumulator state.
pub struct EdgeTenant {
    pub meta: SketchMeta,
    pub op: SketchOperator,
    /// Token pushers must present to this edge (usually the same spec
    /// file as the root tenant, hence the same token — which is also
    /// what the edge presents upstream).
    pub token: Option<String>,
    state: Mutex<TenantState>,
    counters: FaninCounters,
}

struct TenantState {
    /// Rows pooled since the last rotation.
    pending: PooledSketch,
    pending_rows: u64,
    /// Lifetime rows accepted (pushes + child deltas) — the `total_rows`
    /// the acks report.
    total_rows: u64,
    /// Per-shard lifetime rows, capped at `max_shards` labels (I-13).
    shards: BTreeMap<String, u64>,
    /// The rotated-but-unacked delta. At most one: rotation waits for
    /// the ack so a retry always re-sends the identical (seq, bytes).
    inflight: Option<Inflight>,
    /// Last assigned flush sequence number.
    seq: u64,
    /// Child-aggregator dedup gate: agg_id → (instance, last seq), the
    /// same I-21 gate the root keeps — trees compose.
    deltas: BTreeMap<String, (u64, u64)>,
}

struct Inflight {
    seq: u64,
    rows: u64,
    bytes: Vec<u8>,
}

/// The handful of fan-in instruments, pre-labeled per tenant.
struct FaninCounters {
    rows: Arc<Counter>,
    flushes: Arc<Counter>,
    flush_failures: Arc<Counter>,
    replays_sent: Arc<Counter>,
}

impl FaninCounters {
    fn new(reg: &Registry, tenant: &str) -> Self {
        let labels: Vec<(&str, &str)> = if tenant.is_empty() {
            Vec::new()
        } else {
            vec![("tenant", tenant)]
        };
        Self {
            rows: reg.counter(
                "qckm_fanin_rows_total",
                "Rows pooled at this aggregator (pushes and child deltas).",
                &labels,
            ),
            flushes: reg.counter(
                "qckm_fanin_flushes_total",
                "Deltas acked by the upstream parent.",
                &labels,
            ),
            flush_failures: reg.counter(
                "qckm_fanin_flush_failures_total",
                "Flush attempts that exhausted their retries (delta kept in flight).",
                &labels,
            ),
            replays_sent: reg.counter(
                "qckm_fanin_replays_sent_total",
                "Duplicate deltas deliberately sent under --replay fault injection.",
                &labels,
            ),
        }
    }
}

/// The edge node: a [`FrameHandler`] pooling pushes per tenant plus the
/// flusher that forwards merged deltas upstream.
pub struct AggregatorNode {
    cfg: AggregatorConfig,
    /// Startup nonce distinguishing this process's sequence stream from
    /// any predecessor with the same `agg_id`: a restart starts from
    /// empty accumulators, so the parent must accept the fresh stream
    /// rather than dropping everything below the old high-water seq.
    instance: u64,
    tenants: BTreeMap<String, EdgeTenant>,
    /// Flusher wakeup: notified when a tenant crosses `flush_rows` and
    /// on shutdown.
    wake: (Mutex<bool>, Condvar),
    stop: AtomicBool,
}

impl AggregatorNode {
    pub fn new(
        cfg: AggregatorConfig,
        tenants: Vec<(String, SketchMeta, SketchOperator, Option<String>)>,
    ) -> Result<Arc<Self>> {
        if cfg.agg_id.is_empty() || cfg.agg_id.len() > proto::MAX_SHARD_BYTES {
            bail!(
                "aggregator id must be 1..={} bytes (it doubles as the provenance label)",
                proto::MAX_SHARD_BYTES
            );
        }
        if tenants.is_empty() {
            bail!("an aggregator needs at least one tenant");
        }
        let instance = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_nanos() as u64)
            .unwrap_or(1)
            .max(1);
        let mut map = BTreeMap::new();
        for (name, meta, op, token) in tenants {
            if !name.is_empty() {
                crate::server::tenants::validate_tenant_name(&name)?;
            }
            let sketch_len = op.sketch_len();
            let counters = FaninCounters::new(&cfg.registry, &name);
            let prev = map.insert(
                name.clone(),
                EdgeTenant {
                    meta,
                    op,
                    token,
                    state: Mutex::new(TenantState {
                        pending: PooledSketch::new(sketch_len),
                        pending_rows: 0,
                        total_rows: 0,
                        shards: BTreeMap::new(),
                        inflight: None,
                        seq: 0,
                        deltas: BTreeMap::new(),
                    }),
                    counters,
                },
            );
            if prev.is_some() {
                bail!("tenant '{name}' declared twice");
            }
        }
        Ok(Arc::new(Self {
            cfg,
            instance,
            tenants: map,
            wake: (Mutex::new(false), Condvar::new()),
            stop: AtomicBool::new(false),
        }))
    }

    /// Spawn the background flusher. Joined by the caller after the
    /// accept loop returns (the final drain already ran by then, so the
    /// join is immediate).
    pub fn spawn_flusher(self: &Arc<Self>) -> std::thread::JoinHandle<()> {
        let node = Arc::clone(self);
        std::thread::spawn(move || {
            let mut clients: BTreeMap<String, RetryClient> = BTreeMap::new();
            while !node.stop.load(Ordering::SeqCst) {
                {
                    let (lock, cv) = &node.wake;
                    let mut signaled = lock.lock().unwrap_or_else(|e| e.into_inner());
                    if !*signaled {
                        let (guard, _) = cv
                            .wait_timeout(signaled, node.cfg.flush_interval)
                            .unwrap_or_else(|e| e.into_inner());
                        signaled = guard;
                    }
                    *signaled = false;
                }
                if node.stop.load(Ordering::SeqCst) {
                    break;
                }
                node.flush_all(&mut clients);
            }
        })
    }

    fn locked<'a>(&self, t: &'a EdgeTenant) -> std::sync::MutexGuard<'a, TenantState> {
        // Same poisoning stance as the server: state is counters and
        // mergeable pools, never left half-updated across a panic point.
        t.state.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn wake_flusher(&self) {
        let (lock, cv) = &self.wake;
        *lock.lock().unwrap_or_else(|e| e.into_inner()) = true;
        cv.notify_one();
    }

    /// Flush every tenant once: rotate pending into an in-flight delta
    /// where needed, then push each in-flight delta upstream. Failures
    /// keep the delta in flight for the next round.
    fn flush_all(&self, clients: &mut BTreeMap<String, RetryClient>) {
        for (name, tenant) in &self.tenants {
            if let Err(e) = self.flush_tenant(name, tenant, clients) {
                tenant.counters.flush_failures.inc();
                eprintln!("aggregate: flush tenant '{name}': {e:#}");
            }
        }
    }

    fn flush_tenant(
        &self,
        name: &str,
        tenant: &EdgeTenant,
        clients: &mut BTreeMap<String, RetryClient>,
    ) -> Result<()> {
        // Rotate under the lock; send outside it (pushes keep landing in
        // the fresh pending pool while the delta is on the wire).
        let send = {
            let mut st = self.locked(tenant);
            if st.inflight.is_none() && st.pending_rows > 0 {
                let rows = st.pending_rows;
                let sketch_len = st.pending.len();
                let pool = std::mem::replace(&mut st.pending, PooledSketch::new(sketch_len));
                st.pending_rows = 0;
                st.seq += 1;
                let prov = [ShardRecord {
                    label: self.cfg.agg_id.clone(),
                    rows,
                }];
                let mut bytes = Vec::new();
                write_sketch_to(&mut bytes, &tenant.meta, &pool, &prov)?;
                let seq = st.seq;
                st.inflight = Some(Inflight { seq, rows, bytes });
            }
            st.inflight
                .as_ref()
                .map(|i| (i.seq, i.rows, i.bytes.clone()))
        };
        let Some((seq, rows, bytes)) = send else {
            return Ok(());
        };
        if !clients.contains_key(name) {
            let mut c = RetryClient::connect(&self.cfg.upstream, "", self.cfg.retry.clone())
                .with_context(|| format!("connect upstream {}", self.cfg.upstream))?;
            let token = tenant.token.as_deref().unwrap_or("");
            if !name.is_empty() || !token.is_empty() {
                c.set_scope(name, token);
            }
            clients.insert(name.to_string(), c);
        }
        let client = clients.get_mut(name).expect("just inserted");
        let (merged, _) = client.delta(&self.cfg.agg_id, self.instance, seq, &bytes)?;
        if self.cfg.replay {
            // Deliberate duplicate: the parent must recognize the key and
            // drop it. A parent that merged it twice would double-count —
            // the aggregator e2e runs one edge in this mode to prove it
            // cannot.
            let (again, _) = client.delta(&self.cfg.agg_id, self.instance, seq, &bytes)?;
            tenant.counters.replays_sent.inc();
            if again {
                bail!("upstream merged a replayed delta (seq {seq}) — dedup gate broken");
            }
        }
        let mut st = self.locked(tenant);
        if st.inflight.as_ref().map(|i| i.seq) == Some(seq) {
            st.inflight = None;
        }
        drop(st);
        tenant.counters.flushes.inc();
        if !merged {
            // The parent had already seen this key (an earlier send's ack
            // was lost). The rows are safe upstream; nothing to redo.
            eprintln!("aggregate: tenant '{name}' delta seq {seq} was a recognized replay");
        } else {
            eprintln!("aggregate: tenant '{name}' flushed {rows} row(s) upstream (seq {seq})");
        }
        Ok(())
    }

    fn resolve(&self, scope: &Scope) -> Result<&EdgeTenant> {
        match self.tenants.get(&scope.tenant) {
            Some(t) => Ok(t),
            None if scope.tenant.is_empty() => {
                bail!("this aggregator hosts only named tenants; address one with --tenant")
            }
            None => bail!("unknown tenant '{}'", scope.tenant),
        }
    }

    fn authorize(tenant: &EdgeTenant, scope: &Scope) -> Result<()> {
        if let Some(expected) = &tenant.token {
            if !constant_time_eq(expected.as_bytes(), scope.token.as_bytes()) {
                bail!("auth failed (bad or missing token)");
            }
        }
        Ok(())
    }

    fn dispatch(&self, req: proto::Request) -> Result<Response> {
        match req {
            proto::Request::Push {
                scope,
                shard,
                method,
                dim,
                data,
                trace: _,
            } => {
                let tenant = self.resolve(&scope)?;
                Self::authorize(tenant, &scope)?;
                if !method.is_empty() && method != tenant.meta.method {
                    bail!(
                        "method mismatch: client declared '{method}', aggregator pools '{}'",
                        tenant.meta.method
                    );
                }
                if shard.is_empty() || shard.len() > proto::MAX_SHARD_BYTES {
                    bail!("invalid shard label ({} bytes)", shard.len());
                }
                if dim as usize != tenant.op.dim() {
                    bail!("dimension mismatch: push dim {dim}, operator dim {}", tenant.op.dim());
                }
                let rows = data.len() / dim as usize;
                if rows == 0 {
                    bail!("push carries zero rows");
                }
                let batch = Mat::from_vec(rows, dim as usize, data);
                // Encode outside the tenant lock — the exact same
                // fixed-chunk fold as the root, so edge pooling changes
                // nothing bit-wise (I-20).
                let mut partial = PooledSketch::new(tenant.op.sketch_len());
                tenant.op.sketch_into_par(&batch, &mut partial, &self.cfg.threads);
                let (shard_rows, total_rows, full) = {
                    let mut st = self.locked(tenant);
                    if !st.shards.contains_key(&shard) && st.shards.len() >= self.cfg.max_shards {
                        bail!(
                            "shard limit reached ({} labels); reuse an existing label",
                            self.cfg.max_shards
                        );
                    }
                    st.pending.merge(&partial);
                    st.pending_rows += rows as u64;
                    st.total_rows += rows as u64;
                    let entry = st.shards.entry(shard).or_insert(0);
                    *entry += rows as u64;
                    (*entry, st.total_rows, st.pending_rows >= self.cfg.flush_rows)
                };
                tenant.counters.rows.add(rows as u64);
                if full {
                    self.wake_flusher();
                }
                Ok(Response::PushAck {
                    shard_rows,
                    total_rows,
                })
            }
            proto::Request::Delta {
                scope,
                agg_id,
                instance,
                seq,
                sketch,
                trace: _,
            } => {
                let tenant = self.resolve(&scope)?;
                Self::authorize(tenant, &scope)?;
                // Decode + validate outside the lock, like the root.
                let (meta, pool, _prov) = read_sketch_from(&mut &sketch[..], "delta")?;
                tenant.meta.ensure_mergeable(&meta)?;
                let rows = pool.count();
                let (merged, total_rows, full) = {
                    let mut st = self.locked(tenant);
                    let replay = match st.deltas.get(&agg_id) {
                        Some(&(inst, last)) => inst == instance && seq <= last,
                        None => false,
                    };
                    if replay {
                        (false, st.total_rows, false)
                    } else {
                        st.pending.merge(&pool);
                        st.pending_rows += rows;
                        st.total_rows += rows;
                        st.deltas.insert(agg_id, (instance, seq));
                        (true, st.total_rows, st.pending_rows >= self.cfg.flush_rows)
                    }
                };
                if merged {
                    tenant.counters.rows.add(rows);
                }
                if full {
                    self.wake_flusher();
                }
                Ok(Response::DeltaAck {
                    merged,
                    rows_total: total_rows,
                })
            }
            proto::Request::Metrics => Ok(Response::Metrics(self.cfg.registry.render())),
            proto::Request::Shutdown => unreachable!("handled before dispatch"),
            other => bail!(
                "this node is a fan-in aggregator; it only pools pushes and deltas — \
                 send '{}' to the root server",
                other.verb()
            ),
        }
    }
}

impl FrameHandler for AggregatorNode {
    fn new_conn(&self) -> ConnCtx {
        ConnCtx {
            bucket: self
                .cfg
                .rate
                .map(|limit| TokenBucket::new(limit, self.cfg.registry.now_ns())),
        }
    }

    fn handle(&self, conn: &mut ConnCtx, payload: &[u8]) -> Handled {
        if proto::payload_is_ingest(payload) {
            if let Some(bucket) = conn.bucket.as_mut() {
                if let Err(retry_after_ms) = bucket.try_take(self.cfg.registry.now_ns()) {
                    let resp = Response::Busy {
                        retry_after_ms,
                        message: "per-connection ingest rate limit".to_string(),
                    };
                    return Handled::Reply(encode_reply(&resp, reply_version(payload)));
                }
            }
        }
        let version = reply_version(payload);
        match proto::decode_request_v(payload) {
            Err(e) => Handled::Reply(encode_reply(&Response::Error(format!("{e:#}")), version)),
            Ok((_, proto::Request::Shutdown)) => {
                Handled::Shutdown(encode_reply(&Response::ShutdownAck, version))
            }
            Ok((_, req)) => {
                let resp = self
                    .dispatch(req)
                    .unwrap_or_else(|e| Response::Error(format!("{e:#}")));
                Handled::Reply(encode_reply(&resp, version))
            }
        }
    }

    /// The drain: the accept loop has stopped and every connection is
    /// joined, so no new rows can arrive. Push everything upstream, then
    /// release the flusher thread.
    fn drained(&self) {
        self.stop.store(true, Ordering::SeqCst);
        self.wake_flusher();
        let mut clients = BTreeMap::new();
        self.flush_all(&mut clients);
        let stranded: u64 = self
            .tenants
            .values()
            .map(|t| {
                let st = self.locked(t);
                st.pending_rows + st.inflight.as_ref().map(|i| i.rows).unwrap_or(0)
            })
            .sum();
        if stranded > 0 {
            eprintln!(
                "aggregate: WARNING — {stranded} row(s) could not be flushed upstream and are lost"
            );
        }
    }
}

/// Serve an aggregator on `listener` until a shutdown request arrives,
/// draining pending deltas upstream before returning. Returns the number
/// of connections served.
pub fn serve_aggregator(
    listener: std::net::TcpListener,
    node: Arc<AggregatorNode>,
) -> Result<u64> {
    let flusher = node.spawn_flusher();
    let served = crate::server::serve_handler(listener, Arc::clone(&node))?;
    let _ = flusher.join();
    Ok(served)
}

#[cfg(test)]
mod tests;
