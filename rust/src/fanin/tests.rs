//! Unit tests for the fan-in aggregator: local pooling and acks, tenant
//! auth, the child-delta dedup gate (trees compose), verb refusals,
//! defensive frame handling with rate limiting, at-least-once rotation
//! under a dead upstream, and one in-process socket test proving a flush
//! (with deliberate replay injection) lands rows upstream bit-for-bit.

use super::*;
use crate::frequency::FrequencyLaw;
use crate::method::MethodSpec;
use crate::obs::{FakeClock, MonotonicClock, Registry};
use crate::rng::Rng;
use crate::server::{serve, ServiceConfig, SketchService};
use crate::stream::draw_operator;

const DIM: usize = 4;
const M: usize = 24;
const SIGMA: f64 = 1.1;
const SEED: u64 = 5;

fn op_and_meta() -> (SketchMeta, SketchOperator) {
    let qckm = MethodSpec::parse("qckm").unwrap();
    let op = draw_operator(&qckm, FrequencyLaw::AdaptedRadius, M, DIM, SIGMA, SEED);
    let meta = SketchMeta::for_operator(&op, &qckm, SEED);
    (meta, op)
}

fn edge(
    tenant: &str,
    token: Option<&str>,
    upstream: &str,
    replay: bool,
    rate: Option<RateLimit>,
    registry: Arc<Registry>,
) -> Arc<AggregatorNode> {
    let (meta, op) = op_and_meta();
    AggregatorNode::new(
        AggregatorConfig {
            agg_id: "edge-1".to_string(),
            upstream: upstream.to_string(),
            flush_rows: 1_000_000,
            flush_interval: Duration::from_secs(3600),
            retry: RetryPolicy {
                attempts: 0,
                base: Duration::from_millis(5),
                cap: Duration::from_millis(20),
            },
            replay,
            rate,
            registry,
            threads: Parallelism::serial(),
            max_shards: 4,
        },
        vec![(tenant.to_string(), meta, op, token.map(str::to_string))],
    )
    .unwrap()
}

fn test_registry() -> Arc<Registry> {
    Arc::new(Registry::new(Arc::new(MonotonicClock::new())))
}

fn rows(n: usize, seed: u64) -> Vec<f64> {
    let mut rng = Rng::new(seed);
    (0..n * DIM).map(|_| rng.gaussian()).collect()
}

fn push(tenant: &str, token: &str, shard: &str, n: usize, seed: u64) -> proto::Request {
    proto::Request::Push {
        scope: Scope::new(tenant, token),
        shard: shard.to_string(),
        method: String::new(),
        dim: DIM as u32,
        data: rows(n, seed),
        trace: None,
    }
}

/// A well-formed `.qsk` delta payload: `n` rows pooled under `label`.
fn delta_bytes(n: usize, seed: u64, label: &str) -> Vec<u8> {
    let (meta, op) = op_and_meta();
    let batch = Mat::from_vec(n, DIM, rows(n, seed));
    let mut pool = PooledSketch::new(op.sketch_len());
    op.sketch_into_par(&batch, &mut pool, &Parallelism::serial());
    let mut bytes = Vec::new();
    write_sketch_to(
        &mut bytes,
        &meta,
        &pool,
        &[ShardRecord { label: label.to_string(), rows: n as u64 }],
    )
    .unwrap();
    bytes
}

// --------------------------------------------------------------- dispatch

#[test]
fn push_pools_locally_and_acks_shard_and_total_rows() {
    let node = edge("acme", None, "127.0.0.1:1", false, None, test_registry());
    match node.dispatch(push("acme", "", "s1", 3, 10)).unwrap() {
        Response::PushAck { shard_rows, total_rows } => {
            assert_eq!((shard_rows, total_rows), (3, 3));
        }
        other => panic!("expected PushAck, got {other:?}"),
    }
    match node.dispatch(push("acme", "", "s2", 2, 11)).unwrap() {
        Response::PushAck { shard_rows, total_rows } => {
            assert_eq!((shard_rows, total_rows), (2, 5));
        }
        other => panic!("expected PushAck, got {other:?}"),
    }
    let tenant = node.tenants.get("acme").unwrap();
    let st = node.locked(tenant);
    assert_eq!(st.pending_rows, 5);
    assert_eq!(st.pending.count(), 5);
    assert!(st.inflight.is_none());
}

#[test]
fn push_refusals_cover_tenant_method_dim_and_shard_cap() {
    let node = edge("acme", None, "127.0.0.1:1", false, None, test_registry());
    // Unknown tenant; unscoped push against a named-tenant-only node.
    let err = node.dispatch(push("ghost", "", "s", 1, 1)).unwrap_err();
    assert!(err.to_string().contains("unknown tenant"), "{err:#}");
    let err = node.dispatch(push("", "", "s", 1, 1)).unwrap_err();
    assert!(err.to_string().contains("named tenants"), "{err:#}");
    // Declared method must match the tenant's operator.
    let mut req = push("acme", "", "s", 1, 1);
    if let proto::Request::Push { method, .. } = &mut req {
        *method = "modulo".to_string();
    }
    let err = node.dispatch(req).unwrap_err();
    assert!(err.to_string().contains("method mismatch"), "{err:#}");
    // Dimension must match.
    let bad_dim = proto::Request::Push {
        scope: Scope::new("acme", ""),
        shard: "s".to_string(),
        method: String::new(),
        dim: DIM as u32 + 1,
        data: vec![0.0; DIM + 1],
        trace: None,
    };
    let err = node.dispatch(bad_dim).unwrap_err();
    assert!(err.to_string().contains("dimension mismatch"), "{err:#}");
    // The I-13 shard-label cap (max_shards = 4 in the fixture).
    for i in 0..4 {
        node.dispatch(push("acme", "", &format!("s{i}"), 1, i as u64)).unwrap();
    }
    let err = node.dispatch(push("acme", "", "s5", 1, 9)).unwrap_err();
    assert!(err.to_string().contains("shard limit"), "{err:#}");
    // Known labels still pass after the cap is reached.
    node.dispatch(push("acme", "", "s0", 1, 12)).unwrap();
}

#[test]
fn push_requires_the_tenant_token() {
    let node = edge("acme", Some("hunter2"), "127.0.0.1:1", false, None, test_registry());
    let err = node.dispatch(push("acme", "", "s", 1, 1)).unwrap_err();
    assert!(err.to_string().contains("auth failed"), "{err:#}");
    let err = node.dispatch(push("acme", "hunter3", "s", 1, 1)).unwrap_err();
    assert!(err.to_string().contains("auth failed"), "{err:#}");
    node.dispatch(push("acme", "hunter2", "s", 1, 1)).unwrap();
}

#[test]
fn child_delta_dedup_gate_matches_root_semantics() {
    let node = edge("acme", None, "127.0.0.1:1", false, None, test_registry());
    let bytes = delta_bytes(4, 20, "child-a");
    let delta = |instance: u64, seq: u64, b: &[u8]| proto::Request::Delta {
        scope: Scope::new("acme", ""),
        agg_id: "child-1".to_string(),
        instance,
        seq,
        sketch: b.to_vec(),
        trace: None,
    };
    let ack = |r: Response| match r {
        Response::DeltaAck { merged, rows_total } => (merged, rows_total),
        other => panic!("expected DeltaAck, got {other:?}"),
    };
    assert_eq!(ack(node.dispatch(delta(7, 1, &bytes)).unwrap()), (true, 4));
    // Replay of an admitted seq: dropped idempotently (I-21).
    assert_eq!(ack(node.dispatch(delta(7, 1, &bytes)).unwrap()), (false, 4));
    // The next seq merges.
    let bytes2 = delta_bytes(2, 21, "child-a");
    assert_eq!(ack(node.dispatch(delta(7, 2, &bytes2)).unwrap()), (true, 6));
    // A restarted child (new instance) resets the gate: seq 1 is new data.
    assert_eq!(ack(node.dispatch(delta(8, 1, &bytes)).unwrap()), (true, 10));
    // A corrupt payload is an error, and merges nothing.
    let err = node.dispatch(delta(8, 2, b"garbage")).unwrap_err();
    assert!(err.to_string().contains("delta"), "{err:#}");
    let tenant = node.tenants.get("acme").unwrap();
    assert_eq!(node.locked(tenant).total_rows, 10);
}

#[test]
fn non_ingest_verbs_are_refused_with_a_pointer_at_the_root() {
    let node = edge("acme", None, "127.0.0.1:1", false, None, test_registry());
    for req in [
        proto::Request::Query {
            scope: Scope::new("acme", ""),
            spec: crate::server::QuerySpec {
                k: 2,
                window: 0,
                replicates: 1,
                seed: None,
                lo: -1.0,
                hi: 1.0,
                decoder: String::new(),
            },
            method: String::new(),
            trace: None,
        },
        proto::Request::Snapshot {
            scope: Scope::new("acme", ""),
            window: 0,
            method: String::new(),
            trace: None,
        },
        proto::Request::Roll { scope: Scope::new("acme", "") },
        proto::Request::Stats { scope: Scope::new("acme", "") },
        proto::Request::Trace { scope: Scope::new("acme", ""), id: None, limit: 0 },
    ] {
        let verb = req.verb();
        let err = node.dispatch(req).unwrap_err();
        assert!(
            err.to_string().contains("root server"),
            "verb {verb}: {err:#}"
        );
    }
}

// ----------------------------------------------------------------- frames

#[test]
fn handle_answers_garbage_with_an_error_and_rate_limits_ingest() {
    let clock = Arc::new(FakeClock::new());
    let registry = Arc::new(Registry::new(clock.clone()));
    let limit = RateLimit { rate: 10.0, burst: 1.0 };
    let node = edge("acme", None, "127.0.0.1:1", false, Some(limit), registry);
    let mut conn = node.new_conn();
    assert!(conn.bucket.is_some());
    // Garbage never panics — it answers a decodable error frame.
    match node.handle(&mut conn, &[0xFF, 0xFE, 0xFD]) {
        Handled::Reply(bytes) => match proto::decode_response(&bytes).unwrap() {
            Response::Error(_) => {}
            other => panic!("expected Error, got {other:?}"),
        },
        Handled::Shutdown(_) => panic!("garbage must not shut the node down"),
    }
    // Burst 1: the first push is admitted, the second answers Busy with a
    // retry hint; after the hinted wait the bucket has refilled.
    let frame = proto::encode_request(&push("acme", "", "s", 1, 1));
    match node.handle(&mut conn, &frame) {
        Handled::Reply(bytes) => match proto::decode_response(&bytes).unwrap() {
            Response::PushAck { .. } => {}
            other => panic!("expected PushAck, got {other:?}"),
        },
        Handled::Shutdown(_) => unreachable!(),
    }
    let retry_ms = match node.handle(&mut conn, &frame) {
        Handled::Reply(bytes) => match proto::decode_response(&bytes).unwrap() {
            Response::Busy { retry_after_ms, .. } => retry_after_ms,
            other => panic!("expected Busy, got {other:?}"),
        },
        Handled::Shutdown(_) => unreachable!(),
    };
    assert!(retry_ms >= 1);
    clock.advance_ns(retry_ms * 1_000_000);
    match node.handle(&mut conn, &frame) {
        Handled::Reply(bytes) => match proto::decode_response(&bytes).unwrap() {
            Response::PushAck { .. } => {}
            other => panic!("expected PushAck after refill, got {other:?}"),
        },
        Handled::Shutdown(_) => unreachable!(),
    }
    // A shutdown frame reaches the Shutdown path, not a reply.
    let shutdown = proto::encode_request(&proto::Request::Shutdown);
    assert!(matches!(node.handle(&mut conn, &shutdown), Handled::Shutdown(_)));
}

// --------------------------------------------------------------- rotation

#[test]
fn rotation_freezes_one_delta_and_survives_a_dead_upstream() {
    // Port 1 refuses connections: every flush fails after rotation.
    let node = edge("acme", None, "127.0.0.1:1", false, None, test_registry());
    node.dispatch(push("acme", "", "s", 3, 30)).unwrap();
    let mut clients = BTreeMap::new();
    let tenant = node.tenants.get("acme").unwrap();
    assert!(node.flush_tenant("acme", tenant, &mut clients).is_err());
    {
        let st = node.locked(tenant);
        let inflight = st.inflight.as_ref().expect("delta frozen in flight");
        assert_eq!((inflight.seq, inflight.rows), (1, 3));
        assert_eq!(st.pending_rows, 0, "rotation drained pending");
    }
    // More rows land in the fresh pending pool; a second failed flush
    // re-sends the SAME frozen delta — it must not rotate a second one
    // on top (at-least-once needs a stable (seq, bytes) pair).
    node.dispatch(push("acme", "", "s", 2, 31)).unwrap();
    assert!(node.flush_tenant("acme", tenant, &mut clients).is_err());
    let st = node.locked(tenant);
    assert_eq!(st.inflight.as_ref().map(|i| (i.seq, i.rows)), Some((1, 3)));
    assert_eq!((st.pending_rows, st.seq), (2, 1));
}

// ----------------------------------------------------------------- socket

/// One real upstream server: a flush (run in `--replay` fault-injection
/// mode, so every delta is sent twice) lands the edge's pooled rows, the
/// duplicate is deduped, and the upstream pool is bit-for-bit the offline
/// pool of the same rows (I-20/I-21 at module scope).
#[test]
fn flush_delivers_rows_upstream_exactly_once_and_bit_exact() {
    let (meta, op) = op_and_meta();
    let service = Arc::new(SketchService::new(op, meta, ServiceConfig::default()));
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let server = std::thread::spawn(move || serve(listener, service).unwrap());

    // A single-tenant edge (empty tenant name), replay injection on.
    let node = edge("", None, &addr, true, None, test_registry());
    node.dispatch(push("", "", "s", 3, 40)).unwrap();
    let mut clients = BTreeMap::new();
    let tenant = node.tenants.get("").unwrap();
    node.flush_tenant("", tenant, &mut clients).unwrap();
    {
        let st = node.locked(tenant);
        assert!(st.inflight.is_none(), "acked delta cleared");
        assert_eq!(st.pending_rows, 0);
    }
    // Remainder rows drain on shutdown.
    node.dispatch(push("", "", "s", 2, 41)).unwrap();
    node.drained();

    let mut client = crate::server::Client::connect(&addr).unwrap();
    let stats = client.stats().unwrap();
    assert_eq!(stats.rows_total, 5, "replayed deltas must not double-count");
    assert_eq!(stats.shards, vec![("edge-1".to_string(), 5)]);

    // Bit-exactness: the upstream snapshot pools the same bits as an
    // offline encode of the identical rows.
    let snapshot = client.snapshot(0).unwrap();
    let (_, upstream_pool, _) = read_sketch_from(&mut &snapshot[..], "snapshot").unwrap();
    let (_, op2) = op_and_meta();
    let mut offline = PooledSketch::new(op2.sketch_len());
    for seed in [40u64, 41] {
        let n = if seed == 40 { 3 } else { 2 };
        let batch = Mat::from_vec(n, DIM, rows(n, seed));
        op2.sketch_into_par(&batch, &mut offline, &Parallelism::serial());
    }
    assert_eq!(upstream_pool.count(), offline.count());
    assert_eq!(upstream_pool.sum(), offline.sum(), "tree != flat — I-20 broken");

    client.shutdown().unwrap();
    server.join().unwrap();
}
