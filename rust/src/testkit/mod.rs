//! A miniature property-based testing harness (no `proptest` offline).
//!
//! Provides the 20% of proptest this repo needs: seeded random generators,
//! a case runner that reports the failing seed, and greedy input shrinking
//! for a couple of common shapes. Deterministic: every failure message
//! includes the case seed so `QCKM_PROP_SEED=<seed>` reproduces it.
//!
//! ```no_run
//! use qckm::testkit::{property, Gen};
//! property("sum is commutative", 100, |g| {
//!     let a = g.f64_in(-1e6, 1e6);
//!     let b = g.f64_in(-1e6, 1e6);
//!     assert_eq!(a + b, b + a);
//! });
//! ```

pub mod fuzz;

use crate::rng::Rng;

/// Per-case random input generator.
pub struct Gen {
    rng: Rng,
    /// The case seed (for reproduction messages).
    pub seed: u64,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Self {
            rng: Rng::new(seed),
            seed,
        }
    }

    pub fn usize_in(&mut self, lo: usize, hi_inclusive: usize) -> usize {
        assert!(hi_inclusive >= lo);
        lo + self.rng.next_below((hi_inclusive - lo + 1) as u64) as usize
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.uniform(lo, hi)
    }

    pub fn gaussian(&mut self) -> f64 {
        self.rng.gaussian()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_f64() < 0.5
    }

    pub fn vec_f64(&mut self, len: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..len).map(|_| self.f64_in(lo, hi)).collect()
    }

    pub fn vec_gaussian(&mut self, len: usize) -> Vec<f64> {
        (0..len).map(|_| self.gaussian()).collect()
    }

    /// Borrow the underlying RNG for richer draws.
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Run `cases` random cases of `prop`. Panics (with the case seed) on the
/// first failing case. Honors `QCKM_PROP_SEED` to re-run one exact case.
pub fn property(name: &str, cases: usize, mut prop: impl FnMut(&mut Gen)) {
    if let Ok(seed_str) = std::env::var("QCKM_PROP_SEED") {
        let seed: u64 = seed_str.parse().expect("QCKM_PROP_SEED must be a u64");
        let mut g = Gen::new(seed);
        prop(&mut g);
        return;
    }
    // Derive case seeds from the property name so adding properties to a
    // file doesn't shift other properties' cases.
    let name_seed = name
        .bytes()
        .fold(0xcbf2_9ce4_8422_2325u64, |h, b| {
            (h ^ b as u64).wrapping_mul(0x1000_0000_01b3)
        });
    for case in 0..cases {
        let seed = name_seed.wrapping_add(0x9E37_79B9_7F4A_7C15u64.wrapping_mul(case as u64 + 1));
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut g = Gen::new(seed);
            prop(&mut g);
        }));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| payload.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            panic!(
                "property '{name}' failed on case {case} (QCKM_PROP_SEED={seed}): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generators_respect_ranges() {
        let mut g = Gen::new(1);
        for _ in 0..1000 {
            let u = g.usize_in(3, 7);
            assert!((3..=7).contains(&u));
            let f = g.f64_in(-2.0, 2.0);
            assert!((-2.0..2.0).contains(&f));
        }
        assert_eq!(g.vec_f64(5, 0.0, 1.0).len(), 5);
        assert_eq!(g.vec_gaussian(4).len(), 4);
        let _ = g.bool();
        let _ = g.rng().next_u64();
    }

    #[test]
    fn property_passes_good_props() {
        property("addition commutes", 50, |g| {
            let a = g.f64_in(-10.0, 10.0);
            let b = g.f64_in(-10.0, 10.0);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    fn property_reports_seed_on_failure() {
        let result = std::panic::catch_unwind(|| {
            property("always fails", 3, |_g| {
                panic!("boom");
            });
        });
        let err = result.unwrap_err();
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("QCKM_PROP_SEED="), "message: {msg}");
        assert!(msg.contains("boom"));
    }

    #[test]
    fn case_seeds_differ() {
        let mut seen = std::collections::HashSet::new();
        property("records seeds", 20, |g| {
            // property() must hand each case distinct randomness.
            seen.insert(g.seed);
        });
        // (The closure runs 20 times; sets dedupe.)
        assert!(seen.len() >= 19);
    }
}
