//! Deterministic structured-fuzz mutation engine for the untrusted-input
//! decoders (`rust/tests/fuzz_corpus.rs` is the driver; `INVARIANTS.md`
//! catalogs what it locks).
//!
//! Not coverage-guided fuzzing — a seeded corpus mutator: start from
//! *valid* encodings (protocol frames, `.qsk` streams, spec strings) and
//! apply the corruption classes a hostile or broken peer actually
//! produces: bit flips, byte stomps, truncations, garbage extensions,
//! length-field inflation, header/tag scrambling, zero runs, and splices
//! of two valid inputs. Everything derives from one [`crate::rng::Rng`]
//! seed, so a CI failure reproduces exactly with `QCKM_FUZZ_SEED`.

use crate::rng::Rng;

/// Interesting little-endian values for length-field inflation: cap edges,
/// off-by-ones, and all-ones, for both 32- and 64-bit fields. These are
/// the values bounds checks get wrong.
const EVIL_LENGTHS: [u64; 10] = [
    0,
    1,
    u32::MAX as u64,
    u32::MAX as u64 - 1,
    u64::MAX,
    u64::MAX - 1,
    1 << 28,       // MAX_FRAME_BYTES
    (1 << 28) + 1, // just over it
    (1 << 24) + 1, // just over the .qsk m/d plausibility bound
    1 << 31,
];

/// Seeded mutation engine. One instance drives one fuzz target; every draw
/// comes from the seed handed to [`Mutator::new`].
pub struct Mutator {
    rng: Rng,
}

impl Mutator {
    pub fn new(seed: u64) -> Self {
        Self { rng: Rng::new(seed) }
    }

    /// Produce one mutated input: clone a random corpus entry and apply
    /// 1–4 random corruption operators to it.
    pub fn mutate(&mut self, corpus: &[Vec<u8>]) -> Vec<u8> {
        assert!(!corpus.is_empty(), "mutate needs a non-empty corpus");
        let pick = self.rng.next_below(corpus.len() as u64) as usize;
        let mut buf = corpus[pick].clone();
        let ops = 1 + self.rng.next_below(4);
        for _ in 0..ops {
            self.apply_one(&mut buf, corpus);
        }
        buf
    }

    fn apply_one(&mut self, buf: &mut Vec<u8>, corpus: &[Vec<u8>]) {
        match self.rng.next_below(8) {
            // Bit flip: the single-event corruption.
            0 => {
                if !buf.is_empty() {
                    let at = self.rng.next_below(buf.len() as u64) as usize;
                    buf[at] ^= 1 << self.rng.next_below(8);
                }
            }
            // Byte stomp.
            1 => {
                if !buf.is_empty() {
                    let at = self.rng.next_below(buf.len() as u64) as usize;
                    buf[at] = self.rng.next_u64() as u8;
                }
            }
            // Truncation: a peer dying mid-write.
            2 => {
                if !buf.is_empty() {
                    let keep = self.rng.next_below(buf.len() as u64) as usize;
                    buf.truncate(keep);
                }
            }
            // Garbage extension: trailing bytes after a valid message.
            3 => {
                let extra = 1 + self.rng.next_below(64) as usize;
                for _ in 0..extra {
                    buf.push(self.rng.next_u64() as u8);
                }
            }
            // Length-field inflation: stomp an EVIL_LENGTHS value (LE,
            // 4 or 8 bytes wide) at a random offset — this is the op that
            // turns "reads a length" into "allocates 16 EiB" in decoders
            // that don't bounds-check before allocating.
            4 => {
                if !buf.is_empty() {
                    let val = EVIL_LENGTHS[self.rng.next_below(EVIL_LENGTHS.len() as u64) as usize];
                    let width = if self.rng.next_below(2) == 0 { 4 } else { 8 };
                    let at = self.rng.next_below(buf.len() as u64) as usize;
                    for (i, b) in val.to_le_bytes().iter().take(width).enumerate() {
                        if at + i < buf.len() {
                            buf[at + i] = *b;
                        }
                    }
                }
            }
            // Zero run: a hole from a half-initialized buffer.
            5 => {
                if !buf.is_empty() {
                    let at = self.rng.next_below(buf.len() as u64) as usize;
                    let run = (1 + self.rng.next_below(16) as usize).min(buf.len() - at);
                    buf[at..at + run].fill(0);
                }
            }
            // Head scramble: magic / version / tag bytes live in the
            // first few bytes of every format here.
            6 => {
                let head = buf.len().min(8);
                if head > 0 {
                    let at = self.rng.next_below(head as u64) as usize;
                    buf[at] = self.rng.next_u64() as u8;
                }
            }
            // Splice: the head of one valid input onto the tail of
            // another — internally consistent pieces, inconsistent whole.
            _ => {
                let other = &corpus[self.rng.next_below(corpus.len() as u64) as usize];
                if !buf.is_empty() && !other.is_empty() {
                    let cut_a = self.rng.next_below(buf.len() as u64 + 1) as usize;
                    let cut_b = self.rng.next_below(other.len() as u64) as usize;
                    buf.truncate(cut_a);
                    buf.extend_from_slice(&other[cut_b..]);
                }
            }
        }
    }

    /// A junk string for grammar fuzzing (spec parsers): ASCII soup biased
    /// toward the grammar's own separators, with occasional multi-byte
    /// UTF-8 and long repeats. Always valid UTF-8, at most `max_chars`
    /// chars.
    pub fn junk_string(&mut self, max_chars: usize) -> String {
        const FLAVOR: &[char] = &[
            ':', ',', '=', ':', ',', '=', // double weight on separators
            'a', 'z', 'A', 'Z', '0', '9', '_', '-', '.', '+', ' ', '\t',
            'é', 'λ', '💥',
        ];
        let len = self.rng.next_below(max_chars as u64 + 1) as usize;
        let mut s = String::new();
        for _ in 0..len {
            if self.rng.next_below(16) == 0 {
                // A run of one char — tickles any O(n²) or unbounded
                // accumulation in the parser.
                let c = FLAVOR[self.rng.next_below(FLAVOR.len() as u64) as usize];
                let reps = self.rng.next_below(32) as usize;
                s.extend(std::iter::repeat(c).take(reps));
            } else {
                s.push(FLAVOR[self.rng.next_below(FLAVOR.len() as u64) as usize]);
            }
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn corpus() -> Vec<Vec<u8>> {
        vec![vec![1, 2, 3, 4, 5, 6, 7, 8], vec![9; 32], vec![0xAB]]
    }

    #[test]
    fn same_seed_reproduces_the_same_mutations() {
        let c = corpus();
        let a: Vec<Vec<u8>> = {
            let mut m = Mutator::new(42);
            (0..50).map(|_| m.mutate(&c)).collect()
        };
        let b: Vec<Vec<u8>> = {
            let mut m = Mutator::new(42);
            (0..50).map(|_| m.mutate(&c)).collect()
        };
        assert_eq!(a, b, "mutations must be a pure function of the seed");
        let mut other = Mutator::new(43);
        let differs = (0..50).any(|i| other.mutate(&c) != a[i]);
        assert!(differs, "different seeds should mutate differently");
    }

    #[test]
    fn mutations_actually_mutate() {
        let c = corpus();
        let mut m = Mutator::new(7);
        let changed = (0..100).filter(|_| !c.contains(&m.mutate(&c))).count();
        assert!(changed > 50, "only {changed}/100 mutants differed from the corpus");
    }

    #[test]
    fn mutation_size_stays_bounded() {
        let c = corpus();
        let mut m = Mutator::new(11);
        for _ in 0..1000 {
            let out = m.mutate(&c);
            // Worst case: 4 ops, each a splice (≤ +32) or extension (≤ +64).
            assert!(out.len() <= 32 + 4 * 64, "mutant grew to {} bytes", out.len());
        }
    }

    #[test]
    fn junk_strings_are_bounded_utf8() {
        let mut m = Mutator::new(3);
        for _ in 0..500 {
            let s = m.junk_string(40);
            // chars ≤ 40 plus runs of ≤ 31 extra; bytes ≤ 4× chars.
            assert!(s.chars().count() <= 40 * 32);
            assert!(std::str::from_utf8(s.as_bytes()).is_ok());
        }
    }
}
