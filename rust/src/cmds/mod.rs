//! Per-verb command modules behind the `qckm` dispatcher.
//!
//! `main.rs` is a thin table mapping verb → `cmds::<verb>::run(args)`;
//! every CLI concern lives here. [`common`] holds the plumbing the verbs
//! share — job-config resolution, operator construction, search-box
//! derivation, `.qsk` method checks — so no verb duplicates another's
//! wiring.

pub mod common;

pub mod aggregate;
pub mod cluster;
pub mod ctl;
pub mod decode;
pub mod experiment;
pub mod merge;
pub mod pipeline;
pub mod push;
pub mod query;
pub mod serve;
pub mod sketch;
pub mod snapshot;
