//! `qckm push` — stream a dataset into a serving node's shard, with
//! optional reconnect-and-resend under bounded exponential backoff
//! (`--retry N`) so a server kill-and-restart does not abort the stream.

use super::common::{scope_from, shard_label, TENANT_HELP, TOKEN_HELP};
use anyhow::{bail, Context, Result};
use qckm::cli::CliSpec;
use qckm::linalg::Mat;
use qckm::method::MethodSpec;
use qckm::server::{RetryClient, RetryPolicy};
use qckm::stream;
use std::path::Path;

pub fn run(args: Vec<String>) -> Result<()> {
    let spec = CliSpec::new("qckm push", "stream a dataset into a serving node's shard")
        .opt("addr", "HOST:PORT", None, "server address")
        .opt("data", "FILE", None, "input dataset (.csv, else raw f64 bin)")
        .opt("shard", "NAME", None, "shard label (default: the data file stem)")
        .opt(
            "method",
            "SPEC",
            None,
            "declare the expected method; the server refuses a mismatch",
        )
        .opt("batch", "NUM", Some("4096"), "rows per push message")
        .opt("tenant", "NAME", None, TENANT_HELP)
        .opt("token", "TOKEN", None, TOKEN_HELP)
        .opt(
            "retry",
            "NUM",
            Some("0"),
            "transport-error and rate-limit retries with exponential backoff \
             (0 = fail fast); a re-sent batch may double-count if the \
             failure hit mid-ack",
        )
        .flag(
            "trace",
            "attach a trace context to every batch and print the last \
             batch's server-side span tree (JSON, stderr) on exit",
        );
    let parsed = spec.parse(args)?;
    let addr = parsed.get("addr").context("--addr is required")?;
    let data_path = parsed.get("data").context("--data is required")?;
    let batch = parsed.get_usize("batch")?.unwrap().max(1);
    let shard = shard_label(&parsed, data_path);

    let mut reader = stream::open_dataset(Path::new(data_path))?;
    let dim = reader.dim();
    // Clamp the batch so every push message fits one protocol frame.
    let cap = qckm::server::proto::max_batch_rows(dim);
    let batch = if batch > cap {
        eprintln!("note: --batch {batch} clamped to {cap} rows (frame size cap at dim {dim})");
        cap
    } else {
        batch
    };
    // The declared method is canonicalized locally, so junk fails fast
    // with the registry's valid-family list before any connection.
    let method = match parsed.get("method") {
        Some(m) => MethodSpec::parse(m)?.canonical().to_string(),
        None => String::new(),
    };
    let policy = RetryPolicy {
        attempts: parsed.get_u64("retry")?.unwrap().min(u32::MAX as u64) as u32,
        ..RetryPolicy::default()
    };
    let mut client = RetryClient::connect(addr, &method, policy)?;
    let (tenant, token) = scope_from(&parsed);
    if !tenant.is_empty() || !token.is_empty() {
        client.set_scope(&tenant, &token);
    }
    if parsed.flag("trace") {
        client.enable_tracing();
    }
    let mut pushed = 0u64;
    let mut buf: Vec<f64> = Vec::new();
    let (mut shard_rows, mut total_rows) = (0, 0);
    loop {
        buf.clear();
        let mut rows = 0usize;
        while rows < batch {
            let got = reader.next_block(batch - rows, &mut buf)?;
            if got == 0 {
                break;
            }
            rows += got;
        }
        if rows == 0 {
            break;
        }
        let block = Mat::from_vec(rows, dim, std::mem::take(&mut buf));
        (shard_rows, total_rows) = client.push(&shard, &block)?;
        buf = block.into_vec();
        pushed += rows as u64;
    }
    if pushed == 0 {
        bail!("{data_path}: empty dataset");
    }
    println!(
        "pushed {pushed} rows from {data_path} to shard '{shard}' \
         (shard total {shard_rows}, server total {total_rows})"
    );
    // Surface the retry accounting the client kept (mirrored into the
    // qckm_retry_* registry counters): silent recoveries hide flaky
    // networks, and the double-count caveat in --retry's help only
    // matters when retries actually happened.
    let (attempts, backoff) = client.retry_stats();
    if attempts > 0 {
        eprintln!(
            "retries: {attempts} reconnect attempt(s), {} ms total backoff",
            backoff.as_millis()
        );
    }
    // With --trace every batch carried a context; fetch the last one's
    // server-side span tree so the push's latency breakdown (frame
    // decode / cap check / encode / merge) is visible without a
    // separate `ctl trace` round.
    if parsed.flag("trace") {
        if let Some(id) = client.last_trace_id() {
            eprintln!("{}", client.trace(Some(id), 1)?);
        }
    }
    Ok(())
}
