//! Plumbing shared by the `qckm` command modules: job-config resolution
//! (file + CLI overrides), operator construction, the centroid search-box
//! derivation every decode-side verb uses, declared-method checks against
//! `.qsk` headers, wire-format resolution, and service-client helpers.

use anyhow::{bail, Context, Result};
use qckm::cli::ParsedArgs;
use qckm::config::JobConfig;
use qckm::coordinator::WireFormat;
use qckm::decoder::DecoderSpec;
use qckm::frequency::{DrawnFrequencies, SigmaHeuristic};
use qckm::linalg::{bounding_box, Mat};
use qckm::method::MethodSpec;
use qckm::rng::Rng;
use qckm::sketch::SketchOperator;
use std::path::Path;

/// Shared `--method` help text. The CLI layer needs a `'static` string, so
/// this is a hint only; a bad spec gets the registry's authoritative
/// valid-family list at parse time.
pub const METHOD_HELP: &str = "method spec: ckm | qckm[:bits=B] | triangle | modulo";

/// Shared `--decoder` help text (hint only, same convention as
/// [`METHOD_HELP`]: junk specs get the decoder registry's authoritative
/// list at parse time).
pub const DECODER_HELP: &str =
    "decoder spec: clompr[:restarts=R,replacements=P] | hier[:restarts=R]";

/// Load the job config (file + CLI overrides).
pub fn job_from(args: &ParsedArgs) -> Result<JobConfig> {
    let mut cfg = match args.get("config") {
        Some(path) => {
            let text = std::fs::read_to_string(path).with_context(|| format!("read {path}"))?;
            JobConfig::from_toml_str(&text)?
        }
        None => JobConfig::default(),
    };
    if let Some(m) = args.get_usize("m")? {
        cfg.sketch.num_frequencies = m;
    }
    if let Some(k) = args.get_usize("k")? {
        cfg.decode.k = k;
    }
    if let Some(method) = args.get("method") {
        cfg.sketch.method = MethodSpec::parse(method)?;
    }
    if let Some(decoder) = args.get("decoder") {
        cfg.decode.decoder = DecoderSpec::parse(decoder)?;
    }
    if let Some(s) = args.get_f64("sigma")? {
        cfg.sketch.sigma = SigmaHeuristic::Fixed(s);
    }
    if let Some(seed) = args.get_u64("seed")? {
        cfg.seed = seed;
    }
    if let Some(r) = args.get_usize("replicates")? {
        cfg.decode.replicates = r;
    }
    if let Some(t) = args.get_usize("threads")? {
        cfg.threads = t;
        cfg.decode.params.threads = t;
    }
    Ok(cfg)
}

/// Draw the job's sketch operator for dataset `x` (sigma resolved through
/// the config's heuristic, dithering per the method's policy).
pub fn build_operator(cfg: &JobConfig, x: &Mat, rng: &mut Rng) -> SketchOperator {
    let sigma = cfg.sketch.sigma.resolve(x, rng);
    let freqs = if cfg.sketch.method.dithered() {
        DrawnFrequencies::draw(cfg.sketch.law, x.cols(), cfg.sketch.num_frequencies, sigma, rng)
    } else {
        DrawnFrequencies::draw_undithered(
            cfg.sketch.law,
            x.cols(),
            cfg.sketch.num_frequencies,
            sigma,
            rng,
        )
    };
    eprintln!(
        "operator: method={} law={} M={} sigma={sigma:.4}",
        cfg.sketch.method.canonical(),
        cfg.sketch.law.name(),
        cfg.sketch.num_frequencies
    );
    SketchOperator::new(freqs, cfg.sketch.method.signature())
}

/// Resolve the `--decoder` flag through the registry (default: `clompr`,
/// the paper's decoder — bit-for-bit the legacy pipelines).
pub fn decoder_from(parsed: &ParsedArgs) -> Result<DecoderSpec> {
    match parsed.get("decoder") {
        Some(s) => DecoderSpec::parse(s),
        None => Ok(DecoderSpec::default()),
    }
}

/// Verify an optional `--method` declaration against the method a `.qsk`
/// header recorded (canonicalized through the registry first, so aliases
/// and case agree). `what` names the conflicting source in the error.
pub fn check_declared_method(parsed: &ParsedArgs, meta_method: &str, what: &str) -> Result<()> {
    if let Some(m) = parsed.get("method") {
        if MethodSpec::parse(m)?.canonical() != meta_method {
            bail!("--method {m} conflicts with {what} (method={meta_method})");
        }
    }
    Ok(())
}

/// Per-chunk pooling encoding for the streamed sketch — `auto` defers to
/// the method's preferred wire format (the one source of the method→wire
/// mapping, see [`MethodSpec::preferred_wire_format`]).
pub fn wire_from(parsed: &ParsedArgs, method: &MethodSpec) -> Result<WireFormat> {
    Ok(match parsed.get("encoding").unwrap_or("auto") {
        "auto" => method.preferred_wire_format(),
        // The streaming fold re-checks this against the signature, but
        // failing at the flag gives the actionable error.
        "bits" if method.preferred_wire_format() != WireFormat::PackedBits => bail!(
            "--encoding bits needs a ±1-valued method (e.g. qckm); '{}' pools dense",
            method.canonical()
        ),
        "bits" => WireFormat::PackedBits,
        "dense" => WireFormat::DenseF64,
        other => bail!("unknown encoding '{other}' (auto|bits|dense)"),
    })
}

/// The shard label for an ingest verb: `--shard` if given, else the data
/// file's stem (the convention `qckm sketch` and `qckm push` share).
pub fn shard_label(parsed: &ParsedArgs, data_path: &str) -> String {
    match parsed.get("shard") {
        Some(s) => s.to_string(),
        None => Path::new(data_path)
            .file_stem()
            .map(|s| s.to_string_lossy().into_owned())
            .unwrap_or_else(|| data_path.to_string()),
    }
}

/// The validated scalar `--lo` / `--hi` pair (defaulting to −1 / 1, the
/// declared CLI defaults) — the form the server protocol carries.
pub fn scalar_box(parsed: &ParsedArgs) -> Result<(f64, f64)> {
    let lo = parsed.get_f64("lo")?.unwrap_or(-1.0);
    let hi = parsed.get_f64("hi")?.unwrap_or(1.0);
    if lo > hi {
        bail!("--lo {lo} must not exceed --hi {hi}");
    }
    Ok((lo, hi))
}

/// The centroid search box every decode-side verb uses (the one
/// derivation `cluster` / `decode` / `query` used to hand-roll in three
/// slightly divergent copies): the dataset's per-coordinate bounding box
/// when data is available, else the validated scalar `--lo` / `--hi`
/// flags replicated over `dim` coordinates.
pub fn search_box(
    parsed: &ParsedArgs,
    data: Option<&Mat>,
    dim: usize,
) -> Result<(Vec<f64>, Vec<f64>)> {
    match data {
        Some(x) => Ok(bounding_box(x)),
        None => {
            let (lo, hi) = scalar_box(parsed)?;
            Ok((vec![lo; dim], vec![hi; dim]))
        }
    }
}

/// Shared `--tenant` / `--token` help text for verbs that talk to a
/// serving node.
pub const TENANT_HELP: &str = "address this named tenant on a multi-tenant node";
pub const TOKEN_HELP: &str = "auth token for the addressed tenant";

/// The `--tenant` / `--token` scope flags (both default to empty = the
/// server's unnamed default tenant, no auth).
pub fn scope_from(parsed: &ParsedArgs) -> (String, String) {
    (
        parsed.get("tenant").unwrap_or("").to_string(),
        parsed.get("token").unwrap_or("").to_string(),
    )
}

/// Parse a tenant spec file — a TOML job config plus top-level `dim`
/// (required) and `token` (optional) — and draw its operator. `qckm
/// serve --tenant` and `qckm aggregate --tenant` share this, which is
/// what makes an edge's pools mergeable with the root's by construction:
/// both sides draw from the same spec.
pub fn load_tenant_spec(
    name: &str,
    path: &str,
) -> Result<(qckm::stream::SketchMeta, SketchOperator, Option<String>, JobConfig)> {
    let text =
        std::fs::read_to_string(path).with_context(|| format!("tenant '{name}': read {path}"))?;
    let doc = qckm::config::parse_toml(&text)
        .map_err(|e| anyhow::anyhow!("tenant '{name}': {path}: {e}"))?;
    let job = JobConfig::from_toml(&doc).with_context(|| format!("tenant '{name}': {path}"))?;
    let dim = doc.get_int("", "dim", 0);
    if dim < 1 {
        bail!("tenant '{name}': {path} needs a top-level dim >= 1");
    }
    let SigmaHeuristic::Fixed(sigma) = job.sketch.sigma else {
        bail!("tenant '{name}': {path} needs an explicit sketch.sigma (pushers must agree on it)");
    };
    let token = doc.get_str("", "token", "").to_string();
    let op = qckm::stream::draw_operator(
        &job.sketch.method,
        job.sketch.law,
        job.sketch.num_frequencies,
        dim as usize,
        sigma,
        job.seed,
    );
    let meta = qckm::stream::SketchMeta::for_operator(&op, &job.sketch.method, job.seed);
    eprintln!("tenant '{name}': {}", meta.describe());
    Ok((meta, op, (!token.is_empty()).then_some(token), job))
}

/// Connect a service client, declaring `--method` (canonicalized through
/// the registry, so typos and junk fail locally with the valid-family
/// list) and applying the `--tenant` / `--token` scope if the flags were
/// given.
pub fn connect_with_method(addr: &str, parsed: &ParsedArgs) -> Result<qckm::server::Client> {
    let mut client = qckm::server::Client::connect(addr)?;
    if let Some(m) = parsed.get("method") {
        client = client.declare_method(MethodSpec::parse(m)?.canonical());
    }
    let (tenant, token) = scope_from(parsed);
    if !tenant.is_empty() || !token.is_empty() {
        client = client.with_scope(&tenant, &token);
    }
    Ok(client)
}

/// Print the per-centroid rows every decode-side verb shares
/// (`c[k] (alpha=…): …`, 5 decimals — the format the e2e suites diff).
pub fn print_centroids(centroids: &Mat, weights: &[f64]) {
    for c in 0..centroids.rows() {
        let row: Vec<String> = centroids.row(c).iter().map(|v| format!("{v:.5}")).collect();
        println!("c[{c}] (alpha={:.3}): {}", weights[c], row.join(", "));
    }
}

/// Write the centroids CSV when `--out` was given.
pub fn save_centroids(out: Option<&str>, centroids: &Mat) -> Result<()> {
    if let Some(out) = out {
        qckm::data::save_csv(Path::new(out), centroids)?;
        eprintln!("centroids written to {out}");
    }
    Ok(())
}

/// Print a decoded solution the way `qckm decode` does: the objective
/// line, optional SSE/N against a dataset, per-centroid rows, and an
/// optional centroids CSV.
pub fn report_solution(
    sol: &qckm::clompr::Solution,
    x: Option<&Mat>,
    out: Option<&str>,
) -> Result<()> {
    println!("objective = {:.6}", sol.objective);
    if let Some(x) = x {
        let s = qckm::metrics::sse(x, &sol.centroids);
        println!("SSE/N = {:.6}", s / x.rows() as f64);
    }
    print_centroids(&sol.centroids, &sol.weights);
    save_centroids(out, &sol.centroids)
}

#[cfg(test)]
mod tests {
    use super::*;
    use qckm::cli::CliSpec;

    fn boxed_spec() -> CliSpec {
        CliSpec::new("t", "test")
            .opt("lo", "FLOAT", Some("-1"), "lower")
            .opt("hi", "FLOAT", Some("1"), "upper")
    }

    #[test]
    fn search_box_prefers_the_dataset_bounding_box() {
        let parsed = boxed_spec()
            .parse(["--lo", "-9", "--hi", "9"].map(String::from))
            .unwrap();
        let x = Mat::from_vec(3, 2, vec![0.0, 5.0, -2.0, 1.0, 4.0, -3.0]);
        // Data wins over the flags — exactly what cmd_cluster/cmd_decode do.
        let (lo, hi) = search_box(&parsed, Some(&x), 2).unwrap();
        assert_eq!((lo, hi), (vec![-2.0, -3.0], vec![4.0, 5.0]));
    }

    #[test]
    fn search_box_replicates_the_scalar_flags() {
        let parsed = boxed_spec()
            .parse(["--lo", "-2.5", "--hi", "2"].map(String::from))
            .unwrap();
        let (lo, hi) = search_box(&parsed, None, 3).unwrap();
        assert_eq!((lo, hi), (vec![-2.5; 3], vec![2.0; 3]));
    }

    #[test]
    fn search_box_defaults_and_validates() {
        let parsed = boxed_spec().parse(Vec::<String>::new()).unwrap();
        assert_eq!(scalar_box(&parsed).unwrap(), (-1.0, 1.0));
        // Even without declared defaults the helper falls back to ±1.
        let bare = CliSpec::new("t", "test").parse(Vec::<String>::new()).unwrap();
        assert_eq!(scalar_box(&bare).unwrap(), (-1.0, 1.0));
        let flipped = boxed_spec()
            .parse(["--lo", "2", "--hi", "-2"].map(String::from))
            .unwrap();
        let err = format!("{:#}", search_box(&flipped, None, 2).unwrap_err());
        assert!(err.contains("must not exceed"), "{err}");
    }

    #[test]
    fn decoder_flag_resolves_through_the_registry() {
        let spec = CliSpec::new("t", "test").opt("decoder", "SPEC", None, "d");
        let parsed = spec.parse(Vec::<String>::new()).unwrap();
        assert_eq!(decoder_from(&parsed).unwrap().canonical(), "clompr");
        let parsed = spec
            .parse(["--decoder", "hier:restarts=2"].map(String::from))
            .unwrap();
        assert_eq!(decoder_from(&parsed).unwrap().canonical(), "hier:restarts=2");
        let parsed = spec.parse(["--decoder", "junk"].map(String::from)).unwrap();
        let err = format!("{:#}", decoder_from(&parsed).unwrap_err());
        assert!(err.contains("valid decoders"), "{err}");
    }
}
