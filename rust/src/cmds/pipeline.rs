//! `qckm pipeline` — the streaming 1-bit sensor-cloud demo: synthetic
//! sensors, the Fig. 1 coordinator dataflow, and a registry-routed decode
//! of the pooled sketch.

use anyhow::{bail, Result};
use qckm::cli::CliSpec;
use qckm::clompr::ClOmprParams;
use qckm::coordinator::{run_pipeline, PipelineConfig, SampleSource, WireFormat};
use qckm::decoder::DecoderSpec;
use qckm::frequency::{DrawnFrequencies, SigmaHeuristic};
use qckm::method::MethodSpec;
use qckm::rng::Rng;
use qckm::sketch::SketchOperator;
use std::sync::Arc;

pub fn run(args: Vec<String>) -> Result<()> {
    let spec = CliSpec::new("qckm pipeline", "streaming 1-bit sensor-cloud demo")
        .opt("workers", "NUM", Some("4"), "sensor workers")
        .opt("samples", "NUM", Some("100000"), "total samples to acquire")
        .opt("dim", "NUM", Some("10"), "sample dimension")
        .opt("k", "NUM", Some("4"), "clusters to synthesize + decode")
        .opt("m", "NUM", Some("400"), "frequencies")
        .opt("batch", "NUM", Some("64"), "examples per wire message")
        .opt("queue", "NUM", Some("16"), "channel capacity")
        .opt("wire", "FMT", Some("bits"), "bits|dense")
        .opt(
            "method",
            "SPEC",
            None,
            "encode method (default: the wire's preferred method — \
             qckm for bits, ckm for dense)",
        )
        .opt("seed", "NUM", Some("0"), "seed");
    let parsed = spec.parse(args)?;
    let workers = parsed.get_usize("workers")?.unwrap();
    let samples = parsed.get_usize("samples")?.unwrap();
    let dim = parsed.get_usize("dim")?.unwrap();
    let k = parsed.get_usize("k")?.unwrap();
    let m = parsed.get_usize("m")?.unwrap();
    let seed = parsed.get_u64("seed")?.unwrap();
    let wire = match parsed.get("wire").unwrap() {
        "bits" => WireFormat::PackedBits,
        "dense" => WireFormat::DenseF64,
        other => bail!("unknown wire '{other}'"),
    };

    // Synthetic sensor field: K Gaussians at random ±1 corners.
    let mut rng = Rng::new(seed);
    let proto = qckm::data::gaussian_mixture_pm1(k.max(2) * 64, dim, k, &mut rng);
    let means = Arc::new(proto.means.clone());
    let std = (dim as f64 / 20.0).sqrt();
    let source = SampleSource::Synthetic {
        total: samples,
        dim,
        make: Arc::new(move |r: &mut Rng, out: &mut [f64]| {
            let c = r.next_below(means.rows() as u64) as usize;
            for (j, v) in out.iter_mut().enumerate() {
                *v = means.get(c, j) + std * r.gaussian();
            }
        }),
    };

    let sigma = SigmaHeuristic::default().resolve(&proto.points, &mut rng);
    let freqs = DrawnFrequencies::draw(
        qckm::frequency::FrequencyLaw::AdaptedRadius,
        dim,
        m,
        sigma,
        &mut rng,
    );
    // The signature comes from the method spec, not from an assumption
    // about the wire: dense no longer hardcodes the cosine, and any
    // registry family can drive the demo. (The frequency draw above stays
    // dithered for every method, as this demo always did.)
    let method = match parsed.get("method") {
        Some(s) => MethodSpec::parse(s)?,
        None => MethodSpec::parse(match wire {
            WireFormat::PackedBits => "qckm",
            WireFormat::DenseF64 => "ckm",
        })?,
    };
    if wire == WireFormat::PackedBits
        && method.preferred_wire_format() != WireFormat::PackedBits
    {
        bail!(
            "--wire bits needs a ±1-valued method (e.g. qckm); '{}' requires --wire dense",
            method.canonical()
        );
    }
    eprintln!("pipeline method: {}", method.canonical());
    let op = SketchOperator::new(freqs, method.signature());

    let report = run_pipeline(
        &op,
        &source,
        &PipelineConfig {
            workers,
            batch_size: parsed.get_usize("batch")?.unwrap(),
            queue_capacity: parsed.get_usize("queue")?.unwrap(),
            wire,
        },
        seed,
    );
    println!(
        "pipeline: {} samples in {:.3}s → {:.0} samples/s",
        report.samples,
        report.elapsed_secs,
        report.throughput()
    );
    println!(
        "wire: {} bytes total ({:.2} bytes/sample), queue high-water {}, {} stalls",
        report.payload_bytes,
        report.payload_bytes as f64 / report.samples as f64,
        report.queue_high_water,
        report.blocked_sends
    );

    // Decode through the registry's default spec — bitwise the direct
    // ClOmpr run this demo used to hand-roll.
    let lo = vec![-2.0; dim];
    let hi = vec![2.0; dim];
    let sol = DecoderSpec::default().decode_best_of(
        &op,
        k,
        &report.sketch,
        lo,
        hi,
        &ClOmprParams::default(),
        1,
        &mut rng,
    );
    println!(
        "decoded {} centroids, objective {:.4}",
        sol.centroids.rows(),
        sol.objective
    );
    for i in 0..sol.centroids.rows() {
        let c: Vec<String> = sol
            .centroids
            .row(i)
            .iter()
            .take(6)
            .map(|v| format!("{v:+.2}"))
            .collect();
        println!("  c[{i}] alpha={:.3} [{} …]", sol.weights[i], c.join(", "));
    }
    Ok(())
}
