//! `qckm snapshot` — drain a serving node's window into a `.qsk` file the
//! offline stages understand.

use super::common::{connect_with_method, TENANT_HELP, TOKEN_HELP};
use anyhow::{Context, Result};
use qckm::cli::CliSpec;
use qckm::stream;
use std::path::Path;

pub fn run(args: Vec<String>) -> Result<()> {
    let spec = CliSpec::new(
        "qckm snapshot",
        "drain a serving node's window into a .qsk file (offline-decodable)",
    )
    .opt("addr", "HOST:PORT", None, "server address")
    .opt("window", "NUM", Some("0"), "epochs to pool (0 = all-time)")
    .opt(
        "method",
        "SPEC",
        None,
        "declare the expected method; the server refuses a mismatch",
    )
    .opt("tenant", "NAME", None, TENANT_HELP)
    .opt("token", "TOKEN", None, TOKEN_HELP)
    .opt("out", "FILE", None, "write the .qsk here");
    let parsed = spec.parse(args)?;
    let addr = parsed.get("addr").context("--addr is required")?;
    let out = parsed.get("out").context("--out is required")?;

    let mut client = connect_with_method(addr, &parsed)?;
    let bytes = client.snapshot(parsed.get_usize("window")?.unwrap() as u32)?;
    std::fs::write(out, &bytes).with_context(|| format!("write {out}"))?;
    // Re-load what we wrote: validates the checksum end-to-end and tells
    // the operator what they got.
    let (meta, pool, prov) = stream::load_sketch_full(Path::new(out))?;
    println!(
        "snapshot: {} samples across {} shard record(s) -> {out} [{}]",
        pool.count(),
        prov.len(),
        meta.describe()
    );
    Ok(())
}
