//! `qckm aggregate` — a fan-in edge node (see `qckm::fanin`).
//!
//! Accepts the same push protocol as `qckm serve`, pools batches into
//! local per-tenant accumulators, and flushes merged deltas upstream on a
//! row threshold or timer. Because the pooled sketch is an associative
//! integer statistic, an aggregator tree of any depth answers bit-for-bit
//! identically to the flat single-server pipeline (INVARIANTS.md I-20);
//! each flush carries an (aggregator-id, instance, seq) idempotency key
//! so at-least-once delivery never double-counts (I-21).
//!
//! Two shapes, mirroring `qckm serve`:
//!
//! * **Single-tenant**: operator flags (`--dim --m --sigma --seed
//!   [--method]`) describe the one pooled sketch; pushes with no scope
//!   land here, and flushes go upstream unscoped.
//! * **Multi-tenant**: `--tenant name=specfile` declarations (same spec
//!   files as the root server — sharing them is what guarantees the
//!   edge's operator draw matches the root's, so the deltas merge).

use super::common::{job_from, load_tenant_spec, METHOD_HELP};
use anyhow::{bail, Context, Result};
use qckm::cli::CliSpec;
use qckm::fanin::{serve_aggregator, AggregatorConfig, AggregatorNode};
use qckm::frequency::SigmaHeuristic;
use qckm::parallel::Parallelism;
use qckm::server::{tenants, RateLimit, RetryPolicy};
use qckm::sketch::SketchOperator;
use qckm::stream::{self, SketchMeta};
use std::time::Duration;

pub fn run(args: Vec<String>) -> Result<()> {
    let spec = CliSpec::new(
        "qckm aggregate",
        "run a fan-in edge node: pool pushes locally, flush merged deltas upstream",
    )
    .opt("host", "ADDR", Some("127.0.0.1"), "bind address")
    .opt("port", "NUM", Some("0"), "bind port (0 = ephemeral; the bound port is printed)")
    .opt("upstream", "HOST:PORT", None, "the parent to flush into (server or aggregator)")
    .opt(
        "agg-id",
        "ID",
        None,
        "this node's identity upstream (unique among the parent's children)",
    )
    .opt("dim", "NUM", None, "data dimension (single-tenant mode)")
    .opt("m", "NUM", None, "number of frequencies")
    .opt("method", "SPEC", None, METHOD_HELP)
    .opt("sigma", "FLOAT", None, "kernel bandwidth (required in single-tenant mode)")
    .opt("seed", "NUM", None, "frequency-draw seed")
    .opt("threads", "NUM", None, "encode threads (0 = all cores)")
    .multi(
        "tenant",
        "NAME=SPECFILE",
        "pool a named tenant from a TOML spec file (repeatable); \
         use the root server's spec files so the operators match",
    )
    .opt(
        "flush-rows",
        "NUM",
        Some("4096"),
        "flush a tenant upstream once its pending pool reaches this many rows",
    )
    .opt(
        "flush-ms",
        "NUM",
        Some("1000"),
        "flush every tenant at least this often (milliseconds)",
    )
    .opt("retry", "NUM", Some("8"), "upstream flush retries (reconnect + resend)")
    .opt(
        "max-shards",
        "NUM",
        Some("1024"),
        "distinct shard labels accepted per tenant before new ones are refused",
    )
    .opt(
        "rate-limit",
        "RATE[:BURST]",
        None,
        "per-connection ingest rate limit in frames/s (burst defaults to RATE)",
    )
    .flag(
        "replay",
        "fault injection: send every delta twice to prove the upstream dedup gate",
    )
    .opt("config", "FILE", None, "TOML job config (a [tenants] table declares tenants)");
    let parsed = spec.parse(args)?;
    let cfg = job_from(&parsed)?;

    let upstream = parsed.get("upstream").context("--upstream is required")?;
    let agg_id = parsed.get("agg-id").context("--agg-id is required")?;
    let rate = parsed.get("rate-limit").map(RateLimit::parse).transpose()?;

    // Tenant declarations: --tenant flags first, then the config file's
    // [tenants] table (flags win) — the same precedence as `qckm serve`.
    let mut decls: Vec<(String, String)> = Vec::new();
    for d in parsed.get_all("tenant") {
        let Some((name, path)) = d.split_once('=') else {
            bail!("--tenant wants NAME=SPECFILE, got '{d}'");
        };
        decls.push((name.to_string(), path.to_string()));
    }
    if let Some(path) = parsed.get("config") {
        let text = std::fs::read_to_string(path).with_context(|| format!("read {path}"))?;
        let doc = qckm::config::parse_toml(&text).map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
        for key in doc.keys("tenants") {
            if decls.iter().any(|(n, _)| n == key) {
                continue;
            }
            let Some(file) = doc.get("tenants", key).and_then(|v| v.as_str()) else {
                bail!("{path}: [tenants] {key} must be a spec-file path string");
            };
            decls.push((key.to_string(), file.to_string()));
        }
    }

    let mut tenants_vec: Vec<(String, SketchMeta, SketchOperator, Option<String>)> = Vec::new();
    if decls.is_empty() {
        let (meta, op) = single_operator(&parsed, &cfg)?;
        eprintln!("operator: {}", meta.describe());
        tenants_vec.push((String::new(), meta, op, None));
    } else {
        for (name, path) in &decls {
            tenants::validate_tenant_name(name)?;
            if tenants_vec.iter().any(|(n, _, _, _)| n == name) {
                bail!("tenant '{name}' declared twice");
            }
            let (meta, op, token, _job) = load_tenant_spec(name, path)?;
            tenants_vec.push((name.clone(), meta, op, token));
        }
        eprintln!(
            "pooling {} tenant(s): {}",
            tenants_vec.len(),
            tenants_vec.iter().map(|(n, ..)| n.as_str()).collect::<Vec<_>>().join(", ")
        );
    }

    let node = AggregatorNode::new(
        AggregatorConfig {
            agg_id: agg_id.to_string(),
            upstream: upstream.to_string(),
            flush_rows: parsed.get_usize("flush-rows")?.unwrap().max(1) as u64,
            flush_interval: Duration::from_millis(
                parsed.get_usize("flush-ms")?.unwrap().max(1) as u64
            ),
            retry: RetryPolicy {
                attempts: parsed.get_usize("retry")?.unwrap() as u32,
                ..RetryPolicy::default()
            },
            replay: parsed.flag("replay"),
            rate,
            registry: qckm::obs::global().clone(),
            threads: Parallelism::fixed(cfg.threads),
            max_shards: parsed.get_usize("max-shards")?.unwrap().max(1),
        },
        tenants_vec,
    )?;

    let host = parsed.get("host").unwrap();
    let port = parsed.get_usize("port")?.unwrap();
    if port > u16::MAX as usize {
        bail!("--port {port} out of range");
    }
    let listener = std::net::TcpListener::bind((host, port as u16))
        .with_context(|| format!("bind {host}:{port}"))?;
    // Machine-parseable: tests and scripts read the ephemeral port here.
    println!("LISTENING {}", listener.local_addr()?);
    std::io::Write::flush(&mut std::io::stdout())?;
    eprintln!("aggregate: '{agg_id}' flushing to {upstream}");

    let served = serve_aggregator(listener, node)?;
    eprintln!("aggregator stopped after {served} connection(s)");
    Ok(())
}

/// The single-tenant operator from the CLI flags — the same draw `qckm
/// serve` (and the offline `qckm sketch`) performs for these parameters.
fn single_operator(
    parsed: &qckm::cli::ParsedArgs,
    cfg: &qckm::config::JobConfig,
) -> Result<(SketchMeta, SketchOperator)> {
    let dim = parsed
        .get_usize("dim")?
        .context("--dim is required without --tenant")?;
    let SigmaHeuristic::Fixed(sigma) = cfg.sketch.sigma else {
        bail!("--sigma is required without --tenant (the upstream must agree on it)");
    };
    let op = stream::draw_operator(
        &cfg.sketch.method,
        cfg.sketch.law,
        cfg.sketch.num_frequencies,
        dim,
        sigma,
        cfg.seed,
    );
    let meta = stream::SketchMeta::for_operator(&op, &cfg.sketch.method, cfg.seed);
    Ok((meta, op))
}
