//! `qckm decode` — decode K centroids from a pooled sketch (`.qsk`), no
//! dataset needed. The algorithm comes from `--decoder` (registry spec,
//! default `clompr`).

use super::common::{
    check_declared_method, decoder_from, report_solution, search_box, DECODER_HELP,
};
use anyhow::{bail, Context, Result};
use qckm::cli::CliSpec;
use qckm::clompr::ClOmprParams;
use qckm::rng::Rng;
use qckm::stream;
use std::path::Path;

pub fn run(args: Vec<String>) -> Result<()> {
    let spec = CliSpec::new(
        "qckm decode",
        "decode K centroids from a pooled sketch (.qsk) — no dataset needed",
    )
    .opt("sketch", "FILE", None, "input .qsk sketch")
    .opt("k", "NUM", None, "number of clusters")
    .opt(
        "method",
        "SPEC",
        None,
        "declare the expected method; refused if the sketch differs",
    )
    .opt("decoder", "SPEC", None, DECODER_HELP)
    .opt("replicates", "NUM", Some("1"), "decoder replicates (best objective wins)")
    .opt("threads", "NUM", Some("1"), "decoder threads (0 = all cores)")
    .opt("seed", "NUM", None, "decoder RNG seed (default: the sketch's seed)")
    .opt("lo", "FLOAT", Some("-1"), "centroid search box lower bound (every coordinate)")
    .opt("hi", "FLOAT", Some("1"), "centroid search box upper bound (every coordinate)")
    .opt("data", "FILE", None, "optional dataset: use its bounding box and report SSE")
    .opt("out", "FILE", None, "write centroids CSV here");
    let parsed = spec.parse(args)?;
    let sketch_path = parsed.get("sketch").context("--sketch is required")?;
    let k = parsed.get_usize("k")?.context("--k is required")?;

    let (meta, pool) = stream::load_sketch(Path::new(sketch_path))?;
    check_declared_method(&parsed, &meta.method, sketch_path)?;
    if pool.count() == 0 {
        bail!("{sketch_path}: sketch pools zero samples");
    }
    let op = meta.rebuild_operator()?;
    eprintln!(
        "sketch: {} samples, {} slots [{}]",
        pool.count(),
        pool.len(),
        meta.describe()
    );

    let x = match parsed.get("data") {
        Some(p) => {
            let mut reader = stream::open_dataset(Path::new(p))?;
            let x = stream::read_all(reader.as_mut())?;
            if x.cols() != op.dim() {
                bail!(
                    "{p}: dataset dimension {} does not match the sketch's dimension {}",
                    x.cols(),
                    op.dim()
                );
            }
            Some(x)
        }
        None => None,
    };
    let (lo, hi) = search_box(&parsed, x.as_ref(), op.dim())?;

    let params = ClOmprParams {
        threads: parsed.get_usize("threads")?.unwrap(),
        ..ClOmprParams::default()
    };
    let decoder = decoder_from(&parsed)?;
    let replicates = parsed.get_usize("replicates")?.unwrap().max(1);
    let seed = parsed.get_u64("seed")?.unwrap_or(meta.seed);
    let z = pool.mean();
    let mut rng = Rng::new(seed);
    eprintln!("decoder: {}", decoder.canonical());
    let sol = decoder.decode_best_of(&op, k, &z, lo, hi, &params, replicates, &mut rng);
    report_solution(&sol, x.as_ref(), parsed.get("out"))
}
