//! `qckm experiment` — regenerate a paper figure. Every decoding trial
//! routes through the `--decoder` registry spec (default `clompr`, the
//! paper's CL-OMPR — bit-for-bit the legacy harness).

use super::common::{decoder_from, DECODER_HELP};
use anyhow::{bail, Context, Result};
use qckm::cli::CliSpec;
use qckm::experiments as exp;
use std::sync::Arc;

pub fn run(args: Vec<String>) -> Result<()> {
    let spec = CliSpec::new("qckm experiment", "regenerate a paper figure")
        .positionals("<fig2a|fig2b|fig3|prop1|ablation>")
        .flag("full", "paper-scale grid (slow) instead of the quick grid")
        .flag("streamed", "fig2 only: sketch trials through the streaming fold")
        .opt("trials", "NUM", None, "override trials per cell")
        .opt("samples", "NUM", None, "override dataset size")
        .opt("seed", "NUM", None, "override seed")
        .opt("decoder", "SPEC", None, DECODER_HELP)
        .opt("threads", "NUM", None, "trial fan-out threads (0 = all cores)");
    let parsed = spec.parse(args)?;
    let which = parsed
        .positional(0)
        .context("which experiment? (fig2a|fig2b|fig3|prop1|ablation)")?;
    let full = parsed.flag("full");
    let decoder = decoder_from(&parsed)?;

    match which {
        "fig2a" | "fig2b" => {
            let variant = if which == "fig2a" {
                exp::Fig2Variant::VaryDimension
            } else {
                exp::Fig2Variant::VaryClusters
            };
            let mut cfg = if full {
                exp::Fig2Config::full(variant)
            } else {
                exp::Fig2Config::quick(variant)
            };
            if let Some(t) = parsed.get_usize("trials")? {
                cfg.trials = t;
            }
            if let Some(s) = parsed.get_usize("samples")? {
                cfg.n_samples = s;
            }
            if let Some(seed) = parsed.get_u64("seed")? {
                cfg.seed = seed;
            }
            if let Some(t) = parsed.get_usize("threads")? {
                cfg.threads = t;
            }
            cfg.decoder_spec = decoder;
            cfg.streamed = parsed.flag("streamed");
            let res = exp::run_fig2(&cfg);
            println!("{}", res.render());
        }
        "fig3" => {
            let mut cfg = if full {
                exp::Fig3Config::full()
            } else {
                exp::Fig3Config::quick()
            };
            if let Some(t) = parsed.get_usize("trials")? {
                cfg.trials = t;
            }
            if let Some(s) = parsed.get_usize("samples")? {
                cfg.n_samples = s;
            }
            if let Some(seed) = parsed.get_u64("seed")? {
                cfg.seed = seed;
            }
            if let Some(t) = parsed.get_usize("threads")? {
                cfg.threads = t;
            }
            cfg.decoder_spec = decoder;
            let res = exp::run_fig3(&cfg);
            println!("{}", res.render());
        }
        "prop1" => {
            // Prop. 1 validates the *sketch*, not any decode — the decoder
            // registry has nothing to route here.
            if parsed.get("decoder").is_some() {
                eprintln!("note: prop1 never decodes; --decoder is ignored");
            }
            let mut cfg = exp::Prop1Config::default();
            if let Some(t) = parsed.get_usize("trials")? {
                cfg.repeats = t;
            }
            if let Some(seed) = parsed.get_u64("seed")? {
                cfg.seed = seed;
            }
            let sigs: [Arc<dyn qckm::signature::Signature>; 3] = [
                Arc::new(qckm::signature::UniversalQuantizer),
                Arc::new(qckm::signature::Triangle),
                Arc::new(qckm::signature::ModuloRamp),
            ];
            for sig in sigs {
                let res = exp::run_prop1(sig, &cfg);
                println!("{}", res.render());
            }
        }
        "ablation" => {
            let mut cfg = exp::AblationConfig::default();
            if let Some(t) = parsed.get_usize("trials")? {
                cfg.trials = t;
            }
            if let Some(t) = parsed.get_usize("threads")? {
                cfg.threads = t;
            }
            cfg.decoder = decoder;
            if full {
                cfg.trials = 30;
                cfg.ratios = vec![0.5, 1.0, 2.0, 4.0, 8.0];
            }
            let res = exp::run_ablation(&cfg);
            println!("{}", res.render());
        }
        other => bail!("unknown experiment '{other}'"),
    }
    Ok(())
}
