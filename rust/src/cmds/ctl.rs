//! `qckm ctl` — administer a serving node (stats / roll / metrics /
//! trace / shutdown). `metrics` prints the server's Prometheus exposition
//! page verbatim, so `qckm ctl --addr … metrics` is a ready-made scrape
//! target for a textfile collector or a curl-equivalent health probe;
//! `trace` prints recent request span trees (or one, by `--id`) as JSON.

use super::common::{TENANT_HELP, TOKEN_HELP};
use anyhow::{bail, Context, Result};
use qckm::cli::CliSpec;
use qckm::obs::trace::parse_trace_id;

pub fn run(args: Vec<String>) -> Result<()> {
    let spec = CliSpec::new("qckm ctl", "administer a serving node")
        .positionals("<stats|roll|metrics|trace|shutdown>")
        .opt("addr", "HOST:PORT", None, "server address")
        .opt("tenant", "NAME", None, TENANT_HELP)
        .opt("token", "TOKEN", None, TOKEN_HELP)
        .opt("id", "HEX", None, "trace: fetch this 32-hex-char trace id only")
        .opt(
            "limit",
            "NUM",
            Some("0"),
            "trace: newest traces to return (0 = the server default)",
        );
    let parsed = spec.parse(args)?;
    let addr = parsed.get("addr").context("--addr is required")?;
    let verb = parsed
        .positional(0)
        .context("which action? (stats|roll|metrics|trace|shutdown)")?;
    let mut client = qckm::server::Client::connect(addr)?;
    let (tenant, token) = super::common::scope_from(&parsed);
    if !tenant.is_empty() || !token.is_empty() {
        client = client.with_scope(&tenant, &token);
    }
    match verb {
        "stats" => {
            let s = client.stats()?;
            if !s.tenant.is_empty() {
                println!("tenant '{}'", s.tenant);
            }
            println!(
                "method {} | epoch {} | {} rows all-time | {} closed epoch(s) held | \
                 {} of {} shard slots | cache {} hit / {} miss",
                s.method,
                s.epoch,
                s.rows_total,
                s.epochs_held,
                s.shards.len(),
                s.max_shards,
                s.cache_hits,
                s.cache_misses
            );
            for (label, rows) in &s.shards {
                println!("  shard '{label}': {rows} rows");
            }
            for (decoder, queries) in &s.decoders {
                println!("  decoder '{decoder}': {queries} queries");
            }
            // Per-tenant occupancy — present only when a multi-tenant
            // node answered (v6), so single-tenant output is unchanged.
            for (name, rows, shards) in &s.tenants {
                let shown = if name.is_empty() { "(default)" } else { name };
                println!("  tenant '{shown}': {rows} rows, {shards} shard slot(s)");
            }
        }
        "metrics" => {
            // The page is printed byte-for-byte as the server rendered it —
            // already valid Prometheus text format, trailing newline and all.
            print!("{}", client.metrics()?);
        }
        "trace" => {
            let id = parsed.get("id").map(parse_trace_id).transpose()?;
            let limit = parsed.get_usize("limit")?.unwrap().min(u32::MAX as usize) as u32;
            // The JSON is printed as the server rendered it (no trailing
            // newline in the payload — println! supplies the final one).
            println!("{}", client.trace(id, limit)?);
        }
        "roll" => {
            let (epoch, rows_closed) = client.roll()?;
            println!("rolled: epoch {epoch} open, {rows_closed} rows closed");
        }
        "shutdown" => {
            client.shutdown()?;
            println!("server acknowledged shutdown");
        }
        other => bail!("unknown ctl action '{other}' (stats|roll|metrics|trace|shutdown)"),
    }
    Ok(())
}
