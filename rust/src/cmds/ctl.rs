//! `qckm ctl` — administer a serving node (stats / roll / shutdown).

use anyhow::{bail, Context, Result};
use qckm::cli::CliSpec;

pub fn run(args: Vec<String>) -> Result<()> {
    let spec = CliSpec::new("qckm ctl", "administer a serving node")
        .positionals("<stats|roll|shutdown>")
        .opt("addr", "HOST:PORT", None, "server address");
    let parsed = spec.parse(args)?;
    let addr = parsed.get("addr").context("--addr is required")?;
    let verb = parsed.positional(0).context("which action? (stats|roll|shutdown)")?;
    let mut client = qckm::server::Client::connect(addr)?;
    match verb {
        "stats" => {
            let s = client.stats()?;
            println!(
                "method {} | epoch {} | {} rows all-time | {} closed epoch(s) held | \
                 cache {} hit / {} miss",
                s.method, s.epoch, s.rows_total, s.epochs_held, s.cache_hits, s.cache_misses
            );
            for (label, rows) in &s.shards {
                println!("  shard '{label}': {rows} rows");
            }
            for (decoder, queries) in &s.decoders {
                println!("  decoder '{decoder}': {queries} queries");
            }
        }
        "roll" => {
            let (epoch, rows_closed) = client.roll()?;
            println!("rolled: epoch {epoch} open, {rows_closed} rows closed");
        }
        "shutdown" => {
            client.shutdown()?;
            println!("server acknowledged shutdown");
        }
        other => bail!("unknown ctl action '{other}' (stats|roll|shutdown)"),
    }
    Ok(())
}
