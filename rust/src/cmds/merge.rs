//! `qckm merge` — pool shard sketches (`.qsk`) into one. Associative, any
//! order; mismatched operators are refused at the fingerprint.

use super::common::check_declared_method;
use anyhow::{bail, Context, Result};
use qckm::cli::CliSpec;
use qckm::stream;
use std::path::Path;

pub fn run(args: Vec<String>) -> Result<()> {
    let spec = CliSpec::new(
        "qckm merge",
        "pool shard sketches (.qsk) into one — associative, any order",
    )
    .positionals("<shard.qsk>…")
    .opt(
        "method",
        "SPEC",
        None,
        "declare the expected method; refused if the shards differ",
    )
    .opt("out", "FILE", None, "write the merged .qsk here");
    let parsed = spec.parse(args)?;
    let inputs = parsed.positionals();
    if inputs.is_empty() {
        bail!("need at least one input .qsk (see --help)");
    }
    let out = parsed.get("out").context("--out is required")?;

    let (meta, mut pool, mut prov) = stream::load_sketch_full(Path::new(&inputs[0]))?;
    check_declared_method(&parsed, &meta.method, &inputs[0])?;
    eprintln!("{}: {} samples [{}]", inputs[0], pool.count(), meta.describe());
    for input in &inputs[1..] {
        let (shard_meta, shard_pool, shard_prov) = stream::load_sketch_full(Path::new(input))?;
        meta.ensure_mergeable(&shard_meta)
            .with_context(|| format!("merging {input}"))?;
        eprintln!("{}: {} samples", input, shard_pool.count());
        pool.merge(&shard_pool);
        prov.extend(shard_prov);
    }
    stream::save_sketch_with(Path::new(out), &meta, &pool, &prov)?;
    println!(
        "merged {} shard(s), {} samples -> {out}",
        inputs.len(),
        pool.count()
    );
    Ok(())
}
