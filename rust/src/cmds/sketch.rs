//! `qckm sketch` — stream the pooled sketch of a dataset shard into a
//! `.qsk` file (or `--append` it into an existing one: the online-update
//! mode, operator rebuilt and fingerprint-verified from the header).

use super::common::{check_declared_method, job_from, shard_label, wire_from, METHOD_HELP};
use anyhow::{bail, Context, Result};
use qckm::cli::{CliSpec, ParsedArgs};
use qckm::data::save_csv;
use qckm::frequency::SigmaHeuristic;
use qckm::linalg::Mat;
use qckm::method::MethodSpec;
use qckm::parallel::Parallelism;
use qckm::rng::Rng;
use qckm::sketch::PooledSketch;
use qckm::stream;
use std::path::Path;

pub fn run(args: Vec<String>) -> Result<()> {
    let spec = CliSpec::new(
        "qckm sketch",
        "stream the pooled sketch of a dataset shard into a .qsk file",
    )
    .opt("data", "FILE", None, "input dataset (.csv, else raw f64 bin)")
    .opt("m", "NUM", None, "number of frequencies")
    .opt("method", "SPEC", None, METHOD_HELP)
    .opt(
        "sigma",
        "FLOAT",
        None,
        "kernel bandwidth; required for out-of-core streaming and for shards to merge",
    )
    .opt("seed", "NUM", None, "frequency-draw seed (must match across shards)")
    .opt("threads", "NUM", None, "compute threads (0 = all cores)")
    .opt("encoding", "FMT", Some("auto"), "per-chunk pooling: auto|bits|dense")
    .opt(
        "append",
        "FILE",
        None,
        "online update: stream --data into this existing .qsk (operator comes \
         from its header, fingerprint-verified) and rewrite it",
    )
    .opt("shard", "NAME", None, "provenance label (default: the data file stem)")
    .opt("config", "FILE", None, "TOML job config")
    .opt("out", "FILE", None, "write the pooled sketch (.qsk) here")
    .opt("out-csv", "FILE", None, "also write the mean sketch as one CSV row")
    .flag(
        "mmap",
        "raw-f64 input: windowed positional reader (no buffered copy)",
    );
    let parsed = spec.parse(args)?;
    let cfg = job_from(&parsed)?;
    let data_path = parsed.get("data").context("--data is required")?;
    let mmap = parsed.flag("mmap");
    let par = Parallelism::fixed(cfg.threads);
    let shard = shard_label(&parsed, data_path);

    if let Some(append_path) = parsed.get("append") {
        return sketch_append(&parsed, append_path, data_path, &shard, &par);
    }
    let method = cfg.sketch.method.clone();
    let wire = wire_from(&parsed, &method)?;

    // The frequency draw is a pure function of (method, law, m, d, sigma,
    // seed) — the `.qsk` contract that lets every shard and the decoder
    // reproduce the same operator. A fixed sigma streams out-of-core; the
    // data-dependent heuristic needs the dataset once, in memory.
    let (op, pool) = match cfg.sketch.sigma {
        SigmaHeuristic::Fixed(sigma) => {
            let mut reader = stream::open_dataset_with(Path::new(data_path), mmap)?;
            let op = stream::draw_operator(
                &method,
                cfg.sketch.law,
                cfg.sketch.num_frequencies,
                reader.dim(),
                sigma,
                cfg.seed,
            );
            let mut pool = PooledSketch::new(op.sketch_len());
            let rows = stream::sketch_reader(&op, reader.as_mut(), wire, &mut pool, &par)?;
            if rows == 0 {
                bail!("{data_path}: empty dataset");
            }
            eprintln!("streamed {rows} rows from {data_path} ({wire:?} pooling)");
            (op, pool)
        }
        heuristic => {
            let mut reader = stream::open_dataset_with(Path::new(data_path), mmap)?;
            let x = stream::read_all(reader.as_mut())?;
            let sigma = heuristic.resolve(&x, &mut Rng::new(cfg.seed).substream(1));
            eprintln!(
                "note: sigma {sigma:.4} was estimated from the data in memory; pass --sigma \
                 to stream out-of-core and to keep independent shards mergeable"
            );
            let op = stream::draw_operator(
                &method,
                cfg.sketch.law,
                cfg.sketch.num_frequencies,
                x.cols(),
                sigma,
                cfg.seed,
            );
            // Same chunked fold as the streamed path (bitwise identical to
            // `sketch_into_par`), so --encoding is honored here too.
            let mut pool = PooledSketch::new(op.sketch_len());
            stream::sketch_reader(
                &op,
                &mut stream::MatChunkedReader::new(&x),
                wire,
                &mut pool,
                &par,
            )?;
            (op, pool)
        }
    };
    eprintln!(
        "operator: method={} law={} M={} sigma={:.4}",
        method.canonical(),
        cfg.sketch.law.name(),
        op.num_frequencies(),
        op.frequencies().sigma
    );

    let meta = stream::SketchMeta::for_operator(&op, &method, cfg.seed);
    if let Some(out) = parsed.get("out") {
        let prov = [stream::ShardRecord {
            label: shard.clone(),
            rows: pool.count(),
        }];
        stream::save_sketch_with(Path::new(out), &meta, &pool, &prov)?;
        eprintln!("sketch written to {out} [{}]", meta.describe());
    }
    let z = pool.mean();
    println!(
        "sketch: {} slots over {} samples, first 8: {:?}",
        z.len(),
        pool.count(),
        &z[..z.len().min(8)]
    );
    if let Some(out) = parsed.get("out-csv") {
        save_csv(Path::new(out), &Mat::from_vec(1, z.len(), z))?;
        eprintln!("mean sketch written to {out}");
    }
    Ok(())
}

/// `qckm sketch --append`: the online-update mode. The operator is NOT
/// re-drawn from CLI flags — it is rebuilt from the existing `.qsk` header
/// (fingerprint-verified), the new rows are streamed into the loaded pool
/// through the same bounded-memory fold, and the file is rewritten with an
/// extra provenance record. Any operator flag that contradicts the header
/// is an error (silently sketching new rows with a different operator
/// would corrupt the pool).
fn sketch_append(
    parsed: &ParsedArgs,
    append_path: &str,
    data_path: &str,
    shard: &str,
    par: &Parallelism,
) -> Result<()> {
    let (meta, mut pool, mut prov) = stream::load_sketch_full(Path::new(append_path))?;
    if let Some(m) = parsed.get_usize("m")? {
        if m as u64 != meta.m {
            bail!("--m {m} conflicts with {append_path} (m={})", meta.m);
        }
    }
    check_declared_method(parsed, &meta.method, append_path)?;
    if let Some(sigma) = parsed.get_f64("sigma")? {
        if sigma.to_bits() != meta.sigma.to_bits() {
            bail!("--sigma {sigma} conflicts with {append_path} (sigma={})", meta.sigma);
        }
    }
    if let Some(seed) = parsed.get_u64("seed")? {
        if seed != meta.seed {
            bail!("--seed {seed} conflicts with {append_path} (seed={})", meta.seed);
        }
    }
    let op = meta.rebuild_operator()?;
    let method = MethodSpec::parse(&meta.method)?;
    let wire = wire_from(parsed, &method)?;
    let before = pool.count();
    let mut reader = stream::open_dataset_with(Path::new(data_path), parsed.flag("mmap"))?;
    let rows = stream::sketch_reader(&op, reader.as_mut(), wire, &mut pool, par)?;
    if rows == 0 {
        bail!("{data_path}: empty dataset");
    }
    prov.push(stream::ShardRecord {
        label: shard.to_string(),
        rows,
    });
    let out = parsed.get("out").unwrap_or(append_path);
    stream::save_sketch_with(Path::new(out), &meta, &pool, &prov)?;
    println!(
        "appended {rows} rows from {data_path} to {append_path} ({before} -> {} samples) -> {out}",
        pool.count()
    );
    Ok(())
}
