//! `qckm cluster` — compressively cluster a CSV dataset in one process:
//! acquire through the streaming coordinator (the Fig. 1 dataflow), then
//! decode through the configured [`qckm::decoder::DecoderSpec`].

use super::common::{
    build_operator, job_from, print_centroids, save_centroids, search_box, DECODER_HELP,
    METHOD_HELP,
};
use anyhow::{Context, Result};
use qckm::cli::CliSpec;
use qckm::coordinator::{run_pipeline, PipelineConfig, SampleSource};
use qckm::data::load_csv;
use qckm::rng::Rng;
use std::path::Path;
use std::sync::Arc;

pub fn run(args: Vec<String>) -> Result<()> {
    let spec = CliSpec::new("qckm cluster", "compressively cluster a CSV dataset")
        .opt("data", "FILE", None, "input CSV (one sample per row)")
        .opt("k", "NUM", None, "number of clusters")
        .opt("m", "NUM", None, "number of frequencies")
        .opt("method", "SPEC", None, METHOD_HELP)
        .opt("decoder", "SPEC", None, DECODER_HELP)
        .opt("sigma", "FLOAT", None, "kernel bandwidth (default: heuristic)")
        .opt("seed", "NUM", None, "RNG seed")
        .opt("replicates", "NUM", None, "decoder replicates")
        .opt(
            "threads",
            "NUM",
            None,
            "decoder threads, 0 = all cores (acquisition uses [pipeline] workers)",
        )
        .opt("config", "FILE", None, "TOML job config")
        .opt("out", "FILE", None, "write centroids CSV here");
    let parsed = spec.parse(args)?;
    let cfg = job_from(&parsed)?;
    let data_path = parsed.get("data").context("--data is required")?;
    let x = load_csv(Path::new(data_path))?;
    eprintln!("loaded {} x {} from {data_path}", x.rows(), x.cols());

    let mut rng = Rng::new(cfg.seed);
    let op = build_operator(&cfg, &x, &mut rng);

    // Acquire through the streaming coordinator (the Fig. 1 dataflow),
    // with the method's preferred pooling encoding on the wire.
    let wire = cfg.sketch.method.preferred_wire_format();
    let report = run_pipeline(
        &op,
        &SampleSource::Shared(Arc::new(x.clone())),
        &PipelineConfig {
            wire,
            ..cfg.pipeline.clone()
        },
        cfg.seed,
    );
    eprintln!(
        "acquired {} samples in {:.3}s ({:.0}/s), {} wire bytes, {} backpressure stalls",
        report.samples,
        report.elapsed_secs,
        report.throughput(),
        report.payload_bytes,
        report.blocked_sends
    );

    let (lo, hi) = search_box(&parsed, Some(&x), x.cols())?;
    eprintln!("decoder: {}", cfg.decode.decoder.canonical());
    let sol = cfg.decode.decoder.decode_best_of(
        &op,
        cfg.decode.k,
        &report.sketch,
        lo,
        hi,
        &cfg.decode.params,
        cfg.decode.replicates,
        &mut rng,
    );
    let s = qckm::metrics::sse(&x, &sol.centroids);
    println!("objective = {:.6}, SSE/N = {:.6}", sol.objective, s / x.rows() as f64);
    print_centroids(&sol.centroids, &sol.weights);
    save_centroids(parsed.get("out"), &sol.centroids)
}
