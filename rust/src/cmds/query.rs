//! `qckm query` — decode centroids live from a serving node. The decoder
//! spec rides the protocol frame, and the server's centroid cache keys on
//! it, so a cached answer always matches the requested algorithm.

use super::common::{
    connect_with_method, print_centroids, save_centroids, scalar_box, DECODER_HELP, TENANT_HELP,
    TOKEN_HELP,
};
use anyhow::{Context, Result};
use qckm::cli::CliSpec;
use qckm::decoder::DecoderSpec;
use qckm::linalg::Mat;
use qckm::server::QuerySpec;

pub fn run(args: Vec<String>) -> Result<()> {
    let spec = CliSpec::new("qckm query", "decode centroids live from a serving node")
        .opt("addr", "HOST:PORT", None, "server address")
        .opt("k", "NUM", None, "number of clusters")
        .opt(
            "method",
            "SPEC",
            None,
            "declare the expected method; the server refuses a mismatch",
        )
        .opt("decoder", "SPEC", None, DECODER_HELP)
        .opt("tenant", "NAME", None, TENANT_HELP)
        .opt("token", "TOKEN", None, TOKEN_HELP)
        .opt(
            "window",
            "NUM",
            Some("0"),
            "epochs to pool: 0 = all-time, E = open epoch + E-1 newest closed",
        )
        .opt("replicates", "NUM", Some("1"), "decoder replicates (best objective wins)")
        .opt("seed", "NUM", None, "decoder RNG seed (default: the operator's seed)")
        .opt("lo", "FLOAT", Some("-1"), "centroid search box lower bound (every coordinate)")
        .opt("hi", "FLOAT", Some("1"), "centroid search box upper bound (every coordinate)")
        .opt("out", "FILE", None, "write centroids CSV here")
        .flag(
            "trace",
            "attach a trace context and print the server-side span tree \
             (JSON, stderr): frame decode, cap check, window merge, and \
             per-iteration decoder timings",
        );
    let parsed = spec.parse(args)?;
    let addr = parsed.get("addr").context("--addr is required")?;
    let k = parsed.get_usize("k")?.context("--k is required")?;
    let (lo, hi) = scalar_box(&parsed)?;
    // Canonicalize locally so junk fails fast with the registry list; an
    // absent flag sends the empty spec (= the server's default, clompr).
    let decoder = match parsed.get("decoder") {
        Some(s) => DecoderSpec::parse(s)?.canonical().to_string(),
        None => String::new(),
    };

    let mut client = connect_with_method(addr, &parsed)?;
    if parsed.flag("trace") {
        client = client.with_tracing(Box::new(qckm::obs::ProcessIdGen::new()));
    }
    let report = client.query(&QuerySpec {
        k: k as u32,
        window: parsed.get_usize("window")?.unwrap() as u32,
        replicates: parsed.get_usize("replicates")?.unwrap().max(1) as u32,
        seed: parsed.get_u64("seed")?,
        lo,
        hi,
        decoder,
    })?;
    eprintln!(
        "window: {} rows over {} epoch(s){}",
        report.rows,
        report.epochs,
        if report.cached { " [cached]" } else { "" }
    );
    println!("objective = {:.6}", report.objective);
    // The span tree is diagnostics, not output: stderr, like the window
    // summary, so `--out`/stdout pipelines stay byte-identical (I-19).
    if parsed.flag("trace") {
        if let Some(id) = client.last_trace_id() {
            eprintln!("{}", client.trace(Some(id), 1)?);
        }
    }
    let centroids = Mat::from_vec(report.k as usize, report.dim as usize, report.centroids);
    print_centroids(&centroids, &report.weights);
    save_centroids(parsed.get("out"), &centroids)
}
