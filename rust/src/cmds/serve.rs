//! `qckm serve` — the online sketch service (see `qckm::server`).

use super::common::{check_declared_method, job_from, METHOD_HELP};
use anyhow::{bail, Context, Result};
use qckm::cli::CliSpec;
use qckm::clompr::ClOmprParams;
use qckm::frequency::SigmaHeuristic;
use qckm::parallel::Parallelism;
use qckm::server::{self, ServiceConfig, SketchService};
use qckm::stream;
use std::path::Path;
use std::sync::Arc;

pub fn run(args: Vec<String>) -> Result<()> {
    let spec = CliSpec::new(
        "qckm serve",
        "run the online sketch service: concurrent ingest, windowed pooling, live decode",
    )
    .opt("host", "ADDR", Some("127.0.0.1"), "bind address")
    .opt("port", "NUM", Some("0"), "bind port (0 = ephemeral; the bound port is printed)")
    .opt("dim", "NUM", None, "data dimension (required unless --seed-sketch)")
    .opt("m", "NUM", None, "number of frequencies")
    .opt("method", "SPEC", None, METHOD_HELP)
    .opt("sigma", "FLOAT", None, "kernel bandwidth (required unless --seed-sketch)")
    .opt("seed", "NUM", None, "frequency-draw seed")
    .opt("threads", "NUM", None, "encode/decode threads (0 = all cores)")
    .opt("epochs", "NUM", Some("16"), "closed epochs retained for windowed queries")
    .opt("cache", "NUM", Some("32"), "cached decodes retained")
    .opt(
        "max-shards",
        "NUM",
        Some("1024"),
        "distinct shard labels accepted before new ones are refused",
    )
    .opt(
        "trace-ring",
        "NUM",
        Some("128"),
        "finished request traces retained for `qckm ctl trace`",
    )
    .opt(
        "seed-sketch",
        "FILE",
        None,
        "seed the server from this .qsk (operator comes from its header)",
    )
    .opt("seed-shard", "NAME", Some("__seed__"), "shard label for the seeded history")
    .opt("config", "FILE", None, "TOML job config")
    .flag("log-json", "emit structured JSON logs on stderr (same as QCKM_LOG=json)");
    let parsed = spec.parse(args)?;
    let cfg = job_from(&parsed)?;
    if parsed.flag("log-json") {
        qckm::obs::set_json(true, qckm::obs::Level::Info);
    }

    // The operator is fixed for the server's lifetime: either rebuilt from
    // a snapshot header (fingerprint-verified) or drawn fresh from the
    // CLI parameters — the same pure-function draw the offline stages use.
    let (meta, op, seed_pool) = match parsed.get("seed-sketch") {
        Some(path) => {
            let (meta, pool, prov) = stream::load_sketch_full(Path::new(path))?;
            // The operator comes entirely from the snapshot header; refuse
            // operator flags that contradict it (same convention as
            // `qckm sketch --append`) instead of silently ignoring them.
            if let Some(m) = parsed.get_usize("m")? {
                if m as u64 != meta.m {
                    bail!("--m {m} conflicts with {path} (m={})", meta.m);
                }
            }
            check_declared_method(&parsed, &meta.method, path)?;
            if let SigmaHeuristic::Fixed(sigma) = cfg.sketch.sigma {
                if sigma.to_bits() != meta.sigma.to_bits() {
                    bail!("--sigma {sigma} conflicts with {path} (sigma={})", meta.sigma);
                }
            }
            if let Some(seed) = parsed.get_u64("seed")? {
                if seed != meta.seed {
                    bail!("--seed {seed} conflicts with {path} (seed={})", meta.seed);
                }
            }
            let op = meta.rebuild_operator()?;
            eprintln!(
                "seeded from {path}: {} samples across {} provenance record(s)",
                pool.count(),
                prov.len()
            );
            (meta, op, Some(pool))
        }
        None => {
            let dim = parsed
                .get_usize("dim")?
                .context("--dim is required without --seed-sketch")?;
            let SigmaHeuristic::Fixed(sigma) = cfg.sketch.sigma else {
                bail!("--sigma is required without --seed-sketch (shards must agree on it)");
            };
            let op = stream::draw_operator(
                &cfg.sketch.method,
                cfg.sketch.law,
                cfg.sketch.num_frequencies,
                dim,
                sigma,
                cfg.seed,
            );
            let meta = stream::SketchMeta::for_operator(&op, &cfg.sketch.method, cfg.seed);
            (meta, op, None)
        }
    };
    eprintln!("operator: {}", meta.describe());

    // The server shares the process-global registry so a single
    // `ctl metrics` scrape covers every layer: request handling here,
    // plus the stream/decoder/parallel families the library registers
    // lazily. Touch them up front so the first scrape already lists the
    // full catalog, not just whatever stages have run.
    qckm::obs::lib_metrics();
    let service_cfg = ServiceConfig {
        epoch_capacity: parsed.get_usize("epochs")?.unwrap().max(1),
        cache_capacity: parsed.get_usize("cache")?.unwrap().max(1),
        max_shards: parsed.get_usize("max-shards")?.unwrap().max(1),
        threads: Parallelism::fixed(cfg.threads),
        decode: ClOmprParams {
            threads: cfg.threads,
            ..ClOmprParams::default()
        },
        registry: qckm::obs::global().clone(),
        trace_capacity: parsed.get_usize("trace-ring")?.unwrap().max(1),
    };
    let service = SketchService::new(op, meta, service_cfg);
    if let Some(pool) = seed_pool {
        service.seed_with(parsed.get("seed-shard").unwrap(), pool)?;
    }

    let host = parsed.get("host").unwrap();
    let port = parsed.get_usize("port")?.unwrap();
    if port > u16::MAX as usize {
        bail!("--port {port} out of range");
    }
    let listener = std::net::TcpListener::bind((host, port as u16))
        .with_context(|| format!("bind {host}:{port}"))?;
    // Machine-parseable: tests and scripts read the ephemeral port here.
    println!("LISTENING {}", listener.local_addr()?);
    std::io::Write::flush(&mut std::io::stdout())?;

    let served = server::serve(listener, Arc::new(service))?;
    eprintln!("server stopped after {served} connection(s)");
    Ok(())
}
