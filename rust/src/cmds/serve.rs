//! `qckm serve` — the online sketch service (see `qckm::server`).
//!
//! Two shapes:
//!
//! * **Single-tenant** (legacy): operator flags (`--dim --m --sigma …`)
//!   or `--seed-sketch` describe the one hosted sketch; pre-v6 clients
//!   are served byte-identically.
//! * **Multi-tenant**: one or more `--tenant name=specfile` declarations
//!   (or a `[tenants]` table in `--config`), each spec file a TOML job
//!   config plus top-level `dim` (required) and `token` (optional). Every
//!   tenant gets its own operator draw and state; clients address one
//!   with `--tenant`/`--token`.
//!
//! `--rate-limit RATE[:BURST]` arms a per-connection token bucket on
//! ingest frames (push/delta) in either shape; shed frames get a busy
//! reply with a retry-after hint that `--retry` clients sleep on.

use super::common::{check_declared_method, job_from, METHOD_HELP};
use anyhow::{bail, Context, Result};
use qckm::cli::CliSpec;
use qckm::clompr::ClOmprParams;
use qckm::config::JobConfig;
use qckm::frequency::SigmaHeuristic;
use qckm::parallel::Parallelism;
use qckm::server::{self, tenants, Node, RateLimit, ServiceConfig, SketchService};
use qckm::stream;
use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Arc;

pub fn run(args: Vec<String>) -> Result<()> {
    let spec = CliSpec::new(
        "qckm serve",
        "run the online sketch service: concurrent ingest, windowed pooling, live decode",
    )
    .opt("host", "ADDR", Some("127.0.0.1"), "bind address")
    .opt("port", "NUM", Some("0"), "bind port (0 = ephemeral; the bound port is printed)")
    .opt("dim", "NUM", None, "data dimension (required unless --seed-sketch / --tenant)")
    .opt("m", "NUM", None, "number of frequencies")
    .opt("method", "SPEC", None, METHOD_HELP)
    .opt("sigma", "FLOAT", None, "kernel bandwidth (required unless --seed-sketch)")
    .opt("seed", "NUM", None, "frequency-draw seed")
    .opt("threads", "NUM", None, "encode/decode threads (0 = all cores)")
    .opt("epochs", "NUM", Some("16"), "closed epochs retained for windowed queries")
    .opt("cache", "NUM", Some("32"), "cached decodes retained")
    .opt(
        "max-shards",
        "NUM",
        Some("1024"),
        "distinct shard labels accepted before new ones are refused",
    )
    .opt(
        "trace-ring",
        "NUM",
        Some("128"),
        "finished request traces retained for `qckm ctl trace`",
    )
    .opt(
        "seed-sketch",
        "FILE",
        None,
        "seed the server from this .qsk (operator comes from its header)",
    )
    .opt("seed-shard", "NAME", Some("__seed__"), "shard label for the seeded history")
    .multi(
        "tenant",
        "NAME=SPECFILE",
        "host a named tenant from a TOML spec file (repeatable); \
         spec = job config + top-level dim (required) and token (optional)",
    )
    .opt(
        "token",
        "TOKEN",
        None,
        "require this auth token on every scoped request (single-tenant mode)",
    )
    .opt(
        "rate-limit",
        "RATE[:BURST]",
        None,
        "per-connection ingest rate limit in frames/s (burst defaults to RATE)",
    )
    .opt("config", "FILE", None, "TOML job config (a [tenants] table declares tenants)")
    .flag("log-json", "emit structured JSON logs on stderr (same as QCKM_LOG=json)");
    let parsed = spec.parse(args)?;
    let cfg = job_from(&parsed)?;
    if parsed.flag("log-json") {
        qckm::obs::set_json(true, qckm::obs::Level::Info);
    }

    let rate = parsed.get("rate-limit").map(RateLimit::parse).transpose()?;

    // Tenant declarations: every --tenant flag, then the config file's
    // [tenants] table (flags win on a name collision — same precedence
    // as every other CLI-over-config override).
    let mut decls: Vec<(String, String)> = Vec::new();
    for d in parsed.get_all("tenant") {
        let Some((name, path)) = d.split_once('=') else {
            bail!("--tenant wants NAME=SPECFILE, got '{d}'");
        };
        decls.push((name.to_string(), path.to_string()));
    }
    if let Some(path) = parsed.get("config") {
        let text = std::fs::read_to_string(path).with_context(|| format!("read {path}"))?;
        let doc = qckm::config::parse_toml(&text).map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
        for key in doc.keys("tenants") {
            if decls.iter().any(|(n, _)| n == key) {
                continue;
            }
            let Some(file) = doc.get("tenants", key).and_then(|v| v.as_str()) else {
                bail!("{path}: [tenants] {key} must be a spec-file path string");
            };
            decls.push((key.to_string(), file.to_string()));
        }
    }

    // Shared per-node tuning; each tenant (or the single default service)
    // gets its own copy with its own identity fields.
    let base_cfg = ServiceConfig {
        epoch_capacity: parsed.get_usize("epochs")?.unwrap().max(1),
        cache_capacity: parsed.get_usize("cache")?.unwrap().max(1),
        max_shards: parsed.get_usize("max-shards")?.unwrap().max(1),
        threads: Parallelism::fixed(cfg.threads),
        decode: ClOmprParams {
            threads: cfg.threads,
            ..ClOmprParams::default()
        },
        registry: qckm::obs::global().clone(),
        trace_capacity: parsed.get_usize("trace-ring")?.unwrap().max(1),
        tenant: String::new(),
        token: None,
        default_decoder: String::new(),
    };
    // The server shares the process-global registry so a single
    // `ctl metrics` scrape covers every layer: request handling here,
    // plus the stream/decoder/parallel families the library registers
    // lazily. Touch them up front so the first scrape already lists the
    // full catalog, not just whatever stages have run.
    qckm::obs::lib_metrics();
    // One line so operators can see which encode path this box runs without
    // scraping the `qckm_kernel_info` gauge.
    eprintln!("compute kernels: {}", qckm::kernel::describe());

    let mut tenant_map: BTreeMap<String, Arc<SketchService>> = BTreeMap::new();
    if decls.is_empty() {
        tenant_map.insert(String::new(), Arc::new(single_service(&parsed, &cfg, &base_cfg)?));
    } else {
        if parsed.get("seed-sketch").is_some() {
            bail!("--seed-sketch only applies in single-tenant mode (put seeding in a tenant spec later)");
        }
        if parsed.get("token").is_some() {
            bail!("--token only applies in single-tenant mode (tenant spec files carry their own)");
        }
        for (name, path) in &decls {
            tenants::validate_tenant_name(name)?;
            if tenant_map.contains_key(name) {
                bail!("tenant '{name}' declared twice");
            }
            tenant_map.insert(name.clone(), Arc::new(tenant_service(name, path, &base_cfg)?));
        }
        eprintln!(
            "hosting {} tenant(s): {}",
            tenant_map.len(),
            tenant_map.keys().cloned().collect::<Vec<_>>().join(", ")
        );
    }

    let node = Node::new(tenant_map, rate, base_cfg.registry.clone())?;

    let host = parsed.get("host").unwrap();
    let port = parsed.get_usize("port")?.unwrap();
    if port > u16::MAX as usize {
        bail!("--port {port} out of range");
    }
    let listener = std::net::TcpListener::bind((host, port as u16))
        .with_context(|| format!("bind {host}:{port}"))?;
    // Machine-parseable: tests and scripts read the ephemeral port here.
    println!("LISTENING {}", listener.local_addr()?);
    std::io::Write::flush(&mut std::io::stdout())?;

    let served = server::serve_node(listener, Arc::new(node))?;
    eprintln!("server stopped after {served} connection(s)");
    Ok(())
}

/// Build the legacy single-tenant service from the operator flags /
/// `--seed-sketch`, exactly as before multi-tenancy (plus `--token`).
fn single_service(
    parsed: &qckm::cli::ParsedArgs,
    cfg: &JobConfig,
    base_cfg: &ServiceConfig,
) -> Result<SketchService> {
    // The operator is fixed for the server's lifetime: either rebuilt from
    // a snapshot header (fingerprint-verified) or drawn fresh from the
    // CLI parameters — the same pure-function draw the offline stages use.
    let (meta, op, seed_pool) = match parsed.get("seed-sketch") {
        Some(path) => {
            let (meta, pool, prov) = stream::load_sketch_full(Path::new(path))?;
            // The operator comes entirely from the snapshot header; refuse
            // operator flags that contradict it (same convention as
            // `qckm sketch --append`) instead of silently ignoring them.
            if let Some(m) = parsed.get_usize("m")? {
                if m as u64 != meta.m {
                    bail!("--m {m} conflicts with {path} (m={})", meta.m);
                }
            }
            check_declared_method(parsed, &meta.method, path)?;
            if let SigmaHeuristic::Fixed(sigma) = cfg.sketch.sigma {
                if sigma.to_bits() != meta.sigma.to_bits() {
                    bail!("--sigma {sigma} conflicts with {path} (sigma={})", meta.sigma);
                }
            }
            if let Some(seed) = parsed.get_u64("seed")? {
                if seed != meta.seed {
                    bail!("--seed {seed} conflicts with {path} (seed={})", meta.seed);
                }
            }
            let op = meta.rebuild_operator()?;
            eprintln!(
                "seeded from {path}: {} samples across {} provenance record(s)",
                pool.count(),
                prov.len()
            );
            (meta, op, Some(pool))
        }
        None => {
            let dim = parsed
                .get_usize("dim")?
                .context("--dim is required without --seed-sketch or --tenant")?;
            let SigmaHeuristic::Fixed(sigma) = cfg.sketch.sigma else {
                bail!("--sigma is required without --seed-sketch (shards must agree on it)");
            };
            let op = stream::draw_operator(
                &cfg.sketch.method,
                cfg.sketch.law,
                cfg.sketch.num_frequencies,
                dim,
                sigma,
                cfg.seed,
            );
            let meta = stream::SketchMeta::for_operator(&op, &cfg.sketch.method, cfg.seed);
            (meta, op, None)
        }
    };
    eprintln!("operator: {}", meta.describe());

    let service_cfg = ServiceConfig {
        token: parsed.get("token").map(str::to_string),
        ..base_cfg.clone()
    };
    let service = SketchService::new(op, meta, service_cfg);
    if let Some(pool) = seed_pool {
        service.seed_with(parsed.get("seed-shard").unwrap(), pool)?;
    }
    Ok(service)
}

/// Build one named tenant from its TOML spec file: a job config (method,
/// m, sigma, seed, decoder, threads) plus top-level `dim` (required) and
/// `token` (optional).
fn tenant_service(name: &str, path: &str, base_cfg: &ServiceConfig) -> Result<SketchService> {
    let (meta, op, token, job) = super::common::load_tenant_spec(name, path)?;
    let service_cfg = ServiceConfig {
        tenant: name.to_string(),
        token,
        default_decoder: job.decode.decoder.canonical().to_string(),
        decode: ClOmprParams {
            threads: job.threads,
            ..job.decode.params
        },
        threads: Parallelism::fixed(job.threads),
        ..base_cfg.clone()
    };
    Ok(SketchService::new(op, meta, service_cfg))
}
