//! Linear pooling of full-precision sketch contributions.

/// A running (sum, count) of sketch contributions.
///
/// The sketch is linear up to rescaling (`Φ_{S∪S'} = Φ_S + Φ_{S'}` on sums),
/// so shards can be pooled in any order, merged across machines, and updated
/// online for streams — exactly what the coordinator does.
#[derive(Clone, Debug)]
pub struct PooledSketch {
    sum: Vec<f64>,
    count: u64,
}

impl PooledSketch {
    pub fn new(len: usize) -> Self {
        Self {
            sum: vec![0.0; len],
            count: 0,
        }
    }

    /// Rebuild a pool from a previously exported (sum, count) pair — the
    /// deserialization side of the `.qsk` persistence format.
    pub fn from_raw(sum: Vec<f64>, count: u64) -> Self {
        Self { sum, count }
    }

    /// The raw running sum (serialize this, not the mean, so merges of
    /// persisted shards stay exact).
    pub fn sum(&self) -> &[f64] {
        &self.sum
    }

    pub fn len(&self) -> usize {
        self.sum.len()
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub(crate) fn sum_mut(&mut self) -> &mut [f64] {
        &mut self.sum
    }

    pub(crate) fn bump_count(&mut self, by: u64) {
        self.count += by;
    }

    /// Add one dense contribution.
    pub fn add(&mut self, z: &[f64]) {
        assert_eq!(z.len(), self.sum.len(), "contribution length mismatch");
        crate::linalg::axpy(1.0, z, &mut self.sum);
        self.count += 1;
    }

    /// Add a pre-summed shard (sum over `count` examples).
    pub fn add_sum(&mut self, sum: &[f64], count: u64) {
        assert_eq!(sum.len(), self.sum.len(), "shard length mismatch");
        crate::linalg::axpy(1.0, sum, &mut self.sum);
        self.count += count;
    }

    /// Merge another pool (distributed reduction).
    pub fn merge(&mut self, other: &PooledSketch) {
        self.add_sum(&other.sum, other.count);
    }

    /// Finalize: the mean sketch `z_X`.
    pub fn mean(&self) -> Vec<f64> {
        assert!(self.count > 0, "mean of empty sketch pool");
        let inv = 1.0 / self.count as f64;
        self.sum.iter().map(|s| s * inv).collect()
    }
}
