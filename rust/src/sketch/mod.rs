//! The sketch operator `A_f` — encode side and decode side.
//!
//! **Layout convention** (used everywhere in this crate): a sketch over `M`
//! frequencies is a real vector of length `2M`. For frequency `j` with
//! dither `ξ_j`, slot `2j` evaluates the signature at `ω_j^T x + ξ_j` and
//! slot `2j+1` at `ω_j^T x + ξ_j + π/2`. With the cosine signature and
//! `ξ = 0` this is exactly `(Re, −Im)` of the CKM complex measurement
//! `e^{−i ω_j^T x}`, and it is the paper's fair-comparison convention for
//! QCKM (Sec. 5: "two measurements with the same frequency ω_j but two
//! dithering values ξ_j and ξ_j + π/2").
//!
//! * **Encode** ([`SketchOperator::sketch_dataset`], [`encode_point`],
//!   [`BitSketch`]) uses the full signature `f` — this is what the sensor
//!   hardware of Fig. 1 computes, one bit per slot for QCKM.
//! * **Decode** ([`SketchOperator::atom`], [`atom_grad_accumulate`]) always
//!   uses the *first harmonic*: cosine atoms of amplitude `2|F_1|`
//!   (Prop. 1), shifted by the signature's first-harmonic phase `φ₁` when
//!   it has one (odd signatures like the modulo ramp — see
//!   [`crate::signature::Signature::first_harmonic_phase`]). A convenient
//!   consequence of the paired-slot layout is that
//!   `‖a(c)‖² = A²·M` for every `c` (cos² + sin² pairing), so normalized
//!   atoms need no per-candidate norm computation.
//!
//! [`encode_point`]: SketchOperator::encode_point

mod bits;
mod pooled;

pub use bits::{BitAggregator, BitSketch};
pub use pooled::PooledSketch;

use crate::frequency::DrawnFrequencies;
use crate::linalg::{dot, Mat};
use crate::parallel::Parallelism;
use crate::signature::{Signature, UniversalQuantizer};
use std::ops::Range;
use std::sync::Arc;

/// Fixed row-block size of the parallel encode ([`SketchOperator::sketch_into_par`]).
///
/// Part of the determinism contract (see [`crate::parallel`]): the dataset
/// is always cut at multiples of this constant — never at thread-count-
/// derived boundaries — and per-chunk partial pools are merged in chunk
/// order, so the pooled sketch is bit-for-bit identical at every thread
/// count. A multiple of the inner encode batch (64 rows) so each chunk's
/// fold matches the serial fold exactly.
pub const PAR_CHUNK_ROWS: usize = 4096;

/// A fully specified sketch operator: frequencies + dithers + signature.
#[derive(Clone)]
pub struct SketchOperator {
    freqs: Arc<DrawnFrequencies>,
    signature: Arc<dyn Signature>,
    /// Decode-atom amplitude `2|F_1|` (cached).
    amplitude: f64,
    /// Decode-atom phase `φ₁` of `f1(t) = 2|F_1| cos(t + φ₁)` (cached).
    /// Zero for every even signature; the modulo ramp's sine-led first
    /// harmonic lands here, and every atom argument below adds it.
    phase: f64,
}

impl SketchOperator {
    pub fn new(freqs: DrawnFrequencies, signature: Arc<dyn Signature>) -> Self {
        let amplitude = signature.first_harmonic_amplitude();
        assert!(
            amplitude > 0.0,
            "signature '{}' has vanishing first harmonic",
            signature.name()
        );
        let phase = signature.first_harmonic_phase();
        Self {
            freqs: Arc::new(freqs),
            signature,
            amplitude,
            phase,
        }
    }

    /// Convenience: the paper's QCKM operator (1-bit universal quantizer).
    pub fn quantized(freqs: DrawnFrequencies) -> Self {
        Self::new(freqs, Arc::new(UniversalQuantizer))
    }

    /// Data dimension `n`.
    pub fn dim(&self) -> usize {
        self.freqs.dim()
    }

    /// Number of frequencies `M` (the sketch has `2M` real slots).
    pub fn num_frequencies(&self) -> usize {
        self.freqs.num_frequencies()
    }

    /// Length of the sketch vector (`2M`).
    pub fn sketch_len(&self) -> usize {
        2 * self.num_frequencies()
    }

    pub fn frequencies(&self) -> &DrawnFrequencies {
        &self.freqs
    }

    pub fn signature(&self) -> &dyn Signature {
        self.signature.as_ref()
    }

    /// Decode-atom amplitude `A = 2|F_1|`.
    pub fn amplitude(&self) -> f64 {
        self.amplitude
    }

    /// `‖a(c)‖ = A√M`, constant in `c` thanks to the slot pairing.
    pub fn atom_norm(&self) -> f64 {
        self.amplitude * (self.num_frequencies() as f64).sqrt()
    }

    /// Projections `ω_j^T x` for all j (helper; hot paths use batched gemm).
    ///
    /// Branchless on purpose: a zero coordinate's axpy adds exact zeros
    /// (finite Ω, and no accumulator here can reach `−0.0`), so skipping it
    /// cannot change a bit — but the skip branch defeats vectorization of
    /// the inner loop, which [`crate::kernel::axpy`] dispatches wide.
    fn project(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.dim(), "point dimension mismatch");
        let om = &self.freqs.omega;
        let m = om.cols();
        let mut t = vec![0.0; m];
        for (r, &xr) in x.iter().enumerate() {
            crate::kernel::axpy(xr, om.row(r), &mut t);
        }
        t
    }

    /// Encode one example with the full signature: the sensor-side map
    /// `z(x)_{2j+p} = f(ω_j^T x + ξ_j + pπ/2)`.
    pub fn encode_point(&self, x: &[f64]) -> Vec<f64> {
        let mut args = self.project(x);
        let m = args.len();
        for (a, &xi) in args.iter_mut().zip(&self.freqs.xi) {
            *a += xi;
        }
        let mut v0 = vec![0.0; m];
        let mut v1 = vec![0.0; m];
        self.signature.eval_pair_batch(&args, &mut v0, &mut v1);
        let mut z = vec![0.0; 2 * m];
        for j in 0..m {
            z[2 * j] = v0[j];
            z[2 * j + 1] = v1[j];
        }
        z
    }

    /// Encode one example to a packed 1-bit contribution (QCKM hardware
    /// path, Fig. 1 b–d). Panics if the signature is not ±1-valued.
    pub fn encode_point_bits(&self, x: &[f64]) -> BitSketch {
        let mut args = self.project(x);
        let m = args.len();
        for (a, &xi) in args.iter_mut().zip(&self.freqs.xi) {
            *a += xi;
        }
        let mut bits = BitSketch::zeros(2 * m);
        if crate::kernel::mode() == crate::kernel::KernelMode::Wide && self.signature.is_binary() {
            // Sign-bit kernel: no f64 signature values are materialized.
            // Identical bits by the `is_binary` contract (sign == value > 0,
            // same cell formula — I-22).
            let mut s0 = vec![false; m];
            let mut s1 = vec![false; m];
            self.signature.eval_pair_sign_batch(&args, &mut s0, &mut s1);
            for j in 0..m {
                bits.set(2 * j, s0[j]);
                bits.set(2 * j + 1, s1[j]);
            }
            return bits;
        }
        let mut v0 = vec![0.0; m];
        let mut v1 = vec![0.0; m];
        self.signature.eval_pair_batch(&args, &mut v0, &mut v1);
        for j in 0..m {
            debug_assert!(
                v0[j].abs() == 1.0 && v1[j].abs() == 1.0,
                "bit encoding requires a ±1-valued signature, got '{}'",
                self.signature.name()
            );
            bits.set(2 * j, v0[j] > 0.0);
            bits.set(2 * j + 1, v1[j] > 0.0);
        }
        bits
    }

    /// Pooled sketch of a whole dataset (`N × n` row-major), i.e.
    /// `z_X = (1/N) Σ_i z(x_i)`, computed in row batches through a blocked
    /// gemm so the Ω panel stays cache-resident.
    pub fn sketch_dataset(&self, x: &Mat) -> Vec<f64> {
        let mut pool = PooledSketch::new(self.sketch_len());
        self.sketch_into(x, &mut pool);
        pool.mean()
    }

    /// Accumulate the (sum, count) of contributions of `x` into `pool`
    /// without finalizing — the streaming/distributed entry point.
    pub fn sketch_into(&self, x: &Mat, pool: &mut PooledSketch) {
        self.sketch_range_into(x, 0..x.rows(), pool);
    }

    /// Like [`sketch_into`](Self::sketch_into), restricted to the row range
    /// `rows` of `x` — the per-chunk work unit of the parallel encode.
    pub fn sketch_range_into(&self, x: &Mat, rows: Range<usize>, pool: &mut PooledSketch) {
        assert_eq!(x.cols(), self.dim(), "dataset dimension mismatch");
        assert_eq!(pool.len(), self.sketch_len());
        assert!(
            rows.start <= rows.end && rows.end <= x.rows(),
            "row range {rows:?} out of bounds for {} rows",
            x.rows()
        );
        // ±1 signatures take the transposed bit-panel kernel: same
        // projections, then popcount pooling instead of an f64 fold —
        // bit-for-bit identical (I-22, see `crate::kernel::bitpanel`).
        if crate::kernel::mode() == crate::kernel::KernelMode::Wide && self.signature.is_binary() {
            let count = rows.len() as u64;
            crate::kernel::bitpanel::pool_dense_range(
                &self.freqs.omega,
                &self.freqs.xi,
                self.signature.as_ref(),
                x,
                rows,
                pool.sum_mut(),
            );
            pool.bump_count(count);
            return;
        }
        const BATCH: usize = 64;
        let m = self.num_frequencies();
        let om = &self.freqs.omega;
        let mut proj = vec![0.0; BATCH * m];
        let mut v0 = vec![0.0; m];
        let mut v1 = vec![0.0; m];
        let mut acc0 = vec![0.0; m];
        let mut acc1 = vec![0.0; m];
        let mut row = rows.start;
        while row < rows.end {
            let b = BATCH.min(rows.end - row);
            // proj[b × M] = X[row..row+b] · Ω  (ikj, Ω rows streamed),
            // with the dither ξ pre-added to each row's projections.
            // Branchless over zero coordinates — see `project` — so the
            // dispatched wide axpy runs unconditionally.
            for i in 0..b {
                proj[i * m..(i + 1) * m].copy_from_slice(&self.freqs.xi);
            }
            for i in 0..b {
                let xrow = x.row(row + i);
                let dst = &mut proj[i * m..(i + 1) * m];
                for (r, &xr) in xrow.iter().enumerate() {
                    crate::kernel::axpy(xr, om.row(r), dst);
                }
            }
            // Apply the signature at both dither offsets (batched — one
            // dynamic dispatch per row, not per slot) and accumulate into
            // contiguous per-offset accumulators; the strided interleave
            // into the pool happens once per batch, not once per row.
            acc0.fill(0.0);
            acc1.fill(0.0);
            for i in 0..b {
                let args = &proj[i * m..(i + 1) * m];
                self.signature.eval_pair_batch(args, &mut v0, &mut v1);
                crate::kernel::axpy(1.0, &v0, &mut acc0);
                crate::kernel::axpy(1.0, &v1, &mut acc1);
            }
            let sum = pool.sum_mut();
            for j in 0..m {
                sum[2 * j] += acc0[j];
                sum[2 * j + 1] += acc1[j];
            }
            pool.bump_count(b as u64);
            row += b;
        }
    }

    /// Pool the packed-bit contributions of rows `rows` of `x` into `agg` —
    /// the acquisition-side analog of
    /// [`sketch_range_into`](Self::sketch_range_into), used by the streaming
    /// `PackedBits` fold.
    ///
    /// In the wide kernel mode ±1 signatures go through the transposed
    /// bit-panel ([`crate::kernel::bitpanel::pool_bits_range`]); otherwise
    /// (and for non-±1 signatures, which
    /// [`encode_point_bits`](Self::encode_point_bits) rejects) each row is
    /// encoded and added individually. Identical one-counts and count
    /// either way (I-22).
    pub fn pool_bits_range(&self, x: &Mat, rows: Range<usize>, agg: &mut BitAggregator) {
        assert_eq!(x.cols(), self.dim(), "dataset dimension mismatch");
        assert_eq!(agg.len(), self.sketch_len());
        assert!(
            rows.start <= rows.end && rows.end <= x.rows(),
            "row range {rows:?} out of bounds for {} rows",
            x.rows()
        );
        if crate::kernel::mode() == crate::kernel::KernelMode::Wide && self.signature.is_binary() {
            crate::kernel::bitpanel::pool_bits_range(
                &self.freqs.omega,
                &self.freqs.xi,
                self.signature.as_ref(),
                x,
                rows,
                agg,
            );
            return;
        }
        for r in rows {
            agg.add(&self.encode_point_bits(x.row(r)));
        }
    }

    /// Pooled sketch of a whole dataset, sharded across up to `par` threads
    /// in fixed [`PAR_CHUNK_ROWS`]-row blocks.
    ///
    /// Bit-for-bit identical to [`sketch_dataset`](Self::sketch_dataset) for
    /// datasets of at most one chunk, and — by the determinism contract of
    /// [`crate::parallel`] — identical across **all** thread counts for any
    /// dataset: chunk boundaries are fixed by the row count alone and the
    /// per-chunk partial pools are merged in chunk order.
    pub fn sketch_dataset_par(&self, x: &Mat, par: &Parallelism) -> Vec<f64> {
        let mut pool = PooledSketch::new(self.sketch_len());
        self.sketch_into_par(x, &mut pool, par);
        pool.mean()
    }

    /// Accumulate the contributions of every row of `x` into `pool` using
    /// up to `par` threads (see [`sketch_dataset_par`](Self::sketch_dataset_par)).
    pub fn sketch_into_par(&self, x: &Mat, pool: &mut PooledSketch, par: &Parallelism) {
        assert_eq!(x.cols(), self.dim(), "dataset dimension mismatch");
        assert_eq!(pool.len(), self.sketch_len());
        let partials = crate::parallel::run_chunked(x.rows(), PAR_CHUNK_ROWS, par, |_, rows| {
            let mut partial = PooledSketch::new(self.sketch_len());
            self.sketch_range_into(x, rows, &mut partial);
            partial
        });
        // Ordered merge: the floating-point reduction order is fixed.
        for partial in &partials {
            pool.merge(partial);
        }
    }

    /// Decode-atom phase `φ₁` (0 for even signatures).
    pub fn phase(&self) -> f64 {
        self.phase
    }

    /// Decode-side atom `a(c)_{2j+p} = A·cos(ω_j^T c + ξ_j + φ₁ + pπ/2)`.
    ///
    /// (`φ₁` is the signature's first-harmonic phase — 0 for every even
    /// signature, where `+ 0.0` is a bitwise no-op since no reachable
    /// argument is `−0.0`.)
    pub fn atom(&self, c: &[f64]) -> Vec<f64> {
        let t = self.project(c);
        let mut a = vec![0.0; 2 * t.len()];
        for (j, &tj) in t.iter().enumerate() {
            let arg = tj + self.freqs.xi[j] + self.phase;
            let (s, co) = arg.sin_cos();
            a[2 * j] = self.amplitude * co;
            a[2 * j + 1] = -self.amplitude * s; // cos(arg + π/2) = −sin(arg)
        }
        a
    }

    /// Fused atom + v-weighted Jacobian transpose:
    /// returns `a(c)` and accumulates `J(c)ᵀ v` into `grad` (overwritten),
    /// where `J(c)_{2j+p, ·} = ∂a_{2j+p}/∂c = −A·sin(θ_{j,p})·ω_jᵀ`.
    ///
    /// This is the decoder's hottest call (Step 1 / Step 5 objective +
    /// gradient evaluations): one `ω^T c` projection and one sin_cos pass
    /// serve both outputs.
    pub fn atom_and_jtv(&self, c: &[f64], v: &[f64], grad: &mut [f64]) -> Vec<f64> {
        assert_eq!(v.len(), self.sketch_len());
        assert_eq!(grad.len(), self.dim());
        let t = self.project(c);
        let m = t.len();
        let mut a = vec![0.0; 2 * m];
        // w_j = −A (v_{2j} sinθ_j − v_{2j+1} cosθ_j); grad = Ω w = Σ_j w_j ω_j.
        let mut w = vec![0.0; m];
        for (j, &tj) in t.iter().enumerate() {
            let arg = tj + self.freqs.xi[j] + self.phase;
            let (s, co) = arg.sin_cos();
            a[2 * j] = self.amplitude * co;
            a[2 * j + 1] = -self.amplitude * s;
            // θ_{j,0} = arg (sin), θ_{j,1} = arg + π/2 (sin = cos(arg)).
            w[j] = -self.amplitude * (v[2 * j] * s + v[2 * j + 1] * co);
        }
        // grad = Ω · w  (Ω is n × M row-major → row r dot w).
        let om = &self.freqs.omega;
        for r in 0..self.dim() {
            grad[r] = dot(om.row(r), &w);
        }
        a
    }

    /// `J(c)ᵀ v` computed *from an already-evaluated atom* — trig-free.
    ///
    /// The paired-slot atom stores `a_{2j} = A cos θ_j`, `a_{2j+1} =
    /// −A sin θ_j`, so the Jacobian weights `w_j = −A (v_{2j} sin θ_j +
    /// v_{2j+1} cos θ_j)` reduce to `v_{2j}·a_{2j+1} − v_{2j+1}·a_{2j}`
    /// and `JᵀV = Ω w` costs one gemv. Step 5 of CL-OMPR uses this to
    /// evaluate objective + full gradient with a single sincos pass per
    /// atom (EXPERIMENTS.md §Perf).
    pub fn jtv_from_atom(&self, atom: &[f64], v: &[f64], grad: &mut [f64]) {
        assert_eq!(atom.len(), self.sketch_len());
        assert_eq!(v.len(), self.sketch_len());
        assert_eq!(grad.len(), self.dim());
        let m = self.num_frequencies();
        let mut w = vec![0.0; m];
        for j in 0..m {
            w[j] = v[2 * j] * atom[2 * j + 1] - v[2 * j + 1] * atom[2 * j];
        }
        let om = &self.freqs.omega;
        for r in 0..self.dim() {
            grad[r] = dot(om.row(r), &w);
        }
    }

    /// The exact expected sketch of a Dirac mixture under the *first
    /// harmonic* operator: `A_{f1}(Σ_k α_k δ_{c_k}) = Σ_k α_k a(c_k)`.
    pub fn mixture_sketch(&self, centroids: &Mat, weights: &[f64]) -> Vec<f64> {
        assert_eq!(centroids.rows(), weights.len());
        let mut z = vec![0.0; self.sketch_len()];
        for (k, &alpha) in weights.iter().enumerate() {
            let a = self.atom(centroids.row(k));
            crate::linalg::axpy(alpha, &a, &mut z);
        }
        z
    }
}

#[cfg(test)]
mod tests;
