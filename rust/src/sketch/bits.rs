//! Bit-packed 1-bit sketch contributions — the QCKM acquisition format.
//!
//! A [`BitSketch`] is one example's m-bit contribution (Fig. 1d: the sign
//! `+1` is stored as bit 1, `−1` as bit 0). A [`BitAggregator`] pools many
//! contributions into per-slot one-counts, from which the real-valued
//! dataset sketch `z_{X,q} ∈ [−1,1]^{2M}` is recovered exactly:
//! `z_j = 2·ones_j/N − 1`.
//!
//! This is the wire format the L3 coordinator streams from sensor workers to
//! the aggregator: `⌈2M/64⌉` words per example instead of `2M` doubles —
//! a 64× acquisition-bandwidth reduction, which is the paper's point.

/// A packed vector of `len` bits.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BitSketch {
    len: usize,
    words: Vec<u64>,
}

impl BitSketch {
    /// All-zero (all −1) contribution of `len` bits.
    pub fn zeros(len: usize) -> Self {
        Self {
            len,
            words: vec![0u64; len.div_ceil(64)],
        }
    }

    /// Number of bits.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Packed words (little-endian bit order within each word).
    pub fn words(&self) -> &[u64] {
        &self.words
    }

    /// Size of the packed payload in bytes (what goes over the wire).
    pub fn payload_bytes(&self) -> usize {
        self.words.len() * 8
    }

    #[inline]
    pub fn set(&mut self, i: usize, v: bool) {
        debug_assert!(i < self.len);
        let w = i / 64;
        let b = i % 64;
        if v {
            self.words[w] |= 1u64 << b;
        } else {
            self.words[w] &= !(1u64 << b);
        }
    }

    #[inline]
    pub fn get(&self, i: usize) -> bool {
        debug_assert!(i < self.len);
        (self.words[i / 64] >> (i % 64)) & 1 == 1
    }

    /// Number of set bits.
    pub fn count_ones(&self) -> u32 {
        self.words.iter().map(|w| w.count_ones()).sum()
    }

    /// Expand to the dense ±1 representation.
    pub fn to_dense(&self) -> Vec<f64> {
        (0..self.len)
            .map(|i| if self.get(i) { 1.0 } else { -1.0 })
            .collect()
    }

    /// Hamming distance to another contribution (same length).
    ///
    /// Universal quantized embeddings preserve local Euclidean distances in
    /// Hamming space (Boufounos & Rane) — exercised by the tests.
    pub fn hamming(&self, other: &BitSketch) -> u32 {
        assert_eq!(self.len, other.len, "hamming: length mismatch");
        self.words
            .iter()
            .zip(&other.words)
            .map(|(a, b)| (a ^ b).count_ones())
            .sum()
    }
}

/// Pools bit contributions into exact per-slot one-counts.
#[derive(Clone, Debug)]
pub struct BitAggregator {
    ones: Vec<u64>,
    count: u64,
    len: usize,
}

impl BitAggregator {
    pub fn new(len: usize) -> Self {
        Self {
            ones: vec![0u64; len],
            count: 0,
            len,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Number of pooled contributions.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Pool one contribution.
    pub fn add(&mut self, s: &BitSketch) {
        assert_eq!(s.len(), self.len, "aggregator length mismatch");
        // Unpack word-by-word; the trailing partial word is masked by `len`.
        for (w, &word) in s.words().iter().enumerate() {
            if word == 0 {
                continue;
            }
            let base = w * 64;
            let top = (self.len - base).min(64);
            let mut bits = word;
            while bits != 0 {
                let b = bits.trailing_zeros() as usize;
                if b >= top {
                    break;
                }
                self.ones[base + b] += 1;
                bits &= bits - 1;
            }
        }
        self.count += 1;
    }

    /// Merge another aggregator (the sketch's linearity: distributed pooling).
    pub fn merge(&mut self, other: &BitAggregator) {
        assert_eq!(self.len, other.len, "aggregator length mismatch");
        for (a, b) in self.ones.iter_mut().zip(&other.ones) {
            *a += b;
        }
        self.count += other.count;
    }

    /// The exact pooled real sketch: `z_j = 2·ones_j/count − 1`.
    pub fn mean(&self) -> Vec<f64> {
        assert!(self.count > 0, "mean of empty aggregator");
        let n = self.count as f64;
        self.ones.iter().map(|&o| 2.0 * o as f64 / n - 1.0).collect()
    }

    /// (sum of ±1 contributions, count) — for merging into a
    /// [`super::PooledSketch`] alongside full-precision shards.
    pub fn to_sum(&self) -> (Vec<f64>, u64) {
        let n = self.count as f64;
        let _ = n;
        (
            self.ones
                .iter()
                .map(|&o| 2.0 * o as f64 - self.count as f64)
                .collect(),
            self.count,
        )
    }
}
