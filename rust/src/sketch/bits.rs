//! Bit-packed 1-bit sketch contributions — the QCKM acquisition format.
//!
//! A [`BitSketch`] is one example's m-bit contribution (Fig. 1d: the sign
//! `+1` is stored as bit 1, `−1` as bit 0). A [`BitAggregator`] pools many
//! contributions into per-slot one-counts, from which the real-valued
//! dataset sketch `z_{X,q} ∈ [−1,1]^{2M}` is recovered exactly:
//! `z_j = 2·ones_j/N − 1`.
//!
//! This is the wire format the L3 coordinator streams from sensor workers to
//! the aggregator: `⌈2M/64⌉` words per example instead of `2M` doubles —
//! a 64× acquisition-bandwidth reduction, which is the paper's point.

/// A packed vector of `len` bits.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BitSketch {
    len: usize,
    words: Vec<u64>,
}

impl BitSketch {
    /// All-zero (all −1) contribution of `len` bits.
    pub fn zeros(len: usize) -> Self {
        Self {
            len,
            words: vec![0u64; len.div_ceil(64)],
        }
    }

    /// Number of bits.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Packed words (little-endian bit order within each word).
    pub fn words(&self) -> &[u64] {
        &self.words
    }

    /// Size of the packed payload in bytes (what goes over the wire).
    pub fn payload_bytes(&self) -> usize {
        self.words.len() * 8
    }

    #[inline]
    pub fn set(&mut self, i: usize, v: bool) {
        debug_assert!(i < self.len);
        let w = i / 64;
        let b = i % 64;
        if v {
            self.words[w] |= 1u64 << b;
        } else {
            self.words[w] &= !(1u64 << b);
        }
    }

    #[inline]
    pub fn get(&self, i: usize) -> bool {
        debug_assert!(i < self.len);
        (self.words[i / 64] >> (i % 64)) & 1 == 1
    }

    /// Number of set bits.
    pub fn count_ones(&self) -> u32 {
        self.words.iter().map(|w| w.count_ones()).sum()
    }

    /// Expand to the dense ±1 representation.
    pub fn to_dense(&self) -> Vec<f64> {
        // Word-wise unpack: one shift/mask per bit off a register-resident
        // word instead of a bounds-checked `get()` per bit.
        let mut out = Vec::with_capacity(self.len);
        for (w, &word) in self.words.iter().enumerate() {
            let top = (self.len - w * 64).min(64);
            for b in 0..top {
                out.push(2.0 * ((word >> b) & 1) as f64 - 1.0);
            }
        }
        out
    }

    /// Hamming distance to another contribution (same length).
    ///
    /// Universal quantized embeddings preserve local Euclidean distances in
    /// Hamming space (Boufounos & Rane) — exercised by the tests.
    pub fn hamming(&self, other: &BitSketch) -> u32 {
        assert_eq!(self.len, other.len, "hamming: length mismatch");
        self.words
            .iter()
            .zip(&other.words)
            .map(|(a, b)| (a ^ b).count_ones())
            .sum()
    }
}

/// Pools bit contributions into exact per-slot one-counts.
#[derive(Clone, Debug)]
pub struct BitAggregator {
    ones: Vec<u64>,
    count: u64,
    len: usize,
}

impl BitAggregator {
    pub fn new(len: usize) -> Self {
        Self {
            ones: vec![0u64; len],
            count: 0,
            len,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Number of pooled contributions.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Pool one contribution.
    pub fn add(&mut self, s: &BitSketch) {
        assert_eq!(s.len(), self.len, "aggregator length mismatch");
        // Branch-free word-wise unpack: sketch bits are ~50% dense (each is
        // a dithered sign), so iterating set bits via `trailing_zeros` costs
        // more than unconditionally adding every bit of the word — and the
        // unit-stride `+= (word >> b) & 1` loop vectorizes.
        for (w, &word) in s.words().iter().enumerate() {
            let base = w * 64;
            let top = (self.len - base).min(64);
            for (b, o) in self.ones[base..base + top].iter_mut().enumerate() {
                *o += (word >> b) & 1;
            }
        }
        self.count += 1;
    }

    /// Pool a transposed bit panel: bit `i` of `panel0[j]` / `panel1[j]` is
    /// example `i`'s contribution to slot `2j` / `2j+1`, for `i < rows ≤ 64`
    /// (bits at and above `rows` must be zero). One `count_ones()` per slot
    /// pools the whole panel — the word-level parallelism the 1-bit format
    /// was chosen for; see [`crate::kernel::bitpanel`].
    ///
    /// Equivalent to `rows` individual [`add`](Self::add) calls with the
    /// panel's columns.
    pub fn add_panel(&mut self, panel0: &[u64], panel1: &[u64], rows: u32) {
        assert_eq!(panel0.len(), panel1.len(), "panel length mismatch");
        assert_eq!(2 * panel0.len(), self.len, "aggregator length mismatch");
        assert!(rows as usize <= 64, "panel holds at most 64 rows");
        debug_assert!(
            rows == 64 || panel0.iter().chain(panel1).all(|&w| w >> rows == 0),
            "panel bits above `rows` must be zero"
        );
        for (j, (&w0, &w1)) in panel0.iter().zip(panel1).enumerate() {
            self.ones[2 * j] += u64::from(w0.count_ones());
            self.ones[2 * j + 1] += u64::from(w1.count_ones());
        }
        self.count += u64::from(rows);
    }

    /// Merge another aggregator (the sketch's linearity: distributed pooling).
    pub fn merge(&mut self, other: &BitAggregator) {
        assert_eq!(self.len, other.len, "aggregator length mismatch");
        for (a, b) in self.ones.iter_mut().zip(&other.ones) {
            *a += b;
        }
        self.count += other.count;
    }

    /// The exact pooled real sketch: `z_j = 2·ones_j/count − 1`.
    pub fn mean(&self) -> Vec<f64> {
        assert!(self.count > 0, "mean of empty aggregator");
        let n = self.count as f64;
        self.ones.iter().map(|&o| 2.0 * o as f64 / n - 1.0).collect()
    }

    /// (sum of ±1 contributions, count) — for merging into a
    /// [`super::PooledSketch`] alongside full-precision shards.
    pub fn to_sum(&self) -> (Vec<f64>, u64) {
        (
            self.ones
                .iter()
                .map(|&o| 2.0 * o as f64 - self.count as f64)
                .collect(),
            self.count,
        )
    }
}
