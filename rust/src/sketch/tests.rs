//! Tests for sketch encode/decode, bit packing and pooling.

use super::*;
use crate::frequency::{DrawnFrequencies, FrequencyLaw};
use crate::linalg::{norm2, sq_dist, Mat};
use crate::rng::Rng;
use crate::signature::{Cosine, ModuloRamp, Triangle, UniversalQuantizer};
use std::f64::consts::PI;
use std::sync::Arc;

fn op(signature: Arc<dyn crate::signature::Signature>, n: usize, m: usize, seed: u64) -> SketchOperator {
    let mut rng = Rng::new(seed);
    let freqs = DrawnFrequencies::draw(FrequencyLaw::Gaussian, n, m, 1.0, &mut rng);
    SketchOperator::new(freqs, signature)
}

#[test]
fn dims_and_amplitudes() {
    let o = op(Arc::new(UniversalQuantizer), 3, 17, 1);
    assert_eq!(o.dim(), 3);
    assert_eq!(o.num_frequencies(), 17);
    assert_eq!(o.sketch_len(), 34);
    assert!((o.amplitude() - 4.0 / PI).abs() < 1e-12);
    let c = op(Arc::new(Cosine), 3, 17, 1);
    assert!((c.amplitude() - 1.0).abs() < 1e-12);
}

#[test]
fn cosine_encode_matches_complex_exponential() {
    // With ξ = 0 and the cosine signature, slots (2j, 2j+1) must equal
    // (Re, −Im) of e^{−i ω_j^T x} = (cos ω^Tx, −sin ω^Tx)... slot 2j+1 is
    // cos(ω^Tx + π/2) = −sin(ω^Tx). Exactly CKM's measurement.
    let mut rng = Rng::new(2);
    let freqs = DrawnFrequencies::draw_undithered(FrequencyLaw::Gaussian, 4, 25, 1.0, &mut rng);
    let o = SketchOperator::new(freqs.clone(), Arc::new(Cosine));
    let x: Vec<f64> = (0..4).map(|_| rng.gaussian()).collect();
    let z = o.encode_point(&x);
    for j in 0..25 {
        let t: f64 = (0..4).map(|r| freqs.omega.get(r, j) * x[r]).sum();
        assert!((z[2 * j] - t.cos()).abs() < 1e-12);
        assert!((z[2 * j + 1] + t.sin()).abs() < 1e-12);
    }
}

#[test]
fn quantized_encode_is_sign_of_cosine_encode() {
    let o_q = op(Arc::new(UniversalQuantizer), 5, 40, 3);
    let o_c = op(Arc::new(Cosine), 5, 40, 3); // same seed → same freqs/dither
    let mut rng = Rng::new(10);
    let x: Vec<f64> = (0..5).map(|_| rng.gaussian()).collect();
    let zq = o_q.encode_point(&x);
    let zc = o_c.encode_point(&x);
    for (q, c) in zq.iter().zip(&zc) {
        if c.abs() > 1e-9 {
            assert_eq!(*q, c.signum());
        }
        assert!(q.abs() == 1.0);
    }
}

#[test]
fn bit_encoding_round_trips_to_dense() {
    let o = op(Arc::new(UniversalQuantizer), 6, 33, 4); // odd → partial word
    let mut rng = Rng::new(11);
    let x: Vec<f64> = (0..6).map(|_| rng.gaussian()).collect();
    let bits = o.encode_point_bits(&x);
    assert_eq!(bits.len(), 66);
    assert_eq!(bits.payload_bytes(), 16); // ⌈66/64⌉ = 2 words
    assert_eq!(bits.to_dense(), o.encode_point(&x));
}

#[test]
fn dataset_sketch_equals_mean_of_contributions() {
    let o = op(Arc::new(Triangle), 3, 20, 5);
    let mut rng = Rng::new(12);
    let x = Mat::from_fn(130, 3, |_, _| rng.gaussian()); // non-multiple of batch
    let z = o.sketch_dataset(&x);
    let mut want = vec![0.0; o.sketch_len()];
    for i in 0..x.rows() {
        let zi = o.encode_point(x.row(i));
        crate::linalg::axpy(1.0 / 130.0, &zi, &mut want);
    }
    for (a, b) in z.iter().zip(&want) {
        assert!((a - b).abs() < 1e-10);
    }
}

#[test]
fn sketch_linearity_pooling_and_merge() {
    let o = op(Arc::new(UniversalQuantizer), 4, 15, 6);
    let mut rng = Rng::new(13);
    let x = Mat::from_fn(100, 4, |_, _| rng.gaussian());
    let full = o.sketch_dataset(&x);

    // Split into two shards, pool separately, merge.
    let x1 = x.select_rows(&(0..37).collect::<Vec<_>>());
    let x2 = x.select_rows(&(37..100).collect::<Vec<_>>());
    let mut p1 = PooledSketch::new(o.sketch_len());
    let mut p2 = PooledSketch::new(o.sketch_len());
    o.sketch_into(&x1, &mut p1);
    o.sketch_into(&x2, &mut p2);
    p1.merge(&p2);
    assert_eq!(p1.count(), 100);
    let merged = p1.mean();
    for (a, b) in merged.iter().zip(&full) {
        assert!((a - b).abs() < 1e-10, "merge deviates");
    }
}

#[test]
fn bit_aggregator_matches_dense_pooling() {
    let o = op(Arc::new(UniversalQuantizer), 4, 21, 7);
    let mut rng = Rng::new(14);
    let x = Mat::from_fn(64, 4, |_, _| rng.gaussian());
    let dense = o.sketch_dataset(&x);
    let mut agg = BitAggregator::new(o.sketch_len());
    for i in 0..x.rows() {
        agg.add(&o.encode_point_bits(x.row(i)));
    }
    assert_eq!(agg.count(), 64);
    for (a, b) in agg.mean().iter().zip(&dense) {
        assert!((a - b).abs() < 1e-12, "bit pooling exactness");
    }
    // to_sum feeds a PooledSketch identically.
    let (sum, count) = agg.to_sum();
    let mut pool = PooledSketch::new(o.sketch_len());
    pool.add_sum(&sum, count);
    for (a, b) in pool.mean().iter().zip(&dense) {
        assert!((a - b).abs() < 1e-12);
    }
}

#[test]
fn bit_aggregator_merge() {
    let o = op(Arc::new(UniversalQuantizer), 3, 10, 8);
    let mut rng = Rng::new(15);
    let mut a1 = BitAggregator::new(o.sketch_len());
    let mut a2 = BitAggregator::new(o.sketch_len());
    let mut all = BitAggregator::new(o.sketch_len());
    for i in 0..50 {
        let x: Vec<f64> = (0..3).map(|_| rng.gaussian()).collect();
        let b = o.encode_point_bits(&x);
        if i % 2 == 0 {
            a1.add(&b)
        } else {
            a2.add(&b)
        }
        all.add(&b);
    }
    a1.merge(&a2);
    assert_eq!(a1.count(), all.count());
    assert_eq!(a1.mean(), all.mean());
}

#[test]
fn atom_norm_is_constant() {
    let o = op(Arc::new(UniversalQuantizer), 5, 64, 9);
    let mut rng = Rng::new(16);
    for _ in 0..10 {
        let c: Vec<f64> = (0..5).map(|_| rng.gaussian_with(0.0, 3.0)).collect();
        let a = o.atom(&c);
        assert!(
            (norm2(&a) - o.atom_norm()).abs() < 1e-9,
            "atom norm varies with c"
        );
    }
    assert!((o.atom_norm() - (4.0 / PI) * 8.0).abs() < 1e-12); // A·√64
}

/// Decode atoms of a phase-shifted (odd) signature evaluate
/// `A·cos(ω^T c + ξ + φ₁ + pπ/2)` — the first-harmonic phase is baked into
/// every slot — while even signatures keep `φ₁ = 0` and their atoms are
/// bit-for-bit the phase-free formula.
#[test]
fn atom_phase_shifts_for_odd_signatures_only() {
    let o = op(Arc::new(ModuloRamp), 4, 20, 9);
    assert!((o.phase() - 0.5 * PI).abs() < 1e-15);
    assert!((o.amplitude() - 2.0 / PI).abs() < 1e-12);
    let mut rng = Rng::new(10);
    let c: Vec<f64> = (0..4).map(|_| rng.gaussian()).collect();
    let a = o.atom(&c);
    let freqs = o.frequencies();
    for j in 0..20 {
        let t: f64 = (0..4).map(|r| freqs.omega.get(r, j) * c[r]).sum();
        let arg = t + freqs.xi[j] + 0.5 * PI;
        assert!((a[2 * j] - o.amplitude() * arg.cos()).abs() < 1e-9);
        assert!((a[2 * j + 1] + o.amplitude() * arg.sin()).abs() < 1e-9);
    }
    // Norm constancy survives the phase (cos² + sin² pairing).
    assert!((norm2(&a) - o.atom_norm()).abs() < 1e-9);

    // Even signature: phase 0, so `arg + phase` is the bitwise identity
    // (`x + 0.0 == x` for every reachable argument) and the atom is the
    // legacy phase-free formula.
    let e = op(Arc::new(UniversalQuantizer), 4, 20, 9);
    assert_eq!(e.phase(), 0.0);
    let ae = e.atom(&c);
    let freqs = e.frequencies();
    for j in 0..20 {
        let t: f64 = (0..4).map(|r| freqs.omega.get(r, j) * c[r]).sum();
        let arg = t + freqs.xi[j];
        assert!((ae[2 * j] - e.amplitude() * arg.cos()).abs() < 1e-12, "slot {j}");
    }
}

/// The fused atom+gradient path agrees with the plain atom for a
/// phase-shifted signature (both must add φ₁ identically).
#[test]
fn atom_and_jtv_matches_atom_under_phase() {
    let o = op(Arc::new(ModuloRamp), 3, 16, 11);
    let mut rng = Rng::new(12);
    let c: Vec<f64> = (0..3).map(|_| rng.gaussian()).collect();
    let v: Vec<f64> = (0..o.sketch_len()).map(|_| rng.gaussian()).collect();
    let mut grad = vec![0.0; 3];
    let a_fused = o.atom_and_jtv(&c, &v, &mut grad);
    assert_eq!(a_fused, o.atom(&c), "fused atom must equal the plain atom");
    // And the trig-free jtv_from_atom reproduces the fused gradient.
    let mut grad2 = vec![0.0; 3];
    o.jtv_from_atom(&a_fused, &v, &mut grad2);
    for (g1, g2) in grad.iter().zip(&grad2) {
        assert!((g1 - g2).abs() < 1e-9, "gradients diverge: {g1} vs {g2}");
    }
}

#[test]
fn atom_of_dirac_equals_cosine_sketch_of_point() {
    // For the cosine signature, A_{f1} = A_f, so the atom at c must equal
    // the encode of the single point c.
    let o = op(Arc::new(Cosine), 4, 30, 10);
    let mut rng = Rng::new(17);
    let c: Vec<f64> = (0..4).map(|_| rng.gaussian()).collect();
    let atom = o.atom(&c);
    let enc = o.encode_point(&c);
    for (a, e) in atom.iter().zip(&enc) {
        assert!((a - e).abs() < 1e-12);
    }
}

#[test]
fn atom_jacobian_matches_finite_differences() {
    let o = op(Arc::new(UniversalQuantizer), 4, 25, 11);
    let mut rng = Rng::new(18);
    let c: Vec<f64> = (0..4).map(|_| rng.gaussian()).collect();
    let v: Vec<f64> = (0..o.sketch_len()).map(|_| rng.gaussian()).collect();
    let mut grad = vec![0.0; 4];
    let a0 = o.atom_and_jtv(&c, &v, &mut grad);
    assert_eq!(a0, o.atom(&c));
    // f(c) = ⟨a(c), v⟩; grad must match finite differences.
    let h = 1e-6;
    for r in 0..4 {
        let mut cp = c.clone();
        cp[r] += h;
        let mut cm = c.clone();
        cm[r] -= h;
        let fp = crate::linalg::dot(&o.atom(&cp), &v);
        let fm = crate::linalg::dot(&o.atom(&cm), &v);
        let fd = (fp - fm) / (2.0 * h);
        assert!(
            (grad[r] - fd).abs() < 1e-5 * (1.0 + fd.abs()),
            "grad[{r}] = {} vs fd {fd}",
            grad[r]
        );
    }
}

#[test]
fn jtv_from_atom_matches_fused_kernel() {
    let o = op(Arc::new(UniversalQuantizer), 5, 40, 23);
    let mut rng = Rng::new(24);
    let c: Vec<f64> = (0..5).map(|_| rng.gaussian()).collect();
    let v: Vec<f64> = (0..o.sketch_len()).map(|_| rng.gaussian()).collect();
    let mut g_fused = vec![0.0; 5];
    let atom = o.atom_and_jtv(&c, &v, &mut g_fused);
    let mut g_from_atom = vec![0.0; 5];
    o.jtv_from_atom(&atom, &v, &mut g_from_atom);
    for (a, b) in g_fused.iter().zip(&g_from_atom) {
        assert!((a - b).abs() < 1e-9 * (1.0 + a.abs()), "{a} vs {b}");
    }
}

#[test]
fn mixture_sketch_is_weighted_atom_sum() {
    let o = op(Arc::new(UniversalQuantizer), 3, 12, 12);
    let cents = Mat::from_vec(2, 3, vec![1., 0., 0., 0., 2., -1.]);
    let w = [0.3, 0.7];
    let z = o.mixture_sketch(&cents, &w);
    let mut want = vec![0.0; o.sketch_len()];
    crate::linalg::axpy(0.3, &o.atom(cents.row(0)), &mut want);
    crate::linalg::axpy(0.7, &o.atom(cents.row(1)), &mut want);
    for (a, b) in z.iter().zip(&want) {
        assert!((a - b).abs() < 1e-12);
    }
}

#[test]
fn quantized_embedding_preserves_local_distances() {
    // Boufounos–Rane: normalized Hamming distance between bit sketches is
    // monotone in the Euclidean distance for nearby points.
    let n = 8;
    let o = op(Arc::new(UniversalQuantizer), n, 512, 13);
    let mut rng = Rng::new(19);
    let x0: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
    let b0 = o.encode_point_bits(&x0);
    let mut prev = 0.0;
    for &step in &[0.05, 0.2, 0.5, 1.0] {
        let x1: Vec<f64> = x0.iter().map(|v| v + step / (n as f64).sqrt()).collect();
        let d_h = b0.hamming(&o.encode_point_bits(&x1)) as f64 / b0.len() as f64;
        assert!(
            d_h >= prev - 0.02,
            "hamming distance not monotone: {d_h} after {prev} (step {step})"
        );
        prev = d_h;
        let _ = sq_dist(&x0, &x1);
    }
    assert!(prev > 0.05, "largest step should flip a decent bit fraction");
}

#[test]
fn pooled_sketch_empty_and_errors() {
    let p = PooledSketch::new(8);
    assert!(p.is_empty());
    assert_eq!(p.len(), 8);
    let agg = BitAggregator::new(8);
    assert!(agg.is_empty());
    assert_eq!(agg.len(), 8);
}

#[test]
#[should_panic]
fn pooled_mean_of_empty_panics() {
    PooledSketch::new(4).mean();
}

#[test]
#[should_panic]
fn bit_hamming_length_mismatch_panics() {
    let a = BitSketch::zeros(10);
    let b = BitSketch::zeros(12);
    let _ = a.hamming(&b);
}
