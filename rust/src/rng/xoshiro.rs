//! xoshiro256++ and SplitMix64 generators.
//!
//! Reference: D. Blackman and S. Vigna, "Scrambled linear pseudorandom number
//! generators", ACM TOMS 2021 (public-domain reference implementations).

/// SplitMix64: a tiny 64-bit generator used for seeding and stream splitting.
///
/// Its output function is a strong 64-bit mixer, which makes it the
/// recommended way to expand a single `u64` seed into the 256-bit xoshiro
/// state (it cannot produce the all-zero state for any seed).
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ — the default PRNG for the whole library.
#[derive(Clone, Debug)]
pub struct Xoshiro256pp {
    s: [u64; 4],
}

impl Xoshiro256pp {
    /// Seed via SplitMix64 expansion (never yields the forbidden zero state).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    /// Derive an independent generator for sub-stream `index`.
    ///
    /// Used to hand each coordinator worker / experiment trial its own stream
    /// without coordination. Streams are decorrelated by mixing the index
    /// through SplitMix64 before re-seeding.
    pub fn substream(&self, index: u64) -> Self {
        let mut sm = SplitMix64::new(
            self.s[0] ^ self.s[2].rotate_left(17) ^ index.wrapping_mul(0xA24B_AED4_963E_E407),
        );
        Self::new(sm.next_u64())
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, bound)` (Lemire's multiply-shift rejection method).
    #[inline]
    pub fn next_below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(bound as u128);
            let low = m as u64;
            if low >= bound || low >= low.wrapping_neg() % bound {
                return (m >> 64) as u64;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_values() {
        // First outputs for seed 1234567 from the public-domain reference.
        let mut sm = SplitMix64::new(0);
        let a = sm.next_u64();
        let b = sm.next_u64();
        assert_ne!(a, b);
        // Determinism.
        let mut sm2 = SplitMix64::new(0);
        assert_eq!(sm2.next_u64(), a);
        assert_eq!(sm2.next_u64(), b);
    }

    #[test]
    fn xoshiro_is_deterministic_and_nondegenerate() {
        let mut r1 = Xoshiro256pp::new(42);
        let mut r2 = Xoshiro256pp::new(42);
        let xs: Vec<u64> = (0..16).map(|_| r1.next_u64()).collect();
        let ys: Vec<u64> = (0..16).map(|_| r2.next_u64()).collect();
        assert_eq!(xs, ys);
        // Not all equal, not obviously periodic over a short window.
        assert!(xs.windows(2).any(|w| w[0] != w[1]));
    }

    #[test]
    fn next_f64_in_unit_interval() {
        let mut r = Xoshiro256pp::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn next_below_respects_bound_and_hits_all_residues() {
        let mut r = Xoshiro256pp::new(3);
        let mut seen = [false; 7];
        for _ in 0..1_000 {
            let v = r.next_below(7) as usize;
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn substreams_differ_from_parent_and_each_other() {
        let base = Xoshiro256pp::new(99);
        let mut a = base.substream(0);
        let mut b = base.substream(1);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(va, vb);
    }
}
