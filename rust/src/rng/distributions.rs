//! Distribution helpers layered on [`Xoshiro256pp`].

use super::Xoshiro256pp;

impl Xoshiro256pp {
    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Standard Gaussian via Box–Muller (polar-free, two uniforms).
    ///
    /// We deliberately use the trigonometric form and drop the second
    /// variate: it keeps the generator stateless w.r.t. cached spares, which
    /// matters for reproducible parallel substreams.
    #[inline]
    pub fn gaussian(&mut self) -> f64 {
        // Guard against log(0).
        let u1 = loop {
            let u = self.next_f64();
            if u > 1e-300 {
                break u;
            }
        };
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Gaussian with the given mean and standard deviation.
    #[inline]
    pub fn gaussian_with(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.gaussian()
    }

    /// Fill `out` with iid standard Gaussians.
    pub fn fill_gaussian(&mut self, out: &mut [f64]) {
        for v in out.iter_mut() {
            *v = self.gaussian();
        }
    }

    /// A uniformly random direction on the unit sphere of dimension `n`.
    pub fn sphere_direction(&mut self, n: usize) -> Vec<f64> {
        loop {
            let mut v: Vec<f64> = (0..n).map(|_| self.gaussian()).collect();
            let norm = v.iter().map(|x| x * x).sum::<f64>().sqrt();
            if norm > 1e-12 {
                for x in v.iter_mut() {
                    *x /= norm;
                }
                return v;
            }
        }
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        let n = xs.len();
        if n < 2 {
            return;
        }
        for i in (1..n).rev() {
            let j = self.next_below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `0..n` (k ≤ n), in random order.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} distinct indices from 0..{n}");
        if k * 4 >= n {
            // Dense case: partial Fisher–Yates.
            let mut idx: Vec<usize> = (0..n).collect();
            for i in 0..k {
                let j = i + self.next_below((n - i) as u64) as usize;
                idx.swap(i, j);
            }
            idx.truncate(k);
            idx
        } else {
            // Sparse case: rejection with a sorted probe set.
            let mut chosen = std::collections::HashSet::with_capacity(k * 2);
            let mut out = Vec::with_capacity(k);
            while out.len() < k {
                let v = self.next_below(n as u64) as usize;
                if chosen.insert(v) {
                    out.push(v);
                }
            }
            out
        }
    }

    /// Sample an index according to (unnormalized, non-negative) weights.
    ///
    /// Used by k-means++ seeding. Returns `None` if all weights are zero.
    pub fn weighted_index(&mut self, weights: &[f64]) -> Option<usize> {
        let total: f64 = weights.iter().sum();
        if !(total > 0.0) {
            return None;
        }
        let mut target = self.next_f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            target -= w;
            if target <= 0.0 {
                return Some(i);
            }
        }
        Some(weights.len() - 1) // float round-off fallthrough
    }
}
