//! Inverse-CDF table sampling for arbitrary 1-D densities.
//!
//! The adapted-radius frequency distribution of Keriven et al. has the
//! unnormalized density `p(R) ∝ sqrt(R² + R⁴/4) · exp(−R²/2)` which has no
//! closed-form inverse CDF. We tabulate the CDF on a fine grid once and
//! sample by linear interpolation — exact enough (the density is smooth) and
//! O(log grid) per draw.

use super::Xoshiro256pp;

/// A tabulated inverse CDF over a bounded support `[lo, hi]`.
#[derive(Clone, Debug)]
pub struct InverseCdfTable {
    /// Grid points (len = resolution + 1), uniformly spaced on `[lo, hi]`.
    xs: Vec<f64>,
    /// Normalized CDF values at `xs` (cdf[0] = 0, cdf[last] = 1).
    cdf: Vec<f64>,
}

impl InverseCdfTable {
    /// Build the table from an (unnormalized, non-negative) density.
    ///
    /// `resolution` trapezoid cells are used; 4096 is plenty for the smooth
    /// densities in this crate.
    pub fn from_density(density: impl Fn(f64) -> f64, lo: f64, hi: f64, resolution: usize) -> Self {
        assert!(hi > lo && resolution >= 8);
        let n = resolution;
        let h = (hi - lo) / n as f64;
        let xs: Vec<f64> = (0..=n).map(|i| lo + i as f64 * h).collect();
        let pdf: Vec<f64> = xs.iter().map(|&x| density(x).max(0.0)).collect();
        let mut cdf = vec![0.0; n + 1];
        for i in 1..=n {
            cdf[i] = cdf[i - 1] + 0.5 * (pdf[i - 1] + pdf[i]) * h;
        }
        let total = cdf[n];
        assert!(
            total > 0.0 && total.is_finite(),
            "density integrates to {total}; cannot build inverse CDF"
        );
        for v in cdf.iter_mut() {
            *v /= total;
        }
        cdf[n] = 1.0;
        Self { xs, cdf }
    }

    /// Map a uniform `u ∈ [0,1)` through the inverse CDF.
    pub fn quantile(&self, u: f64) -> f64 {
        let u = u.clamp(0.0, 1.0);
        // Binary search for the cell containing u.
        let mut lo = 0usize;
        let mut hi = self.cdf.len() - 1;
        while hi - lo > 1 {
            let mid = (lo + hi) / 2;
            if self.cdf[mid] <= u {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        let (c0, c1) = (self.cdf[lo], self.cdf[hi]);
        let t = if c1 > c0 { (u - c0) / (c1 - c0) } else { 0.0 };
        self.xs[lo] + t * (self.xs[hi] - self.xs[lo])
    }

    /// Draw one sample.
    pub fn sample(&self, rng: &mut Xoshiro256pp) -> f64 {
        self.quantile(rng.next_f64())
    }
}
