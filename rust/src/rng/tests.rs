//! Statistical sanity tests for the RNG substrate.

use super::*;

#[test]
fn uniform_mean_and_range() {
    let mut r = Rng::new(11);
    let n = 50_000;
    let mut sum = 0.0;
    for _ in 0..n {
        let x = r.uniform(-2.0, 6.0);
        assert!((-2.0..6.0).contains(&x));
        sum += x;
    }
    let mean = sum / n as f64;
    assert!((mean - 2.0).abs() < 0.05, "uniform mean {mean} far from 2.0");
}

#[test]
fn gaussian_moments() {
    let mut r = Rng::new(5);
    let n = 200_000;
    let (mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0);
    for _ in 0..n {
        let x = r.gaussian();
        s1 += x;
        s2 += x * x;
        s3 += x * x * x;
    }
    let mean = s1 / n as f64;
    let var = s2 / n as f64 - mean * mean;
    let skew = s3 / n as f64;
    assert!(mean.abs() < 0.01, "gaussian mean {mean}");
    assert!((var - 1.0).abs() < 0.02, "gaussian var {var}");
    assert!(skew.abs() < 0.03, "gaussian third moment {skew}");
}

#[test]
fn gaussian_with_scales_and_shifts() {
    let mut r = Rng::new(8);
    let n = 100_000;
    let mut s1 = 0.0;
    let mut s2 = 0.0;
    for _ in 0..n {
        let x = r.gaussian_with(3.0, 0.5);
        s1 += x;
        s2 += (x - 3.0) * (x - 3.0);
    }
    assert!((s1 / n as f64 - 3.0).abs() < 0.01);
    assert!((s2 / n as f64 - 0.25).abs() < 0.01);
}

#[test]
fn sphere_direction_is_unit_norm_and_isotropic() {
    let mut r = Rng::new(13);
    let n_dim = 8;
    let trials = 20_000;
    let mut mean = vec![0.0; n_dim];
    for _ in 0..trials {
        let v = r.sphere_direction(n_dim);
        let norm: f64 = v.iter().map(|x| x * x).sum::<f64>().sqrt();
        assert!((norm - 1.0).abs() < 1e-9);
        for (m, x) in mean.iter_mut().zip(&v) {
            *m += x;
        }
    }
    for m in &mean {
        assert!((m / trials as f64).abs() < 0.02, "directional bias {m}");
    }
}

#[test]
fn shuffle_is_a_permutation() {
    let mut r = Rng::new(21);
    let mut xs: Vec<usize> = (0..100).collect();
    r.shuffle(&mut xs);
    let mut sorted = xs.clone();
    sorted.sort_unstable();
    assert_eq!(sorted, (0..100).collect::<Vec<_>>());
}

#[test]
fn shuffle_trivial_cases() {
    let mut r = Rng::new(1);
    let mut empty: Vec<u8> = vec![];
    r.shuffle(&mut empty);
    let mut one = vec![42];
    r.shuffle(&mut one);
    assert_eq!(one, vec![42]);
}

#[test]
fn sample_indices_distinct_both_paths() {
    let mut r = Rng::new(77);
    // Dense path (k close to n).
    let dense = r.sample_indices(10, 9);
    let set: std::collections::HashSet<_> = dense.iter().collect();
    assert_eq!(set.len(), 9);
    // Sparse path.
    let sparse = r.sample_indices(100_000, 10);
    let set: std::collections::HashSet<_> = sparse.iter().collect();
    assert_eq!(set.len(), 10);
    assert!(sparse.iter().all(|&i| i < 100_000));
}

#[test]
#[should_panic]
fn sample_indices_rejects_oversample() {
    let mut r = Rng::new(0);
    let _ = r.sample_indices(3, 4);
}

#[test]
fn weighted_index_matches_weights() {
    let mut r = Rng::new(31);
    let weights = [0.0, 1.0, 3.0];
    let mut counts = [0usize; 3];
    for _ in 0..40_000 {
        counts[r.weighted_index(&weights).unwrap()] += 1;
    }
    assert_eq!(counts[0], 0);
    let ratio = counts[2] as f64 / counts[1] as f64;
    assert!((ratio - 3.0).abs() < 0.2, "weighted ratio {ratio}");
    assert_eq!(r.weighted_index(&[0.0, 0.0]), None);
}

#[test]
fn inverse_cdf_recovers_uniform() {
    // density = const on [2, 5] → quantile(u) = 2 + 3u.
    let t = InverseCdfTable::from_density(|_| 1.0, 2.0, 5.0, 64);
    for &(u, want) in &[(0.0, 2.0), (0.5, 3.5), (1.0, 5.0), (0.25, 2.75)] {
        assert!((t.quantile(u) - want).abs() < 1e-9, "quantile({u})");
    }
}

#[test]
fn inverse_cdf_matches_triangular_density() {
    // density p(x) = x on [0,1] → CDF x² → quantile sqrt(u).
    let t = InverseCdfTable::from_density(|x| x, 0.0, 1.0, 4096);
    for &u in &[0.1, 0.3, 0.5, 0.9] {
        assert!((t.quantile(u) - u.sqrt()).abs() < 1e-3);
    }
    // Sampled moments: E[X] = 2/3.
    let mut r = Rng::new(9);
    let n = 50_000;
    let mean: f64 = (0..n).map(|_| t.sample(&mut r)).sum::<f64>() / n as f64;
    assert!((mean - 2.0 / 3.0).abs() < 0.01, "triangular mean {mean}");
}
