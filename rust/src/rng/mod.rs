//! Self-contained pseudo-random number generation.
//!
//! The build environment has no access to the `rand` crate family, so this
//! module implements the small slice of it the library needs, from scratch:
//!
//! * [`Xoshiro256pp`] — the xoshiro256++ generator (Blackman & Vigna), a fast
//!   non-cryptographic PRNG with 256-bit state and good statistical quality.
//! * [`SplitMix64`] — used to expand a user seed into xoshiro state and to
//!   derive independent sub-streams for parallel workers.
//! * Distribution helpers: uniform reals/ints, Box–Muller Gaussians, uniform
//!   directions on the sphere, Fisher–Yates shuffling and an inverse-CDF table
//!   sampler used by the adapted-radius frequency distribution.
//!
//! All algorithms are deterministic given a seed; experiments record their
//! seeds so every table in EXPERIMENTS.md is exactly reproducible.

mod xoshiro;
mod distributions;
mod inverse_cdf;

pub use inverse_cdf::InverseCdfTable;
pub use xoshiro::{SplitMix64, Xoshiro256pp};

/// The library-wide default RNG. An alias so call-sites stay generic-free.
pub type Rng = Xoshiro256pp;

#[cfg(test)]
mod tests;
