//! Runtime tests. The PJRT round-trip tests live in `rust/tests/pjrt_e2e.rs`
//! (they need `make artifacts` to have run); here we cover the native
//! engine, the manifest parser, and shape validation.

use super::*;
use crate::frequency::{DrawnFrequencies, FrequencyLaw};
use crate::linalg::Mat;
use crate::rng::Rng;
use crate::sketch::{PooledSketch, SketchOperator};
use std::path::Path;

fn quant_op(n: usize, m: usize, seed: u64) -> SketchOperator {
    let mut rng = Rng::new(seed);
    SketchOperator::quantized(DrawnFrequencies::draw(
        FrequencyLaw::Gaussian,
        n,
        m,
        1.0,
        &mut rng,
    ))
}

#[test]
fn native_engine_matches_operator() {
    let op = quant_op(4, 25, 1);
    let engine = NativeEngine::new(op.clone());
    assert_eq!(engine.sketch_len(), 50);
    assert_eq!(engine.name(), "native");
    let mut rng = Rng::new(2);
    let x = Mat::from_fn(97, 4, |_, _| rng.gaussian());
    let via_engine = engine.sketch_dataset(&x).unwrap();
    assert_eq!(via_engine, op.sketch_dataset(&x));
    assert_eq!(engine.operator().dim(), 4);
    // sketch_into accumulates.
    let mut pool = PooledSketch::new(50);
    engine.sketch_into(&x, &mut pool).unwrap();
    engine.sketch_into(&x, &mut pool).unwrap();
    assert_eq!(pool.count(), 194);
}

#[test]
fn manifest_parses_and_finds() {
    let text = "# name kind batch dim m file\n\
                sketch_qckm sketch 256 10 1000 sketch_qckm.hlo.txt\n\
                sketch_ckm sketch 256 10 1000 sketch_ckm.hlo.txt\n\n";
    let m = ArtifactManifest::parse(text, Path::new("/tmp/artifacts")).unwrap();
    assert_eq!(m.entries.len(), 2);
    let e = m.find("sketch_qckm").unwrap();
    assert_eq!((e.batch, e.dim, e.m), (256, 10, 1000));
    assert_eq!(e.kind, "sketch");
    assert_eq!(
        m.path_of(e),
        Path::new("/tmp/artifacts/sketch_qckm.hlo.txt")
    );
    assert!(m.find("nope").is_none());
}

#[test]
fn manifest_rejects_malformed_lines() {
    assert!(ArtifactManifest::parse("a b c\n", Path::new(".")).is_err());
    assert!(ArtifactManifest::parse("a sketch x 10 1000 f.txt\n", Path::new(".")).is_err());
    // Comments/blank lines fine.
    let ok = ArtifactManifest::parse("# hi\n\n", Path::new(".")).unwrap();
    assert!(ok.entries.is_empty());
}

#[test]
fn manifest_load_missing_dir_errors() {
    assert!(ArtifactManifest::load(Path::new("/nonexistent/dir")).is_err());
}

#[test]
fn pjrt_load_validates_shapes() {
    // A manifest entry whose (n, M) mismatch the operator must be rejected
    // before any XLA work happens.
    let text = "sketch_qckm sketch 64 3 10 missing.hlo.txt\n";
    let manifest = ArtifactManifest::parse(text, Path::new("/tmp")).unwrap();
    let op = quant_op(4, 25, 3); // n=4, M=25 ≠ (3, 10)
    let err = match PjrtEngine::load(&manifest, "sketch_qckm", op) {
        Err(e) => e,
        Ok(_) => panic!("expected shape mismatch"),
    };
    let msg = format!("{err:#}");
    assert!(msg.contains("lowered for"), "unexpected error: {msg}");
    // Unknown artifact name.
    let op = quant_op(3, 10, 3);
    let err = match PjrtEngine::load(&manifest, "nope", op) {
        Err(e) => e,
        Ok(_) => panic!("expected missing-artifact error"),
    };
    assert!(format!("{err:#}").contains("not in manifest"));
}
