//! The PJRT-backed engine: executes the AOT-lowered JAX/Pallas sketch.
//!
//! Mirrors `/opt/xla-example/load_hlo`: HLO **text** is the interchange
//! format (jax ≥ 0.5 serialized protos carry 64-bit instruction ids that
//! xla_extension 0.5.1 rejects; the text parser reassigns ids).
//!
//! The lowered computation is
//! `sketch_sum(X[B,n] f32, Ω[n,M] f32, ξ[M] f32) → f32[2M]`
//! — the batch-summed signature contributions, so pooling stays linear and
//! the Rust side only divides by N at the end. Full batches go through
//! PJRT; the `N mod B` remainder uses the native path (bit-exact layout,
//! f32-rounded values).

use super::engine::SketchEngine;
use super::manifest::{ArtifactEntry, ArtifactManifest};
use crate::linalg::Mat;
use crate::sketch::{PooledSketch, SketchOperator};
use anyhow::{bail, Context, Result};

/// A PJRT CPU executable for the sketch at fixed `(batch, n, M)` shapes.
pub struct PjrtEngine {
    exe: xla::PjRtLoadedExecutable,
    op: SketchOperator,
    batch: usize,
    /// Ω as f32 (row-major `n × M`), fed to every execution.
    omega_f32: Vec<f32>,
    /// ξ as f32, length M.
    xi_f32: Vec<f32>,
    platform: String,
}

impl PjrtEngine {
    /// Load artifact `name` from `manifest`, validating its shapes against
    /// the operator's (the same Ω/ξ draw must be fed at run time).
    pub fn load(manifest: &ArtifactManifest, name: &str, op: SketchOperator) -> Result<Self> {
        let entry: &ArtifactEntry = manifest
            .find(name)
            .with_context(|| format!("artifact '{name}' not in manifest"))?;
        if entry.dim != op.dim() || entry.m != op.num_frequencies() {
            bail!(
                "artifact '{name}' lowered for (n={}, M={}) but operator has (n={}, M={})",
                entry.dim,
                entry.m,
                op.dim(),
                op.num_frequencies()
            );
        }
        let path = manifest.path_of(entry);
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        let platform = client.platform_name();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-UTF8 artifact path")?,
        )
        .with_context(|| format!("parse HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).context("PJRT compile")?;

        let freqs = op.frequencies();
        let omega_f32: Vec<f32> = freqs.omega.as_slice().iter().map(|&v| v as f32).collect();
        let xi_f32: Vec<f32> = freqs.xi.iter().map(|&v| v as f32).collect();
        Ok(Self {
            exe,
            op,
            batch: entry.batch,
            omega_f32,
            xi_f32,
            platform,
        })
    }

    pub fn batch(&self) -> usize {
        self.batch
    }

    pub fn platform(&self) -> &str {
        &self.platform
    }

    pub fn operator(&self) -> &SketchOperator {
        &self.op
    }

    /// Run one full batch (rows.len() == batch × n) through the executable;
    /// returns the per-slot contribution *sum* over the batch (length 2M).
    fn run_batch(&self, rows_f32: &[f32]) -> Result<Vec<f64>> {
        let n = self.op.dim() as i64;
        let m = self.op.num_frequencies() as i64;
        let x = xla::Literal::vec1(rows_f32)
            .reshape(&[self.batch as i64, n])
            .context("reshape X literal")?;
        let omega = xla::Literal::vec1(&self.omega_f32)
            .reshape(&[n, m])
            .context("reshape Ω literal")?;
        let xi = xla::Literal::vec1(&self.xi_f32)
            .reshape(&[m])
            .context("reshape ξ literal")?;
        let result = self
            .exe
            .execute::<xla::Literal>(&[x, omega, xi])
            .context("PJRT execute")?[0][0]
            .to_literal_sync()
            .context("fetch result")?;
        // aot.py lowers with return_tuple=True → unwrap the 1-tuple.
        let out = result.to_tuple1().context("unwrap result tuple")?;
        let values = out.to_vec::<f32>().context("read result values")?;
        if values.len() != self.op.sketch_len() {
            bail!(
                "artifact returned {} slots, expected {}",
                values.len(),
                self.op.sketch_len()
            );
        }
        Ok(values.iter().map(|&v| v as f64).collect())
    }
}

impl SketchEngine for PjrtEngine {
    fn sketch_into(&self, x: &Mat, pool: &mut PooledSketch) -> Result<()> {
        if x.cols() != self.op.dim() {
            bail!("dataset dim {} != engine dim {}", x.cols(), self.op.dim());
        }
        let full_batches = x.rows() / self.batch;
        let mut rows_f32 = vec![0.0f32; self.batch * x.cols()];
        for b in 0..full_batches {
            let start = b * self.batch;
            for i in 0..self.batch {
                for (j, &v) in x.row(start + i).iter().enumerate() {
                    rows_f32[i * x.cols() + j] = v as f32;
                }
            }
            let sum = self.run_batch(&rows_f32)?;
            pool.add_sum(&sum, self.batch as u64);
        }
        // Remainder through the native path (same operator, f64).
        let rem_start = full_batches * self.batch;
        if rem_start < x.rows() {
            let idx: Vec<usize> = (rem_start..x.rows()).collect();
            let rest = x.select_rows(&idx);
            self.op.sketch_into(&rest, pool);
        }
        Ok(())
    }

    fn sketch_len(&self) -> usize {
        self.op.sketch_len()
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}
