//! Execution engines for the sketch hot path.
//!
//! The encode `z_X = (1/N) Σ f(Ω^T x_i + ξ)` is the only dense-compute step
//! in the system, and it has two interchangeable implementations behind the
//! [`SketchEngine`] trait:
//!
//! * [`NativeEngine`] — the pure-Rust blocked implementation
//!   ([`crate::sketch::SketchOperator::sketch_into`]); works for any shape,
//!   used by the parameter sweeps.
//! * [`PjrtEngine`] — loads the AOT artifact lowered by
//!   `python/compile/aot.py` (JAX model calling the Pallas kernel,
//!   interchanged as **HLO text**) and executes it on the PJRT CPU client
//!   via the `xla` crate. Fixed flagship shapes; Python never runs at
//!   request time. Remainder rows (N mod batch) fall back to the native
//!   path so results stay exact.
//!
//! Artifact discovery goes through [`ArtifactManifest`], the tiny index
//! `aot.py` writes next to the `.hlo.txt` files.

mod engine;
mod manifest;
mod pjrt;

pub use engine::{NativeEngine, SketchEngine};
pub use manifest::{ArtifactEntry, ArtifactManifest};
pub use pjrt::PjrtEngine;

#[cfg(test)]
mod tests;
