//! The `SketchEngine` trait and the native implementation.

use crate::linalg::Mat;
use crate::sketch::{PooledSketch, SketchOperator};

/// Anything that can pool sketch contributions of a row-batch of examples.
///
/// Not `Send`: the PJRT client wraps thread-affine FFI handles (`Rc` + raw
/// pointers inside the `xla` crate). The coordinator's worker threads use
/// [`crate::sketch::SketchOperator`] directly; engines run on the leader.
pub trait SketchEngine {
    /// Accumulate the contributions of every row of `x` into `pool`.
    fn sketch_into(&self, x: &Mat, pool: &mut PooledSketch) -> anyhow::Result<()>;

    /// Sketch length (`2M`).
    fn sketch_len(&self) -> usize;

    /// Human-readable engine name for logs.
    fn name(&self) -> &'static str;

    /// Convenience: pooled mean sketch of a dataset.
    fn sketch_dataset(&self, x: &Mat) -> anyhow::Result<Vec<f64>> {
        let mut pool = PooledSketch::new(self.sketch_len());
        self.sketch_into(x, &mut pool)?;
        Ok(pool.mean())
    }
}

/// Pure-Rust engine: delegates to the blocked encode in `crate::sketch`.
pub struct NativeEngine {
    op: SketchOperator,
}

impl NativeEngine {
    pub fn new(op: SketchOperator) -> Self {
        Self { op }
    }

    pub fn operator(&self) -> &SketchOperator {
        &self.op
    }
}

impl SketchEngine for NativeEngine {
    fn sketch_into(&self, x: &Mat, pool: &mut PooledSketch) -> anyhow::Result<()> {
        self.op.sketch_into(x, pool);
        Ok(())
    }

    fn sketch_len(&self) -> usize {
        self.op.sketch_len()
    }

    fn name(&self) -> &'static str {
        "native"
    }
}
