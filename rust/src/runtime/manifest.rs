//! The artifact manifest written by `python/compile/aot.py`.
//!
//! Plain text, one artifact per line:
//!
//! ```text
//! # name kind batch dim m file
//! sketch_qckm sketch 256 10 1000 sketch_qckm.hlo.txt
//! ```

use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

/// One AOT artifact: a compiled sketch kernel at fixed shapes.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ArtifactEntry {
    /// Artifact name (e.g. `sketch_qckm`).
    pub name: String,
    /// Kind tag (currently always `sketch`).
    pub kind: String,
    /// Fixed row-batch the computation was lowered for.
    pub batch: usize,
    /// Data dimension n.
    pub dim: usize,
    /// Number of frequencies M.
    pub m: usize,
    /// HLO text file, relative to the manifest.
    pub file: PathBuf,
}

/// The parsed manifest of an `artifacts/` directory.
#[derive(Clone, Debug, Default)]
pub struct ArtifactManifest {
    pub entries: Vec<ArtifactEntry>,
    /// Directory the manifest lives in (file paths resolve against it).
    pub root: PathBuf,
}

impl ArtifactManifest {
    /// Load `<dir>/manifest.txt`.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read manifest {}", path.display()))?;
        Self::parse(&text, dir)
    }

    /// Parse manifest text (exposed for tests).
    pub fn parse(text: &str, root: &Path) -> Result<Self> {
        let mut entries = Vec::new();
        for (i, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let parts: Vec<&str> = line.split_whitespace().collect();
            if parts.len() != 6 {
                bail!("manifest line {}: expected 6 fields, got {}", i + 1, parts.len());
            }
            entries.push(ArtifactEntry {
                name: parts[0].to_string(),
                kind: parts[1].to_string(),
                batch: parts[2].parse().with_context(|| format!("line {}: batch", i + 1))?,
                dim: parts[3].parse().with_context(|| format!("line {}: dim", i + 1))?,
                m: parts[4].parse().with_context(|| format!("line {}: m", i + 1))?,
                file: PathBuf::from(parts[5]),
            });
        }
        Ok(Self {
            entries,
            root: root.to_path_buf(),
        })
    }

    /// Find an artifact by name.
    pub fn find(&self, name: &str) -> Option<&ArtifactEntry> {
        self.entries.iter().find(|e| e.name == name)
    }

    /// Absolute path of an entry's HLO file.
    pub fn path_of(&self, entry: &ArtifactEntry) -> PathBuf {
        self.root.join(&entry.file)
    }
}
