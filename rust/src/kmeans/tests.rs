//! k-means tests on separable synthetic data.

use super::*;
use crate::metrics::adjusted_rand_index;

fn two_blobs(rng: &mut Rng, n_per: usize, sep: f64) -> (Mat, Vec<usize>) {
    let mut x = Mat::zeros(0, 2);
    let mut labels = Vec::new();
    for i in 0..2 * n_per {
        let c = if i < n_per { -sep / 2.0 } else { sep / 2.0 };
        x.push_row(&[c + 0.3 * rng.gaussian(), 0.3 * rng.gaussian()]);
        labels.push(usize::from(i >= n_per));
    }
    (x, labels)
}

#[test]
fn kmeans_pp_init_picks_distinct_points() {
    let mut rng = Rng::new(1);
    let (x, _) = two_blobs(&mut rng, 50, 10.0);
    let init = kmeans_pp_init(&x, 4, &mut rng);
    assert_eq!(init.shape(), (4, 2));
    // With separated blobs, ++ seeding should hit both blobs.
    let mut saw_left = false;
    let mut saw_right = false;
    for k in 0..4 {
        if init.get(k, 0) < 0.0 {
            saw_left = true;
        } else {
            saw_right = true;
        }
    }
    assert!(saw_left && saw_right, "++ seeding missed a blob");
}

#[test]
fn kmeans_recovers_separated_clusters() {
    let mut rng = Rng::new(2);
    let (x, truth) = two_blobs(&mut rng, 200, 8.0);
    let res = kmeans(&x, 2, &KMeansParams::default(), &mut rng);
    assert_eq!(res.centroids.rows(), 2);
    let ari = adjusted_rand_index(&res.labels, &truth);
    assert!(ari > 0.99, "ARI = {ari}");
    // Centroid locations near ±4.
    let mut xs: Vec<f64> = (0..2).map(|k| res.centroids.get(k, 0)).collect();
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    assert!((xs[0] + 4.0).abs() < 0.2 && (xs[1] - 4.0).abs() < 0.2, "{xs:?}");
}

#[test]
fn replicates_never_hurt_sse() {
    let mut rng = Rng::new(3);
    let (x, _) = two_blobs(&mut rng, 100, 3.0);
    let p1 = KMeansParams {
        replicates: 1,
        ..KMeansParams::default()
    };
    let p5 = KMeansParams {
        replicates: 5,
        ..KMeansParams::default()
    };
    // Same generator seed for a fair "best of" comparison.
    let r1 = kmeans(&x, 3, &p1, &mut Rng::new(10));
    let r5 = kmeans(&x, 3, &p5, &mut Rng::new(10));
    assert!(r5.sse <= r1.sse + 1e-9, "5 reps {} vs 1 rep {}", r5.sse, r1.sse);
}

#[test]
fn kmeans_k_equals_n_zero_sse() {
    let x = Mat::from_vec(3, 1, vec![0.0, 5.0, 9.0]);
    let mut rng = Rng::new(4);
    let res = kmeans(&x, 3, &KMeansParams::default(), &mut rng);
    assert!(res.sse < 1e-12);
}

#[test]
fn lloyd_monotone_nonincreasing_sse() {
    let mut rng = Rng::new(5);
    let (x, _) = two_blobs(&mut rng, 150, 2.0);
    let init = kmeans_pp_init(&x, 4, &mut rng);
    let sse0 = crate::metrics::sse(&x, &init);
    let res = lloyd(&x, &init, &KMeansParams::default());
    assert!(res.sse <= sse0 + 1e-9, "Lloyd increased SSE");
    assert!(res.iters >= 1);
}

#[test]
fn handles_duplicate_points() {
    // All points identical: SSE 0, no panic from empty-cluster repair.
    let x = Mat::from_fn(20, 2, |_, _| 1.5);
    let mut rng = Rng::new(6);
    let res = kmeans(&x, 3, &KMeansParams::default(), &mut rng);
    assert!(res.sse < 1e-20);
}

#[test]
#[should_panic]
fn rejects_k_larger_than_n() {
    let x = Mat::zeros(3, 2);
    let mut rng = Rng::new(0);
    let _ = kmeans_pp_init(&x, 4, &mut rng);
}
