//! Lloyd's k-means with k-means++ seeding — the paper's baseline.
//!
//! This replaces the "built-in MATLAB function" the paper compares against.
//! The experiments run it with several replicates and keep the best-SSE
//! solution, exactly as in Sec. 5.

use crate::linalg::{sq_dist, Mat};
use crate::metrics::{assign_labels, sse};
use crate::rng::Rng;

/// Tuning knobs for [`kmeans`].
#[derive(Clone, Debug)]
pub struct KMeansParams {
    /// Maximum Lloyd iterations per run.
    pub max_iters: usize,
    /// Stop when relative SSE improvement falls below this.
    pub tol: f64,
    /// Number of independent runs; the best-SSE run is returned.
    pub replicates: usize,
}

impl Default for KMeansParams {
    fn default() -> Self {
        Self {
            max_iters: 100,
            tol: 1e-6,
            replicates: 1,
        }
    }
}

/// Result of a k-means run.
#[derive(Clone, Debug)]
pub struct KMeansResult {
    /// `K × n` centroid matrix.
    pub centroids: Mat,
    /// Final assignment of each input row.
    pub labels: Vec<usize>,
    /// Final SSE.
    pub sse: f64,
    /// Lloyd iterations used by the winning replicate.
    pub iters: usize,
}

/// k-means++ seeding (Arthur & Vassilvitskii): D²-weighted centroid draws.
pub fn kmeans_pp_init(x: &Mat, k: usize, rng: &mut Rng) -> Mat {
    let n = x.rows();
    assert!(k >= 1 && k <= n, "need 1 <= K <= N (K={k}, N={n})");
    let mut centroids = Mat::zeros(0, x.cols());
    let first = rng.next_below(n as u64) as usize;
    centroids.push_row(x.row(first));
    let mut d2: Vec<f64> = (0..n).map(|i| sq_dist(x.row(i), x.row(first))).collect();
    while centroids.rows() < k {
        let next = rng
            .weighted_index(&d2)
            // All points coincide with a centroid: duplicate any point.
            .unwrap_or_else(|| rng.next_below(n as u64) as usize);
        centroids.push_row(x.row(next));
        let c = centroids.row(centroids.rows() - 1);
        for i in 0..n {
            let d = sq_dist(x.row(i), c);
            if d < d2[i] {
                d2[i] = d;
            }
        }
    }
    centroids
}

/// Lloyd iteration from the given initial centroids.
pub fn lloyd(x: &Mat, init: &Mat, params: &KMeansParams) -> KMeansResult {
    let (n, dim) = x.shape();
    let k = init.rows();
    assert_eq!(init.cols(), dim);
    let mut centroids = init.clone();
    let mut labels = vec![0usize; n];
    let mut prev_sse = f64::INFINITY;
    let mut iters = 0;
    for it in 0..params.max_iters {
        iters = it + 1;
        // Assignment step.
        labels = assign_labels(x, &centroids);
        // Update step.
        let mut sums = Mat::zeros(k, dim);
        let mut counts = vec![0u64; k];
        for (i, &l) in labels.iter().enumerate() {
            crate::linalg::axpy(1.0, x.row(i), sums.row_mut(l));
            counts[l] += 1;
        }
        for c in 0..k {
            if counts[c] == 0 {
                // Empty cluster: re-seed at the point farthest from its
                // centroid (standard repair).
                let far = (0..n)
                    .max_by(|&a, &b| {
                        let da = sq_dist(x.row(a), centroids.row(labels[a]));
                        let db = sq_dist(x.row(b), centroids.row(labels[b]));
                        da.partial_cmp(&db).unwrap()
                    })
                    .unwrap();
                centroids.row_mut(c).copy_from_slice(x.row(far));
            } else {
                let inv = 1.0 / counts[c] as f64;
                for (dst, &s) in centroids.row_mut(c).iter_mut().zip(sums.row(c)) {
                    *dst = s * inv;
                }
            }
        }
        let cur = sse(x, &centroids);
        if prev_sse.is_finite() && (prev_sse - cur) <= params.tol * prev_sse.max(1e-300) {
            break;
        }
        prev_sse = cur;
    }
    labels = assign_labels(x, &centroids);
    let final_sse = sse(x, &centroids);
    KMeansResult {
        centroids,
        labels,
        sse: final_sse,
        iters,
    }
}

/// Full k-means: k-means++ seeding + Lloyd, best of `params.replicates`.
pub fn kmeans(x: &Mat, k: usize, params: &KMeansParams, rng: &mut Rng) -> KMeansResult {
    assert!(params.replicates >= 1);
    let mut best: Option<KMeansResult> = None;
    for _ in 0..params.replicates {
        let init = kmeans_pp_init(x, k, rng);
        let run = lloyd(x, &init, params);
        if best.as_ref().map_or(true, |b| run.sse < b.sse) {
            best = Some(run);
        }
    }
    best.unwrap()
}

#[cfg(test)]
mod tests;
