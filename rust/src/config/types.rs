//! Typed job configuration resolved from a [`TomlDoc`] + CLI overrides.

use super::parser::TomlDoc;
use crate::frequency::{FrequencyLaw, SigmaHeuristic};
use crate::method::MethodSpec;
use anyhow::{bail, Result};

/// Sketch-side configuration (`[sketch]` section). The compressive method
/// is an open, parameterized [`MethodSpec`] (`ckm`, `qckm`, `qckm:bits=3`,
/// `triangle`, `modulo`, …) — see [`crate::method`] for the registry.
#[derive(Clone, Debug)]
pub struct SketchConfig {
    /// Number of frequencies M (the sketch has 2M real slots).
    pub num_frequencies: usize,
    pub law: FrequencyLaw,
    pub sigma: SigmaHeuristic,
    pub method: MethodSpec,
}

impl Default for SketchConfig {
    fn default() -> Self {
        Self {
            num_frequencies: 1000,
            law: FrequencyLaw::AdaptedRadius,
            sigma: SigmaHeuristic::default(),
            method: MethodSpec::parse("qckm").expect("default method spec"),
        }
    }
}

/// Decode-side configuration (`[decode]` section). The decoding algorithm
/// is an open, parameterized [`crate::decoder::DecoderSpec`] (`clompr`,
/// `clompr:restarts=5`, `hier`, …) — see [`crate::decoder`] for the
/// registry; `params` is the base tuning the chosen decoder refines.
#[derive(Clone, Debug)]
pub struct DecodeConfig {
    pub k: usize,
    pub replicates: usize,
    pub params: crate::clompr::ClOmprParams,
    pub decoder: crate::decoder::DecoderSpec,
}

impl Default for DecodeConfig {
    fn default() -> Self {
        Self {
            k: 10,
            replicates: 1,
            params: crate::clompr::ClOmprParams::default(),
            decoder: crate::decoder::DecoderSpec::default(),
        }
    }
}

/// A full clustering job: sketch + decode + pipeline settings + seed.
#[derive(Clone, Debug)]
pub struct JobConfig {
    pub sketch: SketchConfig,
    pub decode: DecodeConfig,
    pub pipeline: crate::coordinator::PipelineConfig,
    pub seed: u64,
    /// Thread budget for the compute hot paths (the `qckm sketch` encode,
    /// CL-OMPR Step 1, experiment grids): 1 = serial (default), 0 = all
    /// cores, n = exactly n. Top-level `threads` key / `--threads` CLI
    /// flag. The `qckm cluster` *acquisition* concurrency is a separate
    /// knob — `[pipeline] workers` (sensor simulation). Results never
    /// depend on either (see [`crate::parallel`]).
    pub threads: usize,
}

impl Default for JobConfig {
    fn default() -> Self {
        Self {
            sketch: SketchConfig::default(),
            decode: DecodeConfig::default(),
            pipeline: crate::coordinator::PipelineConfig::default(),
            seed: 0,
            threads: 1,
        }
    }
}

impl JobConfig {
    /// Resolve a job config from a parsed TOML doc, validating ranges.
    pub fn from_toml(doc: &TomlDoc) -> Result<JobConfig> {
        let mut cfg = JobConfig::default();

        // [sketch]
        let m = doc.get_int("sketch", "num_frequencies", cfg.sketch.num_frequencies as i64);
        if m < 1 {
            bail!("sketch.num_frequencies must be >= 1, got {m}");
        }
        cfg.sketch.num_frequencies = m as usize;
        let default_method = cfg.sketch.method.canonical().to_string();
        cfg.sketch.method = MethodSpec::parse(doc.get_str("sketch", "method", &default_method))?;
        cfg.sketch.law = FrequencyLaw::parse(doc.get_str("sketch", "law", "adapted-radius"))?;
        if let Some(v) = doc.get("sketch", "sigma") {
            let s = v
                .as_float()
                .ok_or_else(|| anyhow::anyhow!("sketch.sigma must be a number"))?;
            if s <= 0.0 {
                bail!("sketch.sigma must be positive, got {s}");
            }
            cfg.sketch.sigma = SigmaHeuristic::Fixed(s);
        } else {
            let sub = doc.get_int("sketch", "sigma_subsample", 512);
            let q = doc.get_float("sketch", "sigma_quantile", 0.12);
            if !(0.0..=1.0).contains(&q) {
                bail!("sketch.sigma_quantile must be in [0,1], got {q}");
            }
            cfg.sketch.sigma = SigmaHeuristic::PairwiseQuantile {
                subsample: sub.max(2) as usize,
                quantile: q,
            };
        }

        // [decode]
        let k = doc.get_int("decode", "k", cfg.decode.k as i64);
        if k < 1 {
            bail!("decode.k must be >= 1, got {k}");
        }
        cfg.decode.k = k as usize;
        let reps = doc.get_int("decode", "replicates", 1);
        if reps < 1 {
            bail!("decode.replicates must be >= 1, got {reps}");
        }
        cfg.decode.replicates = reps as usize;
        let default_decoder = cfg.decode.decoder.canonical().to_string();
        cfg.decode.decoder =
            crate::decoder::DecoderSpec::parse(doc.get_str("decode", "decoder", &default_decoder))?;
        cfg.decode.params.step1_restarts = doc
            .get_int("decode", "step1_restarts", cfg.decode.params.step1_restarts as i64)
            as usize;
        cfg.decode.params.step5_iters =
            doc.get_int("decode", "step5_iters", cfg.decode.params.step5_iters as i64) as usize;
        cfg.decode.params.step5_final_iters = doc.get_int(
            "decode",
            "step5_final_iters",
            cfg.decode.params.step5_final_iters as i64,
        ) as usize;

        // [pipeline]
        let workers = doc.get_int("pipeline", "workers", cfg.pipeline.workers as i64);
        if workers < 1 {
            bail!("pipeline.workers must be >= 1");
        }
        cfg.pipeline.workers = workers as usize;
        cfg.pipeline.batch_size =
            doc.get_int("pipeline", "batch_size", cfg.pipeline.batch_size as i64).max(1) as usize;
        cfg.pipeline.queue_capacity = doc
            .get_int("pipeline", "queue_capacity", cfg.pipeline.queue_capacity as i64)
            .max(1) as usize;
        cfg.pipeline.wire = match doc.get_str("pipeline", "wire", "bits") {
            "bits" => crate::coordinator::WireFormat::PackedBits,
            "dense" => crate::coordinator::WireFormat::DenseF64,
            other => bail!("unknown wire format '{other}' (bits|dense)"),
        };

        cfg.seed = doc.get_int("", "seed", 0) as u64;
        let threads = doc.get_int("", "threads", cfg.threads as i64);
        if threads < 0 {
            bail!("threads must be >= 0 (0 = all cores), got {threads}");
        }
        cfg.threads = threads as usize;
        cfg.decode.params.threads = cfg.threads;
        Ok(cfg)
    }

    /// Parse from TOML text.
    pub fn from_toml_str(text: &str) -> Result<JobConfig> {
        let doc = super::parse_toml(text).map_err(|e| anyhow::anyhow!("config parse: {e}"))?;
        Self::from_toml(&doc)
    }
}
