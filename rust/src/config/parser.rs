//! The TOML-subset parser.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed scalar value.
#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Bool(bool),
    Int(i64),
    Float(f64),
    Str(String),
}

impl TomlValue {
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_int(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }

    /// Floats accept integer literals too (`m = 1000`).
    pub fn as_float(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }
}

/// Parse error with a 1-based line number.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TomlError {
    pub line: usize,
    pub message: String,
}

impl fmt::Display for TomlError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "line {}: {}", self.line, self.message)
    }
}

impl std::error::Error for TomlError {}

/// A parsed document: `section → key → value`. Top-level keys live in the
/// unnamed section `""`.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TomlDoc {
    sections: BTreeMap<String, BTreeMap<String, TomlValue>>,
}

impl TomlDoc {
    /// Look up `key` in `section` (`""` for top level).
    pub fn get(&self, section: &str, key: &str) -> Option<&TomlValue> {
        self.sections.get(section).and_then(|s| s.get(key))
    }

    pub fn sections(&self) -> impl Iterator<Item = &str> {
        self.sections.keys().map(|s| s.as_str())
    }

    pub fn keys(&self, section: &str) -> Vec<&str> {
        self.sections
            .get(section)
            .map(|s| s.keys().map(|k| k.as_str()).collect())
            .unwrap_or_default()
    }

    // Typed getters with defaults — the common call pattern.
    pub fn get_bool(&self, section: &str, key: &str, default: bool) -> bool {
        self.get(section, key).and_then(|v| v.as_bool()).unwrap_or(default)
    }

    pub fn get_int(&self, section: &str, key: &str, default: i64) -> i64 {
        self.get(section, key).and_then(|v| v.as_int()).unwrap_or(default)
    }

    pub fn get_float(&self, section: &str, key: &str, default: f64) -> f64 {
        self.get(section, key).and_then(|v| v.as_float()).unwrap_or(default)
    }

    pub fn get_str<'a>(&'a self, section: &str, key: &str, default: &'a str) -> &'a str {
        self.get(section, key).and_then(|v| v.as_str()).unwrap_or(default)
    }
}

fn err(line: usize, message: impl Into<String>) -> TomlError {
    TomlError {
        line,
        message: message.into(),
    }
}

fn parse_value(raw: &str, line: usize) -> Result<TomlValue, TomlError> {
    let raw = raw.trim();
    if raw.is_empty() {
        return Err(err(line, "missing value"));
    }
    if raw == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if raw == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(stripped) = raw.strip_prefix('"') {
        let Some(inner) = stripped.strip_suffix('"') else {
            return Err(err(line, "unterminated string"));
        };
        if inner.contains('"') {
            return Err(err(line, "embedded quotes are not supported"));
        }
        return Ok(TomlValue::Str(inner.to_string()));
    }
    // Numbers: int first, then float.
    if let Ok(i) = raw.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = raw.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(err(line, format!("cannot parse value '{raw}'")))
}

fn valid_key(k: &str) -> bool {
    !k.is_empty()
        && k.chars()
            .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-' || c == '.')
}

/// Parse a TOML-subset document.
pub fn parse_toml(text: &str) -> Result<TomlDoc, TomlError> {
    let mut doc = TomlDoc::default();
    let mut current = String::new();
    doc.sections.entry(current.clone()).or_default();
    for (i, raw_line) in text.lines().enumerate() {
        let lineno = i + 1;
        // Strip comments (naive: '#' inside strings is not supported).
        let line = match raw_line.find('#') {
            Some(pos) if !raw_line[..pos].contains('"') => &raw_line[..pos],
            _ => raw_line,
        };
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        if let Some(inner) = line.strip_prefix('[') {
            let Some(name) = inner.strip_suffix(']') else {
                return Err(err(lineno, "unterminated section header"));
            };
            let name = name.trim();
            if !valid_key(name) {
                return Err(err(lineno, format!("invalid section name '{name}'")));
            }
            current = name.to_string();
            doc.sections.entry(current.clone()).or_default();
            continue;
        }
        let Some(eq) = line.find('=') else {
            return Err(err(lineno, format!("expected 'key = value', got '{line}'")));
        };
        let key = line[..eq].trim();
        if !valid_key(key) {
            return Err(err(lineno, format!("invalid key '{key}'")));
        }
        let value = parse_value(&line[eq + 1..], lineno)?;
        let section = doc.sections.get_mut(&current).unwrap();
        if section.insert(key.to_string(), value).is_some() {
            return Err(err(lineno, format!("duplicate key '{key}'")));
        }
    }
    Ok(doc)
}
