//! Configuration: a TOML-subset parser plus the typed job configs.
//!
//! The environment vendors no `serde`/`toml`, so this module implements the
//! small slice of TOML the launcher needs: `[section]` headers, `key =
//! value` pairs with bool / integer / float / quoted-string values, `#`
//! comments, and nothing else (no arrays-of-tables, no dates, no nesting).
//!
//! Typed views ([`JobConfig`] and friends) resolve defaults and validate
//! ranges so the CLI and the experiment harnesses share one source of truth.

mod parser;
mod types;

pub use parser::{parse_toml, TomlDoc, TomlError, TomlValue};
pub use types::{DecodeConfig, JobConfig, SketchConfig};

#[cfg(test)]
mod tests;
