//! Config parser + typed-config tests.

use super::*;

#[test]
fn parses_sections_scalars_and_comments() {
    let doc = parse_toml(
        r#"
# a job
seed = 42
[sketch]
num_frequencies = 500   # half the default
method = "qckm"
sigma = 1.5
dither = true
[decode]
k = 10
"#,
    )
    .unwrap();
    assert_eq!(doc.get("", "seed"), Some(&TomlValue::Int(42)));
    assert_eq!(doc.get("sketch", "num_frequencies"), Some(&TomlValue::Int(500)));
    assert_eq!(doc.get("sketch", "method"), Some(&TomlValue::Str("qckm".into())));
    assert_eq!(doc.get("sketch", "sigma"), Some(&TomlValue::Float(1.5)));
    assert_eq!(doc.get("sketch", "dither"), Some(&TomlValue::Bool(true)));
    assert_eq!(doc.get("decode", "k"), Some(&TomlValue::Int(10)));
    assert_eq!(doc.get("decode", "missing"), None);
    assert!(doc.sections().any(|s| s == "sketch"));
    assert_eq!(doc.keys("decode"), vec!["k"]);
}

#[test]
fn typed_getters_and_defaults() {
    let doc = parse_toml("x = 3\ny = 2.5\nz = \"s\"\nw = false\n").unwrap();
    assert_eq!(doc.get_int("", "x", 0), 3);
    assert_eq!(doc.get_float("", "x", 0.0), 3.0); // int coerces to float
    assert_eq!(doc.get_float("", "y", 0.0), 2.5);
    assert_eq!(doc.get_str("", "z", "d"), "s");
    assert!(!doc.get_bool("", "w", true));
    assert_eq!(doc.get_int("", "nope", 7), 7);
    // Wrong-type access falls back to default.
    assert_eq!(doc.get_int("", "z", 9), 9);
}

#[test]
fn parse_errors_carry_line_numbers() {
    for (text, line) in [
        ("a = \n", 1),
        ("[sec\nb = 1\n", 1),
        ("a = 1\na = 2\n", 2),
        ("novalue\n", 1),
        ("a = \"unterminated\n", 1),
        ("!bad = 1\n", 1),
        ("a = what?\n", 1),
    ] {
        let e = parse_toml(text).unwrap_err();
        assert_eq!(e.line, line, "for {text:?}: {e}");
    }
}

#[test]
fn job_config_from_toml_full() {
    let cfg = JobConfig::from_toml_str(
        r#"
seed = 7
[sketch]
num_frequencies = 250
method = "ckm"
law = "gaussian"
sigma = 2.0
[decode]
k = 4
replicates = 3
decoder = "hier:restarts=2"
[pipeline]
workers = 2
batch_size = 16
queue_capacity = 8
wire = "dense"
"#,
    )
    .unwrap();
    assert_eq!(cfg.seed, 7);
    assert_eq!(cfg.sketch.num_frequencies, 250);
    assert_eq!(cfg.sketch.method.canonical(), "ckm");
    assert_eq!(cfg.sketch.law, crate::frequency::FrequencyLaw::Gaussian);
    assert!(matches!(
        cfg.sketch.sigma,
        crate::frequency::SigmaHeuristic::Fixed(s) if s == 2.0
    ));
    assert_eq!(cfg.decode.k, 4);
    assert_eq!(cfg.decode.replicates, 3);
    assert_eq!(cfg.decode.decoder.canonical(), "hier:restarts=2");
    assert_eq!(cfg.pipeline.workers, 2);
    assert_eq!(cfg.pipeline.wire, crate::coordinator::WireFormat::DenseF64);
}

#[test]
fn job_config_defaults_when_empty() {
    let cfg = JobConfig::from_toml_str("").unwrap();
    assert_eq!(cfg.sketch.num_frequencies, 1000);
    assert_eq!(cfg.sketch.method.canonical(), "qckm");
    assert_eq!(cfg.decode.k, 10);
    assert_eq!(cfg.decode.decoder.canonical(), "clompr");
    assert_eq!(cfg.pipeline.wire, crate::coordinator::WireFormat::PackedBits);
}

#[test]
fn job_config_validation_errors() {
    assert!(JobConfig::from_toml_str("[sketch]\nnum_frequencies = 0\n").is_err());
    assert!(JobConfig::from_toml_str("[sketch]\nmethod = \"nope\"\n").is_err());
    assert!(JobConfig::from_toml_str("[sketch]\nlaw = \"cauchy\"\n").is_err());
    assert!(JobConfig::from_toml_str("[sketch]\nsigma = -1.0\n").is_err());
    assert!(JobConfig::from_toml_str("[decode]\nk = 0\n").is_err());
    assert!(JobConfig::from_toml_str("[decode]\nreplicates = 0\n").is_err());
    assert!(JobConfig::from_toml_str("[decode]\ndecoder = \"nope\"\n").is_err());
    assert!(JobConfig::from_toml_str("[pipeline]\nwire = \"morse\"\n").is_err());
    assert!(JobConfig::from_toml_str("[pipeline]\nworkers = 0\n").is_err());
}

#[test]
fn method_specs_flow_through_the_config() {
    // The config layer accepts any registry spec string, including
    // parameterized and aliased forms, and stores the canonical spec.
    let cfg =
        JobConfig::from_toml_str("[sketch]\nmethod = \"qckm:bits=3\"\n").unwrap();
    assert_eq!(cfg.sketch.method.canonical(), "qckm:bits=3");
    assert_eq!(cfg.sketch.method.signature().name(), "multibit-3");
    let cfg = JobConfig::from_toml_str("[sketch]\nmethod = \"tri\"\n").unwrap();
    assert_eq!(cfg.sketch.method.canonical(), "triangle");
    let cfg = JobConfig::from_toml_str("[sketch]\nmethod = \"modulo\"\n").unwrap();
    assert!(cfg.sketch.method.dithered());
    // Junk specs surface the registry's actionable error.
    let err = format!(
        "{:#}",
        JobConfig::from_toml_str("[sketch]\nmethod = \"nope\"\n").unwrap_err()
    );
    assert!(err.contains("valid families"), "{err}");
}
