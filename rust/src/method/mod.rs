//! The open method registry — parameterized compressive-method specs.
//!
//! The paper's Sec. 3 point is that the sketch generalizes to a *large
//! class* of periodic nonlinearities; this module is the codebase's single
//! extension point for that class. A [`MethodSpec`] is a parsed, canonical
//! descriptor of one compressive method: it bundles the [`Signature`]
//! instance, the dithering policy of Prop. 1, the preferred wire format
//! for pooled transport, a display name, and the per-slot acquisition cost
//! in bits. Every layer — TOML/CLI config, the streaming sketch stages,
//! the `.qsk` container, the online server protocol, and the experiment
//! harnesses — speaks spec strings and never matches on a method enum.
//!
//! ## Spec-string grammar
//!
//! ```text
//! spec   := family [":" param ("," param)*]
//! param  := key "=" value
//! ```
//!
//! Case-insensitive; the canonical form (lowercase, defaulted params
//! elided, keys in family-defined order) is what [`MethodSpec::canonical`]
//! returns, what `.qsk` v3 headers store, and what the server protocol
//! carries. Parsing the canonical form reproduces an equal spec.
//!
//! Current families (see [`MethodSpec::families_help`]):
//!
//! | spec            | signature                         | wire        |
//! |-----------------|-----------------------------------|-------------|
//! | `ckm`           | cosine (classical CKM)            | dense f64   |
//! | `qckm`          | 1-bit universal quantizer         | packed bits |
//! | `qckm:bits=B`   | `2^B`-level staircase, B in 2..=16| dense f64   |
//! | `triangle`      | even triangle wave (`tri` alias)  | dense f64   |
//! | `modulo`        | self-reset ADC ramp (sawtooth)    | dense f64   |
//!
//! `qckm:bits=1` canonicalizes to plain `qckm` — at one bit the staircase
//! *is* the universal quantizer, and collapsing them keeps the 1-bit
//! pipelines bit-for-bit identical to the legacy `qckm` name.
//!
//! ## Registering a new family
//!
//! Add one [`FamilyDef`] entry to [`FAMILIES`] with a builder that maps
//! parsed params to a [`MethodSpec`]. Nothing else: config, `qckm sketch /
//! merge / decode / serve / push / query`, `.qsk` persistence and the
//! experiments all resolve methods through this table, and parse errors
//! list the valid families from it automatically.

use crate::coordinator::WireFormat;
use crate::signature::{
    Cosine, ModuloRamp, MultiBitQuantizer, Signature, Triangle, UniversalQuantizer,
};
use crate::spec::Params;
use anyhow::{bail, Result};
use std::fmt;
use std::sync::Arc;

/// A fully resolved compressive-method descriptor.
///
/// Equality and ordering go by the canonical spec string — two specs that
/// print the same sketch identically.
#[derive(Clone)]
pub struct MethodSpec {
    canonical: String,
    display: String,
    signature: Arc<dyn Signature>,
    dithered: bool,
    wire: WireFormat,
    bits_per_slot: f64,
}

impl MethodSpec {
    /// Parse a spec string (`ckm`, `qckm`, `qckm:bits=3`, `triangle`,
    /// `modulo`, …). Case-insensitive; aliases accepted; junk specs get an
    /// error naming the valid families.
    pub fn parse(s: &str) -> Result<MethodSpec> {
        let lowered = s.trim().to_ascii_lowercase();
        if lowered.is_empty() {
            bail!(
                "empty method spec (valid families: {})",
                Self::families_help()
            );
        }
        let (family, rest) = match lowered.split_once(':') {
            Some((f, r)) => (f, Some(r)),
            None => (lowered.as_str(), None),
        };
        let Some(def) = FAMILIES
            .iter()
            .find(|d| d.family == family || d.aliases.iter().any(|a| *a == family))
        else {
            bail!(
                "unknown method '{family}' (valid families: {})",
                Self::families_help()
            );
        };
        let mut params = Params::parse("method", def.family, rest)?;
        let spec = (def.build)(&mut params)?;
        params.finish(def.params_help)?;
        Ok(spec)
    }

    /// The canonical spec string (`qckm:bits=3`); re-parses to an equal
    /// spec. This is what `.qsk` headers store and the server protocol
    /// carries.
    pub fn canonical(&self) -> &str {
        &self.canonical
    }

    /// Human-readable name for tables and logs (`qckm (3-bit staircase)`).
    pub fn display_name(&self) -> &str {
        &self.display
    }

    /// The signature function this method encodes with.
    pub fn signature(&self) -> Arc<dyn Signature> {
        Arc::clone(&self.signature)
    }

    /// Whether the frequency draw adds the uniform dither of Prop. 1.
    /// CKM historically runs undithered (the complex exponential needs no
    /// dither); every other signature requires it.
    pub fn dithered(&self) -> bool {
        self.dithered
    }

    /// The wire/pooling format this method's contributions prefer:
    /// [`WireFormat::PackedBits`] for ±1-valued signatures (one bit per
    /// slot), [`WireFormat::DenseF64`] otherwise. The single source of the
    /// method→wire mapping the CLI used to duplicate.
    pub fn preferred_wire_format(&self) -> WireFormat {
        self.wire
    }

    /// Acquired bits per sketch slot (1 for the 1-bit quantizer, B for the
    /// B-bit staircase, 64 for full-precision signatures) — the resource
    /// axis of the bit-depth ablation.
    pub fn bits_per_slot(&self) -> f64 {
        self.bits_per_slot
    }

    /// The valid spec grammars, comma-separated — used by every "unknown
    /// method" error and by `--help` text, so the list can never go stale.
    pub fn families_help() -> String {
        FAMILIES
            .iter()
            .map(|d| d.grammar)
            .collect::<Vec<_>>()
            .join(", ")
    }
}

impl PartialEq for MethodSpec {
    fn eq(&self, other: &Self) -> bool {
        self.canonical == other.canonical
    }
}

impl Eq for MethodSpec {}

impl fmt::Debug for MethodSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "MethodSpec({})", self.canonical)
    }
}

impl fmt::Display for MethodSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.canonical)
    }
}

impl std::str::FromStr for MethodSpec {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        Self::parse(s)
    }
}

// ---------------------------------------------------------------- registry

/// One method family: the single place a nonlinearity registers.
struct FamilyDef {
    /// Canonical family name.
    family: &'static str,
    /// Accepted alternative spellings.
    aliases: &'static [&'static str],
    /// Grammar shown in "valid families" errors, e.g. `qckm[:bits=B]`.
    grammar: &'static str,
    /// Params shown in unknown-parameter errors, e.g. `bits=B (1..=16)`.
    params_help: &'static str,
    /// Build a spec from parsed params (take what you accept; leftovers
    /// are rejected by the caller).
    build: fn(&mut Params) -> Result<MethodSpec>,
}

/// The method registry. Adding a family = adding one entry here.
static FAMILIES: &[FamilyDef] = &[
    FamilyDef {
        family: "ckm",
        aliases: &[],
        grammar: "ckm",
        params_help: "none",
        build: build_ckm,
    },
    FamilyDef {
        family: "qckm",
        aliases: &[],
        grammar: "qckm[:bits=B]",
        params_help: "bits=B (1..=16, default 1)",
        build: build_qckm,
    },
    FamilyDef {
        family: "triangle",
        aliases: &["tri"],
        grammar: "triangle",
        params_help: "none",
        build: build_triangle,
    },
    FamilyDef {
        family: "modulo",
        aliases: &["sawtooth"],
        grammar: "modulo",
        params_help: "none",
        build: build_modulo,
    },
];

fn build_ckm(_p: &mut Params) -> Result<MethodSpec> {
    Ok(MethodSpec {
        canonical: "ckm".into(),
        display: "ckm (64-bit cosine)".into(),
        signature: Arc::new(Cosine),
        dithered: false,
        wire: WireFormat::DenseF64,
        bits_per_slot: 64.0,
    })
}

fn build_qckm(p: &mut Params) -> Result<MethodSpec> {
    let bits = p.take_u32("bits")?.unwrap_or(1);
    if !(1..=16).contains(&bits) {
        bail!("qckm: bits must be in 1..=16, got {bits}");
    }
    Ok(if bits == 1 {
        // At one bit the rescaled staircase IS the universal quantizer;
        // canonicalizing keeps 1-bit pipelines on the legacy `qckm` name
        // (and its packed-bit wire) bit-for-bit.
        MethodSpec {
            canonical: "qckm".into(),
            display: "qckm (1-bit)".into(),
            signature: Arc::new(UniversalQuantizer),
            dithered: true,
            wire: WireFormat::PackedBits,
            bits_per_slot: 1.0,
        }
    } else {
        MethodSpec {
            canonical: format!("qckm:bits={bits}"),
            display: format!("qckm ({bits}-bit staircase)"),
            signature: Arc::new(MultiBitQuantizer::new(bits)),
            dithered: true,
            wire: WireFormat::DenseF64,
            bits_per_slot: bits as f64,
        }
    })
}

fn build_triangle(_p: &mut Params) -> Result<MethodSpec> {
    Ok(MethodSpec {
        canonical: "triangle".into(),
        display: "triangle (64-bit)".into(),
        signature: Arc::new(Triangle),
        dithered: true,
        wire: WireFormat::DenseF64,
        bits_per_slot: 64.0,
    })
}

fn build_modulo(_p: &mut Params) -> Result<MethodSpec> {
    Ok(MethodSpec {
        canonical: "modulo".into(),
        display: "modulo (self-reset ramp)".into(),
        signature: Arc::new(ModuloRamp),
        dithered: true,
        wire: WireFormat::DenseF64,
        bits_per_slot: 64.0,
    })
}

#[cfg(test)]
mod tests;
