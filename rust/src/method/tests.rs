//! Method-registry tests: spec grammar, canonicalization, round-trips,
//! actionable errors, and an end-to-end decode through a registry-built
//! operator for the new families.

use super::*;
use crate::testkit::property;

#[test]
fn legacy_names_resolve_to_the_seed_pipelines() {
    let ckm = MethodSpec::parse("ckm").unwrap();
    assert_eq!(ckm.canonical(), "ckm");
    assert_eq!(ckm.signature().name(), "cosine");
    assert!(!ckm.dithered());
    assert_eq!(ckm.preferred_wire_format(), WireFormat::DenseF64);

    let qckm = MethodSpec::parse("qckm").unwrap();
    assert_eq!(qckm.canonical(), "qckm");
    assert_eq!(qckm.signature().name(), "universal-1bit");
    assert!(qckm.dithered());
    assert_eq!(qckm.preferred_wire_format(), WireFormat::PackedBits);
    assert_eq!(qckm.bits_per_slot(), 1.0);

    let tri = MethodSpec::parse("triangle").unwrap();
    assert_eq!(tri.signature().name(), "triangle");
    assert!(tri.dithered());
    assert_eq!(tri.preferred_wire_format(), WireFormat::DenseF64);
}

#[test]
fn aliases_and_case_canonicalize() {
    assert_eq!(MethodSpec::parse("tri").unwrap().canonical(), "triangle");
    assert_eq!(MethodSpec::parse("QCKM").unwrap().canonical(), "qckm");
    assert_eq!(
        MethodSpec::parse(" Qckm:Bits=3 ").unwrap().canonical(),
        "qckm:bits=3"
    );
    assert_eq!(MethodSpec::parse("sawtooth").unwrap().canonical(), "modulo");
    // bits=1 collapses onto the legacy 1-bit family (same signature, same
    // packed wire) so it stays bit-for-bit the seed pipeline.
    let one = MethodSpec::parse("qckm:bits=1").unwrap();
    assert_eq!(one, MethodSpec::parse("qckm").unwrap());
    assert_eq!(one.signature().name(), "universal-1bit");
}

#[test]
fn parameterized_qckm_builds_staircases() {
    for bits in 2..=16u32 {
        let spec = MethodSpec::parse(&format!("qckm:bits={bits}")).unwrap();
        assert_eq!(spec.canonical(), format!("qckm:bits={bits}"));
        assert_eq!(spec.signature().name(), format!("multibit-{bits}"));
        assert!(spec.dithered());
        assert_eq!(spec.preferred_wire_format(), WireFormat::DenseF64);
        assert_eq!(spec.bits_per_slot(), bits as f64);
    }
    // Distinct bit depths must never collapse: their operators are
    // incompatible and the fingerprint keys on the signature name.
    assert_ne!(
        MethodSpec::parse("qckm:bits=2").unwrap().signature().name(),
        MethodSpec::parse("qckm:bits=3").unwrap().signature().name()
    );
}

#[test]
fn modulo_family_is_phase_shifted() {
    let spec = MethodSpec::parse("modulo").unwrap();
    assert_eq!(spec.signature().name(), "modulo-ramp");
    assert!(spec.dithered());
    assert!(
        (spec.signature().first_harmonic_phase() - std::f64::consts::FRAC_PI_2).abs() < 1e-15
    );
    assert!(
        (spec.signature().first_harmonic_amplitude() - 2.0 / std::f64::consts::PI).abs() < 1e-12
    );
}

#[test]
fn junk_specs_give_actionable_errors() {
    // Unknown family names the valid ones.
    let err = format!("{:#}", MethodSpec::parse("fourier").unwrap_err());
    for family in ["ckm", "qckm[:bits=B]", "triangle", "modulo"] {
        assert!(err.contains(family), "error does not name '{family}': {err}");
    }
    let err = format!("{:#}", MethodSpec::parse("").unwrap_err());
    assert!(err.contains("valid families"), "{err}");

    // Malformed / unknown / duplicate / out-of-range parameters.
    assert!(MethodSpec::parse("qckm:").is_err());
    assert!(MethodSpec::parse("qckm:bits").is_err());
    assert!(MethodSpec::parse("qckm:bits=").is_err());
    assert!(MethodSpec::parse("qckm:bits=zero").is_err());
    assert!(MethodSpec::parse("qckm:bits=0").is_err());
    assert!(MethodSpec::parse("qckm:bits=17").is_err());
    assert!(MethodSpec::parse("qckm:bits=2,bits=3").is_err());
    let err = format!("{:#}", MethodSpec::parse("qckm:depth=2").unwrap_err());
    assert!(err.contains("bits=B"), "unknown-param error must name accepted params: {err}");
    let err = format!("{:#}", MethodSpec::parse("ckm:bits=2").unwrap_err());
    assert!(err.contains("does not accept"), "{err}");
}

/// Every canonical spec string re-parses to an equal spec with the same
/// canonical form — the grammar round-trip contract (`.qsk` headers and
/// the server protocol rely on it).
#[test]
fn prop_canonical_specs_round_trip() {
    property("method spec round-trip", 200, |g| {
        let spec = match g.usize_in(0, 4) {
            0 => MethodSpec::parse("ckm").unwrap(),
            1 => MethodSpec::parse("qckm").unwrap(),
            2 => MethodSpec::parse("triangle").unwrap(),
            3 => MethodSpec::parse("modulo").unwrap(),
            _ => {
                let bits = g.usize_in(1, 16);
                MethodSpec::parse(&format!("qckm:bits={bits}")).unwrap()
            }
        };
        let reparsed = MethodSpec::parse(spec.canonical()).unwrap();
        assert_eq!(reparsed, spec);
        assert_eq!(reparsed.canonical(), spec.canonical());
        assert_eq!(reparsed.display_name(), spec.display_name());
        assert_eq!(reparsed.signature().name(), spec.signature().name());
        assert_eq!(reparsed.dithered(), spec.dithered());
        assert_eq!(reparsed.preferred_wire_format(), spec.preferred_wire_format());
        // Uppercasing / whitespace never changes the resolved spec.
        let shouted = spec.canonical().to_ascii_uppercase();
        assert_eq!(MethodSpec::parse(&format!(" {shouted} ")).unwrap(), spec);
    });
}

/// Random junk never parses silently: either it is one of the known
/// grammars or the error names the valid families.
#[test]
fn prop_junk_specs_error_with_family_list() {
    property("junk method specs", 200, |g| {
        let len = g.usize_in(1, 12);
        let junk: String = (0..len)
            .map(|_| (b'a' + g.usize_in(0, 25) as u8) as char)
            .collect();
        if let Err(e) = MethodSpec::parse(&junk) {
            let msg = format!("{e:#}");
            assert!(
                msg.contains("valid families") || msg.contains("parameter"),
                "unhelpful error for '{junk}': {msg}"
            );
        }
    });
}

/// The registry proves itself end-to-end: a constant dataset sketched
/// through each *new* family decodes its single centroid back (the
/// modulo ramp exercises the phase-shifted atom path — a wrong phase
/// would send the centroid far off).
#[test]
fn new_families_decode_a_dirac_through_the_registry() {
    use crate::clompr::ClOmpr;
    use crate::frequency::{DrawnFrequencies, FrequencyLaw};
    use crate::linalg::Mat;
    use crate::rng::Rng;
    use crate::sketch::SketchOperator;

    for spec_str in ["modulo", "qckm:bits=3"] {
        let spec = MethodSpec::parse(spec_str).unwrap();
        let mut rng = Rng::new(61);
        let x = Mat::from_fn(400, 3, |_, c| 0.3 * (c as f64 + 1.0)); // all rows equal
        // m = 96 frequencies: the ramp's harmonic tail (π²/6 − 1 ≈ 0.64) is
        // ~3× the quantizer's, so give the dithered average more samples.
        let freqs = DrawnFrequencies::draw(FrequencyLaw::Gaussian, 3, 96, 1.0, &mut rng);
        assert!(spec.dithered());
        let op = SketchOperator::new(freqs, spec.signature());
        let z = op.sketch_dataset(&x);
        let sol = ClOmpr::new(&op, 1)
            .with_bounds(vec![-1.0; 3], vec![2.0; 3])
            .run(&z, &mut rng);
        for (j, &v) in sol.centroids.row(0).iter().enumerate() {
            let want = 0.3 * (j as f64 + 1.0);
            assert!(
                (v - want).abs() < 0.25,
                "{spec_str}: coord {j}: {v} vs {want}"
            );
        }
    }
}
