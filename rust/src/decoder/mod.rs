//! The open decoder registry — pluggable sketch-to-centroids solvers.
//!
//! The compressive-learning literature treats the decoder as an
//! interchangeable component: the sketch fixes the *moment-matching
//! inverse problem* `min ‖z − Σ_k α_k a(c_k)‖²`, and CL-OMPR is just one
//! greedy heuristic for it (Keriven et al., *Compressive K-means*;
//! Gribonval et al., *Compressive Statistical Learning with Random
//! Feature Moments*). This module is the decode-side mirror of the method
//! registry ([`crate::method`]): a [`DecoderSpec`] is a parsed, canonical
//! descriptor of one decoding algorithm, every layer (CLI flags, TOML
//! config, the server protocol, the experiment harnesses) speaks decoder
//! spec strings, and a new algorithm registers once in the `DECODERS`
//! table.
//!
//! ## Spec-string grammar
//!
//! ```text
//! spec   := name [":" param ("," param)*]
//! param  := key "=" value
//! ```
//!
//! Case-insensitive; the canonical form (lowercase, explicit params in
//! registry order) is what [`DecoderSpec::canonical`] returns and what
//! the server protocol carries — the centroid cache keys on it, so a
//! query can never be answered with centroids decoded under a different
//! algorithm. Parsing the canonical form reproduces an equal spec.
//!
//! Current decoders (see [`DecoderSpec::decoders_help`]):
//!
//! | spec                                | algorithm                                    |
//! |-------------------------------------|----------------------------------------------|
//! | `clompr`                            | CL-OMPR (the paper's decoder, the default)   |
//! | `clompr:restarts=R,replacements=P`  | CL-OMPR, R Step-1 restarts, P outer passes/K |
//! | `hier`                              | recursive bisection over k = 2 subproblems   |
//! | `hier:restarts=R`                   | same, R Step-1 restarts per subproblem       |
//!
//! Explicit params always override the base [`ClOmprParams`] a caller
//! supplies (even when they equal the compiled-in defaults), so
//! `clompr:restarts=3` and `clompr` are distinct specs on purpose: the
//! former pins Step 1 to 3 restarts no matter what the job config says.
//!
//! ## `hier` — the recursive-bisection decoder
//!
//! CL-OMPR runs `2K` outer iterations, each refining the *entire* support
//! jointly — `O(K²)` atom evaluations per sweep — which dominates decode
//! time at large K. `hier` instead splits the problem: fit a k = 2
//! mixture with a short CL-OMPR run, split the search box at the midpoint
//! of the two centroids (along their widest-separated coordinate), divide
//! the remaining cluster budget between the halves in proportion to the
//! fitted weights, and recurse on the *residual sketches* (each branch
//! sees `z` minus the sibling's fitted atom) within its sub-box. The K
//! leaf centroids then get one global NNLS weight projection and one
//! joint Step-5 polish on the full sketch. Total work is `O(K)` cheap
//! k = 2 subproblems plus a single full-support refinement — a genuinely
//! different speed/quality trade-off (see `benches/decode_bench.rs`).
//!
//! ## Registering a new decoder
//!
//! Add one `DecoderDef` entry to `DECODERS` with a builder that maps
//! parsed params to a [`DecoderSpec`] whose factory produces a
//! [`SketchDecoder`]. Nothing else: the `--decoder` flags on
//! `qckm cluster / decode / query / experiment`, the `decoder` TOML key,
//! the server's query frames and centroid-cache keys, and the experiment
//! harnesses all resolve decoders through this table, and parse errors
//! list the valid decoders from it automatically.

pub mod clompr;
mod hier;

pub use hier::HierDecoder;

use crate::rng::Rng;
use crate::sketch::SketchOperator;
use crate::spec::Params;
use anyhow::{bail, Result};
use clompr::{ClOmpr, ClOmprParams, Solution};
use std::fmt;
use std::sync::Arc;

/// One algorithm for the sketch inverse problem: given the pooled sketch
/// `z`, produce `k` centroids inside the box `[lo, hi]`.
///
/// Implementations must be deterministic functions of `(op, z, k, lo, hi)`
/// and the `rng` stream — the repo-wide reproducibility contract — and
/// must return weights normalized to sum 1 with the residual objective
/// `‖z − Σ α a(c)‖` of the *fitted* (unnormalized) weights, exactly like
/// [`ClOmpr::run`], so replicate selection is decoder-agnostic.
pub trait SketchDecoder: Send + Sync {
    /// Decode `k` centroids from the pooled sketch `z` (length `2M`).
    fn decode(
        &self,
        op: &SketchOperator,
        z: &[f64],
        k: usize,
        lo: &[f64],
        hi: &[f64],
        rng: &mut Rng,
    ) -> Solution;
}

/// Builds a decoder from the caller's base tuning. The base
/// [`ClOmprParams`] carries the job-level knobs every current decoder
/// shares (thread budget, L-BFGS iteration caps, candidate counts); spec
/// params override individual fields on top of it.
type DecoderFactory = dyn Fn(&ClOmprParams) -> Box<dyn SketchDecoder> + Send + Sync;

/// A fully resolved decoder descriptor.
///
/// Equality and ordering go by the canonical spec string — two specs that
/// print the same decode identically (given the same base params).
#[derive(Clone)]
pub struct DecoderSpec {
    canonical: String,
    display: String,
    factory: Arc<DecoderFactory>,
}

impl DecoderSpec {
    /// Parse a spec string (`clompr`, `clompr:restarts=5`, `hier`, …).
    /// Case-insensitive; aliases accepted; junk specs get an error naming
    /// the valid decoders.
    pub fn parse(s: &str) -> Result<DecoderSpec> {
        let lowered = s.trim().to_ascii_lowercase();
        if lowered.is_empty() {
            bail!(
                "empty decoder spec (valid decoders: {})",
                Self::decoders_help()
            );
        }
        let (name, rest) = match lowered.split_once(':') {
            Some((f, r)) => (f, Some(r)),
            None => (lowered.as_str(), None),
        };
        let Some(def) = DECODERS
            .iter()
            .find(|d| d.name == name || d.aliases.iter().any(|a| *a == name))
        else {
            bail!(
                "unknown decoder '{name}' (valid decoders: {})",
                Self::decoders_help()
            );
        };
        let mut params = Params::parse("decoder", def.name, rest)?;
        let spec = (def.build)(&mut params)?;
        params.finish(def.params_help)?;
        Ok(spec)
    }

    /// The canonical spec string (`clompr:restarts=5`); re-parses to an
    /// equal spec. This is what the server protocol carries and the
    /// centroid cache keys on.
    pub fn canonical(&self) -> &str {
        &self.canonical
    }

    /// Human-readable name for tables and logs.
    pub fn display_name(&self) -> &str {
        &self.display
    }

    /// The valid spec grammars, comma-separated — used by every "unknown
    /// decoder" error and by `--help` text, so the list can never go
    /// stale.
    pub fn decoders_help() -> String {
        DECODERS
            .iter()
            .map(|d| d.grammar)
            .collect::<Vec<_>>()
            .join(", ")
    }

    /// Instantiate the decoder over the caller's base tuning (spec params
    /// override individual fields of `base`).
    pub fn decoder(&self, base: &ClOmprParams) -> Box<dyn SketchDecoder> {
        (self.factory)(base)
    }

    /// Run the decoder `replicates` times and keep the solution with the
    /// best sketch-matching objective — the registry-routed form of
    /// [`clompr::decode_best_of`], with identical replicate semantics
    /// (serial on the shared `rng` stream, first strictly-better wins),
    /// so `DecoderSpec::parse("clompr")` reproduces the legacy pipelines
    /// bit for bit.
    #[allow(clippy::too_many_arguments)]
    pub fn decode_best_of(
        &self,
        op: &SketchOperator,
        k: usize,
        z: &[f64],
        lo: Vec<f64>,
        hi: Vec<f64>,
        base: &ClOmprParams,
        replicates: usize,
        rng: &mut Rng,
    ) -> Solution {
        assert!(replicates >= 1);
        let decoder = self.decoder(base);
        // Per-replicate latency histogram, labeled by decoder *family*
        // (the canonical spec's name segment) — clients choose parameter
        // strings freely, so full specs would be unbounded label
        // cardinality (observational only, I-18).
        let family = self.canonical.split(':').next().unwrap_or("unknown");
        let hist = crate::obs::decode_seconds(family);
        let mut best: Option<Solution> = None;
        for _ in 0..replicates {
            let sol = {
                let _span = crate::obs::global().span("decode", &hist);
                decoder.decode(op, z, k, &lo, &hi, rng)
            };
            if best.as_ref().map_or(true, |b| sol.objective < b.objective) {
                best = Some(sol);
            }
        }
        best.unwrap()
    }
}

impl Default for DecoderSpec {
    /// The paper's decoder: plain CL-OMPR with the caller's base params.
    fn default() -> Self {
        DecoderSpec::parse("clompr").expect("default decoder spec")
    }
}

impl PartialEq for DecoderSpec {
    fn eq(&self, other: &Self) -> bool {
        self.canonical == other.canonical
    }
}

impl Eq for DecoderSpec {}

impl fmt::Debug for DecoderSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "DecoderSpec({})", self.canonical)
    }
}

impl fmt::Display for DecoderSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.canonical)
    }
}

impl std::str::FromStr for DecoderSpec {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        Self::parse(s)
    }
}

// ---------------------------------------------------------------- registry

/// One decoder family: the single place an algorithm registers.
struct DecoderDef {
    /// Canonical decoder name.
    name: &'static str,
    /// Accepted alternative spellings.
    aliases: &'static [&'static str],
    /// Grammar shown in "valid decoders" errors, e.g. `hier[:restarts=R]`.
    grammar: &'static str,
    /// Params shown in unknown-parameter errors.
    params_help: &'static str,
    /// Build a spec from parsed params (take what you accept; leftovers
    /// are rejected by the caller).
    build: fn(&mut Params) -> Result<DecoderSpec>,
}

/// The decoder registry. Adding an algorithm = adding one entry here.
static DECODERS: &[DecoderDef] = &[
    DecoderDef {
        name: "clompr",
        aliases: &["cl-ompr", "clomp"],
        grammar: "clompr[:restarts=R,replacements=P]",
        params_help: "restarts=R (>= 1, Step-1 L-BFGS restarts), \
                      replacements=P (>= 1, outer replacement passes per cluster)",
        build: build_clompr,
    },
    DecoderDef {
        name: "hier",
        aliases: &["bisect"],
        grammar: "hier[:restarts=R]",
        params_help: "restarts=R (>= 1, Step-1 restarts of each k=2 subproblem)",
        build: build_hier,
    },
];

/// Render `name[:k1=v1,...]` for the given params, in registry order.
fn render_canonical(name: &str, params: &[(&str, Option<u32>)]) -> String {
    let given: Vec<String> = params
        .iter()
        .filter_map(|(k, v)| v.map(|v| format!("{k}={v}")))
        .collect();
    if given.is_empty() {
        name.to_string()
    } else {
        format!("{name}:{}", given.join(","))
    }
}

fn take_positive(p: &mut Params, key: &str) -> Result<Option<u32>> {
    let v = p.take_u32(key)?;
    if let Some(v) = v {
        if v == 0 {
            bail!("parameter '{key}': must be >= 1, got 0");
        }
    }
    Ok(v)
}

fn build_clompr(p: &mut Params) -> Result<DecoderSpec> {
    let restarts = take_positive(p, "restarts")?;
    let replacements = take_positive(p, "replacements")?;
    let canonical = render_canonical(
        "clompr",
        &[("restarts", restarts), ("replacements", replacements)],
    );
    Ok(DecoderSpec {
        display: match (restarts, replacements) {
            (None, None) => "cl-ompr (greedy matching pursuit)".to_string(),
            _ => format!("cl-ompr ({canonical})"),
        },
        canonical,
        factory: Arc::new(move |base: &ClOmprParams| {
            let mut params = base.clone();
            if let Some(r) = restarts {
                params.step1_restarts = r as usize;
            }
            if let Some(p) = replacements {
                params.outer_iters_factor = p as usize;
            }
            Box::new(ClOmprDecoder { params })
        }),
    })
}

fn build_hier(p: &mut Params) -> Result<DecoderSpec> {
    let restarts = take_positive(p, "restarts")?;
    let canonical = render_canonical("hier", &[("restarts", restarts)]);
    Ok(DecoderSpec {
        display: match restarts {
            None => "hier (recursive bisection)".to_string(),
            Some(_) => format!("hier ({canonical})"),
        },
        canonical,
        factory: Arc::new(move |base: &ClOmprParams| {
            let mut params = base.clone();
            if let Some(r) = restarts {
                params.step1_restarts = r as usize;
            }
            Box::new(HierDecoder::new(params))
        }),
    })
}

// ----------------------------------------------------------- implementations

/// The paper's decoder behind the [`SketchDecoder`] trait: one
/// [`ClOmpr::run`] per call, nothing added — the registry's default path
/// is bitwise the legacy direct construction.
struct ClOmprDecoder {
    params: ClOmprParams,
}

impl SketchDecoder for ClOmprDecoder {
    fn decode(
        &self,
        op: &SketchOperator,
        z: &[f64],
        k: usize,
        lo: &[f64],
        hi: &[f64],
        rng: &mut Rng,
    ) -> Solution {
        ClOmpr::new(op, k)
            .with_bounds(lo.to_vec(), hi.to_vec())
            .with_params(self.params.clone())
            .run(z, rng)
    }
}

#[cfg(test)]
mod tests;
