//! `hier` — the recursive-bisection decoder.
//!
//! Solves the K-centroid inverse problem as a tree of k = 2 CL-OMPR
//! subproblems (see the algorithm sketch in [`crate::decoder`]):
//!
//! 1. Fit a 2-atom mixture to the current sketch inside the current box
//!    with a short CL-OMPR run (subproblems skip the expensive final
//!    polish — one global polish happens at the root instead).
//! 2. Refit the two atoms' unnormalized weights by NNLS on the current
//!    sketch, split the box at the midpoint of the two centroids along
//!    their widest-separated coordinate, and divide the remaining cluster
//!    budget between the halves in proportion to the fitted weights.
//! 3. Recurse on each half with its *residual sketch* — the current
//!    sketch minus the sibling's fitted atom — so mass the sibling
//!    explains does not attract this branch's Step-1 search.
//! 4. At the root, project the K leaf centroids' weights on the full
//!    sketch (NNLS) and run one joint Step-5 refinement over the whole
//!    support.
//!
//! The box split (not just the residual) is what prevents the two
//! branches from rediscovering the same atom: every leaf's search is
//! confined to a cell of a binary space partition, so each well-separated
//! mode is reachable by exactly one branch. Cost is `O(K)` k = 2
//! subproblems — each with O(1)-atom Step-5 refinements — plus one
//! full-support polish, versus CL-OMPR's `2K` outer iterations with up to
//! K-atom refinements; at large K the wall-clock gap is the point (see
//! `benches/decode_bench.rs`). Quality on hard, overlapping mixtures is
//! below CL-OMPR's — the bisection commits early — which is the trade.
//!
//! Determinism: the recursion order is fixed (low side first), every
//! subproblem consumes the shared `rng` stream sequentially, and all the
//! building blocks inherit the thread-invariance contract of
//! [`crate::parallel`], so decodes are bit-for-bit reproducible at every
//! thread count, like everything else in this crate.

use super::clompr::{ClOmpr, ClOmprParams, Solution};
use super::SketchDecoder;
use crate::linalg::{axpy, norm2, sub, Mat};
use crate::rng::Rng;
use crate::sketch::SketchOperator;

/// Below this total fitted weight the 2-atom fit carries no usable mass
/// signal, and the cluster budget splits evenly instead of by weight.
const MIN_BRANCH_WEIGHT: f64 = 1e-12;

/// The recursive-bisection decoder. Register-constructed via the `hier`
/// spec ([`crate::decoder::DecoderSpec`]); the params are the same base
/// tuning CL-OMPR uses (thread budget, iteration caps), applied to every
/// k = 2 subproblem and to the final global polish.
pub struct HierDecoder {
    params: ClOmprParams,
}

impl HierDecoder {
    pub fn new(params: ClOmprParams) -> Self {
        Self { params }
    }

    /// Subproblems skip the expensive final polish: their last outer
    /// iteration refines with the intermediate `step5_iters` budget, and
    /// the one `step5_final_iters` polish happens globally at the root.
    fn subproblem_params(&self) -> ClOmprParams {
        ClOmprParams {
            step5_final_iters: self.params.step5_iters,
            ..self.params.clone()
        }
    }

    /// Recursively collect `k` leaf centroids from `z` inside `[lo, hi]`.
    #[allow(clippy::too_many_arguments)]
    fn bisect(
        &self,
        op: &SketchOperator,
        z: &[f64],
        k: usize,
        lo: &[f64],
        hi: &[f64],
        rng: &mut Rng,
        out: &mut Vec<Vec<f64>>,
    ) {
        let sub_k = k.min(2);
        let sol = {
            // One span per split solve (observational only, I-18).
            let _span = crate::obs::global()
                .span("hier_split", &crate::obs::lib_metrics().hier_split_seconds);
            ClOmpr::new(op, sub_k)
                .with_bounds(lo.to_vec(), hi.to_vec())
                .with_params(self.subproblem_params())
                .run(z, rng)
        };
        if k <= 2 {
            for c in 0..sol.centroids.rows() {
                out.push(sol.centroids.row(c).to_vec());
            }
            return;
        }

        // Refit the two atoms' unnormalized weights on this branch's
        // sketch — `Solution` weights are normalized to sum 1, but the
        // budget split and the residual subtraction need the fitted scale.
        let solver = ClOmpr::new(op, 2)
            .with_bounds(lo.to_vec(), hi.to_vec())
            .with_params(self.subproblem_params());
        let alphas = solver.project_weights(z, &sol.centroids, 1.0);
        let (c0, c1) = (sol.centroids.row(0), sol.centroids.row(1));

        // Split the box at the midpoint of the two centroids along their
        // widest-separated coordinate; branch 0 keeps the low side.
        let mut dim_split = 0;
        let mut widest = -1.0;
        for d in 0..op.dim() {
            let gap = (c0[d] - c1[d]).abs();
            if gap > widest {
                widest = gap;
                dim_split = d;
            }
        }
        let mid = 0.5 * (c0[dim_split] + c1[dim_split]);
        let mut hi_low = hi.to_vec();
        hi_low[dim_split] = mid;
        let mut lo_high = lo.to_vec();
        lo_high[dim_split] = mid;

        // Cluster budget proportional to the fitted weights of each side,
        // clamped so both branches keep at least one cluster.
        let (w_low, w_high) = if c0[dim_split] <= c1[dim_split] {
            (alphas[0], alphas[1])
        } else {
            (alphas[1], alphas[0])
        };
        let total = w_low + w_high;
        let k_low = if total > MIN_BRANCH_WEIGHT {
            ((k as f64 * w_low / total).round() as usize).clamp(1, k - 1)
        } else {
            k / 2
        };
        let k_high = k - k_low;

        // Residual sketches: each branch sees z minus the sibling's
        // fitted atom.
        let (i_low, i_high) = if c0[dim_split] <= c1[dim_split] {
            (0, 1)
        } else {
            (1, 0)
        };
        let mut z_low = z.to_vec();
        axpy(-alphas[i_high], &op.atom(sol.centroids.row(i_high)), &mut z_low);
        let mut z_high = z.to_vec();
        axpy(-alphas[i_low], &op.atom(sol.centroids.row(i_low)), &mut z_high);

        self.bisect(op, &z_low, k_low, lo, &hi_low, rng, out);
        self.bisect(op, &z_high, k_high, &lo_high, hi, rng, out);
    }
}

impl SketchDecoder for HierDecoder {
    fn decode(
        &self,
        op: &SketchOperator,
        z: &[f64],
        k: usize,
        lo: &[f64],
        hi: &[f64],
        rng: &mut Rng,
    ) -> Solution {
        assert_eq!(z.len(), op.sketch_len(), "sketch length mismatch");
        assert!(k >= 1, "need at least one cluster");
        let mut leaves: Vec<Vec<f64>> = Vec::with_capacity(k);
        self.bisect(op, z, k, lo, hi, rng, &mut leaves);
        debug_assert_eq!(leaves.len(), k);
        let mut centroids = Mat::zeros(0, op.dim());
        for c in &leaves {
            centroids.push_row(c);
        }

        // Global polish: NNLS weight projection on the full sketch, then
        // one joint Step-5 refinement over the whole support — the same
        // finishing moves CL-OMPR applies on its last outer iteration.
        let polisher = ClOmpr::new(op, k)
            .with_bounds(lo.to_vec(), hi.to_vec())
            .with_params(self.params.clone());
        let mut alphas = polisher.project_weights(z, &centroids, 1.0);
        polisher.step5_refine(z, &mut centroids, &mut alphas, self.params.step5_final_iters);

        let model = op.mixture_sketch(&centroids, &alphas);
        let objective = norm2(&sub(z, &model));
        let total: f64 = alphas.iter().sum();
        let weights = if total > 0.0 {
            alphas.iter().map(|a| a / total).collect()
        } else {
            vec![1.0 / k as f64; k]
        };
        Solution {
            centroids,
            weights,
            objective,
            // A k-leaf binary bisection tree runs exactly k − 1 splits;
            // there is no hard-threshold step, so nothing is replaced.
            outer_iters: (k as u32).saturating_sub(1),
            atoms_replaced: 0,
        }
    }
}
