//! Decoder-registry tests: spec grammar and canonicalization, bitwise
//! parity between the registry's default `clompr` path and the direct
//! [`ClOmpr`] construction, param plumbing, actionable junk-spec errors,
//! and a `hier` recovery smoke test on well-separated centroids.

use super::clompr::{decode_best_of, ClOmpr, ClOmprParams};
use super::*;
use crate::frequency::{DrawnFrequencies, FrequencyLaw};
use crate::linalg::Mat;

fn dirac_op(m: usize, dim: usize, seed: u64) -> SketchOperator {
    let mut rng = Rng::new(seed);
    let freqs = DrawnFrequencies::draw(FrequencyLaw::AdaptedRadius, dim, m, 1.0, &mut rng);
    SketchOperator::quantized(freqs)
}

/// Match decoded centroids to true ones greedily; returns the worst
/// matched distance.
fn match_centroids(found: &Mat, truth: &Mat) -> f64 {
    let k = truth.rows();
    assert_eq!(found.rows(), k);
    let mut used = vec![false; k];
    let mut worst: f64 = 0.0;
    for t in 0..k {
        let mut best = f64::INFINITY;
        let mut best_j = 0;
        for j in 0..k {
            if !used[j] {
                let d = crate::linalg::sq_dist(found.row(j), truth.row(t));
                if d < best {
                    best = d;
                    best_j = j;
                }
            }
        }
        used[best_j] = true;
        worst = worst.max(best.sqrt());
    }
    worst
}

#[test]
fn grammar_canonicalizes_aliases_case_and_param_order() {
    assert_eq!(DecoderSpec::parse("clompr").unwrap().canonical(), "clompr");
    assert_eq!(DecoderSpec::parse("CL-OMPR").unwrap().canonical(), "clompr");
    assert_eq!(DecoderSpec::parse(" Hier ").unwrap().canonical(), "hier");
    assert_eq!(DecoderSpec::parse("bisect").unwrap().canonical(), "hier");
    assert_eq!(
        DecoderSpec::parse("clompr:restarts=5").unwrap().canonical(),
        "clompr:restarts=5"
    );
    // Params canonicalize into registry order regardless of input order.
    assert_eq!(
        DecoderSpec::parse("clompr:replacements=3,restarts=5")
            .unwrap()
            .canonical(),
        "clompr:restarts=5,replacements=3"
    );
    assert_eq!(
        DecoderSpec::parse("HIER:Restarts=2").unwrap().canonical(),
        "hier:restarts=2"
    );
    // Explicit params are never elided, even at the compiled-in defaults:
    // they pin the field against whatever base params the job supplies.
    assert_ne!(
        DecoderSpec::parse("clompr:restarts=3").unwrap(),
        DecoderSpec::parse("clompr").unwrap()
    );
    assert_eq!(DecoderSpec::default(), DecoderSpec::parse("clompr").unwrap());
}

#[test]
fn junk_specs_give_actionable_errors() {
    let err = format!("{:#}", DecoderSpec::parse("omp").unwrap_err());
    for grammar in ["clompr[:restarts=R,replacements=P]", "hier[:restarts=R]"] {
        assert!(err.contains(grammar), "error does not name '{grammar}': {err}");
    }
    let err = format!("{:#}", DecoderSpec::parse("").unwrap_err());
    assert!(err.contains("valid decoders"), "{err}");

    assert!(DecoderSpec::parse("clompr:").is_err());
    assert!(DecoderSpec::parse("clompr:restarts").is_err());
    assert!(DecoderSpec::parse("clompr:restarts=").is_err());
    assert!(DecoderSpec::parse("clompr:restarts=zero").is_err());
    assert!(DecoderSpec::parse("clompr:restarts=0").is_err());
    assert!(DecoderSpec::parse("clompr:restarts=2,restarts=3").is_err());
    let err = format!("{:#}", DecoderSpec::parse("hier:replacements=2").unwrap_err());
    assert!(err.contains("restarts=R"), "must name accepted params: {err}");
    let err = format!("{:#}", DecoderSpec::parse("clompr:depth=2").unwrap_err());
    assert!(err.contains("does not accept"), "{err}");
}

/// The registry's default path IS the legacy decoder: same sketch, same
/// seed, bitwise-identical centroids/weights/objective — for a single
/// run against [`ClOmpr::run`] and for replicate selection against
/// [`decode_best_of`].
#[test]
fn registry_clompr_matches_direct_clompr_bitwise() {
    let op = dirac_op(150, 2, 42);
    let truth = Mat::from_vec(2, 2, vec![1.5, -0.5, -1.0, 1.0]);
    let z = op.mixture_sketch(&truth, &[0.4, 0.6]);
    let base = ClOmprParams::default();
    let (lo, hi) = (vec![-3.0; 2], vec![3.0; 2]);

    let direct = ClOmpr::new(&op, 2)
        .with_bounds(lo.clone(), hi.clone())
        .with_params(base.clone())
        .run(&z, &mut Rng::new(7));
    let spec = DecoderSpec::parse("clompr").unwrap();
    let routed = spec
        .decoder(&base)
        .decode(&op, &z, 2, &lo, &hi, &mut Rng::new(7));
    assert_eq!(direct.centroids.as_slice(), routed.centroids.as_slice());
    assert_eq!(direct.weights, routed.weights);
    assert_eq!(direct.objective.to_bits(), routed.objective.to_bits());

    let direct_best = decode_best_of(
        &op,
        2,
        &z,
        lo.clone(),
        hi.clone(),
        &base,
        3,
        &mut Rng::new(9),
    );
    let routed_best = spec.decode_best_of(&op, 2, &z, lo, hi, &base, 3, &mut Rng::new(9));
    assert_eq!(
        direct_best.centroids.as_slice(),
        routed_best.centroids.as_slice()
    );
    assert_eq!(direct_best.objective.to_bits(), routed_best.objective.to_bits());
}

/// Spec params override the base tuning field-for-field: the routed
/// decode equals a direct run with the overridden params, bitwise.
#[test]
fn clompr_spec_params_override_the_base_tuning() {
    let op = dirac_op(120, 2, 5);
    let truth = Mat::from_vec(2, 2, vec![1.0, 1.0, -1.0, -1.0]);
    let z = op.mixture_sketch(&truth, &[0.5, 0.5]);
    let base = ClOmprParams::default();
    let (lo, hi) = (vec![-2.0; 2], vec![2.0; 2]);

    let spec = DecoderSpec::parse("clompr:restarts=5,replacements=3").unwrap();
    let routed = spec
        .decoder(&base)
        .decode(&op, &z, 2, &lo, &hi, &mut Rng::new(11));
    let want_params = ClOmprParams {
        step1_restarts: 5,
        outer_iters_factor: 3,
        ..base
    };
    let direct = ClOmpr::new(&op, 2)
        .with_bounds(lo, hi)
        .with_params(want_params)
        .run(&z, &mut Rng::new(11));
    assert_eq!(direct.centroids.as_slice(), routed.centroids.as_slice());
    assert_eq!(direct.objective.to_bits(), routed.objective.to_bits());
}

/// `hier` recovers the modes of a well-separated Dirac mixture: the
/// bisection tree must reach every corner (no duplicated or dropped
/// leaves) and the global polish must land each centroid near its truth.
#[test]
fn hier_recovers_well_separated_centroids() {
    let op = dirac_op(256, 2, 17);
    // Four Diracs at the corners of a [-2, 2]² square — separation 4.
    let truth = Mat::from_vec(
        4,
        2,
        vec![2.0, 2.0, 2.0, -2.0, -2.0, 2.0, -2.0, -2.0],
    );
    let z = op.mixture_sketch(&truth, &[0.25; 4]);
    let spec = DecoderSpec::parse("hier").unwrap();
    let sol = spec.decode_best_of(
        &op,
        4,
        &z,
        vec![-3.0; 2],
        vec![3.0; 2],
        &ClOmprParams::default(),
        1,
        &mut Rng::new(3),
    );
    assert_eq!(sol.centroids.rows(), 4);
    let err = match_centroids(&sol.centroids, &truth);
    assert!(err < 0.5, "hier centroid error {err}");
    assert!((sol.weights.iter().sum::<f64>() - 1.0).abs() < 1e-9);
    for &w in &sol.weights {
        assert!(w >= 0.0);
    }
    // Every centroid stays inside the search box.
    for c in 0..4 {
        for &v in sol.centroids.row(c) {
            assert!((-3.0..=3.0).contains(&v), "escaped the box: {v}");
        }
    }
}

/// `hier` at k <= 2 is a single subproblem — it must still satisfy the
/// trait contract (k centroids, normalized weights, finite objective).
#[test]
fn hier_degenerate_small_k() {
    let op = dirac_op(150, 2, 23);
    let truth = Mat::from_vec(1, 2, vec![0.7, -1.2]);
    let z = op.mixture_sketch(&truth, &[1.0]);
    let spec = DecoderSpec::parse("hier").unwrap();
    let sol = spec.decode_best_of(
        &op,
        1,
        &z,
        vec![-3.0; 2],
        vec![3.0; 2],
        &ClOmprParams::default(),
        1,
        &mut Rng::new(2),
    );
    assert_eq!(sol.centroids.rows(), 1);
    let err = match_centroids(&sol.centroids, &truth);
    assert!(err < 0.1, "hier K=1 error {err}");
    assert_eq!(sol.weights, vec![1.0]);
    assert!(sol.objective.is_finite());
}

/// Decodes are deterministic functions of the rng seed — two identical
/// calls agree bitwise (locks in the recursion/rng ordering of `hier`).
#[test]
fn hier_is_deterministic() {
    let op = dirac_op(128, 3, 31);
    let truth = Mat::from_vec(3, 3, vec![2.0, 0.0, 0.0, -2.0, 1.0, 0.0, 0.0, -2.0, 2.0]);
    let z = op.mixture_sketch(&truth, &[0.3, 0.3, 0.4]);
    let spec = DecoderSpec::parse("hier").unwrap();
    let base = ClOmprParams::default();
    let a = spec
        .decoder(&base)
        .decode(&op, &z, 3, &[-3.0; 3], &[3.0; 3], &mut Rng::new(77));
    let b = spec
        .decoder(&base)
        .decode(&op, &z, 3, &[-3.0; 3], &[3.0; 3], &mut Rng::new(77));
    assert_eq!(a.centroids.as_slice(), b.centroids.as_slice());
    assert_eq!(a.objective.to_bits(), b.objective.to_bits());
}
