//! CL-OMPR — the sketch-matching decoder used by CKM and QCKM.
//!
//! One implementation in the open decoder registry ([`crate::decoder`]) —
//! the default `clompr` spec, reachable at the legacy `crate::clompr`
//! path too. Constructing [`ClOmpr`] directly and resolving
//! `DecoderSpec::parse("clompr")` are bit-for-bit the same decode.
//!
//! Implements the paper's pseudocode (Sec. 2) over the generalized sketch of
//! Sec. 3: given the pooled dataset sketch `z` (computed with *any*
//! admissible signature `f`), find centroids `C` and weights `α ≥ 0`
//! approximately minimizing `‖z − Σ_k α_k A_{f1} δ_{c_k}‖²`, where the
//! decode-side atoms `A_{f1} δ_c` are the *first harmonic* cosine atoms of
//! [`crate::sketch::SketchOperator::atom`]. Running it on a cosine sketch is
//! exactly CKM; on a 1-bit universal-quantizer sketch it is QCKM (Eq. 10).
//!
//! The five steps per outer iteration (2K iterations total):
//!
//! 1. **Atom pick** — box-constrained maximization of the normalized
//!    residual correlation `⟨a(c)/‖a‖, r⟩` by projected L-BFGS from random
//!    restarts inside the data bounding box `[l, u]`.
//! 2. **Support extension** — append the winner to `C`.
//! 3. **Hard thresholding** (when |C| > K) — NNLS on normalized atoms,
//!    keep the K largest coefficients.
//! 4. **Weight projection** — NNLS of `z` on the selected atoms.
//! 5. **Global refinement** — joint projected L-BFGS over `(C, α)` with
//!    `l ≤ c_k ≤ u` and `α ≥ 0`, warm-started at the current solution.
//!
//! The weights are renormalized to sum 1 only on output (the objective is
//! scale-aware through Step 4/5, as in SketchMLbox).
//!
//! Step 1 — the decode's hot path — fans its candidate screening and
//! L-BFGS restarts across threads via [`ClOmprParams::threads`], and
//! Step 5 fans its per-atom objective/gradient terms (independent before
//! the ordered reduce) over the same knob; by the determinism contract of
//! [`crate::parallel`] the decoded solution is bit-for-bit identical at
//! every thread count.

use crate::linalg::{axpy, dot, norm2, sub, Mat};
use crate::optim::{lbfgsb, nnls, Bounds, LbfgsParams, LbfgsResult};
use crate::parallel::{self, Parallelism};
use crate::rng::Rng;
use crate::sketch::SketchOperator;

/// Tuning knobs for [`ClOmpr`]. Defaults follow SketchMLbox's practical
/// choices scaled to this implementation (see EXPERIMENTS.md §Calibration).
#[derive(Clone, Debug)]
pub struct ClOmprParams {
    /// Outer iterations; the paper prescribes `2K`.
    pub outer_iters_factor: usize,
    /// Random candidates screened (gradient-free) before Step 1's descent.
    pub step1_candidates: usize,
    /// How many screened winners get a full L-BFGS refinement.
    pub step1_restarts: usize,
    /// L-BFGS iteration cap for Step 1.
    pub step1_iters: usize,
    /// L-BFGS iteration cap for intermediate Step 5 runs.
    pub step5_iters: usize,
    /// L-BFGS iteration cap for the final Step 5 polish.
    pub step5_final_iters: usize,
    /// Threads for Step 1's candidate screening / L-BFGS restarts and
    /// Step 5's per-atom objective+gradient terms (1 = serial, 0 = all
    /// cores, n = exactly n). The decode is bit-for-bit identical at every
    /// setting — candidate starts are drawn from the RNG up front in the
    /// serial order, the concurrent scores/refinements/atom terms are pure,
    /// and reductions happen in candidate/atom order (see
    /// [`crate::parallel`]).
    pub threads: usize,
}

impl Default for ClOmprParams {
    fn default() -> Self {
        Self {
            outer_iters_factor: 2,
            step1_candidates: 64,
            step1_restarts: 3,
            step1_iters: 60,
            step5_iters: 80,
            step5_final_iters: 300,
            threads: 1,
        }
    }
}

/// A decoded mixture: centroids, weights, and the residual objective.
#[derive(Clone, Debug)]
pub struct Solution {
    /// `K × n` centroid matrix.
    pub centroids: Mat,
    /// Mixture weights, non-negative, normalized to sum 1.
    pub weights: Vec<f64>,
    /// Final sketch-matching objective `‖z − Σ α_k a(c_k)‖` (with the
    /// *unnormalized* weights actually fitted) — the model-selection score
    /// used to pick among replicates without touching the data.
    pub objective: f64,
    /// Outer iterations the decoder ran (CL-OMPR: `outer_iters_factor·K`;
    /// hier: the `K − 1` bisections). Observational bookkeeping for the
    /// serve-side decode-quality instruments.
    pub outer_iters: u32,
    /// Outer iterations whose freshly added atom survived the Step-3
    /// hard-threshold, displacing an established one — the support-churn
    /// signal (0 for the hier decoder, which never thresholds).
    pub atoms_replaced: u32,
}

/// The decoder, bound to a sketch operator and a target cluster count.
pub struct ClOmpr<'a> {
    op: &'a SketchOperator,
    k: usize,
    /// Centroid search box (`l`, `u`). Defaults to `[-1, 1]^n` until
    /// overridden; always set it from data bounds or prior knowledge.
    lo: Vec<f64>,
    hi: Vec<f64>,
    params: ClOmprParams,
}

impl<'a> ClOmpr<'a> {
    pub fn new(op: &'a SketchOperator, k: usize) -> Self {
        assert!(k >= 1, "need at least one cluster");
        Self {
            op,
            k,
            lo: vec![-1.0; op.dim()],
            hi: vec![1.0; op.dim()],
            params: ClOmprParams::default(),
        }
    }

    /// Set the centroid search box (the `l ≤ c ≤ u` of the pseudocode).
    pub fn with_bounds(mut self, lo: Vec<f64>, hi: Vec<f64>) -> Self {
        assert_eq!(lo.len(), self.op.dim());
        assert_eq!(hi.len(), self.op.dim());
        assert!(lo.iter().zip(&hi).all(|(a, b)| a <= b), "need lo <= hi");
        self.lo = lo;
        self.hi = hi;
        self
    }

    pub fn with_params(mut self, params: ClOmprParams) -> Self {
        self.params = params;
        self
    }

    /// Decode centroids from the pooled sketch `z` (length `2M`).
    pub fn run(&self, z: &[f64], rng: &mut Rng) -> Solution {
        assert_eq!(z.len(), self.op.sketch_len(), "sketch length mismatch");
        let n = self.op.dim();
        let atom_norm = self.op.atom_norm();

        let mut centroids = Mat::zeros(0, n);
        let mut alphas: Vec<f64> = Vec::new();
        let mut residual = z.to_vec();

        let outer = self.params.outer_iters_factor * self.k;
        // Step 1 and Step 5 dominate decode cost in opposite regimes
        // (screening scales with M·candidates, refinement with k·M·iters),
        // so each outer iteration times both into its own histogram —
        // observational only (I-18).
        let obs = crate::obs::lib_metrics();
        let mut atoms_replaced: u32 = 0;
        for _t in 0..outer {
            // ---- Step 1: pick the atom best correlated with the residual.
            let c_new = {
                let _span = crate::obs::global().span("clompr_step1", &obs.clompr_step1_seconds);
                self.step1_pick(&residual, rng)
            };

            // ---- Step 2: extend the support.
            centroids.push_row(&c_new);
            alphas.push(0.0);

            // ---- Step 3: hard-threshold the support back to K.
            if centroids.rows() > self.k {
                let new_idx = centroids.rows() - 1; // the atom Step 2 added
                let beta = self.project_weights(z, &centroids, 1.0 / atom_norm);
                let mut order: Vec<usize> = (0..beta.len()).collect();
                order.sort_by(|&a, &b| beta[b].partial_cmp(&beta[a]).unwrap());
                order.truncate(self.k);
                if order.contains(&new_idx) {
                    // The new atom made the cut, so an established one
                    // was displaced — support churn, worth counting.
                    atoms_replaced += 1;
                }
                centroids = centroids.select_rows(&order);
                alphas.truncate(self.k); // values refreshed by Step 4 below
            }

            // ---- Step 4: non-negative weight projection.
            alphas = self.project_weights(z, &centroids, 1.0);

            // ---- Step 5: joint gradient refinement of (C, α).
            let iters = if _t + 1 == outer {
                self.params.step5_final_iters
            } else {
                self.params.step5_iters
            };
            {
                let _span = crate::obs::global().span("clompr_step5", &obs.clompr_step5_seconds);
                self.step5_refine(z, &mut centroids, &mut alphas, iters);
            }

            // ---- Residual update.
            let model = self.op.mixture_sketch(&centroids, &alphas);
            residual = sub(z, &model);
        }

        // Output normalization: weights sum to 1 (drop exact zeros is not
        // needed — NNLS already zeroed useless atoms; keep K slots).
        let objective = norm2(&residual);
        let total: f64 = alphas.iter().sum();
        let weights = if total > 0.0 {
            alphas.iter().map(|a| a / total).collect()
        } else {
            vec![1.0 / alphas.len() as f64; alphas.len()]
        };
        Solution {
            centroids,
            weights,
            objective,
            outer_iters: outer as u32,
            atoms_replaced,
        }
    }

    /// Step 1: `argmax_c ⟨a(c)/‖a‖, r⟩` over the box.
    ///
    /// The objective is highly multimodal (a sum of `2M` cosines), so a
    /// plain multi-start descent wastes restarts in shallow basins. We
    /// first *screen* `step1_candidates` random box points with the cheap
    /// gradient-free correlation, then run projected L-BFGS from the
    /// `step1_restarts` best screens (see EXPERIMENTS.md §Calibration for
    /// the measured effect).
    fn step1_pick(&self, residual: &[f64], rng: &mut Rng) -> Vec<f64> {
        let n = self.op.dim();
        let bounds = Bounds::boxed(&self.lo, &self.hi);
        let lb = LbfgsParams {
            max_iters: self.params.step1_iters,
            pg_tol: 1e-8,
            ..LbfgsParams::default()
        };

        // Screening pass. The starts are drawn serially (one RNG stream, the
        // same draw order at every thread count); only the atom evaluations
        // — the expensive part — fan out, and scores come back in candidate
        // order so the (stable) sort and all tie-breaks are deterministic.
        let par = Parallelism::fixed(self.params.threads);
        let n_cand = self.params.step1_candidates.max(self.params.step1_restarts).max(1);
        let starts: Vec<Vec<f64>> = (0..n_cand)
            .map(|_| {
                (0..n)
                    .map(|i| rng.uniform(self.lo[i], self.hi[i]))
                    .collect()
            })
            .collect();
        const SCORE_CHUNK: usize = 8;
        let scores: Vec<f64> = parallel::run_chunked(n_cand, SCORE_CHUNK, &par, |_, range| {
            range
                .map(|i| -dot(&self.op.atom(&starts[i]), residual))
                .collect::<Vec<f64>>()
        })
        .into_iter()
        .flatten()
        .collect();
        let mut cands: Vec<(f64, Vec<f64>)> = scores.into_iter().zip(starts).collect();
        cands.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        cands.truncate(self.params.step1_restarts.max(1));

        // Concurrent L-BFGS refinement of the screened starts; the winner is
        // folded in restart order (first strictly-better wins), exactly as
        // the serial loop did.
        let results: Vec<LbfgsResult> = parallel::par_map(cands.len(), &par, |i| {
            lbfgsb(
                |c, g| {
                    // f(c) = −⟨a(c), r⟩; gradient via the fused JᵀV kernel.
                    let a = self.op.atom_and_jtv(c, residual, g);
                    for gi in g.iter_mut() {
                        *gi = -*gi;
                    }
                    -dot(&a, residual)
                },
                &cands[i].1,
                &bounds,
                &lb,
            )
        });
        let mut best_x: Option<Vec<f64>> = None;
        let mut best_f = f64::INFINITY;
        for res in results {
            if res.f < best_f {
                best_f = res.f;
                best_x = Some(res.x);
            }
        }
        best_x.expect("at least one restart")
    }

    /// Steps 3/4: NNLS of `z` on the atoms of `centroids`, columns scaled
    /// by `col_scale` (use `1/atom_norm` for normalized atoms). Crate
    /// visibility: other decoders (e.g. [`crate::decoder::HierDecoder`])
    /// reuse it for their own weight projections.
    pub(crate) fn project_weights(&self, z: &[f64], centroids: &Mat, col_scale: f64) -> Vec<f64> {
        let kc = centroids.rows();
        let rows = self.op.sketch_len();
        let mut a = Mat::zeros(rows, kc);
        for k in 0..kc {
            let atom = self.op.atom(centroids.row(k));
            for (r, &v) in atom.iter().enumerate() {
                a.set(r, k, v * col_scale);
            }
        }
        nnls(&a, z)
    }

    /// Step 5: joint minimization of `‖z − Σ α_k a(c_k)‖²` over the packed
    /// variable `[c_1 … c_Kc, α]` with box bounds on centroids, `α ≥ 0`.
    /// Crate visibility: other decoders (e.g.
    /// [`crate::decoder::HierDecoder`]) reuse it as their global polish.
    pub(crate) fn step5_refine(
        &self,
        z: &[f64],
        centroids: &mut Mat,
        alphas: &mut Vec<f64>,
        iters: usize,
    ) {
        let kc = centroids.rows();
        let n = self.op.dim();
        let dim = kc * n + kc;

        // Pack.
        let mut x0 = Vec::with_capacity(dim);
        for k in 0..kc {
            x0.extend_from_slice(centroids.row(k));
        }
        x0.extend_from_slice(alphas);

        // Bounds: per-centroid box, then α ≥ 0.
        let mut lo = Vec::with_capacity(dim);
        let mut hi = Vec::with_capacity(dim);
        for _ in 0..kc {
            lo.extend_from_slice(&self.lo);
            hi.extend_from_slice(&self.hi);
        }
        let bounds = Bounds {
            lo: lo
                .into_iter()
                .map(Some)
                .chain(std::iter::repeat(Some(0.0)).take(kc))
                .collect(),
            hi: hi
                .into_iter()
                .map(Some)
                .chain(std::iter::repeat(None).take(kc))
                .collect(),
        };

        let lb = LbfgsParams {
            max_iters: iters,
            pg_tol: 1e-9,
            ..LbfgsParams::default()
        };

        let sketch_len = self.op.sketch_len();
        // Per-atom evaluation and per-atom gradient terms are independent;
        // they fan out across `params.threads` and reduce in atom order
        // (ordered `u` fold, per-atom gradient slots), so — as everywhere
        // else under the `crate::parallel` contract — the refined solution
        // is bit-for-bit identical at every thread count. Tiny supports
        // run inline: the objective is evaluated every L-BFGS iteration
        // and two thread-scope spawns per call only pay off once there are
        // enough atoms to amortize them (per-atom arithmetic is identical
        // either way, so this cutoff cannot change results).
        let par = if kc < 4 {
            Parallelism::serial()
        } else {
            Parallelism::fixed(self.params.threads)
        };
        let mut res = lbfgsb(
            |x, g| {
                let (cs, al) = x.split_at(kc * n);
                // Atom evaluations (the sincos-heavy part), one per centroid.
                let atoms: Vec<Vec<f64>> =
                    parallel::par_map(kc, &par, |k| self.op.atom(&cs[k * n..(k + 1) * n]));
                // Model u = Σ α_k a(c_k) folded in atom order; residual e.
                let mut u = vec![0.0; sketch_len];
                for k in 0..kc {
                    axpy(al[k], &atoms[k], &mut u);
                }
                let e = sub(z, &u);
                // ∂F/∂c_k = −2 α_k J_kᵀ e ; ∂F/∂α_k = −2 ⟨a_k, e⟩.
                // JᵀV comes trig-free from the atoms computed above; each
                // atom's term touches only its own gradient slots.
                let grads: Vec<(Vec<f64>, f64)> = parallel::par_map(kc, &par, |k| {
                    let mut jte = vec![0.0; n];
                    self.op.jtv_from_atom(&atoms[k], &e, &mut jte);
                    let scale = -2.0 * al[k];
                    for ji in jte.iter_mut() {
                        *ji *= scale;
                    }
                    (jte, -2.0 * dot(&atoms[k], &e))
                });
                for (k, (gc, ga)) in grads.iter().enumerate() {
                    g[k * n..(k + 1) * n].copy_from_slice(gc);
                    g[kc * n + k] = *ga;
                }
                dot(&e, &e)
            },
            &x0,
            &bounds,
            &lb,
        );

        // Unpack (keep only if it improved — L-BFGS is monotone, so it did).
        let (cs, al) = res.x.split_at_mut(kc * n);
        for k in 0..kc {
            centroids.row_mut(k).copy_from_slice(&cs[k * n..(k + 1) * n]);
        }
        alphas.copy_from_slice(al);
    }
}

/// Run the decoder `replicates` times and keep the solution with the best
/// sketch-matching objective — the paper's data-free model selection for
/// compressive algorithms (Sec. 5: "we select the solution of CKM (resp.
/// QCKM) minimizing (6) (resp. (10))").
///
/// Replicates deliberately run serially on the shared `rng` stream so that
/// "best of R" is exactly the minimum over the same replicate stream a
/// caller would produce by looping `run` — the invariant the system tests
/// pin. Intra-run parallelism comes from `params.threads` (Step 1), and
/// the experiment harnesses parallelize across trials instead.
#[allow(clippy::too_many_arguments)]
pub fn decode_best_of(
    op: &SketchOperator,
    k: usize,
    z: &[f64],
    lo: Vec<f64>,
    hi: Vec<f64>,
    params: &ClOmprParams,
    replicates: usize,
    rng: &mut Rng,
) -> Solution {
    assert!(replicates >= 1);
    let mut best: Option<Solution> = None;
    for _ in 0..replicates {
        let sol = ClOmpr::new(op, k)
            .with_bounds(lo.clone(), hi.clone())
            .with_params(params.clone())
            .run(z, rng);
        if best.as_ref().map_or(true, |b| sol.objective < b.objective) {
            best = Some(sol);
        }
    }
    best.unwrap()
}

#[cfg(test)]
mod tests;
