//! Decoder tests: exact recovery of Dirac mixtures from their own sketch,
//! end-to-end CKM and QCKM on separable Gaussian mixtures.

use super::*;
use crate::frequency::{DrawnFrequencies, FrequencyLaw};
use crate::metrics::sse;
use crate::signature::{Cosine, UniversalQuantizer};
use std::sync::Arc;

/// Match decoded centroids to true ones greedily; returns max distance.
fn match_centroids(found: &Mat, truth: &Mat) -> f64 {
    let k = truth.rows();
    assert_eq!(found.rows(), k);
    let mut used = vec![false; k];
    let mut worst: f64 = 0.0;
    for t in 0..k {
        let mut best = f64::INFINITY;
        let mut best_j = 0;
        for j in 0..k {
            if !used[j] {
                let d = crate::linalg::sq_dist(found.row(j), truth.row(t));
                if d < best {
                    best = d;
                    best_j = j;
                }
            }
        }
        used[best_j] = true;
        worst = worst.max(best.sqrt());
    }
    worst
}

fn dirac_mixture_op(signature: Arc<dyn crate::signature::Signature>, seed: u64) -> SketchOperator {
    let mut rng = Rng::new(seed);
    let freqs = DrawnFrequencies::draw(FrequencyLaw::AdaptedRadius, 2, 150, 1.0, &mut rng);
    SketchOperator::new(freqs, signature)
}

#[test]
fn recovers_dirac_mixture_from_cosine_sketch() {
    // The exactly-representable case: P is itself a 2-Dirac mixture and the
    // sketch is its first-harmonic image (cosine signature, A_f = A_{f1}).
    let op = dirac_mixture_op(Arc::new(Cosine), 42);
    let truth = Mat::from_vec(2, 2, vec![1.5, -0.5, -1.0, 1.0]);
    let weights = [0.4, 0.6];
    let z = op.mixture_sketch(&truth, &weights);

    let mut rng = Rng::new(7);
    let sol = ClOmpr::new(&op, 2)
        .with_bounds(vec![-3.0, -3.0], vec![3.0, 3.0])
        .run(&z, &mut rng);

    assert_eq!(sol.centroids.rows(), 2);
    let err = match_centroids(&sol.centroids, &truth);
    assert!(err < 0.05, "centroid error {err}");
    assert!(sol.objective < 0.5, "objective {}", sol.objective);
    // Weights ≈ (0.4, 0.6) up to the centroid matching order.
    let mut w = sol.weights.clone();
    w.sort_by(|a, b| a.partial_cmp(b).unwrap());
    assert!((w[0] - 0.4).abs() < 0.05 && (w[1] - 0.6).abs() < 0.05, "{w:?}");
    assert!((sol.weights.iter().sum::<f64>() - 1.0).abs() < 1e-9);
}

fn gaussian_mixture_2d(rng: &mut Rng, n: usize) -> (Mat, Mat) {
    // 3 clusters at (±2, 0), (0, 2.5), std 0.35.
    let truth = Mat::from_vec(3, 2, vec![-2.0, 0.0, 2.0, 0.0, 0.0, 2.5]);
    let mut x = Mat::zeros(0, 2);
    for i in 0..n {
        let k = i % 3;
        x.push_row(&[
            truth.get(k, 0) + 0.35 * rng.gaussian(),
            truth.get(k, 1) + 0.35 * rng.gaussian(),
        ]);
    }
    (x, truth)
}

#[test]
fn ckm_end_to_end_on_gaussian_mixture() {
    let mut rng = Rng::new(100);
    let (x, truth) = gaussian_mixture_2d(&mut rng, 3000);
    let freqs = DrawnFrequencies::draw(FrequencyLaw::AdaptedRadius, 2, 120, 0.8, &mut rng);
    let op = SketchOperator::new(freqs, Arc::new(Cosine));
    let z = op.sketch_dataset(&x);
    let (lo, hi) = crate::linalg::bounding_box(&x);
    let sol = ClOmpr::new(&op, 3).with_bounds(lo, hi).run(&z, &mut rng);
    let err = match_centroids(&sol.centroids, &truth);
    assert!(err < 0.25, "CKM centroid error {err}");
}

#[test]
fn qckm_end_to_end_on_gaussian_mixture() {
    let mut rng = Rng::new(200);
    let (x, truth) = gaussian_mixture_2d(&mut rng, 3000);
    // QCKM needs the dithering; use ~25% more frequencies than CKM (paper).
    let freqs = DrawnFrequencies::draw(FrequencyLaw::AdaptedRadius, 2, 150, 0.8, &mut rng);
    let op = SketchOperator::new(freqs, Arc::new(UniversalQuantizer));
    let z = op.sketch_dataset(&x);
    let (lo, hi) = crate::linalg::bounding_box(&x);
    let sol = ClOmpr::new(&op, 3).with_bounds(lo, hi).run(&z, &mut rng);
    let err = match_centroids(&sol.centroids, &truth);
    assert!(err < 0.3, "QCKM centroid error {err}");

    // And the SSE competitive with k-means (the paper's success criterion).
    let km = crate::kmeans::kmeans(
        &x,
        3,
        &crate::kmeans::KMeansParams {
            replicates: 5,
            ..Default::default()
        },
        &mut rng,
    );
    let s = sse(&x, &sol.centroids);
    assert!(
        crate::metrics::is_success(s, km.sse),
        "QCKM SSE {s} vs kmeans {}",
        km.sse
    );
}

#[test]
fn decode_best_of_improves_objective() {
    let op = dirac_mixture_op(Arc::new(UniversalQuantizer), 5);
    let truth = Mat::from_vec(2, 2, vec![1.0, 1.0, -1.0, -1.0]);
    let z = op.mixture_sketch(&truth, &[0.5, 0.5]);
    let params = ClOmprParams::default();
    let mut r1 = Rng::new(9);
    let s1 = ClOmpr::new(&op, 2)
        .with_bounds(vec![-2.0; 2], vec![2.0; 2])
        .run(&z, &mut r1);
    let mut r5 = Rng::new(9);
    let s5 = decode_best_of(
        &op,
        2,
        &z,
        vec![-2.0; 2],
        vec![2.0; 2],
        &params,
        5,
        &mut r5,
    );
    assert!(s5.objective <= s1.objective + 1e-9);
}

#[test]
fn k_equals_one_mean_recovery() {
    // K = 1: the decoder must find the single Dirac location.
    let op = dirac_mixture_op(Arc::new(Cosine), 11);
    let truth = Mat::from_vec(1, 2, vec![0.7, -1.2]);
    let z = op.mixture_sketch(&truth, &[1.0]);
    let mut rng = Rng::new(3);
    let sol = ClOmpr::new(&op, 1)
        .with_bounds(vec![-3.0; 2], vec![3.0; 2])
        .run(&z, &mut rng);
    let err = match_centroids(&sol.centroids, &truth);
    assert!(err < 0.05, "K=1 error {err}");
    assert_eq!(sol.weights, vec![1.0]);
}

#[test]
fn centroids_stay_in_box() {
    let op = dirac_mixture_op(Arc::new(UniversalQuantizer), 17);
    // Truth outside the search box: solution must clip to the box.
    let truth = Mat::from_vec(1, 2, vec![5.0, 5.0]);
    let z = op.mixture_sketch(&truth, &[1.0]);
    let mut rng = Rng::new(1);
    let sol = ClOmpr::new(&op, 1)
        .with_bounds(vec![-1.0; 2], vec![1.0; 2])
        .run(&z, &mut rng);
    for k in 0..sol.centroids.rows() {
        for &v in sol.centroids.row(k) {
            assert!((-1.0..=1.0).contains(&v), "escaped the box: {v}");
        }
    }
}

#[test]
#[should_panic]
fn rejects_wrong_sketch_length() {
    let op = dirac_mixture_op(Arc::new(Cosine), 0);
    let mut rng = Rng::new(0);
    let _ = ClOmpr::new(&op, 2).run(&[0.0; 10], &mut rng);
}
