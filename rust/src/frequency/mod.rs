//! Frequency sampling Ω ~ Λ^m and dithering ξ ~ U([0,2π])^m.
//!
//! The frequency distribution Λ determines, via Bochner's theorem, the
//! shift-invariant kernel `κ(x,x') = F(Λ)(x−x')` whose MMD the sketch
//! matching minimizes: Λ acts as a low-pass filter on the data pdf, so its
//! scale controls the clustering resolution.
//!
//! Two families are provided (both isotropic, as in CKM/SketchMLbox):
//!
//! * [`FrequencyLaw::Gaussian`] — `ω ~ N(0, σ_k⁻² I)`, the RFF choice for a
//!   Gaussian kernel of bandwidth `σ_k`.
//! * [`FrequencyLaw::AdaptedRadius`] — direction uniform on the sphere,
//!   radius `R/σ_k` with density `p(R) ∝ sqrt(R² + R⁴/4)·e^{−R²/2}`
//!   (Keriven et al. 2017). It up-weights mid radii, which empirically
//!   improves centroid recovery over the Gaussian law; this is the
//!   default for all experiments.
//!
//! The kernel scale `σ_k` comes from [`SigmaHeuristic`]: fixed by config, or
//! estimated from a subsample (intra-cluster-scale quantile of pairwise
//! distances), mirroring how SketchMLbox adjusts Λ from a subset of X.

use crate::linalg::{sq_dist, Mat};
use crate::rng::{InverseCdfTable, Rng};
use std::f64::consts::PI;

/// Which isotropic frequency law to draw from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FrequencyLaw {
    /// `ω = g/σ_k`, `g ~ N(0, I)`.
    Gaussian,
    /// `ω = (R/σ_k)·u`, `u` uniform direction, `R ~ p(R) ∝ √(R²+R⁴/4)·e^{−R²/2}`.
    AdaptedRadius,
}

impl FrequencyLaw {
    pub fn name(self) -> &'static str {
        match self {
            FrequencyLaw::Gaussian => "gaussian",
            FrequencyLaw::AdaptedRadius => "adapted-radius",
        }
    }

    /// Inverse of [`name`](Self::name) (config files, `.qsk` headers).
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        Ok(match s {
            "gaussian" => FrequencyLaw::Gaussian,
            "adapted-radius" => FrequencyLaw::AdaptedRadius,
            other => anyhow::bail!("unknown frequency law '{other}' (gaussian|adapted-radius)"),
        })
    }
}

/// How to choose the kernel bandwidth `σ_k`.
#[derive(Clone, Copy, Debug)]
pub enum SigmaHeuristic {
    /// Use exactly this bandwidth.
    Fixed(f64),
    /// Estimate from data: `σ_k² = q-quantile of pairwise squared distances
    /// (on a subsample) / (2n)`. A low quantile targets intra-cluster
    /// pairs; a high quantile the inter-cluster scale (the default, which
    /// is what CL-OMPR wants — see EXPERIMENTS.md §Calibration).
    PairwiseQuantile { subsample: usize, quantile: f64 },
}

impl Default for SigmaHeuristic {
    fn default() -> Self {
        // Calibrated on the Fig.-2a setup (EXPERIMENTS.md §Calibration):
        // the decoder wants the kernel at the *inter*-cluster scale, i.e. a
        // quantile high enough to be dominated by between-cluster pairs.
        SigmaHeuristic::PairwiseQuantile {
            subsample: 512,
            quantile: 0.65,
        }
    }
}

impl SigmaHeuristic {
    /// Resolve to a concrete bandwidth for dataset `x` (`N × n`).
    pub fn resolve(&self, x: &Mat, rng: &mut Rng) -> f64 {
        match *self {
            SigmaHeuristic::Fixed(s) => {
                assert!(s > 0.0, "sigma must be positive");
                s
            }
            SigmaHeuristic::PairwiseQuantile {
                subsample,
                quantile,
            } => estimate_sigma(x, subsample, quantile, rng),
        }
    }
}

/// The pairwise-quantile bandwidth estimate (see [`SigmaHeuristic`]).
pub fn estimate_sigma(x: &Mat, subsample: usize, quantile: f64, rng: &mut Rng) -> f64 {
    assert!(x.rows() >= 2, "need at least two points to estimate sigma");
    assert!((0.0..=1.0).contains(&quantile));
    let s = subsample.clamp(2, x.rows());
    let idx = rng.sample_indices(x.rows(), s);
    // All pairs on the subsample is O(s²) with s ≲ 512 — cheap.
    let mut d2: Vec<f64> = Vec::with_capacity(s * (s - 1) / 2);
    for i in 0..s {
        for j in (i + 1)..s {
            d2.push(sq_dist(x.row(idx[i]), x.row(idx[j])));
        }
    }
    d2.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = ((d2.len() - 1) as f64 * quantile).round() as usize;
    let q = d2[pos].max(1e-12);
    (q / (2.0 * x.cols() as f64)).sqrt()
}

/// A concrete draw of the sketch's randomness: frequencies and dithers.
///
/// `omega` is `n × M` (one frequency per column) so the encode is the
/// row-major product `X · Ω`. `xi[j] ∈ [0, 2π)` is frequency j's dither.
/// The *same* draw must be used for encoding and decoding; experiments
/// persist the seed instead of the matrices.
#[derive(Clone, Debug)]
pub struct DrawnFrequencies {
    /// `n × M` frequency matrix (column j = ω_j).
    pub omega: Mat,
    /// Per-frequency dither, length M.
    pub xi: Vec<f64>,
    /// The bandwidth the draw was scaled with (for logging).
    pub sigma: f64,
    /// Which law generated it.
    pub law: FrequencyLaw,
}

impl DrawnFrequencies {
    /// Draw `m` frequencies in dimension `n` at bandwidth `sigma`.
    pub fn draw(law: FrequencyLaw, n: usize, m: usize, sigma: f64, rng: &mut Rng) -> Self {
        assert!(n > 0 && m > 0 && sigma > 0.0);
        let mut omega = Mat::zeros(n, m);
        match law {
            FrequencyLaw::Gaussian => {
                for r in 0..n {
                    for c in 0..m {
                        omega.set(r, c, rng.gaussian() / sigma);
                    }
                }
            }
            FrequencyLaw::AdaptedRadius => {
                let table = adapted_radius_table();
                for c in 0..m {
                    let dir = rng.sphere_direction(n);
                    let radius = table.sample(rng) / sigma;
                    for r in 0..n {
                        omega.set(r, c, radius * dir[r]);
                    }
                }
            }
        }
        let xi: Vec<f64> = (0..m).map(|_| rng.uniform(0.0, 2.0 * PI)).collect();
        Self {
            omega,
            xi,
            sigma,
            law,
        }
    }

    /// Draw with a *zero* dither — the classical undithered CKM sketch.
    /// (Prop. 1 requires dithering for non-sinusoidal signatures; the cosine
    /// signature tolerates ξ = 0, which reproduces original CKM exactly.)
    pub fn draw_undithered(law: FrequencyLaw, n: usize, m: usize, sigma: f64, rng: &mut Rng) -> Self {
        let mut out = Self::draw(law, n, m, sigma, rng);
        out.xi.iter_mut().for_each(|v| *v = 0.0);
        out
    }

    /// Data dimension n.
    pub fn dim(&self) -> usize {
        self.omega.rows()
    }

    /// Number of frequencies M.
    pub fn num_frequencies(&self) -> usize {
        self.omega.cols()
    }
}

/// The adapted-radius inverse-CDF table (support [0, 6] covers all but
/// ~1e-7 of the mass).
pub fn adapted_radius_table() -> InverseCdfTable {
    InverseCdfTable::from_density(
        |r| (r * r + r.powi(4) / 4.0).sqrt() * (-0.5 * r * r).exp(),
        0.0,
        6.0,
        4096,
    )
}

#[cfg(test)]
mod tests;
