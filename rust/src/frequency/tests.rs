//! Tests for frequency sampling and the bandwidth heuristic.

use super::*;
use crate::linalg::norm2;

#[test]
fn gaussian_law_has_right_scale() {
    let mut rng = Rng::new(42);
    let n = 6;
    let m = 4000;
    let sigma = 2.0;
    let d = DrawnFrequencies::draw(FrequencyLaw::Gaussian, n, m, sigma, &mut rng);
    assert_eq!(d.omega.shape(), (n, m));
    assert_eq!(d.xi.len(), m);
    // Per-coordinate variance must be 1/σ² = 0.25.
    let mut s2 = 0.0;
    for r in 0..n {
        for c in 0..m {
            s2 += d.omega.get(r, c).powi(2);
        }
    }
    let var = s2 / (n * m) as f64;
    assert!((var - 0.25).abs() < 0.01, "gaussian freq var {var}");
}

#[test]
fn adapted_radius_law_norms_match_density() {
    let mut rng = Rng::new(7);
    let n = 5;
    let m = 8000;
    let d = DrawnFrequencies::draw(FrequencyLaw::AdaptedRadius, n, m, 1.0, &mut rng);
    // E[R] for p(R) ∝ sqrt(R²+R⁴/4) e^{−R²/2}: compute numerically.
    let table = adapted_radius_table();
    let mut rr = Rng::new(8);
    let want: f64 = (0..20000).map(|_| table.sample(&mut rr)).sum::<f64>() / 20000.0;
    let got: f64 = (0..m).map(|c| norm2(&d.omega.col(c))).sum::<f64>() / m as f64;
    assert!(
        (got - want).abs() < 0.03,
        "adapted radius mean norm {got} vs {want}"
    );
    // Directions isotropic: mean vector near zero.
    for r in 0..n {
        let mean: f64 = (0..m).map(|c| d.omega.get(r, c)).sum::<f64>() / m as f64;
        assert!(mean.abs() < 0.05, "direction bias {mean} on coord {r}");
    }
}

#[test]
fn dither_is_uniform_and_undithered_is_zero() {
    let mut rng = Rng::new(3);
    let d = DrawnFrequencies::draw(FrequencyLaw::Gaussian, 3, 5000, 1.0, &mut rng);
    let mean: f64 = d.xi.iter().sum::<f64>() / d.xi.len() as f64;
    assert!((mean - std::f64::consts::PI).abs() < 0.1, "dither mean {mean}");
    assert!(d.xi.iter().all(|&x| (0.0..2.0 * std::f64::consts::PI).contains(&x)));

    let d0 = DrawnFrequencies::draw_undithered(FrequencyLaw::Gaussian, 3, 100, 1.0, &mut rng);
    assert!(d0.xi.iter().all(|&x| x == 0.0));
}

#[test]
fn draw_is_seed_deterministic() {
    let d1 = DrawnFrequencies::draw(FrequencyLaw::AdaptedRadius, 4, 64, 1.5, &mut Rng::new(99));
    let d2 = DrawnFrequencies::draw(FrequencyLaw::AdaptedRadius, 4, 64, 1.5, &mut Rng::new(99));
    assert_eq!(d1.omega.as_slice(), d2.omega.as_slice());
    assert_eq!(d1.xi, d2.xi);
    assert_eq!(d1.dim(), 4);
    assert_eq!(d1.num_frequencies(), 64);
}

#[test]
fn sigma_estimate_recovers_cluster_scale() {
    // Single isotropic Gaussian, per-dim std 3: pairwise E‖x−x'‖² = 2n·9,
    // so any mid quantile / (2n) ≈ 9 → σ̂ ≈ 3 (low quantile → slightly less).
    let mut rng = Rng::new(5);
    let n = 8;
    let x = Mat::from_fn(2000, n, |_, _| rng.gaussian_with(0.0, 3.0));
    let s = estimate_sigma(&x, 400, 0.5, &mut rng);
    assert!((s - 3.0).abs() < 0.4, "sigma estimate {s}");
    let s_low = estimate_sigma(&x, 400, 0.1, &mut rng);
    assert!(s_low < s, "low quantile should give smaller sigma");
}

#[test]
fn sigma_heuristic_resolve() {
    let mut rng = Rng::new(6);
    let x = Mat::from_fn(50, 2, |_, _| rng.gaussian());
    assert_eq!(SigmaHeuristic::Fixed(1.25).resolve(&x, &mut rng), 1.25);
    let s = SigmaHeuristic::default().resolve(&x, &mut rng);
    assert!(s > 0.0 && s.is_finite());
}

#[test]
#[should_panic]
fn fixed_sigma_must_be_positive() {
    let mut rng = Rng::new(0);
    let x = Mat::zeros(2, 2);
    let _ = SigmaHeuristic::Fixed(0.0).resolve(&x, &mut rng);
}
