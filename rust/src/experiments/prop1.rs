//! Numeric validation of Proposition 1.
//!
//! Prop. 1: for fixed distributions P, Q and a dithered signature f,
//!
//!   (2m|F₁|²)⁻¹ ‖A_f(P) − A_{f1}(Q)‖²  ≈  γ²_Λ(P, Q) + c_P,
//!
//! with error ≤ ε w.p. ≥ 1 − 2exp(−C_f m ε²) over (Ω, ξ). With Dirac
//! mixtures for P and Q everything is computable in closed form:
//! φ_P(ω) = Σ_k α_k e^{iω^T c_k}, γ² estimated to any precision with a huge
//! independent frequency sample, and c_P = Σ_{|k|≥2} |F_k|²/(2|F₁|²)
//! E|φ_P(kω)|². The harness sweeps m and reports the deviation's mean and
//! 95th percentile, which must decay like O(1/√m).

use crate::frequency::{DrawnFrequencies, FrequencyLaw};
use crate::linalg::Mat;
use crate::rng::Rng;
use crate::signature::Signature;
use crate::sketch::SketchOperator;
use std::sync::Arc;

#[derive(Clone, Debug)]
pub struct Prop1Config {
    /// Sketch sizes m to sweep.
    pub ms: Vec<usize>,
    /// Draws of (Ω, ξ) per m.
    pub repeats: usize,
    /// Monte-Carlo frequencies for the γ² / c_P reference values.
    pub reference_draws: usize,
    pub seed: u64,
}

impl Default for Prop1Config {
    fn default() -> Self {
        Self {
            ms: vec![32, 64, 128, 256, 512, 1024, 2048],
            repeats: 48,
            reference_draws: 200_000,
            seed: 0x9101,
        }
    }
}

#[derive(Clone, Debug)]
pub struct Prop1Result {
    pub signature: &'static str,
    pub ms: Vec<usize>,
    /// Mean |deviation| per m.
    pub mean_dev: Vec<f64>,
    /// 95th percentile |deviation| per m.
    pub p95_dev: Vec<f64>,
    /// Reference γ²_Λ(P,Q) and c_P.
    pub gamma2: f64,
    pub c_p: f64,
    /// Fitted decay exponent of mean_dev vs m (should be ≈ −0.5).
    pub decay_exponent: f64,
}

/// |φ_P(ω)|, φ real/imag parts for a Dirac mixture.
fn char_fn(centroids: &Mat, weights: &[f64], omega: &[f64]) -> (f64, f64) {
    let mut re = 0.0;
    let mut im = 0.0;
    for (k, &a) in weights.iter().enumerate() {
        let t = crate::linalg::dot(centroids.row(k), omega);
        re += a * t.cos();
        im += a * t.sin();
    }
    (re, im)
}

pub fn run_prop1(signature: Arc<dyn Signature>, cfg: &Prop1Config) -> Prop1Result {
    let sig_name = signature.name();
    // Fixed P (3 Diracs) and Q (2 Diracs) in 4 dimensions.
    let n = 4;
    let p_cents = Mat::from_vec(
        3,
        n,
        vec![
            0.8, -0.3, 0.5, 0.0, //
            -0.6, 0.7, -0.2, 0.4, //
            0.1, -0.9, 0.3, -0.5,
        ],
    );
    let p_w = [0.5, 0.3, 0.2];
    let q_cents = Mat::from_vec(2, n, vec![0.7, -0.2, 0.4, 0.1, -0.5, 0.6, -0.3, 0.3]);
    let q_w = [0.6, 0.4];
    let law = FrequencyLaw::AdaptedRadius;
    let sigma = 1.0;

    // ---- Reference values by Monte Carlo over ω ~ Λ.
    let mut rng = Rng::new(cfg.seed);
    let big = DrawnFrequencies::draw(law, n, cfg.reference_draws, sigma, &mut rng);
    let f1 = signature.fourier_coeff(1).abs();
    let mut gamma2 = 0.0;
    let mut c_p = 0.0;
    for j in 0..cfg.reference_draws {
        let w = big.omega.col(j);
        let (pr, pi) = char_fn(&p_cents, &p_w, &w);
        let (qr, qi) = char_fn(&q_cents, &q_w, &w);
        gamma2 += (pr - qr).powi(2) + (pi - qi).powi(2);
        // c_P term: Σ_{k≥2} (|F_k|²/|F₁|²) |φ_P(kω)|² (±k symmetric).
        // The square wave's |F_k| ~ 1/k decays slowly; truncating at 201
        // leaves a c_P tail < 1e-3, below the m = 2048 deviation floor.
        for k in 2..=201 {
            let fk = signature.fourier_coeff(k);
            if fk == 0.0 {
                continue;
            }
            let kw: Vec<f64> = w.iter().map(|v| v * k as f64).collect();
            let (r, i) = char_fn(&p_cents, &p_w, &kw);
            c_p += (fk * fk) / (f1 * f1) * (r * r + i * i);
        }
    }
    gamma2 /= cfg.reference_draws as f64;
    c_p /= cfg.reference_draws as f64;

    // ---- Sweep m.
    let mut mean_dev = Vec::with_capacity(cfg.ms.len());
    let mut p95_dev = Vec::with_capacity(cfg.ms.len());
    for (mi, &m) in cfg.ms.iter().enumerate() {
        let mut devs = Vec::with_capacity(cfg.repeats);
        for rep in 0..cfg.repeats {
            let mut r = Rng::new(cfg.seed)
                .substream(1 + mi as u64)
                .substream(rep as u64);
            let freqs = DrawnFrequencies::draw(law, n, m, sigma, &mut r);
            let op = SketchOperator::new(freqs, signature.clone());
            // A_f(P): exact expectation for a Dirac mixture = Σ α_k f-encode(c_k).
            let mut a_f_p = vec![0.0; op.sketch_len()];
            for (k, &a) in p_w.iter().enumerate() {
                let e = op.encode_point(p_cents.row(k));
                crate::linalg::axpy(a, &e, &mut a_f_p);
            }
            // A_{f1}(Q): first-harmonic atoms.
            let a_f1_q = op.mixture_sketch(&q_cents, &q_w);
            let d2: f64 = a_f_p
                .iter()
                .zip(&a_f1_q)
                .map(|(a, b)| (a - b) * (a - b))
                .sum();
            // Paper normalization: (2m'|F₁|²)⁻¹ ‖·‖² over m' slots. Our
            // layout has S = 2m real slots (two dithers per frequency), so
            // the normalizer is 2·S·|F₁|² = 4m|F₁|².
            let normalized = d2 / (2.0 * op.sketch_len() as f64 * f1 * f1);
            devs.push((normalized - gamma2 - c_p).abs());
        }
        devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        mean_dev.push(devs.iter().sum::<f64>() / devs.len() as f64);
        p95_dev.push(devs[(devs.len() as f64 * 0.95) as usize - 1]);
    }

    // Fit log(mean_dev) = a + b log(m): slope b ≈ −1/2.
    let xs: Vec<f64> = cfg.ms.iter().map(|&m| (m as f64).ln()).collect();
    let ys: Vec<f64> = mean_dev.iter().map(|d| d.max(1e-300).ln()).collect();
    let xm = xs.iter().sum::<f64>() / xs.len() as f64;
    let ym = ys.iter().sum::<f64>() / ys.len() as f64;
    let num: f64 = xs.iter().zip(&ys).map(|(x, y)| (x - xm) * (y - ym)).sum();
    let den: f64 = xs.iter().map(|x| (x - xm) * (x - xm)).sum();
    let decay_exponent = num / den;

    Prop1Result {
        signature: sig_name,
        ms: cfg.ms.clone(),
        mean_dev,
        p95_dev,
        gamma2,
        c_p,
        decay_exponent,
    }
}

impl Prop1Result {
    pub fn render(&self) -> String {
        let mut out = format!(
            "== Prop. 1 concentration (signature: {}) ==\n\
             reference: gamma^2 = {:.5}, c_P = {:.5}\n\n\
             {:>6} {:>12} {:>12}\n",
            self.signature, self.gamma2, self.c_p, "m", "mean |dev|", "p95 |dev|"
        );
        for (i, &m) in self.ms.iter().enumerate() {
            out.push_str(&format!(
                "{m:>6} {:>12.5} {:>12.5}\n",
                self.mean_dev[i], self.p95_dev[i]
            ));
        }
        out.push_str(&format!(
            "\nfitted decay m^b: b = {:.3} (theory: -0.5)\n",
            self.decay_exponent
        ));
        out
    }
}
