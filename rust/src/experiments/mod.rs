//! Experiment harnesses regenerating every figure of the paper's evaluation
//! (Sec. 5), plus a numeric validation of Prop. 1 and the signature/bit-depth
//! ablations. See DESIGN.md §Experiment-index for the figure ↔ module map
//! and EXPERIMENTS.md for recorded runs.

mod ablation;
mod common;
mod fig2;
mod fig3;
mod prop1;

pub use ablation::{run_ablation, AblationConfig};
pub use common::{run_method_once, MethodRun, TrialOutcome};
pub use fig2::{run_fig2, Fig2Config, Fig2Result, Fig2Variant};
pub use fig3::{run_fig3, Fig3Config, Fig3Result};
pub use prop1::{run_prop1, Prop1Config, Prop1Result};

#[cfg(test)]
mod tests;
