//! Signature / bit-depth ablation (beyond the paper's figures; DESIGN.md
//! lists it as the design-choice ablation for the generalized sketch of
//! Sec. 3).
//!
//! On a fixed Fig.-2a-style mixture, sweep the method spec — cosine (CKM),
//! the B-bit staircase interpolation `qckm[:bits=B]` for B ∈ {1, 2, 3, 4},
//! the triangle wave, and the self-reset modulo ramp — at several
//! measurement budgets and report success rates and *acquired bits per
//! example*, making the paper's resource trade-off (`m` bits for QCKM vs
//! `64·2m` for full-precision CKM) explicit. Every arm resolves through
//! the open method registry ([`crate::method`]), so the sweep is exactly
//! the operator `qckm sketch --method <spec>` would build.

use crate::clompr::ClOmprParams;
use crate::data::gaussian_mixture_pm1;
use crate::decoder::DecoderSpec;
use crate::frequency::{FrequencyLaw, SigmaHeuristic};
use crate::kmeans::{kmeans, KMeansParams};
use crate::method::MethodSpec;
use crate::metrics::is_success;
use crate::parallel::{self, Parallelism};
use crate::rng::Rng;
use crate::sketch::SketchOperator;

#[derive(Clone, Debug)]
pub struct AblationConfig {
    pub n: usize,
    pub k: usize,
    pub n_samples: usize,
    pub ratios: Vec<f64>,
    pub trials: usize,
    pub seed: u64,
    /// The decoding algorithm every arm routes through
    /// ([`crate::decoder`] registry spec). Default `clompr` keeps the
    /// legacy staircase ablation bit for bit.
    pub decoder: DecoderSpec,
    /// Threads for the trial fan-out (0 = all cores); results are
    /// bit-for-bit identical at any setting (per-trial RNG substreams).
    pub threads: usize,
}

impl Default for AblationConfig {
    fn default() -> Self {
        Self {
            n: 8,
            k: 2,
            n_samples: 4096,
            ratios: vec![1.0, 2.0, 4.0],
            trials: 10,
            seed: 0xAB1A,
            decoder: DecoderSpec::default(),
            threads: 0,
        }
    }
}

/// The swept method specs: the full B ∈ {1, 2, 3, 4} staircase
/// interpolation between QCKM and CKM, plus the non-quantizer signatures.
const ARM_SPECS: [&str; 7] = [
    "ckm",
    "qckm",
    "qckm:bits=2",
    "qckm:bits=3",
    "qckm:bits=4",
    "triangle",
    "modulo",
];

/// Success rate per (arm, ratio) and the per-example acquisition cost.
pub struct AblationResult {
    /// Display names of the swept specs ([`MethodSpec::display_name`]).
    pub labels: Vec<String>,
    pub ratios: Vec<f64>,
    pub success: Vec<Vec<f64>>,
    /// bits per example at each (arm, ratio).
    pub bits_per_example: Vec<Vec<f64>>,
}

pub fn run_ablation(cfg: &AblationConfig) -> AblationResult {
    let arms: Vec<MethodSpec> = ARM_SPECS
        .iter()
        .map(|s| MethodSpec::parse(s).expect("registry spec"))
        .collect();

    // The per-example acquisition cost depends only on the grid, not the
    // trials: fill it up front.
    let mut bits = vec![vec![0.0; cfg.ratios.len()]; arms.len()];
    for (ai, arm) in arms.iter().enumerate() {
        for (ri, &ratio) in cfg.ratios.iter().enumerate() {
            let m = ((ratio * (cfg.n * cfg.k) as f64).round() as usize).max(2);
            bits[ai][ri] = 2.0 * m as f64 * arm.bits_per_slot();
        }
    }

    // Trials fan out across threads (per-trial substreams, ordered merge —
    // bit-for-bit identical at any thread count, see `crate::parallel`).
    let par = Parallelism::fixed(cfg.threads);
    let flags: Vec<Vec<Vec<bool>>> = parallel::par_map(cfg.trials, &par, |trial| {
        let mut rng = Rng::new(cfg.seed).substream(trial as u64);
        let data = gaussian_mixture_pm1(cfg.n_samples, cfg.n, cfg.k, &mut rng);
        let sigma = SigmaHeuristic::default().resolve(&data.points, &mut rng);
        let km = kmeans(
            &data.points,
            cfg.k,
            &KMeansParams {
                replicates: 5,
                ..Default::default()
            },
            &mut rng,
        );
        let mut trial_flags = vec![vec![false; cfg.ratios.len()]; arms.len()];
        for (ai, arm) in arms.iter().enumerate() {
            for (ri, &ratio) in cfg.ratios.iter().enumerate() {
                let m = ((ratio * (cfg.n * cfg.k) as f64).round() as usize).max(2);
                let freqs = if arm.dithered() {
                    crate::frequency::DrawnFrequencies::draw(
                        FrequencyLaw::AdaptedRadius,
                        cfg.n,
                        m,
                        sigma,
                        &mut rng,
                    )
                } else {
                    crate::frequency::DrawnFrequencies::draw_undithered(
                        FrequencyLaw::AdaptedRadius,
                        cfg.n,
                        m,
                        sigma,
                        &mut rng,
                    )
                };
                let op = SketchOperator::new(freqs, arm.signature());
                let z = op.sketch_dataset(&data.points);
                let (lo, hi) = crate::linalg::bounding_box(&data.points);
                // Routed through the decoder registry; `clompr` with the
                // default base params is bitwise the old direct ClOmpr run.
                let sol = cfg.decoder.decode_best_of(
                    &op,
                    cfg.k,
                    &z,
                    lo,
                    hi,
                    &ClOmprParams::default(),
                    1,
                    &mut rng,
                );
                let s = crate::metrics::sse(&data.points, &sol.centroids);
                trial_flags[ai][ri] = is_success(s, km.sse);
            }
        }
        trial_flags
    });

    let mut success = vec![vec![0.0; cfg.ratios.len()]; arms.len()];
    for trial_flags in &flags {
        for (ai, row) in trial_flags.iter().enumerate() {
            for (ri, &hit) in row.iter().enumerate() {
                if hit {
                    success[ai][ri] += 1.0;
                }
            }
        }
    }
    for row in success.iter_mut() {
        for v in row.iter_mut() {
            *v /= cfg.trials as f64;
        }
    }
    AblationResult {
        labels: arms.iter().map(|a| a.display_name().to_string()).collect(),
        ratios: cfg.ratios.clone(),
        success,
        bits_per_example: bits,
    }
}

impl AblationResult {
    pub fn render(&self) -> String {
        let mut out = String::from("== Signature / bit-depth ablation ==\n");
        out.push_str(&format!("{:<24}", "arm"));
        for r in &self.ratios {
            out.push_str(&format!("  m/nK={r:<4} (bits/ex)"));
        }
        out.push('\n');
        for (ai, label) in self.labels.iter().enumerate() {
            out.push_str(&format!("{label:<24}"));
            for ri in 0..self.ratios.len() {
                out.push_str(&format!(
                    "  {:>5.0}%   ({:>6.0})",
                    100.0 * self.success[ai][ri],
                    self.bits_per_example[ai][ri]
                ));
            }
            out.push('\n');
        }
        out
    }
}
