//! Shared plumbing for the experiment harnesses.

use crate::clompr::ClOmprParams;
use crate::coordinator::WireFormat;
use crate::decoder::DecoderSpec;
use crate::frequency::{DrawnFrequencies, FrequencyLaw};
use crate::linalg::{bounding_box, Mat};
use crate::method::MethodSpec;
use crate::metrics::{adjusted_rand_index, assign_labels, sse};
use crate::parallel::Parallelism;
use crate::rng::Rng;
use crate::sketch::{PooledSketch, SketchOperator};
use crate::stream::{sketch_reader, MatChunkedReader};

/// One compressive-method run on one dataset.
#[derive(Clone, Debug)]
pub struct MethodRun {
    pub method: MethodSpec,
    /// Frequencies M (sketch length 2M).
    pub m: usize,
    pub replicates: usize,
    pub sigma: f64,
    pub law: FrequencyLaw,
    pub params: ClOmprParams,
    /// The decoding algorithm ([`crate::decoder`] registry spec); the
    /// default `clompr` reproduces the legacy trials bit for bit.
    pub decoder: DecoderSpec,
    /// Pool the sketch through the out-of-core streaming fold
    /// ([`crate::stream`]) instead of the in-memory encode. Identical to
    /// the in-memory sketch for ±1 signatures (exact integer sums) and for
    /// datasets of at most one 4096-row chunk; beyond that the chunked
    /// reduction order may differ from `sketch_dataset`'s continuous fold
    /// in the last ulp (it always equals `sketch_dataset_par`).
    pub streamed: bool,
}

/// Metrics of one trial.
#[derive(Clone, Debug)]
pub struct TrialOutcome {
    pub sse: f64,
    pub ari: f64,
    pub objective: f64,
}

/// Sketch `x` with the run's operator and decode K centroids from it.
///
/// `rng` drives the frequency draw, the decoder restarts, and nothing else;
/// data generation happens at the caller so methods can share datasets.
pub fn run_method_once(
    run: &MethodRun,
    x: &Mat,
    truth_labels: Option<&[usize]>,
    k: usize,
    rng: &mut Rng,
) -> TrialOutcome {
    let n = x.cols();
    let freqs = if run.method.dithered() {
        DrawnFrequencies::draw(run.law, n, run.m, run.sigma, rng)
    } else {
        DrawnFrequencies::draw_undithered(run.law, n, run.m, run.sigma, rng)
    };
    let op = SketchOperator::new(freqs, run.method.signature());
    let z = if run.streamed {
        let mut pool = PooledSketch::new(op.sketch_len());
        sketch_reader(
            &op,
            &mut MatChunkedReader::new(x),
            WireFormat::DenseF64,
            &mut pool,
            &Parallelism::serial(),
        )
        .expect("in-memory streaming cannot fail");
        pool.mean()
    } else {
        op.sketch_dataset(x)
    };
    let (lo, hi) = bounding_box(x);
    let sol = run
        .decoder
        .decode_best_of(&op, k, &z, lo, hi, &run.params, run.replicates, rng);
    let s = sse(x, &sol.centroids);
    let ari = truth_labels
        .map(|t| adjusted_rand_index(&assign_labels(x, &sol.centroids), t))
        .unwrap_or(f64::NAN);
    TrialOutcome {
        sse: s,
        ari,
        objective: sol.objective,
    }
}

/// Render a success-rate grid (rows = parameter values, cols = ratios) as
/// an ASCII heatmap, 0%…100% mapped to ' .:-=+*#%@'.
pub fn ascii_heatmap(rows: &[String], cols: &[f64], grid: &[Vec<f64>]) -> String {
    const RAMP: &[u8] = b" .:-=+*#%@";
    let mut out = String::new();
    out.push_str("            m/(nK): ");
    for c in cols {
        out.push_str(&format!("{c:>6.2}"));
    }
    out.push('\n');
    for (label, row) in rows.iter().zip(grid) {
        out.push_str(&format!("{label:>18}  "));
        for &v in row {
            let idx = ((v.clamp(0.0, 1.0)) * (RAMP.len() - 1) as f64).round() as usize;
            let ch = RAMP[idx] as char;
            out.push_str(&format!("     {ch}"));
        }
        out.push('\n');
    }
    out
}

/// For one row of success rates, the smallest ratio with ≥ 50% success
/// (`None` if never reached) — the paper's red/yellow transition lines.
pub fn transition_ratio(ratios: &[f64], successes: &[f64]) -> Option<f64> {
    ratios
        .iter()
        .zip(successes)
        .find(|(_, &s)| s >= 0.5)
        .map(|(&r, _)| r)
}
