//! Fig. 3 — clustering a spectral-embedding-like real-data stand-in.
//!
//! The paper clusters a 10-dim spectral-clustering embedding of MNIST
//! (N = 70000, K = 10, m = 1000) and reports SSE/N and ARI (mean ± std over
//! 100 runs) for k-means, CKM and QCKM at 1 and 5 algorithm replicates.
//! The private embedding is substituted by
//! [`crate::data::spectral_embedding_like`] (DESIGN.md §Substitutions);
//! compressive replicates are selected by the *sketch-matching objective*,
//! never the SSE (the compressive algorithms don't get the data).

use super::common::{run_method_once, MethodRun};
use crate::clompr::ClOmprParams;
use crate::data::spectral_embedding_like;
use crate::decoder::DecoderSpec;
use crate::frequency::{FrequencyLaw, SigmaHeuristic};
use crate::kmeans::{kmeans, KMeansParams};
use crate::method::MethodSpec;
use crate::metrics::{adjusted_rand_index, RunningStats};
use crate::parallel::{self, Parallelism};
use crate::rng::Rng;

#[derive(Clone, Debug)]
pub struct Fig3Config {
    pub n_samples: usize,
    pub dim: usize,
    pub k: usize,
    /// Frequencies M (paper: 1000).
    pub m: usize,
    pub trials: usize,
    /// Replicate counts reported side by side (paper: 1 and 5).
    pub replicate_levels: Vec<usize>,
    pub sigma: SigmaHeuristic,
    pub law: FrequencyLaw,
    pub seed: u64,
    pub decoder: ClOmprParams,
    /// The decoding algorithm every compressive trial routes through
    /// ([`crate::decoder`] registry spec; `decoder` above is its base
    /// tuning). Default `clompr` = the paper's CL-OMPR.
    pub decoder_spec: DecoderSpec,
    /// Threads for the trial fan-out (0 = all cores). Per-trial RNG
    /// substreams make results bit-for-bit identical at any setting.
    pub threads: usize,
}

impl Fig3Config {
    pub fn quick() -> Self {
        Self {
            n_samples: 10_000,
            dim: 10,
            k: 10,
            m: 600,
            trials: 8,
            replicate_levels: vec![1, 5],
            sigma: SigmaHeuristic::default(),
            law: FrequencyLaw::AdaptedRadius,
            seed: 0x0F13,
            decoder: ClOmprParams::default(),
            decoder_spec: DecoderSpec::default(),
            threads: 0,
        }
    }

    /// Paper-scale: N = 70000, m = 1000, 100 trials.
    pub fn full() -> Self {
        let mut cfg = Self::quick();
        cfg.n_samples = 70_000;
        cfg.m = 1000;
        cfg.trials = 100;
        cfg
    }
}

/// Per-(method, replicate-level) mean ± std of SSE/N and ARI.
#[derive(Clone, Debug)]
pub struct Fig3Result {
    pub config_desc: String,
    /// Row labels like "k-means x5".
    pub rows: Vec<String>,
    pub sse_per_n: Vec<(f64, f64)>,
    pub ari: Vec<(f64, f64)>,
}

pub fn run_fig3(cfg: &Fig3Config) -> Fig3Result {
    let methods = [
        MethodSpec::parse("ckm").expect("registry spec"),
        MethodSpec::parse("qckm").expect("registry spec"),
    ];
    let levels = &cfg.replicate_levels;
    // Accumulators: k-means rows first, then (method × level).
    let n_rows = levels.len() * (1 + methods.len());
    let mut sse_stats = vec![RunningStats::default(); n_rows];
    let mut ari_stats = vec![RunningStats::default(); n_rows];
    let mut rows = Vec::with_capacity(n_rows);
    for &lvl in levels {
        rows.push(format!("k-means x{lvl}"));
    }
    for method in &methods {
        for &lvl in levels {
            rows.push(format!("{} x{lvl}", method.canonical()));
        }
    }

    // Trials fan out across threads; each returns its (SSE/N, ARI) pairs in
    // row order, and the ordered merge below reproduces the serial stream
    // of RunningStats pushes exactly, at any thread count.
    let par = Parallelism::fixed(cfg.threads);
    let per_trial: Vec<Vec<(f64, f64)>> = parallel::par_map(cfg.trials, &par, |trial| {
        let mut rng = Rng::new(cfg.seed).substream(trial as u64);
        let data = spectral_embedding_like(cfg.n_samples, cfg.dim, cfg.k, &mut rng);
        let sigma = cfg.sigma.resolve(&data.points, &mut rng);
        let mut rows_out: Vec<(f64, f64)> = Vec::with_capacity(n_rows);

        // k-means at each replicate level (selected by SSE, as in practice).
        for &lvl in levels {
            let km = kmeans(
                &data.points,
                cfg.k,
                &KMeansParams {
                    replicates: lvl,
                    ..Default::default()
                },
                &mut rng,
            );
            rows_out.push((
                km.sse / cfg.n_samples as f64,
                adjusted_rand_index(&km.labels, &data.labels),
            ));
        }

        // Compressive methods (replicates selected by sketch objective).
        for method in &methods {
            for &lvl in levels {
                let run = MethodRun {
                    method: method.clone(),
                    m: cfg.m,
                    replicates: lvl,
                    sigma,
                    law: cfg.law,
                    params: cfg.decoder.clone(),
                    decoder: cfg.decoder_spec.clone(),
                    streamed: false,
                };
                let out = run_method_once(&run, &data.points, Some(&data.labels), cfg.k, &mut rng);
                rows_out.push((out.sse / cfg.n_samples as f64, out.ari));
            }
        }
        eprintln!("  fig3 trial {}/{} done", trial + 1, cfg.trials);
        rows_out
    });
    for rows_out in &per_trial {
        for (row, &(s, a)) in rows_out.iter().enumerate() {
            sse_stats[row].push(s);
            ari_stats[row].push(a);
        }
    }

    Fig3Result {
        config_desc: format!(
            "N = {}, n = {}, K = {}, m = {}, {} trials, decoder {}",
            cfg.n_samples,
            cfg.dim,
            cfg.k,
            cfg.m,
            cfg.trials,
            cfg.decoder_spec.canonical()
        ),
        rows,
        sse_per_n: sse_stats.iter().map(|s| (s.mean(), s.std())).collect(),
        ari: ari_stats.iter().map(|s| (s.mean(), s.std())).collect(),
    }
}

impl Fig3Result {
    pub fn render(&self) -> String {
        let mut out = format!(
            "== Fig. 3 spectral-features clustering ==\n{}\n\n",
            self.config_desc
        );
        out.push_str(&format!(
            "{:<16} {:>10} {:>8}    {:>7} {:>7}\n",
            "algorithm", "SSE/N", "±std", "ARI", "±std"
        ));
        for (i, row) in self.rows.iter().enumerate() {
            let (s, ss) = self.sse_per_n[i];
            let (a, as_) = self.ari[i];
            out.push_str(&format!(
                "{row:<16} {s:>10.4} {ss:>8.4}    {a:>7.3} {as_:>7.3}\n"
            ));
        }
        out
    }
}
